"""Multi-device distribution tests (subprocess: needs its own XLA device
flag, which must not leak into this process)."""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=540,
    )
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    """One real train step on a 2x4 mesh == the same step unsharded."""
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import smoke_config
        from repro.models import init_params
        from repro.optim import AdamWConfig, init as opt_init
        from repro.train import make_train_step
        from repro.launch.sharding import params_shardings, opt_shardings, batch_shardings

        cfg = smoke_config("internlm2-1.8b", d_model=64, n_heads=4, n_kv_heads=4)
        params = init_params(cfg, jax.random.key(0))
        opt = opt_init(params)
        batch = {"tokens": jax.random.randint(jax.random.key(1), (8, 32), 0, cfg.vocab),
                 "labels": jax.random.randint(jax.random.key(2), (8, 32), 0, cfg.vocab)}
        step = make_train_step(cfg, AdamWConfig(total_steps=10))
        # single device
        p1, o1, m1 = jax.jit(step)(params, opt, batch)

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        p_sh = params_shardings(cfg, mesh, jax.eval_shape(lambda: params))
        o_sh = opt_shardings(cfg, mesh, jax.eval_shape(lambda: opt), jax.eval_shape(lambda: params))
        b_sh = batch_shardings(cfg, mesh, {k: jax.eval_shape(lambda v=v: v) for k, v in batch.items()})
        with mesh:
            p2, o2, m2 = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh))(params, opt, batch)
        err = abs(float(m1["loss"]) - float(m2["loss"]))
        assert err < 5e-3, err  # bf16 forward, shard-order-dependent sums
        d = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
        assert d < 5e-3, d
        print("SHARDED_OK", err, d)
    """)
    assert "SHARDED_OK" in out


@pytest.mark.slow
def test_explicit_compressed_dp_matches_psum():
    """shard_map int8-EF compressed all-reduce across 8 real devices sums
    gradients equivalently to plain psum (within quantization error)."""
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.optim import CompressionConfig, compressed_psum

        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.normal(size=(8, 512)).astype(np.float32))
        err = jnp.zeros((8, 512), jnp.float32)
        cfg = CompressionConfig(mode="int8_ef", block=64)
        def f(g, e):
            out, ne = compressed_psum(g[0], e[0], cfg, ("data",))
            return out[None], ne[None]
        from repro.compat import shard_map
        with mesh:
            out, _ = jax.jit(shard_map(
                f, mesh=mesh, in_specs=(P("data"), P("data")),
                out_specs=(P("data"), P("data"))))(g, err)
        want = np.asarray(g).sum(0)
        got = np.asarray(out)[0]
        rel = np.linalg.norm(got - want) / np.linalg.norm(want)
        assert rel < 0.02, rel
        print("COMPRESS_OK", rel)
    """)
    assert "COMPRESS_OK" in out


@pytest.mark.slow
def test_dryrun_cell_smoke():
    """The dry-run entry point itself works end-to-end for one cell on a
    reduced mesh proxy (the full 512-device sweep runs via __main__)."""
    out = run_sub("""
        import jax
        from repro.launch.specs import build_case
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        case = build_case("internlm2-1.8b", "decode_32k", scan_layers=True)
        in_sh, out_sh = case.shardings(mesh)
        with mesh:
            c = jax.jit(case.fn, in_shardings=in_sh, out_shardings=out_sh,
                        donate_argnums=case.donate).lower(*case.args).compile()
        assert c.memory_analysis() is not None
        print("DRYRUN_OK")
    """)
    assert "DRYRUN_OK" in out
