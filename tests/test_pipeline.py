"""Pipeline parallelism: GPipe schedule == unpipelined stack (subprocess
with 4 virtual devices)."""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
def test_pipeline_matches_sequential():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from jax import lax
        from repro.configs import smoke_config
        from repro.models import init_params
        from repro.models.transformer import _attn_layer
        from repro.launch.pipeline import make_pipe_mesh, pipeline_apply, stack_stages

        cfg = smoke_config("internlm2-1.8b", n_layers=8, dtype="float32")
        params = init_params(cfg, jax.random.key(0))
        B, S = 2, 16
        x = jax.random.normal(jax.random.key(1), (4, B, S, cfg.d_model))
        pos = jnp.arange(S)[None, :]

        def stage_fn(stage_params, h):
            def body(c, lp):
                return _attn_layer(lp, c, cfg, pos), None
            h, _ = lax.scan(body, h, stage_params)
            return h

        # sequential reference over all 8 layers, microbatch by microbatch
        ref = jnp.stack([stage_fn(params["layers"], x[i]) for i in range(4)])

        mesh = make_pipe_mesh(4)
        staged = stack_stages(params["layers"], 4)
        with mesh:
            out = pipeline_apply(stage_fn, staged, x, mesh)
        err = float(jnp.max(jnp.abs(out - ref)) / jnp.max(jnp.abs(ref)))
        assert err < 1e-5, err

        # AD through the pipeline (training viability)
        def loss_pipe(p):
            return pipeline_apply(stage_fn, p, x, mesh).sum()
        def loss_ref(p):
            return jnp.stack([stage_fn(p["layers"], x[i]) for i in range(4)]).sum()
        with mesh:
            g_pipe = jax.grad(loss_pipe)(staged)
        g_ref = stack_stages(jax.grad(loss_ref)(params)["layers"], 4)
        gerr = max(float(jnp.max(jnp.abs(a - b)))
                   for a, b in zip(jax.tree.leaves(g_pipe), jax.tree.leaves(g_ref)))
        scale = max(float(jnp.max(jnp.abs(a))) for a in jax.tree.leaves(g_ref))
        assert gerr < 1e-4 * max(scale, 1.0), (gerr, scale)
        print("PIPE_OK", err, gerr)
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=540)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "PIPE_OK" in out.stdout
