"""Wire-level switching-activity telemetry (DESIGN.md §15).

Pins the tentpole invariants: the kernels' ``activity_windows=`` output is
bit-exact across backends and chunked/sharded execution, per-wire toggles
sum to the same gross BT the scalar accounting reports (on every measured
link, for every ordering x codec), the sequential numpy reference
reproduces the kernel per wire AND per window, uniform-capacitance
``wire_energy_pj`` equals the scalar energy expressions exactly, and the
SAIF/VCD exports round-trip consistently with the heatmap CSV.
"""

import csv

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given
from hypothesis import strategies as st

from repro.kernels import (
    CodecVariant,
    bt_count_axes,
    bt_count_axes_sharded,
    bt_count_codecs,
    bt_count_links,
)
from repro.link import LinkPowerModel, LinkSpec
from repro.noc import TrafficFlow, simulate_noc
from repro.noc.power import NocPowerModel
from repro.noc.topology import mesh
from repro.obs import (
    ActivityProfile,
    link_profiles,
    parse_saif,
    profile_from_arrays,
    profiles_from_noc,
    wire_name,
    write_saif,
    write_vcd,
    write_wires_csv,
)

_CONFIGS = (
    CodecVariant("none"),
    CodecVariant("none", codec="gray"),
    CodecVariant("none", codec="sign_magnitude"),
    CodecVariant("none", codec="transition"),
    CodecVariant("none", codec="bus_invert", partition=None),
    CodecVariant("none", codec="bus_invert", partition=4),
    CodecVariant("acc", codec="bus_invert", partition=None),
    CodecVariant("acc", codec="transition"),
    CodecVariant("app", k=4, codec="gray"),
)


def _stream(p, n, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, 256, (p, n), dtype=np.uint8))


# ----------------------------------------------------- numpy reference


def _ref_wire(stream, codec, npart):
    """Sequential wire image: (T, lanes) data -> (wire rows, invert rows)."""
    d = np.asarray(stream, np.int64) & 0xFF
    t, lanes = d.shape
    if codec in ("none", "gray", "sign_magnitude"):
        if codec == "gray":
            d = d ^ (d >> 1)
        elif codec == "sign_magnitude":
            neg = d >= 0x80
            mag = np.where(neg, (0x100 - d) & 0xFF, d)
            d = np.where(neg, 0x80 | (mag & 0x7F), mag)
        return d, None
    if codec == "transition":
        w = np.zeros_like(d)
        prev = np.zeros(lanes, np.int64)
        for i in range(t):
            w[i] = prev ^ d[i]
            prev = w[i]
        return w, None
    pw = lanes // npart
    dg = d.reshape(t, npart, pw)
    v = np.zeros((t, npart), np.int64)
    w = np.zeros_like(dg)
    prevw = None
    for i in range(t):
        if i:
            hd = np.array([
                bin(int(x)).count("1") for x in (dg[i] ^ prevw).flatten()
            ]).reshape(npart, pw).sum(-1)
            v[i] = (2 * hd > 8 * pw).astype(np.int64)
        w[i] = dg[i] ^ (v[i][:, None] * 0xFF)
        prevw = w[i]
    return w.reshape(t, lanes), v


def _ref_activity(stream, codec, npart, wlen, nwires):
    """(toggles (NW, nwires), ones (nwires,)) by direct simulation."""
    t, lanes = np.asarray(stream).shape
    w, v = _ref_wire(stream, codec, npart)
    bits = ((w[:, :, None] >> np.arange(8)) & 1).reshape(t, lanes * 8)
    tog = np.zeros((-(-t // wlen), nwires), np.int64)
    for i in range(1, t):
        tog[i // wlen, : lanes * 8] += bits[i] ^ bits[i - 1]
        if v is not None:
            tog[i // wlen, lanes * 8 : lanes * 8 + npart] += v[i] ^ v[i - 1]
    ones = np.zeros(nwires, np.int64)
    ones[: lanes * 8] = bits.sum(0)
    if v is not None:
        ones[lanes * 8 : lanes * 8 + npart] = v.sum(0)
    return tog, ones


# --------------------------------------------------- kernel bit-exactness


def test_activity_matches_sequential_reference_per_wire_and_window():
    """Identity orderings: the kernel's toggle tensor and time-at-1 equal
    direct sequential simulation of the coded wire, for every codec."""
    p, n, lanes, wlen = 13, 16, 8, 5
    x = _stream(p, n, seed=3)
    flits = n // lanes
    out = bt_count_axes(
        x[None], None, configs=_CONFIGS, input_lanes=lanes,
        block_packets=4, activity_windows=wlen,
    )
    nwires = out.toggles.shape[-1]
    stream = np.asarray(
        np.asarray(x, np.int64).reshape(p, lanes, flits)
        .transpose(0, 2, 1).reshape(p * flits, lanes)
    )
    for ci, cfg in enumerate(_CONFIGS):
        if cfg.key != "none":
            continue  # the stream the kernel orders is x as-is only here
        npart = 0
        if cfg.codec == "bus_invert":
            npart = 1 if cfg.partition is None else lanes // cfg.partition
        tog, ones = _ref_activity(stream, cfg.codec, npart, wlen, nwires)
        np.testing.assert_array_equal(
            np.asarray(out.toggles)[0, ci], tog, err_msg=str(cfg)
        )
        np.testing.assert_array_equal(
            np.asarray(out.ones)[0, ci], ones, err_msg=str(cfg)
        )


def test_activity_bit_exact_across_backends_chunked_sharded():
    """The acceptance matrix: compiled vs interpret, chunked vs single
    shot, sharded vs unsharded all produce identical activity tensors,
    and the bt plane never drifts from the activity-free measurement."""
    p, n, lanes = 22, 16, 8
    x = _stream(p, n, seed=5)[None]
    kw = dict(
        configs=_CONFIGS, input_lanes=lanes, block_packets=4,
        activity_windows=3,
    )
    ref = bt_count_axes(x, None, backend="compiled", **kw)
    plain = bt_count_axes(
        x, None, configs=_CONFIGS, input_lanes=lanes, block_packets=4,
        backend="compiled",
    )
    np.testing.assert_array_equal(np.asarray(ref.bt), np.asarray(plain))
    variants = {
        "interpret": bt_count_axes(x, None, backend="interpret", **kw),
        "chunk7": bt_count_axes(
            x, None, backend="compiled", chunk_packets=7, **kw
        ),
        "chunk4": bt_count_axes(
            x, None, backend="compiled", chunk_packets=4, **kw
        ),
        "sharded": bt_count_axes_sharded(x, None, **kw),
    }
    for label, got in variants.items():
        for field, a, b in zip(ref._fields, ref, got):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b), err_msg=f"{label}/{field}"
            )


@pytest.mark.parametrize("width", [4, 8])
@pytest.mark.parametrize(
    "ordering,codec,partition",
    [
        ("none", "none", None),
        ("none", "bus_invert", 4),
        ("acc", "gray", None),
        ("acc", "transition", None),
        ("acc", "bus_invert", None),
        ("app", "sign_magnitude", None),
    ],
)
def test_per_wire_sums_to_gross_bt(width, ordering, codec, partition):
    """The tentpole invariant, ordering x codec x width 4/8 at a P that is
    not a multiple of the kernel block: per-wire toggles sum exactly to
    the gross BT (data + aux) the scalar accounting reports."""
    cfg = CodecVariant(
        ordering, 4 if ordering == "app" else None, False, codec, partition
    )
    x = _stream(11, 16, seed=width)  # P=11, block_packets=4 -> ragged block
    out = bt_count_axes(
        x[None], None, configs=(cfg,), input_lanes=8, width=width,
        block_packets=4, activity_windows=6,
    )
    gross = int(np.asarray(out.bt)[0, 0].sum())
    assert int(np.asarray(out.toggles)[0, 0].sum()) == gross
    # and the per-wire vector prices identically through the power model
    per_wire = np.asarray(out.toggles)[0, 0].sum(axis=0)
    pm = LinkPowerModel()
    extra = int((per_wire[64:] > 0).sum())  # active aux wires
    assert pm.wire_energy_pj(
        per_wire[: 64 + extra], 22, extra_wires=extra
    ) == pm.coded_link_energy_pj(
        int(per_wire[:64].sum()), int(per_wire[64:].sum()), 22, 64, extra
    )


@given(
    seed=st.integers(0, 2**16),
    p=st.integers(1, 17),
    wlen=st.integers(1, 9),
    ci=st.integers(0, len(_CONFIGS) - 1),
)
def test_property_per_wire_activity_sums_to_gross_bt(seed, p, wlen, ci):
    cfg = _CONFIGS[ci]
    x = _stream(p, 16, seed=seed)
    out = bt_count_axes(
        x[None], None, configs=(cfg,), input_lanes=8, block_packets=4,
        activity_windows=wlen,
    )
    assert int(np.asarray(out.toggles).sum()) == int(np.asarray(out.bt).sum())


def test_links_activity_jagged_lengths_match_reference():
    rng = np.random.default_rng(9)
    streams = jnp.asarray(rng.integers(0, 256, (3, 11, 8), dtype=np.uint8))
    lengths = (11, 7, 1)
    la = bt_count_links(streams, input_lanes=8, lengths=lengths,
                        activity_windows=4)
    bt = bt_count_links(streams, input_lanes=8, lengths=lengths)
    np.testing.assert_array_equal(np.asarray(la.bt), np.asarray(bt))
    for li, ln in enumerate(lengths):
        tog, ones = _ref_activity(
            np.asarray(streams)[li, :ln], "none", 0, 4, 64
        )
        got = np.asarray(la.toggles)[li]
        np.testing.assert_array_equal(got[: tog.shape[0]], tog)
        assert got[tog.shape[0]:].sum() == 0  # past-length windows empty
        np.testing.assert_array_equal(np.asarray(la.ones)[li], ones)


# --------------------------------------------------------- ActivityProfile


def test_profile_summaries_and_invariant_check():
    toggles = np.array([[3, 0, 1], [1, 0, 2]])
    ones = np.array([4, 0, 5])
    p = ActivityProfile("l0", 4, 8, 0, toggles, ones)  # 3 aux-only wires?
    # data_lanes=0 means every wire is aux — wire_name covers both kinds
    assert p.num_windows == 2 and p.num_wires == 3
    assert p.gross_bt == 7
    np.testing.assert_array_equal(p.per_wire, [4, 0, 3])
    np.testing.assert_array_equal(p.waveform, [4, 3])
    np.testing.assert_array_equal(p.t0, [4, 8, 3])
    p.check(7)
    with pytest.raises(ValueError, match="gross BT"):
        p.check(8)
    counts, edges = p.rate_histogram(bins=7)
    assert counts.sum() == 3 and len(edges) == 8
    assert p.hottest_wires(2) == [("inv0", 4), ("inv2", 3)]
    assert wire_name(0, 2) == "lane0_b0"
    assert wire_name(15, 2) == "lane1_b7"
    assert wire_name(16, 2) == "inv0"


def test_profile_rejects_inconsistent_shapes():
    with pytest.raises(ValueError, match="wires"):
        ActivityProfile("x", 4, 8, 2, np.zeros((2, 3)), np.zeros(3))
    with pytest.raises(ValueError, match="ones"):
        ActivityProfile("x", 4, 8, 0, np.zeros((2, 3)), np.zeros(2))
    with pytest.raises(ValueError, match="window_flits"):
        ActivityProfile("x", 0, 8, 0, np.zeros((2, 3)), np.zeros(3))


# ------------------------------------------------------------- SAIF / VCD


def test_saif_round_trip_consistent_with_heatmap_csv(tmp_path):
    """The acceptance criterion: the SAIF a run emits parses back with
    T0/T1/TC consistent with the per-wire heatmap CSV on every net."""
    streams = jnp.asarray(
        np.random.default_rng(2).integers(0, 256, (2, 9, 4), dtype=np.uint8)
    )
    lengths = (9, 5)
    la = bt_count_links(streams, input_lanes=4, lengths=lengths,
                        activity_windows=4)
    profs = link_profiles(la, window_flits=4, lengths=lengths, data_lanes=4)
    saif_path = str(tmp_path / "act.saif")
    csv_path = str(tmp_path / "wires.csv")
    write_saif(saif_path, profs, design="t")
    write_wires_csv(csv_path, profs)
    doc = parse_saif(saif_path)
    assert doc["duration"] == max(lengths)
    with open(csv_path) as f:
        rows = list(csv.DictReader(f))
    assert rows, "empty heatmap CSV"
    for r in rows:
        net = doc["instances"][r["profile"]][r["net"]]
        assert net["TC"] == int(r["toggles"])
        assert net["T1"] == int(r["t1"])
        assert net["TX"] == 0 and net["IG"] == 0
        assert net["T0"] + net["T1"] == doc["duration"]
    # total TC across the SAIF == total gross BT of the measurement
    total_tc = sum(
        net["TC"]
        for nets in doc["instances"].values()
        for net in nets.values()
    )
    assert total_tc == int(np.asarray(la.bt).sum())


def test_vcd_transitions_equal_profile_toggles(tmp_path):
    stream = np.random.default_rng(4).integers(0, 256, (7, 2), np.int64)
    text = write_vcd(str(tmp_path / "w.vcd"), stream, name="l")
    # count value-change lines after the $dumpvars block
    body = text.split("$end\n", 2)[-1].split("$dumpvars")[-1]
    changes = [
        ln for ln in body.splitlines()
        if ln and ln[0] in "01" and not ln.startswith("#")
    ]
    changes = changes[16:]  # drop the 16 initial-value dump lines
    prof = profile_from_arrays(
        "l", *_ref_activity(stream, "none", 0, 7, 16),
        window_flits=7, duration_flits=7, data_lanes=2,
    )
    assert len(changes) == prof.gross_bt


# ------------------------------------------------------- power refinement


def test_wire_energy_uniform_caps_reproduce_scalar_model_exactly():
    pm = LinkPowerModel()
    per_wire = [3, 0, 7, 1, 9, 2, 4, 4]
    assert pm.wire_energy_pj(per_wire, 10) == pm.link_energy_pj(30, 10)
    assert pm.wire_energy_pj(
        per_wire, 10, extra_wires=2
    ) == pm.coded_link_energy_pj(24, 6, 10, 6, 2)
    npm = NocPowerModel()
    assert npm.wire_hop_energy_pj(
        per_wire, 10, extra_wires=2
    ) == npm.coded_hop_energy_pj(24, 6, 10, 6, 2)
    # a non-uniform capacitance profile actually changes the answer
    caps = [1.0] * 7 + [2.0]
    assert pm.wire_energy_pj(per_wire, 10, wire_caps=caps) == pytest.approx(
        pm.link_energy_pj(30, 10) + pm.energy_per_transition_pj * 4
    )
    with pytest.raises(ValueError, match="wire_caps"):
        pm.wire_energy_pj(per_wire, 10, wire_caps=[1.0])
    with pytest.raises(ValueError, match="per-wire"):
        pm.wire_energy_pj(per_wire, 10, data_wires=4)


# -------------------------------------------------------- NoC + DSE paths


def test_simulate_noc_activity_profiles_and_energy_identity():
    rng = np.random.default_rng(7)
    topo = mesh(3, 3)
    flows = [
        TrafficFlow("f0", 0, (8,), jnp.asarray(
            rng.integers(0, 256, (5, 64), dtype=np.uint8))),
        TrafficFlow("f1", 2, (6,), jnp.asarray(
            rng.integers(0, 256, (3, 64), dtype=np.uint8))),
    ]
    for codec in ("none", "bus_invert4"):
        spec = LinkSpec(key="acc", codec=codec, input_lanes=16,
                        weight_lanes=0)
        rep = simulate_noc(topo, flows, spec, activity_windows=4)
        base = simulate_noc(topo, flows, spec)
        # activity measurement never changes the scalar accounting
        assert rep.links == base.links
        profs = profiles_from_noc(rep)
        assert len(profs) == rep.active_links
        pm = NocPowerModel()
        ew = profs[0].aux_wires
        for p, s in zip(profs, rep.links):
            p.check(s.gross_bt)  # per-wire sums to gross, every link
            assert pm.wire_hop_energy_pj(
                p.per_wire, s.num_flits,
                data_wires=p.data_wires, extra_wires=ew,
            ) == s.energy_pj


def test_evaluate_grid_activity_per_wire_and_hot_wire_fields():
    from repro.dse import DesignPoint, Workload, evaluate_grid
    from repro.dse.report import point_record

    rng = np.random.default_rng(1)
    wl = Workload(
        "wl",
        (jnp.asarray(rng.integers(0, 256, (7, 32), dtype=np.uint8)),),
        lanes=16,
    )
    pts = [
        DesignPoint("psu", 16, 8, None, ordering="acc"),
        DesignPoint("psu", 16, 8, None, ordering="acc", codec="bus_invert"),
        DesignPoint("psu", 16, 8, None, ordering="none"),
    ]
    ev = evaluate_grid(pts, wl, activity_windows=4)
    plain = evaluate_grid(pts, wl)
    pm = LinkPowerModel()
    for a, b in zip(ev, plain):
        assert (a.total_bt, a.aux_bt, a.energy_pj) == (
            b.total_bt, b.aux_bt, b.energy_pj
        )
        assert len(a.per_wire_bt) == 8 * wl.lanes + a.extra_wires
        assert sum(a.per_wire_bt) == a.gross_bt
        assert pm.wire_energy_pj(
            a.per_wire_bt, a.num_flits, extra_wires=a.extra_wires
        ) == a.energy_pj
        rec = point_record(a)
        assert rec["hot_wire"] == a.hot_wire
        assert rec["hot_wire_bt"] == a.hot_wire_bt
        assert a.hot_wire_ratio >= 1.0
        # the plain path reports the wire fields as absent, not wrong
        assert b.per_wire_bt is None and b.hot_wire is None
        assert point_record(b)["hot_wire_ratio"] is None


def test_codecs_kernel_activity_invariant():
    x = _stream(9, 32, seed=8)
    out = bt_count_codecs(
        x, None, configs=_CONFIGS[:6], input_lanes=16, activity_windows=5
    )
    bt = np.asarray(out.bt)
    for ci in range(len(_CONFIGS[:6])):
        assert int(np.asarray(out.toggles)[ci].sum()) == int(bt[ci].sum())
