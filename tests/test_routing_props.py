"""Property tests for large-fabric routing (hypothesis; auto-skip when
hypothesis is not installed — see conftest.py).

The batched fabric pipeline trusts ``compile_fabric``'s tables blindly, so
the router itself gets the adversarial treatment: XY validity on the
16x16 acceptance mesh, shortest-wrap tie-breaking on tori, and multicast
tree link dedup on arbitrary destination sets.
"""

from hypothesis import given, strategies as st

from repro.noc import (
    compile_fabric,
    hop_count,
    mesh,
    multicast_links,
    route,
    torus,
    unicast_links,
)

MESH = mesh(16, 16)
ROUTERS = st.integers(min_value=0, max_value=MESH.num_routers - 1)


@given(src=ROUTERS, dst=ROUTERS)
def test_mesh16_xy_route_is_valid_and_minimal(src, dst):
    path = route(MESH, src, dst)
    assert path[0] == src and path[-1] == dst
    # link-connected: every step is a physical directed link (link_id
    # raises on anything else)
    for u, v in zip(path[:-1], path[1:]):
        MESH.link_id(u, v)
    # minimal: exactly the Manhattan distance
    (r0, c0), (r1, c1) = MESH.coords(src), MESH.coords(dst)
    assert len(path) - 1 == abs(r0 - r1) + abs(c0 - c1)
    # dimension order: all column correction strictly before row correction
    rows_changed = [MESH.coords(p)[0] != r0 for p in path]
    cols_wrong = [MESH.coords(p)[1] != c1 for p in path]
    assert all(
        not wrong for moved, wrong in zip(rows_changed, cols_wrong) if moved
    )


@given(
    rows=st.integers(min_value=2, max_value=9),
    cols=st.integers(min_value=2, max_value=9),
    src=st.integers(min_value=0, max_value=80),
    dst=st.integers(min_value=0, max_value=80),
)
def test_torus_routes_take_shortest_wrap(rows, cols, src, dst):
    topo = torus(rows, cols)
    src %= topo.num_routers
    dst %= topo.num_routers
    (r0, c0), (r1, c1) = topo.coords(src), topo.coords(dst)
    dr = min((r1 - r0) % rows, (r0 - r1) % rows)
    dc = min((c1 - c0) % cols, (c0 - c1) % cols)
    assert hop_count(topo, src, dst) == dr + dc
    # tie-break toward + : an exact half-way offset must step forward
    path = route(topo, src, dst)
    if cols % 2 == 0 and (c1 - c0) % cols == cols // 2:
        first = topo.coords(path[1])[1]
        assert first == (c0 + 1) % cols


@given(
    src=ROUTERS,
    dsts=st.lists(ROUTERS, min_size=1, max_size=12),
)
def test_mesh16_multicast_tree_dedups_links(src, dsts):
    tree = multicast_links(MESH, src, tuple(dsts))
    # each physical link carries ONE copy (the tree-multicast accounting)
    assert len(tree) == len(set(tree))
    # the tree is exactly the union of the unicast routes
    union = set()
    for d in dsts:
        if d != src:
            union.update(unicast_links(MESH, src, d))
    assert set(tree) == union


@given(
    endpoints=st.lists(
        st.tuples(ROUTERS, st.lists(ROUTERS, min_size=1, max_size=4)),
        min_size=1,
        max_size=16,
    )
)
def test_compile_fabric_tables_are_consistent(endpoints):
    eps = [(s, tuple(d)) for s, d in endpoints]
    plan = compile_fabric(MESH, eps)
    assert plan.num_flows == len(eps)
    assert list(plan.link_ids) == sorted(plan.link_ids)
    # every link's queue is exactly the flows whose tree crosses it, in
    # injection (= flow index) order — the bit-exactness invariant
    for lid in plan.link_ids:
        q = plan.queue_of(lid)
        assert q == tuple(
            fi for fi, links in enumerate(plan.flow_links) if lid in links
        )
    # queue table covers every active link and nothing else
    assert set(plan.link_queue) == set(range(plan.num_queues))
