"""repro.noc: topology/routing correctness, the batched per-link BT kernel
against the per-link ``core.bt.bit_transitions`` reference, and the
fabric-level claims (source-sorted streams keep their BT advantage on every
hop; multicast trees carry one copy per link)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bit_transitions
from repro.kernels import bt_count_links
from repro.link import LinkSpec
from repro.noc import (
    NocPowerModel,
    TrafficFlow,
    conv_platform_flows,
    decode_weight_flows,
    expand_link_streams,
    hop_count,
    mesh,
    multicast_links,
    packetize,
    ring,
    ring_allreduce_flows,
    route,
    simulate_noc,
    torus,
    unicast_links,
)


def _conv_packets(p, n, seed=0):
    """Conv-like byte packets: sparse, spatially-correlated (the data model
    under which popcount ordering has leverage)."""
    rng = np.random.default_rng(seed)
    v = rng.normal(size=(p, n))
    v = (v + np.roll(v, 1, 1) + np.roll(v, -1, 1)) / 3
    v = np.clip(v - np.quantile(v, 0.55), 0, None)
    return jnp.asarray(
        (v / (v.max() + 1e-9) * 255).astype(np.uint8)
    )


# ---------------------------------------------------------------- topology


def test_topology_link_counts():
    assert mesh(3, 3).num_links == 2 * (3 * 2 + 3 * 2)  # 24
    assert mesh(1, 4).num_links == 2 * 3
    assert torus(3, 3).num_links == 4 * 9
    assert torus(4, 4).num_links == 4 * 16
    assert ring(6).num_links == 12
    # wraparound duplicates on 2-long dims are deduplicated, not doubled
    assert torus(2, 2).num_links == 8


def test_topology_maps_and_errors():
    t = mesh(3, 4)
    assert t.coords(7) == (1, 3)
    assert t.router(1, 3) == 7
    assert t.row_routers(2) == (8, 9, 10, 11)
    for i, (u, v) in enumerate(t.links):
        assert t.link_id(u, v) == i
    with pytest.raises(ValueError):
        t.link_id(0, 11)  # not neighbors
    with pytest.raises(ValueError):
        t.coords(12)
    with pytest.raises(ValueError):
        ring(2)
    with pytest.raises(ValueError):
        mesh(1, 1)


# ----------------------------------------------------------------- routing


@pytest.mark.parametrize("topo", [mesh(4, 4), torus(4, 4), ring(7)])
def test_routes_are_link_connected(topo):
    for src in range(topo.num_routers):
        for dst in range(topo.num_routers):
            path = route(topo, src, dst)
            assert path[0] == src and path[-1] == dst
            for u, v in zip(path[:-1], path[1:]):
                topo.link_id(u, v)  # raises if not a physical link


def test_mesh_xy_is_manhattan():
    t = mesh(4, 4)
    for src in range(16):
        for dst in range(16):
            (r0, c0), (r1, c1) = t.coords(src), t.coords(dst)
            assert hop_count(t, src, dst) == abs(r0 - r1) + abs(c0 - c1)


def test_wrap_routing_takes_shortest_direction():
    r = ring(8)
    assert hop_count(r, 0, 3) == 3
    assert hop_count(r, 0, 5) == 3  # wraps backward
    assert route(r, 0, 7) == [0, 7]
    t = torus(4, 4)
    assert hop_count(t, 0, 15) == 2  # (0,0)->(3,3) wraps both dims
    assert hop_count(t, 0, 2) == 2  # tie (2 fwd, 2 back) stays monotone


def test_multicast_tree_shares_prefixes():
    t = mesh(4, 4)
    dsts = (1, 2, 3)  # one row: a 3-hop chain, not 1+2+3 links
    assert multicast_links(t, 0, dsts) == [
        t.link_id(0, 1), t.link_id(1, 2), t.link_id(2, 3)
    ]
    dsts = tuple(range(1, 16))
    tree = multicast_links(t, 0, dsts)
    total = sum(len(unicast_links(t, 0, d)) for d in dsts)
    assert len(tree) == 15  # spanning tree of 16 routers
    assert len(set(tree)) == len(tree) < total


# ------------------------------------------------- batched per-link kernel


def test_bt_count_links_matches_per_link_reference():
    rng = np.random.default_rng(3)
    s = jnp.asarray(rng.integers(0, 256, (5, 37, 16), dtype=np.uint8))
    out = np.asarray(bt_count_links(s, input_lanes=8, block_links=2, block_rows=8))
    for l in range(5):
        assert out[l, 0] == int(bit_transitions(s[l, :, :8]))
        assert out[l, 1] == int(bit_transitions(s[l, :, 8:]))
    # input-only: all lanes on the input side
    out = np.asarray(bt_count_links(s))
    for l in range(5):
        assert out[l, 0] == int(bit_transitions(s[l])) and out[l, 1] == 0


def test_bt_count_links_padding_is_neutral():
    rng = np.random.default_rng(4)
    s = jnp.asarray(rng.integers(0, 256, (3, 19, 8), dtype=np.uint8))
    # repeating the last flit (the simulator's jagged-stream padding) and
    # the wrapper's internal block padding both add zero transitions
    s_pad = jnp.concatenate([s, jnp.repeat(s[:, -1:], 13, axis=1)], axis=1)
    np.testing.assert_array_equal(
        np.asarray(bt_count_links(s)), np.asarray(bt_count_links(s_pad))
    )


def test_bt_count_links_degenerate_shapes():
    assert bt_count_links(jnp.zeros((0, 5, 4), jnp.uint8)).shape == (0, 2)
    assert int(np.asarray(bt_count_links(jnp.zeros((3, 1, 4), jnp.uint8))).sum()) == 0
    with pytest.raises(ValueError, match="input_lanes"):
        bt_count_links(jnp.zeros((2, 4, 8), jnp.uint8), input_lanes=16)


@pytest.mark.parametrize("topo", [mesh(3, 3), ring(5)])
@pytest.mark.parametrize("key", ["none", "acc", "app"])
def test_noc_streams_bit_exact_vs_reference(topo, key):
    """Acceptance criterion: the one-launch fabric measurement equals the
    per-link ``core.bt.bit_transitions`` loop across topology x ordering."""
    spec = LinkSpec(key=key)
    n = spec.elems_per_packet
    flows = [
        TrafficFlow("f0", 0, (topo.num_routers - 1,),
                    _conv_packets(40, n, 0), _conv_packets(40, n, 1)),
        TrafficFlow("f1", 1, (topo.num_routers - 1,),
                    _conv_packets(24, n, 2), _conv_packets(24, n, 3)),
    ]
    for sort_at in ("source", "hop"):
        ls = expand_link_streams(topo, flows, spec, sort_at=sort_at)
        bt = np.asarray(bt_count_links(ls.streams, input_lanes=spec.input_lanes))
        for i, length in enumerate(ls.lengths):
            trimmed = ls.streams[i, :length]
            assert bt[i, 0] == int(bit_transitions(trimmed[:, : spec.input_lanes]))
            assert bt[i, 1] == int(bit_transitions(trimmed[:, spec.input_lanes:]))


# --------------------------------------------------------------- simulator


def test_source_sorted_advantage_survives_every_hop():
    """The fabric claim: sorting once at the source reduces BT on EVERY
    link of a multi-hop route, not just the first."""
    topo = mesh(4, 4)
    flow = [TrafficFlow("f", 0, (15,), _conv_packets(64, 32, 5),
                        _conv_packets(64, 32, 6))]
    base = simulate_noc(topo, flow, LinkSpec(key="none"))
    srt = simulate_noc(topo, flow, LinkSpec(key="acc"))
    assert base.active_links == srt.active_links == 6  # (0,0) -> (3,3)
    by_link_base = {s.link: s for s in base.links}
    for s in srt.links:
        assert s.total_bt < by_link_base[s.link].total_bt
    # every hop retransmits the same ordered stream: per-link BT identical
    assert len({s.total_bt for s in srt.links}) == 1
    assert srt.reduction_vs(base) > 0.05


def test_report_invariants_and_energy_rollup():
    topo = ring(5)
    power = NocPowerModel()
    flows = [TrafficFlow("f", 0, (2,), _conv_packets(16, 32, 7),
                         _conv_packets(16, 32, 8))]
    rep = simulate_noc(topo, flows, LinkSpec(key="app"), power=power)
    assert rep.total_links == topo.num_links
    assert rep.flow_hops == (("f", 2),)
    assert rep.max_hops == 2
    # 16 packets x 4 flits on each of 2 hops
    assert all(s.num_flits == 64 for s in rep.links)
    assert rep.total_flit_hops == 128
    assert rep.energy_pj == pytest.approx(
        sum(power.hop_energy_pj(s.total_bt, s.num_flits) for s in rep.links)
    )
    assert rep.reduction_vs(rep) == pytest.approx(0.0)


def test_hop_sort_reorders_only_transmission_order():
    """Per-hop packet scheduling permutes the packet sequence on a link but
    transmits the same packet payloads (flit multiset preserved)."""
    topo = mesh(3, 3)
    spec = LinkSpec(key="acc")
    flows = [
        TrafficFlow("a", 0, (8,), _conv_packets(20, 32, 9),
                    _conv_packets(20, 32, 10)),
        TrafficFlow("b", 2, (8,), _conv_packets(12, 32, 11),
                    _conv_packets(12, 32, 12)),
    ]
    src = expand_link_streams(topo, flows, spec, sort_at="source")
    hop = expand_link_streams(topo, flows, spec, sort_at="hop")
    assert src.link_ids == hop.link_ids
    assert src.lengths == hop.lengths
    f = spec.flits_per_packet
    for i, length in enumerate(src.lengths):
        a = np.asarray(src.streams[i, :length]).reshape(-1, f, 16)
        b = np.asarray(hop.streams[i, :length]).reshape(-1, f, 16)
        key = lambda pkts: sorted(p.tobytes() for p in pkts)
        assert key(a) == key(b)


def test_expand_validation_errors():
    topo = mesh(2, 2)
    x = _conv_packets(4, 32, 13)
    with pytest.raises(ValueError, match="sort_at"):
        expand_link_streams(topo, [TrafficFlow("f", 0, (3,), x, x)],
                            LinkSpec(), sort_at="midway")
    with pytest.raises(ValueError, match="payload"):
        simulate_noc(topo, [TrafficFlow("f", 0, (3,), x[:, :16], x)], LinkSpec())
    with pytest.raises(ValueError, match="weight"):
        simulate_noc(topo, [TrafficFlow("f", 0, (3,), x)], LinkSpec())
    with pytest.raises(ValueError, match="no destinations"):
        TrafficFlow("f", 0, (), x, x)
    with pytest.raises(ValueError, match="zero packets"):
        simulate_noc(topo, [TrafficFlow("f", 0, (3,), x[:0], x[:0])],
                     LinkSpec())
    # a legal LinkSpec key that has no packet-flow meaning fails up front
    with pytest.raises(ValueError, match="row-stream stage"):
        simulate_noc(topo, [TrafficFlow("f", 0, (3,), x, x)],
                     LinkSpec(key="row_bucket"))


def test_simulate_handles_empty_and_self_traffic():
    topo = mesh(2, 2)
    rep = simulate_noc(topo, [], LinkSpec())
    assert rep.total_bt == 0 and rep.active_links == 0 and rep.energy_pj == 0
    # src == dst: no links crossed
    x = _conv_packets(4, 32, 14)
    rep = simulate_noc(
        topo, [TrafficFlow("self", 1, (1,), x, x)], LinkSpec()
    )
    assert rep.active_links == 0 and rep.flow_hops == (("self", 0),)


# ---------------------------------------------------------------- adapters


def test_packetize_trims_to_whole_packets():
    out = packetize(jnp.arange(70, dtype=jnp.uint8), 32)
    assert out.shape == (2, 32)
    with pytest.raises(ValueError):
        packetize(jnp.arange(10, dtype=jnp.uint8), 32)


def test_conv_platform_flows_cover_all_packets():
    topo = mesh(3, 3)
    patches = _conv_packets(28, 25, 15)
    kernel = jnp.arange(25, dtype=jnp.uint8)
    spec = LinkSpec()  # paired 8+8 framing
    flows = conv_platform_flows(patches, kernel, topo, 0, [4, 5, 7], spec)
    total = sum(f.inputs.shape[0] for f in flows)
    assert total == (28 * 25) // spec.elems_per_packet
    for f in flows:
        assert f.weights.shape == (f.inputs.shape[0],
                                   spec.weight_elems_per_packet)
        assert len(f.dsts) == 1


def test_decode_weight_flows_multicast():
    topo = mesh(3, 3)
    spec = LinkSpec(input_lanes=16, weight_lanes=0)
    w = jnp.asarray(np.random.default_rng(16).normal(size=(64, 32)),
                    jnp.float32)
    (flow,) = decode_weight_flows(w, topo, 0, topo.row_routers(1), spec,
                                  max_packets=8)
    assert flow.dsts == (3, 4, 5)
    assert flow.inputs.shape == (8, 64)
    with pytest.raises(ValueError, match="input-only"):
        decode_weight_flows(w, topo, 0, (1,), LinkSpec())


def test_ring_allreduce_flows_shard_the_gradient():
    topo = ring(4)
    spec = LinkSpec(input_lanes=16, weight_lanes=0)
    g = jnp.asarray(np.random.default_rng(17).normal(size=(4 * 3 * 64,)),
                    jnp.float32)
    flows = ring_allreduce_flows(g, topo, spec=spec)
    assert len(flows) == 4
    assert sum(f.inputs.shape[0] for f in flows) == (4 * 3 * 64) // 64
    for i, f in enumerate(flows):
        assert f.src == i and f.dsts == ((i + 1) % 4,)
    rep = simulate_noc(topo, flows, spec)
    assert rep.active_links == 4  # each cyclic hop is one physical link
    assert rep.max_hops == 1


def test_spec_stage_composition_on_noc():
    """A LinkSpec means the same thing on a NoC link: sign-magnitude encode
    + descending APP sort compose with the fabric expansion."""
    topo = mesh(2, 2)
    spec = LinkSpec(key="app", encode="sign_magnitude", descending=True)
    x = _conv_packets(16, 32, 18)
    rep = simulate_noc(topo, [TrafficFlow("f", 0, (3,), x, x)], spec,
                       sort_at="hop")
    assert rep.total_bt > 0 and rep.active_links == 2
