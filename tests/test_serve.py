"""Serving loop: batched generation over every family."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import init_params
from repro.serve import generate

KEY = jax.random.key(1)


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "mamba2-370m", "whisper-medium"])
def test_generate_shapes(arch):
    cfg = smoke_config(arch)
    params = init_params(cfg, KEY)
    prompts = jax.random.randint(KEY, (2, 8), 0, cfg.vocab)
    kw = {}
    if cfg.family in ("encdec", "audio"):
        kw["frames"] = jax.random.normal(KEY, (2, 8, cfg.d_model), jnp.float32)
    out = generate(params, cfg, prompts, max_new_tokens=4, **kw)
    assert out.tokens.shape == (2, 4)
    assert out.logprobs.shape == (2, 4)
    assert np.isfinite(np.asarray(out.logprobs)).all()
    assert (np.asarray(out.logprobs) <= 0).all()


def test_greedy_is_deterministic():
    cfg = smoke_config("internlm2-1.8b")
    params = init_params(cfg, KEY)
    prompts = jax.random.randint(KEY, (2, 8), 0, cfg.vocab)
    a = generate(params, cfg, prompts, 4)
    b = generate(params, cfg, prompts, 4)
    np.testing.assert_array_equal(np.asarray(a.tokens), np.asarray(b.tokens))


def test_sampled_generation_valid_tokens():
    cfg = smoke_config("internlm2-1.8b")
    params = init_params(cfg, KEY)
    prompts = jax.random.randint(KEY, (2, 8), 0, cfg.vocab)
    out = generate(params, cfg, prompts, 4, temperature=1.0, seed=9)
    toks = np.asarray(out.tokens)
    assert ((toks >= 0) & (toks < cfg.vocab)).all()
