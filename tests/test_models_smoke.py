"""Per-architecture smoke tests (assignment deliverable f): reduced configs
of the same family, one forward/train step on CPU, output shapes + no NaNs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, arch_shapes, get_config, smoke_config
from repro.models import encdec_forward, forward, init_params, lm_loss, unembed
from repro.optim import AdamWConfig
from repro.optim import init as opt_init
from repro.train import make_train_step

KEY = jax.random.key(0)


def _batch(cfg, b=2, s=16):
    tok = jax.random.randint(KEY, (b, s), 0, cfg.vocab)
    lab = jax.random.randint(KEY, (b, s), 0, cfg.vocab)
    batch = {"tokens": tok, "labels": lab}
    if cfg.family in ("encdec", "audio"):
        batch["frames"] = jax.random.normal(KEY, (b, 8, cfg.d_model), jnp.float32)
    elif cfg.family == "vlm":
        batch["patches"] = jax.random.normal(KEY, (b, cfg.n_frontend_tokens, cfg.d_model))
        batch["labels"] = jnp.pad(
            lab, ((0, 0), (cfg.n_frontend_tokens, 0)), constant_values=-100
        )
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_forward_shapes_and_finite(arch):
    cfg = smoke_config(arch)
    params = init_params(cfg, KEY)
    batch = _batch(cfg)
    if cfg.family in ("encdec", "audio"):
        h, aux = encdec_forward(params, cfg, batch["frames"], batch["tokens"])
        want_s = batch["tokens"].shape[1]
    elif cfg.family == "vlm":
        h, aux = forward(params, cfg, tokens=batch["tokens"], inputs_embeds=batch["patches"])
        want_s = batch["tokens"].shape[1] + cfg.n_frontend_tokens
    else:
        h, aux = forward(params, cfg, tokens=batch["tokens"])
        want_s = batch["tokens"].shape[1]
    assert h.shape == (2, want_s, cfg.d_model)
    logits = unembed(params, cfg, h)
    assert logits.shape == (2, want_s, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    loss = lm_loss(params, cfg, h, batch["labels"])
    assert np.isfinite(float(loss)) and float(loss) > 0


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_one_train_step(arch):
    cfg = smoke_config(arch)
    params = init_params(cfg, KEY)
    opt = opt_init(params)
    step = jax.jit(make_train_step(cfg, AdamWConfig(warmup_steps=1, total_steps=10)))
    p2, o2, metrics = step(params, opt, _batch(cfg))
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    # params actually moved
    moved = any(
        float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2))
    )
    assert moved
    assert np.isfinite(
        np.asarray(jax.tree.leaves(p2)[0], np.float32)
    ).all()


def test_exact_assigned_configs_match_table():
    """Spot-check the full configs against the assignment table."""
    g = get_config("gemma-7b")
    assert (g.n_layers, g.d_model, g.n_heads, g.n_kv_heads) == (28, 3072, 16, 16)
    assert (g.head_dim, g.d_ff, g.vocab, g.act) == (256, 24576, 256000, "geglu")
    q = get_config("qwen3-moe-30b-a3b")
    assert (q.moe.num_experts, q.moe.top_k, q.moe.d_ff_expert) == (128, 8, 768)
    assert (q.n_layers, q.d_model, q.n_kv_heads, q.vocab) == (48, 2048, 4, 151936)
    m = get_config("mamba2-370m")
    assert (m.n_layers, m.d_model, m.vocab, m.ssm.d_state) == (48, 1024, 50280, 128)
    z = get_config("zamba2-1.2b")
    assert (z.n_layers, z.d_model, z.vocab, z.ssm.d_state) == (38, 2048, 32000, 64)
    i = get_config("internvl2-26b")
    assert (i.n_layers, i.d_model, i.n_heads, i.n_kv_heads, i.d_ff, i.vocab) == (
        48, 6144, 48, 8, 16384, 92553)
    w = get_config("whisper-medium")
    assert (w.n_layers, w.n_enc_layers, w.d_model, w.vocab) == (24, 24, 1024, 51865)
    gr = get_config("granite-moe-3b-a800m")
    assert (gr.moe.num_experts, gr.moe.top_k, gr.moe.padded_experts) == (40, 8, 48)


def test_shape_assignment():
    """long_500k runs for SSM/hybrid only; all archs get the other three."""
    for arch in ARCH_NAMES:
        shapes = arch_shapes(arch)
        assert {"train_4k", "prefill_32k", "decode_32k"} <= set(shapes)
        fam = get_config(arch).family
        assert ("long_500k" in shapes) == (fam in ("ssm", "hybrid"))
    # 40 nominal cells minus 8 documented long_500k skips
    total = sum(len(arch_shapes(a)) for a in ARCH_NAMES)
    assert total == 32
