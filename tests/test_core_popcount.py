"""Unit + property tests for the popcount stage (paper Fig. 1, stage 1)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given
from hypothesis import strategies as st

from repro.core import bucket_boundaries, bucket_map, num_bucket_bits, popcount, popcount_lut4


def test_popcount_matches_numpy():
    rng = np.random.default_rng(0)
    x = rng.integers(0, 256, (4096,), dtype=np.uint8)
    got = np.asarray(popcount(jnp.asarray(x)))
    want = np.bitwise_count(x).astype(np.int32)
    np.testing.assert_array_equal(got, want)


def test_lut4_circuit_equivalence():
    """The 4-bit-LUT + adder formulation (hardware) == direct popcount."""
    x = jnp.arange(256, dtype=jnp.uint8)
    np.testing.assert_array_equal(np.asarray(popcount(x)), np.asarray(popcount_lut4(x)))


@pytest.mark.parametrize("width", [4, 8, 12, 16])
def test_widths(width):
    rng = np.random.default_rng(width)
    x = jnp.asarray(rng.integers(0, 1 << width, (512,), dtype=np.uint32))
    got = np.asarray(popcount(x, width))
    want = np.bitwise_count(np.asarray(x) & ((1 << width) - 1)).astype(np.int32)
    np.testing.assert_array_equal(got, want)
    np.testing.assert_array_equal(np.asarray(popcount_lut4(x, width)), want)


def test_paper_bucket_mapping():
    """W=8, k=4 must reproduce the paper's example mapping exactly:
    {0,1,2}->B0, {3,4}->B1, {5,6}->B2, {7,8}->B3 (paper §III-B.2)."""
    assert bucket_boundaries(8, 4) == [0, 0, 0, 1, 1, 2, 2, 3, 3]
    p = jnp.arange(9)
    np.testing.assert_array_equal(
        np.asarray(bucket_map(p, 8, 4)), [0, 0, 0, 1, 1, 2, 2, 3, 3]
    )


def test_paper_example_sequence():
    """Input '1'-bit counts {4,1,7,5,3,5} -> bucket indices {1,0,3,2,1,2}."""
    p = jnp.asarray([4, 1, 7, 5, 3, 5])
    np.testing.assert_array_equal(np.asarray(bucket_map(p)), [1, 0, 3, 2, 1, 2])


@given(st.integers(1, 9), st.integers(0, 8))
def test_bucket_map_properties(k, p):
    b = int(bucket_map(jnp.int32(p), 8, k))
    assert 0 <= b < k
    # monotone in p
    if p > 0:
        assert b >= int(bucket_map(jnp.int32(p - 1), 8, k))


def test_bucket_bits():
    assert num_bucket_bits(4) == 2  # paper: 2-bit index for k=4
    assert num_bucket_bits(9) == 4  # exact: ceil(log2(9))
    assert num_bucket_bits(2) == 1
