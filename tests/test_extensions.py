"""Beyond-paper extensions: int8 KV cache, async checkpointing, PSU timing."""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import smoke_config
from repro.core import bitonic_timing, psu_timing
from repro.models import decode_step, init_params, prefill
from repro.serve import cache_bytes, dequantize_cache, quantize_cache


def test_kv_quant_roundtrip_and_decode():
    cfg = smoke_config("internlm2-1.8b")
    params = init_params(cfg, jax.random.key(0))
    # 8 prompts: with a random-init model the logit gaps are tiny, so the
    # top-1 agreement check below needs more than a couple of samples to be
    # statistically meaningful (2 near-tied prompts can both flip)
    tok = jax.random.randint(jax.random.key(1), (8, 13), 0, cfg.vocab)
    _, cache = prefill(params, cfg, tok[:, :12], max_len=16)

    qcache = quantize_cache(cache)
    assert cache_bytes(qcache) < cache_bytes(cache) * 0.6  # ~2x bf16 -> int8
    dcache = dequantize_cache(qcache, jnp.bfloat16)
    # cache contents survive within int8 quantization error
    err = float(jnp.max(jnp.abs(
        dcache["k"].astype(jnp.float32) - cache["k"].astype(jnp.float32))))
    amax = float(jnp.max(jnp.abs(cache["k"].astype(jnp.float32))))
    assert err <= amax / 127.0 + 1e-3

    # decode logits through the quantized cache stay close to exact
    exact, _ = decode_step(params, cfg, cache, tok[:, 12:13])
    approx, _ = decode_step(params, cfg, dcache, tok[:, 12:13])
    top_exact = np.asarray(jnp.argmax(exact, -1))
    top_approx = np.asarray(jnp.argmax(approx, -1))
    rel = float(jnp.max(jnp.abs(exact.astype(jnp.float32) -
                                approx.astype(jnp.float32)))) / (
        float(jnp.max(jnp.abs(exact.astype(jnp.float32)))) + 1e-9)
    assert rel < 0.15  # int8 KV: logits close; ranking usually preserved
    assert (top_exact == top_approx).mean() >= 0.5


def test_kv_quant_passthrough_for_ssm():
    cfg = smoke_config("mamba2-370m")
    params = init_params(cfg, jax.random.key(0))
    tok = jax.random.randint(jax.random.key(1), (2, 12), 0, cfg.vocab)
    _, cache = prefill(params, cfg, tok, max_len=16)
    assert quantize_cache(cache) is not cache or "k" not in cache


def test_async_checkpoint_equivalent_and_nonblocking(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=3)
    tree = {"w": np.random.default_rng(0).normal(size=(512, 256))}
    t0 = time.monotonic()
    m.save_async(1, tree, extra={"data_step": 1})
    t_submit = time.monotonic() - t0
    m.wait()
    got, extra, step = m.restore(tree)
    np.testing.assert_array_equal(got["w"], tree["w"])
    assert step == 1 and extra["data_step"] == 1
    # a second async save supersedes cleanly
    tree2 = {"w": tree["w"] * 2}
    m.save_async(2, tree2)
    m.wait()
    got2, _, step2 = m.restore(tree)
    assert step2 == 2
    np.testing.assert_array_equal(got2["w"], tree2["w"])


def test_psu_timing_claims():
    """O(N) streaming beats comparator networks in LATENCY scaling and the
    APP variant shaves prefix-stage cycles (paper's speed argument)."""
    acc, app = psu_timing(25), psu_timing(25, k=4)
    assert app.latency_cycles < acc.latency_cycles
    # PSU latency is O(log K) == O(1) in N; bitonic latency grows as log^2 N
    assert psu_timing(1024).latency_cycles == psu_timing(25).latency_cycles
    assert bitonic_timing(1024).latency_cycles > bitonic_timing(25).latency_cycles
    assert app.sort_time_ns(25) < acc.sort_time_ns(25)
