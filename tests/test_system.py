"""End-to-end system test: train -> checkpoint -> restore -> order weights
for serving -> generate.  The full pipeline a deployment would run."""

import jax
import numpy as np

from repro.configs import smoke_config
from repro.data import DataConfig
from repro.optim import AdamWConfig
from repro.serve import generate
from repro.traffic import apply_weight_ordering, stream_bt_report
from repro.train import TrainLoopConfig, train


def test_end_to_end(tmp_path):
    cfg = smoke_config("internlm2-1.8b")
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8, seed=1, noise=0.05)
    ocfg = AdamWConfig(peak_lr=2e-3, warmup_steps=3, total_steps=40)

    result = train(cfg, dcfg, ocfg, TrainLoopConfig(
        steps=15, checkpoint_every=5, checkpoint_dir=str(tmp_path), log_every=5))
    losses = [m["loss"] for m in result["log"]]
    assert losses[-1] < losses[0], losses  # the model learns the synthetic LM

    # serving path: popcount-order the trained weights (numeric no-op),
    # measure the modeled weight-stream BT, then generate
    params = result["params"]
    ordered = apply_weight_ordering(params, cfg, "app")
    prompts = jax.random.randint(jax.random.key(0), (2, 8), 0, cfg.vocab)
    out_base = generate(params, cfg, prompts, 5)
    out_ord = generate(ordered, cfg, prompts, 5)
    np.testing.assert_array_equal(
        np.asarray(out_base.tokens), np.asarray(out_ord.tokens)
    )  # ordering never changes serving results

    rep = stream_bt_report(
        "mlp.down.L0", params["layers"]["mlp"]["down"][0], "app",
        sign_magnitude=True, layout="col",
    )
    assert rep.bt_none > 0 and rep.num_flits > 0
