"""Comparison-free sorting unit semantics (paper Fig. 1/Fig. 4).

The QuestaSim waveform checks of Fig. 4 become assertions: sorted output
indices are popcount-monotone (bucket-monotone for APP), stable, and for
the paper's four representative patterns behave exactly as described.
"""

import jax.numpy as jnp
import numpy as np
from hypothesis import given
from hypothesis import strategies as st

from repro.core import (
    acc_sort_indices,
    app_sort_indices,
    apply_order,
    bucket_map,
    counting_sort_indices,
    counting_sort_ranks,
    invert_permutation,
    popcount,
)

packets = st.lists(st.integers(0, 255), min_size=1, max_size=64)


@given(packets, st.integers(1, 9))
def test_counting_sort_matches_stable_argsort(vals, nb):
    keys = jnp.asarray([v % nb for v in vals], jnp.int32)[None]
    order = counting_sort_indices(keys, nb)
    ref = jnp.argsort(keys, axis=-1, stable=True)
    np.testing.assert_array_equal(np.asarray(order), np.asarray(ref))


@given(packets)
def test_rank_is_permutation_and_inverse_of_order(vals):
    v = jnp.asarray(vals, jnp.uint8)[None]
    keys = popcount(v)
    rank = counting_sort_ranks(keys, 9)
    order = counting_sort_indices(keys, 9)
    n = len(vals)
    assert sorted(np.asarray(rank)[0].tolist()) == list(range(n))
    # order[rank[i]] == i  (hardware: element i lands at address rank[i])
    np.testing.assert_array_equal(
        np.asarray(jnp.take_along_axis(order, rank, -1))[0], np.arange(n)
    )


@given(packets)
def test_inverse_permutation_onehot_matmul(vals):
    """The MXU one-hot-matmul scatter == mathematical inverse (DESIGN §3)."""
    perm = jnp.asarray(np.random.default_rng(len(vals)).permutation(len(vals)))[None]
    inv = invert_permutation(perm)
    np.testing.assert_array_equal(
        np.asarray(jnp.take_along_axis(perm, inv, -1))[0], np.arange(len(vals))
    )


@given(packets)
def test_acc_output_popcount_monotone(vals):
    v = jnp.asarray(vals, jnp.uint8)[None]
    out = apply_order(v, acc_sort_indices(v))
    p = np.asarray(popcount(out))[0]
    assert (np.diff(p) >= 0).all()


@given(packets, st.sampled_from([2, 4, 8]))
def test_app_output_bucket_monotone_and_stable(vals, k):
    v = jnp.asarray(vals, jnp.uint8)[None]
    order = app_sort_indices(v, k=k)
    out = apply_order(v, order)
    b = np.asarray(bucket_map(popcount(out), 8, k))[0]
    assert (np.diff(b) >= 0).all()
    # stability: within a bucket, original input order preserved
    o = np.asarray(order)[0]
    for bucket in range(k):
        idx = o[b == bucket]
        assert (np.diff(idx) > 0).all()


# ---- Fig. 4 waveform-equivalent checks ----


def test_fig4_all_ones_pattern():
    v = jnp.full((1, 16), 0xFF, jnp.uint8)
    order = np.asarray(app_sort_indices(v))[0]
    np.testing.assert_array_equal(order, np.arange(16))  # ascending indices


def test_fig4_all_zeros_pattern():
    v = jnp.zeros((1, 16), jnp.uint8)
    order = np.asarray(app_sort_indices(v))[0]
    np.testing.assert_array_equal(order, np.arange(16))


def test_fig4_decreasing_popcount_pattern():
    """'1'-bit count decreasing 8..0: APP ordering reverses to bucket-
    ascending; WITHIN a bucket the input order is preserved (stability), so
    bucket 0 = [0x03, 0x01, 0x00] and bucket 3 = [0xFF, 0x7F] — exactly the
    behavior the paper's Fig. 4 waveform shows for its pattern 3."""
    vals = [0xFF, 0x7F, 0x3F, 0x1F, 0x0F, 0x07, 0x03, 0x01, 0x00]
    v = jnp.asarray(vals, jnp.uint8)[None]
    out = np.asarray(apply_order(v, app_sort_indices(v)))[0]
    b = np.asarray(bucket_map(popcount(jnp.asarray(out)[None])))[0]
    assert (np.diff(b) >= 0).all()
    np.testing.assert_array_equal(out[:3], [0x03, 0x01, 0x00])  # bucket 0, stable
    np.testing.assert_array_equal(out[-2:], [0xFF, 0x7F])  # bucket 3, stable


def test_fig4_random_pattern_sorted():
    rng = np.random.default_rng(4)
    v = jnp.asarray(rng.integers(0, 256, (1, 25), dtype=np.uint8))
    out = np.asarray(apply_order(v, acc_sort_indices(v)))[0]
    p = np.bitwise_count(out).astype(np.int32)
    assert (np.diff(p) >= 0).all()


@given(packets)
def test_descending_mode(vals):
    v = jnp.asarray(vals, jnp.uint8)[None]
    out = apply_order(v, acc_sort_indices(v, descending=True))
    p = np.asarray(popcount(out))[0]
    assert (np.diff(p) <= 0).all()
