"""Numerical equivalence invariants across implementations.

  * decode-with-cache == full forward (KV/SSM state handoff, rope positions)
  * chunked / chunked_skip attention == dense attention
  * chunked SSD scan == naive recurrence; ssd_decode == scan single step
  * sequence-chunked loss == unchunked loss
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import decode_step, forward, init_params, prefill, unembed
from repro.models.config import ModelConfig
from repro.models.layers import attention, init_attention
from repro.models.ssd import ssd_scan
from repro.models import lm_loss

KEY = jax.random.key(7)

# one representative per family (all 10 verified in development; three here
# keep CI time bounded on the single-core host)
DECODE_ARCHS = ["internlm2-1.8b", "qwen3-moe-30b-a3b", "zamba2-1.2b"]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_matches_forward(arch):
    over = dict(dtype="float32")
    cfg0 = smoke_config(arch)
    if cfg0.moe is not None:
        over["moe"] = dataclasses.replace(cfg0.moe, capacity_factor=8.0)
    cfg = smoke_config(arch, **over)
    params = init_params(cfg, KEY)
    b, s = 2, 12
    tok = jax.random.randint(KEY, (b, s + 1), 0, cfg.vocab)
    h, _ = forward(params, cfg, tokens=tok)
    want = np.asarray(unembed(params, cfg, h)[:, -1], np.float32)
    _, cache = prefill(params, cfg, tok[:, :s], max_len=s + 4)
    got, _ = decode_step(params, cfg, cache, tok[:, s : s + 1])
    got = np.asarray(got[:, 0], np.float32)
    err = np.max(np.abs(want - got)) / (np.max(np.abs(want)) + 1e-9)
    assert err < 2e-3, err


@pytest.mark.parametrize("impl", ["chunked", "chunked_skip"])
def test_chunked_attention_equals_dense(impl):
    cfg = smoke_config("internlm2-1.8b", dtype="float32", attn_impl="dense")
    ap = init_attention(KEY, cfg)
    x = jax.random.normal(KEY, (2, 64, cfg.d_model))
    pos = jnp.arange(64)[None, :]
    dense = attention(ap, x, cfg, pos)
    c2 = dataclasses.replace(cfg, attn_impl=impl, attn_chunk=16)
    out = attention(ap, x, c2, pos)
    err = float(jnp.max(jnp.abs(out - dense)) / jnp.max(jnp.abs(dense)))
    assert err < 1e-5, err


def test_ssd_chunked_equals_naive():
    b, s, h, p, n = 2, 32, 3, 4, 5
    xs = jax.random.normal(KEY, (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(KEY, (b, s, h)))
    a = -jnp.exp(jax.random.normal(KEY, (h,)))
    bm = jax.random.normal(KEY, (b, s, h, n))
    cm = jax.random.normal(KEY, (b, s, h, n))
    y_chunk, hl = ssd_scan(xs, dt, a, bm, cm, chunk=8)
    hstate = jnp.zeros((b, h, n, p))
    ys = []
    for t in range(s):
        decay = jnp.exp(dt[:, t] * a[None, :])
        hstate = hstate * decay[..., None, None] + jnp.einsum(
            "bh,bhn,bhp->bhnp", dt[:, t], bm[:, t], xs[:, t]
        )
        ys.append(jnp.einsum("bhn,bhnp->bhp", cm[:, t], hstate))
    y_naive = jnp.stack(ys, 1)
    assert float(jnp.max(jnp.abs(y_chunk - y_naive))) < 1e-4 * float(
        jnp.max(jnp.abs(y_naive))
    )
    assert float(jnp.max(jnp.abs(hl - hstate))) < 1e-4 * float(jnp.max(jnp.abs(hstate)))


def test_ssd_initial_state_threading():
    """ssd_scan(h0) == running the two halves back to back."""
    b, s, h, p, n = 1, 16, 2, 4, 3
    xs = jax.random.normal(KEY, (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(KEY, (b, s, h)))
    a = -jnp.exp(jax.random.normal(KEY, (h,)))
    bm = jax.random.normal(KEY, (b, s, h, n))
    cm = jax.random.normal(KEY, (b, s, h, n))
    y_full, h_full = ssd_scan(xs, dt, a, bm, cm, chunk=8)
    y1, h1 = ssd_scan(xs[:, :8], dt[:, :8], a, bm[:, :8], cm[:, :8], chunk=8)
    y2, h2 = ssd_scan(xs[:, 8:], dt[:, 8:], a, bm[:, 8:], cm[:, 8:], chunk=8, h0=h1)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([y1, y2], 1)), np.asarray(y_full), rtol=2e-5, atol=1e-5
    )
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full), rtol=2e-5, atol=1e-5)


def test_chunked_loss_equals_unchunked():
    cfg = smoke_config("internlm2-1.8b", dtype="float32")
    params = init_params(cfg, KEY)
    h = jax.random.normal(KEY, (2, 32, cfg.d_model))
    labels = jax.random.randint(KEY, (2, 32), 0, cfg.vocab)
    labels = labels.at[0, :5].set(-100)  # ignore-index positions
    base = lm_loss(params, cfg, h, labels)
    cfgc = dataclasses.replace(cfg, logits_chunk=8)
    chunked = lm_loss(params, cfgc, h, labels)
    assert float(jnp.abs(base - chunked)) < 1e-4 * abs(float(base))
