"""repro.dse + the multi-variant BT kernel.

Two load-bearing claims:

  * ``bt_count_variants`` is bit-exact per variant against the
    ``repro.core`` reference composition (counting sort -> gather -> pack
    -> bit_transitions) across precise/k-bucket keys, widths, directions,
    packings and non-block-multiple packet counts — so ONE launch can
    replace one ``psu_stream``/``bt_count`` launch per configuration.
  * On the measured conv streams, the Pareto front over the paper's K axis
    at N=25/W=8 contains the paper's APP point (k=4, ~35.4 % area
    reduction), and the knee of the area x BT plane IS that point.
"""

import json
import pathlib
import sys

import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
from benchmarks.datagen import conv_streams  # noqa: E402

from repro.core import (  # noqa: E402
    apply_order,
    bit_transitions,
    bucket_map,
    counting_sort_indices,
    popcount,
)
from repro.dse import (  # noqa: E402
    AREA_BT_LATENCY_OBJECTIVES,
    AREA_BT_OBJECTIVES,
    DesignPoint,
    Evaluation,
    Workload,
    area_reduction,
    evaluate_grid,
    expand_grid,
    k_sweep,
    knee_point,
    pareto_front,
    write_csv,
    write_json,
)
from repro.kernels import Variant, bt_count_variants, psu_stream  # noqa: E402

# the paper's Table-I input column: none 31.035 -> app 22.887 (-26.26 %);
# the conv data model calibrates the input side (table1_bt docstring)
PAPER_INPUT_RED_APP4 = 1 - 22.887 / 31.035


def _core_reference_bt(x, w, variant, *, width, input_lanes, weight_lanes,
                       pack):
    """Per-variant BT from repro.core primitives only (the unfused path the
    variant kernel replaces)."""
    key_name, k, descending = variant
    p, n = x.shape
    flits = n // input_lanes
    if key_name == "none":
        order = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), (p, n))
    elif key_name == "column_major":
        j = jnp.arange(n, dtype=jnp.int32)
        order = jnp.broadcast_to(
            (j % flits) * input_lanes + j // flits, (p, n)
        )
    else:
        keys = popcount(x, width)
        nb = width + 1
        if key_name == "app":
            keys = bucket_map(keys, width, k)
            nb = k
        if descending:
            keys = (nb - 1) - keys
        order = counting_sort_indices(keys, nb)

    def _flits(values, lanes):
        if pack == "lane":
            return values.reshape(p, lanes, flits).transpose(0, 2, 1)
        return values.reshape(p, flits, lanes)

    halves = [_flits(apply_order(x.astype(jnp.int32), order), input_lanes)]
    if weight_lanes:
        halves.append(
            _flits(apply_order(w.astype(jnp.int32), order), weight_lanes)
        )
    stream = jnp.concatenate(halves, axis=-1).reshape(
        p * flits, input_lanes + weight_lanes
    )
    bt_i = int(bit_transitions(stream[:, :input_lanes]))
    bt_w = int(bit_transitions(stream[:, input_lanes:])) if weight_lanes else 0
    return bt_i, bt_w


@pytest.mark.parametrize("width", [4, 8])
@pytest.mark.parametrize("descending", [False, True])
@pytest.mark.parametrize("p", [64, 65, 7, 130])  # incl. non-block-multiples
def test_variant_kernel_matches_core_references(width, descending, p):
    """ONE launch covers precise + k in {2,4,8} + the layout baselines,
    each bit-exact vs the repro.core composition."""
    rng = np.random.default_rng(hash((width, descending, p)) % 2**31)
    x = jnp.asarray(rng.integers(0, 256, (p, 32), dtype=np.uint8))
    w = jnp.asarray(rng.integers(0, 256, (p, 32), dtype=np.uint8))
    ks = [k for k in (2, 4, 8) if k <= width + 1]
    variants = (
        (Variant("none"), Variant("column_major")) if not descending else ()
    ) + (Variant("acc", None, descending),) + tuple(
        Variant("app", k, descending) for k in ks
    )
    got = np.asarray(
        bt_count_variants(
            x, w, variants=variants, width=width, input_lanes=8,
            block_packets=64,
        )
    )
    for v, row in zip(variants, got):
        ref = _core_reference_bt(
            x, w, v, width=width, input_lanes=8, weight_lanes=8, pack="lane"
        )
        assert (int(row[0]), int(row[1])) == ref, v


@pytest.mark.parametrize("pack", ["lane", "row"])
def test_variant_kernel_input_only_and_row_pack(pack):
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.integers(0, 256, (33, 48), dtype=np.uint8))
    variants = (Variant("none"), Variant("acc"), Variant("app", 4))
    got = np.asarray(
        bt_count_variants(
            x, None, variants=variants, input_lanes=16, pack=pack,
            block_packets=8,
        )
    )
    assert (got[:, 1] == 0).all()
    for v, row in zip(variants, got):
        ref = _core_reference_bt(
            x, x, v, width=8, input_lanes=16, weight_lanes=0, pack=pack
        )
        assert int(row[0]) == ref[0], v


def test_variant_kernel_agrees_with_fused_tx_pipeline():
    """The DSE measurement equals the repro.link hot path per config."""
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.integers(0, 256, (70, 32), dtype=np.uint8))
    w = jnp.asarray(rng.integers(0, 256, (70, 32), dtype=np.uint8))
    variants = (Variant("acc"), Variant("app", 4, True))
    got = np.asarray(bt_count_variants(x, w, variants=variants, input_lanes=8))
    for v, row in zip(variants, got):
        res = psu_stream(x, w, k=v.k, descending=v.descending, input_lanes=8)
        assert (int(row[0]), int(row[1])) == (int(res.bt_input), int(res.bt_weight))


def test_variant_validation():
    x = jnp.zeros((4, 16), jnp.uint8)
    with pytest.raises(ValueError):  # unknown key
        bt_count_variants(x, variants=(Variant("bogus"),))
    with pytest.raises(ValueError):  # app without k
        bt_count_variants(x, variants=(Variant("app"),))
    with pytest.raises(ValueError):  # k out of range for the width
        bt_count_variants(x, variants=(Variant("app", 8),), width=4)
    with pytest.raises(ValueError):  # k on a non-app key
        bt_count_variants(x, variants=(Variant("acc", 4),))
    with pytest.raises(ValueError):  # descending on a layout key
        bt_count_variants(x, variants=(Variant("none", None, True),))


# ------------------------------------------------------------- design space


def test_design_point_validation():
    with pytest.raises(ValueError):
        DesignPoint(family="fpga")
    with pytest.raises(ValueError):
        DesignPoint(ordering="app", k=None)
    with pytest.raises(ValueError):
        DesignPoint(ordering="app", k=12, width=8)
    with pytest.raises(ValueError):
        DesignPoint(ordering="acc", k=4)
    with pytest.raises(ValueError):
        DesignPoint(family="bitonic", ordering="app", k=4)
    with pytest.raises(ValueError):
        DesignPoint(ordering="none", k=None, descending=True)
    with pytest.raises(ValueError):
        DesignPoint(ordering="app", k=4, topology="hypercube3")
    assert DesignPoint(ordering="app", k=4, topology="mesh4x4").label == \
        "app-k4@N25/mesh4x4"


def test_expand_grid_deterministic_and_valid():
    g1 = expand_grid(ns=(25, 49), ks=(2, 4, 8),
                     orderings=("none", "acc", "app"),
                     families=("psu", "bitonic"))
    g2 = expand_grid(ns=(25, 49), ks=(2, 4, 8),
                     orderings=("none", "acc", "app"),
                     families=("psu", "bitonic"))
    assert g1 == g2
    assert len(g1) == len(set(g1))
    # psu: (none + acc + 3 app) x 2 sizes; bitonic: acc x 2 sizes
    assert len(g1) == 5 * 2 + 2
    # ks out of range for the width are skipped, not raised
    small = expand_grid(widths=(2,), ks=(2, 8), orderings=("app",))
    assert all(pt.k == 2 for pt in small)


def test_area_reduction_matches_paper():
    assert area_reduction(
        DesignPoint(n=25, width=8, k=4, ordering="app")
    ) == pytest.approx(0.354, abs=0.005)
    # baselines with no sorting hardware reduce 100 %
    assert area_reduction(DesignPoint(ordering="none", k=None)) == 1.0
    # comparator networks are bigger than the ACC-PSU (negative reduction)
    assert area_reduction(
        DesignPoint(family="bitonic", ordering="acc", k=None)
    ) < 0


# ------------------------------------------------------- pareto machinery


def _mk_eval(k, bt_red):
    pt = DesignPoint(ordering="app", k=k)
    return Evaluation(
        point=pt, area=pt.area(), timing=pt.timing(), total_bt=100,
        num_flits=10, bt_reduction=bt_red, area_reduction=0.0,
        link_power_reduction=0.0, energy_pj=0.0,
    )


def test_pareto_front_dominance():
    from repro.dse import Objective

    objectives = (
        Objective("a", lambda e: e.area_um2),
        Objective("b", lambda e: -e.bt_reduction),
    )
    # k2 (area 1703) red 0.1, k4 (area 2193) red 0.2: trade -> both survive
    evs = [_mk_eval(2, 0.1), _mk_eval(4, 0.2)]
    front = pareto_front(evs, objectives)
    assert set(id(e) for e in front) == set(id(e) for e in evs)
    # reverse the reductions: k2 is smaller AND reduces more -> dominates
    evs2 = [_mk_eval(2, 0.3), _mk_eval(4, 0.2)]
    front2 = pareto_front(evs2, objectives)
    assert [e.point.k for e in front2] == [2]
    # knee of a single-point front is that point
    assert knee_point(front2, objectives) is front2[0]
    with pytest.raises(ValueError):
        knee_point((), objectives)


# --------------------------------------------- the paper's point, measured


@pytest.fixture(scope="module")
def conv_evals():
    inp, wgt = conv_streams(n_images=4)
    workload = Workload("conv", (jnp.asarray(inp), jnp.asarray(wgt)), lanes=16)
    return evaluate_grid(k_sweep(n=25, width=8, ks=(2, 4, 8)), workload)


def test_paper_app_point_on_k_sweep_front(conv_evals):
    """Acceptance: the K-sweep front at N=25/W=8 contains the paper's APP
    point — ~35.4 % area reduction at its measured conv BT reduction — and
    the knee of the paper's area x BT plane is exactly that k=4 choice."""
    front = pareto_front(conv_evals)
    app4 = next(
        e for e in conv_evals
        if e.point.ordering == "app" and e.point.k == 4
    )
    assert app4 in front
    assert app4.area_reduction == pytest.approx(0.354, abs=0.005)
    # measured on conv traffic: a real reduction, below the precise unit's
    acc = next(e for e in conv_evals if e.point.ordering == "acc")
    assert 0.05 < app4.bt_reduction < acc.bt_reduction < 0.25
    # the area x BT knee is the paper's own design choice
    plane = pareto_front(conv_evals, AREA_BT_OBJECTIVES)
    assert knee_point(plane, AREA_BT_OBJECTIVES).point == app4.point
    # link power model rides the measured reduction (Fig. 6/7 path)
    assert app4.link_power_reduction == pytest.approx(
        app4.bt_reduction * 18.27 / 20.42
    )


def test_conv_input_side_matches_paper_calibration():
    """Input streams are the calibration target (table1_bt): the measured
    APP k=4 input-side reduction lands on the paper's Table-I column."""
    inp, _ = conv_streams(n_images=4)
    workload = Workload("conv_input", (jnp.asarray(inp),), lanes=16)
    evals = evaluate_grid(k_sweep(n=25, width=8, ks=(4,)), workload)
    app4 = next(e for e in evals if e.point.ordering == "app")
    assert app4.bt_reduction == pytest.approx(PAPER_INPUT_RED_APP4, abs=0.025)


def test_noc_point_evaluates_per_link():
    rng = np.random.default_rng(11)
    stream = jnp.asarray(rng.integers(0, 256, (96, 64), dtype=np.uint8))
    workload = Workload("rand", (stream,), lanes=16)
    pts = (
        DesignPoint(ordering="acc", k=None, topology="mesh3x3"),
        DesignPoint(ordering="acc", k=None),
    )
    evals = evaluate_grid(pts, workload)
    noc, plain = evals
    assert plain.noc_bt_reduction is None and plain.noc_active_links is None
    # 4 hops from router 0 to the far corner of a 3x3 mesh
    assert noc.noc_active_links == 4
    assert noc.noc_bt_reduction is not None
    # same single-link BT either way (the NoC axis is additive)
    assert noc.total_bt == plain.total_bt


def test_area_bt_latency_plane_and_knee():
    """The AREA_BT_LATENCY plane (DESIGN.md §17): topology points pay the
    wormhole traversal of the workload, and the 3-objective knee is still
    the paper's APP k=4 point-to-point design."""
    rng = np.random.default_rng(12)
    stream = jnp.asarray(rng.integers(0, 256, (96, 64), dtype=np.uint8))
    workload = Workload("rand", (stream,), lanes=16)
    pts = (
        DesignPoint(ordering="acc", k=None),
        DesignPoint(ordering="app", k=4),
        DesignPoint(ordering="app", k=4, topology="mesh3x3"),
    )
    acc, app4, app4_mesh = evaluate_grid(pts, workload)
    # wormhole pin: 4 hops x (3+1) head cycles + 383 body cycles @ 2 ns
    assert acc.noc_latency_ns is None and app4.noc_latency_ns is None
    assert app4_mesh.noc_latency_ns == pytest.approx(798.0)
    assert app4.total_latency_ns == app4.latency_ns
    assert app4_mesh.total_latency_ns == pytest.approx(
        app4_mesh.latency_ns + 798.0
    )
    # the fabric point ties p2p APP on area and BT but pays the route ->
    # dominated out of the 3-objective plane
    plane = pareto_front((acc, app4, app4_mesh), AREA_BT_LATENCY_OBJECTIVES)
    assert app4_mesh not in plane
    assert acc in plane and app4 in plane  # area/BT trade survives
    knee = knee_point(plane, AREA_BT_LATENCY_OBJECTIVES)
    assert knee.point == app4.point


# ------------------------------------------------------------- artifacts


def test_report_artifacts(tmp_path, conv_evals):
    front = pareto_front(conv_evals)
    knee = knee_point(front)
    jpath, cpath = tmp_path / "front.json", tmp_path / "grid.csv"
    doc = write_json(
        str(jpath), conv_evals, front=front, knee=knee, workload="conv",
        meta={"images": 4},
    )
    on_disk = json.loads(jpath.read_text())
    assert on_disk == doc
    assert on_disk["workload"] == "conv"
    assert on_disk["meta"] == {"images": 4}
    assert set(on_disk["front"]) == {e.label for e in front}
    assert on_disk["knee"] == knee.label
    assert len(on_disk["points"]) == len(conv_evals)
    rec = next(r for r in on_disk["points"] if r["label"] == "app-k4@N25")
    assert rec["on_front"] and rec["k"] == 4 and rec["n"] == 25
    assert rec["area_reduction"] == pytest.approx(0.354, abs=0.005)
    json.dumps(on_disk)  # JSON-safe end to end

    write_csv(str(cpath), conv_evals, front=front)
    lines = cpath.read_text().strip().splitlines()
    assert len(lines) == 1 + len(conv_evals)
    assert lines[0].startswith("label,family,n,width,k,ordering")
