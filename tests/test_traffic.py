"""The paper's technique on framework traffic (repro.traffic).

Key invariants: contraction-axis weight ordering is a numeric no-op; the
egress permutation is replica-consistent; sign-magnitude recoding halves
weight-stream BT; ordering reduces BT on magnitude-structured streams.
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given
from hypothesis import strategies as st

from repro.configs import smoke_config
from repro.models import forward, init_params
from repro.traffic import (
    apply_weight_ordering,
    egress_permutation,
    int8_view,
    row_order,
    stream_bt_report,
    to_sign_magnitude,
)

KEY = jax.random.key(11)


def test_weight_ordering_is_numeric_noop():
    cfg = smoke_config("internlm2-1.8b", dtype="float32", d_model=128, d_ff=512)
    params = init_params(cfg, KEY)
    tok = jax.random.randint(KEY, (2, 16), 0, cfg.vocab)
    h0, _ = forward(params, cfg, tokens=tok)
    for strat in ("acc", "app"):
        h1, _ = forward(apply_weight_ordering(params, cfg, strat), cfg, tokens=tok)
        err = float(jnp.max(jnp.abs(h0 - h1)) / jnp.max(jnp.abs(h0)))
        assert err < 1e-5, (strat, err)


def test_weight_ordering_noop_for_hybrid_shared_block():
    cfg = smoke_config("zamba2-1.2b", dtype="float32")
    params = init_params(cfg, KEY)
    tok = jax.random.randint(KEY, (2, 16), 0, cfg.vocab)
    h0, _ = forward(params, cfg, tokens=tok)
    h1, _ = forward(apply_weight_ordering(params, cfg, "app"), cfg, tokens=tok)
    assert float(jnp.max(jnp.abs(h0 - h1)) / jnp.max(jnp.abs(h0))) < 1e-5


@given(st.integers(0, 10_000))
def test_sign_magnitude_properties(seed):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.integers(-127, 128, (64,), dtype=np.int8))
    sm = np.asarray(to_sign_magnitude(q))
    qn = np.asarray(q).astype(np.int32)
    # magnitude bits = |q|; sign bit = (q < 0)
    np.testing.assert_array_equal(sm & 0x7F, np.abs(qn))
    np.testing.assert_array_equal(sm >> 7, (qn < 0).astype(np.uint8))
    # popcount monotone-ish in |value|: zero maps to zero byte
    assert sm[np.asarray(q) == 0].sum() == 0


def test_egress_permutation_is_bijection_and_replica_consistent():
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.integers(-127, 128, (1000,), dtype=np.int8))
    perm, inv = egress_permutation(w, packet=64)
    assert sorted(perm.tolist()) == list(range(1000))
    np.testing.assert_array_equal(perm[inv], np.arange(1000))
    # same weights -> same permutation on every "replica"
    perm2, _ = egress_permutation(w, packet=64)
    np.testing.assert_array_equal(perm, perm2)
    # permuted-psum equivalence: sum_r g_r[perm] then inv == sum_r g_r
    g1 = rng.normal(size=1000)
    g2 = rng.normal(size=1000)
    s = (g1[perm] + g2[perm])[inv]
    np.testing.assert_allclose(s, g1 + g2, rtol=1e-12)


def test_sign_magnitude_halves_weight_stream_bt():
    rng = np.random.default_rng(1)
    scales = rng.lognormal(0, 1.0, (256, 1))
    w = jnp.asarray(rng.normal(size=(256, 128)) * scales)
    raw = stream_bt_report("w", w, "none", sign_magnitude=False)
    sm = stream_bt_report("w", w, "none", sign_magnitude=True)
    assert sm.bt_none < raw.bt_none * 0.7  # measured ~0.45-0.55


def test_row_order_reduces_bt_on_structured_cols():
    """Column-major streams of magnitude-structured rows: popcount row
    ordering must reduce BT (the regime where the paper's idea transfers)."""
    rng = np.random.default_rng(2)
    scales = rng.lognormal(0, 1.2, (512, 1))
    w = jnp.asarray(rng.normal(size=(512, 128)) * scales)
    rep = stream_bt_report("w", w, "acc", sign_magnitude=True, layout="col")
    assert rep.reduction > 0.03, rep


def test_row_order_is_permutation():
    rng = np.random.default_rng(3)
    rows = jnp.asarray(rng.integers(0, 256, (64, 32), dtype=np.uint8))
    for strat in ("none", "acc", "app"):
        o = np.asarray(row_order(rows, strat))
        assert sorted(o.tolist()) == list(range(64))


def test_host_bitwise_count_numpy1_fallback(monkeypatch):
    """egress_permutation's host popcount must not require NumPy 2.x."""
    from repro.traffic import ordering as tord

    rng = np.random.default_rng(5)
    b = rng.integers(0, 256, (32, 64)).astype(np.uint8)
    expected = tord._host_bitwise_count(b)  # NumPy 2 path in this env
    monkeypatch.delattr(np, "bitwise_count", raising=False)
    fallback = tord._host_bitwise_count(b)
    np.testing.assert_array_equal(fallback, expected)
    # and the permutation builder works end-to-end on the fallback
    w = jnp.asarray(rng.integers(-127, 128, (512,), dtype=np.int8))
    perm, inv = tord.egress_permutation(w, packet=64)
    np.testing.assert_array_equal(perm[inv], np.arange(512))


def test_int8_view_range():
    w = jnp.asarray(np.random.default_rng(4).normal(size=(32, 32)) * 10)
    q = np.asarray(int8_view(w))
    assert q.max() <= 127 and q.min() >= -127
    assert abs(int(q.max())) == 127 or abs(int(q.min())) == 127  # full scale
