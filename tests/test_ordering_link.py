"""Ordering strategies + link framing + BT accounting (paper Table I setup)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given
from hypothesis import strategies as st

from repro.core import (
    LinkConfig,
    LinkPowerModel,
    bit_transitions,
    bt_report,
    make_order,
    measure,
    order_packets,
    pack_to_flits,
    paired_stream,
)


def rand_packets(p, n, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, 256, (p, n), dtype=np.uint8))


def test_order_insensitivity_of_mac():
    """THE enabling property (paper §I): reordering (input, weight) pairs
    does not change the accumulated dot product — exactly, in integers."""
    inp = rand_packets(5, 32, 1)
    wgt = rand_packets(5, 32, 2)
    for strat in ("none", "column_major", "acc", "app"):
        oi, ow = order_packets(strat, inp, wgt, lanes=8)
        before = (inp.astype(jnp.int32) * wgt.astype(jnp.int32)).sum(-1)
        after = (oi.astype(jnp.int32) * ow.astype(jnp.int32)).sum(-1)
        np.testing.assert_array_equal(np.asarray(before), np.asarray(after))


@given(st.integers(0, 2**32 - 1))
def test_orders_are_permutations(seed):
    inp = rand_packets(3, 32, seed)
    for strat in ("none", "column_major", "acc", "app"):
        order = np.asarray(make_order(strat, inp, lanes=8))
        for row in order:
            assert sorted(row.tolist()) == list(range(32))


def test_column_major_is_transpose():
    inp = jnp.arange(32, dtype=jnp.uint8)[None]
    order = np.asarray(make_order("column_major", inp, lanes=8))[0]
    want = np.arange(32).reshape(4, 8).T.reshape(-1)
    np.testing.assert_array_equal(order, want)


def test_bit_transitions_manual():
    s = jnp.asarray([[0x00, 0xFF], [0xFF, 0xFF], [0x0F, 0xF0]], jnp.uint8)
    # boundaries: (00->FF: 8) + (FF->FF: 0) = 8 ; (FF->FF:0)+(FF->F0:... )
    # lane0: 00->FF (8), FF->0F (4+4? 0xFF^0x0F=0xF0 ->4). lane1: FF->FF(0), FF->F0 (0x0F ->4)
    assert int(bit_transitions(s)) == 8 + 4 + 0 + 4


def test_pack_lane_vs_row():
    v = jnp.arange(32, dtype=jnp.uint8)[None]
    row = np.asarray(pack_to_flits(v, 8, "row"))[0]
    lane = np.asarray(pack_to_flits(v, 8, "lane"))[0]
    np.testing.assert_array_equal(row[0], np.arange(8))
    np.testing.assert_array_equal(lane[:, 0], np.arange(4))  # lane-contiguous


def test_paired_stream_shape_and_split():
    cfg = LinkConfig()
    inp, wgt = rand_packets(10, 32, 3), rand_packets(10, 32, 4)
    s = paired_stream(inp, wgt, cfg, "acc")
    assert s.shape == (40, 16)  # 10 packets x 4 flits x 16 bytes
    rep = bt_report(s, cfg.input_lanes)
    assert float(rep.overall_bt_per_flit) == pytest.approx(
        float(rep.input_bt_per_flit) + float(rep.weight_bt_per_flit)
    )


def test_acc_reduces_bt_on_uniform_paired_traffic():
    """Even on uniform random bytes, popcount ordering with lane packing
    reduces input-side BT (the E[HD | same popcount] = 3.5 < 4 effect —
    see EXPERIMENTS.md §Table I analysis)."""
    inp, wgt = rand_packets(2000, 32, 5), rand_packets(2000, 32, 6)
    base = measure(inp, wgt, strategy="none")
    acc = measure(inp, wgt, strategy="acc")
    app = measure(inp, wgt, strategy="app")
    assert float(acc.input_bt_per_flit) < float(base.input_bt_per_flit) * 0.95
    assert float(app.input_bt_per_flit) < float(base.input_bt_per_flit) * 0.95
    # weights move with inputs -> weight side statistically unchanged
    assert abs(float(acc.weight_bt_per_flit) - float(base.weight_bt_per_flit)) < 1.0
    # APP retains most of ACC's reduction (paper: 95.5 %; uniform data is the
    # worst case for APP -- require >= 70 %)
    red_acc = 1 - float(acc.input_bt_per_flit) / float(base.input_bt_per_flit)
    red_app = 1 - float(app.input_bt_per_flit) / float(base.input_bt_per_flit)
    assert red_app > 0.7 * red_acc


def test_paired_stream_asymmetric_lanes():
    """Regression: input_lanes != weight_lanes used to crash in the flit
    concatenate (different flit counts per side).  The weight side now
    carries flits*weight_lanes bytes per packet, framed per flit."""
    cfg = LinkConfig(input_lanes=12, weight_lanes=4)
    assert cfg.elems_per_packet == 48 and cfg.weight_elems_per_packet == 16
    inp = rand_packets(10, 48, 7)
    wgt = rand_packets(10, 16, 8)
    s = paired_stream(inp, wgt, cfg, "acc", pack="row")
    assert s.shape == (40, 16)  # 10 packets x 4 flits x 16 bytes
    # per-flit split: first 12 lanes input bytes, last 4 weight bytes —
    # weight side framed natively (no input-derived permutation applies)
    w_half = np.asarray(s)[:, 12:].reshape(10, -1)
    np.testing.assert_array_equal(w_half, np.asarray(wgt))
    rep = bt_report(s, cfg.input_lanes)
    assert float(rep.overall_bt_per_flit) > 0


def test_paired_stream_asymmetric_wrong_payload_raises():
    cfg = LinkConfig(input_lanes=12, weight_lanes=4)
    inp, wgt = rand_packets(4, 48, 1), rand_packets(4, 48, 2)
    with pytest.raises(ValueError, match="weight payload"):
        paired_stream(inp, wgt, cfg, "none")


def test_power_model_transfer():
    m = LinkPowerModel()
    # calibrated to the paper's ACC point: 20.42 % BT -> 18.27 % power
    assert m.power_reduction(0.2042) == pytest.approx(0.1827, abs=1e-4)
    assert m.link_energy_pj(1000, 10) > m.link_energy_pj(500, 10)
