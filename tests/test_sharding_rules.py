"""Unit tests for the sharding rules (pure functions over paths/shapes)."""

from types import SimpleNamespace

from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.launch.sharding import param_spec

MESH = SimpleNamespace(shape={"data": 16, "model": 16}, axis_names=("data", "model"))


def spec(path, shape, arch="internlm2-1.8b", mode="train"):
    return param_spec(path, shape, get_config(arch), MESH, mode)


def test_attention_specs():
    # q heads divisible -> TP on heads
    assert spec("['layers']['attn']['wq']", (24, 2048, 16, 128)) == P(None, None, "model", None)
    # kv heads 8 < 16 -> replicate (GQA rule)
    assert spec("['layers']['attn']['wk']", (24, 2048, 8, 128)) == P()
    # wo row-parallel on heads
    assert spec("['layers']['attn']['wo']", (24, 16, 128, 2048)) == P(None, "model", None, None)
    # granite: 24 heads not divisible -> d-contraction fallback
    assert spec("['layers']['attn']['wq']", (32, 1536, 24, 64),
                arch="granite-moe-3b-a800m") == P(None, "model", None, None)


def test_mlp_specs():
    assert spec("['layers']['mlp']['gate']", (24, 2048, 8192)) == P(None, None, "model")
    assert spec("['layers']['mlp']['down']", (24, 8192, 2048)) == P(None, "model", None)


def test_moe_specs():
    # qwen3-moe: 128 experts / 16 -> EP
    assert spec("['layers']['moe']['gate']", (48, 128, 2048, 768),
                arch="qwen3-moe-30b-a3b", mode="serve") == P(None, "model", None, None)
    # granite: 48 padded experts / 16 = 3 -> EP over padded dim
    assert spec("['layers']['moe']['down']", (32, 48, 512, 1536),
                arch="granite-moe-3b-a800m") == P(None, "model", None, None)


def test_vocab_specs():
    # divisible vocab -> shard vocab
    assert spec("['embed']", (92544, 2048)) == P("model", None)
    # mamba2 vocab 50280 not divisible -> shard d instead
    assert spec("['embed']", (50280, 1024), arch="mamba2-370m") == P(None, "model")


def test_ssd_specs():
    assert spec("['layers']['ssd']['in_proj']", (48, 1024, 4384),
                arch="mamba2-370m") == P(None, "model", None)
    assert spec("['layers']['ssd']['conv_w']", (48, 4, 2304),
                arch="mamba2-370m") == P()


def test_norms_replicated():
    assert spec("['layers']['attn_norm']", (24, 2048)) == P()
    assert spec("['final_norm']", (2048,)) == P()


def test_fsdp_mode_adds_data_axis():
    s = spec("['layers']['moe']['gate']", (48, 128, 2048, 768),
             arch="qwen3-moe-30b-a3b", mode="train")
    assert "model" in s and "data" in s  # 2D: EP x FSDP
    # serve mode: no FSDP (weights stay TP-only for decode latency)
    s2 = spec("['layers']['moe']['gate']", (48, 128, 2048, 768),
              arch="qwen3-moe-30b-a3b", mode="serve")
    assert "data" not in s2
