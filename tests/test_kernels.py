"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given
from hypothesis import strategies as st

from repro.kernels import bt_count, psu_reorder, psu_sort, quantize_egress
from repro.kernels.ref import bt_count_ref, psu_sort_ref, quantize_egress_ref


@pytest.mark.parametrize("shape", [(1, 8), (3, 25), (64, 64), (65, 49), (130, 32)])
@pytest.mark.parametrize("k", [None, 2, 4, 8])
def test_psu_matches_oracle(shape, k):
    rng = np.random.default_rng(hash((shape, k)) % 2**31)
    x = jnp.asarray(rng.integers(0, 256, shape, dtype=np.uint8))
    o, r = psu_sort(x, k=k)
    oref, rref = psu_sort_ref(x, k=k)
    np.testing.assert_array_equal(np.asarray(o), np.asarray(oref))
    np.testing.assert_array_equal(np.asarray(r), np.asarray(rref))


@pytest.mark.parametrize("dtype", [np.uint8, np.int32])
def test_psu_dtypes(dtype):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(0, 256, (16, 16)).astype(dtype))
    o, _ = psu_sort(x)
    oref, _ = psu_sort_ref(x)
    np.testing.assert_array_equal(np.asarray(o), np.asarray(oref))


def test_psu_descending():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.integers(0, 256, (8, 32), dtype=np.uint8))
    out = np.asarray(psu_reorder(x, descending=True))
    p = np.bitwise_count(out).astype(np.int32)  # signed: np.diff must not wrap
    assert all((np.diff(row) <= 0).all() for row in p)


@given(st.integers(2, 600), st.sampled_from([8, 16, 128]))
def test_bt_kernel_matches_oracle(t, lanes):
    rng = np.random.default_rng(t * lanes)
    s = jnp.asarray(rng.integers(0, 256, (t, lanes), dtype=np.uint8))
    assert int(bt_count(s)) == int(bt_count_ref(s))


def test_bt_kernel_block_boundaries():
    # sizes straddling the 512-row block boundary
    for t in (511, 512, 513, 1025):
        rng = np.random.default_rng(t)
        s = jnp.asarray(rng.integers(0, 256, (t, 16), dtype=np.uint8))
        assert int(bt_count(s)) == int(bt_count_ref(s))


@pytest.mark.parametrize("m", [256, 300, 8192, 100_000])
def test_quantizer_matches_oracle(m):
    rng = np.random.default_rng(m)
    x = jnp.asarray(rng.normal(size=(m,)).astype(np.float32) * rng.lognormal(0, 2))
    q, s, mp = quantize_egress(x)
    qr, sr = quantize_egress_ref(jnp.pad(x, (0, int(mp) - m)))
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)


def test_quantizer_roundtrip_error_bound():
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(size=(4096,)).astype(np.float32))
    q, s, _ = quantize_egress(x)
    deq = (q.astype(jnp.float32).reshape(-1, 256) * s[:, None]).reshape(-1)[:4096]
    amax_per_block = np.abs(np.asarray(x).reshape(-1, 256)).max(1)
    err = np.abs(np.asarray(deq - x)).reshape(-1, 256).max(1)
    assert (err <= amax_per_block / 127.0 * 0.5 + 1e-7).all()
