"""ZeRO-1 optimizer-state sharding rules (§Perf C6 lever)."""

import jax
import numpy as np
from jax.sharding import Mesh

from repro.configs import get_config
from repro.launch.sharding import opt_shardings, params_shardings
from repro.models import param_shapes
from repro.optim import init as opt_init

MESH = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))


def test_zero1_shards_mv_over_data():
    cfg = get_config("internlm2-1.8b", zero1=True)
    ps = param_shapes(cfg)
    os_shapes = jax.eval_shape(opt_init, ps)
    sh = opt_shardings(cfg, MESH, os_shapes, ps)
    p_sh = params_shardings(cfg, MESH, ps)
    # every m/v leaf with a free divisible axis gains a "data" placement the
    # param sharding does not have
    n_data = sum("data" in str(s.spec) for s in jax.tree.leaves(sh.m))
    n_data_params = sum("data" in str(s.spec) for s in jax.tree.leaves(p_sh))
    assert n_data > 0
    assert n_data_params == 0  # params keep pure-TP sharding
    # step stays replicated
    assert sh.step.spec == jax.sharding.PartitionSpec()


def test_zero1_off_mirrors_params():
    cfg = get_config("internlm2-1.8b")
    ps = param_shapes(cfg)
    os_shapes = jax.eval_shape(opt_init, ps)
    sh = opt_shardings(cfg, MESH, os_shapes, ps)
    p_sh = params_shardings(cfg, MESH, ps)
    for a, b in zip(jax.tree.leaves(sh.m), jax.tree.leaves(p_sh)):
        assert a.spec == b.spec
