"""Link framing edge cases (DESIGN.md §1): pack/unpack round-trips,
single-flit packets, and non-byte-multiple sort-key widths."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import psu_stream
from repro.kernels.ref import psu_stream_ref
from repro.link import (
    LinkSpec,
    TxPipeline,
    pack_to_flits,
    paired_stream,
    unpack_from_flits,
)


@pytest.mark.parametrize("pack", ["row", "lane"])
@pytest.mark.parametrize(
    "shape,lanes",
    [
        ((5, 64), 8),
        ((5, 64), 16),
        ((7, 16), 16),  # single-flit packets: F = 1
        ((3, 8), 8),  # single-flit, minimal lanes
        ((1, 32), 8),  # single packet
    ],
)
def test_pack_unpack_round_trip(pack, shape, lanes):
    rng = np.random.default_rng(hash((pack, shape, lanes)) % 2**31)
    v = jnp.asarray(rng.integers(0, 256, shape, dtype=np.uint8))
    flits = pack_to_flits(v, lanes, pack)
    assert flits.shape == (shape[0], shape[1] // lanes, lanes)
    assert (np.asarray(unpack_from_flits(flits, pack)) == np.asarray(v)).all()


def test_single_flit_packets_through_tx_pipeline():
    """F=1 framing: each packet is one flit; 'row' and 'lane' packing
    coincide and the fused path equals the staged one."""
    spec = LinkSpec(
        width_bits=128, flits_per_packet=1, input_lanes=8, weight_lanes=8
    )
    assert spec.elems_per_packet == 8
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(0, 256, (12, 8), dtype=np.uint8))
    w = jnp.asarray(rng.integers(0, 256, (12, 8), dtype=np.uint8))
    fused = TxPipeline(spec, fused=True).run(x, w)
    staged = TxPipeline(spec, fused=False).run(x, w)
    assert fused.stream.shape == (12, 16)
    assert (np.asarray(fused.stream) == np.asarray(staged.stream)).all()
    assert int(fused.bt_input) == int(staged.bt_input)
    assert int(fused.bt_weight) == int(staged.bt_weight)
    # row/lane packing coincide at F=1
    row = pack_to_flits(x, 8, "row")
    lane = pack_to_flits(x, 8, "lane")
    assert (np.asarray(row) == np.asarray(lane)).all()


@pytest.mark.parametrize("width", [4, 5])
def test_non_byte_multiple_key_widths(width):
    """Sort keys narrower than a byte (W=4/5): the fused kernel, the ref
    composition and the staged pipeline agree, and the wire image
    round-trips through pack/unpack as a per-packet permutation."""
    rng = np.random.default_rng(width)
    x = jnp.asarray(rng.integers(0, 2**width, (10, 32), dtype=np.uint8))
    w = jnp.asarray(rng.integers(0, 2**width, (10, 32), dtype=np.uint8))
    res = psu_stream(x, w, width=width, input_lanes=8)
    order, rank, stream, bt_i, bt_w = psu_stream_ref(
        x, w, width=width, input_lanes=8
    )
    assert (np.asarray(res.stream) == np.asarray(stream)).all()
    assert int(res.bt_input) == int(bt_i)

    spec = LinkSpec(key="acc", width=width)
    fused = TxPipeline(spec, fused=True).run(x, w)
    staged = TxPipeline(spec, fused=False).run(x, w)
    assert int(fused.bt_input) == int(staged.bt_input)
    assert int(fused.bt_weight) == int(staged.bt_weight)

    # unpacking the input half of the wire recovers each packet's bytes
    # up to the transmit permutation
    half = fused.stream[:, :8].reshape(10, 4, 8)
    back = unpack_from_flits(half, "lane")
    assert (
        np.sort(np.asarray(back), axis=-1) == np.sort(np.asarray(x), axis=-1)
    ).all()


def test_paired_stream_round_trips_byte_content():
    cfg = LinkSpec()
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.integers(0, 256, (6, 32), dtype=np.uint8))
    w = jnp.asarray(rng.integers(0, 256, (6, 32), dtype=np.uint8))
    s = paired_stream(x, w, cfg, "acc")
    # both halves carry exactly the packets' bytes (reordered)
    halves = np.asarray(s).reshape(6, 4, 16)
    for side, src in ((halves[:, :, :8], x), (halves[:, :, 8:], w)):
        back = unpack_from_flits(jnp.asarray(side), "lane")
        assert (
            np.sort(np.asarray(back), -1) == np.sort(np.asarray(src), -1)
        ).all()


def test_stream_only_pack_rejected_with_registry_ux():
    v = jnp.zeros((2, 16), jnp.uint8)
    with pytest.raises(ValueError, match="stream-only"):
        pack_to_flits(v, 8, "col")
    with pytest.raises(ValueError, match="registered pack stages"):
        unpack_from_flits(jnp.zeros((2, 2, 8), jnp.uint8), "bogus")
