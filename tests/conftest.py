import os

# Tests must see the real (single) CPU device — the 512-device flag belongs
# to the dry-run entry point ONLY (repro/launch/dryrun.py).
assert "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", "")

from hypothesis import HealthCheck, settings

settings.register_profile(
    "repro",
    max_examples=25,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow],
)
settings.load_profile("repro")
