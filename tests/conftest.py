import os
import sys
import types

# Tests must see the real (single) CPU device — the 512-device flag belongs
# to the dry-run entry point ONLY (repro/launch/dryrun.py).
assert "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", "")

try:
    from hypothesis import HealthCheck, settings

    settings.register_profile(
        "repro",
        max_examples=25,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow],
    )
    settings.load_profile("repro")
except ModuleNotFoundError:
    # hypothesis is optional: property tests auto-skip, everything else runs.
    # A stub module is installed so `from hypothesis import given` (and
    # `strategies as st`) in test modules import cleanly; the @given
    # decorator replaces the test body with a skip.
    import pytest

    def _given(*_args, **_kwargs):
        def decorate(_fn):
            def skipper(*a, **k):
                pytest.skip("hypothesis not installed")

            # deliberately no functools.wraps: pytest must see the (*a, **k)
            # signature, not the test's hypothesis-provided parameters
            skipper.__name__ = getattr(_fn, "__name__", "hypothesis_test")
            skipper.__doc__ = getattr(_fn, "__doc__", None)
            return skipper

        return decorate

    def _strategy_stub(*_args, **_kwargs):
        return None

    _st = types.ModuleType("hypothesis.strategies")
    for _name in (
        "integers", "sampled_from", "lists", "floats", "booleans", "text",
        "tuples", "one_of", "just", "composite", "binary",
    ):
        setattr(_st, _name, _strategy_stub)

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.strategies = _st
    _hyp.settings = _strategy_stub
    _hyp.HealthCheck = types.SimpleNamespace(too_slow=None)
    _hyp.assume = _strategy_stub
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
