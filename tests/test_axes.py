"""The unified multi-axis BT kernel core (DESIGN.md §12).

Load-bearing claims:

  * ONE ``bt_count_axes`` launch covers jagged links x every ordering
    (none / column_major / acc / app k in {2,4,8} x direction) x every
    codec (none / gray / transition / bus-invert w/ partitions) x width
    4/8 x non-block-multiple P, each (link, config) cell bit-exact vs the
    sequential ``kernels/ref.py`` composition on that link's real packets;
  * the four historical entry points (``psu_stream``, ``bt_count_links``,
    ``bt_count_variants``, ``bt_count_codecs``) are thin configurations of
    the same kernel and still trace to exactly one ``pallas_call``;
  * the unified masking convention makes padded flits contribute zero
    aux-BT: a bus-invert decision is never evaluated on a padded flit
    (the old repeated-flit convention was BT-neutral for data wires only)
    — regression-tested on a jagged mesh with ``bus_invert``;
  * ``repro.dse.evaluate_grid`` with a NoC topology AND a codec axis
    traces to ONE pallas launch, with the fabric numbers bit-exact vs the
    ``repro.noc.simulate_noc`` composition;
  * ``conv_streams`` pads its final partial packet (repeated-flit
    convention) instead of silently dropping trailing bytes, and cycles
    the layer's output-channel kernels through the weight stream.
"""

import pathlib
import sys

import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
from benchmarks.datagen import conv_streams, im2col, synth_images  # noqa: E402

from repro.kernels import (  # noqa: E402
    CodecVariant,
    Variant,
    bt_count_axes,
    bt_count_codecs,
    bt_count_links,
    bt_count_variants,
    pallas_launch_count,
    psu_stream,
)
from repro.kernels.ref import bt_codecs_ref  # noqa: E402


def _stack_jagged(arrays):
    """(P_l, N) packet queues -> zero-padded (L, P_max, N) + valid tuple."""
    valid = tuple(a.shape[0] for a in arrays)
    pmax = max(valid)
    return (
        jnp.stack(
            [jnp.pad(a, ((0, pmax - a.shape[0]), (0, 0))) for a in arrays]
        ),
        valid,
    )


def _grid_configs(width):
    orderings = [("none", None, False), ("column_major", None, False),
                 ("acc", None, False), ("acc", None, True)]
    orderings += [("app", k, False) for k in (2, 4, 8) if k <= width + 1]
    codecs = [("none", None), ("gray", None), ("transition", None),
              ("bus_invert", None), ("bus_invert", 4)]
    return tuple(
        CodecVariant(key, k, desc, scheme, part)
        for key, k, desc in orderings
        for scheme, part in codecs
    )


# ----------------------------------------------- the multi-axis bit-exactness


@pytest.mark.parametrize("width", [4, 8])
def test_axes_matches_reference_per_link_and_config(width):
    """Acceptance: jagged links x ordering x codec x width in ONE launch,
    every cell bit-exact (data sides AND invert lines) vs ref.py on that
    link's real packets."""
    rng = np.random.default_rng(width)
    hi = 2**width if width < 8 else 256
    # deliberately non-block-multiple, all-different link lengths
    ps = [37, 16, 53]
    xs = [jnp.asarray(rng.integers(0, hi, (p, 32), dtype=np.uint8)) for p in ps]
    ws = [jnp.asarray(rng.integers(0, 256, (p, 32), dtype=np.uint8)) for p in ps]
    x, valid = _stack_jagged(xs)
    w, _ = _stack_jagged(ws)
    configs = _grid_configs(width)
    got = np.asarray(
        bt_count_axes(
            x, w, valid=valid, configs=configs, width=width, input_lanes=8,
            block_packets=16,
        )
    )
    for i, p in enumerate(valid):
        ref = np.asarray(
            bt_codecs_ref(
                xs[i], ws[i], configs, width=width, input_lanes=8,
                weight_lanes=8,
            )
        )
        np.testing.assert_array_equal(got[i], ref, err_msg=f"link {i}")


def test_axes_input_only_row_pack_and_split_lanes():
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.integers(0, 256, (33, 48), dtype=np.uint8))
    configs = (CodecVariant("none"), CodecVariant("app", 4, codec="gray"))
    for pack in ("lane", "row"):
        got = np.asarray(
            bt_count_axes(
                x[None], None, configs=configs, input_lanes=16, pack=pack,
                block_packets=8,
            )
        )[0]
        ref = np.asarray(
            bt_codecs_ref(x, None, configs, input_lanes=16, weight_lanes=0,
                          pack=pack)
        )
        np.testing.assert_array_equal(got, ref)
        assert (got[:, 1] == 0).all()  # no weight side


# -------------------------------------------------- launch-count assertions


def test_every_entry_point_is_one_launch():
    """The four rebuilt entry points and the full multi-axis call each
    trace to exactly ONE pallas_call."""
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.integers(0, 256, (40, 32), dtype=np.uint8))
    w = jnp.asarray(rng.integers(0, 256, (40, 32), dtype=np.uint8))
    s = jnp.asarray(rng.integers(0, 256, (3, 19, 16), dtype=np.uint8))
    configs = _grid_configs(8)
    assert pallas_launch_count(
        lambda a, b: psu_stream(a, b, k=4, block_packets=16), x, w
    ) == 1
    assert pallas_launch_count(
        lambda a: bt_count_variants(
            a, None, variants=(Variant("none"), Variant("acc"),
                               Variant("app", 4)), block_packets=16,
        ), x,
    ) == 1
    assert pallas_launch_count(
        lambda a, b: bt_count_codecs(
            a, b, configs=configs, block_packets=16
        ), x, w,
    ) == 1
    assert pallas_launch_count(
        lambda a: bt_count_links(a, input_lanes=8, block_rows=8), s
    ) == 1
    assert pallas_launch_count(
        lambda a, b: bt_count_axes(
            a[None], b[None], configs=configs, block_packets=16
        ), x, w,
    ) == 1


# ------------------------------------- jagged mesh + bus-invert regression


def test_jagged_mesh_bus_invert_padding_contributes_zero_aux():
    """Satellite regression: on a jagged mesh (links carrying different
    queue lengths) with a ``bus_invert`` codec, the kernel's masking keeps
    padded flits out of the invert decision — per-link (data, aux) equal
    the ``simulate_noc`` composition, while treating the repeated-flit
    padding as real flits provably flips invert lines."""
    from repro.link import LinkSpec
    from repro.noc import TrafficFlow, mesh, simulate_noc
    from repro.noc.simulate import expand_link_streams

    rng = np.random.default_rng(13)
    topo = mesh(3, 3)
    spec = LinkSpec(
        width_bits=128, flits_per_packet=4, input_lanes=16, weight_lanes=0,
        key="acc", codec="bus_invert4",
    )
    n = spec.elems_per_packet
    flows = [
        TrafficFlow("long", 0, (8,),
                    jnp.asarray(rng.integers(0, 256, (21, n), np.uint8))),
        TrafficFlow("short", 2, (8,),
                    jnp.asarray(rng.integers(0, 256, (6, n), np.uint8))),
    ]
    rep = simulate_noc(topo, flows, spec, sort_at="source")
    ls = expand_link_streams(topo, flows, spec, sort_at="source")
    assert len(set(ls.lengths)) > 1  # genuinely jagged

    # the same jagged links through ONE multi-axis launch: each coded wire
    # row is an N = lanes packet with the identity ordering; bus-invert is
    # applied in-kernel on the UN-coded queue, so feed the plain streams
    import dataclasses

    plain = expand_link_streams(
        topo, flows, dataclasses.replace(spec, codec="none"),
        sort_at="source",
    )
    cfg = (CodecVariant("none", codec="bus_invert", partition=4),)
    got = np.asarray(
        bt_count_axes(
            plain.streams, None, valid=plain.lengths, configs=cfg,
            input_lanes=16, block_packets=8,
        )
    )[:, 0]
    by_id = {s.link: s for s in rep.links}
    for i, lid in enumerate(plain.link_ids):
        s = by_id[lid]
        assert tuple(got[i].tolist()) == (s.bt_input, s.bt_weight, s.bt_aux)

    # the hazard the masking removes, pinned deterministically: jagged
    # links are zero-padded in the stacked tensor, and a bus-invert
    # decision evaluated on a padded zero flit fires whenever the previous
    # wire is mostly-high (HD(0, w_prev) = popcount(w_prev)) — flipping
    # the invert line.  Masked, the pad contributes zero aux-BT.
    ones = jnp.full((1, 16), 255, jnp.uint8)  # one real all-high flit
    long_link = jnp.zeros((4, 16), jnp.uint8)
    stacked, valid = _stack_jagged([ones, long_link])
    bi = (CodecVariant("none", codec="bus_invert"),)
    masked = np.asarray(
        bt_count_axes(stacked, None, valid=valid, configs=bi,
                      input_lanes=16, block_packets=4)
    )[0, 0]
    unmasked = np.asarray(
        bt_count_axes(stacked, None, valid=None, configs=bi,
                      input_lanes=16, block_packets=4)
    )[0, 0]
    assert tuple(masked.tolist()) == (0, 0, 0)  # a lone flit flips nothing
    assert unmasked[2] > 0  # the padded zeros fired the invert decision


def test_bt_count_links_lengths_mask_any_padding():
    """With explicit lengths the padding VALUE is irrelevant (the unified
    convention) — garbage tails measure identically to trimmed streams."""
    rng = np.random.default_rng(17)
    streams = [
        jnp.asarray(rng.integers(0, 256, (t, 8), dtype=np.uint8))
        for t in (19, 7, 31)
    ]
    stacked, valid = _stack_jagged(streams)
    garbage = stacked + jnp.asarray(
        rng.integers(0, 256, stacked.shape, dtype=np.uint8)
    ) * (jnp.arange(stacked.shape[1])[None, :, None] >= jnp.asarray(valid)[:, None, None])
    got = np.asarray(bt_count_links(garbage, input_lanes=4, lengths=valid,
                                    block_rows=8))
    for i, s in enumerate(streams):
        ref = np.asarray(bt_count_links(s[None], input_lanes=4))[0]
        np.testing.assert_array_equal(got[i], ref)


# ------------------------------------------- dse: the one-launch full grid


def test_evaluate_grid_with_noc_and_codec_is_one_launch():
    """Acceptance: a grid mixing a NoC topology and a codec axis traces to
    exactly ONE pallas launch, and the fabric numbers are bit-exact vs the
    repro.noc composition."""
    from repro.dse import DesignPoint, Workload, evaluate_grid, grid_launch_count
    from repro.link import LinkSpec
    from repro.noc import TrafficFlow, hop_count, simulate_noc
    from repro.dse.space import parse_topology

    rng = np.random.default_rng(23)
    streams = (
        jnp.asarray(rng.integers(0, 256, (40, 64), dtype=np.uint8)),
        jnp.asarray(rng.integers(0, 256, (25, 64), dtype=np.uint8)),
    )
    workload = Workload("rand", streams, lanes=16)
    pts = (
        DesignPoint(ordering="acc", k=None, topology="mesh3x3"),
        DesignPoint(ordering="acc", k=None, codec="bus_invert4",
                    topology="mesh3x3"),
        DesignPoint(ordering="app", k=4),
    )
    assert grid_launch_count(pts, workload) == 1
    evals = evaluate_grid(pts, workload)
    plain, coded, _ = evals
    assert plain.noc_active_links == coded.noc_active_links == 4

    # reference composition: repro.noc end to end, per point
    topo = parse_topology("mesh3x3")
    far = max(range(topo.num_routers), key=lambda r: hop_count(topo, 0, r))

    def fabric_gross(key, codec):
        spec = LinkSpec(
            width_bits=128, flits_per_packet=4, input_lanes=16,
            weight_lanes=0, key=key, k=4, codec=codec,
        )
        flows = [
            TrafficFlow(f"s{i}", 0, (far,), s) for i, s in enumerate(streams)
        ]
        return simulate_noc(topo, flows, spec, sort_at="source").gross_bt

    base = fabric_gross("none", "none")
    assert plain.noc_bt_reduction == pytest.approx(
        1 - fabric_gross("acc", "none") / base, abs=1e-12
    )
    assert coded.noc_bt_reduction == pytest.approx(
        1 - fabric_gross("acc", "bus_invert4") / base, abs=1e-12
    )


# ------------------------------------------------ conv_streams regressions


def test_conv_streams_pads_instead_of_truncating():
    """One image's 19600-byte stream is not a whole number of 64-byte
    packets: every real byte must survive and the tail must follow the
    repeated-flit convention."""
    inp, wgt = conv_streams(n_images=1, elems=64, lanes=16)
    raw = np.concatenate([im2col(im, 5).reshape(-1)
                          for im in synth_images(1, seed=42)])
    assert raw.size == 19600 and raw.size % 64 != 0  # the boundary case
    assert inp.shape == ((raw.size + 63) // 64, 64)
    flat = inp.reshape(-1)
    np.testing.assert_array_equal(flat[: raw.size], raw)  # nothing dropped
    pad = flat[raw.size:]
    np.testing.assert_array_equal(
        pad, np.resize(raw[-16:], pad.size)  # cycled last 16-byte flit
    )
    assert wgt.shape == inp.shape
    # streams that already fit whole packets are untouched (24 images)
    inp24, _ = conv_streams(n_images=4, elems=64)
    assert (inp24.size % 64) == 0


def test_conv_streams_cycles_output_channel_kernels():
    """The weight stream cycles C distinct kernels (LeNet conv1: 6) per
    the PE allocation instead of broadcasting one."""
    _, wgt6 = conv_streams(n_images=1, channels=6)
    _, wgt1 = conv_streams(n_images=1, channels=1)
    flat6, flat1 = wgt6.reshape(-1), wgt1.reshape(-1)
    # channels=1 reproduces the broadcast model: period-25 stream
    assert (flat1[:19600].reshape(-1, 25) == flat1[:25]).all()
    # channels=6 cycles: consecutive 25-byte kernels differ, period 6*25
    rows6 = flat6[:19600].reshape(-1, 25)
    assert not (rows6 == rows6[0]).all()
    np.testing.assert_array_equal(rows6[6], rows6[0])
    assert len({r.tobytes() for r in rows6[:6]}) == 6
