"""Fused TX-pipeline kernel + repro.link subsystem.

The load-bearing claim: the single-launch ``psu_stream`` kernel is bit-exact
against the unfused ``repro.core.sorting`` reference composition (sort ->
gather -> flit-pack -> BT count) across strategies, widths, directions and
non-block-multiple packet counts — so the fused hot path can replace the
three-launch path everywhere without changing any reported number.
"""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    apply_order,
    bit_transitions,
    bucket_map,
    counting_sort_indices,
    counting_sort_ranks,
    popcount,
)
from repro.kernels import psu_stream
from repro.link import LinkReport, LinkSpec, TxPipeline


def _sorting_reference(x, w, *, width, k, descending, input_lanes, weight_lanes,
                       pack="lane"):
    """Unfused reference built ONLY from repro.core.sorting + repro.core.bt:
    the one-hot counting-sort formulation the fused kernel replaced."""
    keys = popcount(x, width)
    nb = width + 1
    if k is not None:
        keys = bucket_map(keys, width, k)
        nb = k
    if descending:
        keys = (nb - 1) - keys
    rank = counting_sort_ranks(keys, nb)
    order = counting_sort_indices(keys, nb)
    p, n = x.shape
    flits = n // input_lanes

    def fl(values, lanes):
        if pack == "lane":
            return values.reshape(p, lanes, flits).transpose(0, 2, 1)
        return values.reshape(p, flits, lanes)

    halves = [fl(apply_order(x.astype(jnp.int32), order), input_lanes)]
    if weight_lanes:
        halves.append(fl(apply_order(w.astype(jnp.int32), order), weight_lanes))
    stream = jnp.concatenate(halves, axis=-1).reshape(
        p * flits, input_lanes + weight_lanes
    )
    bt_i = int(bit_transitions(stream[:, :input_lanes]))
    bt_w = int(bit_transitions(stream[:, input_lanes:])) if weight_lanes else 0
    return order, rank, stream.astype(jnp.uint8), bt_i, bt_w


@pytest.mark.parametrize("k", [None, 4])  # ACC / APP
@pytest.mark.parametrize("width", [4, 8])
@pytest.mark.parametrize("descending", [False, True])
@pytest.mark.parametrize("p", [64, 65, 7, 130])  # incl. non-block-multiples
def test_fused_matches_core_sorting_reference(k, width, descending, p):
    rng = np.random.default_rng(hash((k, width, descending, p)) % 2**31)
    x = jnp.asarray(rng.integers(0, 256, (p, 32), dtype=np.uint8))
    w = jnp.asarray(rng.integers(0, 256, (p, 32), dtype=np.uint8))
    res = psu_stream(x, w, width=width, k=k, descending=descending,
                     block_packets=64)
    oref, rref, sref, bi, bw = _sorting_reference(
        x, w, width=width, k=k, descending=descending,
        input_lanes=8, weight_lanes=8,
    )
    np.testing.assert_array_equal(np.asarray(res.order), np.asarray(oref))
    np.testing.assert_array_equal(np.asarray(res.rank), np.asarray(rref))
    np.testing.assert_array_equal(np.asarray(res.stream), np.asarray(sref))
    assert int(res.bt_input) == bi
    assert int(res.bt_weight) == bw


@pytest.mark.parametrize("pack", ["lane", "row"])
def test_fused_input_only_and_row_pack(pack):
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.integers(0, 256, (33, 48), dtype=np.uint8))
    res = psu_stream(x, None, k=4, input_lanes=16, pack=pack, block_packets=8)
    oref, rref, sref, bi, _ = _sorting_reference(
        x, x, width=8, k=4, descending=False,
        input_lanes=16, weight_lanes=0, pack=pack,
    )
    np.testing.assert_array_equal(np.asarray(res.order), np.asarray(oref))
    np.testing.assert_array_equal(np.asarray(res.stream), np.asarray(sref))
    assert int(res.bt_input) == bi
    assert int(res.bt_weight) == 0


# ---------------------------------------------------------------- TxPipeline


def test_pipeline_fused_and_staged_paths_agree():
    rng = np.random.default_rng(5)
    spec = LinkSpec(key="app", k=4)
    inp = jnp.asarray(rng.integers(0, 256, (50, spec.elems_per_packet), np.uint8))
    wgt = jnp.asarray(rng.integers(0, 256, (50, spec.elems_per_packet), np.uint8))
    fused = TxPipeline(spec, fused=True).measure(inp, wgt)
    staged = TxPipeline(spec, fused=False).measure(inp, wgt)
    assert fused.fused and not staged.fused
    assert fused.input_bt == staged.input_bt
    assert fused.weight_bt == staged.weight_bt
    assert fused.num_flits == staged.num_flits
    # streams agree byte-for-byte too
    np.testing.assert_array_equal(
        np.asarray(TxPipeline(spec, fused=True).transmit(inp, wgt)),
        np.asarray(TxPipeline(spec, fused=False).transmit(inp, wgt)),
    )


def test_pipeline_matches_legacy_measure():
    from repro.core import measure as legacy_measure

    rng = np.random.default_rng(6)
    inp = jnp.asarray(rng.integers(0, 256, (40, 32), np.uint8))
    wgt = jnp.asarray(rng.integers(0, 256, (40, 32), np.uint8))
    for key in ("none", "column_major", "acc", "app"):
        rep = TxPipeline(LinkSpec(key=key)).measure(inp, wgt)
        old = legacy_measure(inp, wgt, strategy=key)
        assert rep.overall_bt_per_flit == pytest.approx(
            float(old.overall_bt_per_flit), rel=1e-6
        )


def test_pipeline_encode_stage_changes_wire_image():
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.integers(-127, 128, (20, 32), np.int8))
    raw = TxPipeline(LinkSpec(key="acc")).measure(q, q)
    sm = TxPipeline(LinkSpec(key="acc", encode="sign_magnitude")).measure(q, q)
    assert raw.total_bt != sm.total_bt  # recoding changed the stream


def test_pipeline_asymmetric_falls_back_to_staged():
    rng = np.random.default_rng(8)
    spec = LinkSpec(input_lanes=12, weight_lanes=4, key="acc")
    inp = jnp.asarray(rng.integers(0, 256, (10, spec.elems_per_packet), np.uint8))
    wgt = jnp.asarray(
        rng.integers(0, 256, (10, spec.weight_elems_per_packet), np.uint8)
    )
    rep = TxPipeline(spec).measure(inp, wgt)
    assert not rep.fused
    assert rep.num_flits == 10 * spec.flits_per_packet
    with pytest.raises(ValueError):
        TxPipeline(spec, fused=True).measure(inp, wgt)


def test_pipeline_row_stream_col_layout():
    rng = np.random.default_rng(9)
    rows = jnp.asarray(
        (rng.normal(size=(128, 64)) * rng.lognormal(0, 1.2, (128, 1)) * 20)
        .clip(-127, 127).astype(np.int8)
    )
    spec = LinkSpec(
        flits_per_packet=1, input_lanes=16, weight_lanes=0,
        key="row_bucket", encode="sign_magnitude", pack="col", k=9,
    )  # k=9 = ACC-granularity row buckets
    base = TxPipeline(dataclasses.replace(spec, key="none")).measure_rows(rows)
    ordered = TxPipeline(spec).measure_rows(rows)
    assert base.num_flits == ordered.num_flits == 128 * 64 // 16
    # ordering magnitude-structured rows under col layout reduces BT
    assert ordered.total_bt < base.total_bt


def test_link_report_accounting():
    rep = LinkReport("x", num_flits=10, input_bt=30, weight_bt=10, fused=True)
    base = LinkReport("x", num_flits=10, input_bt=50, weight_bt=30)
    assert rep.total_bt == 40
    assert rep.overall_bt_per_flit == pytest.approx(4.0)
    assert rep.reduction_vs(base) == pytest.approx(0.5)
    bt = rep.to_bt_report()
    assert float(bt.overall_bt_per_flit) == pytest.approx(4.0)


def test_spec_validates_stage_names_and_framing():
    with pytest.raises(ValueError):
        LinkSpec(key="bogus")
    with pytest.raises(ValueError):
        LinkSpec(encode="bogus")
    with pytest.raises(ValueError):
        LinkSpec(input_lanes=9)  # 9 + 8 != 16


# ------------------------------------------------------------- import shims


def test_legacy_import_paths_still_work():
    from repro.core.link import LinkConfig, paired_stream  # noqa: F401
    from repro.core.ordering import ORDER_STRATEGIES, make_order

    import repro.core as core

    assert core.LinkConfig is LinkSpec  # the shim aliases the new spec
    assert set(ORDER_STRATEGIES) == {"none", "column_major", "acc", "app"}
    rng = np.random.default_rng(10)
    x = jnp.asarray(rng.integers(0, 256, (3, 32), np.uint8))
    order = core.make_order("acc", x, lanes=8)
    assert order is not None and order.shape == (3, 32)
    assert make_order is core.make_order
