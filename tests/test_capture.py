"""Real-model traffic capture (``repro.obs.capture``, DESIGN.md §16).

The load-bearing claim mirrors ``tests/test_obs.py``: ZERO cost when off.
Model-zoo hot paths carry tap sites (``repro._obs_hooks.tap`` — a None
test while no capture is active), and the installed tap performs no jax
operation on tracer payloads, so every model-zoo traced jaxpr is
byte-identical whether capture is absent from the process, imported but
inactive, or actively recording (subprocess- and in-process-pinned).

The rest pins capture determinism, the save/load replay round-trip, BT
consistency between captured packets and ``stream_bt_report`` (same wire
image, same totals), the clear flit-divisibility error, the per-config
smoke (every ``repro.configs`` arch flows through capture), the trained
LeNet (learns + checkpoints + restores), and the MoE dispatch adapter.
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import _obs_hooks, obs
from repro.configs import ARCH_NAMES, smoke_config
from repro.link import LinkSpec, TxPipeline
from repro.models import init_cache, init_params
from repro.models.moe import init_moe, moe_block
from repro.noc import mesh, moe_dispatch_flows, simulate_noc
from repro.optim import AdamWConfig
from repro.optim import init as opt_init
from repro.train import make_train_step

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _dense_cfg():
    return smoke_config("qwen3-4b")


def _model_jaxprs(cfg):
    """Traced-jaxpr strings of the tapped model-zoo entry points."""
    from repro.models import decode_step
    from repro.models.lenet import init_lenet, lenet_forward
    from repro.obs.capture import train_batch

    key = jax.random.key(0)
    params = init_params(cfg, key)
    cache = init_cache(cfg, 2, 8)
    tok = jnp.zeros((2, 1), jnp.int32)
    step = make_train_step(cfg, AdamWConfig(warmup_steps=1, total_steps=10))
    opt = opt_init(params)
    batch = train_batch(cfg, 2, 8)
    lparams = init_lenet(key)
    imgs = jnp.zeros((2, 32, 32, 1), jnp.float32)
    return {
        "decode_step": str(jax.make_jaxpr(
            lambda p, c, t: decode_step(p, cfg, c, t))(params, cache, tok)),
        "train_step": str(jax.make_jaxpr(step)(params, opt, batch)),
        "lenet": str(jax.make_jaxpr(lenet_forward)(lparams, imgs)),
    }


# --------------------------------------------- zero cost when disabled


def test_model_jaxprs_identical_with_capture_absent_vs_active():
    """In a fresh process: serve/train/models never import repro.obs, and
    installing + activating capture leaves every model-zoo traced jaxpr
    byte-identical (the tentpole claim; capture therefore adds zero
    launches to any measured path)."""
    script = """
import sys

import jax
import jax.numpy as jnp

from repro.configs import smoke_config
from repro.models import decode_step, init_cache, init_params
from repro.models.lenet import init_lenet, lenet_forward
from repro.models.moe import init_moe, moe_block
from repro.optim import AdamWConfig, init as opt_init
from repro.serve.loop import generate
from repro.train import make_train_step

assert "repro.obs" not in sys.modules, "production code imported repro.obs"

cfg = smoke_config("qwen3-4b")
mcfg = smoke_config("qwen3-moe-30b-a3b")
key = jax.random.key(0)
params = init_params(cfg, key)
cache = init_cache(cfg, 2, 8)
tok = jnp.zeros((2, 1), jnp.int32)
step = make_train_step(cfg, AdamWConfig(warmup_steps=1, total_steps=10))
opt = opt_init(params)
batch = {
    "tokens": jnp.zeros((2, 8), jnp.int32),
    "labels": jnp.zeros((2, 8), jnp.int32),
}
mparams = init_moe(key, mcfg)
mx = jnp.zeros((2, 8, mcfg.d_model), jnp.dtype(mcfg.dtype))
lparams = init_lenet(key)
imgs = jnp.zeros((2, 32, 32, 1), jnp.float32)

def jaxprs():
    return {
        "decode_step": str(jax.make_jaxpr(
            lambda p, c, t: decode_step(p, cfg, c, t))(params, cache, tok)),
        "train_step": str(jax.make_jaxpr(step)(params, opt, batch)),
        "moe_block": str(jax.make_jaxpr(
            lambda p, x: moe_block(p, x, mcfg))(mparams, mx)),
        "lenet": str(jax.make_jaxpr(lenet_forward)(lparams, imgs)),
    }

before = jaxprs()
assert "repro.obs" not in sys.modules, "tracing imported repro.obs"
from repro import obs
mid = jaxprs()
with obs.capture() as sess:
    active = jaxprs()
assert before == mid, "importing repro.obs changed a model jaxpr"
assert before == active, "active capture changed a model jaxpr"
# the traced firings carried tracers and were dropped whole
assert sess.streams == [], "capture recorded tracer payloads"
print("CAPTURE-JAXPR-IDENTITY-OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(_REPO, "src"), _REPO]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else [])
    )
    env.setdefault("JAX_PLATFORMS", "cpu")
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        env=env, cwd=_REPO, timeout=600,
    )
    assert out.returncode == 0, out.stderr
    assert "CAPTURE-JAXPR-IDENTITY-OK" in out.stdout


def test_jaxpr_identity_in_process():
    """Same identity in this process: inactive vs installed vs recording."""
    cfg = _dense_cfg()
    assert _obs_hooks.TAP is None
    before = _model_jaxprs(cfg)
    with obs.capture() as sess:
        assert _obs_hooks.TAP is not None
        assert _obs_hooks.capturing()
        active = _model_jaxprs(cfg)
    assert _obs_hooks.TAP is None
    assert not _obs_hooks.capturing()
    assert before == active
    # every in-trace firing carried tracers and was dropped whole
    assert sess.streams == []


def test_tap_site_is_noop_without_capture():
    """A tap firing with no capture active records nowhere and returns."""
    _obs_hooks.tap("serve.weights", params={"w": jnp.ones((2, 2))})


# --------------------------------------------- recording real traffic


def test_capture_serve_decode_records_and_is_deterministic():
    cfg = _dense_cfg()
    a = obs.capture_serve_decode(cfg, batch=2, prompt=8, new_tokens=2)
    b = obs.capture_serve_decode(cfg, batch=2, prompt=8, new_tokens=2)
    assert a.scenarios() == ("serve_decode",)
    names = [s.name for s in a.streams]
    assert names == ["weights", "kv", "kv"]
    assert all(s.num_bytes > 0 for s in a.streams)
    assert all(s.data.dtype == np.uint8 for s in a.streams)
    # same model, same seed -> byte-identical capture (replay determinism)
    assert len(a.streams) == len(b.streams)
    for sa, sb in zip(a.streams, b.streams):
        np.testing.assert_array_equal(sa.data, sb.data)


def test_capture_train_and_moe_drivers():
    grads = obs.capture_train_step(_dense_cfg(), batch=2, seq=8)
    (g,) = grads.get("train_allreduce")
    assert g.kind == "train.grads" and g.num_bytes > 0

    moe = obs.capture_moe_dispatch(
        smoke_config("qwen3-moe-30b-a3b"), batch=2, seq=8
    )
    (e,) = moe.get("moe_dispatch")
    assert e.name == "expert_in" and len(e.source_shape) == 4
    with pytest.raises(ValueError, match="MoE"):
        obs.capture_moe_dispatch(_dense_cfg())


def test_capture_fires_probe_events():
    """Each recorded stream fires a capture.stream event: byte counters per
    scenario/stream land on active registries."""
    cfg = _dense_cfg()
    with obs.collect() as reg:
        sess = obs.capture_train_step(cfg, batch=2, seq=8)
    (g,) = sess.get("train_allreduce")
    assert reg.value(
        "capture.bytes", scenario="train_allreduce", stream="grads"
    ) == g.num_bytes
    assert reg.value(
        "capture.streams", scenario="train_allreduce", stream="grads"
    ) == 1


def test_nested_capture_sessions_both_record():
    with obs.capture() as outer:
        with obs.capture() as inner:
            obs.capture_train_step(_dense_cfg(), batch=2, seq=8)
    assert len(outer.streams) == len(inner.streams) == 1
    np.testing.assert_array_equal(outer.streams[0].data, inner.streams[0].data)


# --------------------------------------------- replay round-trip


def test_save_load_session_roundtrip(tmp_path):
    sess = obs.capture_train_step(_dense_cfg(), batch=2, seq=8)
    path = str(tmp_path / "capture.npz")
    obs.save_session(path, sess)
    back = obs.load_session(path)
    assert len(back.streams) == len(sess.streams)
    for sa, sb in zip(sess.streams, back.streams):
        assert (sa.scenario, sa.name, sa.kind) == (sb.scenario, sb.name, sb.kind)
        assert sa.source_shape == sb.source_shape
        assert sa.meta == sb.meta
        np.testing.assert_array_equal(sa.data, sb.data)
    # replayed workload measures identically
    wa = sess.workload("train_allreduce", elems=64)
    wb = back.workload("train_allreduce", elems=64)
    spec = LinkSpec(
        width_bits=128, flits_per_packet=4, input_lanes=16, weight_lanes=0,
        key="acc",
    )
    for a, b in zip(wa.streams, wb.streams):
        ra = TxPipeline(spec).measure(a)
        rb = TxPipeline(spec).measure(b)
        assert ra.overall_bt_per_flit == rb.overall_bt_per_flit


# --------------------------------------------- BT consistency


def test_capture_bt_matches_stream_bt_report():
    """The captured wire image is THE wire image: measuring a captured
    tensor's packets (row-pack framing) gives byte-identical baseline BT
    to ``repro.traffic.stream_bt_report`` on the original tensor."""
    from repro.traffic.ordering import stream_bt_report

    rng = np.random.default_rng(3)
    t = jnp.asarray(rng.normal(size=(8, 64)).astype(np.float32))
    sess = obs.CaptureSession()
    sess.add("manual", "w", t)
    pkts = sess.packets("manual", 64)
    spec = LinkSpec(
        width_bits=128, flits_per_packet=4, input_lanes=16, weight_lanes=0,
        key="none", pack="row",
    )
    m = TxPipeline(spec).measure(pkts)
    rep = stream_bt_report("w", t, strategy="acc", lanes=16, layout="row")
    assert m.num_flits == rep.num_flits
    assert int(round(m.overall_bt_per_flit * m.num_flits)) == rep.bt_none


def test_workload_bt_sums_over_streams():
    """Workload streams are measured independently (no seam transitions),
    so a scenario's total BT is exactly the sum of its per-stream BT —
    the sum-over-scenarios consistency behind the campaign tables."""
    from repro.dse import DesignPoint, evaluate_grid

    rng = np.random.default_rng(5)
    sess = obs.CaptureSession()
    for i, shape in enumerate([(4, 64), (6, 64)]):
        sess.add("manual", f"s{i}", jnp.asarray(
            rng.normal(size=shape).astype(np.float32)
        ))
    wl = sess.workload("manual", elems=64)
    (ev,) = evaluate_grid([DesignPoint(ordering="none", k=None)], wl)
    spec = LinkSpec(
        width_bits=128, flits_per_packet=4, input_lanes=16, weight_lanes=0,
        key="none",
    )
    per_stream = sum(
        int(round(
            TxPipeline(spec).measure(s).overall_bt_per_flit
            * 4 * int(s.shape[0])
        ))
        for s in wl.streams
    )
    assert ev.total_bt == per_stream


# --------------------------------------------- clear divisibility errors


def test_flit_divisibility_error_is_clear():
    sess = obs.CaptureSession()
    sess.add("manual", "odd", jnp.ones((10, 10), jnp.float32))  # 100 bytes
    with pytest.raises(ValueError, match="my-config.*not.*divisible"):
        sess.packets("manual", 64, owner="my-config", strict=True)
    # non-strict trims to whole packets instead
    assert sess.packets("manual", 64).shape == (1, 64)
    with pytest.raises(ValueError, match="smaller than one"):
        sess.packets("manual", 128, owner="my-config")
    with pytest.raises(ValueError, match="no captured streams"):
        sess.workload("nothing")


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_every_config_flows_through_capture(arch):
    """The satellite fix for the dead-weight model zoo: every one of the
    ~10 real configs drives a captured train step; non-flit-divisible
    shapes fail with the clear ValueError naming the config, never a
    shape crash."""
    cfg = smoke_config(arch)
    sess = obs.capture_train_step(cfg, batch=2, seq=8)
    streams = sess.get("train_allreduce")
    assert streams and all(s.num_bytes > 0 for s in streams)
    try:
        wl = sess.workload("train_allreduce", elems=64, owner=arch, strict=True)
    except ValueError as e:
        assert arch in str(e) and "divisible" in str(e)
        wl = sess.workload("train_allreduce", elems=64, owner=arch)
    assert wl.num_flits > 0


# --------------------------------------------- trained LeNet


def test_lenet_trains_and_checkpoints(tmp_path):
    from repro.models import lenet

    params, info = lenet.train_lenet(
        steps=30, batch=32, ckpt_dir=str(tmp_path)
    )
    assert info["restored"] is False
    # the synthetic task is learnable: well under chance cross-entropy
    assert info["final_loss"] < 1.0
    restored, info2 = lenet.train_lenet(
        steps=30, batch=32, ckpt_dir=str(tmp_path)
    )
    assert info2["restored"] is True and info2["steps"] == 30
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_lenet_capture_streams():
    sess = obs.capture_lenet_conv(steps=5, batch=16)
    names = {s.name for s in sess.get("lenet_conv")}
    assert names == {"conv1", "conv2", "inputs"}
    conv2 = sess.get("lenet_conv", "conv2")[0]
    assert conv2.source_shape == (5, 5, 6, 16)
    assert conv2.num_bytes == 5 * 5 * 6 * 16


# --------------------------------------------- MoE dispatch adapter


def test_moe_dispatch_flows_adapter():
    mcfg = smoke_config("qwen3-moe-30b-a3b")
    sess = obs.capture_moe_dispatch(mcfg, batch=2, seq=8)
    stream = sess.get("moe_dispatch", "expert_in")[0]
    expert_in = jnp.asarray(
        stream.data.view(np.int8).reshape(stream.source_shape)
    )
    topo = mesh(4, 4)
    spec = LinkSpec(
        width_bits=128, flits_per_packet=4, input_lanes=16, weight_lanes=0,
        key="acc",
    )
    flows = moe_dispatch_flows(
        expert_in, topo, 0, tuple(range(1, 16)), spec
    )
    assert flows and len(flows) <= stream.source_shape[1]
    assert all(f.src == 0 and len(f.dsts) == 1 for f in flows)
    rep = simulate_noc(topo, flows, spec, sort_at="source")
    assert rep.total_bt > 0
    with pytest.raises(ValueError, match="groups, experts"):
        moe_dispatch_flows(expert_in[0], topo, 0, (1,), spec)
    with pytest.raises(ValueError, match="weight_lanes=0"):
        moe_dispatch_flows(expert_in, topo, 0, (1,), LinkSpec(key="acc"))


def test_adapter_int8_passthrough():
    """int8/uint8 adapter inputs ARE their wire image: the flows carry the
    same bytes, not a re-quantized (rescaled) copy."""
    from repro.noc.adapters import _wire_bytes

    b = np.arange(-60, 68, dtype=np.int8)  # amax < 127: int8_view would rescale
    out = np.asarray(_wire_bytes(jnp.asarray(b)))
    np.testing.assert_array_equal(out, b.view(np.uint8))


# --------------------------------------------- probe vocabulary


def test_capture_kind_in_probe_vocabulary():
    assert obs.PROBE_KINDS["capture.stream"] == "event"
    assert set(obs.TAP_SCENARIOS) == {
        "serve.weights", "serve.kv", "train.grads", "moe.dispatch",
        "lenet.conv",
    }
