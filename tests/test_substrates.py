"""Data pipeline, optimizer, compression, checkpointing, fault tolerance."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, SyntheticLMDataset
from repro.optim import (
    AdamWConfig,
    CompressionConfig,
    compressed_psum,
    global_norm,
    init,
    lr_schedule,
    update,
)

KEY = jax.random.key(0)


# ---------------- data pipeline ----------------


def test_data_determinism_and_sharding():
    cfg = DataConfig(vocab=101, seq_len=16, global_batch=8, seed=5)
    ds = SyntheticLMDataset(cfg)
    g1, g2 = ds.global_batch(3), ds.global_batch(3)
    np.testing.assert_array_equal(g1["tokens"], g2["tokens"])
    # shards tile the global batch exactly, for any shard count
    for ns in (1, 2, 4, 8):
        parts = [ds.shard_batch(3, s, ns)["tokens"] for s in range(ns)]
        np.testing.assert_array_equal(np.concatenate(parts), g1["tokens"])
    # labels are next-token shifted
    row = ds._row(3, 0)
    np.testing.assert_array_equal(g1["tokens"][0], row[:-1])
    np.testing.assert_array_equal(g1["labels"][0], row[1:])


def test_data_steps_differ():
    ds = SyntheticLMDataset(DataConfig(vocab=101, seq_len=16, global_batch=2))
    assert not np.array_equal(ds.global_batch(0)["tokens"], ds.global_batch(1)["tokens"])


# ---------------- optimizer ----------------


def test_adamw_minimizes_quadratic():
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    state = init(params)
    cfg = AdamWConfig(peak_lr=0.1, warmup_steps=5, total_steps=300, weight_decay=0.0)
    for _ in range(300):
        grads = {"w": 2 * (params["w"] - target)}
        params, state, _ = update(cfg, grads, state, params)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target), atol=1e-2)


def test_grad_clipping():
    params = {"w": jnp.zeros(4)}
    state = init(params)
    cfg = AdamWConfig(clip_norm=1.0, warmup_steps=0, weight_decay=0.0)
    _, _, metrics = update(cfg, {"w": jnp.full(4, 1e6)}, state, params)
    assert float(metrics["grad_norm"]) > 1e6  # reported pre-clip


def test_lr_schedule_shape():
    cfg = AdamWConfig(peak_lr=1.0, warmup_steps=10, total_steps=110, min_lr_ratio=0.1)
    lr = lr_schedule(cfg)
    assert float(lr(jnp.int32(0))) == 0.0
    assert float(lr(jnp.int32(10))) == pytest.approx(1.0)
    assert float(lr(jnp.int32(110))) == pytest.approx(0.1, abs=1e-6)
    assert float(lr(jnp.int32(60))) < 1.0


def test_global_norm():
    t = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    assert float(global_norm(t)) == pytest.approx(5.0)


# ---------------- compressed all-reduce (shard_map, 1-device axis) --------


def _run_compressed(mode, g, err, perm=None, inv=None):
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    cfg = CompressionConfig(mode=mode, block=64,
                            use_egress_ordering=perm is not None)

    @jax.jit
    def f(g, err):
        from repro.compat import shard_map

        return shard_map(
            lambda g, e: compressed_psum(g, e, cfg, ("data",), perm, inv),
            mesh=mesh,
            in_specs=(jax.sharding.PartitionSpec(), jax.sharding.PartitionSpec()),
            out_specs=(jax.sharding.PartitionSpec(), jax.sharding.PartitionSpec()),
        )(g, err)

    return f(g, err)


def test_int8_ef_error_feedback_converges():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(256,)).astype(np.float32))
    err = jnp.zeros_like(g)
    # EF property: accumulated compressed sum -> accumulated true sum
    acc_comp = jnp.zeros_like(g)
    for _ in range(50):
        out, err = _run_compressed("int8_ef", g, err)
        acc_comp = acc_comp + out
    rel = float(jnp.linalg.norm(acc_comp - 50 * g) / jnp.linalg.norm(50 * g))
    assert rel < 0.01, rel


def test_int8_ef_single_step_bounded_error():
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.normal(size=(128,)).astype(np.float32))
    out, err = _run_compressed("int8_ef", g, jnp.zeros_like(g))
    np.testing.assert_allclose(np.asarray(out + err), np.asarray(g), rtol=1e-5, atol=1e-6)


def test_bf16_mode():
    g = jnp.asarray(np.random.default_rng(2).normal(size=(64,)).astype(np.float32))
    out, _ = _run_compressed("bf16", g, jnp.zeros_like(g))
    np.testing.assert_allclose(np.asarray(out), np.asarray(g), rtol=1e-2)


def test_ordered_egress_is_transparent():
    from repro.traffic import egress_permutation, int8_view

    rng = np.random.default_rng(3)
    w = int8_view(jnp.asarray(rng.normal(size=(256,))))
    perm, inv = egress_permutation(w, packet=64)
    g = jnp.asarray(rng.normal(size=(256,)).astype(np.float32))
    base, _ = _run_compressed("int8_ef", g, jnp.zeros_like(g))
    ordered, _ = _run_compressed("int8_ef", g, jnp.zeros_like(g),
                                 jnp.asarray(perm), jnp.asarray(inv))
    np.testing.assert_allclose(np.asarray(base), np.asarray(ordered), rtol=1e-6)


# ---------------- checkpointing ----------------


def test_checkpoint_roundtrip(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": np.arange(10, dtype=np.float32), "b": {"c": np.ones((2, 3))}}
    m.save(1, tree, extra={"data_step": 1})
    m.save(2, tree, extra={"data_step": 2})
    got, extra, step = m.restore(tree)
    assert step == 2 and extra["data_step"] == 2
    np.testing.assert_array_equal(got["a"], tree["a"])


def test_checkpoint_gc_keeps_n(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=2)
    for s in range(5):
        m.save(s, {"x": np.zeros(1)})
    assert m.all_steps() == [3, 4]


def test_checkpoint_corruption_fallback(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=5)
    tree = {"x": np.arange(4, dtype=np.float32)}
    m.save(1, tree)
    m.save(2, {"x": np.arange(4, dtype=np.float32) * 2})
    # corrupt the newest
    with open(os.path.join(str(tmp_path), "step_0000000002", "arrays.npz"), "r+b") as f:
        f.seek(200)
        f.write(b"\xde\xad\xbe\xef")
    got, _, step = m.restore(tree)
    assert step == 1
    np.testing.assert_array_equal(got["x"], tree["x"])


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    m = CheckpointManager(str(tmp_path))
    m.save(1, {"x": np.zeros((2, 2))})
    with pytest.raises(FileNotFoundError):
        m.restore({"x": np.zeros((3, 3))})


def test_restart_equivalence_bitwise(tmp_path):
    """Full fault-tolerance test: preempt mid-run, resume, final params must
    be BITWISE identical to the uninterrupted run."""
    from repro.configs import smoke_config
    from repro.train import SimulatedPreemption, TrainLoopConfig, train

    cfg = smoke_config("internlm2-1.8b")
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=4, seed=3)
    ocfg = AdamWConfig(peak_lr=1e-3, warmup_steps=2, total_steps=20)
    r1 = train(cfg, dcfg, ocfg, TrainLoopConfig(
        steps=8, checkpoint_every=3, checkpoint_dir=str(tmp_path / "a"), log_every=100))
    with pytest.raises(SimulatedPreemption):
        train(cfg, dcfg, ocfg, TrainLoopConfig(
            steps=8, checkpoint_every=3, checkpoint_dir=str(tmp_path / "b"),
            log_every=100, fail_at_step=5))
    r2 = train(cfg, dcfg, ocfg, TrainLoopConfig(
        steps=8, checkpoint_every=3, checkpoint_dir=str(tmp_path / "b"), log_every=100))
    for a, b in zip(jax.tree.leaves(r1["params"]), jax.tree.leaves(r2["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_elastic_restore_resharded(tmp_path):
    """Save -> restore with device_put onto a (degenerate) new sharding."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.checkpoint import restore_resharded

    m = CheckpointManager(str(tmp_path))
    tree = {"w": np.arange(16, dtype=np.float32).reshape(4, 4)}
    m.save(1, tree)
    got, _, _ = m.restore(tree)
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    sh = {"w": NamedSharding(mesh, P())}
    placed = restore_resharded(got, sh)
    np.testing.assert_array_equal(np.asarray(placed["w"]), tree["w"])
