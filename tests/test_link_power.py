"""Direct unit tests for the link power model (paper Fig. 6/7) and its NoC
extension — previously only exercised indirectly through benchmark paths.

The load-bearing number: the paper's ACC calibration point, 20.42 % BT
reduction -> 18.27 % link-related power reduction, which pins the default
``transfer_factor``."""

import dataclasses

import pytest

from repro.link import LinkPowerModel
from repro.noc import NocPowerModel


def test_default_transfer_factor_is_paper_calibrated():
    m = LinkPowerModel()
    assert m.transfer_factor == pytest.approx(18.27 / 20.42)
    # the calibration point itself: ACC's BT reduction maps to its measured
    # link-related power reduction
    assert m.power_reduction(0.2042) == pytest.approx(0.1827, abs=1e-6)


def test_power_reduction_is_linear_in_bt_reduction():
    m = LinkPowerModel()
    assert m.power_reduction(0.0) == 0.0
    assert m.power_reduction(1.0) == pytest.approx(m.transfer_factor)
    # APP's paper point rides the same line: 19.50 % BT -> ~17.45 % power
    assert m.power_reduction(0.1950) == pytest.approx(0.1745, abs=5e-4)
    custom = LinkPowerModel(transfer_factor=0.5)
    assert custom.power_reduction(0.4) == pytest.approx(0.2)


def test_link_energy_decomposes_into_switching_and_floor():
    m = LinkPowerModel()
    # zero transitions: only the clock/control floor remains
    assert m.link_energy_pj(0, 10) == pytest.approx(
        10 * m.static_flit_energy_pj
    )
    # zero flits (and zero BT): no energy
    assert m.link_energy_pj(0, 0) == 0.0
    got = m.link_energy_pj(1000, 64)
    assert got == pytest.approx(
        1000 * m.energy_per_transition_pj + 64 * m.static_flit_energy_pj
    )
    # energy is monotone in BT at fixed flit count
    assert m.link_energy_pj(2000, 64) > got


def test_link_energy_custom_constants():
    m = LinkPowerModel(energy_per_transition_pj=1.0, static_flit_energy_pj=0.0)
    assert m.link_energy_pj(123, 456) == pytest.approx(123.0)


def test_noc_model_extends_link_model():
    noc = NocPowerModel()
    link = LinkPowerModel()
    # inherited per-link constants and behavior are unchanged
    for f in dataclasses.fields(LinkPowerModel):
        assert getattr(noc, f.name) == getattr(link, f.name)
    assert noc.link_energy_pj(500, 32) == pytest.approx(
        link.link_energy_pj(500, 32)
    )
    # a hop adds exactly the router flit overhead on top of the wire energy
    assert noc.hop_energy_pj(500, 32) == pytest.approx(
        link.link_energy_pj(500, 32) + 32 * noc.router_flit_energy_pj
    )
    # zero traffic on a hop costs nothing
    assert noc.hop_energy_pj(0, 0) == 0.0
