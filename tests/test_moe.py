"""MoE layer invariants: routing, capacity, padding, dropless decode."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.models.moe import capacity, init_moe, moe_block

KEY = jax.random.key(3)


def _cfg(**kw):
    return smoke_config("granite-moe-3b-a800m", dtype="float32", **kw)


def test_padded_experts_receive_no_tokens():
    cfg = _cfg()
    assert cfg.moe.padded_experts > cfg.moe.num_experts
    p = init_moe(KEY, cfg)
    x = jax.random.normal(KEY, (2, 16, cfg.d_model))
    # route-only check: padded expert logits must be -inf-masked
    logits = (x.reshape(1, 32, -1) @ p["router"]).astype(jnp.float32)
    pad = jnp.arange(cfg.moe.padded_experts) >= cfg.moe.num_experts
    masked = jnp.where(pad[None, None], -1e30, logits)
    probs = jax.nn.softmax(masked, axis=-1)
    assert float(probs[..., cfg.moe.num_experts:].max()) < 1e-12


def test_dropless_capacity_no_drops():
    cfg = _cfg()
    p = init_moe(KEY, cfg)
    x = jax.random.normal(KEY, (4, 8, cfg.d_model))
    y1, _ = moe_block(p, x, cfg, dropless=True)
    # with dropless, scaling cf arbitrarily cannot change the output
    cfg2 = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=99.0)
    )
    y2, _ = moe_block(p, x, cfg2, dropless=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-6)


def test_capacity_drops_are_real():
    """With tiny capacity some tokens must be dropped -> outputs differ from
    the dropless result (documents the capacity/quality trade-off)."""
    cfg = _cfg()
    cfg_small = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.25)
    )
    p = init_moe(KEY, cfg)
    x = jax.random.normal(KEY, (4, 16, cfg.d_model))
    y_drop, _ = moe_block(p, x, cfg_small)
    y_full, _ = moe_block(p, x, cfg, dropless=True)
    assert float(jnp.max(jnp.abs(y_drop - y_full))) > 1e-6


def test_aux_loss_positive_and_balanced_bound():
    cfg = _cfg()
    p = init_moe(KEY, cfg)
    x = jax.random.normal(KEY, (8, 32, cfg.d_model))
    _, aux = moe_block(p, x, cfg)
    assert float(aux) > 0
    # Switch bound: aux_weight * E * sum(me*ce) >= aux_weight (at balance ~ 1)
    assert float(aux) < cfg.moe.router_aux_weight * cfg.moe.num_experts


def test_capacity_formula():
    cfg = _cfg()
    c = capacity(cfg, 128)
    m = cfg.moe
    assert c == int(np.ceil(128 * m.top_k * m.capacity_factor / m.num_experts))


def test_moe_output_is_combination_of_expert_outputs():
    """Single token, top-k=all -> output equals weighted expert sum."""
    cfg = _cfg()
    m = dataclasses.replace(cfg.moe, num_experts=4, top_k=4, pad_experts_to=4,
                            capacity_factor=4.0, group_size=4)
    cfg = dataclasses.replace(cfg, moe=m)
    p = init_moe(KEY, cfg)
    x = jax.random.normal(KEY, (1, 1, cfg.d_model))
    y, _ = moe_block(p, x, cfg, dropless=True)
    logits = (x[0] @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)[0]
    want = jnp.zeros((cfg.d_model,))
    for e in range(4):
        h = jax.nn.silu(x[0, 0] @ p["gate"][e]) * (x[0, 0] @ p["up"][e])
        want = want + probs[e] * (h @ p["down"][e])
    np.testing.assert_allclose(np.asarray(y[0, 0]), np.asarray(want), rtol=2e-4, atol=1e-5)
