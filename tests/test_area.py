"""Analytical area model: the paper's Fig. 5 anchors must hold exactly."""

import pytest

from repro.core import AREA_ANCHORS, bitonic_area, csn_area, psu_area


def test_paper_anchors_exact():
    assert psu_area(25, k=4).total == pytest.approx(AREA_ANCHORS[("app", 25)], rel=5e-3)
    assert psu_area(49, k=4).total == pytest.approx(AREA_ANCHORS[("app", 49)], rel=5e-3)
    assert psu_area(25).total == pytest.approx(AREA_ANCHORS[("acc", 25)], rel=5e-3)


def test_headline_claims():
    acc, app = psu_area(25), psu_area(25, k=4)
    # 35.4 % overall reduction (paper abstract)
    assert 1 - app.total / acc.total == pytest.approx(0.354, abs=0.005)
    # 24.9 % popcount-unit and 36.7 % sorting-unit reductions (paper §IV-B.3)
    assert 1 - app.popcount / acc.popcount == pytest.approx(0.249, abs=0.005)
    assert 1 - app.sort / acc.sort == pytest.approx(0.367, abs=0.005)


def test_fig5_ordering():
    """APP < ACC < Bitonic < CSN for both kernel sizes (Fig. 5)."""
    for n in (25, 49):
        app, acc = psu_area(n, k=4).total, psu_area(n).total
        bit, csn = bitonic_area(n).total, csn_area(n).total
        assert app < acc < bit < csn


def test_monotone_in_k_and_n():
    areas = [psu_area(25, k=k).total for k in (2, 4, 8)]
    assert areas == sorted(areas)
    assert psu_area(49, k=4).total > psu_area(25, k=4).total


def test_csn_is_80pct_more_logic():
    assert csn_area(25).sort == pytest.approx(bitonic_area(25).sort * 1.8)
