"""Analytical area + timing models: the paper's Fig. 5 anchors must hold
exactly, and the 500 MHz pipelined timing model must match its documented
stage structure."""

import math

import pytest

from repro.core import (
    AREA_ANCHORS,
    PSUTiming,
    bitonic_area,
    bitonic_timing,
    csn_area,
    psu_area,
    psu_timing,
)


def test_paper_anchors_exact():
    assert psu_area(25, k=4).total == pytest.approx(AREA_ANCHORS[("app", 25)], rel=5e-3)
    assert psu_area(49, k=4).total == pytest.approx(AREA_ANCHORS[("app", 49)], rel=5e-3)
    assert psu_area(25).total == pytest.approx(AREA_ANCHORS[("acc", 25)], rel=5e-3)


def test_all_area_anchors_within_tolerance():
    """psu_area must reproduce every AREA_ANCHORS entry (the calibration
    contract of DESIGN.md §6), not just the headline points."""
    for (kind, n), um2 in AREA_ANCHORS.items():
        k = 4 if kind == "app" else None
        assert psu_area(n, k=k).total == pytest.approx(um2, rel=5e-3), (kind, n)


def test_headline_claims():
    acc, app = psu_area(25), psu_area(25, k=4)
    # 35.4 % overall reduction (paper abstract)
    assert 1 - app.total / acc.total == pytest.approx(0.354, abs=0.005)
    # 24.9 % popcount-unit and 36.7 % sorting-unit reductions (paper §IV-B.3)
    assert 1 - app.popcount / acc.popcount == pytest.approx(0.249, abs=0.005)
    assert 1 - app.sort / acc.sort == pytest.approx(0.367, abs=0.005)


def test_fig5_ordering():
    """APP < ACC < Bitonic < CSN for both kernel sizes (Fig. 5)."""
    for n in (25, 49):
        app, acc = psu_area(n, k=4).total, psu_area(n).total
        bit, csn = bitonic_area(n).total, csn_area(n).total
        assert app < acc < bit < csn


def test_monotone_in_k_and_n():
    areas = [psu_area(25, k=k).total for k in (2, 4, 8)]
    assert areas == sorted(areas)
    assert psu_area(49, k=4).total > psu_area(25, k=4).total


def test_csn_is_80pct_more_logic():
    assert csn_area(25).sort == pytest.approx(bitonic_area(25).sort * 1.8)


# ------------------------------------------------------------- timing model


def test_psu_timing_stage_structure():
    """PSU latency = popcount(1) + encode(1) + prefix(ceil(log2 K)) +
    scatter(1) cycles, O(1) in N, streaming 1 element/cycle."""
    for n in (25, 49):
        acc = psu_timing(n)
        assert acc.latency_cycles == 3 + math.ceil(math.log2(9))  # K = W+1 = 9
        assert acc.throughput_elems_per_cycle == 1.0
    # O(1) in N: the window size never enters the latency
    assert psu_timing(25).latency_cycles == psu_timing(49).latency_cycles
    # APP's narrower bucket index shortens the prefix stage
    assert psu_timing(25, k=4).latency_cycles == 3 + 2
    assert psu_timing(25, k=2).latency_cycles == 3 + 1
    assert psu_timing(25, k=4).latency_cycles < psu_timing(25).latency_cycles
    # width drives the exact unit's bucket count
    assert psu_timing(25, width=4).latency_cycles == 3 + math.ceil(math.log2(5))


def test_bitonic_timing_stage_count():
    """Batcher network: log2(n_pad)*(log2(n_pad)+1)/2 pipelined stages."""
    assert bitonic_timing(25).latency_cycles == 5 * 6 // 2  # pad 25 -> 32
    assert bitonic_timing(49).latency_cycles == 6 * 7 // 2  # pad 49 -> 64
    assert bitonic_timing(25).throughput_elems_per_cycle == 25.0
    # the paper's scaling argument: bitonic latency grows with N, PSU's not
    assert bitonic_timing(49).latency_cycles > bitonic_timing(25).latency_cycles


def test_sort_time_ns_at_500mhz():
    """sort_time = (latency + n/throughput) cycles at 2 ns/cycle."""
    t = PSUTiming(latency_cycles=5, throughput_elems_per_cycle=1.0)
    assert t.clock_mhz == 500.0
    assert t.latency_ns == pytest.approx(10.0)  # 5 cycles @ 500 MHz
    assert t.sort_time_ns(25) == pytest.approx((5 + 25) * 2.0)
    # streamed PSU vs fully-parallel bitonic at the paper's sizes
    acc, bit = psu_timing(25), bitonic_timing(25)
    assert acc.sort_time_ns(25) == pytest.approx((7 + 25) * 2.0)
    assert bit.sort_time_ns(25) == pytest.approx((15 + 1) * 2.0)
