"""The batched fabric pipeline (DESIGN.md §17): plan compilation, the
device-side expansion's bit-exactness against the legacy per-flow loop,
the one-launch fleet pin, and the contention-latency model."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.kernels import bt_count_links, pallas_launch_count
from repro.link import LinkSpec
from repro.noc import (
    FabricLatency,
    FlowBatch,
    NocLatencyModel,
    TrafficFlow,
    compile_fabric,
    expand_fabric,
    fabric_latency,
    fabric_to_link_streams,
    fleet_decode_flows,
    hop_count,
    mesh,
    ring,
    route_latency_cycles,
    route_latency_ns,
    simulate_noc,
    torus,
)
from repro.noc.simulate import (
    _expand_link_streams_reference,
    expand_link_streams,
)


def _pk(p, seed=0, elems=32):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, 256, (p, elems), dtype=np.uint8))


def _flows(topo, n=4, seed=0):
    """Multi-tenant-ish endpoints: unicasts + multicasts, shared prefixes."""
    far = topo.num_routers - 1
    mid = topo.num_routers // 2
    specs = [
        (0, (far,)),
        (0, (mid, far)),  # shares the flow-0 prefix -> queue merge
        (1, (far,)),
        (mid, (0, 1, far)),
    ][:n]
    return [
        TrafficFlow(
            f"f{i}", src, dsts,
            _pk(3 + 2 * i, seed + 2 * i), _pk(3 + 2 * i, seed + 2 * i + 1),
        )
        for i, (src, dsts) in enumerate(specs)
    ]


# ------------------------------------------------------------ FabricPlan


def test_fabric_plan_tables_match_legacy_queue_semantics():
    topo = mesh(4, 4)
    flows = _flows(topo)
    plan = compile_fabric(topo, [(f.src, f.dsts) for f in flows])
    assert plan.num_flows == len(flows)
    assert plan.link_ids == tuple(sorted(plan.link_ids))  # ascending scan
    assert len(plan.link_queue) == plan.active_links
    # every queue holds flow indices in injection order, and every link's
    # queue is the set of flows whose multicast tree crosses it
    for lid, qi in zip(plan.link_ids, plan.link_queue):
        q = plan.queues[qi]
        assert list(q) == sorted(q)  # injection order == flow index order
        assert q == tuple(
            fi for fi, links in enumerate(plan.flow_links) if lid in links
        )
        assert plan.queue_of(lid) == q
    # distinct compositions are deduplicated: flows 0 and 1 share a path
    # prefix, so at least one queue serves several physical links
    assert plan.num_queues < plan.active_links
    counts = [plan.link_queue.count(qi) for qi in range(plan.num_queues)]
    assert max(counts) >= 2
    # endpoints survive normalization (the latency model walks them)
    assert plan.endpoints == tuple(
        (f.src, tuple(f.dsts)) for f in flows
    )


def test_fabric_plan_rejects_batch_size_mismatch():
    topo = ring(6)
    flows = _flows(topo, n=2)
    plan = compile_fabric(topo, [(f.src, f.dsts) for f in flows])
    batch = FlowBatch.from_flows(flows[:1], LinkSpec())
    with pytest.raises(ValueError, match="1 flows"):
        expand_fabric(plan, batch, LinkSpec())


# ------------------------------------- bit-exactness vs the legacy loop


@pytest.mark.parametrize("topo", [mesh(4, 4), torus(3, 4), ring(8)],
                         ids=["mesh4x4", "torus3x4", "ring8"])
@pytest.mark.parametrize("key,sort_at,codec", [
    ("none", "source", "none"),
    ("acc", "source", "none"),
    ("acc", "hop", "none"),
    ("app", "hop", "none"),
    ("acc", "source", "bus_invert"),
    ("none", "hop", "bus_invert"),
])
def test_batched_expansion_bit_exact_vs_reference(topo, key, sort_at, codec):
    spec = LinkSpec(key=key, codec=codec)
    flows = _flows(topo, seed=17)
    got = expand_link_streams(topo, flows, spec, sort_at=sort_at)
    ref = _expand_link_streams_reference(topo, flows, spec, sort_at=sort_at)
    assert got.link_ids == ref.link_ids
    assert got.lengths == ref.lengths
    assert got.aux_bt == ref.aux_bt
    # full padded tensors: edge-padding must reproduce too, the BT kernel
    # reads the pad region even though lengths mask it out of the totals
    np.testing.assert_array_equal(
        np.asarray(got.streams), np.asarray(ref.streams)
    )
    for gi, ri in zip(got.inverts, ref.inverts):
        assert (gi is None) == (ri is None)
        if gi is not None:
            np.testing.assert_array_equal(np.asarray(gi), np.asarray(ri))


def test_expansion_handles_empty_flow_set():
    topo = mesh(3, 3)
    got = expand_link_streams(topo, [], LinkSpec())
    assert got.link_ids == () and got.streams.shape[0] == 0


# ------------------------------------------------- the fleet-scale pins


def test_fleet_fabric_one_launch_per_key_width():
    """The acceptance fleet: 16x16 mesh, >= 1024 multi-tenant decode
    flows, whole-fabric measurement traces to ONE pallas launch."""
    topo = mesh(16, 16)
    spec = LinkSpec(input_lanes=16, weight_lanes=0)
    data = _pk(1, seed=3, elems=4096).reshape(-1)
    flows = fleet_decode_flows(
        data, topo, users=16, layers=16, shards=4, spec=spec
    )
    assert len(flows) == 1024
    plan = compile_fabric(topo, [(f.src, f.dsts) for f in flows])
    batch = FlowBatch.from_flows(flows, spec)
    fs = expand_fabric(plan, batch, spec, sort_at="source")
    assert fs.num_queues == plan.num_queues < plan.active_links
    assert pallas_launch_count(
        lambda s: bt_count_links(
            s, input_lanes=spec.input_lanes, lengths=fs.lengths
        ),
        fs.streams,
    ) == 1
    # queue -> link fan-out keeps the legacy per-link report shape
    ls = fabric_to_link_streams(fs)
    assert ls.link_ids == plan.link_ids
    assert len(ls.lengths) == plan.active_links


def test_fleet_decode_flows_shapes_and_validation():
    topo = mesh(4, 5)
    spec = LinkSpec(input_lanes=16, weight_lanes=0)
    data = _pk(2, seed=9, elems=256).reshape(-1)
    flows = fleet_decode_flows(
        data, topo, users=3, layers=2, shards=2, spec=spec
    )
    assert len(flows) == 3 * 2 * 2
    assert flows[0].name == "u0/l0/s0"
    for f in flows:
        assert f.weights is None
        assert f.inputs.shape == (2, spec.flits_per_packet * 16)
        # memory-column source, PE-column destinations
        assert topo.coords(f.src)[1] == 0
        assert all(topo.coords(d)[1] >= 1 for d in f.dsts)
    with pytest.raises(ValueError, match="weight"):
        fleet_decode_flows(data, topo, users=1, layers=1, shards=1,
                           spec=LinkSpec())  # weight-lane spec
    with pytest.raises(ValueError, match="shards"):
        fleet_decode_flows(data, topo, users=1, layers=1, shards=9,
                           spec=spec)  # > PE columns


# ------------------------------------------------- the contention model


def test_route_latency_pins():
    m = NocLatencyModel()  # 500 MHz, 3-cycle router, 1-cycle link
    assert m.cycle_ns == pytest.approx(2.0)
    assert route_latency_cycles(0, 10, m) == 0
    assert route_latency_cycles(3, 0, m) == 0
    # head: 3 hops x (3+1), body: 7 flits pipeline behind
    assert route_latency_cycles(3, 8, m) == 12 + 7
    assert route_latency_ns(3, 8, m) == pytest.approx(38.0)
    with pytest.raises(ValueError):
        NocLatencyModel(clock_ghz=0.0)
    with pytest.raises(ValueError):
        NocLatencyModel(link_cycles=0)


def test_fabric_latency_injection_order_contention():
    # two flows merging on the same 1x4-mesh row: f0 injects first, f1
    # waits f0's full serialization at every shared link
    topo = mesh(1, 4)
    plan = compile_fabric(topo, [(0, (3,)), (1, (3,))])
    lat = fabric_latency(plan, [4, 4], NocLatencyModel())
    assert isinstance(lat, FabricLatency)
    by_link = {l.link: l for l in lat.links}
    l01 = by_link[topo.link_id(0, 1)]
    l12 = by_link[topo.link_id(1, 2)]
    assert l01.flows == 1 and l01.wait_cycles == 0
    # merged link: f1 queues behind f0's 4 flits (link_cycles=1)
    assert l12.flows == 2 and l12.wait_cycles == 4
    f0, f1 = lat.flows
    assert f0.hops == 3 and f0.wait_cycles == 0
    assert f0.cycles == route_latency_cycles(3, 4)
    # f1: 2 hops + 4-cycle wait at each of its 2 shared links
    assert f1.hops == 2 and f1.wait_cycles == 8
    assert f1.cycles == route_latency_cycles(2, 4) + 8
    assert lat.max_latency_ns == pytest.approx(2.0 * f1.cycles)
    assert lat.contended_links == 2
    with pytest.raises(ValueError, match="flit counts"):
        fabric_latency(plan, [4])


def test_contend_probe_and_simulate_latency_report():
    topo = mesh(1, 4)
    flows = [
        TrafficFlow("a", 0, (3,), _pk(2, 1), _pk(2, 2)),
        TrafficFlow("b", 1, (3,), _pk(2, 3), _pk(2, 4)),
    ]
    with obs.collect() as reg:
        rep = simulate_noc(
            topo, flows, LinkSpec(), latency=NocLatencyModel()
        )
    assert rep.latency is not None
    assert rep.latency.contended_links == 2
    # one noc.contend event per contended link, labeled by route
    lab = {"link": topo.link_id(1, 2), "src": 1, "dst": 2}
    assert reg.value("noc.contend.flows", **lab) == 2
    assert reg.value("noc.contend.wait_cycles", **lab) == 8  # 2pk x 4 flits
    # without latency= the report stays latency-free (and fires nothing)
    rep2 = simulate_noc(topo, flows, LinkSpec())
    assert rep2.latency is None
