"""repro.codec + the single-launch multi-codec BT kernel.

Load-bearing claims:

  * every registered codec is a true encode/decode pair —
    ``decode(encode(x)) == x`` on arbitrary flit streams;
  * ``bt_count_codecs`` is bit-exact per (codec, ordering) config against
    the sequential ``kernels/ref.py`` composition (order -> gather -> pack
    -> codec-encode -> BT) across every codec x ordering (none / acc /
    app k in {2,4,8}) x width 4/8 x non-block-multiple P, in ONE launch;
  * ``codec.compare`` reports ordering-alone, coding-alone and composed
    reductions net of invert-line overhead on the conv workload.
"""

import pathlib
import sys

import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
from benchmarks.kernel_bench import count_pallas_launches  # noqa: E402

from repro.codec import (  # noqa: E402
    CODECS,
    codec_by_name,
    codec_overhead,
    compare_streams,
    demo_workloads,
    format_table,
    invert_line_transitions,
    kernel_config,
    make_bus_invert,
)
from repro.core.area import PSUArea, codec_area  # noqa: E402
from repro.core.coding import (  # noqa: E402
    gray_decode_bytes,
    gray_encode_bytes,
    sign_magnitude_decode_bytes,
    sign_magnitude_encode_bytes,
)
from repro.core.popcount import popcount  # noqa: E402
from repro.kernels import CodecVariant, bt_count_codecs  # noqa: E402
from repro.kernels.ref import bt_codecs_ref  # noqa: E402
from repro.link import LinkPowerModel, LinkSpec, TxPipeline  # noqa: E402


# ------------------------------------------------------------- the schemes


@pytest.mark.parametrize("name", sorted(CODECS))
@pytest.mark.parametrize("shape", [(1, 16), (2, 16), (37, 16), (64, 8)])
def test_decode_encode_identity(name, shape):
    """The subsystem contract: decode∘encode == identity, every codec."""
    rng = np.random.default_rng(hash((name, shape)) % 2**31)
    s = jnp.asarray(rng.integers(0, 256, shape, dtype=np.uint8))
    codec = CODECS[name]
    coded = codec.encode(s)
    assert (np.asarray(codec.decode(coded)) == np.asarray(s)).all()


def test_byte_maps_bijective_over_all_bytes():
    b = jnp.arange(256, dtype=jnp.uint8)
    for enc, dec in (
        (gray_encode_bytes, gray_decode_bytes),
        (sign_magnitude_encode_bytes, sign_magnitude_decode_bytes),
    ):
        e = np.asarray(enc(b))
        assert len(set(e.tolist())) == 256  # bijection
        assert (np.asarray(dec(jnp.asarray(e))) == np.arange(256)).all()


def test_bus_invert_matches_naive_sequential_and_bounds_hd():
    """The lax.scan encoder equals the textbook per-flit decision, and the
    coded wire never moves more than half the partition bits."""
    rng = np.random.default_rng(7)
    s = rng.integers(0, 256, (50, 8), dtype=np.uint8)
    for partition in (None, 4, 2):
        codec = make_bus_invert(partition)
        wire, inv = codec.encode(jnp.asarray(s))
        wire, inv = np.asarray(wire), np.asarray(inv)
        pw = 8 if partition is None else partition
        npart = 8 // pw
        # naive python re-implementation, partition by partition
        prev = s[0].reshape(npart, pw).astype(np.uint8)
        exp_wire = [s[0]]
        exp_inv = [np.zeros(npart, int)]
        for t in range(1, 50):
            d = s[t].reshape(npart, pw)
            row_w, row_v = [], []
            for part in range(npart):
                hd = int(
                    np.asarray(popcount(jnp.asarray(d[part] ^ prev[part]), 8)).sum()
                )
                v = int(2 * hd > 8 * pw)
                row_w.append(d[part] ^ (0xFF * v))
                row_v.append(v)
            prev = np.stack(row_w).astype(np.uint8)
            exp_wire.append(prev.reshape(-1))
            exp_inv.append(np.array(row_v))
        assert (wire == np.stack(exp_wire)).all()
        assert (inv == np.stack(exp_inv)).all()
        # the bus-invert guarantee, per partition
        wi = wire.reshape(50, npart, pw)
        hd = np.asarray(popcount(jnp.asarray(wi[1:] ^ wi[:-1]), 8)).sum(-1)
        assert hd.max() <= 8 * pw // 2


def test_transition_bt_equals_data_popcount():
    rng = np.random.default_rng(9)
    s = jnp.asarray(rng.integers(0, 256, (40, 16), dtype=np.uint8))
    wire = CODECS["transition"].encode(s).wire
    flips = popcount(
        jnp.bitwise_xor(wire[1:].astype(jnp.int32), wire[:-1].astype(jnp.int32)), 8
    )
    assert int(flips.sum()) == int(popcount(s[1:].astype(jnp.int32), 8).sum())


def test_codec_registry_unknown_name_lists_registered():
    with pytest.raises(ValueError, match="registered codecs"):
        codec_by_name("hamming")


# ------------------------------------------------- the single-launch kernel


def _grid_configs(width):
    orderings = [("none", None, False), ("acc", None, False),
                 ("acc", None, True)]
    orderings += [("app", k, False) for k in (2, 4, 8) if k <= width + 1]
    codecs = [("none", None), ("gray", None), ("sign_magnitude", None),
              ("transition", None), ("bus_invert", None), ("bus_invert", 4)]
    return tuple(
        CodecVariant(key, k, desc, scheme, part)
        for key, k, desc in orderings
        for scheme, part in codecs
    )


@pytest.mark.parametrize("width", [4, 8])
@pytest.mark.parametrize("p", [65, 7])  # non-block-multiple packet counts
def test_codec_kernel_matches_reference(width, p):
    """Acceptance: ONE launch covers every codec x ordering (none / acc /
    app k in {2,4,8}) x width 4/8 x non-block-multiple P, each config
    bit-exact (data sides AND invert lines) vs the ref.py composition."""
    rng = np.random.default_rng(hash((width, p)) % 2**31)
    hi = 2**width if width < 8 else 256
    x = jnp.asarray(rng.integers(0, hi, (p, 32), dtype=np.uint8))
    w = jnp.asarray(rng.integers(0, 256, (p, 32), dtype=np.uint8))
    configs = _grid_configs(width)
    got = np.asarray(
        bt_count_codecs(
            x, w, configs=configs, width=width, input_lanes=8,
            block_packets=16,
        )
    )
    ref = np.asarray(
        bt_codecs_ref(
            x, w, configs, width=width, input_lanes=8, weight_lanes=8
        )
    )
    np.testing.assert_array_equal(got, ref)


def test_codec_kernel_input_only_row_pack_and_single_launch():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.integers(0, 256, (33, 48), dtype=np.uint8))
    configs = (
        CodecVariant("none"),
        CodecVariant("acc", codec="transition"),
        CodecVariant("app", 4, codec="bus_invert", partition=4),
    )
    for pack in ("lane", "row"):
        got = np.asarray(
            bt_count_codecs(
                x, None, configs=configs, input_lanes=16, pack=pack,
                block_packets=8,
            )
        )
        ref = np.asarray(
            bt_codecs_ref(
                x, None, configs, input_lanes=16, weight_lanes=0, pack=pack
            )
        )
        np.testing.assert_array_equal(got, ref)
        assert (got[:, 1] == 0).all()  # no weight side
    # the whole grid is ONE pallas launch in the traced jaxpr
    launches = count_pallas_launches(
        lambda s: bt_count_codecs(
            s, None, configs=configs, input_lanes=16, block_packets=8
        ),
        x,
    )
    assert launches == 1


def test_codec_kernel_validation():
    x = jnp.zeros((4, 16), jnp.uint8)
    with pytest.raises(ValueError):  # unknown scheme
        bt_count_codecs(x, configs=(CodecVariant(codec="bogus"),))
    with pytest.raises(ValueError):  # partition without bus_invert
        bt_count_codecs(x, configs=(CodecVariant(codec="gray", partition=4),))
    with pytest.raises(ValueError):  # partition not dividing the flit
        bt_count_codecs(
            x, configs=(CodecVariant(codec="bus_invert", partition=3),),
            input_lanes=8,
        )
    with pytest.raises(ValueError):  # ordering contract still enforced
        bt_count_codecs(x, configs=(CodecVariant("app", None),))


# -------------------------------------------------- link-layer integration


def test_tx_pipeline_coded_path_matches_kernel():
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.integers(0, 256, (20, 32), dtype=np.uint8))
    w = jnp.asarray(rng.integers(0, 256, (20, 32), dtype=np.uint8))
    spec = LinkSpec(key="app", codec="bus_invert4")
    rep = TxPipeline(spec).measure(x, w)
    got = np.asarray(
        bt_count_codecs(
            x, w, configs=(kernel_config(spec),), input_lanes=8
        )
    )[0]
    assert (rep.input_bt, rep.weight_bt, rep.aux_bt) == tuple(got.tolist())
    assert not rep.fused and rep.extra_wires == 4
    assert rep.gross_bt == rep.total_bt + rep.aux_bt
    # reduction is scored net of the invert lines
    base = TxPipeline(LinkSpec(key="none")).measure(x, w)
    assert rep.reduction_vs(base) == pytest.approx(
        1 - rep.gross_bt / base.total_bt
    )


def test_input_only_coded_link_frames_codec_on_actual_stream():
    """An input-only run of a paired spec assembles an input_lanes-wide
    stream; the codec partitions (and the wire/energy accounting) must
    follow that actual width, not bytes_per_flit."""
    rng = np.random.default_rng(19)
    x = jnp.asarray(rng.integers(0, 256, (10, 32), dtype=np.uint8))
    pipe = TxPipeline(LinkSpec(key="none", codec="bus_invert4"))
    res = pipe.run(x)  # default spec: 8 input + 8 weight lanes, no weights
    assert res.stream.shape[-1] == 8  # input half only
    assert res.invert.shape[-1] == 2  # 8 lanes / 4-lane partitions
    rep = pipe.measure(x)
    assert rep.extra_wires == 2
    m = LinkPowerModel()
    assert rep.energy_pj == pytest.approx(
        m.coded_link_energy_pj(rep.total_bt, rep.aux_bt, rep.num_flits, 64, 2)
    )


def test_link_spec_codec_validation_lists_names():
    with pytest.raises(ValueError, match="bus_invert"):
        LinkSpec(codec="bogus")
    with pytest.raises(ValueError):
        TxPipeline(LinkSpec(key="acc", codec="bus_invert"), fused=True).run(
            jnp.zeros((4, 32), jnp.uint8)
        )


def test_stage_registry_errors_list_registered_names():
    """Satellite: unknown stage-name errors enumerate the registry, like
    benchmarks/run.py does for unknown module names."""
    from repro.link import pack_to_flits

    for field, known in (
        ("key", "acc"),
        ("encode", "sign_magnitude"),
        ("pack", "lane"),
    ):
        with pytest.raises(ValueError, match=known):
            LinkSpec(**{field: "bogus"})
    with pytest.raises(ValueError, match="row"):
        pack_to_flits(jnp.zeros((2, 16), jnp.uint8), 8, "bogus")


def test_gray_is_an_encode_stage():
    rng = np.random.default_rng(13)
    x = jnp.asarray(rng.integers(0, 256, (8, 32), dtype=np.uint8))
    pipe = TxPipeline(LinkSpec(key="acc", encode="gray"))
    assert (
        np.asarray(pipe.encode(x)) == np.asarray(gray_encode_bytes(x))
    ).all()
    pipe.measure(x)  # end to end through the fused path


# ------------------------------------------------------ compare + overhead


@pytest.fixture(scope="module")
def conv_rows():
    streams = demo_workloads(images=2)["conv"]
    return compare_streams(
        streams, 16,
        orderings=("none", ("acc", None, False), ("app", 4, False)),
        codecs=("none", "bus_invert4"),
        workload="conv",
    )


def test_compare_reports_ordering_coding_and_composed(conv_rows):
    """Acceptance: bus-invert-alone, ordering-alone and composed BT
    reductions, net of invert-line overhead, on the conv workload."""
    by_label = {r.label: r for r in conv_rows}
    base = by_label["none"]
    coding = by_label["none+bus_invert4"]
    ordering = by_label["acc"]
    composed = by_label["acc+bus_invert4"]
    assert base.bt_reduction == 0.0 and base.aux_bt == 0
    # bus-invert fires on unordered conv traffic and pays for its lines
    assert coding.aux_bt > 0 and coding.extra_wires == 4
    assert coding.bt_reduction == pytest.approx(
        1 - coding.gross_bt / base.gross_bt
    )
    assert 0 < coding.bt_reduction < ordering.bt_reduction
    # composing coding on top of ordering still wins net of overhead
    assert composed.bt_reduction > ordering.bt_reduction
    assert composed.bt_reduction > coding.bt_reduction
    format_table(conv_rows)  # renders


def test_compare_all_pairs_one_launch_per_stream(conv_rows):
    # 3 orderings x 2 codecs = 6 pairs, baseline included in the grid
    assert len(conv_rows) == 6
    assert len({(r.ordering, r.codec) for r in conv_rows}) == 6


def test_overhead_accounting():
    ov = codec_overhead("bus_invert4", 16)
    assert ov.extra_wires == 4 and ov.data_wires == 128
    assert ov.wire_overhead == pytest.approx(4 / 128)
    assert ov.encoder_area_um2 == pytest.approx(codec_area("bus_invert", 16, 4))
    assert codec_overhead("gray", 16).extra_wires == 0
    assert codec_area("none", 16) == 0.0
    # PSUArea folds the encoder into the total
    a = PSUArea(100.0, 200.0, codec=50.0)
    assert a.total == 350.0
    # the energy model charges aux transitions and the widened floor
    m = LinkPowerModel()
    assert m.coded_link_energy_pj(1000, 0, 64, 128, 0) == pytest.approx(
        m.link_energy_pj(1000, 64)
    )
    coded = m.coded_link_energy_pj(1000, 50, 64, 128, 4)
    assert coded == pytest.approx(
        m.energy_per_transition_pj * 1050
        + m.static_flit_energy_pj * (1 + 4 / 128) * 64
    )


# --------------------------------------------------------- noc + dse axes


def test_noc_links_carry_coded_wire_and_aux():
    import dataclasses

    from repro.noc import TrafficFlow, mesh, simulate_noc
    from repro.noc.simulate import expand_link_streams

    rng = np.random.default_rng(17)
    x = jnp.asarray(rng.integers(0, 256, (24, 64), dtype=np.uint8))
    topo = mesh(2, 2)
    spec = LinkSpec(
        width_bits=128, flits_per_packet=4, input_lanes=16, weight_lanes=0,
        key="acc", codec="bus_invert4",
    )
    flows = [TrafficFlow("f", 0, (3,), x)]
    plain = expand_link_streams(
        topo, flows, dataclasses.replace(spec, codec="none")
    )
    coded = expand_link_streams(topo, flows, spec)
    assert coded.link_ids == plain.link_ids
    codec = CODECS["bus_invert4"]
    for i, length in enumerate(plain.lengths):
        ref = codec.encode(plain.streams[i][:length])
        assert (
            np.asarray(coded.streams[i][:length]) == np.asarray(ref.wire)
        ).all()
        assert coded.aux_bt[i] == int(invert_line_transitions(ref.invert))
    rep = simulate_noc(topo, flows, spec)
    assert rep.total_aux_bt == sum(coded.aux_bt)
    assert rep.gross_bt == rep.total_bt + rep.total_aux_bt
    base = simulate_noc(topo, flows, dataclasses.replace(spec, key="none",
                                                         codec="none"))
    assert 0 < rep.reduction_vs(base) < 1


def test_design_point_codec_axis():
    from repro.dse import DesignPoint, expand_grid

    with pytest.raises(ValueError, match="registered codecs"):
        DesignPoint(codec="bogus")
    pt = DesignPoint(ordering="acc", k=None, codec="bus_invert4")
    assert pt.label == "acc+bus_invert4@N25"
    cv = pt.codec_variant
    assert cv.codec == "bus_invert" and cv.partition == 4
    grid = expand_grid(
        ks=(4,), orderings=("none", "acc"), codecs=(None, "bus_invert4")
    )
    assert [p.label for p in grid] == [
        "none@N25", "none+bus_invert4@N25", "acc@N25", "acc+bus_invert4@N25",
    ]


def test_evaluate_grid_codec_points_net_of_overhead():
    from repro.dse import DesignPoint, Workload, evaluate_grid, point_record

    rng = np.random.default_rng(23)
    stream = jnp.asarray(rng.integers(0, 256, (40, 64), dtype=np.uint8))
    workload = Workload("rand", (stream,), lanes=16)
    pts = (
        DesignPoint(ordering="acc", k=None),
        DesignPoint(ordering="acc", k=None, codec="bus_invert4"),
    )
    plain, coded = evaluate_grid(pts, workload)
    assert plain.aux_bt == 0 and plain.extra_wires == 0
    assert plain.area.codec == 0.0
    assert coded.extra_wires == 4
    assert coded.area.codec == pytest.approx(codec_area("bus_invert", 16, 4))
    assert coded.area_um2 == plain.area_um2 + coded.area.codec
    # the coded point's reduction is charged its invert-line transitions
    base = plain.total_bt / (1 - plain.bt_reduction)
    assert coded.bt_reduction == pytest.approx(
        1 - (coded.total_bt + coded.aux_bt) / base
    )
    rec = point_record(coded)
    assert rec["codec"] == "bus_invert4"
    assert rec["aux_bt"] == coded.aux_bt and rec["extra_wires"] == 4
