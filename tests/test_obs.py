"""The repro.obs observability subsystem (DESIGN.md §14).

The load-bearing claim is ZERO cost when disabled: production modules
import only ``repro._obs_hooks`` (a None test per probe, fired outside
any traced computation), so every kernel entry point's traced jaxpr is
byte-identical whether ``repro.obs`` is absent from the process, imported
but inactive, or actively collecting.  The rest pins the probe
vocabulary, the metrics JSON round-trip, the per-link report against
``NocReport``, the Chrome trace schema, and the ``check_bench``
regression gate.
"""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import _obs_hooks, obs
from repro.kernels import CodecVariant, bt_count, bt_count_axes
from repro.link import LinkSpec, TxPipeline
from repro.noc import TrafficFlow, simulate_noc
from repro.noc.topology import mesh

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_CFG = (CodecVariant("none", None, False, "none", None),
        CodecVariant("acc", None, False, "bus_invert", 4))


def _packets(p=8, elems=32, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, 255, (p, elems), dtype=np.uint8))


def _input_spec():
    return LinkSpec(width_bits=64, input_lanes=8, weight_lanes=0)


def _jaxprs():
    """Traced-jaxpr strings of the probed public entry points."""
    x = _packets()
    pipe = TxPipeline(_input_spec(), interpret=True)
    return {
        "bt_count": str(jax.make_jaxpr(
            lambda a: bt_count(a, interpret=True))(x)),
        "bt_count_axes": str(jax.make_jaxpr(
            lambda a: bt_count_axes(
                a[None], None, configs=_CFG, width=8, input_lanes=8,
                interpret=True,
            ))(x)),
        "tx_run": str(jax.make_jaxpr(
            lambda a: pipe.run(a).bt_input)(x)),
    }


# --------------------------------------------- zero cost when disabled


def test_jaxpr_identical_with_obs_absent_vs_imported():
    """In a fresh process: production imports never pull in repro.obs,
    and importing + activating it leaves every traced jaxpr
    byte-identical (the tentpole claim)."""
    script = """
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import CodecVariant, bt_count, bt_count_axes
from repro.link import LinkSpec, TxPipeline

assert "repro.obs" not in sys.modules, "production code imported repro.obs"

x = jnp.asarray(
    np.random.default_rng(0).integers(0, 255, (8, 32), dtype=np.uint8)
)
cfg = (CodecVariant("none", None, False, "none", None),
       CodecVariant("acc", None, False, "bus_invert", 4))
pipe = TxPipeline(
    LinkSpec(width_bits=64, input_lanes=8, weight_lanes=0), interpret=True
)

def jaxprs():
    return {
        "bt_count": str(jax.make_jaxpr(
            lambda a: bt_count(a, interpret=True))(x)),
        "bt_count_axes": str(jax.make_jaxpr(
            lambda a: bt_count_axes(
                a[None], None, configs=cfg, width=8, input_lanes=8,
                interpret=True,
            ))(x)),
        "tx_run": str(jax.make_jaxpr(lambda a: pipe.run(a).bt_input)(x)),
    }

before = jaxprs()
assert "repro.obs" not in sys.modules, "tracing imported repro.obs"
from repro import obs
mid = jaxprs()
with obs.collect(), obs.tracing():
    after = jaxprs()
assert before == mid, "importing repro.obs changed a jaxpr"
assert before == after, "activating repro.obs changed a jaxpr"
print("JAXPR-IDENTITY-OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(_REPO, "src"), _REPO]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else [])
    )
    env.setdefault("JAX_PLATFORMS", "cpu")
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        env=env, cwd=_REPO, timeout=600,
    )
    assert out.returncode == 0, out.stderr
    assert "JAXPR-IDENTITY-OK" in out.stdout


def test_jaxpr_identical_inactive_vs_collecting():
    before = _jaxprs()
    with obs.collect(), obs.tracing():
        during = _jaxprs()
    after = _jaxprs()
    assert before == during == after


def test_hooks_inactive_by_default():
    assert _obs_hooks.SINK is None or obs.active_registries()
    with obs.collect():
        assert _obs_hooks.active()
        assert _obs_hooks.SINK is not None
    assert not _obs_hooks.active()
    # the null span is a no-op context manager
    with _obs_hooks.span("kernel.dispatch", entry="x"):
        pass
    _obs_hooks.event("noc.link", link=0)  # swallowed


# --------------------------------------------------- probe vocabulary


def test_kernel_dispatch_counters():
    x = _packets()
    with obs.collect() as reg:
        bt_count(x, backend="interpret")
        bt_count(x, backend="interpret")
        bt_count(x, backend="compiled")
    assert reg.value(
        "kernel.dispatch.calls", entry="bt_count", backend="interpret") == 2
    assert reg.value(
        "kernel.dispatch.calls", entry="bt_count", backend="compiled") == 1
    # pallas launch accounting: interpret dispatches launch, compiled don't
    assert reg.value(
        "kernel.pallas_launches", entry="bt_count", backend="interpret") == 2
    assert reg.value(
        "kernel.pallas_launches", entry="bt_count", backend="compiled") == 0


def test_link_pipeline_probes_and_report_counters():
    x = _packets()
    pipe = TxPipeline(_input_spec(), interpret=True)
    with obs.collect() as reg:
        rep = pipe.measure(x, name="s0")
    assert reg.value("link.tx.calls", path="fused", key="acc",
                     codec="none") == 1
    assert reg.value("link.bt", side="input", stream="s0") == rep.input_bt
    assert reg.value("link.flits", stream="s0") == rep.num_flits
    # staged path fires the stage spans
    staged = TxPipeline(
        LinkSpec(width_bits=64, input_lanes=8, weight_lanes=0,
                 key="column_major"),
        interpret=True,
    )
    with obs.collect() as reg2:
        staged.measure(x, name="s1")
    assert reg2.value("link.tx.calls", path="staged", key="column_major",
                      codec="none") == 1
    for stage in ("order", "assemble", "bt"):
        assert reg2.value("link.stage.calls", stage=stage) == 1


def test_nested_collect_scopes_both_see_firings():
    x = _packets()
    with obs.collect() as outer:
        bt_count(x, backend="interpret")
        with obs.collect() as inner:
            bt_count(x, backend="interpret")
    assert outer.value("kernel.dispatch.calls", entry="bt_count",
                       backend="interpret") == 2
    assert inner.value("kernel.dispatch.calls", entry="bt_count",
                       backend="interpret") == 1


# ------------------------------------------- NoC per-link report layer


def _noc_run():
    x = _packets(elems=_input_spec().elems_per_packet, seed=3)
    flows = [TrafficFlow("f0", 0, (3,), x), TrafficFlow("f1", 1, (2,), x)]
    with obs.collect() as reg:
        rep = simulate_noc(
            mesh(2, 2), flows, _input_spec(), interpret=True
        )
    return reg, rep


def test_noc_link_counters_match_report():
    reg, rep = _noc_run()
    table = obs.link_table(reg)
    assert len(table) == rep.active_links
    by_id = {s.link: s for s in rep.links}
    for row in table:
        s = by_id[row["link"]]
        assert (row["src"], row["dst"]) == (s.src, s.dst)
        assert row["bt_input"] == s.bt_input
        assert row["bt_weight"] == s.bt_weight
        assert row["aux_bt"] == s.bt_aux
        assert row["gross_bt"] == s.gross_bt
        assert row["num_flits"] == s.num_flits
        assert row["energy_pj"] == pytest.approx(s.energy_pj, abs=0.01)
    assert sum(r["gross_bt"] for r in table) == rep.gross_bt


def test_top_links_ordering_and_format(tmp_path):
    reg, rep = _noc_run()
    top = obs.top_links(reg, 2)
    assert len(top) == min(2, rep.active_links)
    gross = [r["gross_bt"] for r in obs.link_table(reg)]
    assert top[0]["gross_bt"] == max(gross)
    assert [r["gross_bt"] for r in top] == sorted(
        [r["gross_bt"] for r in top], reverse=True
    )
    text = obs.format_links(top)
    assert "gross BT" in text and str(top[0]["gross_bt"]) in text
    # heatmap CSV artifact: header + one row per link
    path = tmp_path / "links.csv"
    rows = obs.write_links_csv(str(path), reg)
    lines = path.read_text().strip().splitlines()
    assert lines[0].split(",") == list(obs.report.LINK_FIELDS)
    assert len(lines) == 1 + len(rows)


def test_metrics_json_round_trip(tmp_path):
    reg, _ = _noc_run()
    path = tmp_path / "metrics.json"
    doc = obs.write_metrics_json(str(path), reg)
    assert doc["links"] == obs.link_table(reg)
    reg2 = obs.read_metrics_json(str(path))
    assert reg2.to_dict() == reg.to_dict()
    assert obs.link_table(reg2) == obs.link_table(reg)


# ------------------------------------------------------- trace schema


def test_tracer_chrome_schema(tmp_path):
    x = _packets()
    tracer = obs.Tracer()
    with obs.tracing(tracer):
        with _obs_hooks.span("bench.module", module="demo"):
            bt_count(x, backend="interpret")
        _obs_hooks.event("noc.link", link=0, shape=(2, 3))
    doc = tracer.to_chrome(metadata={"git_sha": "abc"})
    json.dumps(doc)  # JSON-safe throughout (tuples coerced)
    assert doc["metadata"] == {"git_sha": "abc"}
    spans = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    names = {e["name"] for e in spans}
    assert {"bench.module", "kernel.dispatch"} <= names
    for e in spans:
        assert {"name", "cat", "ph", "ts", "dur", "pid", "tid",
                "args"} <= set(e)
        assert e["dur"] >= 0
    outer = next(e for e in spans if e["name"] == "bench.module")
    inner = next(e for e in spans if e["name"] == "kernel.dispatch")
    # nested purely by timestamp containment
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1.0
    assert tracer.span_seconds("bench.module") >= tracer.span_seconds(
        "kernel.dispatch"
    )
    instants = [e for e in doc["traceEvents"] if e["ph"] == "i"]
    assert instants and instants[0]["args"]["shape"] == [2, 3]
    out = tracer.write(str(tmp_path / "t.json"))
    assert json.load(open(tmp_path / "t.json")) == out


# ------------------------------------------------- check_bench gating


def _write_bench(dirpath, name, wall_s, tiny=True, failed=None):
    payload = {
        "module": name, "tiny": tiny, "wall_s": wall_s,
        "rows": [] if failed else [
            {"name": f"{name}/r0", "us_per_call": 1.0, "derived": "ok"}
        ],
    }
    if failed:
        payload["failed"] = failed
    os.makedirs(dirpath, exist_ok=True)
    with open(os.path.join(dirpath, f"BENCH_{name}.json"), "w") as f:
        json.dump(payload, f)


def test_check_bench_gates(tmp_path):
    from benchmarks.check_bench import check
    from benchmarks.run import MODULES

    run_dir, base_dir = str(tmp_path / "run"), str(tmp_path / "base")
    for name in MODULES:
        _write_bench(run_dir, name, wall_s=1.0)
        _write_bench(base_dir, name, wall_s=1.0)
    problems, warnings = check(run_dir, base_dir)
    assert problems == [] and warnings == []

    # a registered module that wrote no JSON fails by name
    os.remove(os.path.join(run_dir, f"BENCH_{MODULES[0]}.json"))
    problems, _ = check(run_dir, base_dir)
    assert len(problems) == 1 and MODULES[0] in problems[0]
    _write_bench(run_dir, MODULES[0], wall_s=1.0)

    # a module dropped from MODULES but still in the baseline fails by name
    _write_bench(base_dir, "ghost_module", wall_s=1.0)
    problems, _ = check(run_dir, base_dir)
    assert len(problems) == 1
    assert "ghost_module" in problems[0] and "dropped" in problems[0]
    os.remove(os.path.join(base_dir, "BENCH_ghost_module.json"))

    # wall regression: >2x AND >1s fails; >1.25x AND >0.25s warns
    _write_bench(run_dir, MODULES[1], wall_s=4.0)
    problems, _ = check(run_dir, base_dir)
    assert len(problems) == 1 and "regression" in problems[0]
    _write_bench(run_dir, MODULES[1], wall_s=1.6)
    problems, warnings = check(run_dir, base_dir)
    assert problems == []
    assert len(warnings) == 1 and MODULES[1] in warnings[0]

    # sub-second smoke noise never fails on ratio alone
    _write_bench(run_dir, MODULES[1], wall_s=0.3)
    _write_bench(base_dir, MODULES[1], wall_s=0.1)
    problems, warnings = check(run_dir, base_dir)
    assert problems == [] and warnings == []
    _write_bench(run_dir, MODULES[1], wall_s=1.0)
    _write_bench(base_dir, MODULES[1], wall_s=1.0)

    # a failed module is reported once, not also wall-gated
    _write_bench(run_dir, MODULES[2], wall_s=99.0, failed="FAILED: boom")
    problems, _ = check(run_dir, base_dir)
    assert len(problems) == 1 and "boom" in problems[0]
    _write_bench(run_dir, MODULES[2], wall_s=1.0)

    # tiny-flag mismatch skips the wall gate with a warning
    _write_bench(run_dir, MODULES[3], wall_s=99.0, tiny=False)
    problems, warnings = check(run_dir, base_dir)
    assert problems == []
    assert any("tiny" in w for w in warnings)

    # no baseline at all: presence still gates, wall gate skipped
    problems, warnings = check(run_dir, str(tmp_path / "nope"))
    assert problems == []
    assert any("skipped" in w for w in warnings)


# ------------------------------------------- bench --trace end to end


@pytest.mark.slow
def test_bench_trace_artifact(tmp_path):
    """One tiny dse_sweep run under --json --trace: the BENCH json carries
    provenance, the TRACE json is Chrome-loadable with >=95% of the module
    wall covered by spans (the DESIGN.md §14 acceptance bar)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join([os.path.join(_REPO, "src"), _REPO])
    env.setdefault("JAX_PLATFORMS", "cpu")
    env["REPRO_BENCH_TINY"] = "1"
    env["REPRO_DSE_ARTIFACT"] = str(tmp_path / "dse_front.json")
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--json", "--trace",
         "dse_sweep"],
        capture_output=True, text=True, env=env, cwd=str(tmp_path),
        timeout=600,
    )
    assert out.returncode == 0, out.stderr

    bench = json.load(open(tmp_path / "BENCH_dse_sweep.json"))
    for field in ("git_sha", "timestamp", "jax_version"):
        assert bench.get(field), f"missing provenance field {field!r}"
    assert "T" in bench["timestamp"]  # ISO-8601
    assert any("dse/obs/" in r["name"] for r in bench["rows"])

    trace = json.load(open(tmp_path / "TRACE_dse_sweep.json"))
    assert isinstance(trace["traceEvents"], list) and trace["traceEvents"]
    meta = trace["metadata"]
    assert meta["module"] == "dse_sweep"
    assert meta["span_coverage"] >= 0.95
    spans = [e for e in trace["traceEvents"] if e["ph"] == "X"]
    assert {"bench.module", "kernel.dispatch", "dse.measure"} <= {
        e["name"] for e in spans
    }
    outer = next(e for e in spans if e["name"] == "bench.module")
    assert outer["dur"] / 1e6 >= 0.95 * sum(
        e["dur"] for e in spans if e["name"] == "dse.measure"
    ) / 1e6


# ------------------------------- wire-activity report layer (§15)


def _noc_activity_sim(seed=3):
    x = _packets(elems=_input_spec().elems_per_packet, seed=seed)
    flows = [TrafficFlow("f0", 0, (3,), x), TrafficFlow("f1", 1, (2,), x)]
    return simulate_noc(
        mesh(2, 2), flows, _input_spec(), interpret=True,
        activity_windows=4,
    )


def _noc_activity_run(seed=3):
    with obs.collect() as reg:
        rep = _noc_activity_sim(seed=seed)
    return reg, rep


def test_activity_counters_match_noc_report():
    reg, rep = _noc_activity_run()
    table = obs.activity_table(reg)
    assert len(table) == rep.active_links
    profs = obs.profiles_from_noc(rep)
    by_id = {s.link: (s, p) for s, p in zip(rep.links, profs)}
    for row in table:
        s, p = by_id[row["link"]]
        assert (row["src"], row["dst"]) == (s.src, s.dst)
        assert row["toggles"] == s.gross_bt == p.gross_bt
        assert row["windows"] == p.num_windows
        assert row["wire_max"] == int(p.per_wire.max())
        hot_name, hot_tog = p.hottest_wires(1)[0]
        assert (row["hot_wire"], row["hot_wire_toggles"]) == (
            hot_name, hot_tog
        )
    # top_wires descends by toggles and agrees with the table rows
    top = obs.top_wires(reg, 3)
    assert [r["toggles"] for r in top] == sorted(
        [r["toggles"] for r in top], reverse=True
    )
    assert top[0]["toggles"] == max(r["hot_wire_toggles"] for r in table)


def test_report_tables_empty_registry():
    reg = obs.Registry()
    assert obs.link_table(reg) == []
    assert obs.activity_table(reg) == []
    assert obs.top_links(reg) == []
    assert obs.top_wires(reg) == []
    doc = obs.metrics_dict(reg)
    assert doc["links"] == []
    assert "activity" not in doc  # absent, not empty — PR 7 artifacts
    # byte-identical for runs without wire activity


def test_report_csvs_empty_registry(tmp_path):
    reg = obs.Registry()
    links = tmp_path / "links.csv"
    act = tmp_path / "activity.csv"
    assert obs.write_links_csv(str(links), reg) == []
    assert obs.write_activity_csv(str(act), reg) == []
    # header-only CSVs, parseable with the documented field lists
    assert links.read_text().strip().split(",") == list(
        obs.report.LINK_FIELDS
    )
    assert act.read_text().strip().split(",") == list(
        obs.report.ACTIVITY_FIELDS
    )


def test_activity_accumulates_across_runs():
    """A link seen by two simulate_noc runs inside one collect scope
    reports its total activity — same accumulation rule as link_table."""
    reg1, rep = _noc_activity_run()
    single = obs.activity_table(reg1)
    with obs.collect() as reg2:
        _noc_activity_sim()
        _noc_activity_sim()
    double = obs.activity_table(reg2)
    assert len(double) == len(single)
    for a, b in zip(single, double):
        assert (a["link"], a["src"], a["dst"]) == (
            b["link"], b["src"], b["dst"]
        )
        assert b["toggles"] == 2 * a["toggles"]
        assert b["windows"] == 2 * a["windows"]
        assert b["wire_max"] == a["wire_max"]  # histogram max, not a sum
        # the hot-wire counter is keyed by wire name, so the same wire
        # winning both runs accumulates like every other counter
        assert b["hot_wire"] == a["hot_wire"]
        assert b["hot_wire_toggles"] == 2 * a["hot_wire_toggles"]
    doc = obs.metrics_dict(reg2)
    assert doc["activity"] == double


def test_link_table_missing_energy_counter():
    """A registry populated without the energy counter (older artifact,
    partial collection) still renders: energy reads as 0, not a crash."""
    reg = obs.Registry()
    lab = {"link": 7, "src": 0, "dst": 1}
    reg.counter("noc.link.bt", side="input", **lab).inc(30)
    reg.counter("noc.link.bt", side="weight", **lab).inc(12)
    reg.counter("noc.link.flits", **lab).inc(6)
    (row,) = obs.link_table(reg)
    assert row["gross_bt"] == 42 and row["aux_bt"] == 0
    assert row["energy_pj"] == 0
    assert row["bt_per_flit"] == 7.0
    assert obs.top_links(reg) == [row]


def test_probe_kinds_match_design_table():
    """DESIGN.md §14's vocabulary table and obs.PROBE_KINDS must not
    drift — adding a probe point means updating both."""
    import re

    text = open(os.path.join(_REPO, "DESIGN.md")).read()
    documented = {
        m.group(1): m.group(2)
        for m in re.finditer(
            r"^\| `([a-z]+\.[a-z_]+)`\s*\| (span|event)\s*\|", text, re.M
        )
    }
    assert documented == obs.PROBE_KINDS
