"""Backend-dispatch equivalence (DESIGN.md §13).

The compiled jnp backend is the CPU/GPU production path and the Pallas
interpreter is the validation switch; every public kernel entry point must
be bit-exact between the two — across the full ordering x codec grid,
width 4/8, jagged links and non-block-multiple P — and the chunked
streaming / sharded-link paths must reproduce the plain launch exactly
(the bus-invert carry threads across chunk edges).  Also pins the
resolution order: explicit ``backend=`` > legacy ``interpret=`` >
``force_default_backend`` > ``REPRO_KERNEL_BACKEND`` > platform default.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (
    BACKEND_ENV_VAR,
    BACKENDS,
    CodecVariant,
    Variant,
    bt_count,
    bt_count_axes,
    bt_count_axes_sharded,
    bt_count_codecs,
    bt_count_links,
    bt_count_variants,
    default_backend,
    force_default_backend,
    pallas_launch_count,
    psu_sort,
    psu_stream,
    quantize_egress,
    resolve_backend,
)


def _stack_jagged(arrays):
    """(P_l, N) packet queues -> zero-padded (L, P_max, N) + valid tuple."""
    valid = tuple(a.shape[0] for a in arrays)
    pmax = max(valid)
    return (
        jnp.stack(
            [jnp.pad(a, ((0, pmax - a.shape[0]), (0, 0))) for a in arrays]
        ),
        valid,
    )


def _grid_configs(width):
    orderings = [("none", None, False), ("column_major", None, False),
                 ("acc", None, False), ("acc", None, True)]
    orderings += [("app", k, False) for k in (2, 4, 8) if k <= width + 1]
    codecs = [("none", None), ("gray", None), ("transition", None),
              ("bus_invert", None), ("bus_invert", 4)]
    return tuple(
        CodecVariant(key, k, desc, scheme, part)
        for key, k, desc in orderings
        for scheme, part in codecs
    )


def _jagged_case(width, seed):
    rng = np.random.default_rng(seed)
    hi = 2**width if width < 8 else 256
    ps = [37, 16, 53]  # non-block-multiple, all-different link lengths
    xs = [jnp.asarray(rng.integers(0, hi, (p, 32), dtype=np.uint8))
          for p in ps]
    ws = [jnp.asarray(rng.integers(0, 256, (p, 32), dtype=np.uint8))
          for p in ps]
    x, valid = _stack_jagged(xs)
    w, _ = _stack_jagged(ws)
    return x, w, valid


# ------------------------------------------ compiled == interpret, per entry


@pytest.mark.parametrize("width", [4, 8])
def test_bt_count_axes_backends_bit_exact(width):
    """Acceptance: the full ordering x codec grid on jagged links at a
    non-block-multiple P, compiled vs interpret, every cell equal."""
    x, w, valid = _jagged_case(width, seed=width)
    kw = dict(valid=valid, configs=_grid_configs(width), width=width,
              input_lanes=8, block_packets=16)
    got = np.asarray(bt_count_axes(x, w, backend="compiled", **kw))
    ref = np.asarray(bt_count_axes(x, w, backend="interpret", **kw))
    np.testing.assert_array_equal(got, ref)


def test_psu_entry_points_backends_bit_exact():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.integers(0, 256, (50, 32), dtype=np.uint8))
    w = jnp.asarray(rng.integers(0, 256, (50, 32), dtype=np.uint8))
    for kw in ({"k": None}, {"k": 4, "descending": True}):
        oc, rc = psu_sort(x, backend="compiled", **kw)
        oi, ri = psu_sort(x, backend="interpret", **kw)
        np.testing.assert_array_equal(np.asarray(oc), np.asarray(oi))
        np.testing.assert_array_equal(np.asarray(rc), np.asarray(ri))
        sc = psu_stream(x, w, block_packets=16, **kw, backend="compiled")
        si = psu_stream(x, w, block_packets=16, **kw, backend="interpret")
        for fc, fi in zip(sc, si):
            np.testing.assert_array_equal(np.asarray(fc), np.asarray(fi))


def test_scalar_entry_points_backends_bit_exact():
    rng = np.random.default_rng(5)
    s = jnp.asarray(rng.integers(0, 256, (77, 16), dtype=np.uint8))
    assert int(bt_count(s, backend="compiled")) == int(
        bt_count(s, backend="interpret")
    )
    g = jnp.asarray(rng.normal(size=(1000,)).astype(np.float32))
    qc = quantize_egress(g, backend="compiled")
    qi = quantize_egress(g, backend="interpret")
    for a, b in zip(qc, qi):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_variant_and_codec_entry_points_backends_bit_exact():
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.integers(0, 256, (41, 32), dtype=np.uint8))
    w = jnp.asarray(rng.integers(0, 256, (41, 32), dtype=np.uint8))
    variants = (Variant("none"), Variant("acc"), Variant("app", 4, True))
    np.testing.assert_array_equal(
        np.asarray(bt_count_variants(x, w, variants=variants,
                                     block_packets=16, backend="compiled")),
        np.asarray(bt_count_variants(x, w, variants=variants,
                                     block_packets=16, backend="interpret")),
    )
    configs = _grid_configs(8)[::3]
    np.testing.assert_array_equal(
        np.asarray(bt_count_codecs(x, w, configs=configs, block_packets=16,
                                   backend="compiled")),
        np.asarray(bt_count_codecs(x, w, configs=configs, block_packets=16,
                                   backend="interpret")),
    )
    s = jnp.asarray(rng.integers(0, 256, (3, 29, 16), dtype=np.uint8))
    np.testing.assert_array_equal(
        np.asarray(bt_count_links(s, input_lanes=8, lengths=(29, 11, 2),
                                  block_rows=8, backend="compiled")),
        np.asarray(bt_count_links(s, input_lanes=8, lengths=(29, 11, 2),
                                  block_rows=8, backend="interpret")),
    )


# --------------------------------------------------- chunked-streaming carry


def test_chunked_streaming_carries_state_across_chunk_edges():
    """The lax.scan streaming path must thread the inter-block fold carry
    (bus-invert wire state + edge flits) across chunk boundaries: any
    chunk size reproduces the single-launch totals exactly, on both
    backends.  Stateful codecs make a dropped carry visible immediately —
    a cold bus-invert restart at a chunk edge flips invert decisions."""
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.integers(0, 256, (150, 32), dtype=np.uint8))[None]
    configs = (
        CodecVariant("acc"),
        CodecVariant("none", codec="bus_invert"),
        CodecVariant("app", 4, codec="bus_invert", partition=4),
        CodecVariant("acc", codec="transition"),
        CodecVariant("none", codec="gray"),
    )
    kw = dict(configs=configs, input_lanes=8, block_packets=16)
    whole = np.asarray(bt_count_axes(x, None, backend="compiled", **kw))
    assert whole[0, 1, 2] > 0  # the invert line actually switches
    for chunk in (16, 32, 48, 96):  # incl. non-divisors of P=150
        for be in ("compiled", "interpret"):
            got = np.asarray(
                bt_count_axes(x, None, backend=be, chunk_packets=chunk, **kw)
            )
            np.testing.assert_array_equal(got, whole, err_msg=f"{be}/{chunk}")


def test_chunked_links_matches_unchunked():
    rng = np.random.default_rng(13)
    s = jnp.asarray(rng.integers(0, 256, (4, 700, 16), dtype=np.uint8))
    lengths = (700, 333, 2, 0)
    whole = np.asarray(bt_count_links(s, input_lanes=8, lengths=lengths))
    got = np.asarray(
        bt_count_links(s, input_lanes=8, lengths=lengths, chunk_rows=256,
                       backend="compiled")
    )
    np.testing.assert_array_equal(got, whole)


# ------------------------------------------------------- sharded link axis


def test_sharded_axes_matches_unsharded_on_one_device():
    """`bt_count_axes_sharded` (shard_map over the link axis + psum) is a
    layout change, not a math change: on however many devices are present
    (1 in CI) it reproduces the unsharded table, including the link-count
    padding it adds to fill the device mesh."""
    x, w, valid = _jagged_case(8, seed=17)
    kw = dict(valid=valid, configs=_grid_configs(8)[:6], input_lanes=8,
              block_packets=16)
    np.testing.assert_array_equal(
        np.asarray(bt_count_axes_sharded(x, w, **kw)),
        np.asarray(bt_count_axes(x, w, **kw)),
    )


# ------------------------------------------------------- resolution order


def test_backend_resolution_order(monkeypatch):
    monkeypatch.delenv(BACKEND_ENV_VAR, raising=False)
    platform_default = default_backend()
    assert platform_default in BACKENDS
    if jax.default_backend() != "tpu":
        assert platform_default == "compiled"
    # env var beats the platform default, read at call time
    monkeypatch.setenv(BACKEND_ENV_VAR, "interpret")
    assert default_backend() == "interpret"
    assert resolve_backend(None, None) == "interpret"
    # a force context beats the env var
    with force_default_backend("compiled"):
        assert default_backend() == "compiled"
    assert default_backend() == "interpret"
    # the legacy interpret= bool beats the default; backend= beats all
    assert resolve_backend(None, False) == "pallas"
    assert resolve_backend(None, True) == "interpret"
    assert resolve_backend("compiled", True) == "compiled"
    # junk is rejected loudly, never silently mapped
    monkeypatch.setenv(BACKEND_ENV_VAR, "turbo")
    with pytest.raises(ValueError, match="turbo"):
        default_backend()
    with pytest.raises(ValueError, match="backend="):
        resolve_backend("turbo", None)


def test_env_var_selects_execution_path(monkeypatch):
    """The env override changes which path actually runs (not just a
    label): results stay bit-exact and the launch-count trace still pins
    the pallas path under a compiled default."""
    rng = np.random.default_rng(19)
    s = jnp.asarray(rng.integers(0, 256, (40, 8), dtype=np.uint8))
    monkeypatch.setenv(BACKEND_ENV_VAR, "compiled")
    a = int(bt_count(s))
    monkeypatch.setenv(BACKEND_ENV_VAR, "interpret")
    b = int(bt_count(s))
    assert a == b
    # launch counts remain the cross-backend invariant: the counter traces
    # the pallas path even when the session default is compiled
    monkeypatch.setenv(BACKEND_ENV_VAR, "compiled")
    assert pallas_launch_count(bt_count, s) == 1
    assert pallas_launch_count(lambda v: bt_count(v, backend="compiled"), s) == 0
