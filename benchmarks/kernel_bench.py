"""Microbenchmarks of the BT/PSU kernels across the execution backends
(DESIGN.md §13): ``compiled`` jnp (the CPU/GPU production default),
``interpret`` (the Pallas interpreter, kept as an explicit validation
switch), and ``pallas`` (the real TPU kernel, timed only when a TPU is
attached).

Includes the fused-vs-unfused TX-pipeline comparison: the unfused path is
the seed's three-step ordered-BT measurement (``psu_sort`` launch -> host
gather + flit pack -> ``bt_count`` launch), the fused path is the single
``psu_stream`` launch.  Launch counts are measured from the traced jaxpr
(every ``pallas_call`` equation, recursively), not asserted by hand —
they are the cross-backend invariant.  Wall time is reported PER BACKEND
(the ``kernel/tx_fused/<backend>`` rows): an earlier revision compared
fused-vs-unfused wall clock measured in interpret mode, which times the
Python interpreter rather than the kernels, and that framing is gone.
``benchmarks/run.py --json`` persists these rows as the wall-clock
trajectory (``BENCH_kernel_bench.json``).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import (
    bt_count,
    pallas_launch_count,
    psu_sort,
    psu_stream,
    quantize_egress,
)


def _time(fn, *args, iters=3):
    fn(*args)  # compile/warm
    t0 = time.monotonic()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.monotonic() - t0) / iters * 1e6


def count_pallas_launches(fn, *args) -> int:
    """Number of ``pallas_call`` equations in the traced jaxpr of ``fn``
    (recursing through pjit/scan/etc. sub-jaxprs).  The walker's one home
    is ``repro.kernels.pallas_launch_count``; this alias keeps the
    historical benchmark import path."""
    return pallas_launch_count(fn, *args)


def _tx_unfused(x, w):
    """The seed's ordered-BT path: sort launch, host gather + lane pack,
    BT launch per lane half."""
    p, n = x.shape
    lanes = 8
    flits = n // lanes
    order, _ = psu_sort(x, k=4)
    oi = jnp.take_along_axis(x, order, axis=-1)
    ow = jnp.take_along_axis(w, order, axis=-1)
    fi = oi.reshape(p, lanes, flits).transpose(0, 2, 1)
    fw = ow.reshape(p, lanes, flits).transpose(0, 2, 1)
    stream = jnp.concatenate([fi, fw], axis=-1).reshape(p * flits, 2 * lanes)
    return bt_count(stream[:, :lanes]) + bt_count(stream[:, lanes:])


def _tx_fused(x, w):
    res = psu_stream(x, w, k=4)
    return res.bt_input + res.bt_weight


TINY_KWARGS = {"packets": 128, "bt_flits": 2048, "quant_elems": 1 << 14}
# CI smoke shapes (REPRO_BENCH_TINY=1): same code paths, minutes -> seconds


def run(
    packets: int = 1024,
    bt_flits: int = 16384,
    quant_elems: int = 1 << 20,
) -> list[tuple[str, float, str]]:
    rng = np.random.default_rng(0)
    rows = []
    for p, n in [(min(256, packets), 25), (packets, 64)]:
        x = jnp.asarray(rng.integers(0, 256, (p, n), dtype=np.uint8))
        us = _time(lambda v: psu_sort(v)[0], x)
        rows.append((f"kernel/psu/P{p}xN{n}", us, f"{us / p:.2f}us/packet"))
        us = _time(lambda v: psu_sort(v, k=4)[0], x)
        rows.append((f"kernel/psu_app/P{p}xN{n}", us, f"{us / p:.2f}us/packet"))

    # fused vs unfused TX pipeline (ordered-BT measurement path)
    p, n = packets, 64
    x = jnp.asarray(rng.integers(0, 256, (p, n), dtype=np.uint8))
    w = jnp.asarray(rng.integers(0, 256, (p, n), dtype=np.uint8))
    blocks = p // 64
    lu = count_pallas_launches(_tx_unfused, x, w)
    lf = count_pallas_launches(_tx_fused, x, w)
    us_u = _time(_tx_unfused, x, w)
    us_f = _time(_tx_fused, x, w)
    assert int(_tx_unfused(x, w)) == int(_tx_fused(x, w))  # bit-exact paths
    rows.append((
        f"kernel/tx_unfused/P{p}xN{n}", us_u,
        f"pallas_launches={lu} (sort + bt per half; host gather between)",
    ))
    rows.append((
        f"kernel/tx_fused/P{p}xN{n}", us_f,
        f"pallas_launches={lf} (one launch, {blocks} grid steps = 1/block; "
        f"launch count is the claim — per-backend wall rows below)",
    ))

    # --- the SAME fused measurement on every available backend ---
    # (bit-exact by construction; these rows are the wall-clock trajectory
    # the BENCH_kernel_bench.json artifact tracks)
    backends = ["compiled", "interpret"]
    if jax.default_backend() == "tpu":
        backends.insert(0, "pallas")
    wall = {}
    for be in backends:
        fn = lambda a, b, be=be: psu_stream(a, b, k=4, backend=be).bt_input
        wall[be] = _time(fn, x, w, iters=1 if be == "interpret" else 3)
    for be in backends:
        if be == "interpret":
            note = "pallas interpreter — validation switch, not a perf path"
        else:
            note = (
                f"{wall['interpret'] / max(wall[be], 1e-9):.1f}x vs "
                f"interpret wall, bit-exact"
            )
        rows.append((f"kernel/tx_fused/{be}/P{p}xN{n}", wall[be], note))

    s = jnp.asarray(rng.integers(0, 256, (bt_flits, 16), dtype=np.uint8))
    us = _time(bt_count, s)
    rows.append((
        f"kernel/bt_count/{bt_flits}_flits", us, f"{bt_flits * 16 / us:.1f}MB/s"
    ))
    g = jnp.asarray(rng.normal(size=(quant_elems,)).astype(np.float32))
    us = _time(lambda v: quantize_egress(v)[0], g)
    rows.append((
        f"kernel/quantize/{quant_elems}", us, f"{quant_elems * 4 / us:.1f}MB/s"
    ))
    return rows
