"""Microbenchmarks of the Pallas kernels (interpret mode on CPU; on-TPU
these compile to real kernels — the numbers here track algorithmic cost and
regression, not TPU throughput)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import bt_count, psu_sort, quantize_egress


def _time(fn, *args, iters=3):
    fn(*args)  # compile/warm
    t0 = time.monotonic()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.monotonic() - t0) / iters * 1e6


def run() -> list[tuple[str, float, str]]:
    rng = np.random.default_rng(0)
    rows = []
    for p, n in [(256, 25), (1024, 64)]:
        x = jnp.asarray(rng.integers(0, 256, (p, n), dtype=np.uint8))
        us = _time(lambda v: psu_sort(v)[0], x)
        rows.append((f"kernel/psu/P{p}xN{n}", us, f"{us / p:.2f}us/packet"))
        us = _time(lambda v: psu_sort(v, k=4)[0], x)
        rows.append((f"kernel/psu_app/P{p}xN{n}", us, f"{us / p:.2f}us/packet"))
    s = jnp.asarray(rng.integers(0, 256, (16384, 16), dtype=np.uint8))
    us = _time(bt_count, s)
    rows.append(("kernel/bt_count/16k_flits", us, f"{16384 * 16 / us:.1f}MB/s"))
    g = jnp.asarray(rng.normal(size=(1 << 20,)).astype(np.float32))
    us = _time(lambda v: quantize_egress(v)[0], g)
    rows.append(("kernel/quantize/1M", us, f"{(1 << 20) * 4 / us:.1f}MB/s"))
    return rows
