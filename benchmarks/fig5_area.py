"""Fig. 5 reproduction: area breakdown of four sorting-unit designs.

Absolute um^2 are modeled (no EDA flow; DESIGN.md §6) but anchored so the
paper's APP points and reduction percentages hold exactly; Bitonic/CSN use a
gate-level comparator-network model.
"""

from __future__ import annotations

from repro.core import bitonic_area, csn_area, psu_area

PAPER = {("app", 25): 2193.0, ("app", 49): 6928.0, "overall_reduction": 35.4}

TINY_KWARGS = {"ns": (25,)}  # CI smoke (REPRO_BENCH_TINY=1): one sort width


def run(ns: tuple[int, ...] = (25, 49)) -> list[tuple[str, float, str]]:
    rows = []
    for n in ns:
        designs = {
            "bitonic": bitonic_area(n),
            "csn": csn_area(n),
            "acc_psu": psu_area(n),
            "app_psu": psu_area(n, k=4),
        }
        for name, a in designs.items():
            rows.append((
                f"fig5/N{n}/{name}", 0.0,
                f"popcount={a.popcount:.0f}um2 sort={a.sort:.0f}um2 "
                f"total={a.total:.0f}um2",
            ))
        acc, app = designs["acc_psu"], designs["app_psu"]
        rows.append((
            f"fig5/N{n}/reductions", 0.0,
            f"overall={100 * (1 - app.total / acc.total):.1f}% "
            f"popcount={100 * (1 - app.popcount / acc.popcount):.1f}% "
            f"sort={100 * (1 - app.sort / acc.sort):.1f}% "
            f"(paper@N25: 35.4/24.9/36.7)",
        ))
    # k-sweep beyond the paper (k=4 fixed there): the area leg of the
    # repro.dse trade-off curve (dse_sweep joins it with measured BT)
    from repro.dse import k_sweep

    for pt in k_sweep(n=25, width=8, ks=(2, 4, 8),
                      include_baseline=False, include_precise=False):
        a = pt.area()
        rows.append((f"fig5/k_sweep/k{pt.k}", 0.0, f"total={a.total:.0f}um2"))

    # timing model at the paper's 500 MHz target (latency scaling argument)
    from repro.core import bitonic_timing, psu_timing

    for n in ns:
        acc, app, bit = psu_timing(n), psu_timing(n, k=4), bitonic_timing(n)
        rows.append((
            f"fig5/timing/N{n}", 0.0,
            f"acc={acc.sort_time_ns(n):.0f}ns app={app.sort_time_ns(n):.0f}ns "
            f"bitonic_latency={bit.latency_cycles}cyc vs psu "
            f"{acc.latency_cycles}cyc (O(1) in N)",
        ))
    return rows
