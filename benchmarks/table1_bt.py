"""Table I reproduction: BT per 128-bit flit under four orderings.

Paper values (for reference, 100k packets of paired random data):
  non-optimized 63.072 | column-major 54.011 (-14.37 %) |
  ACC 50.346 (-20.18 %) | APP 50.896 (-19.31 %)

We report both data models (see datagen.py): the paper's reductions are
reproduced on the conv-traffic model; uniform iid bytes show the analytic
~5 % ceiling for paired framing (derivation in EXPERIMENTS.md §Table I).

All measurements run through ``repro.link.TxPipeline``; ACC/APP take the
fused single-launch kernel path, 'none'/'column_major' the staged path
(bit-identical, see tests/test_psu_stream.py).
"""

from __future__ import annotations

import time

import jax.numpy as jnp

from repro.link import LinkSpec, TxPipeline

from .datagen import conv_streams, uniform_pairs

PAPER = {
    "none": (63.072, 0.0),
    "column_major": (54.011, 14.366),
    "acc": (50.346, 20.177),
    "app": (50.896, 19.305),
}
# input-side BT/flit from Table I (the stream the PSU actually orders);
# the weight-stream generation is underspecified in the paper (see
# EXPERIMENTS.md §Table I), so the input side is the calibration target.
# The conv weight stream cycles the layer's 6 output-channel kernels
# (DESIGN.md §10 recalibration: overall ACC 14.2 % / APP 12.7 % vs the
# paper's 20.42 % / 19.50 % — reported side by side, never substituted).
PAPER_INPUT = {"none": 31.035, "column_major": 26.004, "acc": 22.333, "app": 22.887}

STRATS = ("none", "column_major", "acc", "app")

TINY_KWARGS = {"packets": 512, "conv_images": 2}  # CI smoke (REPRO_BENCH_TINY=1)


def _input_only_spec(strat: str, elems: int, lanes: int = 16, k: int = 4) -> LinkSpec:
    """Spec for one PE's input-side link: all lanes carry input bytes."""
    return LinkSpec(
        width_bits=8 * lanes,
        flits_per_packet=elems // lanes,
        input_lanes=lanes,
        weight_lanes=0,
        key=strat,
        k=k,
    )


def _measure_separate(vals, strat, lanes=16, k=4):
    x = jnp.asarray(vals)
    pipe = TxPipeline(_input_only_spec(strat, x.shape[-1], lanes, k))
    return pipe.measure(x).overall_bt_per_flit


def run(packets: int = 20000, conv_images: int = 24) -> list[tuple[str, float, str]]:
    rows = []

    # --- paired uniform framing (paper's literal setup) ---
    inp, wgt = uniform_pairs(packets, LinkSpec().elems_per_packet)
    inp, wgt = jnp.asarray(inp), jnp.asarray(wgt)
    t0 = time.monotonic()
    base = TxPipeline(LinkSpec(key="none")).measure(inp, wgt)
    for strat in STRATS:
        r = TxPipeline(LinkSpec(key=strat)).measure(inp, wgt)
        red = r.reduction_vs(base) * 100
        rows.append((
            f"table1/uniform/{strat}",
            (time.monotonic() - t0) * 1e6 / packets,
            f"bt_per_flit={r.overall_bt_per_flit:.3f} red={red:.2f}% "
            f"fused={int(r.fused)} "
            f"paper_bt={PAPER[strat][0]} paper_red={PAPER[strat][1]}%",
        ))

    # --- conv-traffic model (reproduces the paper's magnitudes) ---
    inp, wgt = conv_streams(n_images=conv_images)
    inp_cm, wgt_cm = conv_streams(n_images=conv_images, column_major=True)
    t0 = time.monotonic()
    base_i = _measure_separate(inp, "none")
    base_w = _measure_separate(wgt, "none")
    for strat in STRATS:
        if strat == "column_major":
            # the paper's column-major is a LAYOUT of the im2col traversal
            # (position-major), not a per-packet permutation
            bi = _measure_separate(inp_cm, "none")
            bw = _measure_separate(wgt_cm, "none")
        else:
            bi = _measure_separate(inp, strat)
            bw = _measure_separate(wgt, strat)
        red = 100 * (1 - (bi + bw) / (base_i + base_w))
        in_red = 100 * (1 - bi / base_i)
        paper_in_red = 100 * (1 - PAPER_INPUT[strat] / PAPER_INPUT["none"])
        rows.append((
            f"table1/conv/{strat}",
            (time.monotonic() - t0) * 1e6 / inp.shape[0],
            f"in={bi:.3f} (paper {PAPER_INPUT[strat]}) wt={bw:.3f} "
            f"overall_red={red:.2f}% input_red={in_red:.2f}% "
            f"(paper input_red={paper_in_red:.2f}%)",
        ))

    # APP retention of ACC's reduction (paper: 95.5 %)
    acc_i, app_i = _measure_separate(inp, "acc"), _measure_separate(inp, "app")
    acc_w, app_w = _measure_separate(wgt, "acc"), _measure_separate(wgt, "app")
    red_acc = 1 - (acc_i + acc_w) / (base_i + base_w)
    red_app = 1 - (app_i + app_w) / (base_i + base_w)
    rows.append((
        "table1/conv/app_retention",
        0.0,
        f"app/acc={100 * red_app / red_acc:.1f}% (paper 95.5%)",
    ))

    # beyond-paper: bucket-count sweep (pairs with the fig5 area k-sweep to
    # complete the area/BT trade-off curve the paper fixes at k=4)
    for k in (2, 4, 8):
        bi = _measure_separate(inp, "app", k=k)
        rows.append((
            f"table1/conv/k_sweep/k{k}", 0.0,
            f"input_bt={bi:.3f} input_red={100 * (1 - bi / base_i):.2f}% "
            f"(acc={100 * (1 - acc_i / base_i):.2f}%)",
        ))
    return rows
