"""§IV-B reproduction: LeNet conv1+pool workload through the PSU platform.

16 PEs compute the first convolution (6 kernels, 5x5) and 2x2 mean-pool of
LeNet-5 on synthetic MNIST-like images.  The allocation unit runs the fused
TX pipeline (``repro.link.TxPipeline``: one Pallas launch sorts, reorders,
packs and measures each packet block), the transmitting units permute
(input, weight) pairs, and we verify the CONVOLUTION OUTPUT is unchanged by
the reordering (order-insensitive accumulation) while link BT drops — the
end-to-end statement of the paper.

The same LeNet streams then route through ``repro.codec.compare``, so the
conv scenario reports ordering-alone, coding-alone and ordering∘coding
side by side (net of invert-line overhead, one ``bt_count_codecs`` launch
per stream — DESIGN.md §11).
"""

from __future__ import annotations

import dataclasses
import time

import jax.numpy as jnp
import numpy as np

from repro.codec import compare_streams
from repro.kernels import Variant
from repro.link import LinkSpec, TxPipeline

from .datagen import im2col, synth_images

KERNEL = 5
N_CH = 6
ELEMS, LANES = 64, 16  # validated Table-I framing on the input link

TINY_KWARGS = {"n_images": 1}  # CI smoke (REPRO_BENCH_TINY=1)


def conv_pool_reference(img: np.ndarray, kernels: np.ndarray):
    patches = im2col(img, KERNEL).astype(np.int64)  # (P, 25)
    conv = patches @ kernels.astype(np.int64).T  # (P, 6)
    hw = img.shape[0] - KERNEL + 1
    conv = conv.reshape(hw, hw, N_CH)
    pooled = conv[: hw // 2 * 2, : hw // 2 * 2].reshape(hw // 2, 2, hw // 2, 2, N_CH)
    return pooled.mean((1, 3))


def _pipes() -> dict[str, TxPipeline]:
    spec = LinkSpec(
        width_bits=8 * LANES,
        flits_per_packet=ELEMS // LANES,
        input_lanes=LANES,
        weight_lanes=0,
    )
    return {
        name: TxPipeline(dataclasses.replace(spec, key=name))
        for name in ("none", "acc", "app")
    }


def run(n_images: int = 6) -> list[tuple[str, float, str]]:
    rng = np.random.default_rng(0)
    imgs = synth_images(n_images, seed=7)
    kernels = rng.integers(0, 256, (N_CH, KERNEL * KERNEL), dtype=np.uint8)
    pipes = _pipes()

    rows = []
    total_bt = {"none": 0, "acc": 0, "app": 0}
    t_psu = 0.0
    n_packets = 0
    in_streams, w_streams = [], []
    for img in imgs:
        patches = im2col(img, KERNEL)  # (P, 25) uint8
        w_stream = np.broadcast_to(kernels[0], patches.shape)  # channel-0 link
        flat_i = patches.reshape(-1)
        flat_w = np.ascontiguousarray(w_stream).reshape(-1)
        p = flat_i.size // ELEMS
        x = jnp.asarray(flat_i[: p * ELEMS].reshape(p, ELEMS))
        w = jnp.asarray(flat_w[: p * ELEMS].reshape(p, ELEMS))
        in_streams.append(x)
        w_streams.append(w)
        t0 = time.monotonic()
        res = {name: pipes[name].run(x) for name in ("acc", "app")}
        t_psu += time.monotonic() - t0
        n_packets += p
        total_bt["none"] += int(pipes["none"].run(x).bt_input)
        for name, r in res.items():
            total_bt[name] += int(r.bt_input)
            # order-insensitivity: per-packet MAC identical (exact, ints)
            oi = jnp.take_along_axis(x, r.order, axis=-1)
            ow = jnp.take_along_axis(w, r.order, axis=-1)
            macs0 = (x.astype(jnp.int32) * w.astype(jnp.int32)).sum(-1)
            macs1 = (oi.astype(jnp.int32) * ow.astype(jnp.int32)).sum(-1)
            assert bool(jnp.all(macs0 == macs1))

        # full conv+pool output sanity (reference path)
        out = conv_pool_reference(img, kernels)
        assert np.isfinite(out).all()

    for name in ("acc", "app"):
        red = 100 * (1 - total_bt[name] / total_bt["none"])
        rows.append((
            f"lenet/{name}", t_psu * 1e6 / max(n_packets, 1),
            f"bt={total_bt[name]} base={total_bt['none']} red={red:.2f}% "
            f"(paper link-BT red: acc 20.42% app 19.50%)",
        ))

    # --- ordering vs coding vs composed on the same LeNet streams ---
    # (repro.codec.compare: one bt_count_codecs launch per stream; both
    # links of the conv scenario — patch packets and kernel bytes — summed)
    t0 = time.monotonic()
    table = compare_streams(
        in_streams + w_streams,
        LANES,
        orderings=("none", Variant("acc"), Variant("app", 4)),
        codecs=("none", "bus_invert4"),
        workload="lenet",
    )
    us = (time.monotonic() - t0) * 1e6 / len(table)
    for r in table:
        rows.append((
            f"lenet/compare/{r.label}", us,
            f"data_bt={r.data_bt} aux_bt={r.aux_bt} wires=+{r.extra_wires} "
            f"net_red={100 * r.bt_reduction:.2f}%",
        ))
    return rows
