"""Ordering vs coding vs ordering∘coding — the codec comparison bench.

The paper reduces link BT purely by popcount ordering; classic link
*coding* (bus-invert, gray, transition signaling; cf. Li et al.,
arXiv:2002.05293) is the standard alternative, and the NoC follow-up
(arXiv:2509.00500) frames the two as composable.  This bench scores the
three-way on the repo's traffic families:

  * **conv**      — the calibrated §IV-B conv streams (input + paired
    weight links, ``datagen.conv_streams``);
  * **decode**    — a weight matrix's int8 HBM broadcast image;
  * **allreduce** — an int8 gradient wire image;

every (ordering, codec) pair measured net of invert-line overhead by ONE
``bt_count_codecs`` launch per stream (``repro.codec.compare``).  The
fused-vs-per-config comparison reads launch counts from the traced jaxpr
(1 vs one ``psu_stream``/``bt_count`` chain per configuration — launches
are the claim, wall time is reference only, as in ``kernel_bench`` /
``dse_sweep``), after asserting the two paths bit-exact.

Artifact: the full comparison table as CSV (``REPRO_CODEC_ARTIFACT``
overrides the path; CI uploads it with the bench-smoke trajectory).

With ``--activity`` (or REPRO_BENCH_ACTIVITY=1) the conv stream's full
(ordering x codec) grid is additionally measured wire-resolved
(``bt_count_codecs(..., activity_windows=)``, DESIGN.md §15): each
config's hottest wire becomes a report row and all configs export as
``ACTIVITY_codec_bt.saif`` + the ``ACTIVITY_codec_bt_wires.csv``
per-wire heatmap.
"""

from __future__ import annotations

import csv
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.codec import codec_by_name, compare_streams, demo_workloads
from repro.kernels import (
    CodecVariant,
    Variant,
    bt_count,
    bt_count_codecs,
    psu_stream,
)

from .datagen import conv_streams
from .kernel_bench import count_pallas_launches

TINY_KWARGS = {
    "conv_images": 1,
    "codecs": ("none", "bus_invert4"),
    "demo_images": 1,
}

_LANES = 16

_CSV_FIELDS = (
    "workload",
    "ordering",
    "codec",
    "data_bt",
    "aux_bt",
    "num_flits",
    "extra_wires",
    "bt_reduction",
    "power_reduction",
    "energy_pj",
)

_ORDERINGS = ("none", Variant("acc"), Variant("app", 4))


def _write_csv(path: str, rows) -> None:
    parent = os.path.dirname(path)
    if parent:
        os.makedirs(parent, exist_ok=True)
    with open(path, "w", newline="") as f:
        writer = csv.DictWriter(f, fieldnames=_CSV_FIELDS)
        writer.writeheader()
        writer.writerows(
            {k: getattr(r, k) for k in _CSV_FIELDS} for r in rows
        )


def _per_config_bt(stream: jax.Array, cfg: CodecVariant) -> jax.Array:
    """The pre-codec-kernel measurement chain for ONE config: a
    ``psu_stream`` sort launch (or the staged layout path), a jnp codec,
    and a ``bt_count`` launch on the coded wire."""
    from repro.kernels.ref import codec_stream_ref, variant_order_ref

    p, n = stream.shape
    flits = n // _LANES
    if cfg.key in ("acc", "app"):
        res = psu_stream(
            stream, None, k=cfg.k, descending=cfg.descending,
            input_lanes=_LANES, weight_lanes=0,
        )
        raw = res.stream
    else:
        order = variant_order_ref(
            jnp.asarray(stream, jnp.int32), cfg.ordering, input_lanes=_LANES
        )
        xs = jnp.take_along_axis(stream.astype(jnp.int32), order, axis=-1)
        raw = xs.reshape(p, _LANES, flits).transpose(0, 2, 1).reshape(
            p * flits, _LANES
        )
    coded = codec_stream_ref(raw.astype(jnp.uint8), cfg.codec, cfg.partition)
    return bt_count(coded.wire)


def run(
    conv_images: int = 6,
    codecs: tuple[str, ...] = ("none", "bus_invert", "bus_invert4", "transition"),
    demo_images: int = 4,
) -> list[tuple[str, float, str]]:
    rows: list[tuple[str, float, str]] = []
    inp, wgt = conv_streams(n_images=conv_images)
    demo = demo_workloads(images=demo_images)
    workloads = {
        "conv": (jnp.asarray(inp), jnp.asarray(wgt)),
        "decode": demo["decode"],
        "allreduce": demo["allreduce"],
    }

    all_rows = []
    with obs.collect() as reg:  # codec.stream probe: per-stream baselines
        for name, streams in workloads.items():
            t0 = time.monotonic()
            table = compare_streams(
                streams, _LANES, orderings=_ORDERINGS, codecs=codecs,
                workload=name,
            )
            us = (time.monotonic() - t0) * 1e6 / len(table)
            all_rows.extend(table)
            for r in table:
                rows.append((
                    f"codec/{name}/{r.label}",
                    us,
                    f"data_bt={r.data_bt} aux_bt={r.aux_bt} "
                    f"wires=+{r.extra_wires} "
                    f"net_red={100 * r.bt_reduction:.2f}% "
                    f"power_red={100 * r.power_reduction:.2f}%",
                ))

    # --- obs telemetry: per-stream baseline breakdown of each workload ---
    for s in reg.series("codec.stream.bt"):
        lab = dict(s.labels)
        rows.append((
            f"codec/obs/stream/{lab['stream']}", 0.0,
            f"baseline_bt={int(s.value)} (unordered uncoded wire, "
            f"one bt_count_codecs launch per stream)",
        ))

    # --- fused vs per-config: 1 launch vs one chain per config ---
    configs = tuple(
        CodecVariant(
            key=o.key if isinstance(o, Variant) else o,
            k=o.k if isinstance(o, Variant) else None,
            descending=o.descending if isinstance(o, Variant) else False,
            codec=codec_by_name(c).scheme,
            partition=codec_by_name(c).partition,
        )
        for o in _ORDERINGS
        for c in codecs
    )
    x = workloads["conv"][0]

    # --- wire-resolved activity of the conv grid (--activity, §15) ---
    if os.environ.get("REPRO_BENCH_ACTIVITY", "") not in ("", "0"):
        from repro.kernels import bt_count_codecs as _codecs_kernel

        window = 32
        act = _codecs_kernel(
            x, None, configs=configs, input_lanes=_LANES,
            activity_windows=window,
        )
        p, n = x.shape
        duration = p * (n // _LANES)
        bt = np.asarray(act.bt, dtype=np.int64)
        profs = []
        for ci, cfg in enumerate(configs):
            label = f"{cfg.key}+{cfg.codec}" + (
                f"{cfg.partition}" if cfg.partition else ""
            )
            prof = obs.profile_from_arrays(
                label, act.toggles[ci], act.ones[ci],
                window_flits=window, duration_flits=duration,
                data_lanes=_LANES,
            )
            prof.check(int(bt[ci].sum()))  # per-wire sum == gross BT
            profs.append(prof)
            hot = prof.hottest_wires(1)[0]
            rows.append((
                f"codec/hot_wire/{label}", 0.0,
                f"wire={hot[0]} toggles={hot[1]} "
                f"rate={hot[1] / max(duration - 1, 1):.3f} "
                f"tail={hot[1] / max(prof.per_wire.mean(), 1e-9):.2f}x_mean",
            ))
        obs.write_saif("ACTIVITY_codec_bt.saif", profs, design="codec_bt")
        obs.write_wires_csv("ACTIVITY_codec_bt_wires.csv", profs)
        rows.append((
            "codec/activity/artifact", 0.0,
            f"SAIF + wire heatmap for {len(profs)} configs x "
            f"{profs[0].num_wires} wires (window={window} flits) -> "
            "ACTIVITY_codec_bt.saif",
        ))

    def fused(stream):
        return bt_count_codecs(stream, None, configs=configs, input_lanes=_LANES)

    def per_config(stream):
        return jnp.stack([_per_config_bt(stream, cfg) for cfg in configs])

    np.testing.assert_array_equal(
        np.asarray(fused(x))[:, 0], np.asarray(per_config(x))
    )  # bit-exact paths (data lanes; invert lines are the fused aux column)
    launches = {
        "fused": count_pallas_launches(fused, x),
        "per_config": count_pallas_launches(per_config, x),
    }
    for name, fn in (("fused", fused), ("per_config", per_config)):
        jax.block_until_ready(fn(x))  # compile/warm
        t0 = time.monotonic()
        for _ in range(3):
            jax.block_until_ready(fn(x))
        us = (time.monotonic() - t0) / 3 * 1e6
        rows.append((
            f"codec/launches/{name}",
            us,
            f"configs={len(configs)} pallas_launches={launches[name]}",
        ))

    # --- machine-readable artifact for the bench trajectory ---
    path = os.environ.get("REPRO_CODEC_ARTIFACT", "codec_compare.csv")
    _write_csv(path, all_rows)
    rows.append((
        "codec/artifact", 0.0,
        f"comparison CSV -> {path} ({len(all_rows)} rows over "
        f"{len(workloads)} workloads)",
    ))
    return rows
