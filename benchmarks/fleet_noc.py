"""Fleet-scale NoC serving benchmark (DESIGN.md §17).

The ROADMAP's north star: multi-tenant decode traffic (users x layers x
shards multicast flows from ``noc.adapters.fleet_decode_flows``) on a
16x16 mesh, expanded by the batched fabric pipeline and measured by ONE
``bt_count_links`` launch over the fabric's distinct link queues.  Report
groups:

  * **scale** — flows / active links / distinct queues of the compiled
    ``FabricPlan``, plus the one-launch pin read from the traced jaxpr
    (the same mechanism as ``kernel_bench``; launches are the claim,
    wall is the reference).
  * **expand wall** — the batched device-side expansion vs the legacy
    per-flow loop (``_expand_link_streams_reference``) on the identical
    fleet: the refactor's headline speedup.
  * **ordering** — fabric BT / energy for unsorted vs ACC vs APP source
    sorting at fleet scale — the paper's link-power argument at the
    scale where it pays.
  * **latency** — the wormhole/contention model (``noc.latency``) over
    the same plan: max / mean flow latency, contended links, aggregate
    queueing; per-flit-count only, so one evaluation serves every
    ordering.
  * **hot links** — the top links by gross BT with their contention
    (merged flows, wait cycles) alongside — BT hot-spots and merge
    hot-spots are the same links in this traffic, which is the point of
    putting both models on one plan.

With ``REPRO_FLEET_ARTIFACT=path`` the full per-link latency/BT table is
written as the CSV heatmap CI uploads.
"""

from __future__ import annotations

import csv
import os
import time

import jax
import numpy as np

from repro.kernels import bt_count_links
from repro.link import LinkSpec
from repro.noc import (
    FlowBatch,
    NocLatencyModel,
    compile_fabric,
    expand_fabric,
    fabric_latency,
    fleet_decode_flows,
    mesh,
    simulate_noc,
)
from repro.noc.simulate import _expand_link_streams_reference

from .kernel_bench import count_pallas_launches

TINY_KWARGS = {"users": 4, "layers": 4, "shards": 2, "rows": 8, "cols": 8}

_ORDERINGS = ("none", "acc", "app")


def _spec(key: str) -> LinkSpec:
    # one-sided weight-broadcast framing: all 16 flit bytes carry payload
    return LinkSpec(input_lanes=16, weight_lanes=0, key=key)


def run(
    users: int = 16,
    layers: int = 16,
    shards: int = 4,
    rows: int = 16,
    cols: int = 16,
    reps: int = 3,
) -> list[tuple[str, float, str]]:
    out: list[tuple[str, float, str]] = []
    topo = mesh(rows, cols)
    spec = _spec("acc")
    weights = np.random.default_rng(0).integers(
        0, 256, (1 << 16,), dtype=np.uint8
    )
    flows = fleet_decode_flows(
        jax.numpy.asarray(weights), topo,
        users=users, layers=layers, shards=shards, spec=spec,
    )
    plan = compile_fabric(topo, [(f.src, f.dsts) for f in flows])
    batch = FlowBatch.from_flows(flows, spec)
    out.append((
        "fleet/scale", 0.0,
        f"mesh{rows}x{cols} flows={len(flows)} "
        f"active_links={plan.active_links}/{topo.num_links} "
        f"queues={plan.num_queues} packets={sum(batch.counts)}",
    ))

    # --- expand wall: batched fabric pipeline vs the legacy per-flow loop ---
    def batched():
        fs = expand_fabric(plan, batch, spec, sort_at="source")
        jax.block_until_ready(fs.streams)
        return fs

    fs = batched()  # warm/compile
    t0 = time.monotonic()
    for _ in range(reps):
        batched()
    us_batched = (time.monotonic() - t0) / reps * 1e6
    t0 = time.monotonic()
    ref = _expand_link_streams_reference(topo, flows, spec, sort_at="source")
    jax.block_until_ready(ref.streams)
    us_legacy = (time.monotonic() - t0) * 1e6
    out.append((
        "fleet/expand/batched", us_batched,
        f"queues={plan.num_queues} T={int(fs.streams.shape[1])} "
        f"lanes={spec.bytes_per_flit}",
    ))
    out.append((
        "fleet/expand/legacy", us_legacy,
        f"links={len(ref.link_ids)} (per-flow Python loop, 1 rep)",
    ))
    out.append((
        "fleet/expand/speedup", 0.0,
        f"batched is {us_legacy / max(us_batched, 1e-9):.1f}x faster "
        f"({len(flows)} flows)",
    ))

    # --- the one-launch pin: whole fabric, one bt_count_links launch ---
    launches = count_pallas_launches(
        lambda s: bt_count_links(
            s, input_lanes=spec.input_lanes, lengths=fs.lengths
        ),
        fs.streams,
    )
    out.append((
        "fleet/launches", 0.0,
        f"bt_count_links launches={launches} for {plan.num_queues} queues "
        f"/ {plan.active_links} links (one per key width)",
    ))

    # --- ordering: fabric BT / energy at fleet scale ---
    reports = {}
    for key in _ORDERINGS:
        t0 = time.monotonic()
        rep = simulate_noc(topo, flows, _spec(key), sort_at="source")
        us = (time.monotonic() - t0) * 1e6
        reports[key] = rep
        base = reports[_ORDERINGS[0]]
        out.append((
            f"fleet/{key}", us,
            f"bt={rep.total_bt} red={100 * rep.reduction_vs(base):.2f}% "
            f"flit_hops={rep.total_flit_hops} E={rep.energy_pj / 1e3:.1f}nJ",
        ))

    # --- latency: wormhole + merge contention over the same plan ---
    lat = fabric_latency(
        plan,
        [c * spec.flits_per_packet for c in batch.counts],
        NocLatencyModel(),
    )
    out.append((
        "fleet/latency", 0.0,
        f"max={lat.max_latency_ns:.0f}ns mean={lat.mean_latency_ns:.0f}ns "
        f"contended={lat.contended_links}/{len(lat.links)} "
        f"wait={lat.total_wait_cycles}cyc",
    ))

    # --- hot links: BT hot-spots with their contention alongside ---
    acc = reports["acc"]
    by_link = {l.link: l for l in lat.links}
    hot = sorted(acc.links, key=lambda s: -s.gross_bt)[:3]
    for rank, s in enumerate(hot, 1):
        c = by_link[s.link]
        out.append((
            f"fleet/hot_link/{rank}", 0.0,
            f"link={s.link} route={s.src}->{s.dst} gross_bt={s.gross_bt} "
            f"flits={s.num_flits} flows={c.flows} wait={c.wait_cycles}cyc "
            f"drain={c.drain_ns:.0f}ns E={s.energy_pj:.1f}pJ",
        ))

    artifact = os.environ.get("REPRO_FLEET_ARTIFACT")
    if artifact:  # the per-link latency/BT heatmap CSV CI uploads
        parent = os.path.dirname(artifact)
        if parent:
            os.makedirs(parent, exist_ok=True)
        with open(artifact, "w", newline="") as f:
            w = csv.writer(f)
            w.writerow([
                "link", "src", "dst", "flits", "flows", "bt_input",
                "bt_aux", "energy_pj", "wait_cycles", "busy_ns", "drain_ns",
            ])
            for s in acc.links:
                c = by_link[s.link]
                w.writerow([
                    s.link, s.src, s.dst, s.num_flits, c.flows, s.bt_input,
                    s.bt_aux, round(s.energy_pj, 3), c.wait_cycles,
                    round(c.busy_ns, 3), round(c.drain_ns, 3),
                ])
        out.append((
            "fleet/artifact", 0.0,
            f"per-link latency/BT heatmap ({len(acc.links)} links) -> "
            f"{artifact}",
        ))
    return out
