"""Gate on the bench trajectory (the CI bench-smoke check step).

After ``python -m benchmarks.run --json``, every module in
``benchmarks.run.MODULES`` must have written a ``BENCH_<module>.json``
with at least one row and no recorded failure — a module that silently
produced nothing is as much a regression as one that raised.

Usage: ``python -m benchmarks.check_bench [dir]`` (default: cwd, the
directory the JSONs were written to).  Exits non-zero listing every
missing/failed module.
"""

from __future__ import annotations

import json
import os
import sys

from .run import MODULES


def check(root: str = ".") -> list[str]:
    """Problem strings for the trajectory under ``root`` (empty = clean)."""
    problems = []
    for name in MODULES:
        path = os.path.join(root, f"BENCH_{name}.json")
        if not os.path.exists(path):
            problems.append(f"{name}: missing {path} (module produced no JSON)")
            continue
        with open(path) as f:
            payload = json.load(f)
        if payload.get("failed"):
            problems.append(f"{name}: {payload['failed']}")
            continue
        rows = payload.get("rows", [])
        if not rows:
            problems.append(f"{name}: JSON has no rows")
            continue
        bad = [
            str(r.get("name", "?"))
            for r in rows
            if "FAILED:" in f"{r.get('name', '')},{r.get('derived', '')}"
        ]
        if bad:
            problems.append(f"{name}: FAILED rows: {', '.join(bad)}")
    return problems


def main() -> None:
    root = sys.argv[1] if len(sys.argv) > 1 else "."
    problems = check(root)
    if problems:
        raise SystemExit(
            "bench trajectory check failed:\n  " + "\n  ".join(problems)
        )
    print(f"bench trajectory OK: all {len(MODULES)} module JSONs present")


if __name__ == "__main__":
    main()
