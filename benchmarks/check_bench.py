"""Gate on the bench trajectory (the CI bench-smoke check step).

After ``python -m benchmarks.run --json``, three checks run against the
``BENCH_<module>.json`` artifacts:

  1. **presence** — every module in ``benchmarks.run.MODULES`` wrote a
     JSON with at least one row and no recorded failure; a module that
     silently produced nothing is as much a regression as one that
     raised.
  2. **registry coverage** — every module of the committed baseline
     trajectory still exists in ``MODULES``.  A module silently dropped
     from the registry used to pass the gate (the loop only walked
     ``MODULES``); now it exits 1 with the named diff.
  3. **wall regression** — each module's ``wall_s`` against the committed
     baseline (matched on the ``tiny`` smoke flag): fail when it exceeds
     both {FAIL_RATIO}x the baseline and +{FAIL_DELTA_S}s absolute, warn
     beyond {WARN_RATIO}x and +{WARN_DELTA_S}s.  The paired ratio+delta
     thresholds keep sub-second smoke modules from tripping on scheduler
     noise.

Usage::

    python -m benchmarks.check_bench [dir] [--baseline DIR]
    python -m benchmarks.check_bench [dir] --update-baseline

``dir`` (default cwd) holds the fresh artifacts; ``--baseline`` overrides
the committed trajectory directory, which otherwise resolves to
``benchmarks/trajectory/tiny`` or ``.../full`` to match the run's
``tiny`` flag.  With no baseline committed yet, checks 2-3 are skipped
with a warning.

``--update-baseline`` regenerates the committed trajectory in place:
after the presence check passes (a broken run must never become the
baseline), every fresh ``BENCH_<module>.json`` is copied into
``benchmarks/trajectory/{tiny|full}`` (matched to the run's ``tiny``
flag) and baseline files for modules no longer in the registry are
removed.  The README bench section documents the workflow: run
``python -m benchmarks.run --json``, then this, then commit the diff.
"""

from __future__ import annotations

import argparse
import json
import os

from .run import MODULES

# fail/warn when wall exceeds BOTH the ratio and the absolute delta —
# ratio alone trips on sub-second smoke modules, delta alone never trips
# for them
FAIL_RATIO, FAIL_DELTA_S = 2.0, 1.0
WARN_RATIO, WARN_DELTA_S = 1.25, 0.25

TRAJECTORY_DIR = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "trajectory"
)


def _load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def _baseline_dir(root: str, baseline: str | None) -> str | None:
    """The committed-baseline directory for the run under ``root``."""
    if baseline is not None:
        return baseline if os.path.isdir(baseline) else None
    for name in MODULES:  # match tiny/ vs full/ on the first present run
        path = os.path.join(root, f"BENCH_{name}.json")
        if os.path.exists(path):
            sub = "tiny" if _load(path).get("tiny") else "full"
            cand = os.path.join(TRAJECTORY_DIR, sub)
            return cand if os.path.isdir(cand) else None
    return None


def _baseline_payloads(bdir: str) -> dict[str, dict]:
    out: dict[str, dict] = {}
    for fn in sorted(os.listdir(bdir)):
        if fn.startswith("BENCH_") and fn.endswith(".json"):
            out[fn[len("BENCH_"):-len(".json")]] = _load(
                os.path.join(bdir, fn)
            )
    return out


def check(
    root: str = ".", baseline: str | None = None
) -> tuple[list[str], list[str]]:
    """(problems, warnings) for the artifacts under ``root``."""
    problems: list[str] = []
    warnings: list[str] = []
    payloads: dict[str, dict] = {}
    for name in MODULES:
        path = os.path.join(root, f"BENCH_{name}.json")
        if not os.path.exists(path):
            problems.append(f"{name}: missing {path} (module produced no JSON)")
            continue
        payload = _load(path)
        payloads[name] = payload
        if payload.get("failed"):
            problems.append(f"{name}: {payload['failed']}")
            continue
        rows = payload.get("rows", [])
        if not rows:
            problems.append(f"{name}: JSON has no rows")
            continue
        bad = [
            str(r.get("name", "?"))
            for r in rows
            if "FAILED:" in f"{r.get('name', '')},{r.get('derived', '')}"
        ]
        if bad:
            problems.append(f"{name}: FAILED rows: {', '.join(bad)}")

    bdir = _baseline_dir(root, baseline)
    if bdir is None:
        warnings.append(
            "no committed baseline trajectory found — registry-coverage "
            "and wall-regression gates skipped"
        )
        return problems, warnings

    base = _baseline_payloads(bdir)
    dropped = sorted(set(base) - set(MODULES))
    if dropped:
        problems.append(
            "modules in the committed baseline but gone from run.MODULES "
            f"(silently dropped from the registry): {', '.join(dropped)}"
        )
    for name, payload in payloads.items():
        b = base.get(name)
        if b is None or b.get("failed") or payload.get("failed"):
            continue
        if bool(payload.get("tiny")) != bool(b.get("tiny")):
            warnings.append(
                f"{name}: tiny flag differs from baseline — wall gate skipped"
            )
            continue
        wall = float(payload.get("wall_s") or 0.0)
        bwall = float(b.get("wall_s") or 0.0)
        if bwall <= 0.0:
            continue
        ratio, delta = wall / bwall, wall - bwall
        line = (
            f"{name}: wall {wall:.2f}s vs baseline {bwall:.2f}s "
            f"({ratio:.2f}x, +{delta:.2f}s)"
        )
        if ratio > FAIL_RATIO and delta > FAIL_DELTA_S:
            problems.append(f"{line} — regression")
        elif ratio > WARN_RATIO and delta > WARN_DELTA_S:
            warnings.append(line)
    return problems, warnings


def update_baseline(root: str = ".") -> str:
    """Copy the fresh ``BENCH_*.json`` artifacts under ``root`` into the
    committed trajectory directory (tiny/full matched to the run), after
    gating on the presence check.  Returns the updated directory."""
    problems: list[str] = []
    payloads: dict[str, dict] = {}
    for name in MODULES:
        path = os.path.join(root, f"BENCH_{name}.json")
        if not os.path.exists(path):
            problems.append(f"{name}: missing {path}")
            continue
        payload = _load(path)
        if payload.get("failed"):
            problems.append(f"{name}: {payload['failed']}")
        elif not payload.get("rows"):
            problems.append(f"{name}: JSON has no rows")
        else:
            payloads[name] = payload
    if problems:
        raise SystemExit(
            "refusing to update the baseline from a broken run:\n  "
            + "\n  ".join(problems)
        )
    tiny = {bool(p.get("tiny")) for p in payloads.values()}
    if len(tiny) != 1:
        raise SystemExit(
            "refusing to update the baseline: artifacts mix tiny and full "
            "runs (rerun all modules with one REPRO_BENCH_TINY setting)"
        )
    bdir = os.path.join(TRAJECTORY_DIR, "tiny" if tiny.pop() else "full")
    os.makedirs(bdir, exist_ok=True)
    for name in payloads:
        with open(os.path.join(bdir, f"BENCH_{name}.json"), "w") as f:
            json.dump(payloads[name], f, indent=2, sort_keys=True)
            f.write("\n")
    stale = sorted(set(_baseline_payloads(bdir)) - set(MODULES))
    for name in stale:
        os.remove(os.path.join(bdir, f"BENCH_{name}.json"))
        print(f"removed stale baseline BENCH_{name}.json")
    print(
        f"baseline updated: {len(payloads)} module JSONs -> {bdir}"
        + (f" ({len(stale)} stale removed)" if stale else "")
    )
    return bdir


def main(argv: list[str] | None = None) -> None:
    parser = argparse.ArgumentParser(
        prog="benchmarks.check_bench",
        description="gate fresh BENCH_*.json artifacts on the committed "
        "bench trajectory",
    )
    parser.add_argument(
        "root", nargs="?", default=".",
        help="directory holding the fresh BENCH_*.json artifacts",
    )
    parser.add_argument(
        "--baseline", default=None,
        help="committed baseline directory (default: benchmarks/trajectory/"
        "{tiny|full} matched to the run's tiny flag)",
    )
    parser.add_argument(
        "--update-baseline", action="store_true",
        help="copy the fresh artifacts into the committed trajectory "
        "(tiny/full matched to the run) instead of gating on it",
    )
    args = parser.parse_args(argv)
    if args.update_baseline:
        update_baseline(args.root)
        return
    problems, warnings = check(args.root, args.baseline)
    for w in warnings:
        print(f"WARNING: {w}")
    if problems:
        raise SystemExit(
            "bench trajectory check failed:\n  " + "\n  ".join(problems)
        )
    print(f"bench trajectory OK: all {len(MODULES)} module JSONs present")


if __name__ == "__main__":
    main()
