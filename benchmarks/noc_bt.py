"""NoC BT benchmark: the sorting unit inside a multi-router fabric.

Four report groups (DESIGN.md §9, §14):

  * **topology x ordering** — fabric-total BT / energy for conv-platform
    traffic on a mesh and a ring, under sort-at-source and sort-at-every-
    hop, precise (ACC) vs approximate (APP) vs unsorted.
  * **hottest links** — per-link BT telemetry of the mesh acc/source
    fabric via the ``repro.obs`` ``noc.link`` probe: the top-3 links by
    gross BT as report rows, and (with ``REPRO_NOC_LINKS_ARTIFACT=path``)
    the full per-link heatmap CSV.  With ``--activity`` (or
    REPRO_BENCH_ACTIVITY=1) the same run is measured wire-resolved
    (DESIGN.md §15): top-3 hottest *wires* as report rows plus
    ``ACTIVITY_noc_bt.saif`` and the ``ACTIVITY_noc_bt_wires.csv``
    per-wire heatmap.
  * **hop sweep** — one unicast flow at increasing XY distance: with
    sort-at-source, every extra hop retransmits the *already ordered*
    stream, so the absolute BT saving scales linearly with hop count and
    the relative reduction is preserved end-to-end.
  * **fused vs looped** — the batched ``bt_count_links`` kernel (link axis
    on the Pallas grid, ONE launch for the whole fabric) against looping
    the single-stream ``bt_count`` kernel per link (two launches per link,
    one per lane side).  Launch counts are read from the traced jaxpr, not
    asserted by hand; wall time is reported for reference only on whatever
    backend ``repro.kernels.default_backend()`` resolves (DESIGN.md §13 —
    compiled jnp on CPU) and can favor either path depending on shape
    (same caveat as ``kernel_bench``'s fused-vs-unfused rows: launches are
    the claim).
"""

from __future__ import annotations

import os
import sys
import time
from contextlib import nullcontext

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.kernels import bt_count, bt_count_links
from repro.link import LinkSpec
from repro.noc import (
    TrafficFlow,
    conv_platform_flows,
    expand_link_streams,
    hop_count,
    mesh,
    ring,
    simulate_noc,
)

from .datagen import im2col, synth_images
from .kernel_bench import count_pallas_launches

TINY_KWARGS = {"n_images": 1, "max_hops": 2}

# (key, sort_at) design points; 'none'/'source' is the baseline fabric
DESIGNS = [
    ("none", "source"),
    ("acc", "source"),
    ("app", "source"),
    ("acc", "hop"),
    ("none", "hop"),
]


def _conv_flows(topo, src, pes, spec, n_images):
    rng = np.random.default_rng(0)
    imgs = synth_images(n_images, seed=7)
    kernel = rng.integers(0, 256, (25,), dtype=np.uint8)
    flows = []
    for img in imgs:
        flows.extend(
            conv_platform_flows(
                jnp.asarray(im2col(img, 5)), jnp.asarray(kernel),
                topo, src, pes, spec,
            )
        )
    return flows


def run(n_images: int = 3, max_hops: int = 6) -> list[tuple[str, float, str]]:
    rows = []

    # --- topology x ordering: conv-platform traffic ---
    fabrics = [
        (mesh(4, 4), 0, [r for r in range(16) if r % 4]),  # PEs off col 0
        (ring(8), 0, list(range(1, 8))),
    ]
    conv_flows = {}  # flows depend only on the framing, not the key/sort_at
    hot_reg = None  # per-link telemetry of the mesh acc/source fabric
    hot_rep = None  # its report (carries wire activity under --activity)
    activity = os.environ.get("REPRO_BENCH_ACTIVITY", "") not in ("", "0")
    for topo, src, pes in fabrics:
        tname = f"{topo.kind}{topo.rows}x{topo.cols}"
        conv_flows[tname] = _conv_flows(topo, src, pes, LinkSpec(), n_images)
        base = None
        for key, sort_at in DESIGNS:
            spec = LinkSpec(key=key)
            flows = conv_flows[tname]
            # collect per-link telemetry on the paper-default mesh fabric
            # (the repro.obs noc.link probe feeds the hottest-link rows)
            watch = tname.startswith("mesh") and (key, sort_at) == (
                "acc", "source",
            )
            t0 = time.monotonic()
            with obs.collect() if watch else nullcontext() as reg:
                rep = simulate_noc(
                    topo, flows, spec, sort_at=sort_at,
                    activity_windows=32 if watch and activity else None,
                )
            us = (time.monotonic() - t0) * 1e6
            if watch:
                hot_reg, hot_rep = reg, rep
            if base is None:
                base = rep
            rows.append((
                f"noc/{tname}/{key}-{sort_at}",
                us,
                f"bt={rep.total_bt} red={100 * rep.reduction_vs(base):.2f}% "
                f"links={rep.active_links}/{rep.total_links} "
                f"flit_hops={rep.total_flit_hops} E={rep.energy_pj / 1e3:.1f}nJ",
            ))

    # --- hottest links: per-link BT telemetry of the mesh acc/source run ---
    if hot_reg is not None:
        for rank, r in enumerate(obs.top_links(hot_reg, 3), 1):
            rows.append((
                f"noc/hot_link/{rank}",
                0.0,
                f"link={r['link']} route={r['src']}->{r['dst']} "
                f"gross_bt={r['gross_bt']} flits={r['num_flits']} "
                f"bt_per_flit={r['bt_per_flit']:.2f} "
                f"E={r['energy_pj']:.1f}pJ",
            ))
        artifact = os.environ.get("REPRO_NOC_LINKS_ARTIFACT")
        if artifact:  # the per-link heatmap CSV (README quickstart)
            obs.write_links_csv(artifact, hot_reg)

    # --- hottest wires: wire-resolved telemetry of the same run (§15) ---
    if activity and hot_rep is not None and hot_rep.activity_window:
        profs = obs.profiles_from_noc(hot_rep)
        for p, s in zip(profs, hot_rep.links):
            p.check(s.gross_bt)  # sum(per-wire) == gross BT, every link
        for rank, r in enumerate(obs.top_wires(hot_reg, 3), 1):
            rows.append((
                f"noc/hot_wire/{rank}",
                0.0,
                f"link={r['link']} route={r['src']}->{r['dst']} "
                f"wire={r['wire']} toggles={r['toggles']}",
            ))
        obs.write_saif("ACTIVITY_noc_bt.saif", profs, design="noc_bt")
        obs.write_wires_csv("ACTIVITY_noc_bt_wires.csv", profs)
        rows.append((
            "noc/activity/artifact", 0.0,
            f"SAIF + wire heatmap for {len(profs)} links x "
            f"{profs[0].num_wires} wires (window="
            f"{hot_rep.activity_window} flits) -> ACTIVITY_noc_bt.saif",
        ))

    # --- hop sweep: source-sorted advantage is preserved across hops ---
    topo = mesh(4, 4)
    rng = np.random.default_rng(1)
    img = synth_images(1, seed=11)[0]
    pkts = jnp.asarray(im2col(img, 5).reshape(-1)[: 96 * 32].reshape(96, 32))
    wgts = jnp.asarray(
        rng.integers(0, 256, pkts.shape, dtype=np.uint8)
    )
    # XY distances 1..max_hops from router 0, capped at the 4x4 mesh
    # diameter (say so rather than silently covering less than asked)
    diameter = (topo.rows - 1) + (topo.cols - 1)
    if max_hops > diameter:
        print(
            f"# noc_bt: hop sweep capped at the mesh diameter "
            f"({max_hops} requested, {diameter} possible)",
            file=sys.stderr,
        )
        max_hops = diameter
    dests = [
        topo.router(max(0, h - (topo.cols - 1)), min(h, topo.cols - 1))
        for h in range(1, max_hops + 1)
    ]
    for dst in dests:
        h = hop_count(topo, 0, dst)
        flow = [TrafficFlow("sweep", 0, (dst,), pkts, wgts)]
        per_key = {}
        for key in ("none", "acc"):
            rep = simulate_noc(topo, flow, LinkSpec(key=key), sort_at="source")
            per_key[key] = rep
        red = 100 * per_key["acc"].reduction_vs(per_key["none"])
        rows.append((
            f"noc/hops{h}",
            0.0,
            f"bt_none={per_key['none'].total_bt} "
            f"bt_acc={per_key['acc'].total_bt} red={red:.2f}% "
            "(per-hop reduction preserved)",
        ))

    # --- fused vs looped per-link measurement ---
    spec = LinkSpec(key="acc")
    topo = fabrics[0][0]
    flows = conv_flows[f"{topo.kind}{topo.rows}x{topo.cols}"]
    ls = expand_link_streams(topo, flows, spec, sort_at="source")
    il = spec.input_lanes

    def fused(streams):
        return bt_count_links(streams, input_lanes=il)

    def looped(streams):
        return jnp.stack([
            jnp.stack([
                bt_count(streams[i, :, :il]), bt_count(streams[i, :, il:])
            ])
            for i in range(streams.shape[0])
        ])

    np.testing.assert_array_equal(
        np.asarray(fused(ls.streams)), np.asarray(looped(ls.streams))
    )
    launches = {
        "fused": count_pallas_launches(fused, ls.streams),
        "looped": count_pallas_launches(looped, ls.streams),
    }
    for name, fn in (("fused", fused), ("looped", looped)):
        jax.block_until_ready(fn(ls.streams))  # compile/warm
        t0 = time.monotonic()
        for _ in range(3):
            jax.block_until_ready(fn(ls.streams))
        us = (time.monotonic() - t0) / 3 * 1e6
        rows.append((
            f"noc/per_link_bt/{name}",
            us,
            f"links={ls.streams.shape[0]} launches={launches[name]}",
        ))
    return rows
