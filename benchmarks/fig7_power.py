"""Fig. 6/7 reproduction: link-related and PE-level power reductions.

Power model (DESIGN.md §6): link-related power reduction = transfer_factor x
BT reduction (transfer_factor calibrated on the paper's ACC point); PE-level
reduction = link_share x link-related reduction, with link_share calibrated
from the paper's Fig. 6 (ACC: 18.27 % link -> 4.98 % PE => share ~ 0.273).
BT reductions come from the measured conv-traffic model (table1 bench).
"""

from __future__ import annotations

from repro.link import LinkPowerModel

from .table1_bt import _measure_separate
from .datagen import conv_streams

PAPER = {
    "acc": {"bt": 20.42, "link_power": 18.27, "pe_power": 4.98},
    "app": {"bt": 19.50, "link_power": 16.48, "pe_power": 4.58},
}
LINK_SHARE = 4.98 / 18.27  # PE-level share of link-related power (Fig. 6)

TINY_KWARGS = {"conv_images": 2}  # CI smoke (REPRO_BENCH_TINY=1)


def run(conv_images: int = 24) -> list[tuple[str, float, str]]:
    model = LinkPowerModel()
    inp, wgt = conv_streams(n_images=conv_images)
    base = _measure_separate(inp, "none") + _measure_separate(wgt, "none")
    rows = []
    for strat in ("acc", "app"):
        bt = _measure_separate(inp, strat) + _measure_separate(wgt, strat)
        bt_red = 1 - bt / base
        link_red = model.power_reduction(bt_red)
        pe_red = LINK_SHARE * link_red * 100
        p = PAPER[strat]
        rows.append((
            f"fig7/{strat}", 0.0,
            f"bt_red={bt_red * 100:.2f}% (paper {p['bt']}%) "
            f"link_power_red={link_red * 100:.2f}% (paper {p['link_power']}%) "
            f"pe_power_red={pe_red:.2f}% (paper {p['pe_power']}%)",
        ))
    # sorting-unit power overhead ratio (paper: APP 1.43 mW vs ACC 2.28 mW,
    # -37.3 %): modeled as proportional to the area model, via the
    # repro.dse design-point API (the one home of the sweep logic)
    from repro.dse import DesignPoint, area_reduction

    app_red = area_reduction(DesignPoint(n=25, width=8, k=4, ordering="app"))
    rows.append((
        "fig7/psu_power_overhead", 0.0,
        f"app/acc area ratio={1 - app_red:.3f} -> overhead "
        f"reduction={100 * app_red:.1f}% (paper 37.3% "
        "power, 35.4% area)",
    ))
    return rows
