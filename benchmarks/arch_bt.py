"""Beyond-paper: BT accounting on transformer traffic (the paper's §V
future work — 'extend the analysis to ResNets and Transformers').

Streams measured per architecture (smoke-scale weights, full-scale rules):
  * MLP weight stream (decode-dominant HBM traffic), two's-complement vs
    sign-magnitude, row/col layouts, ACC/APP row ordering;
  * MoE dispatch buffers (token sets per expert are unordered — the cleanest
    order-insensitivity in the zoo): token popcount-bucket ordering;
  * gradient egress int8 image with the weight-derived static permutation.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.kernels import bt_count
from repro.link import LinkSpec, TxPipeline, tensor_flit_stream
from repro.models import init_params
from repro.traffic import egress_permutation, int8_view, stream_bt_report

ARCHS = ["internlm2-1.8b", "qwen3-moe-30b-a3b", "mamba2-370m"]


def _structured_weight(rng, ff, d):
    """Trained-net-like weights: per-row lognormal scale structure."""
    return jnp.asarray(rng.normal(size=(ff, d)) * rng.lognormal(0, 1.0, (ff, 1)))


def run() -> list[tuple[str, float, str]]:
    rows = []
    rng = np.random.default_rng(0)

    # 1. weight streams: encoding x layout x ordering
    w = _structured_weight(rng, 1024, 256)
    for sm in (False, True):
        for layout in ("row", "col"):
            for strat in ("none", "acc", "app"):
                rep = stream_bt_report("w", w, strat, sign_magnitude=sm, layout=layout)
                rows.append((
                    f"arch_bt/weights/sm={int(sm)}/{layout}/{strat}", 0.0,
                    f"bt/flit={rep.bt_ordered / rep.num_flits:.2f} "
                    f"red_vs_unordered={rep.reduction * 100:.2f}%",
                ))

    # 2. per-arch MLP weight-stream totals (iid-init weights: the honest
    #    negative control — near-zero ordering gain at row granularity)
    for arch in ARCHS:
        cfg = smoke_config(arch)
        params = init_params(cfg, jax.random.key(0))
        layer = jax.tree.map(lambda x: x[0], params["layers"])
        tensor = (
            layer["mlp"]["down"] if "mlp" in layer
            else layer["moe"]["down"].reshape(-1, cfg.d_model) if "moe" in layer
            else layer["ssd"]["out_proj"]
        )
        rep = stream_bt_report(arch, tensor, "app", sign_magnitude=True, layout="col")
        rows.append((
            f"arch_bt/{arch}/mlp_stream", 0.0,
            f"bt_base={rep.bt_none} bt_app={rep.bt_ordered} "
            f"red={rep.reduction * 100:.2f}% (iid-init rows: expected ~0)",
        ))

    # 3. MoE dispatch buffer ordering: activations have token-norm structure
    #    (token rows are an unordered set -> row-bucket TX pipeline applies)
    toks = jnp.asarray(
        rng.normal(size=(256, 128)) * rng.lognormal(0, 0.8, (256, 1))
    )
    t8 = int8_view(toks)
    dispatch_spec = LinkSpec(
        flits_per_packet=1, input_lanes=16, weight_lanes=0,
        key="row_bucket", encode="sign_magnitude", pack="row", k=4,
    )
    base = TxPipeline(
        dataclasses.replace(dispatch_spec, key="none")
    ).measure_rows(t8, "moe_dispatch")
    ordered = TxPipeline(dispatch_spec).measure_rows(t8, "moe_dispatch")
    rows.append((
        "arch_bt/moe_dispatch/app", 0.0,
        f"bt_base={base.total_bt} bt_ordered={ordered.total_bt} "
        f"red={100 * (1 - ordered.total_bt / base.total_bt):.2f}%",
    ))

    # 4. gradient egress image with weight-derived static permutation
    wflat = int8_view(jnp.asarray(rng.normal(size=(64 * 1024,))))
    perm, _ = egress_permutation(wflat, packet=64)
    g = int8_view(jnp.asarray(rng.normal(size=(64 * 1024,))))
    base = int(bt_count(tensor_flit_stream(g)))
    permuted = int(bt_count(tensor_flit_stream(g[jnp.asarray(perm)])))
    rows.append((
        "arch_bt/grad_egress/static_perm", 0.0,
        f"bt_base={base} bt_perm={permuted} red={100 * (1 - permuted / base):.2f}% "
        "(uncorrelated grads: expected ~0 — recorded as the honest negative "
        "result; value-dependent per-step sorting would desynchronise the "
        "reduction, see DESIGN.md §8)",
    ))
    return rows
