"""Regenerate the §Dry-run table and §Roofline sections of EXPERIMENTS.md
from the dry-run JSON records (idempotent; keyed on HTML markers)."""

from __future__ import annotations

import glob
import json
import re

from repro.configs import ARCH_NAMES, arch_shapes
from repro.configs.shapes import SHAPES
from repro.roofline import analyse


def dryrun_table() -> str:
    lines = [
        "| arch | shape | mesh | kind | compile | peak GiB/dev (TPU-adj) | "
        "HLO flops/dev | wire bytes/dev | collectives |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    recs = {}
    for f in sorted(glob.glob("experiments/dryrun/*.json")):
        r = json.load(open(f))
        recs[(r["arch"], r["shape"], r["mesh"])] = r
    for arch in ARCH_NAMES:
        for shape in arch_shapes(arch):
            for mesh in ("16x16", "2x16x16"):
                r = recs.get((arch, shape, mesh))
                if r is None:
                    lines.append(f"| {arch} | {shape} | {mesh} | — | MISSING | | | | |")
                    continue
                if r["status"] != "ok":
                    lines.append(
                        f"| {arch} | {shape} | {mesh} | — | {r['status']} | | | | |")
                    continue
                peak = r.get("tpu_peak_bytes_per_device", 0) / 2**30
                fits = "✓" if peak < 16 else "OVER"
                flops = r.get("hlo_flops_per_device")
                wire = r.get("wire_bytes_per_device")
                colls = r.get("collectives", {})
                cstr = " ".join(
                    f"{k.split('-')[0]}-{k.split('-')[1][:1]}:{v['count']}"
                    if "-" in k else f"{k}:{v['count']}"
                    for k, v in sorted(colls.items())
                ) or "—"
                lines.append(
                    f"| {arch} | {shape} | {mesh} | {r['kind']} | "
                    f"{r['compile_sec']:.0f}s | {peak:.2f} {fits} | "
                    f"{'%.2e' % flops if flops else '—'} | "
                    f"{'%.2e' % wire if wire is not None else '—'} | {cstr} |"
                )
    skips = [
        f"{a} x long_500k" for a in ARCH_NAMES if "long_500k" not in arch_shapes(a)
    ]
    lines += ["", f"Skipped (documented, DESIGN.md §4): {', '.join(skips)}."]
    return "\n".join(lines)


def roofline_section() -> str:
    lines = [
        "Terms per (arch x shape), single-pod 16x16 (cost pass: unrolled-"
        "extrapolated; see §Dry-run methodology).  `mem floor` = TPU-adjusted "
        "resident bytes / HBM bw (every live byte crosses HBM >= once); "
        "`mem hlo` = XLA bytes-accessed / HBM bw (upper bound — the "
        "CPU-backend compile fuses less than TPU).  Dominant term and the "
        "roofline fraction use the floor.",
        "",
        "| arch | shape | compute s | mem floor s | mem hlo s | collective s |"
        " dominant | useful ratio | roofline frac | one-line lever |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    levers = {
        "compute": "cut remat recompute / FLOP-optimal attention",
        "memory": "shrink resident set: smaller chunks, quantized caches",
        "collective": "resharding (pure-DP for small models), saved "
                      "collective outputs, int8 wire",
    }
    for f in sorted(glob.glob("experiments/dryrun/*__16x16.json")):
        r = json.load(open(f))
        if r.get("status") != "ok" or "hlo_flops_per_device" not in r:
            continue
        shp = SHAPES[r["shape"]]
        t = analyse(r, shp.seq_len, shp.global_batch)
        lines.append(
            f"| {t.arch} | {t.shape} | {t.compute_s:.3e} | "
            f"{t.memory_floor_s:.3e} | {t.memory_hlo_s:.3e} | "
            f"{t.collective_s:.3e} | {t.dominant} | {t.useful_ratio:.3f} | "
            f"{t.roofline_fraction:.3f} | {levers[t.dominant]} |"
        )
    return "\n".join(lines)


def splice(text: str, marker: str, content: str) -> str:
    pattern = re.compile(
        rf"<!-- {marker} -->.*?(?=\n## |\Z)", re.DOTALL
    )
    replacement = f"<!-- {marker} -->\n{content}\n"
    if pattern.search(text):
        return pattern.sub(lambda _: replacement, text)
    return text + f"\n{replacement}"


def main() -> None:
    path = "EXPERIMENTS.md"
    text = open(path).read()
    text = splice(text, "DRYRUN_TABLE", dryrun_table())
    text = splice(text, "ROOFLINE", roofline_section())
    open(path, "w").write(text)
    print("EXPERIMENTS.md sections regenerated")


if __name__ == "__main__":
    main()
