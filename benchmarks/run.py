"""Benchmark harness — one module per paper table/figure plus the
beyond-paper traffic and roofline reports.  Prints ``name,us_per_call,
derived`` CSV (the harness contract).

  table1_bt        -> paper Table I   (BT per flit, 4 orderings, 2 data models)
  fig5_area        -> paper Fig. 5    (area breakdown, 4 designs, 2 sizes)
  fig7_power       -> paper Fig. 6/7  (link-related + PE power reductions)
  lenet_workload   -> paper §IV-B     (conv+pool platform, PSU in the loop)
  arch_bt          -> paper §V future work (transformer traffic BT)
  noc_bt           -> §V NoC fabric   (per-link BT across topologies/hops)
  dse_sweep        -> design-space Pareto fronts (area x BT x latency)
  codec_bt         -> ordering vs coding vs composed (repro.codec tables)
  kernel_bench     -> kernel microbenchmarks (per-backend wall rows)
  roofline_report  -> deliverable (g) tables from the dry-run records
  model_traffic    -> captured real-model streams: per-scenario BT/power
                      campaign + trained-weight recalibration (§16)
  fleet_noc        -> fleet-scale serving fabric (§17): batched expansion
                      vs legacy loop, one-launch pin, BT + contention
                      latency on a 16x16 mesh of multi-tenant decode flows

Usage: ``python -m benchmarks.run [--json] [--trace] [--activity]
[module ...]`` runs
the named modules in registry order (no names = all); ``--list`` prints
the valid names.  Set REPRO_BENCH_TINY=1 to run each module at its
smoke-test shape (a module's optional ``TINY_KWARGS`` dict) — the CI
benchmark smoke step.

``--json`` additionally writes one ``BENCH_<module>.json`` per module run
to the current directory: the CSV rows plus the resolved kernel backend
(DESIGN.md §13), the jax platform, the run kwargs (the shapes), the
module wall time, and run provenance (git SHA, ISO timestamp, jax
version).  CI uploads these as the persistent wall-clock trajectory and
``benchmarks.check_bench`` gates on them against the committed baseline
under ``benchmarks/trajectory/``.

``--trace`` activates ``repro.obs`` around each module and writes a
Chrome/Perfetto-loadable ``TRACE_<module>.json`` next to the bench JSON:
one top-level ``bench.module`` span per run with every probe span
(kernel dispatches, link stages, NoC/DSE launches) nested inside by
timestamp, plus the trace's span coverage of the module wall time in its
``metadata``.  Load it at https://ui.perfetto.dev or chrome://tracing.

``--activity`` (or REPRO_BENCH_ACTIVITY=1) turns on wire-level
switching-activity measurement in the modules that support it
(``noc_bt``, ``codec_bt``, DESIGN.md §15): hottest-wire report rows plus
an ``ACTIVITY_<module>.saif`` (standard backward SAIF for EDA power
flows) and ``ACTIVITY_<module>_wires.csv`` per-wire heatmap next to the
bench JSON.  CI's bench-smoke step uploads both with the trajectory.
"""

from __future__ import annotations

import datetime
import importlib
import json
import os
import subprocess
import sys
import time

# The registry: ``--list`` order, run order, and the set of JSON artifacts
# ``benchmarks.check_bench`` requires.
MODULES = (
    "table1_bt",
    "fig5_area",
    "fig7_power",
    "lenet_workload",
    "arch_bt",
    "noc_bt",
    "dse_sweep",
    "codec_bt",
    "kernel_bench",
    "roofline_report",
    "model_traffic",
    "fleet_noc",
)


def _write_json(name: str, payload: dict) -> None:
    with open(f"BENCH_{name}.json", "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")


def _git_sha() -> str:
    """The repo HEAD the numbers were measured at ('unknown' off-git)."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__)),
        )
        if out.returncode == 0 and out.stdout.strip():
            return out.stdout.strip()
    except (OSError, subprocess.SubprocessError):
        pass
    return "unknown"


def main() -> None:
    args = sys.argv[1:]
    emit_json = "--json" in args
    emit_trace = "--trace" in args
    if "--activity" in args:
        # modules read the env (same pattern as REPRO_BENCH_TINY), so the
        # flag and the variable are interchangeable
        os.environ["REPRO_BENCH_ACTIVITY"] = "1"
    args = [a for a in args if a not in ("--json", "--trace", "--activity")]
    if "--list" in args:
        for name in MODULES:
            print(name)
        return
    names = dict.fromkeys(args)  # dedup, keep request order for the error
    unknown = [a for a in names if a not in MODULES]
    if unknown:
        listed = ", ".join(repr(a) for a in unknown)
        raise SystemExit(
            f"unknown benchmark module{'s' if len(unknown) > 1 else ''} "
            f"{listed}; valid names: {', '.join(MODULES)}"
        )

    import jax

    from repro.kernels import default_backend

    tiny = os.environ.get("REPRO_BENCH_TINY", "") not in ("", "0")
    git_sha = _git_sha()
    print("name,us_per_call,derived")
    failures = 0
    for name in MODULES:
        if names and name not in names:
            continue
        mod = importlib.import_module(f".{name}", __package__)
        kwargs = getattr(mod, "TINY_KWARGS", {}) if tiny else {}
        meta = {
            "module": name,
            "backend": default_backend(),
            "platform": jax.default_backend(),
            "tiny": tiny,
            "kwargs": kwargs,
            "git_sha": git_sha,
            "timestamp": datetime.datetime.now(
                datetime.timezone.utc
            ).isoformat(timespec="seconds"),
            "jax_version": jax.__version__,
        }
        tracer = None
        t0 = time.monotonic()
        try:
            if emit_trace:
                from repro import _obs_hooks, obs

                tracer = obs.Tracer(process_name=f"bench.{name}")
                with obs.tracing(tracer), obs.collect():
                    with _obs_hooks.span("bench.module", module=name):
                        rows = mod.run(**kwargs)
            else:
                rows = mod.run(**kwargs)
        except Exception as e:  # keep the harness running; report the failure
            msg = f"FAILED: {type(e).__name__}: {e}"
            print(f"{name},0,{msg}")
            failures += 1
            if emit_json:
                _write_json(name, {
                    **meta,
                    "wall_s": round(time.monotonic() - t0, 3),
                    "failed": msg,
                    "rows": [],
                })
            continue
        dt = time.monotonic() - t0
        for rname, us, derived in rows:
            print(f'{rname},{us:.2f},"{derived}"')
        if emit_json:
            _write_json(name, {
                **meta,
                "wall_s": round(dt, 3),
                "rows": [
                    {"name": r, "us_per_call": round(us, 2), "derived": d}
                    for r, us, d in rows
                ],
            })
        if tracer is not None:
            # the bench.module span wraps the whole run, so its duration
            # over the module wall time is the trace's span coverage (the
            # DESIGN.md §14 >=95% target; the remainder is harness I/O)
            coverage = min(
                1.0, tracer.span_seconds("bench.module") / max(dt, 1e-9)
            )
            tracer.write(f"TRACE_{name}.json", metadata={
                **meta,
                "wall_s": round(dt, 3),
                "span_coverage": round(coverage, 4),
            })
        print(f"# {name} done in {dt:.1f}s", file=sys.stderr)
    if failures:
        raise SystemExit(f"{failures} benchmark modules failed")


if __name__ == "__main__":
    main()
