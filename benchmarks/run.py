"""Benchmark harness — one module per paper table/figure plus the
beyond-paper traffic and roofline reports.  Prints ``name,us_per_call,
derived`` CSV (the harness contract).

  table1_bt        -> paper Table I   (BT per flit, 4 orderings, 2 data models)
  fig5_area        -> paper Fig. 5    (area breakdown, 4 designs, 2 sizes)
  fig7_power       -> paper Fig. 6/7  (link-related + PE power reductions)
  lenet_workload   -> paper §IV-B     (conv+pool platform, PSU in the loop)
  arch_bt          -> paper §V future work (transformer traffic BT)
  noc_bt           -> §V NoC fabric   (per-link BT across topologies/hops)
  dse_sweep        -> design-space Pareto fronts (area x BT x latency)
  codec_bt         -> ordering vs coding vs composed (repro.codec tables)
  kernel_bench     -> kernel microbenchmarks (per-backend wall rows)
  roofline_report  -> deliverable (g) tables from the dry-run records

Usage: ``python -m benchmarks.run [--json] [module ...]`` runs the named
modules in registry order (no names = all); ``--list`` prints the valid
names.  Set REPRO_BENCH_TINY=1 to run each module at its smoke-test shape
(a module's optional ``TINY_KWARGS`` dict) — the CI benchmark smoke step.

``--json`` additionally writes one ``BENCH_<module>.json`` per module run
to the current directory: the CSV rows plus the resolved kernel backend
(DESIGN.md §13), the jax platform, the run kwargs (the shapes) and the
module wall time.  CI uploads these as the persistent wall-clock
trajectory and ``benchmarks.check_bench`` gates on them.
"""

from __future__ import annotations

import importlib
import json
import os
import sys
import time

# The registry: ``--list`` order, run order, and the set of JSON artifacts
# ``benchmarks.check_bench`` requires.
MODULES = (
    "table1_bt",
    "fig5_area",
    "fig7_power",
    "lenet_workload",
    "arch_bt",
    "noc_bt",
    "dse_sweep",
    "codec_bt",
    "kernel_bench",
    "roofline_report",
)


def _write_json(name: str, payload: dict) -> None:
    with open(f"BENCH_{name}.json", "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")


def main() -> None:
    args = sys.argv[1:]
    emit_json = "--json" in args
    args = [a for a in args if a != "--json"]
    if "--list" in args:
        for name in MODULES:
            print(name)
        return
    names = dict.fromkeys(args)  # dedup, keep request order for the error
    unknown = [a for a in names if a not in MODULES]
    if unknown:
        listed = ", ".join(repr(a) for a in unknown)
        raise SystemExit(
            f"unknown benchmark module{'s' if len(unknown) > 1 else ''} "
            f"{listed}; valid names: {', '.join(MODULES)}"
        )

    import jax

    from repro.kernels import default_backend

    tiny = os.environ.get("REPRO_BENCH_TINY", "") not in ("", "0")
    print("name,us_per_call,derived")
    failures = 0
    for name in MODULES:
        if names and name not in names:
            continue
        mod = importlib.import_module(f".{name}", __package__)
        kwargs = getattr(mod, "TINY_KWARGS", {}) if tiny else {}
        meta = {
            "module": name,
            "backend": default_backend(),
            "platform": jax.default_backend(),
            "tiny": tiny,
            "kwargs": kwargs,
        }
        t0 = time.monotonic()
        try:
            rows = mod.run(**kwargs)
        except Exception as e:  # keep the harness running; report the failure
            msg = f"FAILED: {type(e).__name__}: {e}"
            print(f"{name},0,{msg}")
            failures += 1
            if emit_json:
                _write_json(name, {
                    **meta,
                    "wall_s": round(time.monotonic() - t0, 3),
                    "failed": msg,
                    "rows": [],
                })
            continue
        dt = time.monotonic() - t0
        for rname, us, derived in rows:
            print(f'{rname},{us:.2f},"{derived}"')
        if emit_json:
            _write_json(name, {
                **meta,
                "wall_s": round(dt, 3),
                "rows": [
                    {"name": r, "us_per_call": round(us, 2), "derived": d}
                    for r, us, d in rows
                ],
            })
        print(f"# {name} done in {dt:.1f}s", file=sys.stderr)
    if failures:
        raise SystemExit(f"{failures} benchmark modules failed")


if __name__ == "__main__":
    main()
