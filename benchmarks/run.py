"""Benchmark harness — one module per paper table/figure plus the
beyond-paper traffic and roofline reports.  Prints ``name,us_per_call,
derived`` CSV (the harness contract).

  table1_bt        -> paper Table I   (BT per flit, 4 orderings, 2 data models)
  fig5_area        -> paper Fig. 5    (area breakdown, 4 designs, 2 sizes)
  fig7_power       -> paper Fig. 6/7  (link-related + PE power reductions)
  lenet_workload   -> paper §IV-B     (conv+pool platform, PSU in the loop)
  arch_bt          -> paper §V future work (transformer traffic BT)
  noc_bt           -> §V NoC fabric   (per-link BT across topologies/hops)
  dse_sweep        -> design-space Pareto fronts (area x BT x latency)
  codec_bt         -> ordering vs coding vs composed (repro.codec tables)
  kernel_bench     -> Pallas kernel microbenchmarks
  roofline_report  -> deliverable (g) tables from the dry-run records

Usage: ``python -m benchmarks.run [module ...]`` runs the named modules in
registry order (no names = all); ``--list`` prints the valid names.  Set
REPRO_BENCH_TINY=1 to run each module at its smoke-test shape (a module's
optional ``TINY_KWARGS`` dict) — the CI benchmark smoke step.
"""

from __future__ import annotations

import os
import sys
import time


def main() -> None:
    from . import (
        arch_bt,
        codec_bt,
        dse_sweep,
        fig5_area,
        fig7_power,
        kernel_bench,
        lenet_workload,
        noc_bt,
        roofline_report,
        table1_bt,
    )

    mods = [
        ("table1_bt", table1_bt),
        ("fig5_area", fig5_area),
        ("fig7_power", fig7_power),
        ("lenet_workload", lenet_workload),
        ("arch_bt", arch_bt),
        ("noc_bt", noc_bt),
        ("dse_sweep", dse_sweep),
        ("codec_bt", codec_bt),
        ("kernel_bench", kernel_bench),
        ("roofline_report", roofline_report),
    ]
    args = sys.argv[1:]
    if "--list" in args:
        for name, _ in mods:
            print(name)
        return
    valid = ", ".join(name for name, _ in mods)
    names = dict.fromkeys(args)  # dedup, keep request order for the error
    unknown = [a for a in names if a not in dict(mods)]
    if unknown:
        listed = ", ".join(repr(a) for a in unknown)
        raise SystemExit(
            f"unknown benchmark module{'s' if len(unknown) > 1 else ''} "
            f"{listed}; valid names: {valid}"
        )
    tiny = os.environ.get("REPRO_BENCH_TINY", "") not in ("", "0")
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in mods:
        if names and name not in names:
            continue
        t0 = time.monotonic()
        try:
            kwargs = getattr(mod, "TINY_KWARGS", {}) if tiny else {}
            rows = mod.run(**kwargs)
        except Exception as e:  # keep the harness running; report the failure
            print(f"{name},0,FAILED: {type(e).__name__}: {e}")
            failures += 1
            continue
        for rname, us, derived in rows:
            print(f'{rname},{us:.2f},"{derived}"')
        print(f"# {name} done in {time.monotonic() - t0:.1f}s", file=sys.stderr)
    if failures:
        raise SystemExit(f"{failures} benchmark modules failed")


if __name__ == "__main__":
    main()
