"""DSE sweep: the paper's two-point comparison as full Pareto fronts.

The paper evaluates ACC vs APP k=4 only; this bench maps the design space
with `repro.dse` on the measured conv streams:

  * **grid** — unsorted / column-major baselines, APP per bucket count,
    precise ACC, plus the Fig. 5 comparator families (bitonic, CSN) at both
    paper sort widths, each joined across area / timing / BT / link power;
  * **fronts** — the 3-objective (area x BT-reduction x latency) front and
    the paper's area x BT plane, whose measured knee is the paper's own
    k=4 choice;
  * **fused vs per-config** — the grid's stream measurements come from
    ONE `bt_count_variants` launch (the variant axis lives inside the
    launch) where the per-config baseline pays one `psu_stream`/`bt_count`
    launch per configuration.  Launch counts are read from the traced
    jaxpr, not asserted by hand; wall time is reported for reference only
    (same caveat as `kernel_bench` / `noc_bt`: launches are the claim);
  * **full multi-axis grid** — a grid mixing a NoC topology and a wire
    codec still traces to ONE `bt_count_axes` launch (DESIGN.md §12):
    every workload stream, every mesh route link and every (ordering,
    codec) config are axes of the same launch
    (`repro.dse.grid_launch_count` reads it from the jaxpr; the per-point
    path pays one chain per point x link);
  * **NoC point** — one APP k=4 design evaluated per link on a 4x4 mesh
    (the route links ride the same launch);
  * **artifact** — `repro.dse.report` writes the machine-readable JSON
    front (`REPRO_DSE_ARTIFACT` overrides the path) for the bench
    trajectory; CI uploads it with the smoke CSV.

Paper reference points ride along in the derived strings (Table I / Fig. 5
/ abstract): APP k=4 = 35.4 % area reduction at 19.50 % overall BT
reduction (20.42 % precise).  The conv-traffic model reproduces the paper's
input-side reductions (the stream the PSU actually orders, table1_bt's
calibration target); the weight stream cycles the layer's output-channel
kernels (DESIGN.md §10's recalibration: overall ACC 14.2 % / APP 12.7 %
measured vs the paper's 20.42 % / 19.50 % — the residual gap is the
synthetic kernels' near-uniform byte distribution) — reported side by
side, as in fig7, never substituted.
"""

from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.dse import (
    AREA_BT_OBJECTIVES,
    DesignPoint,
    Workload,
    evaluate_grid,
    grid_launch_count,
    k_sweep,
    knee_point,
    pareto_front,
    write_json,
)
from repro.kernels import bt_count, bt_count_variants, psu_stream
from repro.link import make_order

from .datagen import conv_streams
from .kernel_bench import count_pallas_launches

PAPER = {"app_area_red": 35.4, "app_bt_red": 19.50, "acc_bt_red": 20.42}

TINY_KWARGS = {"conv_images": 1, "ks": (2, 4), "ns": (25,)}

_LANES = 16


def _grid(ks: tuple[int, ...], ns: tuple[int, ...]) -> tuple[DesignPoint, ...]:
    points: list[DesignPoint] = []
    for n in ns:
        points.extend(k_sweep(n=n, width=8, ks=ks))
        points.append(DesignPoint(n=n, width=8, k=None, ordering="column_major"))
        points.append(DesignPoint(family="bitonic", n=n, width=8, k=None,
                                  ordering="acc"))
        points.append(DesignPoint(family="csn", n=n, width=8, k=None,
                                  ordering="acc"))
    return tuple(points)


def _staged_bt(stream: jax.Array, variant) -> jax.Array:
    """Per-config baseline for unsorted/layout keys: order on the host,
    lane-pack, one bt_count launch (the pre-DSE measurement path)."""
    p, n = stream.shape
    flits = n // _LANES
    order = make_order(
        variant.key, stream, lanes=_LANES, width=8, k=variant.k or 4,
        descending=variant.descending,
    )
    xs = jnp.take_along_axis(stream.astype(jnp.int32), order, axis=-1)
    packed = xs.reshape(p, _LANES, flits).transpose(0, 2, 1)
    return bt_count(packed.reshape(p * flits, _LANES))


def run(
    conv_images: int = 8,
    ks: tuple[int, ...] = (2, 4, 8),
    ns: tuple[int, ...] = (25, 49),
) -> list[tuple[str, float, str]]:
    rows: list[tuple[str, float, str]] = []
    inp, wgt = conv_streams(n_images=conv_images)
    workload = Workload(
        "conv", (jnp.asarray(inp), jnp.asarray(wgt)), lanes=_LANES
    )
    points = _grid(tuple(ks), tuple(ns))

    # --- evaluate the whole grid (one variant launch per stream),
    # collecting the repro.obs dse.* / kernel.* telemetry alongside ---
    t0 = time.monotonic()
    with obs.collect() as reg:
        evals = evaluate_grid(points, workload)
    us = (time.monotonic() - t0) * 1e6
    front = pareto_front(evals)
    for e in evals:
        rows.append((
            f"dse/{e.label}",
            us / len(evals),
            f"area={e.area_um2:.0f}um2 area_red={e.area_reduction * 100:.1f}% "
            f"bt_red={e.bt_reduction * 100:.2f}% lat={e.latency_ns:.0f}ns "
            f"front={int(e in front)}",
        ))

    # --- obs telemetry: per-link baseline BT + launch accounting ---
    for s in reg.series("dse.link.bt"):
        lab = dict(s.labels)
        packets = reg.value("dse.link.packets", **lab)
        rows.append((
            f"dse/obs/link/{lab['link']}/w{lab['width']}", 0.0,
            f"baseline_bt={int(s.value)} packets={int(packets)}",
        ))
    n_points = sum(int(s.value) for s in reg.series("dse.points"))
    dispatches = sum(
        int(s.value) for s in reg.series("kernel.dispatch.calls")
    )
    launches = sum(
        int(s.value) for s in reg.series("kernel.pallas_launches")
    )
    rows.append((
        "dse/obs/points", 0.0,
        f"{n_points} design points measured by {dispatches} kernel "
        f"dispatch(es) ({launches} pallas launches) — the grid collapse, "
        f"read from live telemetry",
    ))

    # --- the paper's area x BT plane: front + knee ---
    n0 = ns[0]
    plane = [e for e in evals if e.point.n == n0]
    plane_front = pareto_front(plane, AREA_BT_OBJECTIVES)
    knee = knee_point(plane_front, AREA_BT_OBJECTIVES)
    app4 = next(
        (e for e in plane
         if e.point.ordering == "app" and e.point.k == 4), None,
    )
    rows.append((
        f"dse/front/N{n0}", 0.0,
        f"area_x_bt front: {'|'.join(e.label for e in plane_front)} "
        f"knee={knee.label} (paper picks k=4: "
        f"{PAPER['app_area_red']}% area red at {PAPER['app_bt_red']}% BT red)",
    ))
    if app4 is not None:
        rows.append((
            f"dse/paper_point/N{n0}", 0.0,
            f"app-k4 area_red={app4.area_reduction * 100:.1f}% "
            f"(paper {PAPER['app_area_red']}%) "
            f"bt_red={app4.bt_reduction * 100:.2f}% "
            f"(paper overall {PAPER['app_bt_red']}%; multi-channel weight "
            f"model, DESIGN.md §10 recalibration) on_front={int(app4 in front)}",
        ))

    # --- fused vs per-config: 1 launch vs |grid| (traced jaxpr) ---
    variants = tuple(dict.fromkeys(e.point.variant for e in plane))
    x = workload.streams[0]

    def fused(stream):
        return bt_count_variants(stream, variants=variants, input_lanes=_LANES)

    def per_config(stream):
        outs = []
        for v in variants:
            if v.key in ("acc", "app"):
                res = psu_stream(
                    stream, None, width=8, k=v.k, descending=v.descending,
                    input_lanes=_LANES, weight_lanes=0,
                )
                outs.append(res.bt_input)
            else:
                outs.append(_staged_bt(stream, v))
        return jnp.stack(outs)

    np.testing.assert_array_equal(
        np.asarray(fused(x))[:, 0], np.asarray(per_config(x))
    )  # bit-exact paths
    launches = {
        "fused": count_pallas_launches(fused, x),
        "per_config": count_pallas_launches(per_config, x),
    }
    for name, fn in (("fused", fused), ("per_config", per_config)):
        jax.block_until_ready(fn(x))  # compile/warm
        t0 = time.monotonic()
        for _ in range(3):
            jax.block_until_ready(fn(x))
        us = (time.monotonic() - t0) / 3 * 1e6
        rows.append((
            f"dse/variant_bt/{name}",
            us,
            f"configs={len(variants)} pallas_launches={launches[name]}",
        ))

    # --- one NoC design point: per-link evaluation on a 4x4 mesh ---
    noc_pt = DesignPoint(ordering="app", k=4, topology="mesh4x4")
    noc_workload = Workload("conv", (workload.streams[0],), lanes=_LANES)
    noc_eval = evaluate_grid((noc_pt,), noc_workload)[0]
    rows.append((
        f"dse/{noc_eval.label}", 0.0,
        f"fabric bt_red={noc_eval.noc_bt_reduction * 100:.2f}% over "
        f"{noc_eval.noc_active_links} links (source-sorted, route links "
        f"ride the grid launch)",
    ))

    # --- the FULL multi-axis grid (streams + NoC links + codec axis)
    # still traces to ONE pallas launch (DESIGN.md §12) ---
    axis_pts = tuple(k_sweep(n=n0, width=8, ks=tuple(ks))) + (
        DesignPoint(n=n0, ordering="acc", k=None, codec="bus_invert4"),
        DesignPoint(n=n0, ordering="app", k=4, topology="mesh4x4"),
    )
    grid_launches = grid_launch_count(axis_pts, workload)
    n_links = len(workload.streams) + (noc_eval.noc_active_links or 0)
    rows.append((
        "dse/grid_launches", 0.0,
        f"{len(axis_pts)} points over {n_links} links (streams + mesh4x4 "
        f"route, identical route queues deduped) x orderings x codecs -> "
        f"{grid_launches} pallas launch(es) in the traced jaxpr (per-point "
        f"path: one sort/codec/BT chain per point x link)",
    ))

    # --- machine-readable artifact for the bench trajectory ---
    # top-level front/knee/objectives all describe the SAME 3-objective
    # full-grid analysis (a consumer can recompute them from `points`);
    # the paper's area x BT plane at N=ns[0] rides in `meta`
    path = os.environ.get("REPRO_DSE_ARTIFACT", "dse_front.json")
    write_json(
        path, evals, front=front, knee=knee_point(front),
        workload=workload.name,
        meta={
            "conv_images": conv_images,
            "paper": PAPER,
            "launches": launches,
            "area_bt_plane_n": n0,
            "area_bt_front": [e.label for e in plane_front],
            "area_bt_knee": knee.label,
        },
    )
    rows.append((
        "dse/artifact", 0.0,
        f"front JSON -> {path} ({len(front)} of {len(evals)} points on the "
        f"3-objective front)",
    ))
    return rows
