"""Roofline report (deliverable g): reads the dry-run JSON records and
derives the three-term roofline per (arch x shape) on the single-pod mesh.

Writes ``experiments/roofline.md`` and returns summary rows for run.py.
"""

from __future__ import annotations

import glob
import json
import os

from repro.configs.shapes import SHAPES
from repro.roofline import PEAK_FLOPS, analyse

WHAT_MOVES_IT = {
    "compute": "reduce HLO FLOPs: less remat recompute, FLOP-optimal causal "
               "attention (chunked_skip), gather-based MoE dispatch",
    "memory": "fuse/chunk the big intermediates (logits chunking, smaller "
              "attention chunks), bf16 caches, better layouts",
    "collective": "shrink wire bytes: avoid remat-recomputed collectives, "
                  "compress gradients (int8-EF), overlap via async collectives",
}


def load_records(dirpath: str = "experiments/dryrun"):
    recs = []
    for f in sorted(glob.glob(os.path.join(dirpath, "*__16x16.json"))):
        r = json.load(open(f))
        if r.get("status") == "ok" and "hlo_flops_per_device" in r:
            recs.append(r)
    return recs


def run() -> list[tuple[str, float, str]]:
    recs = load_records()
    rows = []
    lines = [
        "# Roofline — single-pod 16x16 (256 x v5e: 197 TFLOP/s bf16, "
        "819 GB/s HBM, 50 GB/s ICI)",
        "",
        "| arch | shape | kind | compute s | mem floor s | mem hlo s | "
        "collective s | dominant | MODEL_FLOPs/dev | useful ratio | "
        "roofline frac |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        shp = SHAPES[r["shape"]]
        t = analyse(r, shp.seq_len, shp.global_batch)
        lines.append(
            f"| {t.arch} | {t.shape} | {t.kind} | {t.compute_s:.3e} | "
            f"{t.memory_floor_s:.3e} | {t.memory_hlo_s:.3e} | "
            f"{t.collective_s:.3e} | {t.dominant} | "
            f"{t.model_flops_per_device:.3e} | {t.useful_ratio:.3f} | "
            f"{t.roofline_fraction:.3f} |"
        )
        rows.append((
            f"roofline/{t.arch}/{t.shape}", 0.0,
            f"dom={t.dominant} frac={t.roofline_fraction:.3f} "
            f"useful={t.useful_ratio:.3f}",
        ))
    lines += [
        "",
        "Per-term improvement levers:",
        *[f"- **{k}**: {v}" for k, v in WHAT_MOVES_IT.items()],
        "",
        "Caveats: `memory s` uses XLA bytes-accessed from the CPU-backend "
        "compile — an upper bound (CPU fuses less than TPU).  `useful ratio` "
        "= MODEL_FLOPS / HLO_FLOPs exposes remat + dispatch overhead.",
    ]
    os.makedirs("experiments", exist_ok=True)
    with open("experiments/roofline.md", "w") as f:
        f.write("\n".join(lines) + "\n")
    if not rows:
        rows.append(("roofline/no_records", 0.0,
                     "run: python -m repro.launch.dryrun --all --out experiments/dryrun"))
    return rows
