"""Real-model traffic campaign: captured streams through the BT stack.

Every other bench module measures synthetic streams
(``benchmarks/datagen.py``).  This one drives the model zoo itself under
``repro.obs.capture`` (DESIGN.md §16) and profiles the *captured* int8
traffic — four real scenarios:

  * **lenet_conv**       — a LeNet trained in-repo (``repro.models.lenet``,
    checkpointed via ``repro.checkpoint`` so CI restores instead of
    retraining): trained conv kernels + task inputs, the honest version of
    the paper's Table-I conv setup.
  * **serve_decode**     — ``serve.generate`` on a smoke transformer: the
    multicast decode weight stream plus per-token KV bytes.
  * **train_allreduce**  — one eager train step: the gradient tree, i.e.
    the ring all-reduce payload.
  * **moe_dispatch**     — one eager MoE block: the dispatched expert
    capacity buffers (the ICI all-to-all leg).

Each scenario's captured workload runs through ``dse.evaluate_grid``
(baseline / ACC / APP k=4 / APP+bus-invert composed, wire-resolved) and
through ``noc.simulate`` on a fabric via the matching ``noc.adapters``
flow builder, with per-link telemetry collected by ``repro.obs``.  The
campaign lands as ``SCENARIOS_model_traffic.csv`` / ``.json`` artifacts
(``repro.obs.report.scenario_table``) next to the bench JSON, and the
trained-weight recalibration rows report captured overall reductions SIDE
BY SIDE with the §10 synthetic numbers and the paper's — never
substituted.
"""

from __future__ import annotations

import os
import time
from contextlib import nullcontext

import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.dse import DesignPoint, evaluate_grid
from repro.link import LinkSpec
from repro.noc import (
    conv_platform_flows,
    decode_weight_flows,
    mesh,
    moe_dispatch_flows,
    ring,
    ring_allreduce_flows,
    simulate_noc,
)

from .datagen import im2col, synth_images
from .table1_bt import _input_only_spec, _measure_separate

TINY_KWARGS = {"lenet_steps": 40, "new_tokens": 2, "seq": 16}

# the §10 calibration state this campaign recalibrates (percent overall
# reduction on the synthetic conv streams; benchmarks/table1_bt.py) and
# the paper's reported numbers — always shown side by side
SYNTHETIC_OVERALL = {"acc": 14.21, "app": 12.66}
PAPER_OVERALL = {"acc": 20.42, "app": 19.50}

# smoke-config archetypes behind the serve/train/moe scenarios
SERVE_ARCH = "qwen3-4b"
TRAIN_ARCH = "internlm2-1.8b"
MOE_ARCH = "qwen3-moe-30b-a3b"

ELEMS = 64  # 4 flits x 16 input lanes per measured packet
LANES = 16

# the campaign's design points, in report order
_POINTS = (
    DesignPoint(ordering="none", k=None),
    DesignPoint(ordering="acc", k=None),
    DesignPoint(ordering="app", k=4),
    DesignPoint(ordering="app", k=4, codec="bus_invert"),
)


def _evaluate(sess: obs.CaptureSession, scenario: str, windows: int):
    wl = sess.workload(scenario, elems=ELEMS, lanes=LANES)
    return wl, evaluate_grid(_POINTS, wl, activity_windows=windows)


def _record(scenario, sess, evals, noc_red=None, hot_link=None):
    """One obs.report scenario record from the campaign measurements."""
    base, acc, app, comp = evals
    streams = sess.get(scenario)
    rec = {
        "scenario": scenario,
        "streams": len(streams),
        "num_bytes": sum(s.num_bytes for s in streams),
        "num_flits": base.num_flits,
        "bt_base": base.total_bt,
        "red_acc": acc.bt_reduction,
        "red_app": app.bt_reduction,
        "red_composed": comp.bt_reduction,
        "energy_base_pj": base.energy_pj,
        "energy_app_pj": app.energy_pj,
    }
    if app.hot_wire is not None:
        rec["hot_wire"] = obs.wire_name(app.hot_wire, LANES)
    if noc_red is not None:
        rec["noc_red_acc"] = noc_red
    if hot_link is not None:
        rec["hot_link"] = (
            f"{hot_link['src']}->{hot_link['dst']}"
        )
    return rec


def _noc_run(topo, flows, spec):
    """(acc-vs-none fabric reduction, hottest link record) of one flow set."""
    import dataclasses

    base = simulate_noc(
        topo, flows, dataclasses.replace(spec, key="none"), sort_at="source"
    )
    with obs.collect() as reg:
        rep = simulate_noc(
            topo, flows, dataclasses.replace(spec, key="acc"),
            sort_at="source",
        )
    top = obs.top_links(reg, 1)
    return rep.reduction_vs(base), (top[0] if top else None), rep


def run(
    lenet_steps: int = 300,
    batch: int = 2,
    prompt: int = 8,
    new_tokens: int = 4,
    seq: int = 32,
    activity_windows: int = 32,
) -> list[tuple[str, float, str]]:
    from repro.configs import smoke_config
    from repro.models import lenet

    rows = []
    records = []
    io_spec = _input_only_spec("none", ELEMS, LANES)

    # ---- lenet_conv: train (or restore) the real model, capture, measure
    ckpt_dir = os.environ.get("REPRO_LENET_CKPT", ".lenet_ckpt")
    t0 = time.monotonic()
    params, info = lenet.train_lenet(steps=lenet_steps, ckpt_dir=ckpt_dir)
    rows.append((
        "model/lenet/train",
        (time.monotonic() - t0) * 1e6,
        f"steps={info['steps']} final_loss={info['final_loss']:.4f} "
        f"restored={int(info['restored'])} ckpt={ckpt_dir}",
    ))
    sessions = {"lenet_conv": obs.capture_lenet_conv(params=params)}

    # ---- serve_decode / train_allreduce / moe_dispatch: eager captures
    t0 = time.monotonic()
    sessions["serve_decode"] = obs.capture_serve_decode(
        smoke_config(SERVE_ARCH), batch=batch, prompt=prompt,
        new_tokens=new_tokens,
    )
    sessions["train_allreduce"] = obs.capture_train_step(
        smoke_config(TRAIN_ARCH), batch=batch, seq=seq
    )
    sessions["moe_dispatch"] = obs.capture_moe_dispatch(
        smoke_config(MOE_ARCH), batch=batch, seq=seq
    )
    capture_us = (time.monotonic() - t0) * 1e6
    rows.append((
        "model/capture",
        capture_us,
        "scenarios=4 streams="
        + " ".join(
            f"{k}:{len(s.streams)}" for k, s in sorted(sessions.items())
        ),
    ))

    # ---- per-scenario NoC runs on captured bytes (adapters + telemetry)
    noc_results = {}
    m44, r8 = mesh(4, 4), ring(8)

    w = sessions["serve_decode"].scenario_bytes("serve_decode", ["weights"])
    noc_results["serve_decode"] = _noc_run(
        m44,
        decode_weight_flows(
            jnp.asarray(w.view(np.int8)), m44, 0, (1, 2, 3), io_spec
        ),
        io_spec,
    )

    g = sessions["train_allreduce"].scenario_bytes("train_allreduce")
    noc_results["train_allreduce"] = _noc_run(
        r8, ring_allreduce_flows(jnp.asarray(g.view(np.int8)), r8, spec=io_spec),
        io_spec,
    )

    moe_stream = sessions["moe_dispatch"].get("moe_dispatch", "expert_in")[0]
    expert_in = jnp.asarray(
        moe_stream.data.view(np.int8).reshape(moe_stream.source_shape)
    )
    noc_results["moe_dispatch"] = _noc_run(
        m44,
        moe_dispatch_flows(
            expert_in, m44, 0, tuple(range(1, 16)), io_spec
        ),
        io_spec,
    )

    # conv platform: REAL trained kernel bytes on the weight lanes, im2col
    # patches of the task images on the input lanes (paper §IV-B framing)
    kernel = sessions["lenet_conv"].scenario_bytes("lenet_conv", ["conv1"])
    patches = jnp.asarray(im2col(synth_images(1, seed=7)[0], 5))
    noc_results["lenet_conv"] = _noc_run(
        m44,
        conv_platform_flows(
            patches, jnp.asarray(kernel), m44, 0,
            [r for r in range(16) if r % 4], LinkSpec(),
        ),
        LinkSpec(),
    )

    # ---- per-scenario DSE grid over the captured workloads
    for scenario in sorted(sessions):
        sess = sessions[scenario]
        t0 = time.monotonic()
        wl, evals = _evaluate(sess, scenario, activity_windows)
        us = (time.monotonic() - t0) * 1e6
        noc_red, hot_link, _ = noc_results[scenario]
        records.append(
            _record(scenario, sess, evals, float(noc_red), hot_link)
        )
        base, acc, app, comp = evals
        rows.append((
            f"model/{scenario}/bt",
            us,
            f"streams={len(wl.streams)} flits={wl.num_flits} "
            f"bt_base={base.total_bt} red_acc={100 * acc.bt_reduction:.2f}% "
            f"red_app={100 * app.bt_reduction:.2f}% "
            f"red_composed={100 * comp.bt_reduction:.2f}% "
            f"E_app={app.energy_pj / 1e3:.1f}nJ",
        ))
        rows.append((
            f"model/{scenario}/noc",
            0.0,
            f"fabric_red_acc={100 * noc_red:.2f}% hot_link="
            + (
                f"{hot_link['src']}->{hot_link['dst']} "
                f"gross_bt={hot_link['gross_bt']}"
                if hot_link else "-"
            ),
        ))

    # ---- recalibration: trained-weight overall reductions, side by side
    # with the §10 synthetic numbers (table1_bt separate-stream framing:
    # captured task inputs on one link, captured trained weights on the
    # other; overall = 1 - (bi+bw)/(base_i+base_w))
    lsess = sessions["lenet_conv"]
    inp = np.asarray(
        lsess.packets("lenet_conv", ELEMS, names=["inputs"])
    )
    wgt = np.asarray(
        lsess.packets("lenet_conv", ELEMS, names=["conv1", "conv2"])
    )
    base_i = _measure_separate(inp, "none")
    base_w = _measure_separate(wgt, "none")
    recal = {}
    for strat, key in (("acc", "acc"), ("app", "app")):
        bi = _measure_separate(inp, key)
        bw = _measure_separate(wgt, key)
        red = 100 * (1 - (bi + bw) / (base_i + base_w))
        recal[strat] = {
            "captured_red": round(float(red), 2),
            "synthetic_red": SYNTHETIC_OVERALL[strat],
            "paper_red": PAPER_OVERALL[strat],
        }
        rows.append((
            f"model/recalib/{strat}",
            0.0,
            f"captured_red={red:.2f}% "
            f"synthetic_red={SYNTHETIC_OVERALL[strat]}% "
            f"paper_red={PAPER_OVERALL[strat]}% (trained LeNet streams)",
        ))

    # ---- the campaign artifacts (CSV table + JSON with recalibration)
    csv_path = "SCENARIOS_model_traffic.csv"
    json_path = "SCENARIOS_model_traffic.json"
    obs.write_scenarios_csv(csv_path, records)
    obs.write_scenarios_json(
        json_path, records, meta={"recalibration": recal},
    )
    rows.append((
        "model/artifact",
        0.0,
        f"{len(records)} scenario records -> {csv_path} + {json_path}",
    ))
    return rows
