"""Synthetic traffic generators shared by the paper-reproduction benches.

Two data models (EXPERIMENTS.md §Table I discusses why both are needed):

  * ``uniform``  — the paper's literal "random inputs and weights": iid
    uniform bytes.  Analytically, popcount ordering's gain is bounded here
    by E[HD | same popcount] = 3.5 bits/byte vs 4.0 unordered (~12.5 % on
    the ordered side).
  * ``conv``     — LeNet-like conv traffic: spatially-correlated synthetic
    images streamed as im2col patches with a repeated quantized kernel.
    This reproduces the paper's Table-I magnitudes (their workload is the
    first two LeNet layers).
"""

from __future__ import annotations

import numpy as np


def uniform_pairs(packets: int, elems: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    inp = rng.integers(0, 256, (packets, elems), dtype=np.uint8)
    wgt = rng.integers(0, 256, (packets, elems), dtype=np.uint8)
    return inp, wgt


def synth_images(n: int, hw: int = 32, sparsity: float = 0.55, smooth: int = 2,
                 seed: int = 0) -> np.ndarray:
    """MNIST-like 8-bit images: smoothed noise thresholded to sparse strokes."""
    rng = np.random.default_rng(seed)
    imgs = rng.normal(size=(n, hw, hw))
    for _ in range(smooth):
        imgs = (imgs + np.roll(imgs, 1, 1) + np.roll(imgs, -1, 1)
                + np.roll(imgs, 1, 2) + np.roll(imgs, -1, 2)) / 5
    thr = np.quantile(imgs, sparsity, axis=(1, 2), keepdims=True)
    v = np.clip(imgs - thr, 0, None)
    v = v / (v.max(axis=(1, 2), keepdims=True) + 1e-9) * 255
    return v.astype(np.uint8)


def im2col(img: np.ndarray, k: int = 5) -> np.ndarray:
    out = img.shape[0] - k + 1
    return np.lib.stride_tricks.sliding_window_view(img, (k, k)).reshape(
        out * out, k * k
    )


def conv_streams(n_images: int = 24, kernel: int = 5, elems: int = 64,
                 seed: int = 42, column_major: bool = False):
    """(input_packets, weight_packets) for one PE's link (one output channel,
    matching the paper's platform where the allocation unit feeds each PE its
    own stream).  Inputs are im2col patches streamed patch-major
    (``column_major=False``, the non-optimized order) or position-major
    (``column_major=True`` — the paper's column-major layout: all patches'
    values at kernel position 0, then position 1, ...); weights follow the
    same traversal of the repeated kernel."""
    rng = np.random.default_rng(seed)
    imgs = synth_images(n_images, seed=seed)
    k2 = kernel * kernel
    kern = (rng.normal(size=k2) * 60 + 128).clip(0, 255).astype(np.uint8)
    inps, wgts = [], []
    for im in imgs:
        patches = im2col(im, kernel)  # (P, 25)
        wmat = np.broadcast_to(kern, patches.shape)
        if column_major:
            inps.append(patches.T.reshape(-1))
            wgts.append(wmat.T.reshape(-1))
        else:
            inps.append(patches.reshape(-1))
            wgts.append(wmat.reshape(-1))
    inp_stream = np.concatenate(inps)
    wgt_stream = np.concatenate(wgts)
    p = inp_stream.size // elems
    return (
        inp_stream[: p * elems].reshape(p, elems),
        wgt_stream[: p * elems].reshape(p, elems),
    )
