"""Synthetic traffic generators shared by the paper-reproduction benches.

Two data models (EXPERIMENTS.md §Table I discusses why both are needed):

  * ``uniform``  — the paper's literal "random inputs and weights": iid
    uniform bytes.  Analytically, popcount ordering's gain is bounded here
    by E[HD | same popcount] = 3.5 bits/byte vs 4.0 unordered (~12.5 % on
    the ordered side).
  * ``conv``     — LeNet-like conv traffic: spatially-correlated synthetic
    images streamed as im2col patches with a repeated quantized kernel.
    This reproduces the paper's Table-I magnitudes (their workload is the
    first two LeNet layers).
"""

from __future__ import annotations

import numpy as np


def uniform_pairs(packets: int, elems: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    inp = rng.integers(0, 256, (packets, elems), dtype=np.uint8)
    wgt = rng.integers(0, 256, (packets, elems), dtype=np.uint8)
    return inp, wgt


def synth_images(n: int, hw: int = 32, sparsity: float = 0.55, smooth: int = 2,
                 seed: int = 0) -> np.ndarray:
    """MNIST-like 8-bit images: smoothed noise thresholded to sparse strokes."""
    rng = np.random.default_rng(seed)
    imgs = rng.normal(size=(n, hw, hw))
    for _ in range(smooth):
        imgs = (imgs + np.roll(imgs, 1, 1) + np.roll(imgs, -1, 1)
                + np.roll(imgs, 1, 2) + np.roll(imgs, -1, 2)) / 5
    thr = np.quantile(imgs, sparsity, axis=(1, 2), keepdims=True)
    v = np.clip(imgs - thr, 0, None)
    v = v / (v.max(axis=(1, 2), keepdims=True) + 1e-9) * 255
    return v.astype(np.uint8)


def im2col(img: np.ndarray, k: int = 5) -> np.ndarray:
    out = img.shape[0] - k + 1
    return np.lib.stride_tricks.sliding_window_view(img, (k, k)).reshape(
        out * out, k * k
    )


def _pad_to_packets(stream: np.ndarray, elems: int, lanes: int) -> np.ndarray:
    """Round a flat byte stream up to whole packets without dropping bytes.

    The final partial packet is completed by cycling the stream's last
    ``lanes`` bytes — i.e. the period-``lanes`` extension
    ``padded[o] = padded[o - lanes]``, which is phase-correct at any tail
    offset: since packet offsets are flit-aligned (``elems`` is a multiple
    of ``lanes``), every fully-padded flit equals its predecessor and the
    only boundary transitions left are the final real bytes' own — the
    repeated-flit convention of the repro.kernels padding/masking contract
    (under per-packet sorting the tail packet additionally pays its own
    intra-packet transitions).  Streams already a whole number of packets
    are returned unchanged.
    """
    pad = (-stream.size) % elems
    if not pad:
        return stream
    tail = stream[-min(lanes, stream.size):]
    return np.concatenate([stream, np.resize(tail, pad)])


def conv_streams(n_images: int = 24, kernel: int = 5, elems: int = 64,
                 seed: int = 42, column_major: bool = False,
                 channels: int = 6, lanes: int = 16):
    """(input_packets, weight_packets) for one PE's link of the paper's
    conv platform.  Inputs are im2col patches streamed patch-major
    (``column_major=False``, the non-optimized order) or position-major
    (``column_major=True`` — the paper's column-major layout: all patches'
    values at kernel position 0, then position 1, ...); weights follow the
    same traversal.

    The weight stream cycles the layer's ``channels`` output-channel
    kernels across the patch sequence (LeNet: 6 in conv1, 16 in conv2) —
    the PE allocation's round-robin over output channels.  The pre-fix
    model broadcast ONE kernel into every packet, which collapsed
    weight-side ordering gains and under-reduced the overall numbers
    (DESIGN.md §10's honest-calibration note records the recalibration).

    Streams whose byte count is not a whole number of ``elems`` packets
    are padded — never truncated — by cycling the last ``lanes``-byte flit
    into the final packet (see :func:`_pad_to_packets`).
    """
    rng = np.random.default_rng(seed)
    imgs = synth_images(n_images, seed=seed)
    k2 = kernel * kernel
    kerns = (rng.normal(size=(channels, k2)) * 60 + 128).clip(0, 255).astype(
        np.uint8
    )
    inps, wgts = [], []
    for im in imgs:
        patches = im2col(im, kernel)  # (P, 25)
        wmat = kerns[np.arange(len(patches)) % channels]  # cycle channels
        if column_major:
            inps.append(patches.T.reshape(-1))
            wgts.append(wmat.T.reshape(-1))
        else:
            inps.append(patches.reshape(-1))
            wgts.append(wmat.reshape(-1))
    inp_stream = _pad_to_packets(np.concatenate(inps), elems, lanes)
    wgt_stream = _pad_to_packets(np.concatenate(wgts), elems, lanes)
    return (
        inp_stream.reshape(-1, elems),
        wgt_stream.reshape(-1, elems),
    )
