import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Capacity-fix pass: apply the §Perf levers to every remaining over-16GiB
cell (EXPERIMENTS.md §Perf addendum).  Lever mapping:

  * granite (24 heads % 16 != 0 -> attention fully replicated per TP rank):
    logical remesh to TP in {4, 8} so heads shard; prefill also takes scan
    attention (unrolled-block liveness).
  * 32k prefill cells: scan attention (B2 lever).
  * internvl2 train: ZeRO-1 instead of FSDP (weight re-gathers under remat
    were the temp driver; TP params 5 GiB + data-sharded Adam fits).
  * qwen3-moe train: FSDP + 8 microbatches (dispatch buffers halve).
  * whisper train: 4 microbatches + chunked loss.
"""
import json
import repro.launch.specs as specs
from repro.launch.dryrun import run_cell

FIXES = [
    ("granite-moe-3b-a800m", "train_4k", "fix_mesh64x4", {}, (64, 4), None),
    ("granite-moe-3b-a800m", "prefill_32k", "fix_scan_mesh32x8",
     {"attn_impl": "chunked", "attn_chunk": 4096}, (32, 8), None),
    ("internvl2-26b", "train_4k", "fix_zero1",
     {"fsdp": False, "zero1": True}, None, None),
    ("qwen3-moe-30b-a3b", "train_4k", "fix_mb8", {}, None, 8),
    ("whisper-medium", "train_4k", "fix_mb4_logitschunk",
     {"logits_chunk": 512}, None, 4),
    ("qwen3-4b", "prefill_32k", "fix_scan",
     {"attn_impl": "chunked", "attn_chunk": 4096}, None, None),
    ("zamba2-1.2b", "prefill_32k", "fix_scan",
     {"attn_impl": "chunked", "attn_chunk": 4096}, None, None),
    ("qwen3-moe-30b-a3b", "prefill_32k", "fix_scan",
     {"attn_impl": "chunked", "attn_chunk": 4096}, None, None),
    ("internvl2-26b", "prefill_32k", "fix_scan",
     {"attn_impl": "chunked", "attn_chunk": 4096}, None, None),
    # round 2: (64,4) left granite train at 23.1 GiB (state-dominated);
    # ZeRO-1 + TP=8 + mb4 lands at 6.9 GiB
    ("granite-moe-3b-a800m", "train_4k", "fix2_mesh32x8_zero1_mb4",
     {"zero1": True}, (32, 8), 4),
]

os.makedirs("experiments/perf", exist_ok=True)
for arch, shape, tag, over, mesh_shape, mb in FIXES:
    out = f"experiments/perf/{arch}__{shape}__{tag}.json"
    if os.path.exists(out):
        print("skip", tag); continue
    saved = specs.DEFAULT_TRAIN_MICROBATCHES
    saved_map = dict(specs.TRAIN_MICROBATCHES)
    if mb:
        specs.TRAIN_MICROBATCHES[arch] = mb
    try:
        rec = run_cell(arch, shape, multi_pod=False, cfg_overrides=over,
                       mesh_shape=mesh_shape, with_cost_pass=False)
        rec["perf_tag"] = tag
        json.dump(rec, open(out, "w"), indent=1)
    except Exception as e:
        print(f"{arch} {shape} {tag} FAILED: {type(e).__name__}: {e}")
    finally:
        specs.TRAIN_MICROBATCHES.clear(); specs.TRAIN_MICROBATCHES.update(saved_map)
        specs.DEFAULT_TRAIN_MICROBATCHES = saved
