import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""C6 = save_block_io + mesh(128,2) + ZeRO-1: the C5 peak was params+opt
(10.8 GiB/device at TP=2); sharding Adam m/v over the 128-wide data axis
frees ~7.1 GiB for ~0.07 s of post-update weight all-gather."""
import json
from repro.launch.dryrun import run_cell

rec = run_cell("internlm2-1.8b", "train_4k", multi_pod=False,
               cfg_overrides={"remat_policy": "save_block_io", "zero1": True},
               mesh_shape=(128, 2))
rec["perf_tag"] = "C6_blockio_mesh128x2_zero1"
json.dump(rec, open("experiments/perf/internlm2-1.8b__train_4k__C6_blockio_mesh128x2_zero1.json", "w"), indent=1)
