import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb round 3.

A4: pure-DP + chunk128 + remat OFF — 9.6 GiB peak leaves headroom; dropping
    recompute should cut the compute term ~25 % (remat multiplier 1.33).
C4: save_block_io + logical mesh (128, 2) — TP payload/device halves again
    and ring factor (n=2) drops to 1.0; grad all-reduce grows (params/2
    replicated over 128-wide data axis).  Napkin: collective 0.76 -> ~0.2 s,
    compute-bound at frac ~0.7 IF params+opt (10.8 GiB) + activations fit.
"""
import dataclasses, json
from repro.configs import get_config
from repro.launch.dryrun import run_cell

ITERS = [
    ("mamba2-370m", "train_4k", "A4_pure_dp_chunk128_noremat",
     lambda: {"pure_dp": True, "remat": False,
              "ssm": dataclasses.replace(get_config("mamba2-370m").ssm, chunk=128)},
     None),
    ("internlm2-1.8b", "train_4k", "C4_blockio_mesh128x2",
     lambda: {"remat_policy": "save_block_io"}, (128, 2)),
]

for arch, shape, tag, over_fn, mesh_shape in ITERS:
    out = f"experiments/perf/{arch}__{shape}__{tag}.json"
    if os.path.exists(out):
        print("skip", tag); continue
    try:
        rec = run_cell(arch, shape, multi_pod=False, cfg_overrides=over_fn(),
                       mesh_shape=mesh_shape)
        rec["perf_tag"] = tag
        json.dump(rec, open(out, "w"), indent=1)
    except Exception as e:
        print(f"{tag} FAILED: {type(e).__name__}: {e}")
