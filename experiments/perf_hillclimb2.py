import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb round 2 (after round-1 measurement + parser fixes).

New hypotheses (napkin math in EXPERIMENTS.md §Perf):
  A3: pure-DP + SSD chunk 128 — intra-chunk quadratic term halves
  B2b: scan attention re-measured with the loop-unroll cost fix
  B4: logical remesh (32, 8) for prefill — batch 32 fully data-sharded,
      TP degree 8: per-AR payload/device halves and ring factor drops
  C3: save_block_io + logical remesh (64, 4) — TP all-reduce payload
      scales with per-device batch; predicted wire ~5x down
  C4: C3 + int8-EF wire (2x demonstrated in HLO; applied analytically)
"""

import json  # noqa: E402

ITERS = [
    ("mamba2-370m", "train_4k", "A3_pure_dp_chunk128", {"pure_dp": True},
     {"ssm_chunk": 128}, None),
    # scan-attention FLOPs are chunk-size-invariant (masked full-KV = S^2);
    # measure at chunk 4096 so the unrolled cost pass compiles 8x8 = 64
    # blocks/layer instead of 1024
    ("codeqwen1.5-7b", "prefill_32k", "B2b_attn_scan_remeasure",
     {"attn_impl": "chunked", "attn_chunk": 4096}, {}, None),
    ("codeqwen1.5-7b", "prefill_32k", "B4_mesh32x8", {}, {}, (32, 8)),
    ("codeqwen1.5-7b", "prefill_32k", "B5_scan_mesh32x8",
     {"attn_impl": "chunked", "attn_chunk": 4096}, {}, (32, 8)),
    ("internlm2-1.8b", "train_4k", "C3_blockio_mesh64x4",
     {"remat_policy": "save_block_io"}, {}, (64, 4)),
    ("mamba2-370m", "train_4k", "A1b_pure_dp_remeasure", {"pure_dp": True},
     {}, None),
]


def main() -> None:
    import dataclasses
    import sys

    from repro.configs import get_config
    from repro.launch.dryrun import run_cell

    only = set(sys.argv[1:])
    os.makedirs("experiments/perf", exist_ok=True)
    for arch, shape, tag, over, extra, mesh_shape in ITERS:
        if only and tag not in only:
            continue
        out = f"experiments/perf/{arch}__{shape}__{tag}.json"
        if os.path.exists(out):
            print(f"skip existing {tag}")
            continue
        over = dict(over)
        if "ssm_chunk" in extra:
            base = get_config(arch)
            over["ssm"] = dataclasses.replace(base.ssm, chunk=extra["ssm_chunk"])
        try:
            rec = run_cell(arch, shape, multi_pod=False, cfg_overrides=over,
                           mesh_shape=mesh_shape)
            rec["perf_tag"] = tag
            with open(out, "w") as f:
                json.dump(rec, f, indent=1)
        except Exception as e:
            print(f"{tag} FAILED: {type(e).__name__}: {e}")


if __name__ == "__main__":
    main()
