import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb driver — ALL rounds, one parameterized script.

Each iteration reruns one (arch, shape) cell of the baseline roofline
sweep with one config change and records the JSON under
``experiments/perf/``; the hypotheses behind each tag live in
EXPERIMENTS.md §Perf and the lever taxonomy in DESIGN.md §13
("Roofline levers").  Cells:

  A. mamba2-370m    x train_4k    — most collective-bound cell
  B. codeqwen1.5-7b x prefill_32k — worst roofline fraction (+ over-memory)
  C. internlm2-1.8b x train_4k    — most representative of the paper's
     technique (link/collective-traffic levers)

Usage::

    PYTHONPATH=src python experiments/perf_hillclimb.py [tag ...]
    PYTHONPATH=src python experiments/perf_hillclimb.py --list

No tags = run every iteration (existing outputs are skipped, so the
script is resumable).  Baseline sweep records are copied alongside for
side-by-side reading.
"""

import dataclasses  # noqa: E402
import json  # noqa: E402
import shutil  # noqa: E402
import sys  # noqa: E402


@dataclasses.dataclass(frozen=True)
class Iteration:
    arch: str
    shape: str
    tag: str
    overrides: dict = dataclasses.field(default_factory=dict)
    mesh_shape: tuple | None = None  # logical remesh (data, tensor)
    microbatches: int | None = None  # TRAIN_MICROBATCHES override
    ssm_chunk: int | None = None  # SSD chunk override (needs get_config)


ITERS = [
    # --- round 1: first levers per cell ---
    Iteration("mamba2-370m", "train_4k", "A1_pure_dp", {"pure_dp": True}),
    Iteration("mamba2-370m", "train_4k", "A2_pure_dp_mb4",
              {"pure_dp": True}, microbatches=4),
    Iteration("codeqwen1.5-7b", "prefill_32k", "B1_attn_chunk_2048",
              {"attn_chunk": 2048}),
    Iteration("codeqwen1.5-7b", "prefill_32k", "B2_attn_scan",
              {"attn_impl": "chunked"}),
    Iteration("codeqwen1.5-7b", "prefill_32k", "B3_scan_chunk4k",
              {"attn_impl": "chunked", "attn_chunk": 4096}),
    Iteration("internlm2-1.8b", "train_4k", "C1_save_block_io",
              {"remat_policy": "save_block_io"}),
    Iteration("internlm2-1.8b", "train_4k", "C2_save_block_io_mb4",
              {"remat_policy": "save_block_io"}, microbatches=4),
    # --- round 2: after round-1 measurement + parser fixes ---
    Iteration("mamba2-370m", "train_4k", "A3_pure_dp_chunk128",
              {"pure_dp": True}, ssm_chunk=128),
    # scan-attention FLOPs are chunk-size-invariant (masked full-KV =
    # S^2); chunk 4096 keeps the unrolled cost pass at 64 blocks/layer
    Iteration("codeqwen1.5-7b", "prefill_32k", "B2b_attn_scan_remeasure",
              {"attn_impl": "chunked", "attn_chunk": 4096}),
    Iteration("codeqwen1.5-7b", "prefill_32k", "B4_mesh32x8",
              mesh_shape=(32, 8)),
    Iteration("codeqwen1.5-7b", "prefill_32k", "B5_scan_mesh32x8",
              {"attn_impl": "chunked", "attn_chunk": 4096},
              mesh_shape=(32, 8)),
    Iteration("internlm2-1.8b", "train_4k", "C3_blockio_mesh64x4",
              {"remat_policy": "save_block_io"}, mesh_shape=(64, 4)),
    Iteration("mamba2-370m", "train_4k", "A1b_pure_dp_remeasure",
              {"pure_dp": True}),
    # --- round 3 ---
    Iteration("mamba2-370m", "train_4k", "A4_pure_dp_chunk128_noremat",
              {"pure_dp": True, "remat": False}, ssm_chunk=128),
    Iteration("internlm2-1.8b", "train_4k", "C4_blockio_mesh128x2",
              {"remat_policy": "save_block_io"}, mesh_shape=(128, 2)),
    # --- rounds 4-5: C5 adds mb4 (C2's -23 % peak), C6 swaps in ZeRO-1
    # (the C5 peak was params+opt; data-sharded Adam frees ~7.1 GiB) ---
    Iteration("internlm2-1.8b", "train_4k", "C5_blockio_mesh128x2_mb4",
              {"remat_policy": "save_block_io"}, mesh_shape=(128, 2),
              microbatches=4),
    Iteration("internlm2-1.8b", "train_4k", "C6_blockio_mesh128x2_zero1",
              {"remat_policy": "save_block_io", "zero1": True},
              mesh_shape=(128, 2)),
]


def main() -> None:
    args = sys.argv[1:]
    if "--list" in args:
        for it in ITERS:
            print(f"{it.tag}  ({it.arch} x {it.shape})")
        return
    only = set(args)
    unknown = only - {it.tag for it in ITERS}
    if unknown:
        raise SystemExit(f"unknown tags: {', '.join(sorted(unknown))}")

    import repro.launch.specs as specs
    from repro.configs import get_config
    from repro.launch.dryrun import run_cell

    os.makedirs("experiments/perf", exist_ok=True)
    # copy sweep baselines for side-by-side reading
    for arch, shape in {(it.arch, it.shape) for it in ITERS}:
        src = f"experiments/dryrun/{arch}__{shape}__16x16.json"
        dst = f"experiments/perf/{arch}__{shape}__baseline.json"
        if os.path.exists(src) and not os.path.exists(dst):
            shutil.copy(src, dst)

    for it in ITERS:
        if only and it.tag not in only:
            continue
        out = f"experiments/perf/{it.arch}__{it.shape}__{it.tag}.json"
        if os.path.exists(out):
            print(f"skip existing {it.tag}")
            continue
        over = dict(it.overrides)
        if it.ssm_chunk is not None:
            over["ssm"] = dataclasses.replace(
                get_config(it.arch).ssm, chunk=it.ssm_chunk
            )
        saved = dict(specs.TRAIN_MICROBATCHES)
        saved_default = specs.DEFAULT_TRAIN_MICROBATCHES
        if it.microbatches is not None:
            specs.TRAIN_MICROBATCHES[it.arch] = it.microbatches
            specs.DEFAULT_TRAIN_MICROBATCHES = it.microbatches
        try:
            rec = run_cell(
                it.arch, it.shape, multi_pod=False, cfg_overrides=over,
                mesh_shape=it.mesh_shape,
            )
            rec["perf_tag"] = it.tag
            rec["overrides"] = {
                **it.overrides,
                **({"microbatches": it.microbatches}
                   if it.microbatches else {}),
                **({"ssm_chunk": it.ssm_chunk} if it.ssm_chunk else {}),
                **({"mesh_shape": list(it.mesh_shape)}
                   if it.mesh_shape else {}),
            }
            with open(out, "w") as f:
                json.dump(rec, f, indent=1)
        except Exception as e:
            print(f"{it.tag} FAILED: {type(e).__name__}: {e}")
        finally:
            specs.TRAIN_MICROBATCHES.clear()
            specs.TRAIN_MICROBATCHES.update(saved)
            specs.DEFAULT_TRAIN_MICROBATCHES = saved_default


if __name__ == "__main__":
    main()
