import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb driver: run the three chosen cells' optimization
iterations and record before/after JSONs under experiments/perf/.

Cells (chosen per the assignment from the baseline roofline table):
  A. mamba2-370m    x train_4k    — most collective-bound cell
  B. codeqwen1.5-7b x prefill_32k — worst roofline fraction (+ over-memory)
  C. internlm2-1.8b x train_4k    — most representative of the paper's
     technique (link/collective-traffic levers: remat policy that stops
     re-running forward all-reduces; compressed wire)

Baselines are the untouched sweep records (experiments/dryrun/...); each
iteration here reruns the cell with one config change.

  PYTHONPATH=src python experiments/perf_hillclimb.py [tag ...]
"""

import json  # noqa: E402
import shutil  # noqa: E402
import sys  # noqa: E402

ITERS = [
    # (arch, shape, tag, overrides)
    ("mamba2-370m", "train_4k", "A1_pure_dp", {"pure_dp": True}),
    ("mamba2-370m", "train_4k", "A2_pure_dp_mb4", {"pure_dp": True}),  # + mb=4
    ("codeqwen1.5-7b", "prefill_32k", "B1_attn_chunk_2048", {"attn_chunk": 2048}),
    ("codeqwen1.5-7b", "prefill_32k", "B2_attn_scan", {"attn_impl": "chunked"}),
    ("codeqwen1.5-7b", "prefill_32k", "B3_scan_chunk4k",
     {"attn_impl": "chunked", "attn_chunk": 4096}),
    ("internlm2-1.8b", "train_4k", "C1_save_block_io",
     {"remat_policy": "save_block_io"}),
    ("internlm2-1.8b", "train_4k", "C2_save_block_io_mb4",
     {"remat_policy": "save_block_io"}),  # + mb=4
]


def main() -> None:
    from repro.launch.dryrun import run_cell
    import repro.launch.specs as specs

    only = set(sys.argv[1:])
    os.makedirs("experiments/perf", exist_ok=True)
    # copy sweep baselines for side-by-side reading
    for arch, shape in {(a, s) for a, s, _, _ in ITERS}:
        src = f"experiments/dryrun/{arch}__{shape}__16x16.json"
        dst = f"experiments/perf/{arch}__{shape}__baseline.json"
        if os.path.exists(src) and not os.path.exists(dst):
            shutil.copy(src, dst)

    for arch, shape, tag, over in ITERS:
        if only and tag not in only:
            continue
        out = f"experiments/perf/{arch}__{shape}__{tag}.json"
        if os.path.exists(out):
            print(f"skip existing {tag}")
            continue
        mb_override = 4 if tag.endswith("_mb4") else None
        saved = dict(specs.TRAIN_MICROBATCHES)
        saved_default = specs.DEFAULT_TRAIN_MICROBATCHES
        if mb_override:
            specs.TRAIN_MICROBATCHES[arch] = mb_override
            specs.DEFAULT_TRAIN_MICROBATCHES = mb_override
        try:
            rec = run_cell(arch, shape, multi_pod=False, cfg_overrides=over)
            rec["perf_tag"] = tag
            rec["overrides"] = {**over, **({"microbatches": mb_override} if mb_override else {})}
            with open(out, "w") as f:
                json.dump(rec, f, indent=1)
        except Exception as e:
            print(f"{tag} FAILED: {type(e).__name__}: {e}")
        finally:
            specs.TRAIN_MICROBATCHES.clear()
            specs.TRAIN_MICROBATCHES.update(saved)
            specs.DEFAULT_TRAIN_MICROBATCHES = saved_default


if __name__ == "__main__":
    main()
