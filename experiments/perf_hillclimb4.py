import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""C5 = C4 (save_block_io + mesh 128x2) + 4 microbatches: C2 measured -23 %
peak from mb4; predicted 20.1 GiB -> ~15.5 (fits), wire unchanged."""
import json
import repro.launch.specs as specs
from repro.launch.dryrun import run_cell

specs.TRAIN_MICROBATCHES["internlm2-1.8b"] = 4
specs.DEFAULT_TRAIN_MICROBATCHES = 4
rec = run_cell("internlm2-1.8b", "train_4k", multi_pod=False,
               cfg_overrides={"remat_policy": "save_block_io"},
               mesh_shape=(128, 2))
rec["perf_tag"] = "C5_blockio_mesh128x2_mb4"
json.dump(rec, open("experiments/perf/internlm2-1.8b__train_4k__C5_blockio_mesh128x2_mb4.json", "w"), indent=1)
