from .analysis import (
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS,
    RooflineTerms,
    analyse,
    model_flops_global,
    wire_bytes_per_device,
)
from .collect import collect_from_compiled, parse_collectives, summarize_collectives

__all__ = [
    "PEAK_FLOPS",
    "HBM_BW",
    "ICI_BW",
    "RooflineTerms",
    "analyse",
    "wire_bytes_per_device",
    "model_flops_global",
    "collect_from_compiled",
    "parse_collectives",
    "summarize_collectives",
]
