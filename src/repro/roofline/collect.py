"""Extract roofline inputs from a compiled (dry-run) executable.

``cost_analysis`` provides HLO FLOPs and bytes for the per-device SPMD
module; collective traffic is NOT in cost_analysis, so we parse the
post-partitioning HLO text and sum result bytes of every collective op,
keeping the op kind and replica-group size so the analysis layer can apply
wire factors (ring all-reduce moves ~2x its payload, etc.).
"""

from __future__ import annotations

import re
from typing import Any

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[\d+\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_COMP_HEAD_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\([^)]*\)\s*->")
_WHILE_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_WHILE_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_CALL_RE = re.compile(r"(?:call|fusion)\(.*?to_apply=%?([\w.\-]+)")
_COND_RE = re.compile(r"conditional\(.*?branch_computations=\{([^}]*)\}")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_computations(hlo_text: str) -> dict[str, list[str]]:
    """Map computation name -> its instruction lines.  ENTRY is ''-prefixed
    with its real name; we also record which computation is the entry."""
    comps: dict[str, list[str]] = {}
    current = None
    for line in hlo_text.splitlines():
        if not line.startswith(" ") and ("->" in line) and ("{" in line):
            m = _COMP_HEAD_RE.match(line.strip())
            if m:
                current = m.group(1)
                comps.setdefault(current, [])
                if line.lstrip().startswith("ENTRY"):
                    comps["__entry__"] = comps[current]
                continue
        if current is not None:
            if line.strip() == "}":
                current = None
            else:
                comps[current].append(line.strip())
    return comps


def _line_collective(stripped: str) -> dict[str, Any] | None:
    if "=" not in stripped:
        return None
    for kind in _COLLECTIVES:
        if f" {kind}(" in stripped or f" {kind}-start(" in stripped:
            lhs = (
                stripped.split(f"{kind}-start(")[0]
                if f" {kind}-start(" in stripped
                else stripped.split(f"{kind}(")[0]
            )
            try:
                type_part = lhs.split("=", 1)[1]
            except IndexError:
                return None
            group = None
            m = _GROUPS_IOTA_RE.search(stripped)
            if m:
                group = int(m.group(2))
            else:
                m = _GROUPS_LIST_RE.search(stripped)
                if m:
                    group = len([x for x in m.group(1).split(",") if x.strip()])
            return {"kind": kind, "bytes": _shape_bytes(type_part), "group": group}
    return None


def parse_collectives(hlo_text: str) -> list[dict[str, Any]]:
    """Collective records with DYNAMIC execution counts.

    Scan-over-layers / microbatching lower to HLO while-loops whose bodies
    contain each collective once; we walk the call graph from ENTRY and
    multiply by loop trip counts (largest s32 constant in the loop condition
    — the standard counted-loop pattern jax emits).  Each returned record
    carries ``trip`` = number of dynamic executions.
    """
    comps = _split_computations(hlo_text)
    entry_lines = comps.get("__entry__")
    if entry_lines is None:
        # fallback: flat static scan
        out = []
        for line in hlo_text.splitlines():
            rec = _line_collective(line.strip())
            if rec:
                rec["trip"] = 1
                out.append(rec)
        return out

    def trip_count(cond_name: str) -> int:
        consts = [int(c) for c in _CONST_RE.findall("\n".join(comps.get(cond_name, [])))]
        return max(consts) if consts else 1

    out: list[dict[str, Any]] = []
    seen: set[tuple[str, int]] = set()

    def walk(comp_name: str, mult: int) -> None:
        key = (comp_name, mult)
        if key in seen:  # guard cycles; computations are DAGs in practice
            return
        seen.add(key)
        for line in comps.get(comp_name, []):
            rec = _line_collective(line)
            if rec:
                rec = dict(rec)
                rec["trip"] = mult
                out.append(rec)
            if " while(" in line:
                mc = _WHILE_COND_RE.search(line)
                mb = _WHILE_BODY_RE.search(line)
                if mc and mb:
                    walk(mb.group(1), mult * trip_count(mc.group(1)))
                continue
            m = _CALL_RE.search(line)
            if m:
                walk(m.group(1), mult)
            m = _COND_RE.search(line)
            if m:
                for branch in m.group(1).split(","):
                    walk(branch.strip().lstrip("%"), mult)

    entry_name = next(k for k, v in comps.items() if v is entry_lines and k != "__entry__")
    walk(entry_name, 1)
    return out


def summarize_collectives(records: list[dict]) -> dict[str, dict]:
    summary: dict[str, dict] = {}
    for r in records:
        trip = r.get("trip", 1)
        s = summary.setdefault(r["kind"], {"count": 0, "bytes": 0})
        s["count"] += trip
        s["bytes"] += r["bytes"] * trip
    return summary


_WIRE_FACTOR = {
    "all-reduce": lambda n: 2.0 * (n - 1) / n,
    "all-gather": lambda n: (n - 1) / n,
    "reduce-scatter": lambda n: float(n - 1),
    "all-to-all": lambda n: (n - 1) / n,
    "collective-permute": lambda n: 1.0,
}


def wire_bytes(collective_ops: list[dict]) -> float:
    """Ring-algorithm wire bytes per device (factors above, trips applied)."""
    total = 0.0
    for op in collective_ops:
        n = max(op.get("group") or 2, 2)
        total += _WIRE_FACTOR[op["kind"]](n) * op["bytes"] * op.get("trip", 1)
    return total


_UPCAST_HDR_RE = re.compile(
    r"\(param[\w.]*: bf16\[([0-9,]*)\]\) -> f32\[\1\]"
)
_UPCAST_LINE_RE = re.compile(r"= f32(\[[0-9,]+\])[^=]*? convert\(")


def cpu_bf16_upcast_bytes(hlo_text: str, min_bytes: int = 1 << 26) -> int:
    """Bytes of loop-invariant bf16->f32 whole-array converts.

    XLA:CPU has no native bf16 compute, so it materialises f32 copies of
    bf16 weight stacks / KV caches (hoisted out of the layer loop).  These
    buffers do NOT exist on the TPU target; we measure them so the dry-run
    can report a TPU-adjusted peak (EXPERIMENTS.md §Dry-run caveats).
    """
    total = 0
    for m in _UPCAST_HDR_RE.finditer(hlo_text):
        dims = m.group(1)
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        if n * 4 >= min_bytes:
            total += n * 4
    return total


def collect_from_compiled(
    *, arch: str, shape: str, kind: str, mesh_desc: str, num_devices: int,
    compiled, cfg,
) -> dict[str, Any]:
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    colls = parse_collectives(hlo)
    mem = compiled.memory_analysis()
    mem_rec = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        v = getattr(mem, attr, None)
        if v is not None:
            mem_rec[attr] = int(v)

    return {
        "arch": arch,
        "shape": shape,
        "kind": kind,
        "mesh": mesh_desc,
        "num_devices": num_devices,
        "hlo_flops_per_device": float(cost.get("flops", 0.0)),
        "hlo_bytes_per_device": float(cost.get("bytes accessed", 0.0)),
        "collectives": summarize_collectives(colls),
        "collective_ops": colls,
        "wire_bytes_per_device": wire_bytes(colls),
        "memory": mem_rec,
        "params": int(cfg.param_count()),
        "active_params": int(cfg.active_param_count()),
    }
