"""Three-term roofline from dry-run records (EXPERIMENTS.md §Roofline).

    compute    = HLO_FLOPs_per_device / peak_FLOPs        [s]
    memory     = HLO_bytes_per_device / HBM_bw            [s]
    collective = wire_bytes_per_device / ICI_link_bw      [s]

Hardware constants: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI (assignment-provided).

Wire factors per collective kind (ring algorithms, group size n):
    all-reduce         2 (n-1)/n   x result bytes
    all-gather           (n-1)/n   x result bytes
    reduce-scatter       (n-1)     x result bytes (result is the shard)
    all-to-all           (n-1)/n   x result bytes
    collective-permute   1         x result bytes

MODEL_FLOPS: 6·N·D train (2 fwd + 4 bwd), 2·N·D prefill, 2·N_active·B
decode — per device after dividing by chip count.  The ratio
MODEL_FLOPS / HLO_FLOPs exposes remat/dispatch/redundancy waste.

Link-energy column (the paper's metric on ICI traffic): wire bytes ->
128-bit flits -> BT x per-transition energy, with the measured ordering
reduction factor applied (repro.traffic) — see EXPERIMENTS.md §Arch-BT.
"""

from __future__ import annotations

import dataclasses
from typing import Any

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s/link

_WIRE_FACTOR = {
    "all-reduce": lambda n: 2.0 * (n - 1) / n,
    "all-gather": lambda n: (n - 1) / n,
    "reduce-scatter": lambda n: float(n - 1),
    "all-to-all": lambda n: (n - 1) / n,
    "collective-permute": lambda n: 1.0,
}


def wire_bytes_per_device(rec: dict[str, Any]) -> float:
    if "wire_bytes_per_device" in rec:
        return float(rec["wire_bytes_per_device"])
    total = 0.0
    for op in rec.get("collective_ops", []):
        n = op.get("group") or 2
        n = max(n, 2)
        total += _WIRE_FACTOR[op["kind"]](n) * op["bytes"] * op.get("trip", 1)
    return total


def _attention_flops(rec: dict[str, Any], seq_len: int, global_batch: int) -> float:
    """Attention (QK^T + PV) FLOPs — part of useful MODEL_FLOPS.

    Dense/MoE/VLM: causal full attention over seq_len.  SSM archs: the SSD
    scan's state FLOPs are already ~proportional to params x tokens (no
    quadratic term).  Hybrid: shared attention every k layers.
    """
    from repro.configs import get_config

    cfg = get_config(rec["arch"])
    hd = cfg.resolved_head_dim
    d_attn = cfg.n_heads * hd
    if cfg.family == "ssm":
        return 0.0
    if cfg.family == "hybrid":
        n_attn_layers = cfg.n_layers // cfg.shared_attn_every
    else:
        n_attn_layers = cfg.n_layers
    mult = 3.0 if rec["kind"] == "train" else 1.0  # fwd+bwd vs fwd
    if cfg.family in ("encdec", "audio"):
        enc_len = 1500  # whisper stub frontend (launch/specs.ENC_FRAMES)
        if rec["kind"] == "decode":
            per_tok = 4.0 * cfg.n_layers * (seq_len + enc_len) * d_attn
            return global_batch * per_tok
        # encoder bidirectional S_enc^2 + decoder causal S^2/2 + cross S*S_enc
        fwd = 4.0 * global_batch * d_attn * (
            cfg.n_enc_layers * enc_len**2
            + cfg.n_layers * (seq_len**2 / 2 + seq_len * enc_len)
        )
        return mult * fwd
    if rec["kind"] == "decode":
        # each new token attends the full cache
        return 4.0 * global_batch * n_attn_layers * seq_len * d_attn
    # causal: 4*S^2/2 = 2 S^2 per layer (QK + PV) forward
    return mult * 2.0 * global_batch * n_attn_layers * seq_len**2 * d_attn


def model_flops_global(rec: dict[str, Any], seq_len: int, global_batch: int) -> float:
    n_active = rec["active_params"]
    attn = _attention_flops(rec, seq_len, global_batch)
    if rec["kind"] == "train":
        return 6.0 * n_active * seq_len * global_batch + attn
    if rec["kind"] == "prefill":
        return 2.0 * n_active * seq_len * global_batch + attn
    return 2.0 * n_active * global_batch + attn  # decode: one token/sequence


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    kind: str
    compute_s: float
    memory_hlo_s: float  # XLA bytes-accessed / HBM: UPPER bound (CPU-backend
    #                      compiles fuse less than TPU; see report caveats)
    memory_floor_s: float  # resident bytes (TPU-adjusted peak) / HBM: every
    #                        live byte crosses HBM at least once per step
    collective_s: float
    model_flops_per_device: float
    hlo_flops_per_device: float
    useful_ratio: float

    @property
    def dominant(self) -> str:
        """Dominant term, using the memory FLOOR (the defensible bound)."""
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_floor_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_floor_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the binding roofline that useful model FLOPs occupy:
        (model_flops/peak) / max(term) — 1.0 means the dominant resource is
        spent entirely on useful compute."""
        if self.bound_s <= 0:
            return 0.0
        return (self.model_flops_per_device / PEAK_FLOPS) / self.bound_s


def analyse(rec: dict[str, Any], seq_len: int, global_batch: int) -> RooflineTerms:
    chips = rec["num_devices"]
    mf = model_flops_global(rec, seq_len, global_batch) / chips
    hf = rec["hlo_flops_per_device"]
    floor_bytes = rec.get(
        "tpu_peak_bytes_per_device", rec.get("peak_bytes_per_device", 0)
    )
    return RooflineTerms(
        arch=rec["arch"],
        shape=rec["shape"],
        mesh=rec["mesh"],
        kind=rec["kind"],
        compute_s=hf / PEAK_FLOPS,
        memory_hlo_s=rec["hlo_bytes_per_device"] / HBM_BW,
        memory_floor_s=floor_bytes / HBM_BW,
        collective_s=wire_bytes_per_device(rec) / ICI_BW,
        model_flops_per_device=mf,
        hlo_flops_per_device=hf,
        useful_ratio=mf / hf if hf else 0.0,
    )
