from .kv_quant import cache_bytes, dequantize_cache, quantize_cache
from .loop import GenerateResult, generate, make_decode_fn, make_prefill_fn

__all__ = [
    "generate",
    "make_prefill_fn",
    "make_decode_fn",
    "GenerateResult",
    "quantize_cache",
    "dequantize_cache",
    "cache_bytes",
]
