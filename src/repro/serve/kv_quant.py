"""int8 KV-cache quantization for decode (beyond-paper link-traffic lever).

The decode-time KV cache is the second-largest HBM stream after weights
(§Roofline: decode cells are memory-dominant).  Symmetric per-(batch, head)
int8 storage halves-to-quarters the cache footprint and its HBM traffic;
combined with sign-magnitude recoding (repro.traffic) the modeled BT of the
cache stream drops further — the paper's metric applied to the cache bus.

Layout: q_k/q_v int8 with fp32 scales of shape (L, B, H_kv); scales are
per-(layer, batch, head) amax / 127 maintained with a running max so decode
appends never rescale history (monotone amax => earlier entries stay exact).
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


def quantize_kv(k: jax.Array, scale: jax.Array) -> jax.Array:
    """k: (..., S, Hkv, D) bf16/f32; scale: broadcastable (..., 1, Hkv, 1)."""
    safe = jnp.maximum(scale, 1e-8)
    return jnp.clip(jnp.round(k.astype(jnp.float32) / safe), -127, 127).astype(
        jnp.int8
    )


def dequantize_kv(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def quantize_cache(cache: Params) -> Params:
    """Convert a populated bf16 cache (from ``prefill``) to int8 storage."""
    out: Params = {k: v for k, v in cache.items() if k not in ("k", "v")}
    for name in ("k", "v"):
        if name not in cache:
            return cache  # SSM-only cache: nothing to quantize
        t = cache[name]  # (L, B, S, Hkv, D)
        amax = jnp.max(jnp.abs(t.astype(jnp.float32)), axis=(2, 4), keepdims=True)
        scale = amax / 127.0
        out[f"{name}_q"] = quantize_kv(t, scale)
        out[f"{name}_scale"] = scale[:, :, 0, :, 0]  # (L, B, Hkv)
    out["quantized"] = jnp.bool_(True)
    return out


def dequantize_cache(cache: Params, dtype=jnp.bfloat16) -> Params:
    """Materialise the bf16 view expected by ``decode_step``."""
    if "k_q" not in cache:
        return cache
    out: Params = {
        k: v
        for k, v in cache.items()
        if k not in ("k_q", "v_q", "k_scale", "v_scale", "quantized")
    }
    for name in ("k", "v"):
        scale = cache[f"{name}_scale"][:, :, None, :, None]
        out[name] = dequantize_kv(cache[f"{name}_q"], scale, dtype)
    return out


def cache_bytes(cache: Params) -> int:
    """Storage bytes of a cache pytree (for the traffic/footprint reports)."""
    return sum(
        leaf.size * leaf.dtype.itemsize for leaf in jax.tree.leaves(cache)
    )
