"""Serving loop: batched prefill + greedy/sampled decode with KV caches.

Also hosts the serving-side integration of the paper's technique: before
serving, ``repro.traffic.apply_weight_ordering`` permutes contraction axes
so the decode weight stream (the dominant HBM traffic at batch decode) has
popcount-monotone rows; the modeled BT saving is quantified by the
``repro.link`` row-stream TX pipeline (``TxPipeline.measure_rows``, see
examples/serve_decode.py).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro import _obs_hooks
from repro.models import decode_step, prefill
from repro.models.config import ModelConfig

Params = Any


def make_prefill_fn(cfg: ModelConfig, max_len: int):
    @partial(jax.jit, static_argnames=())
    def fn(params, tokens, frames=None, inputs_embeds=None):
        kw = {}
        if frames is not None:
            kw["frames"] = frames
        if inputs_embeds is not None:
            kw["inputs_embeds"] = inputs_embeds
        return prefill(params, cfg, tokens, max_len, **kw)

    return fn


def make_decode_fn(cfg: ModelConfig):
    @jax.jit
    def fn(params, cache, tokens):
        return decode_step(params, cfg, cache, tokens)

    return fn


@dataclasses.dataclass
class GenerateResult:
    tokens: jax.Array  # (B, generated)
    logprobs: jax.Array  # (B, generated)


def generate(
    params: Params,
    cfg: ModelConfig,
    prompts: jax.Array,  # (B, S) int32
    max_new_tokens: int,
    frames: Optional[jax.Array] = None,
    inputs_embeds: Optional[jax.Array] = None,
    temperature: float = 0.0,
    seed: int = 0,
) -> GenerateResult:
    b, s = prompts.shape
    extra = inputs_embeds.shape[1] if inputs_embeds is not None else 0
    max_len = s + extra + max_new_tokens
    prefill_fn = make_prefill_fn(cfg, max_len)
    decode_fn = make_decode_fn(cfg)
    logits, cache = prefill_fn(
        params, prompts, frames=frames, inputs_embeds=inputs_embeds
    )
    # traffic tap (None test when no capture active): the decode weight
    # stream is multicast once per step — one firing represents it
    _obs_hooks.tap("serve.weights", params=params)
    key = jax.random.key(seed)
    out_toks, out_lp = [], []
    tok = None
    for i in range(max_new_tokens):
        lf = logits[:, -1].astype(jnp.float32)
        if temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, lf / temperature)[:, None]
        else:
            tok = jnp.argmax(lf, axis=-1)[:, None]
        lp = jax.nn.log_softmax(lf)
        out_lp.append(jnp.take_along_axis(lp, tok, axis=-1)[:, 0])
        out_toks.append(tok[:, 0])
        tok = tok.astype(jnp.int32)
        logits, cache = decode_fn(params, cache, tok)
        # cache is concrete here (decode_fn already ran): the new KV /
        # SSM-state bytes of this step are the per-token link traffic
        _obs_hooks.tap("serve.kv", cache=cache, step=i)
    return GenerateResult(
        tokens=jnp.stack(out_toks, axis=1), logprobs=jnp.stack(out_lp, axis=1)
    )
