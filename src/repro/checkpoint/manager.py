"""Fault-tolerant checkpointing (DESIGN.md §5).

Guarantees:
  * **Atomicity** — checkpoints are written to a temp dir and ``os.rename``d
    into place; a crash mid-write never corrupts the latest checkpoint.
  * **Integrity** — every array carries a CRC32 in the manifest, verified on
    restore; corrupt checkpoints are skipped and the previous one is used.
  * **Elasticity** — arrays are stored unsharded (host numpy); restore can
    re-``device_put`` onto a *different* mesh / sharding than the one that
    saved (``restore_resharded``), so the job can resume on a resized
    slice after node failures.
  * **Pipeline state** — the data-pipeline step, RNG key and arbitrary JSON
    metadata ride in the manifest, so restarts are bit-exact end to end.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import zipfile
import zlib
from typing import Any, Optional

import jax
import numpy as np


def _path_str(path) -> str:
    return jax.tree_util.keystr(path)


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return [(_path_str(p), np.asarray(v)) for p, v in leaves], treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._async_thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ---------------- save ----------------

    def save_async(self, step: int, tree: Any, extra: Optional[dict] = None) -> None:
        """Straggler-friendly save: snapshot to host memory synchronously
        (device buffers must not mutate underneath), then write + fsync +
        rename on a background thread so the training loop never blocks on
        disk.  At most one async save in flight; a second call joins the
        first (bounded staleness)."""
        snapshot = jax.tree.map(lambda x: np.asarray(x), tree)
        self.wait()
        self._async_thread = threading.Thread(
            target=self.save, args=(step, snapshot, extra), daemon=True
        )
        self._async_thread.start()

    def wait(self) -> None:
        """Block until any in-flight async save has been published."""
        if self._async_thread is not None:
            self._async_thread.join()
            self._async_thread = None

    def save(self, step: int, tree: Any, extra: Optional[dict] = None) -> str:
        final = os.path.join(self.directory, f"step_{step:010d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        leaves, _ = _flatten(tree)
        arrays = {f"a{i}": arr for i, (_, arr) in enumerate(leaves)}
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        manifest = {
            "step": step,
            "extra": extra or {},
            "leaves": [
                {
                    "path": p,
                    "key": f"a{i}",
                    "shape": list(a.shape),
                    "dtype": str(a.dtype),
                    "crc32": zlib.crc32(np.ascontiguousarray(a).tobytes()),
                }
                for i, (p, a) in enumerate(leaves)
            ],
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._gc()
        return final

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:010d}"))

    # ---------------- restore ----------------

    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(
        self, template: Any, step: Optional[int] = None
    ) -> tuple[Any, dict, int]:
        """Restore into the structure of ``template``.

        Walks back through older checkpoints if the newest fails integrity.
        Returns (tree, extra, step).
        """
        candidates = self.all_steps()
        if step is not None:
            candidates = [s for s in candidates if s == step]
        if not candidates:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        for s in reversed(candidates):
            try:
                return (*self._load(template, s), s)
            except (OSError, ValueError, KeyError, zipfile.BadZipFile) as e:
                # corrupt / truncated / CRC-mismatch: fall back to older
                print(f"checkpoint step {s} failed integrity ({e}); falling back")
        raise FileNotFoundError(f"no valid checkpoint in {self.directory}")

    def _load(self, template: Any, step: int) -> tuple[Any, dict]:
        d = os.path.join(self.directory, f"step_{step:010d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(d, "arrays.npz"))
        by_path = {}
        for leaf in manifest["leaves"]:
            arr = data[leaf["key"]]
            crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
            if crc != leaf["crc32"]:
                raise ValueError(f"crc mismatch at {leaf['path']}")
            by_path[leaf["path"]] = arr
        leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
        out = []
        for p, tmpl in leaves:
            key = _path_str(p)
            if key not in by_path:
                raise KeyError(f"missing leaf {key}")
            arr = by_path[key]
            want = tuple(np.shape(tmpl))
            if tuple(arr.shape) != want:
                raise ValueError(f"shape mismatch at {key}: {arr.shape} vs {want}")
            out.append(arr)
        return jax.tree_util.tree_unflatten(treedef, out), manifest["extra"]


def restore_resharded(tree_host: Any, shardings: Any) -> Any:
    """Place a host-restored pytree onto (possibly different) shardings —
    the elastic-rescale path: save on mesh A, restore onto mesh B."""
    return jax.tree.map(
        lambda a, s: jax.device_put(a, s), tree_host, shardings
    )
