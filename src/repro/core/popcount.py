"""Popcount ('1'-bit count) primitives — the paper's stage 1.

The hardware popcount unit (Fig. 1) computes the Hamming weight of each W-bit
input element with 4-bit LUTs whose outputs are summed by an adder tree.  We
provide:

  * :func:`popcount` — production path (``jax.lax.population_count``).
  * :func:`popcount_lut4` — hardware-faithful 4-bit-LUT + adder formulation,
    used as the oracle for the Pallas kernel and in tests to show equivalence
    with the circuit-level description.
  * :func:`bucket_map` — the APP-PSU coarse-bucket mapping (paper §III-B.2).

All functions are jit-/vmap-safe and operate elementwise on integer arrays.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "popcount",
    "popcount_lut4",
    "bucket_map",
    "bucket_boundaries",
    "num_bucket_bits",
]


def popcount(x: jax.Array, width: int = 8) -> jax.Array:
    """Exact '1'-bit count of each element of ``x``.

    Args:
      x: integer array; only the low ``width`` bits of each element count.
      width: element bit width W (paper uses W=8 fixed-point).

    Returns:
      int32 array of the same shape with values in ``[0, width]``.
    """
    if width < 1 or width > 32:
        raise ValueError(f"width must be in [1, 32], got {width}")
    ux = x.astype(jnp.uint32)
    if width < 32:
        ux = ux & jnp.uint32((1 << width) - 1)
    return jax.lax.population_count(ux).astype(jnp.int32)


def popcount_lut4(x: jax.Array, width: int = 8) -> jax.Array:
    """Hardware-faithful popcount: 4-bit LUT lookups aggregated by adders.

    Mirrors the circuit in Fig. 1: the W-bit input is split into ceil(W/4)
    nibbles, each nibble indexes a 16-entry LUT holding its Hamming weight,
    and the LUT outputs are summed.  Numerically identical to
    :func:`popcount`; kept separate so tests can assert the equivalence the
    paper's synthesis flow relies on.
    """
    if width < 1 or width > 32:
        raise ValueError(f"width must be in [1, 32], got {width}")
    lut = jnp.array([bin(i).count("1") for i in range(16)], dtype=jnp.int32)
    ux = x.astype(jnp.uint32) & jnp.uint32((1 << width) - 1)
    total = jnp.zeros(x.shape, dtype=jnp.int32)
    n_nibbles = (width + 3) // 4
    for n in range(n_nibbles):
        nib = (ux >> jnp.uint32(4 * n)) & jnp.uint32(0xF)
        total = total + lut[nib.astype(jnp.int32)]
    return total


def bucket_boundaries(width: int, k: int) -> list[int]:
    """Exact popcount values assigned to each bucket (python-side helper).

    Returns a list of length ``width + 1`` mapping popcount value -> bucket.
    For W=8, k=4 this reproduces the paper's mapping
    {0,1,2}->0, {3,4}->1, {5,6}->2, {7,8}->3.
    """
    return [(p * k) // (width + 1) for p in range(width + 1)]


def bucket_map(p: jax.Array, width: int = 8, k: int = 4) -> jax.Array:
    """APP-PSU deterministic coarse-bucket mapping (paper §III-B.2).

    Maps exact '1'-bit counts ``p`` in [0, width] to bucket indices in
    [0, k).  The mapping is the uniform partition ``bucket = p*k // (W+1)``,
    which for W=8, k=4 reproduces the paper's example exactly.
    """
    if k < 1 or k > width + 1:
        raise ValueError(f"k must be in [1, width+1]; got k={k}, width={width}")
    return (p.astype(jnp.int32) * k) // (width + 1)


def num_bucket_bits(k: int) -> int:
    """Datapath width of the bucket index: ceil(log2(k)) bits (>=1)."""
    return max(1, (k - 1).bit_length())
