"""Analytical area model — reproduces the paper's Fig. 5 scaling claims.

We have no 22 nm EDA flow, so absolute um^2 are *modeled*; the model is
anchored so the paper's measured points hold exactly (DESIGN.md §6):

  * APP-PSU total area: 2193 um^2 @ N=25, 6928 um^2 @ N=49 (paper §IV-B.3)
  * overall APP vs ACC reduction @ N=25: 35.4 %
  * popcount-unit reduction: 24.9 %; sorting-unit reduction: 36.7 %

Structural form (W = input bit width, K = bucket count, N = sort width):

  popcount(N, out_bits) = A_PC * N * (1 + PRUNE * out_bits)
      -- 4-bit LUTs + adder tree; the approximate unit synthesizes only the
         bucket index, pruning the upper adder levels (out_bits 4 -> 2).
  sort(N, K) = C_NK * N * K  +  C_N2 * N^2 * (1 + BETA * K)
      -- one-hot encode / histogram / prefix-sum scale with N*K; the index
         mapping (scatter crossbar) contributes the N^2 wiring term whose
         control width grows with the one-hot bucket count (BETA).

Baselines for Fig. 5: Batcher bitonic (comparator network, N log^2 N
compare-exchange units) and CSN (constant-time, ~1.8x bitonic logic,
paper §II).  Gate-level constants are representative 22 nm equivalents.
"""

from __future__ import annotations

import dataclasses
import math

__all__ = [
    "PSUArea",
    "psu_area",
    "bitonic_area",
    "csn_area",
    "codec_area",
    "AREA_ANCHORS",
    "PSUTiming",
    "psu_timing",
    "bitonic_timing",
]

# --- calibrated constants (closed-form solve, see DESIGN.md §6) -------------
A_PC = 373.0 / (25 * 1.992)  # popcount scale: 11 % of ACC-PSU total @ N=25
PRUNE = 0.248  # adder-level pruning per output bit (fits the 24.9 % claim)
C_NK = 5.155  # one-hot/histogram/prefix datapath, per element-bucket
C_N2 = 1.642  # scatter crossbar wiring, per element^2
BETA = 0.0904  # crossbar control-width growth per bucket

# gate-level constants for comparator baselines and the link-codec
# encoders (22 nm equivalents, um^2)
_FA_AREA = 1.0  # full adder / 1-bit comparator slice
_MUX_BIT = 0.55  # 2:1 mux per bit
_DFF_BIT = 1.1  # pipeline register per bit
_XOR_BIT = 0.75  # 2-input XOR per bit

AREA_ANCHORS = {
    ("app", 25): 2193.0,
    ("app", 49): 6928.0,
    ("acc", 25): 3394.0,  # derived: 2193 / (1 - 0.354)
}


@dataclasses.dataclass(frozen=True)
class PSUArea:
    """Area breakdown of one transmit-side unit (um^2, modeled).

    ``codec`` is the link-codec encoder sitting after the sorting unit
    (zero when the link is uncoded) — folded in here so any area-vs-BT
    comparison that adds a codec is automatically net of its hardware."""

    popcount: float
    sort: float
    codec: float = 0.0

    @property
    def total(self) -> float:
        return self.popcount + self.sort + self.codec


def psu_area(n: int, width: int = 8, k: int | None = None) -> PSUArea:
    """Area of an ACC-PSU (k=None) or APP-PSU (k buckets) sorting n elements.

    Args:
      n: sort window size (kernel size in the paper: 25 or 49).
      width: input element bit width W.
      k: bucket count for APP; ``None`` means exact (K = W + 1).
    """
    if k is None:
        buckets = width + 1
        out_bits = max(1, math.ceil(math.log2(width + 1)))
    else:
        if not 1 <= k <= width + 1:
            raise ValueError(f"k={k} out of range [1, {width + 1}]")
        buckets = k
        out_bits = max(1, math.ceil(math.log2(k)))
    pc = A_PC * n * (1.0 + PRUNE * out_bits)
    sort = C_NK * n * buckets + C_N2 * n * n * (1.0 + BETA * buckets)
    return PSUArea(popcount=pc, sort=sort)


def _sort_payload_bits(n: int, width: int) -> int:
    """Bits moved per element by a comparator network sorting (key, index)."""
    key_bits = max(1, math.ceil(math.log2(width + 1)))  # popcount key
    idx_bits = max(1, math.ceil(math.log2(n)))
    return key_bits + idx_bits


def bitonic_area(n: int, width: int = 8) -> PSUArea:
    """Batcher bitonic sorting network [10] on popcount keys.

    Compare-exchange count for n padded to a power of two:
    (n/4) * log2(n) * (log2(n)+1); each CE = key comparator + two payload
    muxes; pipeline registers at every stage (same pipeline depth as PSU
    per the paper's synthesis setup).
    """
    n_pad = 1 << max(1, math.ceil(math.log2(n)))
    stages = int(math.log2(n_pad))
    n_ce = n_pad * stages * (stages + 1) // 4
    bits = _sort_payload_bits(n, width)
    ce_area = _FA_AREA * bits + 2 * _MUX_BIT * bits
    reg_area = stages * (stages + 1) // 2 * n_pad * bits * _DFF_BIT * 0.5
    pc = A_PC * n * (1.0 + PRUNE * max(1, math.ceil(math.log2(width + 1))))
    return PSUArea(popcount=pc, sort=n_ce * ce_area + reg_area)


def csn_area(n: int, width: int = 8) -> PSUArea:
    """Competition Sorter Network [11][12]: O(1)-time, ~80 % more logic
    elements than bitonic (paper §II)."""
    b = bitonic_area(n, width)
    return PSUArea(popcount=b.popcount, sort=b.sort * 1.8)


def codec_area(scheme: str, lanes: int, partition: int | None = None) -> float:
    """Encoder area of one link codec over a ``lanes``-byte flit (um^2).

    Gate-count closed forms from the same 22 nm equivalents as the
    comparator baselines (DESIGN.md §11):

      * ``gray``           — 7 XOR per byte (top bit passes through).
      * ``sign_magnitude`` — conditional two's-complement negate per byte:
        an 8-bit ripple increment plus sign-controlled inversion muxes.
      * ``transition``     — XOR per wire bit plus the previous-flit
        register the feedback needs.
      * ``bus_invert``     — per partition of ``partition`` lanes (None =
        whole flit): popcount tree over the group bits (~1 FA/bit),
        majority comparator (log2 of the group width), inversion XORs and
        the previous-wire register, plus the invert-line driver flop.
    """
    bits = 8 * lanes
    if scheme == "none":
        return 0.0
    if scheme == "gray":
        return 7.0 * lanes * _XOR_BIT
    if scheme == "sign_magnitude":
        return lanes * (8 * _FA_AREA + 8 * _MUX_BIT)
    if scheme == "transition":
        return bits * (_XOR_BIT + _DFF_BIT)
    if scheme == "bus_invert":
        from .coding import bus_invert_partitions  # the one partition home

        npart, pw = bus_invert_partitions(lanes, partition)
        group_bits = 8 * pw
        per_group = (
            group_bits * (_FA_AREA + _XOR_BIT + _DFF_BIT)  # tree+inv+reg
            + math.ceil(math.log2(group_bits)) * _FA_AREA  # majority cmp
            + _DFF_BIT  # invert-line flop
        )
        return npart * per_group
    raise ValueError(f"unknown codec scheme {scheme!r} for the area model")


# --------------------------------------------------------------------------
# timing model (paper targets 500 MHz, "same pipeline depth" for all designs)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PSUTiming:
    """Pipelined sorting-unit timing at the paper's 500 MHz clock."""

    latency_cycles: int  # input-to-first-index latency
    throughput_elems_per_cycle: float
    clock_mhz: float = 500.0

    @property
    def latency_ns(self) -> float:
        return self.latency_cycles / self.clock_mhz * 1e3

    def sort_time_ns(self, n: int) -> float:
        return (self.latency_cycles + n / self.throughput_elems_per_cycle) \
            / self.clock_mhz * 1e3


def psu_timing(n: int, width: int = 8, k: int | None = None) -> PSUTiming:
    """Comparison-free PSU: O(N) single-pass — popcount (1 cycle), one-hot +
    histogram accumulate (streamed, 1 elem/cycle), prefix sum over K buckets
    (log2 K cycles), scatter (streamed).  APP's narrower bucket index
    shortens the prefix stage (k=4: 2 cycles vs 4 for exact W=8)."""
    buckets = (width + 1) if k is None else k
    prefix = max(1, math.ceil(math.log2(buckets)))
    # stages: popcount(1) + encode(1) + prefix(log2 K) + scatter(1)
    return PSUTiming(latency_cycles=3 + prefix, throughput_elems_per_cycle=1.0)


def bitonic_timing(n: int) -> PSUTiming:
    """Batcher network: log2(n)*(log2(n)+1)/2 pipelined compare stages."""
    n_pad = 1 << max(1, math.ceil(math.log2(n)))
    s = int(math.log2(n_pad))
    return PSUTiming(latency_cycles=s * (s + 1) // 2, throughput_elems_per_cycle=float(n))
