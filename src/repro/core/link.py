"""Link model: flit framing, stream assembly and the link power model.

The paper's platform transmits packets over a 128-bit link: each packet is 4
flits, each flit carries 8 input bytes and 8 paired weight bytes (DESIGN.md
§1).  This module packs (reordered) packet payloads into flit streams and
provides the dynamic-power model used for Fig. 6/7:

    P_link ∝ alpha · C · V^2 · f,  alpha ∝ BT per flit

so *link-related power reduction = transfer_factor × BT reduction*, where the
transfer factor < 1 absorbs the non-data switching floor (clock, control) of
the transmission registers.  Calibrated from the paper: ACC 20.42 % BT ->
18.27 % power gives transfer_factor ≈ 0.895.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

import jax
import jax.numpy as jnp

from .bt import BTReport, bt_report
from .ordering import make_order

__all__ = ["LinkConfig", "pack_to_flits", "paired_stream", "LinkPowerModel"]

PackOrder = Literal["row", "lane"]


@dataclasses.dataclass(frozen=True)
class LinkConfig:
    """Framing of the evaluation link (defaults = paper's Table-I setup)."""

    width_bits: int = 128  # physical link width
    flits_per_packet: int = 4
    input_lanes: int = 8  # bytes of input data per flit
    weight_lanes: int = 8  # bytes of weight data per flit

    @property
    def bytes_per_flit(self) -> int:
        return self.width_bits // 8

    @property
    def elems_per_packet(self) -> int:
        """(input, weight) pairs carried per packet."""
        return self.flits_per_packet * self.input_lanes

    def __post_init__(self) -> None:
        if self.input_lanes + self.weight_lanes != self.bytes_per_flit:
            raise ValueError(
                "input_lanes + weight_lanes must fill the flit: "
                f"{self.input_lanes}+{self.weight_lanes} != {self.bytes_per_flit}"
            )


def pack_to_flits(
    values: jax.Array, lanes: int, pack: PackOrder = "lane"
) -> jax.Array:
    """Pack (P, N) packet payloads into (P, flits, lanes) flit halves.

    ``pack="lane"`` places consecutive payload elements in the *same lane* of
    consecutive flits (element e of a packet -> flit e % F, lane e // F), so a
    popcount-sorted payload yields monotone lane streams — this is the
    packing the transmitting unit uses after the PSU (paper Fig. 2 shows the
    resulting per-flit popcount trend).  ``pack="row"`` is plain row-major.
    """
    p, n = values.shape
    if n % lanes != 0:
        raise ValueError(f"payload size {n} not divisible by lanes {lanes}")
    flits = n // lanes
    if pack == "row":
        return values.reshape(p, flits, lanes)
    if pack == "lane":
        return values.reshape(p, lanes, flits).transpose(0, 2, 1)
    raise ValueError(f"unknown pack order {pack!r}")


def paired_stream(
    inputs: jax.Array,
    weights: jax.Array,
    cfg: LinkConfig = LinkConfig(),
    strategy: str = "none",
    pack: PackOrder = "lane",
    **order_kwargs: object,
) -> jax.Array:
    """Assemble the full link stream for P packets of (input, weight) pairs.

    Applies ``strategy`` per packet (deriving the order from the input side,
    moving the paired weights along), packs both halves into flits and
    concatenates packets into one (P*F, bytes_per_flit) uint8 stream.
    """
    if inputs.shape != weights.shape:
        raise ValueError(f"paired shapes differ: {inputs.shape} vs {weights.shape}")
    if inputs.shape[-1] != cfg.elems_per_packet:
        raise ValueError(
            f"packet payload {inputs.shape[-1]} != "
            f"flits*input_lanes = {cfg.elems_per_packet}"
        )
    order = make_order(strategy, inputs, lanes=cfg.input_lanes, **order_kwargs)
    inp = jnp.take_along_axis(inputs, order, axis=-1)
    wgt = jnp.take_along_axis(weights, order, axis=-1)
    fi = pack_to_flits(inp, cfg.input_lanes, pack)
    fw = pack_to_flits(wgt, cfg.weight_lanes, pack)
    flits = jnp.concatenate([fi, fw], axis=-1)  # (P, F, bytes_per_flit)
    return flits.reshape(-1, cfg.bytes_per_flit).astype(jnp.uint8)


def measure(
    inputs: jax.Array,
    weights: jax.Array,
    cfg: LinkConfig = LinkConfig(),
    strategy: str = "none",
    pack: PackOrder = "lane",
    **order_kwargs: object,
) -> BTReport:
    """One-call Table-I measurement for a strategy."""
    stream = paired_stream(inputs, weights, cfg, strategy, pack, **order_kwargs)
    return bt_report(stream, cfg.input_lanes)


@dataclasses.dataclass(frozen=True)
class LinkPowerModel:
    """Dynamic-power model for link-related power (Fig. 6/7).

    ``transfer_factor`` maps BT reduction to link-related power reduction
    (non-data switching floor of the transmission registers); calibrated to
    the paper's ACC point (20.42 % BT -> 18.27 % power).
    ``energy_per_transition_pj`` sets the absolute scale (representative
    22 nm on-chip wire; absolute numbers are modeled, ratios are the claim).
    """

    transfer_factor: float = 18.27 / 20.42
    energy_per_transition_pj: float = 0.18
    static_flit_energy_pj: float = 2.0  # clock/control floor per flit

    def link_energy_pj(self, total_bt: float, num_flits: int) -> float:
        return (
            self.energy_per_transition_pj * float(total_bt)
            + self.static_flit_energy_pj * float(num_flits)
        )

    def power_reduction(self, bt_reduction: float) -> float:
        """Link-related power reduction predicted from a BT reduction."""
        return self.transfer_factor * bt_reduction
