"""DEPRECATED shim — the link model moved to :mod:`repro.link`.

``LinkConfig`` (now an alias of :class:`repro.link.LinkSpec`), flit packing,
paired-stream assembly and the power model live in the TX-pipeline
subsystem; this module re-exports them so old imports keep working.  New
code should import from ``repro.link`` (and prefer
``repro.link.TxPipeline`` over the one-call ``measure``).
"""

from __future__ import annotations

from repro.link.framing import (  # noqa: F401
    LinkConfig,
    measure,
    pack_to_flits,
    paired_stream,
)
from repro.link.power import LinkPowerModel  # noqa: F401

__all__ = ["LinkConfig", "pack_to_flits", "paired_stream", "measure", "LinkPowerModel"]
