"""Bit-transition (BT) counting — the paper's evaluation metric.

Dynamic link power is proportional to switching activity: each bit that flips
between consecutive flits on a W-bit link charges/discharges wire capacitance
(paper §I).  BT of a flit stream is therefore the Hamming distance between
consecutive flits, summed over the stream.

Streams are represented as uint8 arrays shaped ``(num_flits, bytes_per_flit)``;
a 128-bit link has ``bytes_per_flit = 16``.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .popcount import popcount

__all__ = ["bit_transitions", "bt_per_flit", "BTReport", "bt_report"]


def bit_transitions(stream: jax.Array, width: int = 8) -> jax.Array:
    """Total bit transitions over a flit stream.

    Args:
      stream: (T, B) integer array; element [t, b] is byte lane b of flit t.
      width: bits per element (8 for byte lanes).

    Returns:
      int32 scalar: sum over t of HammingDistance(flit_t, flit_{t+1}).
    """
    a = stream.astype(jnp.uint32)
    flips = jnp.bitwise_xor(a[1:], a[:-1])
    return popcount(flips, width).sum()


def bt_per_flit(stream: jax.Array, width: int = 8) -> jax.Array:
    """Average BT per transmitted flit (the paper's Table-I normalisation).

    The paper reports "Bit Transitions per 128-bit flit" = total BT divided by
    the number of flits sent (boundaries = flits - 1, which for 400 000 flits
    is indistinguishable from flits).
    """
    t = stream.shape[0]
    return bit_transitions(stream, width) / jnp.maximum(t, 1)


class BTReport(NamedTuple):
    """Per-side BT accounting matching Table I columns."""

    input_bt_per_flit: jax.Array
    weight_bt_per_flit: jax.Array
    overall_bt_per_flit: jax.Array

    def reduction_vs(self, base: "BTReport") -> jax.Array:
        """Overall BT reduction relative to a baseline report (fraction)."""
        return 1.0 - self.overall_bt_per_flit / base.overall_bt_per_flit


def bt_report(stream: jax.Array, input_lanes: int, width: int = 8) -> BTReport:
    """Split BT between the input half and weight half of each flit.

    The Table-I link carries input bytes in lanes [0, input_lanes) and weight
    bytes in the remaining lanes (DESIGN.md §1: 128-bit flit = 64-bit input +
    64-bit weight for the paired framing).
    """
    inp = bt_per_flit(stream[:, :input_lanes], width)
    wgt = bt_per_flit(stream[:, input_lanes:], width)
    return BTReport(inp, wgt, inp + wgt)
