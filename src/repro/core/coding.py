"""Stateless wire byte recodes — the bijective per-byte maps of the link
codecs (DESIGN.md §11).

These are the shared primitives of the codec subsystem: ``repro.codec``
builds its stateless encode/decode pairs from them, and the Pallas codec
kernel (``repro.kernels.axes``) applies the same maps inside one
launch, so the two paths cannot drift.  Every function operates on the low
8 bits of any integer array and returns the input dtype (uint8 streams
outside kernels, int32 lanes inside them).

  * **gray**            — reflected binary: g = b ^ (b >> 1).  Consecutive
    values differ in one bit, decorrelating BT from carry ripples.
  * **sign-magnitude**  — two's-complement int8 bytes to sign|magnitude
    (the ``repro.link`` 'sign_magnitude' encode stage, made invertible
    here: 0x80, the lone -128 pattern, maps to 0x80).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "gray_encode_bytes",
    "gray_decode_bytes",
    "sign_magnitude_encode_bytes",
    "sign_magnitude_decode_bytes",
    "bus_invert_partitions",
]


def bus_invert_partitions(lanes: int, partition: int | None) -> tuple[int, int]:
    """(number of partitions, lanes per partition) of a bus-invert framing.

    The one home of the partition contract — the codec encoders
    (``repro.codec.schemes``), the single-launch kernel
    (``repro.kernels.axes``) and the area model
    (``repro.core.area.codec_area``) all validate against this, so they
    cannot drift.  ``partition=None`` means one invert line over the whole
    flit; otherwise it must divide the flit's lane count.
    """
    pw = lanes if partition is None else partition
    if pw < 1 or lanes % pw != 0:
        raise ValueError(
            f"bus-invert partition of {pw} lanes does not divide the "
            f"{lanes}-lane flit"
        )
    return lanes // pw, pw


def gray_encode_bytes(x: jax.Array) -> jax.Array:
    """Reflected-binary Gray code of each byte: g = b ^ (b >> 1)."""
    v = x.astype(jnp.int32) & 0xFF
    return ((v ^ (v >> 1)) & 0xFF).astype(x.dtype)


def gray_decode_bytes(g: jax.Array) -> jax.Array:
    """Inverse Gray map per byte: b = g ^ (g>>1) ^ ... ^ (g>>7), folded."""
    v = g.astype(jnp.int32) & 0xFF
    for s in (1, 2, 4):  # prefix-XOR fold over the 8 bit positions
        v = v ^ (v >> s)
    return (v & 0xFF).astype(g.dtype)


def sign_magnitude_encode_bytes(x: jax.Array) -> jax.Array:
    """Two's-complement int8 byte patterns to sign|magnitude bytes.

    Matches ``repro.link.stages.to_sign_magnitude`` on every byte
    (including -128 -> 0x80, which keeps the map bijective: 0x80 is the
    only pattern with sign set and zero magnitude).
    """
    v = x.astype(jnp.int32) & 0xFF
    neg = v >= 0x80
    mag = jnp.where(neg, (0x100 - v) & 0xFF, v)
    out = jnp.where(neg, 0x80 | (mag & 0x7F), mag)
    return (out & 0xFF).astype(x.dtype)


def sign_magnitude_decode_bytes(s: jax.Array) -> jax.Array:
    """Inverse of :func:`sign_magnitude_encode_bytes` per byte."""
    v = s.astype(jnp.int32) & 0xFF
    mag = v & 0x7F
    neg = v >= 0x80
    out = jnp.where(neg, jnp.where(mag == 0, 0x80, (0x100 - mag) & 0xFF), mag)
    return (out & 0xFF).astype(s.dtype)
