"""DEPRECATED shim — ordering strategies moved to :mod:`repro.link.stages`.

The four Table-I strategies ('none', 'column_major', 'acc', 'app') are now
key stages of the unified TX pipeline (every strategy is "derive keys, then
stable counting sort" — the data-independent ones degenerate to fixed
permutations).  This module re-exports the legacy API so old imports keep
working; new code should use ``repro.link`` (``KEY_STAGES`` /
``TxPipeline``).
"""

from __future__ import annotations

from repro.link.stages import (  # noqa: F401
    ORDER_STRATEGIES,
    make_order,
    order_packets,
)

__all__ = ["make_order", "ORDER_STRATEGIES", "order_packets"]
