"""Transmit-ordering strategies (paper §IV, Table I).

Four strategies are evaluated in the paper:

  * ``none``          — non-optimized baseline: stream order as produced.
  * ``column_major``  — layout reordering: traverse the packet's
                        (flits x lanes) matrix column-major.  Helps when the
                        stream has lane-periodic structure (im2col patches).
  * ``acc``           — ACC-PSU: stable sort by exact '1'-bit count.
  * ``app``           — APP-PSU: stable sort by k-bucket approximate count.

A strategy maps the *input-side* values of each packet to a permutation; the
transmitting units apply the same permutation to every stream that shares the
packet framing (paper: the paired weight bytes move with their inputs, which
is what keeps the MAC accumulation legal — the (input, weight) products are
summed order-insensitively).
"""

from __future__ import annotations

from typing import Callable, Dict

import jax
import jax.numpy as jnp

from .sorting import acc_sort_indices, app_sort_indices

__all__ = ["make_order", "ORDER_STRATEGIES", "order_packets"]


def _order_none(values: jax.Array, **_: object) -> jax.Array:
    n = values.shape[-1]
    order = jnp.arange(n, dtype=jnp.int32)
    return jnp.broadcast_to(order, values.shape)


def _order_column_major(
    values: jax.Array, *, lanes: int = 8, **_: object
) -> jax.Array:
    """Permutation that re-traverses the (flits, lanes) packet matrix
    column-major.  Element at (f, l) is visited in order l*flits + f."""
    n = values.shape[-1]
    if n % lanes != 0:
        raise ValueError(f"packet size {n} not divisible by lanes {lanes}")
    flits = n // lanes
    order = jnp.arange(n, dtype=jnp.int32).reshape(flits, lanes).T.reshape(n)
    return jnp.broadcast_to(order, values.shape)


def _order_acc(
    values: jax.Array, *, width: int = 8, descending: bool = False, **_: object
) -> jax.Array:
    return acc_sort_indices(values, width=width, descending=descending)


def _order_app(
    values: jax.Array,
    *,
    width: int = 8,
    k: int = 4,
    descending: bool = False,
    **_: object,
) -> jax.Array:
    return app_sort_indices(values, width=width, k=k, descending=descending)


ORDER_STRATEGIES: Dict[str, Callable[..., jax.Array]] = {
    "none": _order_none,
    "column_major": _order_column_major,
    "acc": _order_acc,
    "app": _order_app,
}


def make_order(strategy: str, values: jax.Array, **kwargs: object) -> jax.Array:
    """Per-packet element order for ``strategy``.

    Args:
      strategy: one of ``ORDER_STRATEGIES``.
      values: (..., N) uint8 input-side packet values the order is derived
        from (ACC/APP sort keys come from these).
      kwargs: strategy parameters (width, k, lanes, descending).

    Returns:
      int32 (..., N) permutation per packet; gather with it to reorder.
    """
    try:
        fn = ORDER_STRATEGIES[strategy]
    except KeyError:
        raise ValueError(
            f"unknown ordering strategy {strategy!r}; "
            f"choose from {sorted(ORDER_STRATEGIES)}"
        ) from None
    return fn(values, **kwargs)


def order_packets(
    strategy: str,
    inputs: jax.Array,
    weights: jax.Array | None = None,
    **kwargs: object,
) -> tuple[jax.Array, jax.Array | None]:
    """Reorder packets of (input, weight) pairs with one strategy.

    Args:
      inputs: (P, N) uint8 — P packets of N input bytes.
      weights: optional (P, N) uint8 paired weights (move with the inputs).

    Returns:
      (ordered_inputs, ordered_weights_or_None).
    """
    order = make_order(strategy, inputs, **kwargs)
    out_i = jnp.take_along_axis(inputs, order, axis=-1)
    out_w = (
        jnp.take_along_axis(weights, order, axis=-1) if weights is not None else None
    )
    return out_i, out_w
