"""Comparison-free popcount sorting — ACC-PSU and APP-PSU (paper §III).

The hardware unit (Fig. 1) has three stages after popcount:

  1. one-hot encode each '1'-bit count (or bucket index),
  2. frequency histogram + prefix sum  -> per-value start addresses,
  3. index mapping: scatter element index i to address
     ``start[key_i] + (#earlier elements with the same key)``.

That is exactly a *stable counting sort*.  We implement the same dataflow in
JAX, batched over a leading packet axis, so the software model, the Pallas
kernel (``repro.kernels.psu``) and the RTL description share one structure.

TPU adaptation note (DESIGN.md §3): the hardware scatter stage writes to an
SRAM at computed addresses; random scatter is slow on TPU, so the permutation
is materialised with a one-hot matmul (MXU-friendly).  Both formulations are
provided and tested equal.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .popcount import bucket_map, popcount

__all__ = [
    "counting_sort_ranks",
    "counting_sort_indices",
    "acc_sort_indices",
    "app_sort_indices",
    "apply_order",
    "invert_permutation",
]


def counting_sort_ranks(keys: jax.Array, num_buckets: int) -> jax.Array:
    """Stable counting-sort *ranks* (the hardware 'index mapping' addresses).

    Args:
      keys: int array (..., N) with values in [0, num_buckets).
      num_buckets: number of distinct key values (W+1 for ACC, k for APP).

    Returns:
      int32 (..., N): ``rank[i]`` = output position of input element i.
      Stable: equal keys keep their input order.
    """
    keys = keys.astype(jnp.int32)
    onehot = jax.nn.one_hot(keys, num_buckets, dtype=jnp.int32)  # (..., N, K)
    hist = onehot.sum(axis=-2)  # (..., K)          stage: frequency histogram
    starts = jnp.cumsum(hist, axis=-1) - hist  # exclusive prefix sum
    within = jnp.cumsum(onehot, axis=-2) - onehot  # earlier same-key count
    start_i = jnp.take_along_axis(
        jnp.broadcast_to(starts[..., None, :], onehot.shape),
        keys[..., None],
        axis=-1,
    )[..., 0]
    within_i = jnp.take_along_axis(within, keys[..., None], axis=-1)[..., 0]
    return start_i + within_i


def invert_permutation(perm: jax.Array) -> jax.Array:
    """Invert a (batched) permutation via one-hot matmul (TPU-friendly).

    ``out[perm[i]] = i`` without random scatter: builds the one-hot matrix of
    ``perm`` and contracts it with ``arange`` — the MXU form of the hardware
    index-mapping SRAM write (DESIGN.md §3).
    """
    n = perm.shape[-1]
    onehot = jax.nn.one_hot(perm, n, dtype=jnp.int32)  # (..., N, N)
    idx = jnp.arange(n, dtype=jnp.int32)
    # out[j] = sum_i onehot[i, j] * i
    return jnp.einsum("...ij,i->...j", onehot, idx)


def counting_sort_indices(keys: jax.Array, num_buckets: int) -> jax.Array:
    """Stable sorted order: ``order[j]`` = input index of the j-th output.

    ``order = inverse(rank)``; gathering data with ``order`` yields the
    sorted stream the transmitting unit puts on the link.
    """
    return invert_permutation(counting_sort_ranks(keys, num_buckets))


@partial(jax.jit, static_argnames=("width", "descending"))
def acc_sort_indices(
    values: jax.Array, width: int = 8, descending: bool = False
) -> jax.Array:
    """ACC-PSU: stable sort order of ``values`` (..., N) by exact popcount."""
    keys = popcount(values, width)
    if descending:
        keys = width - keys
    return counting_sort_indices(keys, width + 1)


@partial(jax.jit, static_argnames=("width", "k", "descending"))
def app_sort_indices(
    values: jax.Array, width: int = 8, k: int = 4, descending: bool = False
) -> jax.Array:
    """APP-PSU: stable sort order by the k-bucket approximate popcount."""
    keys = bucket_map(popcount(values, width), width, k)
    if descending:
        keys = (k - 1) - keys
    return counting_sort_indices(keys, k)


def apply_order(data: jax.Array, order: jax.Array) -> jax.Array:
    """Permute elements along the last axis: ``out[..., j] = data[..., order[j]]``.

    This is the transmitting unit's rearrangement step (paper §III-A).
    Supports batched ``order`` matching data's leading dims.
    """
    return jnp.take_along_axis(data, order, axis=-1)
