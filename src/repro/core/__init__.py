# The paper's primary contribution: comparison-free popcount sorting
# (ACC-PSU / APP-PSU) for link bit-transition reduction, plus the BT /
# link-power / area models used to evaluate it.
from .popcount import (
    bucket_boundaries,
    bucket_map,
    num_bucket_bits,
    popcount,
    popcount_lut4,
)
from .sorting import (
    acc_sort_indices,
    app_sort_indices,
    apply_order,
    counting_sort_indices,
    counting_sort_ranks,
    invert_permutation,
)
from .ordering import ORDER_STRATEGIES, make_order, order_packets
from .bt import BTReport, bit_transitions, bt_per_flit, bt_report
from .link import LinkConfig, LinkPowerModel, pack_to_flits, paired_stream, measure
from .area import (
    AREA_ANCHORS,
    PSUArea,
    PSUTiming,
    bitonic_area,
    bitonic_timing,
    csn_area,
    psu_area,
    psu_timing,
)

__all__ = [
    "popcount",
    "popcount_lut4",
    "bucket_map",
    "bucket_boundaries",
    "num_bucket_bits",
    "counting_sort_ranks",
    "counting_sort_indices",
    "acc_sort_indices",
    "app_sort_indices",
    "apply_order",
    "invert_permutation",
    "make_order",
    "order_packets",
    "ORDER_STRATEGIES",
    "bit_transitions",
    "bt_per_flit",
    "bt_report",
    "BTReport",
    "LinkConfig",
    "LinkPowerModel",
    "pack_to_flits",
    "paired_stream",
    "measure",
    "psu_area",
    "bitonic_area",
    "csn_area",
    "PSUArea",
    "AREA_ANCHORS",
    "PSUTiming",
    "psu_timing",
    "bitonic_timing",
]
