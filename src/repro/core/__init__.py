# The paper's primary contribution: comparison-free popcount sorting
# (ACC-PSU / APP-PSU) for link bit-transition reduction, plus the BT /
# link-power / area models used to evaluate it.
#
# The ordering-strategy and link-framing APIs moved to the repro.link
# TX-pipeline subsystem; they are re-exported here LAZILY (PEP 562) through
# the repro.core.ordering / repro.core.link shims so legacy imports keep
# working without creating an import cycle (repro.link itself depends on
# repro.core.bt / repro.core.sorting).
from .popcount import (
    bucket_boundaries,
    bucket_map,
    num_bucket_bits,
    popcount,
    popcount_lut4,
)
from .sorting import (
    acc_sort_indices,
    app_sort_indices,
    apply_order,
    counting_sort_indices,
    counting_sort_ranks,
    invert_permutation,
)
from .bt import BTReport, bit_transitions, bt_per_flit, bt_report
from .area import (
    AREA_ANCHORS,
    PSUArea,
    PSUTiming,
    bitonic_area,
    bitonic_timing,
    codec_area,
    csn_area,
    psu_area,
    psu_timing,
)

_LINK_SHIM = {
    # repro.core.ordering -> repro.link.stages
    "make_order": "ordering",
    "order_packets": "ordering",
    "ORDER_STRATEGIES": "ordering",
    # repro.core.link -> repro.link.framing / repro.link.power
    "LinkConfig": "link",
    "LinkPowerModel": "link",
    "pack_to_flits": "link",
    "paired_stream": "link",
    "measure": "link",
}


def __getattr__(name: str):
    shim = _LINK_SHIM.get(name)
    if shim is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib

    return getattr(importlib.import_module(f"{__name__}.{shim}"), name)


__all__ = [
    "popcount",
    "popcount_lut4",
    "bucket_map",
    "bucket_boundaries",
    "num_bucket_bits",
    "counting_sort_ranks",
    "counting_sort_indices",
    "acc_sort_indices",
    "app_sort_indices",
    "apply_order",
    "invert_permutation",
    "make_order",
    "order_packets",
    "ORDER_STRATEGIES",
    "bit_transitions",
    "bt_per_flit",
    "bt_report",
    "BTReport",
    "LinkConfig",
    "LinkPowerModel",
    "pack_to_flits",
    "paired_stream",
    "measure",
    "psu_area",
    "bitonic_area",
    "csn_area",
    "codec_area",
    "PSUArea",
    "AREA_ANCHORS",
    "PSUTiming",
    "psu_timing",
    "bitonic_timing",
]
