"""SAIF / VCD export of measured wire activity (DESIGN.md §15).

``write_saif`` serializes :class:`~repro.obs.activity.ActivityProfile`s as
a standard backward-SAIF file — per net, ``T0``/``T1`` (time at 0/1, in
flit units) and ``TC`` (toggle count) — the exchange format EDA power
flows (PrimeTime PX, OpenSTA, ...) consume, so the kernels' measured
activity can drive an independent power estimate without re-simulation.
``parse_saif`` round-trips the format (pinned in tests, and handy for
reading third-party SAIF back into profiles).  ``write_vcd`` dumps an
actual coded wire stream as a value-change waveform for eyeballing in
GTKWave.

Time unit: ONE FLIT.  SAIF ``DURATION`` is the longest profile's flit
count; per net ``T0 = DURATION − T1`` (a link idle past its own traffic
holds its wires at 0), so ``T0 + T1 == DURATION`` on every net.
"""

from __future__ import annotations

import os
import re
from typing import Sequence

import numpy as np

from .activity import ActivityProfile, wire_name

__all__ = ["write_saif", "parse_saif", "write_vcd"]


def _ensure_parent(path: str) -> None:
    parent = os.path.dirname(path)
    if parent:
        os.makedirs(parent, exist_ok=True)


def _sanitize(name: str) -> str:
    """SAIF/VCD identifiers: collapse anything non-word to '_'."""
    return re.sub(r"\W", "_", name) or "_"


def write_saif(
    path: str,
    profiles: Sequence[ActivityProfile] | ActivityProfile,
    *,
    design: str = "repro",
    timescale: str = "1 ns",
) -> str:
    """Write profiles as one backward-SAIF file; returns the text.

    Each profile becomes one ``INSTANCE`` under the design top, each wire
    one ``NET`` entry named per DESIGN.md §15 (``lane<l>_b<b>`` /
    ``inv<p>``).  ``TX`` and ``IG`` are 0 — the measurement has no unknown
    or glitch states.
    """
    if isinstance(profiles, ActivityProfile):
        profiles = [profiles]
    if not profiles:
        raise ValueError("write_saif: no profiles")
    duration = max(p.duration_flits for p in profiles)
    lines = [
        "(SAIFILE",
        '(SAIFVERSION "2.0")',
        '(DIRECTION "backward")',
        f'(DESIGN "{_sanitize(design)}")',
        "(DIVIDER / )",
        f"(TIMESCALE {timescale})",
        f"(DURATION {duration})",
        f"(INSTANCE {_sanitize(design)}",
    ]
    for p in profiles:
        pw, t1 = p.per_wire, p.t1
        lines.append(f"  (INSTANCE {_sanitize(p.name)}")
        lines.append("    (NET")
        for i in range(p.num_wires):
            net = wire_name(i, p.data_lanes)
            one = int(t1[i])
            lines.append(f"      ({net}")
            lines.append(
                f"        (T0 {duration - one}) (T1 {one}) (TX 0)"
                f" (TC {int(pw[i])}) (IG 0)"
            )
            lines.append("      )")
        lines.append("    )")
        lines.append("  )")
    lines.append(")")
    lines.append(")")
    text = "\n".join(lines) + "\n"
    _ensure_parent(path)
    with open(path, "w") as f:
        f.write(text)
    return text


# --------------------------------------------------------------- SAIF parse
def _sexpr_tokens(text: str) -> list[str]:
    return re.findall(r'\(|\)|"[^"]*"|[^\s()]+', text)


def _sexpr_parse(tokens: list[str], pos: int = 0):
    """One nested list per parenthesized group; returns (tree, next_pos)."""
    if tokens[pos] != "(":
        return tokens[pos], pos + 1
    out: list = []
    pos += 1
    while tokens[pos] != ")":
        node, pos = _sexpr_parse(tokens, pos)
        out.append(node)
    return out, pos + 1


def parse_saif(path: str) -> dict:
    """Read a SAIF file back into a plain dict:

    ``{"duration": int, "timescale": str, "design": str,
    "instances": {name: {net: {"T0","T1","TX","TC","IG"}}}}``

    Nested instances flatten to '/'-joined names (the top design instance
    is dropped from the prefix).
    """
    with open(path) as f:
        text = f.read()
    tree, _ = _sexpr_parse(_sexpr_tokens(text))
    if not tree or tree[0] != "SAIFILE":
        raise ValueError(f"{path}: not a SAIF file")
    doc: dict = {"duration": 0, "timescale": "", "design": "", "instances": {}}

    def walk_instance(node: list, prefix: str) -> None:
        name = node[1] if len(node) > 1 and isinstance(node[1], str) else "?"
        full = f"{prefix}/{name}" if prefix else name
        for child in node[2:]:
            if not isinstance(child, list):
                continue
            if child[0] == "INSTANCE":
                walk_instance(child, full)
            elif child[0] == "NET":
                nets = doc["instances"].setdefault(full, {})
                for net in child[1:]:
                    counts = {}
                    for item in net[1:]:
                        if isinstance(item, list) and len(item) == 2:
                            counts[item[0]] = int(item[1])
                    nets[net[0]] = counts

    for node in tree[1:]:
        if not isinstance(node, list):
            continue
        key = node[0]
        if key == "DURATION":
            doc["duration"] = int(node[1])
        elif key == "TIMESCALE":
            doc["timescale"] = " ".join(node[1:])
        elif key == "DESIGN":
            doc["design"] = str(node[1]).strip('"')
        elif key == "INSTANCE":
            # the design top: recurse with an empty prefix so instance
            # names in the doc match the profile names 1:1
            for child in node[2:]:
                if isinstance(child, list) and child[0] == "INSTANCE":
                    walk_instance(child, "")
                elif isinstance(child, list) and child[0] == "NET":
                    nets = doc["instances"].setdefault(
                        str(node[1]) if len(node) > 1 else "?", {}
                    )
                    for net in child[1:]:
                        counts = {}
                        for item in net[1:]:
                            if isinstance(item, list) and len(item) == 2:
                                counts[item[0]] = int(item[1])
                        nets[net[0]] = counts
    return doc


# ---------------------------------------------------------------------- VCD
def _vcd_id(i: int) -> str:
    """Short VCD identifier for wire i (printable ASCII 33..126)."""
    chars = ""
    i += 1
    while i:
        i, r = divmod(i - 1, 94)
        chars = chr(33 + r) + chars
    return chars


def write_vcd(
    path: str,
    stream,
    *,
    inverts=None,
    name: str = "link",
    timescale: str = "1 ns",
) -> str:
    """Dump an actual (T, lanes) coded byte stream as a VCD waveform.

    One VCD time unit per flit row; every data bit is a 1-bit wire named
    ``lane<l>_b<b>`` (LSB first, matching the SAIF nets) and an optional
    (T, npart) ``inverts`` array adds the ``inv<p>`` aux wires.  Returns
    the text.
    """
    arr = np.asarray(stream, dtype=np.int64) & 0xFF
    if arr.ndim != 2:
        raise ValueError(f"stream must be (T, lanes), got {arr.shape}")
    t, lanes = arr.shape
    bits = ((arr[:, :, None] >> np.arange(8)) & 1).reshape(t, lanes * 8)
    if inverts is not None:
        inv = np.asarray(inverts, dtype=np.int64) & 1
        if inv.shape[0] != t:
            raise ValueError(
                f"inverts rows {inv.shape[0]} != stream rows {t}"
            )
        bits = np.concatenate([bits, inv], axis=1)
    nwires = bits.shape[1]
    ids = [_vcd_id(i) for i in range(nwires)]
    lines = [
        f"$timescale {timescale} $end",
        f"$scope module {_sanitize(name)} $end",
    ]
    for i in range(nwires):
        lines.append(f"$var wire 1 {ids[i]} {wire_name(i, lanes)} $end")
    lines += ["$upscope $end", "$enddefinitions $end", "#0", "$dumpvars"]
    for i in range(nwires):
        lines.append(f"{bits[0, i] if t else 0}{ids[i]}")
    lines.append("$end")
    for row in range(1, t):
        changed = np.nonzero(bits[row] != bits[row - 1])[0]
        if changed.size == 0:
            continue
        lines.append(f"#{row}")
        for i in changed:
            lines.append(f"{bits[row, i]}{ids[i]}")
    lines.append(f"#{t}")
    text = "\n".join(lines) + "\n"
    _ensure_parent(path)
    with open(path, "w") as f:
        f.write(text)
    return text
