"""Reports over collected telemetry: per-link BT tables, top-N hottest
links, CSV/JSON heatmap dumps (DESIGN.md §14).

Everything here reads a :class:`~repro.obs.metrics.Registry` populated by
the ``noc.link`` / ``link.report`` / ``dse.link`` probes and emits the
same flat-scalar record style as ``repro.dse.report`` — one dict per link
with JSON-safe values — so the artifacts diff cleanly and slot next to
the DSE front JSON/CSV in the bench trajectory.
"""

from __future__ import annotations

import csv
import json
import os
from typing import Sequence

from .metrics import Registry, registry_from_dict

__all__ = [
    "link_table",
    "top_links",
    "format_links",
    "write_links_csv",
    "metrics_dict",
    "write_metrics_json",
    "read_metrics_json",
]

LINK_FIELDS = (
    "link",
    "src",
    "dst",
    "bt_input",
    "bt_weight",
    "aux_bt",
    "gross_bt",
    "num_flits",
    "bt_per_flit",
    "energy_pj",
)


def link_table(registry: Registry) -> list[dict]:
    """One flat record per NoC link seen by the ``noc.link`` probe.

    Values accumulate across every ``simulate_noc`` run inside the
    ``collect()`` scope — a link traversed by several fabric runs reports
    its total traffic.
    """
    rows: dict[tuple[int, int, int], dict] = {}
    for series in registry.series("noc.link.bt"):
        lab = series.labels
        key = (int(lab["link"]), int(lab["src"]), int(lab["dst"]))
        row = rows.setdefault(
            key,
            {
                "link": key[0],
                "src": key[1],
                "dst": key[2],
                "bt_input": 0,
                "bt_weight": 0,
                "aux_bt": 0,
            },
        )
        row[f"bt_{lab['side']}" if lab["side"] != "aux" else "aux_bt"] = int(
            series.value
        )
    for key, row in rows.items():
        lab = {"link": key[0], "src": key[1], "dst": key[2]}
        flits = int(registry.value("noc.link.flits", **lab))
        gross = row["bt_input"] + row["bt_weight"] + row["aux_bt"]
        row["gross_bt"] = gross
        row["num_flits"] = flits
        row["bt_per_flit"] = round(gross / max(flits, 1), 6)
        row["energy_pj"] = round(
            registry.value("noc.link.energy_pj", **lab), 3
        )
    return [rows[k] for k in sorted(rows)]


def top_links(registry: Registry, n: int = 5) -> list[dict]:
    """The n hottest links by gross BT (data + invert-line), descending."""
    table = link_table(registry)
    table.sort(key=lambda r: (-r["gross_bt"], r["link"]))
    return table[:n]


def format_links(rows: Sequence[dict]) -> str:
    """Aligned text table of link records (the bench / example view)."""
    head = (
        f"{'link':>4s} {'route':>9s} {'input BT':>10s} {'weight BT':>10s} "
        f"{'aux BT':>8s} {'gross BT':>10s} {'flits':>8s} {'BT/flit':>8s} "
        f"{'energy pJ':>11s}"
    )
    lines = [head, "-" * len(head)]
    for r in rows:
        lines.append(
            f"{r['link']:4d} {r['src']:>4d}->{r['dst']:<4d} "
            f"{r['bt_input']:10d} {r['bt_weight']:10d} {r['aux_bt']:8d} "
            f"{r['gross_bt']:10d} {r['num_flits']:8d} "
            f"{r['bt_per_flit']:8.2f} {r['energy_pj']:11.1f}"
        )
    return "\n".join(lines)


def _ensure_parent(path: str) -> None:
    parent = os.path.dirname(path)
    if parent:
        os.makedirs(parent, exist_ok=True)


def write_links_csv(path: str, registry: Registry) -> list[dict]:
    """Write (and return) the per-link heatmap CSV — one row per directed
    link with its accumulated BT/energy, the ``(src, dst)`` pair being the
    heatmap coordinate (README: "reading a per-link heatmap CSV")."""
    rows = link_table(registry)
    _ensure_parent(path)
    with open(path, "w", newline="") as f:
        writer = csv.DictWriter(f, fieldnames=LINK_FIELDS)
        writer.writeheader()
        writer.writerows(rows)
    return rows


def metrics_dict(registry: Registry) -> dict:
    """The registry as one JSON-safe document (counters/gauges/histograms
    plus the derived per-link table)."""
    doc = registry.to_dict()
    doc["links"] = link_table(registry)
    return doc


def write_metrics_json(path: str, registry: Registry) -> dict:
    """Write (and return) the full metrics report as JSON."""
    doc = metrics_dict(registry)
    _ensure_parent(path)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=False)
        f.write("\n")
    return doc


def read_metrics_json(path: str) -> Registry:
    """Rebuild a registry from a :func:`write_metrics_json` artifact (the
    round-trip pinned in ``tests/test_obs.py``)."""
    with open(path) as f:
        return registry_from_dict(json.load(f))
