"""Reports over collected telemetry: per-link BT tables, top-N hottest
links, CSV/JSON heatmap dumps (DESIGN.md §14).

Everything here reads a :class:`~repro.obs.metrics.Registry` populated by
the ``noc.link`` / ``link.report`` / ``dse.link`` probes and emits the
same flat-scalar record style as ``repro.dse.report`` — one dict per link
with JSON-safe values — so the artifacts diff cleanly and slot next to
the DSE front JSON/CSV in the bench trajectory.
"""

from __future__ import annotations

import csv
import json
import os
from typing import Sequence

from .metrics import Registry, registry_from_dict

__all__ = [
    "link_table",
    "top_links",
    "format_links",
    "write_links_csv",
    "activity_table",
    "top_wires",
    "write_activity_csv",
    "scenario_table",
    "format_scenarios",
    "write_scenarios_csv",
    "write_scenarios_json",
    "metrics_dict",
    "write_metrics_json",
    "read_metrics_json",
]

LINK_FIELDS = (
    "link",
    "src",
    "dst",
    "bt_input",
    "bt_weight",
    "aux_bt",
    "gross_bt",
    "num_flits",
    "bt_per_flit",
    "energy_pj",
)


def link_table(registry: Registry) -> list[dict]:
    """One flat record per NoC link seen by the ``noc.link`` probe.

    Values accumulate across every ``simulate_noc`` run inside the
    ``collect()`` scope — a link traversed by several fabric runs reports
    its total traffic.
    """
    rows: dict[tuple[int, int, int], dict] = {}
    for series in registry.series("noc.link.bt"):
        lab = series.labels
        key = (int(lab["link"]), int(lab["src"]), int(lab["dst"]))
        row = rows.setdefault(
            key,
            {
                "link": key[0],
                "src": key[1],
                "dst": key[2],
                "bt_input": 0,
                "bt_weight": 0,
                "aux_bt": 0,
            },
        )
        row[f"bt_{lab['side']}" if lab["side"] != "aux" else "aux_bt"] = int(
            series.value
        )
    for key, row in rows.items():
        lab = {"link": key[0], "src": key[1], "dst": key[2]}
        flits = int(registry.value("noc.link.flits", **lab))
        gross = row["bt_input"] + row["bt_weight"] + row["aux_bt"]
        row["gross_bt"] = gross
        row["num_flits"] = flits
        row["bt_per_flit"] = round(gross / max(flits, 1), 6)
        row["energy_pj"] = round(
            registry.value("noc.link.energy_pj", **lab), 3
        )
    return [rows[k] for k in sorted(rows)]


def top_links(registry: Registry, n: int = 5) -> list[dict]:
    """The n hottest links by gross BT (data + invert-line), descending."""
    table = link_table(registry)
    table.sort(key=lambda r: (-r["gross_bt"], r["link"]))
    return table[:n]


def format_links(rows: Sequence[dict]) -> str:
    """Aligned text table of link records (the bench / example view)."""
    head = (
        f"{'link':>4s} {'route':>9s} {'input BT':>10s} {'weight BT':>10s} "
        f"{'aux BT':>8s} {'gross BT':>10s} {'flits':>8s} {'BT/flit':>8s} "
        f"{'energy pJ':>11s}"
    )
    lines = [head, "-" * len(head)]
    for r in rows:
        lines.append(
            f"{r['link']:4d} {r['src']:>4d}->{r['dst']:<4d} "
            f"{r['bt_input']:10d} {r['bt_weight']:10d} {r['aux_bt']:8d} "
            f"{r['gross_bt']:10d} {r['num_flits']:8d} "
            f"{r['bt_per_flit']:8.2f} {r['energy_pj']:11.1f}"
        )
    return "\n".join(lines)


def _ensure_parent(path: str) -> None:
    parent = os.path.dirname(path)
    if parent:
        os.makedirs(parent, exist_ok=True)


def write_links_csv(path: str, registry: Registry) -> list[dict]:
    """Write (and return) the per-link heatmap CSV — one row per directed
    link with its accumulated BT/energy, the ``(src, dst)`` pair being the
    heatmap coordinate (README: "reading a per-link heatmap CSV")."""
    rows = link_table(registry)
    _ensure_parent(path)
    with open(path, "w", newline="") as f:
        writer = csv.DictWriter(f, fieldnames=LINK_FIELDS)
        writer.writeheader()
        writer.writerows(rows)
    return rows


ACTIVITY_FIELDS = (
    "link",
    "src",
    "dst",
    "toggles",
    "windows",
    "wire_mean",
    "wire_max",
    "hot_wire",
    "hot_wire_toggles",
)


def activity_table(registry: Registry) -> list[dict]:
    """One flat record per link seen by the ``link.activity`` probe —
    the wire-resolved companion to :func:`link_table` (totals, per-wire
    spread, and the hottest net of each link)."""
    rows: dict[tuple[int, int, int], dict] = {}
    for series in registry.series("link.activity.toggles"):
        lab = series.labels
        key = (int(lab["link"]), int(lab["src"]), int(lab["dst"]))
        slab = {"link": lab["link"], "src": lab["src"], "dst": lab["dst"]}
        hist = registry.histogram("link.activity.wire_toggles", **slab)
        hot_wire, hot_tog = "", 0
        for s in registry.series("link.activity.hot_wire_toggles"):
            hl = s.labels
            if (int(hl["link"]), int(hl["src"]), int(hl["dst"])) == key:
                if s.value >= hot_tog:
                    hot_wire, hot_tog = hl["wire"], int(s.value)
        rows[key] = {
            "link": key[0],
            "src": key[1],
            "dst": key[2],
            "toggles": int(series.value),
            "windows": int(
                registry.value("link.activity.windows", **slab)
            ),
            "wire_mean": round(hist.mean, 3),
            "wire_max": int(hist.max) if hist.count else 0,
            "hot_wire": hot_wire,
            "hot_wire_toggles": hot_tog,
        }
    return [rows[k] for k in sorted(rows)]


def top_wires(registry: Registry, n: int = 5) -> list[dict]:
    """The n hottest (link, wire) pairs by toggle count, descending —
    the hot-wire-tail summary the bench prints."""
    pairs = [
        {
            "link": int(s.labels["link"]),
            "src": int(s.labels["src"]),
            "dst": int(s.labels["dst"]),
            "wire": s.labels["wire"],
            "toggles": int(s.value),
        }
        for s in registry.series("link.activity.hot_wire_toggles")
    ]
    pairs.sort(key=lambda r: (-r["toggles"], r["link"], r["wire"]))
    return pairs[:n]


def write_activity_csv(path: str, registry: Registry) -> list[dict]:
    """Write (and return) the per-link activity summary CSV (the full
    per-wire heatmap CSV comes from ``repro.obs.activity.write_wires_csv``
    — this one is the registry-derived roll-up)."""
    rows = activity_table(registry)
    _ensure_parent(path)
    with open(path, "w", newline="") as f:
        writer = csv.DictWriter(f, fieldnames=ACTIVITY_FIELDS)
        writer.writeheader()
        writer.writerows(rows)
    return rows


SCENARIO_FIELDS = (
    "scenario",
    "streams",
    "num_bytes",
    "num_flits",
    "bt_base",
    "red_acc",
    "red_app",
    "red_composed",
    "energy_base_pj",
    "energy_app_pj",
    "noc_red_acc",
    "hot_link",
    "hot_wire",
)


def scenario_table(records: Sequence[dict]) -> list[dict]:
    """Normalized per-scenario campaign records (DESIGN.md §16).

    ``records`` come from real-traffic capture campaigns
    (``benchmarks/model_traffic.py``): one dict per scenario with captured
    stream totals, DSE-measured BT under baseline/ACC/APP/codec-composed
    ordering, link energy, and the hottest link/wire of the scenario's NoC
    run.  Missing fields become ``""`` so partial campaigns still emit
    well-formed tables; reduction/energy floats are rounded for diffable
    artifacts.
    """
    out = []
    for rec in records:
        row = {k: rec.get(k, "") for k in SCENARIO_FIELDS}
        for k in ("red_acc", "red_app", "red_composed", "noc_red_acc"):
            if row[k] != "":
                row[k] = round(float(row[k]), 6)
        for k in ("energy_base_pj", "energy_app_pj"):
            if row[k] != "":
                row[k] = round(float(row[k]), 3)
        out.append(row)
    out.sort(key=lambda r: str(r["scenario"]))
    return out


def format_scenarios(records: Sequence[dict]) -> str:
    """Aligned text table of scenario records (the bench / README view)."""
    rows = scenario_table(records)
    head = (
        f"{'scenario':>16s} {'streams':>8s} {'bytes':>10s} {'flits':>8s} "
        f"{'base BT':>10s} {'ACC red':>8s} {'APP red':>8s} {'+codec':>8s} "
        f"{'E base pJ':>11s} {'E app pJ':>10s}"
    )
    lines = [head, "-" * len(head)]

    def pct(v):
        return f"{100 * v:7.2f}%" if v != "" else f"{'-':>8s}"

    for r in rows:
        lines.append(
            f"{str(r['scenario']):>16s} {str(r['streams']):>8s} "
            f"{str(r['num_bytes']):>10s} {str(r['num_flits']):>8s} "
            f"{str(r['bt_base']):>10s} {pct(r['red_acc'])} "
            f"{pct(r['red_app'])} {pct(r['red_composed'])} "
            f"{str(r['energy_base_pj']):>11s} {str(r['energy_app_pj']):>10s}"
        )
    return "\n".join(lines)


def write_scenarios_csv(path: str, records: Sequence[dict]) -> list[dict]:
    """Write (and return) the per-scenario campaign CSV."""
    rows = scenario_table(records)
    _ensure_parent(path)
    with open(path, "w", newline="") as f:
        writer = csv.DictWriter(f, fieldnames=SCENARIO_FIELDS)
        writer.writeheader()
        writer.writerows(rows)
    return rows


def write_scenarios_json(
    path: str, records: Sequence[dict], meta: dict | None = None
) -> dict:
    """Write (and return) the scenario campaign as one JSON document —
    the table plus campaign-level metadata (e.g. the recalibration
    comparison against the §10 synthetic numbers)."""
    doc = {"scenarios": scenario_table(records), **(meta or {})}
    _ensure_parent(path)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=False)
        f.write("\n")
    return doc


def metrics_dict(registry: Registry) -> dict:
    """The registry as one JSON-safe document (counters/gauges/histograms
    plus the derived per-link table)."""
    doc = registry.to_dict()
    doc["links"] = link_table(registry)
    act = activity_table(registry)
    if act:  # only present when wire activity was measured — artifacts
        doc["activity"] = act  # without it stay byte-identical to PR 7
    return doc


def write_metrics_json(path: str, registry: Registry) -> dict:
    """Write (and return) the full metrics report as JSON."""
    doc = metrics_dict(registry)
    _ensure_parent(path)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=False)
        f.write("\n")
    return doc


def read_metrics_json(path: str) -> Registry:
    """Rebuild a registry from a :func:`write_metrics_json` artifact (the
    round-trip pinned in ``tests/test_obs.py``)."""
    with open(path) as f:
        return registry_from_dict(json.load(f))
