"""Span tracing in Chrome/Perfetto trace-event JSON (DESIGN.md §14).

A :class:`Tracer` records *complete* spans (``ph: "X"``) and *instant*
events (``ph: "i"``) with microsecond timestamps on one (pid, tid)
timeline; nested ``span()`` contexts nest visually in Perfetto /
``chrome://tracing`` purely by timestamp containment.  ``to_chrome()``
emits the JSON object form (``{"traceEvents": [...]}``) so extra metadata
keys can ride along; ``write()`` puts it on disk (the
``TRACE_<module>.json`` artifacts of ``benchmarks/run.py --trace``).

Spans measure *dispatch wall time* — the Python-side duration of the
probed call, including jax tracing/compilation on first execution.  For
asynchronous device work that is an upper bound on what the caller
observes, not device occupancy; bench modules that need settled numbers
already ``block_until_ready`` inside the outermost span.
"""

from __future__ import annotations

import json
import os
import threading
import time
from contextlib import contextmanager
from typing import Mapping

__all__ = ["Tracer"]


def _json_safe(value):
    """Coerce probe payload values into JSON-serializable scalars."""
    if value is None or isinstance(value, (bool, int, float, str)):
        return value
    if isinstance(value, (tuple, list)):
        return [_json_safe(v) for v in value]
    if isinstance(value, Mapping):
        return {str(k): _json_safe(v) for k, v in value.items()}
    return str(value)


class Tracer:
    """Collects trace events; one instance per trace file."""

    def __init__(self, process_name: str = "repro") -> None:
        self._t0 = time.perf_counter()
        self._pid = os.getpid()
        self._events: list[dict] = [
            {
                "name": "process_name",
                "ph": "M",
                "pid": self._pid,
                "tid": 0,
                "args": {"name": process_name},
            }
        ]

    # ----------------------------------------------------------------- time
    def _now_us(self) -> float:
        return (time.perf_counter() - self._t0) * 1e6

    # --------------------------------------------------------------- record
    @contextmanager
    def span(self, name: str, cat: str = "repro", args: Mapping | None = None):
        """Record one complete ("X") span around the with-body."""
        ts = self._now_us()
        try:
            yield self
        finally:
            self._events.append(
                {
                    "name": name,
                    "cat": cat,
                    "ph": "X",
                    "ts": ts,
                    "dur": self._now_us() - ts,
                    "pid": self._pid,
                    "tid": threading.get_ident() & 0xFFFF,
                    "args": _json_safe(dict(args or {})),
                }
            )

    def begin(self, name: str, cat: str = "repro", args: Mapping | None = None):
        """Imperative form of :meth:`span` for the probe layer: returns a
        zero-argument ``end()`` callable."""
        ts = self._now_us()

        def end() -> None:
            self._events.append(
                {
                    "name": name,
                    "cat": cat,
                    "ph": "X",
                    "ts": ts,
                    "dur": self._now_us() - ts,
                    "pid": self._pid,
                    "tid": threading.get_ident() & 0xFFFF,
                    "args": _json_safe(dict(args or {})),
                }
            )

        return end

    def instant(self, name: str, cat: str = "repro", args: Mapping | None = None):
        """Record one instant ("i") event."""
        self._events.append(
            {
                "name": name,
                "cat": cat,
                "ph": "i",
                "s": "t",
                "ts": self._now_us(),
                "pid": self._pid,
                "tid": threading.get_ident() & 0xFFFF,
                "args": _json_safe(dict(args or {})),
            }
        )

    # -------------------------------------------------------------- queries
    @property
    def events(self) -> tuple[dict, ...]:
        return tuple(self._events)

    def spans(self, name: str | None = None) -> list[dict]:
        """All complete spans, optionally filtered by exact name."""
        return [
            e
            for e in self._events
            if e["ph"] == "X" and (name is None or e["name"] == name)
        ]

    def span_seconds(self, name: str) -> float:
        """Total duration (s) of every span with this name."""
        return sum(e["dur"] for e in self.spans(name)) / 1e6

    # --------------------------------------------------------------- export
    def to_chrome(self, metadata: Mapping | None = None) -> dict:
        """The JSON-object trace form Perfetto / chrome://tracing load."""
        doc = {
            "traceEvents": list(self._events),
            "displayTimeUnit": "ms",
        }
        if metadata:
            doc["metadata"] = _json_safe(dict(metadata))
        return doc

    def write(self, path: str, metadata: Mapping | None = None) -> dict:
        """Write (and return) the Chrome trace document."""
        doc = self.to_chrome(metadata)
        parent = os.path.dirname(path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        with open(path, "w") as f:
            json.dump(doc, f, indent=1)
            f.write("\n")
        return doc
