"""Real-model traffic capture: tap the model zoo, record int8 wire streams
(DESIGN.md §16).

Every BT/power number before this layer came from synthetic streams
(``benchmarks/datagen.py``).  This module records the *actual* traffic of
the model zoo — decode weight/KV streams (``repro.serve``), a train step's
gradient all-reduce payload (``repro.train``), MoE dispatch buffers
(``repro.models.moe``) and trained-LeNet conv kernels
(``repro.models.lenet``) — as int8 wire images (``repro.traffic.int8_view``)
ready for the existing measurement stack: ``TxPipeline`` /
``dse.evaluate_grid`` / ``noc.simulate`` / the §15 activity plane.

The hook contract mirrors ``repro.obs.probes`` exactly (zero cost when
uninstalled):

  * production modules call ``repro._obs_hooks.tap(kind, **payload)`` at
    fixed tap sites — one ``None`` test while no capture is active;
  * a :func:`capture` context installs this module's ``_Tap`` into
    ``repro._obs_hooks.TAP``; every firing fans out to all active
    :class:`CaptureSession`\\ s;
  * payloads may be jax arrays or pytrees.  A tap site inside a jitted
    function fires with *tracers* during tracing — the tap drops those
    payloads whole (no jax operation ever touches them), so every traced
    jaxpr is byte-identical whether capture is absent, installed, or
    active (``tests/test_capture.py`` pins this in a subprocess).  Real
    values are recorded by calling the tapped functions *eagerly* (the
    ``capture_*`` scenario drivers below), outside any measured path.

The tap vocabulary (kind -> scenario):

  =================  ===============  =====================================
  kind               scenario         fired by
  =================  ===============  =====================================
  serve.weights      serve_decode     ``serve.generate`` once before the
                                      decode loop (the multicast weight
                                      stream)
  serve.kv           serve_decode     ``serve.generate`` after each decode
                                      step (the new KV / SSM-state bytes)
  train.grads        train_allreduce  ``train.make_train_step`` after the
                                      gradients are computed
  moe.dispatch       moe_dispatch     ``models.moe.moe_block`` after the
                                      expert input buffers are gathered
  lenet.conv         lenet_conv       ``models.lenet.lenet_forward``
                                      (trained conv kernels + input batch)
  =================  ===============  =====================================

Each recorded stream fires a ``capture.stream`` probe event (bytes per
scenario/stream) so captures show up in ``obs.collect`` registries and
``bench --trace`` timelines.
"""

from __future__ import annotations

import dataclasses
import json
from contextlib import contextmanager
from typing import Optional, Sequence

import numpy as np

from repro import _obs_hooks

__all__ = [
    "TAP_SCENARIOS",
    "CapturedStream",
    "CaptureSession",
    "capture",
    "capture_serve_decode",
    "capture_train_step",
    "capture_moe_dispatch",
    "capture_lenet_conv",
    "save_session",
    "load_session",
]

# the canonical tap vocabulary: tap kind -> report scenario.  Unknown kinds
# capture under their own name (new tap sites degrade gracefully, like
# unknown probe kinds in repro.obs.probes).
TAP_SCENARIOS: dict[str, str] = {
    "serve.weights": "serve_decode",
    "serve.kv": "serve_decode",
    "train.grads": "train_allreduce",
    "moe.dispatch": "moe_dispatch",
    "lenet.conv": "lenet_conv",
}


# --------------------------------------------------------------------------
# captured streams and sessions
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CapturedStream:
    """One recorded int8 wire stream.

    ``data`` is the 1-D uint8 view of the tensor's symmetric int8 wire
    image (``repro.traffic.int8_view``) — exactly the bytes the link /
    NoC / DSE stack measures.
    """

    scenario: str
    name: str
    kind: str
    data: np.ndarray
    source_shape: tuple[int, ...]
    meta: dict = dataclasses.field(default_factory=dict)

    @property
    def num_bytes(self) -> int:
        return int(self.data.size)


def _int8_bytes(arr) -> np.ndarray:
    """A tensor's int8 wire image as 1-D uint8 (already-int8 data passes
    through unquantized — it IS its own wire image)."""
    a = np.asarray(arr)
    if a.dtype == np.uint8:
        return a.reshape(-1)
    if a.dtype == np.int8:
        return a.view(np.uint8).reshape(-1)
    from repro.traffic.ordering import int8_view

    return np.asarray(int8_view(arr)).view(np.uint8).reshape(-1)


def _tree_bytes(tree, min_ndim: int) -> tuple[np.ndarray, int]:
    """Concatenated int8 wire bytes of a pytree's float leaves."""
    import jax
    import jax.numpy as jnp

    leaves = [
        x
        for x in jax.tree.leaves(tree)
        if getattr(x, "ndim", None) is not None
        and x.ndim >= min_ndim
        and x.size
        and jnp.issubdtype(x.dtype, jnp.floating)
    ]
    if not leaves:
        return np.zeros(0, np.uint8), 0
    return np.concatenate([_int8_bytes(x) for x in leaves]), len(leaves)


class CaptureSession:
    """An ordered collection of captured streams, grouped by scenario.

    Sessions are what the :func:`capture` context yields; they convert to
    the measurement stack's native shapes via :meth:`packets` (one
    concatenated packet matrix) and :meth:`workload` (one
    ``repro.dse.Workload`` with each captured stream measured
    independently — no seam transitions between streams, so per-stream
    BT sums exactly to the scenario total).
    """

    def __init__(self, name: str = "capture") -> None:
        self.name = name
        self.streams: list[CapturedStream] = []

    # ---------------- recording ----------------

    def add(
        self, scenario: str, name: str, tensor, *, kind: str = "manual", **meta
    ) -> CapturedStream:
        """Quantize ``tensor`` to its int8 wire image and record it."""
        data = _int8_bytes(tensor)
        shape = tuple(int(d) for d in getattr(tensor, "shape", (data.size,)))
        s = self._add_bytes(scenario, name, data, shape, kind, meta)
        _obs_hooks.event(
            "capture.stream",
            tap=kind,
            scenario=scenario,
            stream=name,
            bytes=s.num_bytes,
        )
        return s

    def _add_bytes(
        self,
        scenario: str,
        name: str,
        data: np.ndarray,
        source_shape: tuple[int, ...],
        kind: str,
        meta: dict,
    ) -> CapturedStream:
        s = CapturedStream(
            scenario=scenario,
            name=name,
            kind=kind,
            data=np.ascontiguousarray(data, dtype=np.uint8).reshape(-1),
            source_shape=tuple(int(d) for d in source_shape),
            meta=dict(meta),
        )
        self.streams.append(s)
        return s

    # ---------------- inspection ----------------

    def scenarios(self) -> tuple[str, ...]:
        return tuple(dict.fromkeys(s.scenario for s in self.streams))

    def get(
        self, scenario: str, name: str | None = None
    ) -> list[CapturedStream]:
        return [
            s
            for s in self.streams
            if s.scenario == scenario and (name is None or s.name == name)
        ]

    def scenario_bytes(
        self, scenario: str, names: Sequence[str] | None = None
    ) -> np.ndarray:
        sel = [
            s
            for s in self.get(scenario)
            if names is None or s.name in names
        ]
        if not sel:
            return np.zeros(0, np.uint8)
        return np.concatenate([s.data for s in sel])

    # ---------------- conversion to the measurement stack ----------------

    def packets(
        self,
        scenario: str,
        elems: int = 64,
        *,
        names: Sequence[str] | None = None,
        owner: str | None = None,
        strict: bool = False,
    ):
        """The scenario's captured bytes as one (P, elems) packet matrix.

        ``strict=True`` raises a clear :class:`ValueError` naming ``owner``
        when the byte count is not flit-divisible (otherwise the tail is
        trimmed to whole packets, the NoC-flow convention)."""
        data = self.scenario_bytes(scenario, names)
        return _bytes_to_packets(
            data, elems, owner=owner or scenario, strict=strict
        )

    def workload(
        self,
        scenario: str,
        *,
        elems: int = 64,
        lanes: int = 16,
        names: Sequence[str] | None = None,
        owner: str | None = None,
        strict: bool = False,
    ):
        """The scenario as a ``repro.dse.Workload``: every captured stream
        becomes its own (P, elems) measurement stream (independent links,
        Table-I style — stream BT adds with no seam transitions)."""
        from repro.dse.evaluate import Workload

        label = owner or scenario
        sel = [
            s for s in self.get(scenario) if names is None or s.name in names
        ]
        if not sel:
            raise ValueError(
                f"{label}: no captured streams for scenario {scenario!r} "
                f"(captured: {list(self.scenarios()) or 'nothing'})"
            )
        pkts = tuple(
            _bytes_to_packets(
                s.data, elems, owner=f"{label}/{s.name}", strict=strict
            )
            for s in sel
        )
        return Workload(name=label, streams=pkts, lanes=lanes)


def _bytes_to_packets(
    data: np.ndarray, elems: int, *, owner: str, strict: bool
):
    import jax.numpy as jnp

    n = int(data.size)
    if strict and n % elems:
        raise ValueError(
            f"{owner}: captured stream carries {n} bytes, which is not "
            f"divisible into {elems}-byte packets ({n % elems} bytes left "
            f"over) — the config's dims are not flit-divisible; pad the "
            f"model dims or pick a LinkSpec whose packet size divides {n}"
        )
    p = n // elems
    if p == 0:
        raise ValueError(
            f"{owner}: captured only {n} bytes — smaller than one "
            f"{elems}-byte packet; capture more traffic or shrink the "
            f"packet size"
        )
    return jnp.asarray(data[: p * elems].reshape(p, elems))


# --------------------------------------------------------------------------
# the tap installed into repro._obs_hooks.TAP
# --------------------------------------------------------------------------


def _has_tracer(payload: dict) -> bool:
    import jax

    return any(
        isinstance(x, jax.core.Tracer) for x in jax.tree.leaves(payload)
    )


def _extract(kind: str, payload: dict) -> list[tuple]:
    """(name, bytes, source_shape, meta) streams of one tap firing."""
    if kind == "serve.weights":
        data, n = _tree_bytes(payload["params"], 2)
        return [("weights", data, (int(data.size),), {"leaves": n})]
    if kind == "serve.kv":
        cache = payload["cache"]
        step = int(payload.get("step", 0))
        parts = []
        if "k" in cache:
            # decode_step already advanced pos: the new KV row is pos-1
            pos = max(int(np.asarray(cache["pos"])) - 1, 0)
            for key in ("k", "v"):
                parts.append(_int8_bytes(cache[key][:, :, pos]))
        for key in ("ssm", "ssm_trailing"):
            if key in cache:
                data, _ = _tree_bytes(cache[key], 2)
                parts.append(data)
        data = (
            np.concatenate(parts) if parts else np.zeros(0, np.uint8)
        )
        return [("kv", data, (int(data.size),), {"step": step})]
    if kind == "train.grads":
        data, n = _tree_bytes(payload["grads"], 1)
        return [("grads", data, (int(data.size),), {"leaves": n})]
    if kind == "moe.dispatch":
        ei = payload["expert_in"]
        shape = tuple(int(d) for d in ei.shape)  # (G, E, C, D)
        return [
            (
                "expert_in",
                _int8_bytes(ei),
                shape,
                {"experts": shape[1], "capacity": shape[2]},
            )
        ]
    # generic: every array-valued payload entry becomes one stream
    # (lenet.conv and future tap kinds)
    out = []
    for name, arr in payload.items():
        if getattr(arr, "ndim", None) is None or not getattr(arr, "size", 0):
            continue
        out.append(
            (
                name,
                _int8_bytes(arr),
                tuple(int(d) for d in arr.shape),
                {},
            )
        )
    return out


class _Tap:
    """The multiplexer installed into ``repro._obs_hooks.TAP``."""

    def __init__(self) -> None:
        self.sessions: list[CaptureSession] = []

    def tap(self, kind: str, payload: dict) -> None:
        if _has_tracer(payload):
            return  # tracing pass: drop whole payload, touch nothing
        scenario = TAP_SCENARIOS.get(kind, kind)
        for name, data, shape, meta in _extract(kind, payload):
            for sess in self.sessions:
                sess._add_bytes(scenario, name, data, shape, kind, meta)
            _obs_hooks.event(
                "capture.stream",
                tap=kind,
                scenario=scenario,
                stream=name,
                bytes=int(data.size),
            )


_TAP = _Tap()


def _refresh() -> None:
    _obs_hooks.TAP = _TAP if _TAP.sessions else None


@contextmanager
def capture(session: CaptureSession | None = None):
    """Activate traffic capture for the with-body; yields the session.

    Nested ``capture()`` scopes all record every tap firing (each scope
    keeps its own streams).  Entering the first scope installs the tap —
    before that, tap sites are a ``None`` test and nothing else.
    """
    sess = CaptureSession() if session is None else session
    _TAP.sessions.append(sess)
    _refresh()
    try:
        yield sess
    finally:
        _TAP.sessions.remove(sess)
        _refresh()


# --------------------------------------------------------------------------
# scenario drivers (shared by tests and benchmarks/model_traffic.py)
# --------------------------------------------------------------------------


def train_batch(cfg, batch: int = 2, seq: int = 16, seed: int = 0) -> dict:
    """A family-aware random batch for ``make_train_step`` (the
    ``tests/test_models_smoke.py`` construction, shared here so every
    config can be driven through capture)."""
    import jax
    import jax.numpy as jnp

    key = jax.random.key(seed)
    tok = jax.random.randint(key, (batch, seq), 0, cfg.vocab)
    lab = jax.random.randint(key, (batch, seq), 0, cfg.vocab)
    out = {"tokens": tok, "labels": lab}
    if cfg.family in ("encdec", "audio"):
        out["frames"] = jax.random.normal(
            key, (batch, 8, cfg.d_model), jnp.float32
        )
    elif cfg.family == "vlm":
        out["patches"] = jax.random.normal(
            key, (batch, cfg.n_frontend_tokens, cfg.d_model)
        )
        out["labels"] = jnp.pad(
            lab, ((0, 0), (cfg.n_frontend_tokens, 0)), constant_values=-100
        )
    return out


def capture_serve_decode(
    cfg,
    *,
    batch: int = 2,
    prompt: int = 8,
    new_tokens: int = 4,
    seed: int = 0,
    session: CaptureSession | None = None,
) -> CaptureSession:
    """Run ``serve.generate`` under capture: records the multicast weight
    stream once plus one KV/state stream per decoded token."""
    import jax
    import jax.numpy as jnp

    from repro.models import init_params
    from repro.serve.loop import generate

    key = jax.random.key(seed)
    params = init_params(cfg, key)
    prompts = jax.random.randint(key, (batch, prompt), 0, cfg.vocab)
    kw = {}
    if cfg.family in ("encdec", "audio"):
        kw["frames"] = jax.random.normal(
            key, (batch, 8, cfg.d_model), jnp.float32
        )
    elif cfg.family == "vlm":
        kw["inputs_embeds"] = jax.random.normal(
            key, (batch, cfg.n_frontend_tokens, cfg.d_model)
        )
    with capture(session) as sess:
        generate(params, cfg, prompts, new_tokens, **kw)
    return sess


def capture_train_step(
    cfg,
    *,
    batch: int = 2,
    seq: int = 16,
    seed: int = 0,
    session: CaptureSession | None = None,
) -> CaptureSession:
    """Run one EAGER train step under capture: the ``train.grads`` tap
    records the gradient all-reduce payload (jitted callers trace through
    the same tap at zero cost — tracers are dropped)."""
    from repro.models import init_params
    from repro.optim import AdamWConfig
    from repro.optim import init as opt_init
    from repro.train import make_train_step

    import jax

    params = init_params(cfg, jax.random.key(seed))
    opt = opt_init(params)
    step = make_train_step(cfg, AdamWConfig(warmup_steps=1, total_steps=10))
    with capture(session) as sess:
        step(params, opt, train_batch(cfg, batch, seq, seed))
    return sess


def capture_moe_dispatch(
    cfg,
    *,
    batch: int = 2,
    seq: int = 16,
    seed: int = 0,
    session: CaptureSession | None = None,
) -> CaptureSession:
    """Run one EAGER MoE block under capture: records the dispatched
    expert input buffers (the ICI dispatch traffic)."""
    import jax
    import jax.numpy as jnp

    if cfg.moe is None:
        raise ValueError(
            f"config family {cfg.family!r} has no MoE block; "
            "capture_moe_dispatch needs a MoE config"
        )
    from repro.models.moe import init_moe, moe_block

    key = jax.random.key(seed)
    params = init_moe(key, cfg)
    x = jax.random.normal(
        key, (batch, seq, cfg.d_model), jnp.dtype(cfg.dtype)
    )
    with capture(session) as sess:
        moe_block(params, x, cfg)
    return sess


def capture_lenet_conv(
    params=None,
    *,
    steps: int = 300,
    batch: int = 64,
    seed: int = 0,
    ckpt_dir: str | None = None,
    session: CaptureSession | None = None,
) -> CaptureSession:
    """Run a trained LeNet forward under capture: records the trained
    (honestly zero-clustered) conv kernels plus the input batch.  With
    ``params=None`` the model is trained in-repo first (restored from
    ``ckpt_dir`` when a checkpoint exists)."""
    import jax

    from repro.models import lenet

    if params is None:
        params, _ = lenet.train_lenet(
            steps=steps, batch=batch, seed=seed, ckpt_dir=ckpt_dir
        )
    images, _ = lenet.synth_batch(jax.random.key(seed), batch=8)
    with capture(session) as sess:
        lenet.lenet_forward(params, images)
    return sess


# --------------------------------------------------------------------------
# capture -> replay (artifact round-trip)
# --------------------------------------------------------------------------


def save_session(path: str, session: CaptureSession) -> None:
    """Persist a session's streams as one .npz (bytes + JSON manifest) —
    the capture->replay artifact (round-trip pinned in tests)."""
    manifest = [
        {
            "scenario": s.scenario,
            "name": s.name,
            "kind": s.kind,
            "source_shape": list(s.source_shape),
            "meta": s.meta,
        }
        for s in session.streams
    ]
    arrays = {f"s{i}": s.data for i, s in enumerate(session.streams)}
    arrays["manifest"] = np.frombuffer(
        json.dumps({"name": session.name, "streams": manifest}).encode(),
        dtype=np.uint8,
    )
    with open(path, "wb") as f:
        np.savez(f, **arrays)


def load_session(path: str) -> CaptureSession:
    """Rebuild a session from a :func:`save_session` artifact."""
    data = np.load(path)
    doc = json.loads(bytes(data["manifest"]).decode())
    sess = CaptureSession(doc.get("name", "capture"))
    for i, entry in enumerate(doc["streams"]):
        sess._add_bytes(
            entry["scenario"],
            entry["name"],
            np.asarray(data[f"s{i}"], dtype=np.uint8),
            tuple(entry["source_shape"]),
            entry["kind"],
            entry.get("meta", {}),
        )
    return sess
