"""The probe layer: routes hook firings into registries and tracers
(DESIGN.md §14).

Production modules call ``repro._obs_hooks.span/event`` at fixed probe
points; while at least one :func:`collect` or :func:`tracing` context is
active this module's sink is installed into the hook slot and every firing
fans out to all active collectors.  The probe vocabulary:

  =================  =====  ==============================================
  kind               form   fired by
  =================  =====  ==============================================
  kernel.dispatch    span   every public kernel entry point in
                            ``repro.kernels.ops`` (resolved backend,
                            shapes, grid blocks, pallas launches)
  link.tx            span   ``link.TxPipeline.run`` (fused or staged)
  link.stage         span   each staged-path stage (order/assemble/codec/
                            bt) inside ``TxPipeline.run``
  link.report        event  ``TxPipeline.measure``/``measure_rows`` —
                            per-stream BT/energy totals
  noc.expand         span   ``noc.expand_link_streams``
  noc.simulate       span   ``noc.simulate_noc``
  noc.link           event  one per measured NoC link (the per-link BT
                            telemetry behind ``repro.obs.report``)
  noc.contend        event  one per contended link (>= 2 merged flows) of
                            a ``noc.latency`` contention-model evaluation
  link.activity      event  one per link measured with wire-level
                            activity (``activity_windows=``) — per-wire
                            toggle telemetry (DESIGN.md §15)
  dse.measure        span   each per-width multi-axis launch in
                            ``dse.evaluate_grid``
  dse.link           event  one per measurement link of a DSE grid launch
  dse.point          event  one per evaluated design point
  codec.stream       event  per-stream totals in ``codec.compare_streams``
  capture.stream     event  one per stream recorded by a traffic-capture
                            session (``repro.obs.capture``) — bytes per
                            scenario/stream
  bench.module       span   ``benchmarks/run.py --trace`` around each
                            module run
  =================  =====  ==============================================

Span firings become Chrome trace spans on every active tracer plus a
``<kind>.calls`` counter and ``<kind>.seconds`` histogram (labeled by the
kind's identity keys) on every active registry; event firings become
instant trace events plus the per-kind counters below.  Unknown kinds
still count (``<kind>.calls``) so new probe points degrade gracefully.
"""

from __future__ import annotations

import time
from contextlib import contextmanager

from repro import _obs_hooks

from .activity import wire_name
from .metrics import Registry
from .trace import Tracer

__all__ = [
    "PROBE_KINDS",
    "collect",
    "tracing",
    "active_registries",
    "active_tracers",
]

# the canonical probe vocabulary — kind -> form.  This dict IS the source
# of truth the DESIGN.md §14 table must mirror (a guard test parses the
# table and fails on drift), so adding a probe point means updating both.
PROBE_KINDS: dict[str, str] = {
    "kernel.dispatch": "span",
    "link.tx": "span",
    "link.stage": "span",
    "link.report": "event",
    "link.activity": "event",
    "noc.expand": "span",
    "noc.simulate": "span",
    "noc.link": "event",
    "noc.contend": "event",
    "dse.measure": "span",
    "dse.link": "event",
    "dse.point": "event",
    "codec.stream": "event",
    "capture.stream": "event",
    "bench.module": "span",
}

# label keys lifted from span payloads into metric series identity —
# everything else stays trace-only (unbounded-cardinality values like
# shapes must never become label sets)
_SPAN_LABELS: dict[str, tuple[str, ...]] = {
    "kernel.dispatch": ("entry", "backend"),
    "link.tx": ("path", "key", "codec"),
    "link.stage": ("stage",),
    "noc.expand": ("topology", "sort_at"),
    "noc.simulate": ("topology", "sort_at"),
    "dse.measure": ("width",),
    "bench.module": ("module",),
}


def _labels(kind: str, data: dict) -> dict:
    keys = _SPAN_LABELS.get(kind, ())
    return {k: data[k] for k in keys if k in data}


def _record_span(reg: Registry, kind: str, data: dict, seconds: float) -> None:
    labels = _labels(kind, data)
    reg.counter(f"{kind}.calls", **labels).inc()
    reg.histogram(f"{kind}.seconds", **labels).observe(seconds)
    if kind == "kernel.dispatch":
        reg.counter(
            "kernel.pallas_launches", **_labels(kind, data)
        ).inc(data.get("pallas_launches", 0))


def _record_event(reg: Registry, kind: str, data: dict) -> None:
    if kind == "noc.link":
        lab = {
            "link": data["link"], "src": data["src"], "dst": data["dst"],
        }
        reg.counter("noc.link.bt", side="input", **lab).inc(data["bt_input"])
        reg.counter("noc.link.bt", side="weight", **lab).inc(data["bt_weight"])
        reg.counter("noc.link.bt", side="aux", **lab).inc(data["bt_aux"])
        reg.counter("noc.link.flits", **lab).inc(data["num_flits"])
        reg.counter("noc.link.energy_pj", **lab).inc(data["energy_pj"])
    elif kind == "noc.contend":
        lab = {
            "link": data["link"], "src": data["src"], "dst": data["dst"],
        }
        reg.counter("noc.contend.flows", **lab).inc(data["flows"])
        reg.counter("noc.contend.wait_cycles", **lab).inc(
            data["wait_cycles"]
        )
    elif kind == "link.report":
        lab = {"stream": data["name"]}
        reg.counter("link.bt", side="input", **lab).inc(data["bt_input"])
        reg.counter("link.bt", side="weight", **lab).inc(data["bt_weight"])
        reg.counter("link.bt", side="aux", **lab).inc(data["aux_bt"])
        reg.counter("link.flits", **lab).inc(data["num_flits"])
        reg.counter("link.energy_pj", **lab).inc(data["energy_pj"])
    elif kind == "link.activity":
        lab = {
            "link": data["link"], "src": data["src"], "dst": data["dst"],
        }
        reg.counter("link.activity.toggles", **lab).inc(
            data["toggles_total"]
        )
        reg.counter("link.activity.windows", **lab).inc(
            data["num_windows"]
        )
        reg.counter(
            "link.activity.hot_wire_toggles",
            wire=wire_name(data["hot_wire"], data["data_lanes"]),
            **lab,
        ).inc(data["hot_wire_toggles"])
        # per-wire distribution as a histogram (bounded series count —
        # wire *values* stream through one series per link, never one
        # series per wire)
        hist = reg.histogram("link.activity.wire_toggles", **lab)
        for v in data["per_wire"]:
            hist.observe(v)
    elif kind == "dse.link":
        lab = {"link": data["link"], "width": data["width"]}
        reg.counter("dse.link.bt", **lab).inc(data["bt"])
        reg.counter("dse.link.packets", **lab).inc(data["packets"])
    elif kind == "dse.point":
        reg.counter("dse.points", width=data["width"]).inc()
        reg.histogram("dse.point.bt_reduction").observe(data["bt_reduction"])
    elif kind == "codec.stream":
        reg.counter(
            "codec.stream.bt", workload=data["workload"],
            stream=data["stream"],
        ).inc(data["bt"])
    elif kind == "capture.stream":
        lab = {"scenario": data["scenario"], "stream": data["stream"]}
        reg.counter("capture.bytes", **lab).inc(data["bytes"])
        reg.counter("capture.streams", **lab).inc()
    else:  # unknown kinds still count — new probes degrade gracefully
        reg.counter(f"{kind}.calls", **_labels(kind, data)).inc()


class _SpanCtx:
    """One probe span fanned out to every active tracer + registry."""

    __slots__ = ("_sink", "_kind", "_data", "_ends", "_t0")

    def __init__(self, sink: "_Sink", kind: str, data: dict) -> None:
        self._sink, self._kind, self._data = sink, kind, data

    def __enter__(self):
        self._ends = [
            t.begin(self._kind, args=self._data) for t in self._sink.tracers
        ]
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        seconds = time.perf_counter() - self._t0
        for end in self._ends:
            end()
        for reg in self._sink.registries:
            _record_span(reg, self._kind, self._data, seconds)
        return False


class _Sink:
    """The multiplexer installed into ``repro._obs_hooks.SINK``."""

    def __init__(self) -> None:
        self.registries: list[Registry] = []
        self.tracers: list[Tracer] = []

    def span(self, kind: str, data: dict) -> _SpanCtx:
        return _SpanCtx(self, kind, data)

    def event(self, kind: str, data: dict) -> None:
        for t in self.tracers:
            t.instant(kind, args=data)
        for reg in self.registries:
            _record_event(reg, kind, data)


_SINK = _Sink()


def _refresh() -> None:
    _obs_hooks.SINK = (
        _SINK if (_SINK.registries or _SINK.tracers) else None
    )


def active_registries() -> tuple[Registry, ...]:
    return tuple(_SINK.registries)


def active_tracers() -> tuple[Tracer, ...]:
    return tuple(_SINK.tracers)


@contextmanager
def collect(registry: Registry | None = None):
    """Activate metrics collection for the with-body; yields the registry.

    Nested ``collect()`` scopes all receive every probe firing (each scope
    sees its own totals).  Entering the first scope is what installs the
    sink — before that, probes are a ``None`` test and nothing else.
    """
    reg = Registry() if registry is None else registry
    _SINK.registries.append(reg)
    _refresh()
    try:
        yield reg
    finally:
        _SINK.registries.remove(reg)
        _refresh()


@contextmanager
def tracing(tracer: Tracer | None = None):
    """Activate span tracing for the with-body; yields the tracer."""
    tr = Tracer() if tracer is None else tracer
    _SINK.tracers.append(tr)
    _refresh()
    try:
        yield tr
    finally:
        _SINK.tracers.remove(tr)
        _refresh()
