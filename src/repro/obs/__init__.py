# repro.obs — observability for the kernel/link/NoC/DSE stack
# (DESIGN.md §14):
#   metrics.py - counter/gauge/histogram registry + scoped collect()
#   trace.py   - span API emitting Chrome/Perfetto trace-event JSON
#   probes.py  - the sink behind repro._obs_hooks: probe vocabulary,
#                collect()/tracing() activation
#   report.py  - per-link BT tables, top-N hottest links, CSV/JSON dumps
#
# Disabled by default with provably zero cost: production modules import
# only repro._obs_hooks (a None-test per probe, fired OUTSIDE any traced
# computation), so importing or activating this package leaves every
# kernel entry point's traced jaxpr byte-identical (tests/test_obs.py).
from .metrics import Counter, Gauge, Histogram, Registry, registry_from_dict
from .probes import active_registries, active_tracers, collect, tracing
from .report import (
    format_links,
    link_table,
    metrics_dict,
    read_metrics_json,
    top_links,
    write_links_csv,
    write_metrics_json,
)
from .trace import Tracer

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "Registry",
    "registry_from_dict",
    "Tracer",
    "collect",
    "tracing",
    "active_registries",
    "active_tracers",
    "link_table",
    "top_links",
    "format_links",
    "write_links_csv",
    "metrics_dict",
    "write_metrics_json",
    "read_metrics_json",
]
