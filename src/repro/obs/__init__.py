# repro.obs — observability for the kernel/link/NoC/DSE stack
# (DESIGN.md §14):
#   metrics.py  - counter/gauge/histogram registry + scoped collect()
#   trace.py    - span API emitting Chrome/Perfetto trace-event JSON
#   probes.py   - the sink behind repro._obs_hooks: probe vocabulary,
#                 collect()/tracing() activation
#   report.py   - per-link BT tables, top-N hottest links, CSV/JSON dumps
#   activity.py - wire-level switching-activity profiles (DESIGN.md §15)
#   saif.py     - SAIF / VCD export of measured activity for EDA flows
#   capture.py  - real-model traffic capture: taps on the model zoo
#                 recording int8 wire streams for the BT stack
#                 (DESIGN.md §16)
#
# Disabled by default with provably zero cost: production modules import
# only repro._obs_hooks (a None-test per probe, fired OUTSIDE any traced
# computation), so importing or activating this package leaves every
# kernel entry point's traced jaxpr byte-identical (tests/test_obs.py).
from .activity import (
    ActivityProfile,
    link_profiles,
    profile_from_arrays,
    profiles_from_noc,
    wire_name,
    wire_records,
    write_wires_csv,
)
from .capture import (
    TAP_SCENARIOS,
    CapturedStream,
    CaptureSession,
    capture,
    capture_lenet_conv,
    capture_moe_dispatch,
    capture_serve_decode,
    capture_train_step,
    load_session,
    save_session,
)
from .metrics import Counter, Gauge, Histogram, Registry, registry_from_dict
from .probes import (
    PROBE_KINDS,
    active_registries,
    active_tracers,
    collect,
    tracing,
)
from .report import (
    activity_table,
    format_links,
    format_scenarios,
    link_table,
    metrics_dict,
    read_metrics_json,
    scenario_table,
    top_links,
    top_wires,
    write_activity_csv,
    write_links_csv,
    write_metrics_json,
    write_scenarios_csv,
    write_scenarios_json,
)
from .saif import parse_saif, write_saif, write_vcd
from .trace import Tracer

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "Registry",
    "registry_from_dict",
    "Tracer",
    "PROBE_KINDS",
    "collect",
    "tracing",
    "active_registries",
    "active_tracers",
    "link_table",
    "top_links",
    "format_links",
    "write_links_csv",
    "activity_table",
    "top_wires",
    "write_activity_csv",
    "scenario_table",
    "format_scenarios",
    "write_scenarios_csv",
    "write_scenarios_json",
    "TAP_SCENARIOS",
    "CapturedStream",
    "CaptureSession",
    "capture",
    "capture_serve_decode",
    "capture_train_step",
    "capture_moe_dispatch",
    "capture_lenet_conv",
    "save_session",
    "load_session",
    "metrics_dict",
    "write_metrics_json",
    "read_metrics_json",
    "ActivityProfile",
    "profile_from_arrays",
    "link_profiles",
    "profiles_from_noc",
    "wire_name",
    "wire_records",
    "write_wires_csv",
    "parse_saif",
    "write_saif",
    "write_vcd",
]
