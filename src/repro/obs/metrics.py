"""Counter / gauge / histogram registry with a scoped ``collect()`` context
(DESIGN.md §14).

A :class:`Registry` holds labeled metric series; the probe layer
(``repro.obs.probes``) writes into every registry currently activated by a
``collect()`` context.  Everything is plain Python ints/floats — metrics
are recorded OUTSIDE any traced computation, so an active registry never
changes a jaxpr, and a registry serializes to flat JSON-safe records
(``to_dict`` / :func:`registry_from_dict` round-trip exactly, asserted in
``tests/test_obs.py``).

Series identity is ``(name, sorted labels)``; the same call site with the
same labels accumulates into one series.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterator, Mapping

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "Registry",
    "registry_from_dict",
]

_Key = tuple[str, tuple[tuple[str, str], ...]]


def _key(name: str, labels: Mapping[str, object]) -> _Key:
    return name, tuple(sorted((k, str(v)) for k, v in labels.items()))


@dataclasses.dataclass
class Counter:
    """Monotonically accumulating value (BT totals, dispatch counts)."""

    name: str
    labels: dict[str, str]
    value: float = 0

    def inc(self, amount: float = 1) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name!r}: negative inc {amount}")
        self.value += amount


@dataclasses.dataclass
class Gauge:
    """Last-write-wins value (current link count, active backend id)."""

    name: str
    labels: dict[str, str]
    value: float = 0

    def set(self, value: float) -> None:
        self.value = value


@dataclasses.dataclass
class Histogram:
    """Streaming count/sum/min/max summary (span walls, per-link BT)."""

    name: str
    labels: dict[str, str]
    count: int = 0
    sum: float = 0.0
    min: float = math.inf
    max: float = -math.inf

    def observe(self, value: float) -> None:
        self.count += 1
        self.sum += value
        self.min = min(self.min, value)
        self.max = max(self.max, value)

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0


class Registry:
    """One scope's metric series, keyed by (name, labels)."""

    def __init__(self) -> None:
        self._counters: dict[_Key, Counter] = {}
        self._gauges: dict[_Key, Gauge] = {}
        self._histograms: dict[_Key, Histogram] = {}

    # ------------------------------------------------------------ factories
    def counter(self, name: str, **labels) -> Counter:
        k = _key(name, labels)
        c = self._counters.get(k)
        if c is None:
            c = self._counters[k] = Counter(name, dict(k[1]))
        return c

    def gauge(self, name: str, **labels) -> Gauge:
        k = _key(name, labels)
        g = self._gauges.get(k)
        if g is None:
            g = self._gauges[k] = Gauge(name, dict(k[1]))
        return g

    def histogram(self, name: str, **labels) -> Histogram:
        k = _key(name, labels)
        h = self._histograms.get(k)
        if h is None:
            h = self._histograms[k] = Histogram(name, dict(k[1]))
        return h

    # -------------------------------------------------------------- queries
    def series(self, name: str) -> Iterator[Counter | Gauge | Histogram]:
        """Every series (any kind) with this metric name."""
        for store in (self._counters, self._gauges, self._histograms):
            for (n, _), s in store.items():
                if n == name:
                    yield s

    def value(self, name: str, **labels) -> float:
        """The value of one counter/gauge series (0 when never written)."""
        k = _key(name, labels)
        s = self._counters.get(k) or self._gauges.get(k)
        return 0 if s is None else s.value

    def __len__(self) -> int:
        return (
            len(self._counters) + len(self._gauges) + len(self._histograms)
        )

    # -------------------------------------------------------- serialization
    def to_dict(self) -> dict:
        """Flat JSON-safe records (the metrics report schema)."""

        def num(v: float):
            return v if isinstance(v, int) or math.isfinite(v) else None

        return {
            "counters": [
                {"name": c.name, "labels": c.labels, "value": c.value}
                for c in self._counters.values()
            ],
            "gauges": [
                {"name": g.name, "labels": g.labels, "value": g.value}
                for g in self._gauges.values()
            ],
            "histograms": [
                {
                    "name": h.name,
                    "labels": h.labels,
                    "count": h.count,
                    "sum": h.sum,
                    "min": num(h.min),
                    "max": num(h.max),
                }
                for h in self._histograms.values()
            ],
        }


def registry_from_dict(doc: Mapping) -> Registry:
    """Rebuild a registry from :meth:`Registry.to_dict` output (the JSON
    round-trip used by the report layer and pinned in tests)."""
    reg = Registry()
    for rec in doc.get("counters", ()):
        reg.counter(rec["name"], **rec["labels"]).value = rec["value"]
    for rec in doc.get("gauges", ()):
        reg.gauge(rec["name"], **rec["labels"]).value = rec["value"]
    for rec in doc.get("histograms", ()):
        h = reg.histogram(rec["name"], **rec["labels"])
        h.count, h.sum = rec["count"], rec["sum"]
        h.min = math.inf if rec["min"] is None else rec["min"]
        h.max = -math.inf if rec["max"] is None else rec["max"]
    return reg
