"""Wire-level switching-activity profiles (DESIGN.md §15).

The kernels' ``activity_windows=`` mode (``repro.kernels.bt_count_axes`` /
``bt_count_links``) returns raw per-wire × per-time-window toggle tensors
plus per-wire time-at-1 totals; this module wraps one measured link's
tensors into an :class:`ActivityProfile` — the unit of wire-resolved
telemetry that the SAIF/VCD exporters (``repro.obs.saif``), the per-wire
heatmap CSV, and the wire-resolved power model all consume.

Wire indexing is fixed by the kernel layout: data wire ``i`` is bit
``i % 8`` of byte lane ``i // 8`` (LSB first), named ``lane<l>_b<b>``;
codec aux wires (the bus-invert invert lines) follow the data wires and
are named ``inv<p>``.  The load-bearing invariant — pinned by
:meth:`ActivityProfile.check` and the property tests — is that the sum of
per-wire toggles equals the link's gross BT (data + aux), i.e. nothing the
scalar accounting counts escapes the wire-resolved view.
"""

from __future__ import annotations

import csv
import dataclasses
import os
from typing import Sequence

import numpy as np

__all__ = [
    "ActivityProfile",
    "profile_from_arrays",
    "link_profiles",
    "profiles_from_noc",
    "wire_name",
    "wire_records",
    "write_wires_csv",
    "WIRE_FIELDS",
]


def wire_name(index: int, data_lanes: int) -> str:
    """Canonical net name of wire ``index`` (DESIGN.md §15 / SAIF nets)."""
    dw = data_lanes * 8
    if index < 0:
        raise ValueError(f"negative wire index {index}")
    if index < dw:
        return f"lane{index // 8}_b{index % 8}"
    return f"inv{index - dw}"


@dataclasses.dataclass(frozen=True)
class ActivityProfile:
    """One link's wire-resolved switching activity.

    ``toggles`` is (num_windows, num_wires) — transition counts per time
    window (a window spans ``window_flits`` flit rows); ``ones`` is
    (num_wires,) — flit rows each wire spent at logic 1 over the whole
    ``duration_flits`` run (SAIF T1; T0 = duration − T1).
    """

    name: str
    window_flits: int
    duration_flits: int
    data_lanes: int
    toggles: np.ndarray
    ones: np.ndarray

    def __post_init__(self) -> None:
        tog = np.asarray(self.toggles, dtype=np.int64)
        one = np.asarray(self.ones, dtype=np.int64)
        if tog.ndim != 2:
            raise ValueError(
                f"toggles must be (windows, wires), got {tog.shape}"
            )
        if one.shape != (tog.shape[1],):
            raise ValueError(
                f"ones shape {one.shape} != (num_wires,)={tog.shape[1:]}"
            )
        if tog.shape[1] < self.data_lanes * 8:
            raise ValueError(
                f"{tog.shape[1]} wires < {self.data_lanes} lanes x 8 bits"
            )
        if self.window_flits < 1:
            raise ValueError(f"window_flits must be >= 1: {self.window_flits}")
        object.__setattr__(self, "toggles", tog)
        object.__setattr__(self, "ones", one)

    # ------------------------------------------------------------- geometry
    @property
    def num_windows(self) -> int:
        return int(self.toggles.shape[0])

    @property
    def num_wires(self) -> int:
        return int(self.toggles.shape[1])

    @property
    def data_wires(self) -> int:
        return self.data_lanes * 8

    @property
    def aux_wires(self) -> int:
        return self.num_wires - self.data_wires

    def wire_names(self) -> list[str]:
        return [wire_name(i, self.data_lanes) for i in range(self.num_wires)]

    # ------------------------------------------------------------ summaries
    @property
    def per_wire(self) -> np.ndarray:
        """Total toggles per wire over the whole run — (num_wires,)."""
        return self.toggles.sum(axis=0)

    @property
    def gross_bt(self) -> int:
        """All transitions on all wires (data + aux) — the scalar the
        per-link counters report."""
        return int(self.per_wire.sum())

    @property
    def waveform(self) -> np.ndarray:
        """Total toggles per time window — (num_windows,), the time view."""
        return self.toggles.sum(axis=1)

    @property
    def toggle_rate(self) -> np.ndarray:
        """Per-wire activity factor: toggles per flit-boundary opportunity
        (``duration − 1`` boundaries) — (num_wires,) float in [0, 1]."""
        return self.per_wire / max(self.duration_flits - 1, 1)

    @property
    def static_prob(self) -> np.ndarray:
        """Per-wire probability of logic 1 (SAIF T1 / duration)."""
        return self.ones / max(self.duration_flits, 1)

    @property
    def t1(self) -> np.ndarray:
        """SAIF T1 per wire: flit rows at logic 1."""
        return self.ones

    @property
    def t0(self) -> np.ndarray:
        """SAIF T0 per wire: flit rows at logic 0."""
        return self.duration_flits - self.ones

    def rate_histogram(
        self, bins: int = 10
    ) -> tuple[np.ndarray, np.ndarray]:
        """Histogram of per-wire toggle rates — (counts, bin_edges) over
        [0, 1], the hot-wire-tail view."""
        return np.histogram(self.toggle_rate, bins=bins, range=(0.0, 1.0))

    def hottest_wires(self, n: int = 5) -> list[tuple[str, int]]:
        """The n wires with the most toggles, descending — ties broken by
        wire index so the ranking is deterministic."""
        pw = self.per_wire
        order = np.lexsort((np.arange(len(pw)), -pw))[:n]
        return [(wire_name(int(i), self.data_lanes), int(pw[i])) for i in order]

    # ------------------------------------------------------------ invariant
    def check(self, gross_bt: int | None = None) -> None:
        """Assert internal consistency; with ``gross_bt`` also pin the
        wire-vs-scalar invariant ``sum(per-wire toggles) == gross_bt``.

        Per-wire sanity: a wire cannot toggle more than once per boundary
        and cannot be at 1 for more rows than the run has.
        """
        max_tog = max(self.duration_flits - 1, 0)
        if (self.per_wire > max_tog).any():
            raise ValueError(
                f"{self.name}: wire toggles exceed {max_tog} boundaries"
            )
        if (self.ones > self.duration_flits).any() or (self.ones < 0).any():
            raise ValueError(
                f"{self.name}: T1 outside [0, {self.duration_flits}]"
            )
        if gross_bt is not None and self.gross_bt != int(gross_bt):
            raise ValueError(
                f"{self.name}: sum(per-wire toggles) = {self.gross_bt} "
                f"!= gross BT {int(gross_bt)}"
            )


def profile_from_arrays(
    name: str,
    toggles,
    ones,
    *,
    window_flits: int,
    duration_flits: int,
    data_lanes: int,
) -> ActivityProfile:
    """Wrap one link's raw kernel activity arrays, trimming the trailing
    all-padding windows of a stacked jagged batch (a link shorter than the
    batch's T_max owns only ``ceil(duration / window)`` windows)."""
    tog = np.asarray(toggles, dtype=np.int64)
    nw = -(-duration_flits // window_flits) if duration_flits else 0
    return ActivityProfile(
        name=name,
        window_flits=window_flits,
        duration_flits=duration_flits,
        data_lanes=data_lanes,
        toggles=tog[:nw],
        ones=np.asarray(ones, dtype=np.int64),
    )


def link_profiles(
    activity,
    *,
    window_flits: int,
    lengths: Sequence[int],
    data_lanes: int,
    names: Sequence[str] | None = None,
) -> list[ActivityProfile]:
    """Profiles for a batched measurement — duck-typed over anything with
    ``.toggles`` (L, NW, W) and ``.ones`` (L, W) arrays, i.e. the
    ``LinkActivity`` result of ``bt_count_links(..., activity_windows=)``.
    """
    tog = np.asarray(activity.toggles)
    one = np.asarray(activity.ones)
    if names is None:
        names = [f"link{i}" for i in range(tog.shape[0])]
    return [
        profile_from_arrays(
            str(names[i]),
            tog[i],
            one[i],
            window_flits=window_flits,
            duration_flits=int(lengths[i]),
            data_lanes=data_lanes,
        )
        for i in range(tog.shape[0])
    ]


def profiles_from_noc(report) -> list[ActivityProfile]:
    """Profiles from a ``simulate_noc(activity_windows=)`` report —
    duck-typed over ``.links`` / ``.wire_toggles`` / ``.wire_ones`` /
    ``.activity_window`` so ``repro.noc`` never has to import ``repro.obs``
    (the zero-cost-observability direction of DESIGN.md §14)."""
    if not getattr(report, "activity_window", 0):
        raise ValueError(
            f"report {getattr(report, 'name', '?')!r} carries no activity "
            "(run simulate_noc with activity_windows=)"
        )
    lanes = report.wire_lanes
    return [
        profile_from_arrays(
            f"{report.name}.link{s.link}",
            report.wire_toggles[i],
            report.wire_ones[i],
            window_flits=report.activity_window,
            duration_flits=s.num_flits,
            data_lanes=lanes,
        )
        for i, s in enumerate(report.links)
    ]


WIRE_FIELDS = (
    "profile",
    "wire",
    "net",
    "kind",
    "lane",
    "bit",
    "toggles",
    "t1",
    "t0",
    "toggle_rate",
    "static_prob",
)


def _ensure_parent(path: str) -> None:
    parent = os.path.dirname(path)
    if parent:
        os.makedirs(parent, exist_ok=True)


def wire_records(profiles: Sequence[ActivityProfile]) -> list[dict]:
    """One flat JSON-safe record per (profile, wire) — the heatmap rows."""
    rows: list[dict] = []
    for p in profiles:
        pw, t1, t0 = p.per_wire, p.t1, p.t0
        rate, prob = p.toggle_rate, p.static_prob
        dw = p.data_wires
        for i in range(p.num_wires):
            rows.append(
                {
                    "profile": p.name,
                    "wire": i,
                    "net": wire_name(i, p.data_lanes),
                    "kind": "data" if i < dw else "aux",
                    "lane": i // 8 if i < dw else "",
                    "bit": i % 8 if i < dw else "",
                    "toggles": int(pw[i]),
                    "t1": int(t1[i]),
                    "t0": int(t0[i]),
                    "toggle_rate": round(float(rate[i]), 6),
                    "static_prob": round(float(prob[i]), 6),
                }
            )
    return rows


def write_wires_csv(
    path: str, profiles: Sequence[ActivityProfile]
) -> list[dict]:
    """Write (and return) the per-wire heatmap CSV — one row per wire of
    each profile, the ``(profile, wire)`` pair being the heatmap
    coordinate (README: "wire heatmap in 3 commands")."""
    rows = wire_records(profiles)
    _ensure_parent(path)
    with open(path, "w", newline="") as f:
        writer = csv.DictWriter(f, fieldnames=WIRE_FIELDS)
        writer.writeheader()
        writer.writerows(rows)
    return rows
