"""Popcount-ordering applied to real model traffic — the paper's technique
as a first-class framework feature (DESIGN.md §3.3).

This module owns the *model-side* integration points (which tensors may be
permuted, and how, without changing results); the stream mechanics (encode /
row-bucket keys / flit layout / BT measurement) live in the unified TX
pipeline, :mod:`repro.link`, and are delegated to it.

Three integration points, all exploiting order-insensitive accumulation:

  1. **Contraction-axis weight permutation** (`apply_mlp_ordering`,
     `apply_head_ordering`): for ``y = act(x @ Wg, x @ Wu) @ Wd`` the d_ff
     axis order is free — permuting Wg/Wu columns together with Wd rows is a
     numeric no-op (up to fp addition order).  We order d_ff rows by the
     popcount bucket of their int8-quantized bytes so the *weight stream*
     (HBM -> VMEM during decode; the dominant decode traffic) has monotone
     Hamming weight — the TPU analogue of the paper's link ordering.
     Attention heads are permuted analogously (KV-head groups move with
     their q-head blocks and output rows).

  2. **Gradient egress permutation** (`egress_permutation`): a static
     permutation of the int8 gradient wire image, derived from the weight
     bytes so it is identical on every replica (value-dependent per-step
     sorting would desynchronise the reduction — recorded as an adaptation
     from the paper's per-packet sorting, DESIGN.md §8).

  3. **BT accounting** (`stream_bt_report`): models any tensor as a 128-bit
     flit stream and measures bit transitions before/after ordering via a
     ``repro.link.TxPipeline`` row-stream measurement — this is what feeds
     the link-energy column of the roofline report.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from repro.link import LinkSpec, TxPipeline, row_bucket_keys as _link_row_bucket_keys
from repro.link import tensor_flit_stream, to_sign_magnitude  # noqa: F401  (re-export)
from repro.models.config import ModelConfig

Strategy = Literal["none", "acc", "app"]


def _row_levels(strategy: Strategy, k: int) -> int:
    """ACC keeps the element-granularity 9-level mapping; APP coarsens to k."""
    return 9 if strategy == "acc" else k


# --------------------------------------------------------------------------
# int8 views and popcount keys
# --------------------------------------------------------------------------


def int8_view(w: jax.Array) -> jax.Array:
    """Symmetric per-tensor int8 quantization of a weight tensor (the wire /
    HBM-stream image used for BT accounting and ordering keys)."""
    amax = jnp.max(jnp.abs(w.astype(jnp.float32)))
    scale = jnp.maximum(amax / 127.0, 1e-12)
    return jnp.clip(jnp.round(w.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)


def row_bucket_keys(
    rows_int8: jax.Array, strategy: Strategy, k: int = 4
) -> jax.Array:
    """Bucket key per row of an (R, B) int8 matrix (see
    :func:`repro.link.row_bucket_keys` for the mapping)."""
    return _link_row_bucket_keys(
        rows_int8.astype(jnp.uint8), _row_levels(strategy, k)
    )


def row_order(rows_int8: jax.Array, strategy: Strategy, k: int = 4) -> jax.Array:
    """Stable comparison-free sort order of rows by popcount bucket."""
    if strategy == "none":
        return jnp.arange(rows_int8.shape[0], dtype=jnp.int32)
    pipe = TxPipeline(_row_spec(strategy, k, sign_magnitude=False, layout="row"))
    return pipe.row_order(rows_int8.astype(jnp.uint8))


# --------------------------------------------------------------------------
# contraction-axis weight permutation (numeric no-op graph rewrites)
# --------------------------------------------------------------------------


def mlp_permutation(mlp_params: dict, strategy: Strategy, k: int = 4) -> jax.Array:
    """d_ff permutation keyed on the down-projection rows (streamed axis)."""
    down = mlp_params["down"]  # (ff, d)
    return row_order(int8_view(down), strategy, k)


def apply_mlp_ordering(
    mlp_params: dict, perm: jax.Array
) -> dict:
    """Permute the d_ff axis: gate/up columns and down rows move together."""
    out = dict(mlp_params)
    if "gate" in out:
        out["gate"] = out["gate"][..., perm]
    out["up"] = out["up"][..., perm]
    out["down"] = jnp.take(out["down"], perm, axis=-2)
    return out


def head_permutation(attn_params: dict, cfg: ModelConfig, strategy: Strategy, k: int = 4) -> jax.Array:
    """KV-head-group permutation keyed on wk bytes (groups move atomically
    so GQA head->group mapping is preserved)."""
    wk = attn_params["wk"]  # (d, Hkv, hd)
    hkv = wk.shape[-2]
    rows = int8_view(wk).transpose(1, 0, 2).reshape(hkv, -1)
    return row_order(rows, strategy, k)


def apply_head_ordering(attn_params: dict, cfg: ModelConfig, perm: jax.Array) -> dict:
    """Permute KV-head groups (wk/wv) and the matching q-head blocks (wq/wo)."""
    out = dict(attn_params)
    rep = cfg.q_rep
    hkv = out["wk"].shape[-2]
    out["wk"] = jnp.take(out["wk"], perm, axis=-2)
    out["wv"] = jnp.take(out["wv"], perm, axis=-2)
    d, h, hd = out["wq"].shape
    wq = out["wq"].reshape(d, hkv, rep, hd)
    out["wq"] = jnp.take(wq, perm, axis=1).reshape(d, h, hd)
    wo = out["wo"].reshape(hkv, rep, hd, -1)
    out["wo"] = jnp.take(wo, perm, axis=0).reshape(h, hd, -1)
    return out


def apply_weight_ordering(
    params: dict, cfg: ModelConfig, strategy: Strategy = "app", k: int = 4
) -> dict:
    """Order every layer's MLP d_ff axis and attention KV groups.

    Layer-stacked params get per-layer permutations via vmap.  Returns a new
    params pytree; model outputs are unchanged up to fp summation order
    (verified in tests/test_traffic.py).
    """
    if strategy == "none":
        return params
    out = dict(params)

    def order_layer(lp: dict) -> dict:
        lp = dict(lp)
        if "mlp" in lp:
            perm = mlp_permutation(lp["mlp"], strategy, k)
            lp["mlp"] = apply_mlp_ordering(lp["mlp"], perm)
        if "attn" in lp:
            perm = head_permutation(lp["attn"], cfg, strategy, k)
            lp["attn"] = apply_head_ordering(lp["attn"], cfg, perm)
        return lp

    for key in ("layers", "enc_layers", "trailing"):
        if key in out and isinstance(out[key], dict) and (
            "mlp" in out[key] or "attn" in out[key]
        ):
            out[key] = jax.vmap(order_layer)(out[key])
    if "shared" in out:
        out["shared"] = order_layer(out["shared"])
    return out


# --------------------------------------------------------------------------
# gradient egress permutation (static, replica-identical)
# --------------------------------------------------------------------------


def _host_bitwise_count(bytes_u8: np.ndarray) -> np.ndarray:
    """Host-side per-byte popcount with a NumPy<2 fallback.

    ``np.bitwise_count`` is NumPy 2.x only; older NumPy gets the
    ``unpackbits`` formulation (identical results for uint8 views).
    """
    if hasattr(np, "bitwise_count"):
        return np.bitwise_count(bytes_u8)
    return np.unpackbits(bytes_u8[..., None], axis=-1).sum(axis=-1)


def egress_permutation(
    weights_flat_int8: jax.Array, packet: int = 64, strategy: Strategy = "app", k: int = 4
) -> tuple[np.ndarray, np.ndarray]:
    """Static wire permutation: int8 positions grouped into ``packet``-byte
    packets, packets ordered within by the *weight* byte popcount bucket.

    Returns (perm, inv_perm) as numpy int32 (host-side, computed once).
    """
    m = weights_flat_int8.shape[0]
    usable = (m // packet) * packet
    w = np.asarray(weights_flat_int8[:usable]).reshape(-1, packet)
    bits = _host_bitwise_count(w.view(np.uint8)).astype(np.int32)
    levels = _row_levels(strategy, k)
    keys = (bits * levels) // 9
    order = np.argsort(keys, axis=1, kind="stable")
    base = np.arange(0, usable, packet, dtype=np.int64)[:, None]
    perm = (base + order).reshape(-1)
    perm = np.concatenate([perm, np.arange(usable, m, dtype=np.int64)])
    inv = np.empty_like(perm)
    inv[perm] = np.arange(m, dtype=np.int64)
    return perm.astype(np.int32), inv.astype(np.int32)


# --------------------------------------------------------------------------
# BT accounting over modeled flit streams (delegates to repro.link)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BTStreamReport:
    name: str
    num_flits: int
    bt_none: float
    bt_ordered: float

    @property
    def reduction(self) -> float:
        return 1.0 - self.bt_ordered / max(self.bt_none, 1e-9)


def _row_spec(
    strategy: Strategy, k: int, sign_magnitude: bool, layout: str
) -> LinkSpec:
    return LinkSpec(
        width_bits=128,
        flits_per_packet=1,
        input_lanes=16,
        weight_lanes=0,
        key="none" if strategy == "none" else "row_bucket",
        encode="sign_magnitude" if sign_magnitude else "identity",
        pack="col" if layout == "col" else "row",
        k=_row_levels(strategy, k),
    )


def stream_bt_report(
    name: str,
    tensor: jax.Array,
    strategy: Strategy = "app",
    k: int = 4,
    row_axis: int = -2,
    lanes: int = 16,
    sign_magnitude: bool = False,
    layout: Literal["row", "col"] = "row",
) -> BTStreamReport:
    """BT of streaming ``tensor`` before/after popcount row ordering.

    ``layout="row"`` streams whole rows (the HBM-natural order; row ordering
    only touches row-boundary flits).  ``layout="col"`` interleaves rows
    column-major so consecutive flits carry *adjacent rows in the sorted
    order* — the layout under which row ordering has leverage (see the
    measured trade-off in EXPERIMENTS.md §Arch-BT).

    Implemented as two ``repro.link.TxPipeline`` row-stream measurements
    (baseline spec with key='none', ordered spec as configured).
    """
    t8 = int8_view(tensor)
    mat = jnp.moveaxis(t8, row_axis, 0).reshape(t8.shape[row_axis], -1)
    # encode is part of BOTH specs: the baseline wire image is the encoded
    # one, so the report isolates the *ordering* gain (the encoding gain is
    # measured by comparing reports with sign_magnitude on/off)
    base_spec = dataclasses.replace(
        _row_spec("none", k, sign_magnitude, layout),
        width_bits=lanes * 8, input_lanes=lanes,
    )
    ord_spec = dataclasses.replace(
        _row_spec(strategy, k, sign_magnitude, layout),
        width_bits=lanes * 8, input_lanes=lanes,
    )
    base = TxPipeline(base_spec).measure_rows(mat, name=name)
    ordered = TxPipeline(ord_spec).measure_rows(mat, name=name)
    return BTStreamReport(name, base.num_flits, base.total_bt, ordered.total_bt)
