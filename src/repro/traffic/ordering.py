"""Popcount-ordering applied to real model traffic — the paper's technique
as a first-class framework feature (DESIGN.md §3.3).

Three integration points, all exploiting order-insensitive accumulation:

  1. **Contraction-axis weight permutation** (`apply_mlp_ordering`,
     `apply_head_ordering`): for ``y = act(x @ Wg, x @ Wu) @ Wd`` the d_ff
     axis order is free — permuting Wg/Wu columns together with Wd rows is a
     numeric no-op (up to fp addition order).  We order d_ff rows by the
     popcount bucket of their int8-quantized bytes so the *weight stream*
     (HBM -> VMEM during decode; the dominant decode traffic) has monotone
     Hamming weight — the TPU analogue of the paper's link ordering.
     Attention heads are permuted analogously (KV-head groups move with
     their q-head blocks and output rows).

  2. **Gradient egress permutation** (`egress_permutation`): a static
     permutation of the int8 gradient wire image, derived from the weight
     bytes so it is identical on every replica (value-dependent per-step
     sorting would desynchronise the reduction — recorded as an adaptation
     from the paper's per-packet sorting, DESIGN.md §8).

  3. **BT accounting** (`stream_bt_report`): models any tensor as a 128-bit
     flit stream and measures bit transitions before/after ordering with the
     Pallas BT kernel — this is what feeds the link-energy column of the
     roofline report.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.popcount import popcount
from repro.core.sorting import counting_sort_indices
from repro.kernels import bt_count
from repro.models.config import ModelConfig

Strategy = Literal["none", "acc", "app"]


# --------------------------------------------------------------------------
# int8 views and popcount keys
# --------------------------------------------------------------------------


def int8_view(w: jax.Array) -> jax.Array:
    """Symmetric per-tensor int8 quantization of a weight tensor (the wire /
    HBM-stream image used for BT accounting and ordering keys)."""
    amax = jnp.max(jnp.abs(w.astype(jnp.float32)))
    scale = jnp.maximum(amax / 127.0, 1e-12)
    return jnp.clip(jnp.round(w.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)


def to_sign_magnitude(q_int8: jax.Array) -> jax.Array:
    """Recode two's-complement int8 as sign-magnitude bytes.

    Beyond-paper optimization (EXPERIMENTS.md §Arch-BT): two's complement
    decorrelates popcount from magnitude (-1 = 0xFF has popcount 8), which
    both halves the ordering signal and inflates baseline BT.  Sign-magnitude
    makes popcount monotone in |value| — near-zero weights become near-zero
    bytes — cutting weight-stream BT by ~50 % *before* any ordering.  In
    hardware this is one XOR per bit at the link interface.
    """
    q = q_int8.astype(jnp.int16)
    sign = (q < 0).astype(jnp.uint8) << 7
    return (sign | jnp.abs(q).astype(jnp.uint8)).astype(jnp.uint8)


def row_bucket_keys(
    rows_int8: jax.Array, strategy: Strategy, k: int = 4
) -> jax.Array:
    """Bucket key per row of an (R, B) int8 matrix.

    Row key = total '1'-bit count of the row's bytes, mapped to buckets the
    same way the paper maps element popcounts: ACC keeps the exact count
    quantised to W+1=9 levels (matching the element-granularity datapath),
    APP coarsens to k buckets.
    """
    bits = popcount(rows_int8.astype(jnp.uint8), 8).sum(axis=-1)  # (R,)
    nbytes = rows_int8.shape[-1]
    max_bits = 8 * nbytes
    levels = 9 if strategy == "acc" else k
    return (bits * levels) // (max_bits + 1)


def row_order(rows_int8: jax.Array, strategy: Strategy, k: int = 4) -> jax.Array:
    """Stable comparison-free sort order of rows by popcount bucket."""
    if strategy == "none":
        return jnp.arange(rows_int8.shape[0], dtype=jnp.int32)
    levels = 9 if strategy == "acc" else k
    keys = row_bucket_keys(rows_int8, strategy, k)
    return counting_sort_indices(keys, levels)


# --------------------------------------------------------------------------
# contraction-axis weight permutation (numeric no-op graph rewrites)
# --------------------------------------------------------------------------


def mlp_permutation(mlp_params: dict, strategy: Strategy, k: int = 4) -> jax.Array:
    """d_ff permutation keyed on the down-projection rows (streamed axis)."""
    down = mlp_params["down"]  # (ff, d)
    return row_order(int8_view(down), strategy, k)


def apply_mlp_ordering(
    mlp_params: dict, perm: jax.Array
) -> dict:
    """Permute the d_ff axis: gate/up columns and down rows move together."""
    out = dict(mlp_params)
    if "gate" in out:
        out["gate"] = out["gate"][..., perm]
    out["up"] = out["up"][..., perm]
    out["down"] = jnp.take(out["down"], perm, axis=-2)
    return out


def head_permutation(attn_params: dict, cfg: ModelConfig, strategy: Strategy, k: int = 4) -> jax.Array:
    """KV-head-group permutation keyed on wk bytes (groups move atomically
    so GQA head->group mapping is preserved)."""
    wk = attn_params["wk"]  # (d, Hkv, hd)
    hkv = wk.shape[-2]
    rows = int8_view(wk).transpose(1, 0, 2).reshape(hkv, -1)
    return row_order(rows, strategy, k)


def apply_head_ordering(attn_params: dict, cfg: ModelConfig, perm: jax.Array) -> dict:
    """Permute KV-head groups (wk/wv) and the matching q-head blocks (wq/wo)."""
    out = dict(attn_params)
    rep = cfg.q_rep
    hkv = out["wk"].shape[-2]
    out["wk"] = jnp.take(out["wk"], perm, axis=-2)
    out["wv"] = jnp.take(out["wv"], perm, axis=-2)
    d, h, hd = out["wq"].shape
    wq = out["wq"].reshape(d, hkv, rep, hd)
    out["wq"] = jnp.take(wq, perm, axis=1).reshape(d, h, hd)
    wo = out["wo"].reshape(hkv, rep, hd, -1)
    out["wo"] = jnp.take(wo, perm, axis=0).reshape(h, hd, -1)
    return out


def apply_weight_ordering(
    params: dict, cfg: ModelConfig, strategy: Strategy = "app", k: int = 4
) -> dict:
    """Order every layer's MLP d_ff axis and attention KV groups.

    Layer-stacked params get per-layer permutations via vmap.  Returns a new
    params pytree; model outputs are unchanged up to fp summation order
    (verified in tests/test_traffic.py).
    """
    if strategy == "none":
        return params
    out = dict(params)

    def order_layer(lp: dict) -> dict:
        lp = dict(lp)
        if "mlp" in lp:
            perm = mlp_permutation(lp["mlp"], strategy, k)
            lp["mlp"] = apply_mlp_ordering(lp["mlp"], perm)
        if "attn" in lp:
            perm = head_permutation(lp["attn"], cfg, strategy, k)
            lp["attn"] = apply_head_ordering(lp["attn"], cfg, perm)
        return lp

    for key in ("layers", "enc_layers", "trailing"):
        if key in out and isinstance(out[key], dict) and (
            "mlp" in out[key] or "attn" in out[key]
        ):
            out[key] = jax.vmap(order_layer)(out[key])
    if "shared" in out:
        out["shared"] = order_layer(out["shared"])
    return out


# --------------------------------------------------------------------------
# gradient egress permutation (static, replica-identical)
# --------------------------------------------------------------------------


def egress_permutation(
    weights_flat_int8: jax.Array, packet: int = 64, strategy: Strategy = "app", k: int = 4
) -> tuple[np.ndarray, np.ndarray]:
    """Static wire permutation: int8 positions grouped into ``packet``-byte
    packets, packets ordered within by the *weight* byte popcount bucket.

    Returns (perm, inv_perm) as numpy int32 (host-side, computed once).
    """
    m = weights_flat_int8.shape[0]
    usable = (m // packet) * packet
    w = np.asarray(weights_flat_int8[:usable]).reshape(-1, packet)
    bits = np.bitwise_count(w.view(np.uint8)).astype(np.int32)
    levels = 9 if strategy == "acc" else k
    keys = (bits * levels) // 9
    order = np.argsort(keys, axis=1, kind="stable")
    base = np.arange(0, usable, packet, dtype=np.int64)[:, None]
    perm = (base + order).reshape(-1)
    perm = np.concatenate([perm, np.arange(usable, m, dtype=np.int64)])
    inv = np.empty_like(perm)
    inv[perm] = np.arange(m, dtype=np.int64)
    return perm.astype(np.int32), inv.astype(np.int32)


# --------------------------------------------------------------------------
# BT accounting over modeled flit streams
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BTStreamReport:
    name: str
    num_flits: int
    bt_none: float
    bt_ordered: float

    @property
    def reduction(self) -> float:
        return 1.0 - self.bt_ordered / max(self.bt_none, 1e-9)


def tensor_flit_stream(t_int8: jax.Array, lanes: int = 16) -> jax.Array:
    """View a tensor's int8 image as a (T, lanes) flit stream (128-bit link).

    Rows stream in the tensor's native last-axis-major order — for a weight
    matrix that is exactly the HBM row stream the decode path reads.
    """
    flat = t_int8.reshape(-1)
    usable = (flat.shape[0] // lanes) * lanes
    return flat[:usable].reshape(-1, lanes)


def stream_bt_report(
    name: str,
    tensor: jax.Array,
    strategy: Strategy = "app",
    k: int = 4,
    row_axis: int = -2,
    lanes: int = 16,
    sign_magnitude: bool = False,
    layout: Literal["row", "col"] = "row",
) -> BTStreamReport:
    """BT of streaming ``tensor`` before/after popcount row ordering.

    ``layout="row"`` streams whole rows (the HBM-natural order; row ordering
    only touches row-boundary flits).  ``layout="col"`` interleaves rows
    column-major so consecutive flits carry *adjacent rows in the sorted
    order* — the layout under which row ordering has leverage (see the
    measured trade-off in EXPERIMENTS.md §Arch-BT).
    """
    t8 = int8_view(tensor)
    mat = jnp.moveaxis(t8, row_axis, 0).reshape(t8.shape[row_axis], -1)
    if sign_magnitude:
        mat = to_sign_magnitude(mat)

    def stream(m):
        mm = m.T if layout == "col" else m
        return tensor_flit_stream(mm, lanes)

    base_stream = stream(mat)
    bt0 = int(bt_count(base_stream))
    order = row_order(mat, strategy, k)
    bt1 = int(bt_count(stream(jnp.take(mat, order, axis=0))))
    return BTStreamReport(name, base_stream.shape[0], bt0, bt1)
