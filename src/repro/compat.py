"""Small JAX version-compatibility surface.

The repo targets a range of jax releases; APIs that moved between them are
resolved here once so call sites stay clean.
"""

from __future__ import annotations

import jax

__all__ = ["shard_map"]

if hasattr(jax, "shard_map"):  # jax >= 0.6: promoted to the top level
    shard_map = jax.shard_map
else:  # jax <= 0.5: experimental namespace
    from jax.experimental.shard_map import shard_map  # noqa: F401
