"""codeqwen1.5-7b [dense]: 32L d_model=4096 32H (kv=32) d_ff=13440
vocab=92416 [hf:Qwen/CodeQwen1.5-7B].  qwen1.5 architecture (MHA at kv=32),
SwiGLU, long-context rope theta 1e6.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=13440,
    vocab=92416,
    act="swiglu",
    rope_theta=1_000_000.0,
).validate()

SMOKE = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=256)
