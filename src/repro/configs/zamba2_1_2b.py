"""zamba2-1.2b [hybrid]: 38L d_model=2048, Mamba2 backbone + SHARED attention
block (32H kv=32, head_dim=64, d_ff=8192 MLP), vocab=32000, ssm_state=64
[arXiv:2411.15242].

Structure (DESIGN.md §4): 6 groups of 6 SSM layers, each followed by ONE
shared attention+MLP block (same weights every invocation), plus 2 trailing
SSM layers = 38 SSM layers total.  Zamba2 alternates two shared blocks; we
model one (noted fidelity delta, DESIGN.md §8).
"""

from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab=32000,
    act="swiglu",
    shared_attn_every=6,
    ssm=SSMConfig(d_state=64, head_dim=64, expand=2, n_groups=1, d_conv=4, chunk=256),
).validate()

SMOKE = dict(
    n_layers=5, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128,
    vocab=256, shared_attn_every=2,
    ssm=SSMConfig(d_state=16, head_dim=16, expand=2, n_groups=1, d_conv=4, chunk=16),
)
