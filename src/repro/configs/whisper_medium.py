"""whisper-medium [audio]: enc-dec, 24L enc + 24L dec, d_model=1024 16H
d_ff=4096 vocab=51865 [arXiv:2212.04356].

The conv frontend is a STUB per the assignment: input_specs() provides
precomputed frame embeddings (B, 1500, d).  Adaptation notes (DESIGN.md §8):
RoPE replaces whisper's learned/sinusoidal positions; the assigned shapes'
seq_len applies to the DECODER sequence, encoder frames fixed at 1500.
"""

from repro.models.config import ModelConfig

ENC_FRAMES = 1500  # 30 s of audio at 50 Hz after the (stubbed) conv stem

CONFIG = ModelConfig(
    name="whisper-medium",
    family="audio",
    n_layers=24,
    n_enc_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=51865,
    act="gelu",
).validate()

SMOKE = dict(n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
             d_ff=128, vocab=256)
