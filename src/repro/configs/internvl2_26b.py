"""internvl2-26b [vlm]: 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92553 [arXiv:2404.16821] — InternLM2-20B language backbone.

The InternViT frontend is a STUB per the assignment: input_specs() provides
1024 precomputed patch embeddings (B, 1024, d) prepended to the text tokens;
seq_len counts patches + text.
"""

from repro.models.config import ModelConfig

N_PATCHES = 1024

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=92553,
    act="swiglu",
    rope_theta=1_000_000.0,
    n_frontend_tokens=N_PATCHES,
    logits_chunk=512,
    fsdp=True,
).validate()

SMOKE = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
             vocab=256, n_frontend_tokens=8, logits_chunk=0)
