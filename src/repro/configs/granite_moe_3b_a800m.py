"""granite-moe-3b-a800m [moe]: 32L d_model=1536 24H (GQA kv=8) vocab=49155,
MoE 40 experts top-8, expert d_ff=512 [ibm-granite assignment spec].

NOTE: the assignment line reads "MoE 40e top-8" while its trailing note says
"32 experts" (hf granite-3.0-1b-a400m has 32); we follow the primary spec:
40 experts.  40 does not divide the 16-wide "model" axis, so experts are
PADDED to 48 (pad_experts_to) and the router masks the 8 padded experts to
-inf — shardable without changing routing semantics (DESIGN.md §5).
"""

from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,
    vocab=49155,
    act="swiglu",
    moe=MoEConfig(num_experts=40, top_k=8, d_ff_expert=512, capacity_factor=1.5,
                  group_size=256, pad_experts_to=48),
).validate()

SMOKE = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=64, vocab=256,
             moe=MoEConfig(num_experts=5, top_k=2, d_ff_expert=64,
                           capacity_factor=2.0, group_size=32, pad_experts_to=8))
