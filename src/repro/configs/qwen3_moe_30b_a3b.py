"""qwen3-moe-30b-a3b [moe]: 48L d_model=2048 32H (GQA kv=4) vocab=151936,
MoE 128 experts top-8, expert d_ff=768, qk_norm, head_dim=128
[hf:Qwen/Qwen3-30B-A3B].

Experts shard 128/16 = 8 per device on the "model" mesh axis (EP).
"""

from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=768,
    vocab=151936,
    act="swiglu",
    qk_norm=True,
    rope_theta=1_000_000.0,
    logits_chunk=512,
    fsdp=True,
    moe=MoEConfig(num_experts=128, top_k=8, d_ff_expert=768, capacity_factor=1.5,
                  group_size=256),
).validate()

SMOKE = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
             d_ff=64, vocab=256, logits_chunk=0,
             moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=64,
                           capacity_factor=2.0, group_size=32))
