"""mamba2-370m [ssm]: 48L d_model=1024 (attn-free) vocab=50280, ssm_state=128.

SSD (state-space duality) [arXiv:2405.21060].  d_inner = 2*1024 = 2048,
SSM head_dim 64 -> 32 SSM heads.  The attention fields are unused
(family="ssm" has no attention blocks) but kept valid for the config schema.
"""

from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=0,
    vocab=50280,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, n_groups=1, d_conv=4, chunk=256),
    tie_embeddings=True,  # mamba2 ties in/out embeddings
).validate()

SMOKE = dict(
    n_layers=4, d_model=64, vocab=128,
    ssm=SSMConfig(d_state=16, head_dim=16, expand=2, n_groups=1, d_conv=4, chunk=16),
)
