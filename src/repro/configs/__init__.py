"""Architecture registry: the 10 assigned configs + reduced smoke variants.

``get_config(name)`` returns the exact assigned configuration;
``smoke_config(name)`` returns the same *family* at toy scale (few layers,
narrow width, tiny vocab/experts) for CPU smoke tests.  The FULL configs are
exercised only through the dry-run (ShapeDtypeStruct, no allocation).
"""

from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import ModelConfig

from .shapes import SHAPES, ShapeSpec, shapes_for_family  # noqa: F401

_MODULES = {
    "mamba2-370m": "mamba2_370m",
    "gemma-7b": "gemma_7b",
    "codeqwen1.5-7b": "codeqwen15_7b",
    "internlm2-1.8b": "internlm2_1_8b",
    "qwen3-4b": "qwen3_4b",
    "whisper-medium": "whisper_medium",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "internvl2-26b": "internvl2_26b",
    "zamba2-1.2b": "zamba2_1_2b",
}

ARCH_NAMES = list(_MODULES)


def _module(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; choose from {ARCH_NAMES}")
    return importlib.import_module(f"repro.configs.{_MODULES[name]}")


def get_config(name: str, **overrides) -> ModelConfig:
    cfg = _module(name).CONFIG
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides).validate()
    return cfg


def smoke_config(name: str, **overrides) -> ModelConfig:
    mod = _module(name)
    fields = dict(mod.SMOKE)
    fields.setdefault("attn_impl", "dense")
    fields.update(overrides)
    return dataclasses.replace(mod.CONFIG, **fields).validate()


def arch_shapes(name: str) -> list[str]:
    return shapes_for_family(get_config(name).family)


def all_cells() -> list[tuple[str, str]]:
    """Every assigned (arch, shape) pair — 40 nominal, minus documented
    long_500k skips for pure full-attention archs."""
    cells = []
    for arch in ARCH_NAMES:
        for shape in arch_shapes(arch):
            cells.append((arch, shape))
    return cells
