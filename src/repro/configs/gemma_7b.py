"""gemma-7b [dense]: 28L d_model=3072 16H (kv=16) d_ff=24576 vocab=256000.

GeGLU activation, head_dim=256 (> d_model / n_heads) [arXiv:2403.08295; hf].
The 256k vocab makes the unembedding the memory hot-spot: logits are
sequence-chunked (cfg.logits_chunk).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab=256000,
    act="geglu",
    rope_theta=10000.0,
    tie_embeddings=True,  # gemma ties embeddings
    logits_chunk=512,
).validate()

SMOKE = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=32,
             d_ff=128, vocab=256, logits_chunk=0)
