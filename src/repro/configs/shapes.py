"""Assigned input-shape set for the LM-family architectures.

Every architecture pairs with these four shapes (assignment):

  train_4k     seq_len=4096    global_batch=256   -> train_step
  prefill_32k  seq_len=32768   global_batch=32    -> serve prefill
  decode_32k   seq_len=32768   global_batch=128   -> serve_step (1 new token,
                                                     KV cache of seq_len)
  long_500k    seq_len=524288  global_batch=1     -> serve_step; needs
                                                     sub-quadratic attention,
                                                     run for SSM/hybrid only
"""

from __future__ import annotations

import dataclasses
from typing import Literal

Kind = Literal["train", "prefill", "decode"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: Kind
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}

# long_500k is skipped for pure full-attention archs (DESIGN.md §4): a dense
# 512k-token KV attention is the quadratic-cost case the assignment says to
# skip; SSM/hybrid archs run it with O(1) state.
SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")


def shapes_for_family(family: str) -> list[str]:
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if family in SUBQUADRATIC_FAMILIES:
        names.append("long_500k")
    return names
