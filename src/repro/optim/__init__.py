from .adamw import AdamWConfig, OptState, global_norm, init, lr_schedule, update
from .compress import CompressionConfig, compressed_psum, init_error_buffer

__all__ = [
    "AdamWConfig",
    "OptState",
    "init",
    "update",
    "lr_schedule",
    "global_norm",
    "CompressionConfig",
    "compressed_psum",
    "init_error_buffer",
]
