"""AdamW with global-norm clipping and warmup-cosine schedule (pure JAX).

Optimizer state is a pytree mirroring the params (m, v) plus a step counter;
it shards exactly like the parameters under pjit (the sharding rules map
leaves positionally).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

Params = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class OptState(NamedTuple):
    step: jax.Array
    m: Params
    v: Params


def lr_schedule(cfg: AdamWConfig) -> Callable[[jax.Array], jax.Array]:
    def lr(step: jax.Array) -> jax.Array:
        step = step.astype(jnp.float32)
        warm = step / jnp.maximum(cfg.warmup_steps, 1)
        decay_steps = jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1)
        frac = jnp.clip((step - cfg.warmup_steps) / decay_steps, 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
        mult = jnp.where(step < cfg.warmup_steps, warm, cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)
        return cfg.peak_lr * mult

    return lr


def init(params: Params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(step=jnp.zeros((), jnp.int32), m=zeros, v=jax.tree.map(jnp.copy, zeros))


def global_norm(tree: Params) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def update(
    cfg: AdamWConfig, grads: Params, state: OptState, params: Params
) -> tuple[Params, OptState, dict[str, jax.Array]]:
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = lr_schedule(cfg)(step)
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, OptState(step=step, m=new_m, v=new_v), metrics
