"""Compressed + popcount-ordered gradient all-reduce (explicit-DP path).

Distributed-optimization tricks for the ICI collective term (DESIGN.md §5):

  * **bf16 wire**: grads cross ICI as bfloat16 (2x fewer bytes than fp32).
  * **int8 + error feedback**: blockwise symmetric int8 with *shared* scales
    (one cheap fp32 max-reduce per block), int16 wire accumulation (exact for
    DP degree <= 258), and an error-feedback buffer carrying quantization
    residue to the next step (EF-SGD semantics).
  * **popcount-ordered egress** (the paper's technique on ICI): a *static*
    permutation — derived from the corresponding weight bytes via
    ``repro.traffic.egress_permutation``, identical on all replicas, so the
    reduction stays aligned — reorders the int8 wire image so flits with
    similar Hamming weight are adjacent.  BT reduction is measured by the
    ``repro.link`` TX pipeline (DESIGN.md §8).

These run inside ``shard_map`` over the data axes, where the wire format is
explicit; the GSPMD path (default dry-run) keeps implicit fp32 all-reduce.
"""

from __future__ import annotations

import dataclasses
from typing import Literal, Optional

import jax
import jax.numpy as jnp
from jax import lax

Mode = Literal["none", "bf16", "int8_ef"]


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    mode: Mode = "none"
    block: int = 256
    # static egress permutation (see repro.traffic); applied to the int8
    # wire image before the collective and inverted after.
    use_egress_ordering: bool = False


def _blockify(x: jax.Array, block: int) -> tuple[jax.Array, int]:
    m = x.shape[0]
    pad = (-m) % block
    return jnp.pad(x, (0, pad)), m


def compressed_psum(
    g: jax.Array,
    error: jax.Array,
    cfg: CompressionConfig,
    axis_names: tuple[str, ...],
    perm: Optional[jax.Array] = None,
    inv_perm: Optional[jax.Array] = None,
) -> tuple[jax.Array, jax.Array]:
    """All-reduce a flat fp32 gradient vector with compression + EF.

    Must be called inside ``shard_map`` with ``axis_names`` bound.  Returns
    (summed gradient fp32 (same shape as g), new error buffer).
    """
    if cfg.mode == "none":
        return lax.psum(g, axis_names), error

    if cfg.mode == "bf16":
        wire = g.astype(jnp.bfloat16)
        out = lax.psum(wire, axis_names).astype(jnp.float32)
        return out, error  # rounding error is not fed back in bf16 mode

    # --- int8_ef ---
    x = g + error
    xb, m = _blockify(x, cfg.block)
    rows = xb.shape[0] // cfg.block
    xr = xb.reshape(rows, cfg.block)
    local_amax = jnp.max(jnp.abs(xr), axis=1)
    # shared scales: one fp32 max-reduce per block keeps dequantization exact
    amax = lax.pmax(local_amax, axis_names)
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(xr / scale[:, None]), -127, 127).astype(jnp.int8)
    dq_local = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)[:m]
    new_error = x - dq_local

    wire = q.reshape(-1)
    if cfg.use_egress_ordering and perm is not None:
        wire = wire[perm]  # static, replica-identical: reduction stays aligned
    acc = lax.psum(wire.astype(jnp.int16), axis_names)  # 2-byte wire accum
    if cfg.use_egress_ordering and inv_perm is not None:
        acc = acc[inv_perm]
    out = (acc.astype(jnp.float32).reshape(rows, cfg.block) * scale[:, None]).reshape(-1)
    return out[:m], new_error


def init_error_buffer(params_flat_size: int) -> jax.Array:
    return jnp.zeros((params_flat_size,), jnp.float32)
