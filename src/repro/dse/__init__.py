# The design-space exploration subsystem (DESIGN.md §10): the paper
# evaluates two points (ACC, APP k=4); this layer maps the whole space.
#   space.py    - DesignPoint (family/N/W/k/ordering/topology) + grids
#   evaluate.py - grid x workload -> joined BT/area/timing/power records;
#                 every stream, NoC route link and (ordering, codec) config
#                 rides ONE multi-axis Pallas launch per key width
#                 (repro.kernels.bt_count_axes, DESIGN.md §12);
#                 grid_launch_count reads the collapse from the traced jaxpr
#   pareto.py   - dominance filtering + knee selection over
#                 area x BT-reduction x latency
#   report.py   - JSON / CSV artifacts for the bench trajectory
from .evaluate import Evaluation, Workload, evaluate_grid, grid_launch_count
from .pareto import (
    AREA_BT_LATENCY_OBJECTIVES,
    AREA_BT_OBJECTIVES,
    DEFAULT_OBJECTIVES,
    Objective,
    dominates,
    knee_point,
    pareto_front,
)
from .report import point_record, to_records, write_csv, write_json
from .space import (
    FAMILIES,
    ORDERINGS,
    DesignPoint,
    area_reduction,
    expand_grid,
    k_sweep,
    parse_topology,
    topology_route_hops,
)

__all__ = [
    "DesignPoint",
    "FAMILIES",
    "ORDERINGS",
    "expand_grid",
    "k_sweep",
    "area_reduction",
    "Workload",
    "Evaluation",
    "evaluate_grid",
    "grid_launch_count",
    "parse_topology",
    "topology_route_hops",
    "Objective",
    "DEFAULT_OBJECTIVES",
    "AREA_BT_OBJECTIVES",
    "AREA_BT_LATENCY_OBJECTIVES",
    "dominates",
    "pareto_front",
    "knee_point",
    "point_record",
    "to_records",
    "write_json",
    "write_csv",
]
