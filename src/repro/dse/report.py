"""Machine-readable DSE artifacts — JSON / CSV export of evaluated grids.

The bench trajectory (CI's bench-smoke artifact) and downstream tooling
consume these; every record is flat scalars so the artifact diffs cleanly
run to run.  ``write_json`` emits the full grid plus the front/knee labels;
``write_csv`` emits one row per point with the same fields.
"""

from __future__ import annotations

import csv
import json
import os
from typing import Mapping, Sequence

from .evaluate import Evaluation
from .pareto import DEFAULT_OBJECTIVES, Objective

__all__ = ["point_record", "to_records", "write_json", "write_csv"]

_FIELDS = (
    "label",
    "family",
    "n",
    "width",
    "k",
    "ordering",
    "descending",
    "codec",
    "topology",
    "area_um2",
    "area_popcount_um2",
    "area_sort_um2",
    "area_codec_um2",
    "area_reduction",
    "total_bt",
    "aux_bt",
    "extra_wires",
    "num_flits",
    "bt_per_flit",
    "bt_reduction",
    "link_power_reduction",
    "energy_pj",
    "latency_ns",
    "latency_cycles",
    "noc_latency_ns",
    "total_latency_ns",
    "noc_bt_reduction",
    "noc_active_links",
    "hot_wire",
    "hot_wire_bt",
    "hot_wire_ratio",
    "on_front",
)


def point_record(e: Evaluation, *, on_front: bool = False) -> dict:
    """One evaluation as a flat dict of JSON-safe scalars."""
    pt = e.point
    return {
        "label": e.label,
        "family": pt.family,
        "n": pt.n,
        "width": pt.width,
        "k": pt.k,
        "ordering": pt.ordering,
        "descending": pt.descending,
        "codec": pt.codec,
        "topology": pt.topology,
        "area_um2": round(e.area_um2, 3),
        "area_popcount_um2": round(e.area.popcount, 3),
        "area_sort_um2": round(e.area.sort, 3),
        "area_codec_um2": round(e.area.codec, 3),
        "area_reduction": round(e.area_reduction, 6),
        "total_bt": e.total_bt,
        "aux_bt": e.aux_bt,
        "extra_wires": e.extra_wires,
        "num_flits": e.num_flits,
        "bt_per_flit": round(e.bt_per_flit, 6),
        "bt_reduction": round(e.bt_reduction, 6),
        "link_power_reduction": round(e.link_power_reduction, 6),
        "energy_pj": round(e.energy_pj, 3),
        "latency_ns": round(e.latency_ns, 3),
        "latency_cycles": e.timing.latency_cycles,
        "noc_latency_ns": (
            None if e.noc_latency_ns is None else round(e.noc_latency_ns, 3)
        ),
        "total_latency_ns": round(e.total_latency_ns, 3),
        "noc_bt_reduction": (
            None if e.noc_bt_reduction is None else round(e.noc_bt_reduction, 6)
        ),
        "noc_active_links": e.noc_active_links,
        "hot_wire": e.hot_wire,
        "hot_wire_bt": e.hot_wire_bt,
        "hot_wire_ratio": (
            None if e.hot_wire_ratio is None else round(e.hot_wire_ratio, 4)
        ),
        "on_front": on_front,
    }


def to_records(
    evals: Sequence[Evaluation], front: Sequence[Evaluation] = ()
) -> list[dict]:
    """Flat records for every evaluation, front membership marked."""
    front_ids = {id(e) for e in front}
    return [point_record(e, on_front=id(e) in front_ids) for e in evals]


def write_json(
    path: str,
    evals: Sequence[Evaluation],
    *,
    front: Sequence[Evaluation] = (),
    knee: Evaluation | None = None,
    workload: str = "",
    objectives: Sequence[Objective] = DEFAULT_OBJECTIVES,
    meta: Mapping[str, object] | None = None,
) -> dict:
    """Write (and return) the full grid artifact as one JSON document."""
    doc = {
        "workload": workload,
        "objectives": [obj.name for obj in objectives],
        "points": to_records(evals, front),
        "front": [e.label for e in front],
        "knee": None if knee is None else knee.label,
    }
    if meta:
        doc["meta"] = dict(meta)
    parent = os.path.dirname(path)
    if parent:
        os.makedirs(parent, exist_ok=True)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=False)
        f.write("\n")
    return doc


def write_csv(
    path: str,
    evals: Sequence[Evaluation],
    *,
    front: Sequence[Evaluation] = (),
) -> None:
    """Write one CSV row per evaluated point (bench-trajectory format)."""
    parent = os.path.dirname(path)
    if parent:
        os.makedirs(parent, exist_ok=True)
    with open(path, "w", newline="") as f:
        writer = csv.DictWriter(f, fieldnames=_FIELDS)
        writer.writeheader()
        writer.writerows(to_records(evals, front))
