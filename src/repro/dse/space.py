"""`DesignPoint` — one sorting-unit configuration, and grids of them.

The paper evaluates exactly two points of a large design space: the precise
ACC-PSU and the k=4 APP-PSU, at sort widths 25 and 49 (Fig. 5, Table I).
A :class:`DesignPoint` names any point of that space —

  * ``family``    — the sorting-hardware family: the paper's comparison-free
    PSU, or the Fig. 5 comparator baselines (Batcher bitonic, CSN);
  * ``n``         — hardware sort-window size N (area/timing scale with it);
  * ``width``     — element bit width W of the sort keys;
  * ``k``         — APP bucket count, or ``None`` for precise;
  * ``ordering``  — what the transmitted stream actually does: 'acc', 'app',
    or the data-independent baselines 'none' / 'column_major' (which have
    NO sorting hardware: zero area, zero sort latency);
  * ``descending``— sort direction of the transmit order;
  * ``codec``     — optional ``repro.codec`` wire codec at the link
    egress ('bus_invert', 'gray', ...; None = uncoded) — the
    coding-vs-ordering axis, measured net of invert-line overhead and
    encoder area (DESIGN.md §11);
  * ``topology``  — optional NoC fabric ('mesh4x4', 'torus4x4', 'ring8',
    ...) on which the point is additionally evaluated per link.

— and `expand_grid` / `k_sweep` enumerate deterministic grids of valid
points for `repro.dse.evaluate.evaluate_grid`.
"""

from __future__ import annotations

import dataclasses
import functools
import re

from repro.core.area import (
    PSUArea,
    PSUTiming,
    bitonic_area,
    bitonic_timing,
    csn_area,
    psu_area,
    psu_timing,
)
from repro.kernels import CodecVariant, Variant

__all__ = [
    "DesignPoint",
    "FAMILIES",
    "ORDERINGS",
    "expand_grid",
    "k_sweep",
    "area_reduction",
    "parse_topology",
    "topology_route_hops",
]

FAMILIES = ("psu", "bitonic", "csn")
ORDERINGS = ("none", "column_major", "acc", "app")

# the one home of the topology-name grammar: DesignPoint validation and
# parse_topology both use it, so they cannot drift
_TOPOLOGY_RE = re.compile(r"^(mesh|torus)(\d+)x(\d+)$|^ring(\d+)$")


def parse_topology(name: str):
    """'mesh4x4' | 'torus2x3' | 'ring8' -> a ``repro.noc`` Topology."""
    m = _TOPOLOGY_RE.match(name)
    if m is None:
        raise ValueError(
            f"topology {name!r} does not match "
            "'mesh<R>x<C>' | 'torus<R>x<C>' | 'ring<N>'"
        )
    from repro.noc import mesh, ring, torus  # deferred: keep space.py light

    if m.group(4) is not None:
        return ring(int(m.group(4)))
    builder = mesh if m.group(1) == "mesh" else torus
    return builder(int(m.group(2)), int(m.group(3)))


@functools.lru_cache(maxsize=None)
def topology_route_hops(name: str) -> int:
    """Hop count of a topology's DSE evaluation route: router 0 to the
    farthest router under XY routing — the one home of the 'how long is
    the fabric' question (``dse.evaluate``'s measurement row scaling AND
    the wormhole latency objective both read it)."""
    from repro.noc import hop_count  # deferred: keep space.py light

    topo = parse_topology(name)
    return max(hop_count(topo, 0, r) for r in range(topo.num_routers))


@dataclasses.dataclass(frozen=True)
class DesignPoint:
    """One sorting-unit configuration in the explored design space."""

    family: str = "psu"
    n: int = 25
    width: int = 8
    k: int | None = 4
    ordering: str = "app"
    descending: bool = False
    codec: str | None = None
    topology: str | None = None

    def __post_init__(self) -> None:
        if self.family not in FAMILIES:
            raise ValueError(
                f"unknown family {self.family!r}; choose from {FAMILIES}"
            )
        if self.ordering not in ORDERINGS:
            raise ValueError(
                f"unknown ordering {self.ordering!r}; choose from {ORDERINGS}"
            )
        if self.n < 1 or self.width < 1:
            raise ValueError(f"need n >= 1 and width >= 1, got {self}")
        if self.ordering == "app":
            if self.k is None or not 1 <= self.k <= self.width + 1:
                raise ValueError(
                    f"'app' needs k in [1, {self.width + 1}], got k={self.k}"
                )
            if self.family != "psu":
                raise ValueError(
                    "coarse buckets are the PSU's trick: 'app' ordering "
                    f"requires family 'psu', got {self.family!r}"
                )
        elif self.k is not None:
            raise ValueError(
                f"k is only meaningful for 'app' ordering, got {self}"
            )
        if self.ordering in ("none", "column_major"):
            if self.family != "psu":
                raise ValueError(
                    f"{self.ordering!r} has no sorting hardware; use the "
                    "default family 'psu'"
                )
            if self.descending:
                raise ValueError(
                    f"descending is meaningless for {self.ordering!r}"
                )
        if self.codec is not None:
            from repro.codec.schemes import CODECS  # deferred: keep space light

            if self.codec not in CODECS:
                raise ValueError(
                    f"unknown codec {self.codec!r}; registered codecs: "
                    f"{', '.join(sorted(CODECS))}"
                )
        if self.topology is not None and not _TOPOLOGY_RE.match(self.topology):
            raise ValueError(
                f"topology {self.topology!r} does not match "
                "'mesh<R>x<C>' | 'torus<R>x<C>' | 'ring<N>'"
            )

    # ------------------------------------------------------------ derived
    @property
    def label(self) -> str:
        """Compact report name, e.g. ``app-k4@N25`` or
        ``acc+bus_invert@N25``."""
        if self.ordering == "app":
            head = f"app-k{self.k}"
        elif self.family != "psu":
            head = self.family
        else:
            head = self.ordering
        tail = "-desc" if self.descending else ""
        coded = f"+{self.codec}" if self.codec else ""
        noc = f"/{self.topology}" if self.topology else ""
        return f"{head}{tail}{coded}@N{self.n}{noc}"

    @property
    def variant(self) -> Variant:
        """The stream-measurement variant for the batched BT kernel."""
        return Variant(self.ordering, self.k, self.descending)

    @property
    def codec_variant(self) -> CodecVariant:
        """The (ordering, codec) config for the single-launch codec-BT
        kernel (``repro.kernels.bt_count_codecs``)."""
        if self.codec is None:
            scheme, partition = "none", None
        else:
            from repro.codec.schemes import codec_by_name  # deferred

            c = codec_by_name(self.codec)
            scheme, partition = c.scheme, c.partition
        return CodecVariant(
            self.ordering, self.k, self.descending, scheme, partition
        )

    def area(self) -> PSUArea:
        """Modeled area of this point's sorting unit (um^2, DESIGN.md §6)."""
        if self.ordering in ("none", "column_major"):
            return PSUArea(popcount=0.0, sort=0.0)  # no sorting hardware
        if self.family == "bitonic":
            return bitonic_area(self.n, self.width)
        if self.family == "csn":
            return csn_area(self.n, self.width)
        return psu_area(self.n, self.width, self.k)

    def noc_hops(self) -> int | None:
        """Hops of this point's NoC evaluation route (None off-fabric)."""
        if self.topology is None:
            return None
        return topology_route_hops(self.topology)

    def timing(self) -> PSUTiming:
        """Pipelined sort timing at the paper's 500 MHz clock."""
        if self.ordering in ("none", "column_major"):
            # pass-through: no sort stage in the transmit path
            return PSUTiming(
                latency_cycles=0, throughput_elems_per_cycle=float("inf")
            )
        if self.family in ("bitonic", "csn"):
            return bitonic_timing(self.n)
        return psu_timing(self.n, self.width, self.k)


def area_reduction(point: DesignPoint) -> float:
    """Fractional area reduction vs the precise ACC-PSU at the same (N, W).

    The paper's headline comparison (APP k=4 @ N=25: 35.4 %), generalized to
    any point; negative for designs larger than the ACC-PSU (bitonic, CSN).
    """
    base = psu_area(point.n, point.width).total
    return 1.0 - point.area().total / base


def expand_grid(
    *,
    families: tuple[str, ...] = ("psu",),
    ns: tuple[int, ...] = (25,),
    widths: tuple[int, ...] = (8,),
    ks: tuple[int, ...] = (2, 4, 8),
    orderings: tuple[str, ...] = ("none", "acc", "app"),
    descendings: tuple[bool, ...] = (False,),
    codecs: tuple[str | None, ...] = (None,),
    topologies: tuple[str | None, ...] = (None,),
) -> tuple[DesignPoint, ...]:
    """Deterministic expansion of a design grid into valid points.

    Invalid combinations are skipped rather than raised (an 'app' ordering
    expands once per bucket count in ``ks``; every other ordering ignores
    ``ks``; comparator families pair only with 'acc'; the data-independent
    orderings carry no hardware so only family 'psu' and ascending order).
    Every point additionally expands over ``codecs`` (None = uncoded wire,
    or registered ``repro.codec`` names — the coding-vs-ordering axis).
    Duplicates are dropped, first occurrence wins — the output order is a
    pure function of the argument order.
    """
    points: list[DesignPoint] = []
    seen: set[DesignPoint] = set()
    for topo in topologies:
        for family in families:
            for n in ns:
                for width in widths:
                    for ordering in orderings:
                        if family != "psu" and ordering != "acc":
                            continue
                        k_axis: tuple[int | None, ...]
                        if ordering == "app":
                            k_axis = tuple(k for k in ks if 1 <= k <= width + 1)
                        else:
                            k_axis = (None,)
                        for k in k_axis:
                            for desc in descendings:
                                if desc and ordering in ("none", "column_major"):
                                    continue
                                for codec in codecs:
                                    pt = DesignPoint(
                                        family=family,
                                        n=n,
                                        width=width,
                                        k=k,
                                        ordering=ordering,
                                        descending=desc,
                                        codec=codec,
                                        topology=topo,
                                    )
                                    if pt not in seen:
                                        seen.add(pt)
                                        points.append(pt)
    return tuple(points)


def k_sweep(
    n: int = 25,
    width: int = 8,
    ks: tuple[int, ...] = (2, 4, 8),
    *,
    include_baseline: bool = True,
    include_precise: bool = True,
    topology: str | None = None,
) -> tuple[DesignPoint, ...]:
    """The paper's k axis: unsorted baseline, precise ACC, and APP per k.

    This is the sweep `benchmarks/fig5_area.py` (area side) and
    `benchmarks/table1_bt.py` (BT side) ran ad hoc; `repro.dse` is its one
    home now.
    """
    points: list[DesignPoint] = []
    if include_baseline:
        points.append(
            DesignPoint(n=n, width=width, k=None, ordering="none", topology=topology)
        )
    if include_precise:
        points.append(
            DesignPoint(n=n, width=width, k=None, ordering="acc", topology=topology)
        )
    points.extend(
        DesignPoint(n=n, width=width, k=k, ordering="app", topology=topology)
        for k in ks
    )
    return tuple(points)
