"""Evaluate a design grid against a workload — the DSE measurement core.

For every :class:`~repro.dse.space.DesignPoint` of a grid, one
:class:`Evaluation` joins the repo's models end to end:

  * **BT** — measured on the workload's actual flit streams by ONE
    multi-axis Pallas launch per key width (``repro.kernels.bt_count_axes``,
    DESIGN.md §12): every workload stream AND every distinct NoC fabric
    queue rides the launch's link axis (jagged links masked in-kernel),
    every (ordering, codec) config its static variant x codec axes.  A
    grid of G configurations over S streams plus an R-link fabric costs
    ONE launch where the per-point path costs G x (S + R)
    (:func:`grid_launch_count` reads the collapse from the traced jaxpr;
    ``benchmarks/dse_sweep.py`` reports it).  Coded points' invert-line
    transitions count against them, so their BT reductions are net of
    wire overhead (DESIGN.md §11).
  * **Area / timing** — the calibrated closed-form models of
    ``repro.core.area`` (DESIGN.md §6), per family/N/W/k, plus the codec
    encoder area folded into ``PSUArea.codec`` for coded points.
  * **Link power / energy** — ``repro.link.LinkPowerModel`` maps the BT
    reduction to link-related power reduction and absolute energy
    (``coded_link_energy_pj`` charges invert lines and the widened static
    floor).
  * **NoC (optional)** — points with a ``topology`` are additionally
    scored per link on a source-sorted fabric carrying the workload from
    router 0 to the farthest router: the fabric's link queue is one more
    row of the SAME multi-axis launch, scaled by the route length (every
    route link retransmits the byte-identical queue — the same
    distinct-queue dedup ``noc.simulate`` applies; source sorting is a
    per-packet ordering, so the in-kernel reorder reproduces
    ``repro.noc.simulate_noc``'s wire images bit-for-bit, asserted in
    ``tests/test_axes.py``), reported as fabric-level BT reduction vs the
    unsorted fabric.

The unsorted 'none' variant is always measured as the reduction baseline;
area reductions are vs the precise ACC-PSU at the same (N, W), matching the
paper's Fig. 5 comparison.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import _obs_hooks as _obs
from repro.core.area import PSUArea, PSUTiming, codec_area, psu_area
from repro.kernels import (
    CodecVariant,
    bt_count_axes,
    default_interpret,
    pallas_launch_count,
)
from repro.link import LinkPowerModel

from .space import DesignPoint, topology_route_hops

__all__ = ["Workload", "Evaluation", "evaluate_grid", "grid_launch_count"]

_BASELINE = CodecVariant("none", None, False, "none", None)


class Workload(NamedTuple):
    """The traffic a design grid is evaluated on.

    ``streams`` are (P, elems) byte-packet arrays measured independently
    (the Table-I conv setup streams inputs and weights on separate links);
    ``lanes`` is the byte width of each measured flit.
    """

    name: str
    streams: tuple[jax.Array, ...]
    lanes: int = 16

    @property
    def elems_per_packet(self) -> int:
        return int(self.streams[0].shape[-1])

    @property
    def num_flits(self) -> int:
        return sum(
            int(s.shape[0]) * (int(s.shape[-1]) // self.lanes)
            for s in self.streams
        )


def _validate_workload(workload: Workload) -> None:
    if not workload.streams:
        raise ValueError(f"workload {workload.name!r} has no streams")
    elems = None
    for s in workload.streams:
        if getattr(s, "ndim", None) != 2 or s.shape[0] == 0:
            raise ValueError(
                f"workload {workload.name!r}: streams must be non-empty "
                f"(P, elems) arrays, got {getattr(s, 'shape', None)}"
            )
        elems = s.shape[-1] if elems is None else elems
        if s.shape[-1] != elems:
            raise ValueError(
                f"workload {workload.name!r}: streams disagree on packet "
                f"size ({elems} vs {s.shape[-1]})"
            )
    if elems % workload.lanes != 0:
        raise ValueError(
            f"workload {workload.name!r}: packet size {elems} not divisible "
            f"by lanes={workload.lanes}"
        )


@dataclasses.dataclass(frozen=True)
class Evaluation:
    """One design point joined across the BT / area / timing / power models."""

    point: DesignPoint
    area: PSUArea
    timing: PSUTiming
    total_bt: int
    num_flits: int
    bt_reduction: float  # vs the unsorted uncoded stream, net of overhead
    area_reduction: float  # vs the precise ACC-PSU at the same (N, W)
    link_power_reduction: float  # Fig. 6/7 model applied to bt_reduction
    energy_pj: float
    noc_bt_reduction: float | None = None  # fabric-level, when topology set
    noc_active_links: int | None = None
    aux_bt: int = 0  # invert-line transitions (wire-codec overhead)
    extra_wires: int = 0  # invert lines beside the data lanes
    # per-wire BT over the workload streams (data wires then invert lines,
    # DESIGN.md §15) — populated when evaluated with ``activity_windows=``
    per_wire_bt: tuple[int, ...] | None = None
    # wormhole traversal of the point's NoC route under the contention
    # model (``repro.noc.latency``, DESIGN.md §17) — set when topology is
    noc_latency_ns: float | None = None

    @property
    def label(self) -> str:
        return self.point.label

    @property
    def hot_wire(self) -> int | None:
        """Index of the busiest wire (first on ties), wire-resolved runs."""
        if not self.per_wire_bt:
            return None
        return int(np.argmax(self.per_wire_bt))

    @property
    def hot_wire_bt(self) -> int | None:
        return None if not self.per_wire_bt else int(max(self.per_wire_bt))

    @property
    def wire_bt_mean(self) -> float | None:
        if not self.per_wire_bt:
            return None
        return sum(self.per_wire_bt) / len(self.per_wire_bt)

    @property
    def hot_wire_ratio(self) -> float | None:
        """Hot-wire tail: busiest wire's BT over the mean (1.0 = perfectly
        flat) — the figure of merit for orderings that flatten the tail."""
        mean = self.wire_bt_mean
        if mean is None:
            return None
        return self.hot_wire_bt / max(mean, 1e-12)

    @property
    def area_um2(self) -> float:
        return self.area.total

    @property
    def gross_bt(self) -> int:
        """Data BT plus the codec's invert-line transitions."""
        return self.total_bt + self.aux_bt

    @property
    def bt_per_flit(self) -> float:
        return self.total_bt / max(self.num_flits, 1)

    @property
    def latency_ns(self) -> float:
        """Time to sort one N-element window at the paper's 500 MHz."""
        return self.timing.sort_time_ns(self.point.n)

    @property
    def total_latency_ns(self) -> float:
        """Sort latency plus the NoC traversal of the workload (when the
        point names a topology) — the latency axis of the
        AREA_BT_LATENCY Pareto plane.  Point-to-point designs pay the
        sorting unit only, fabric designs add the wormhole route."""
        return self.latency_ns + (self.noc_latency_ns or 0.0)


def _configs_by_width(
    points: tuple[DesignPoint, ...],
) -> dict[int, tuple[CodecVariant, ...]]:
    """Unique (ordering, codec) configs per key width, baseline first."""
    by_width: dict[int, list[CodecVariant]] = {}
    for pt in points:
        vs = by_width.setdefault(pt.width, [_BASELINE])
        if pt.codec_variant not in vs:
            vs.append(pt.codec_variant)
    return {w: tuple(vs) for w, vs in by_width.items()}


def _grid_links(
    points: tuple[DesignPoint, ...], workload: Workload
) -> tuple[list[jax.Array], dict[str, tuple[int, int]]]:
    """The measurement links of one grid launch.

    The first ``len(workload.streams)`` rows are the point-to-point
    streams (measured independently, the Table-I setup).  Then, per
    distinct topology named by any point, ONE row carrying the
    source-sorted fabric's link queue (all the workload's packets, router
    0 toward the farthest router): every link of the unicast route
    retransmits the byte-identical queue, so — exactly like
    ``noc.simulate``'s distinct-queue dedup — the queue is measured once
    and the fold scales it by the route length.  Returns
    (payloads, {topology: (row index, link count)}).
    """
    streams = [jnp.asarray(s) for s in workload.streams]
    payloads = list(streams)
    topo_rows: dict[str, tuple[int, int]] = {}
    names = dict.fromkeys(
        pt.topology for pt in points if pt.topology is not None
    )
    for name in names:
        nlinks = topology_route_hops(name)
        q = streams[0] if len(streams) == 1 else jnp.concatenate(streams, axis=0)
        topo_rows[name] = (len(payloads), nlinks)
        payloads.append(q)
    return payloads, topo_rows


def _stack_links(
    payloads: Sequence[jax.Array],
) -> tuple[jax.Array, tuple[int, ...]]:
    """Stack jagged (P_l, N) packet queues to (L, P_max, N) + valid counts
    (zero-padded; the kernel masks past each link's valid count)."""
    valid = tuple(int(s.shape[0]) for s in payloads)
    pmax = max(valid)
    stacked = jnp.stack(
        [
            s if s.shape[0] == pmax
            else jnp.pad(s, ((0, pmax - s.shape[0]), (0, 0)))
            for s in payloads
        ]
    )
    return stacked, valid


def _measure_grid(
    points: tuple[DesignPoint, ...],
    workload: Workload,
    *,
    interpret: bool | None,
    block_packets: int,
    backend: str | None = None,
    chunk_packets: int | None = None,
    activity_windows: int | None = None,
) -> tuple[
    dict[tuple[int, CodecVariant], tuple[int, int]],
    dict[tuple[int, str, CodecVariant], int],
    dict[str, int],
    dict[tuple[int, CodecVariant], np.ndarray],
]:
    """Run the grid's single-launch-per-width measurement.

    Returns (bt_tab, noc_tab, topo_links, wire_tab): point-to-point (data
    BT, aux BT) per (width, config), fabric gross BT per (width,
    topology, config), active link counts per topology, and — when
    ``activity_windows`` is set — the per-wire BT vector of the workload
    streams per (width, config) (empty dict otherwise).
    """
    configs_by_width = _configs_by_width(points)
    payloads, topo_rows = _grid_links(points, workload)
    stacked, valid = _stack_links(payloads)
    n_p2p = len(workload.streams)
    bt_tab: dict[tuple[int, CodecVariant], tuple[int, int]] = {}
    noc_tab: dict[tuple[int, str, CodecVariant], int] = {}
    wire_tab: dict[tuple[int, CodecVariant], np.ndarray] = {}
    link_names = [
        f"{workload.name}[{i}]" for i in range(n_p2p)
    ] + [name for name in topo_rows]
    for width in sorted(configs_by_width):
        vs = configs_by_width[width]
        with _obs.span(
            "dse.measure", width=width, links=len(payloads),
            configs=len(vs), workload=workload.name,
        ):
            raw = bt_count_axes(
                stacked,
                None,
                valid=valid,
                configs=vs,
                width=width,
                input_lanes=workload.lanes,
                block_packets=block_packets,
                interpret=interpret,
                backend=backend,
                chunk_packets=chunk_packets,
                activity_windows=activity_windows,
            )
        toggles = None
        if activity_windows is not None:
            toggles = np.asarray(raw.toggles, dtype=np.int64)
            raw = raw.bt
        out = np.asarray(raw, dtype=np.int64)  # (L, C, 3)
        if _obs.active():
            # per-link baseline BT of this width's launch (config 0 is
            # always the unsorted/uncoded baseline)
            for li, lname in enumerate(link_names):
                _obs.event(
                    "dse.link", link=lname, width=width,
                    bt=int(out[li, 0, :2].sum()), packets=int(valid[li]),
                )
        for ci, v in enumerate(vs):
            p2p = out[:n_p2p, ci]
            bt_tab[(width, v)] = (
                int(p2p[:, :2].sum()),
                int(p2p[:, 2].sum()),
            )
            if toggles is not None:
                # workload streams share one link in the energy roll-up,
                # so their per-wire vectors sum (windows collapse too —
                # the DSE scores totals, the time view stays in obs)
                wire_tab[(width, v)] = toggles[:n_p2p, ci].sum(axis=(0, 1))
            for name, (row, nlinks) in topo_rows.items():
                # every route link retransmits the identical queue
                noc_tab[(width, name, v)] = nlinks * int(out[row, ci].sum())
    return bt_tab, noc_tab, {n: r[1] for n, r in topo_rows.items()}, wire_tab


def grid_launch_count(
    points: Sequence[DesignPoint],
    workload: Workload,
    *,
    interpret: bool | None = None,
    block_packets: int = 64,
) -> int:
    """``pallas_call`` equations in the traced jaxpr of the WHOLE grid
    measurement — every stream, every NoC route link, every (ordering,
    codec) config.  One key width traces to exactly 1 (the DESIGN.md §12
    claim, asserted in ``tests/test_axes.py`` and reported by
    ``benchmarks/dse_sweep.py``); mixed widths add one launch per width
    (the popcount mask is per width).
    """
    points = tuple(points)
    if not points:
        return 0
    _validate_workload(workload)
    if interpret is None:
        interpret = default_interpret()
    configs_by_width = _configs_by_width(points)
    payloads, _ = _grid_links(points, workload)
    stacked, valid = _stack_links(payloads)

    def measure(arr):
        return tuple(
            bt_count_axes(
                arr,
                None,
                valid=valid,
                configs=configs_by_width[w],
                width=w,
                input_lanes=workload.lanes,
                block_packets=block_packets,
                interpret=interpret,
            )
            for w in sorted(configs_by_width)
        )

    return pallas_launch_count(measure, stacked)


def evaluate_grid(
    points: Sequence[DesignPoint],
    workload: Workload,
    *,
    power: LinkPowerModel | None = None,
    interpret: bool | None = None,
    block_packets: int = 64,
    backend: str | None = None,
    chunk_packets: int | None = None,
    activity_windows: int | None = None,
    latency=None,
) -> tuple[Evaluation, ...]:
    """Evaluate every design point of a grid against one workload.

    Points sharing a stream variant (e.g. the comparator families, which
    sort exactly like ACC) share one measurement; all streams, NoC route
    links and (ordering, codec) configs ride ONE multi-axis launch, with
    distinct key widths split into one launch per width (the popcount
    mask is per width).

    ``backend`` selects the kernel execution path (pallas | compiled |
    interpret, DESIGN.md §13) and ``chunk_packets`` streams the packet
    axis in fixed-size chunks (``repro.kernels.bt_count_axes``) — both
    default to the session/platform resolution.  ``activity_windows``
    rides the same launch and resolves each point's BT per wire
    (``Evaluation.per_wire_bt`` and the hot-wire properties, DESIGN.md
    §15) — the view that shows which orderings flatten the hot-wire
    tail rather than just lowering the mean.  ``latency`` (a
    ``repro.noc.NocLatencyModel``; pass nothing for the default timing
    constants) prices each topology point's NoC traversal — the whole
    workload crossing the evaluation route under the wormhole model
    (DESIGN.md §17) — into ``Evaluation.noc_latency_ns``.
    """
    points = tuple(points)
    if not points:
        return ()
    _validate_workload(workload)
    power = power if power is not None else LinkPowerModel()
    lanes = workload.lanes
    from repro.noc.latency import (  # deferred: keep dse importable alone
        NocLatencyModel,
        route_latency_ns,
    )

    latency = latency if latency is not None else NocLatencyModel()

    bt_tab, noc_tab, topo_links, wire_tab = _measure_grid(
        points,
        workload,
        interpret=interpret,
        block_packets=block_packets,
        backend=backend,
        chunk_packets=chunk_packets,
        activity_windows=activity_windows,
    )
    num_flits = workload.num_flits

    evals: list[Evaluation] = []
    for pt in points:
        total_bt, aux_bt = bt_tab[(pt.width, pt.codec_variant)]
        base_bt, _ = bt_tab[(pt.width, _BASELINE)]
        # coded points are scored net of their invert-line transitions
        bt_red = 1.0 - (total_bt + aux_bt) / max(base_bt, 1)
        area = pt.area()
        extra_wires = 0
        if pt.codec is not None:
            # fold the encoder hardware into the point's area breakdown
            cv = pt.codec_variant
            area = PSUArea(
                area.popcount,
                area.sort,
                codec=codec_area(cv.codec, lanes, cv.partition),
            )
            from repro.codec.schemes import codec_by_name  # deferred

            extra_wires = codec_by_name(pt.codec).extra_wires(lanes)
        acc_total = psu_area(pt.n, pt.width).total
        noc_red = noc_links = noc_lat = None
        if pt.topology is not None:
            gross = noc_tab[(pt.width, pt.topology, pt.codec_variant)]
            base = noc_tab[(pt.width, pt.topology, _BASELINE)]
            noc_red = 1.0 - gross / max(base, 1)
            noc_links = topo_links[pt.topology]
            # the whole workload crossing the evaluation route (router 0
            # to the farthest router) under the wormhole model
            noc_lat = route_latency_ns(noc_links, num_flits, latency)
        per_wire = None
        if activity_windows is not None:
            # trim the launch-wide aux columns to this point's own invert
            # lines so len(per_wire_bt) == data wires + extra_wires (the
            # contract wire_energy_pj checks); dropped columns are zero
            pw = wire_tab[(pt.width, pt.codec_variant)]
            per_wire = tuple(
                int(b) for b in pw[: 8 * lanes + extra_wires]
            )
        evals.append(
            Evaluation(
                point=pt,
                area=area,
                timing=pt.timing(),
                total_bt=total_bt,
                num_flits=num_flits,
                bt_reduction=bt_red,
                area_reduction=1.0 - area.total / acc_total,
                link_power_reduction=power.power_reduction(bt_red),
                energy_pj=power.coded_link_energy_pj(
                    total_bt, aux_bt, num_flits, 8 * lanes, extra_wires
                ),
                noc_bt_reduction=noc_red,
                noc_active_links=noc_links,
                aux_bt=aux_bt,
                extra_wires=extra_wires,
                per_wire_bt=per_wire,
                noc_latency_ns=noc_lat,
            )
        )
        _obs.event(
            "dse.point", label=pt.label, width=pt.width,
            bt_reduction=bt_red, area_um2=float(area.total),
        )
    return tuple(evals)
