"""Evaluate a design grid against a workload — the DSE measurement core.

For every :class:`~repro.dse.space.DesignPoint` of a grid, one
:class:`Evaluation` joins the repo's models end to end:

  * **BT** — measured on the workload's actual flit streams.  All points'
    (ordering, codec) configs are measured by ONE batched Pallas launch
    per (stream, key width) via ``repro.kernels.bt_count_codecs`` — the
    config axis lives inside the launch, so a grid of G configurations
    costs 1 launch where the per-config path costs G (the same claim
    structure as ``bt_count_links`` for the NoC; demonstrated from the
    traced jaxpr in ``benchmarks/dse_sweep.py`` / ``codec_bt.py``).
    Coded points' invert-line transitions count against them, so their BT
    reductions are net of wire overhead (DESIGN.md §11).
  * **Area / timing** — the calibrated closed-form models of
    ``repro.core.area`` (DESIGN.md §6), per family/N/W/k, plus the codec
    encoder area folded into ``PSUArea.codec`` for coded points.
  * **Link power / energy** — ``repro.link.LinkPowerModel`` maps the BT
    reduction to link-related power reduction and absolute energy
    (``coded_link_energy_pj`` charges invert lines and the widened static
    floor).
  * **NoC (optional)** — points with a ``topology`` are additionally run
    through ``repro.noc.simulate_noc`` (per-link batched BT kernel) as a
    source-sorted fabric carrying the workload across the topology
    diameter, reported as fabric-level BT reduction vs the unsorted fabric.

The unsorted 'none' variant is always measured as the reduction baseline;
area reductions are vs the precise ACC-PSU at the same (N, W), matching the
paper's Fig. 5 comparison.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.area import PSUArea, PSUTiming, codec_area, psu_area
from repro.kernels import CodecVariant, bt_count_codecs
from repro.link import LinkPowerModel, LinkSpec

from .space import DesignPoint, parse_topology

__all__ = ["Workload", "Evaluation", "evaluate_grid"]

_BASELINE = CodecVariant("none", None, False, "none", None)


class Workload(NamedTuple):
    """The traffic a design grid is evaluated on.

    ``streams`` are (P, elems) byte-packet arrays measured independently
    (the Table-I conv setup streams inputs and weights on separate links);
    ``lanes`` is the byte width of each measured flit.
    """

    name: str
    streams: tuple[jax.Array, ...]
    lanes: int = 16

    @property
    def elems_per_packet(self) -> int:
        return int(self.streams[0].shape[-1])

    @property
    def num_flits(self) -> int:
        return sum(
            int(s.shape[0]) * (int(s.shape[-1]) // self.lanes)
            for s in self.streams
        )


def _validate_workload(workload: Workload) -> None:
    if not workload.streams:
        raise ValueError(f"workload {workload.name!r} has no streams")
    elems = None
    for s in workload.streams:
        if getattr(s, "ndim", None) != 2 or s.shape[0] == 0:
            raise ValueError(
                f"workload {workload.name!r}: streams must be non-empty "
                f"(P, elems) arrays, got {getattr(s, 'shape', None)}"
            )
        elems = s.shape[-1] if elems is None else elems
        if s.shape[-1] != elems:
            raise ValueError(
                f"workload {workload.name!r}: streams disagree on packet "
                f"size ({elems} vs {s.shape[-1]})"
            )
    if elems % workload.lanes != 0:
        raise ValueError(
            f"workload {workload.name!r}: packet size {elems} not divisible "
            f"by lanes={workload.lanes}"
        )


@dataclasses.dataclass(frozen=True)
class Evaluation:
    """One design point joined across the BT / area / timing / power models."""

    point: DesignPoint
    area: PSUArea
    timing: PSUTiming
    total_bt: int
    num_flits: int
    bt_reduction: float  # vs the unsorted uncoded stream, net of overhead
    area_reduction: float  # vs the precise ACC-PSU at the same (N, W)
    link_power_reduction: float  # Fig. 6/7 model applied to bt_reduction
    energy_pj: float
    noc_bt_reduction: float | None = None  # fabric-level, when topology set
    noc_active_links: int | None = None
    aux_bt: int = 0  # invert-line transitions (wire-codec overhead)
    extra_wires: int = 0  # invert lines beside the data lanes

    @property
    def label(self) -> str:
        return self.point.label

    @property
    def area_um2(self) -> float:
        return self.area.total

    @property
    def gross_bt(self) -> int:
        """Data BT plus the codec's invert-line transitions."""
        return self.total_bt + self.aux_bt

    @property
    def bt_per_flit(self) -> float:
        return self.total_bt / max(self.num_flits, 1)

    @property
    def latency_ns(self) -> float:
        """Time to sort one N-element window at the paper's 500 MHz."""
        return self.timing.sort_time_ns(self.point.n)


def _noc_spec(point: DesignPoint, workload: Workload) -> LinkSpec:
    """Input-only LinkSpec carrying the workload packets under the point's
    ordering and codec (a LinkSpec means the same thing on a NoC link,
    DESIGN.md §9/§11)."""
    lanes = workload.lanes
    return LinkSpec(
        width_bits=8 * lanes,
        flits_per_packet=workload.elems_per_packet // lanes,
        input_lanes=lanes,
        weight_lanes=0,
        key=point.ordering,
        width=point.width,
        k=point.k if point.k is not None else 4,
        descending=point.descending,
        codec=point.codec if point.codec is not None else "none",
    )


def _noc_total_bt(
    point: DesignPoint, workload: Workload, interpret: bool | None
) -> tuple[int, int]:
    """(fabric total BT, active links) of the workload crossing the fabric
    from router 0 to the farthest router, sorted at the source."""
    from repro.noc import TrafficFlow, hop_count, simulate_noc

    topo = parse_topology(point.topology)
    far = max(
        range(topo.num_routers), key=lambda r: hop_count(topo, 0, r)
    )
    flows = [
        TrafficFlow(f"{workload.name}/{i}", 0, (far,), jnp.asarray(s))
        for i, s in enumerate(workload.streams)
    ]
    rep = simulate_noc(
        topo, flows, _noc_spec(point, workload), sort_at="source",
        interpret=interpret, name=point.label,
    )
    return rep.gross_bt, rep.active_links


def evaluate_grid(
    points: Sequence[DesignPoint],
    workload: Workload,
    *,
    power: LinkPowerModel | None = None,
    interpret: bool | None = None,
    block_packets: int = 64,
) -> tuple[Evaluation, ...]:
    """Evaluate every design point of a grid against one workload.

    Points sharing a stream variant (e.g. the comparator families, which
    sort exactly like ACC) share one measurement; distinct key widths get
    separate launches (the popcount mask is per width).
    """
    points = tuple(points)
    if not points:
        return ()
    _validate_workload(workload)
    power = power if power is not None else LinkPowerModel()
    lanes = workload.lanes

    # --- unique (ordering, codec) configs per key width (+ baseline) ---
    configs_by_width: dict[int, list[CodecVariant]] = {}
    for pt in points:
        vs = configs_by_width.setdefault(pt.width, [_BASELINE])
        if pt.codec_variant not in vs:
            vs.append(pt.codec_variant)

    # --- measure: ONE batched launch per (stream, width) ---
    bt_tab: dict[tuple[int, CodecVariant], tuple[int, int]] = {}
    for width in sorted(configs_by_width):
        vs = tuple(configs_by_width[width])
        totals = np.zeros((len(vs), 3), dtype=np.int64)
        for s in workload.streams:
            totals += np.asarray(
                bt_count_codecs(
                    jnp.asarray(s),
                    None,
                    configs=vs,
                    width=width,
                    input_lanes=lanes,
                    block_packets=block_packets,
                    interpret=interpret,
                ),
                dtype=np.int64,
            )
        for v, (bi, bw, aux) in zip(vs, totals.tolist()):
            bt_tab[(width, v)] = (int(bi) + int(bw), int(aux))

    # --- NoC runs (points with a topology), baseline cached per fabric ---
    noc_base: dict[tuple[str, int], int] = {}
    num_flits = workload.num_flits

    evals: list[Evaluation] = []
    for pt in points:
        total_bt, aux_bt = bt_tab[(pt.width, pt.codec_variant)]
        base_bt, _ = bt_tab[(pt.width, _BASELINE)]
        # coded points are scored net of their invert-line transitions
        bt_red = 1.0 - (total_bt + aux_bt) / max(base_bt, 1)
        area = pt.area()
        extra_wires = 0
        if pt.codec is not None:
            # fold the encoder hardware into the point's area breakdown
            cv = pt.codec_variant
            area = PSUArea(
                area.popcount,
                area.sort,
                codec=codec_area(cv.codec, lanes, cv.partition),
            )
            from repro.codec.schemes import codec_by_name  # deferred

            extra_wires = codec_by_name(pt.codec).extra_wires(lanes)
        acc_total = psu_area(pt.n, pt.width).total
        noc_red = noc_links = None
        if pt.topology is not None:
            key = (pt.topology, pt.width)
            if key not in noc_base:
                base_pt = dataclasses.replace(
                    pt, family="psu", ordering="none", k=None,
                    descending=False, codec=None,
                )
                noc_base[key], _ = _noc_total_bt(base_pt, workload, interpret)
            bt_fabric, noc_links = _noc_total_bt(pt, workload, interpret)
            noc_red = 1.0 - bt_fabric / max(noc_base[key], 1)
        evals.append(
            Evaluation(
                point=pt,
                area=area,
                timing=pt.timing(),
                total_bt=total_bt,
                num_flits=num_flits,
                bt_reduction=bt_red,
                area_reduction=1.0 - area.total / acc_total,
                link_power_reduction=power.power_reduction(bt_red),
                energy_pj=power.coded_link_energy_pj(
                    total_bt, aux_bt, num_flits, 8 * lanes, extra_wires
                ),
                noc_bt_reduction=noc_red,
                noc_active_links=noc_links,
                aux_bt=aux_bt,
                extra_wires=extra_wires,
            )
        )
    return tuple(evals)
