"""Pareto analysis over area × BT-reduction × latency.

The paper's central result is a trade: the APP-PSU gives up 0.92 pp of BT
reduction (19.50 % vs 20.42 %) to buy a 35.4 % area reduction.  This module
generalizes that two-point comparison into proper dominance analysis:

  * an :class:`Objective` is a named value-to-MINIMIZE extracted from an
    :class:`~repro.dse.evaluate.Evaluation` (maximized metrics are negated,
    as `bt_reduction` is in the defaults);
  * ``pareto_front`` keeps the non-dominated points — a point is dominated
    when some other point is no worse on every objective and strictly
    better on at least one;
  * ``knee_point`` picks the front's best-balanced point: objectives are
    normalized to [0, 1] over the front and the point closest (Euclidean)
    to the per-objective ideal wins.

Default objectives: sorting-unit area (um^2, down), BT reduction (up),
sort latency per window (ns, down).
"""

from __future__ import annotations

from typing import Callable, NamedTuple, Sequence

from .evaluate import Evaluation

__all__ = [
    "Objective",
    "DEFAULT_OBJECTIVES",
    "AREA_BT_OBJECTIVES",
    "AREA_BT_LATENCY_OBJECTIVES",
    "dominates",
    "pareto_front",
    "knee_point",
]


class Objective(NamedTuple):
    """A named scalar to minimize over evaluations."""

    name: str
    fn: Callable[[Evaluation], float]


DEFAULT_OBJECTIVES: tuple[Objective, ...] = (
    Objective("area_um2", lambda e: e.area_um2),
    Objective("neg_bt_reduction", lambda e: -e.bt_reduction),
    Objective("latency_ns", lambda e: e.latency_ns),
)

# The paper's Fig. 5 trade as a plane: area vs BT reduction only.  On the
# measured conv streams the knee of this front is the paper's own k=4
# choice (asserted in tests/test_dse.py).
AREA_BT_OBJECTIVES: tuple[Objective, ...] = DEFAULT_OBJECTIVES[:2]

# The fleet-scale plane (DESIGN.md §17): area vs BT reduction vs
# END-TO-END latency — sort window plus the point's NoC traversal under
# the wormhole/contention model (``Evaluation.total_latency_ns``).  For
# point-to-point designs it degrades gracefully to the sort latency, so
# mixed grids rank on one consistent axis; the knee on the reference
# fleet grid is pinned in tests/test_dse.py.
AREA_BT_LATENCY_OBJECTIVES: tuple[Objective, ...] = (
    *AREA_BT_OBJECTIVES,
    Objective("total_latency_ns", lambda e: e.total_latency_ns),
)


def _values(e: Evaluation, objectives: Sequence[Objective]) -> tuple[float, ...]:
    return tuple(float(obj.fn(e)) for obj in objectives)


def dominates(
    a: Evaluation,
    b: Evaluation,
    objectives: Sequence[Objective] = DEFAULT_OBJECTIVES,
) -> bool:
    """True when ``a`` is no worse than ``b`` everywhere and better somewhere."""
    va, vb = _values(a, objectives), _values(b, objectives)
    return all(x <= y for x, y in zip(va, vb)) and any(
        x < y for x, y in zip(va, vb)
    )


def pareto_front(
    evals: Sequence[Evaluation],
    objectives: Sequence[Objective] = DEFAULT_OBJECTIVES,
) -> tuple[Evaluation, ...]:
    """The non-dominated subset of ``evals``, in input order.

    Objective-value ties survive together (neither dominates the other), so
    duplicated design points stay on the front rather than being silently
    merged.
    """
    evals = tuple(evals)
    return tuple(
        e
        for e in evals
        if not any(dominates(o, e, objectives) for o in evals if o is not e)
    )


def knee_point(
    front: Sequence[Evaluation],
    objectives: Sequence[Objective] = DEFAULT_OBJECTIVES,
) -> Evaluation:
    """The front's best-balanced point: min normalized distance to the ideal.

    Each objective is scaled to [0, 1] over the front (constant objectives
    contribute 0); the ideal is the componentwise minimum.  Deterministic:
    ties resolve to the earliest point in ``front`` order.
    """
    front = tuple(front)
    if not front:
        raise ValueError("empty front")
    table = [_values(e, objectives) for e in front]
    lo = [min(col) for col in zip(*table)]
    hi = [max(col) for col in zip(*table)]
    span = [h - l if h > l else 1.0 for l, h in zip(lo, hi)]

    def dist(values: tuple[float, ...]) -> float:
        return sum(((v - l) / s) ** 2 for v, l, s in zip(values, lo, span))

    best = min(range(len(front)), key=lambda i: dist(table[i]))
    return front[best]
