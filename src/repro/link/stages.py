"""Registered TX-pipeline stages (paper §III/§IV; DESIGN.md §3.2).

Every transmit path in the framework is the same five-stage pipeline —

    KEY -> ENCODE -> ORDER (counting sort) -> PACK -> MEASURE

— and this module holds the pluggable stages of it:

  * ``KEY_STAGES``    — sort-key derivation.  Everything is expressed as
    "keys + bucket count" so the ORDER stage is always the paper's stable
    counting sort: 'acc' keys on exact '1'-bit counts, 'app' on k coarse
    buckets, 'row_bucket' on whole-row popcount buckets (the TPU row-stream
    adaptation, DESIGN.md §3.3), and the data-independent 'none' /
    'column_major' degenerate to fixed permutations (keys = transmit rank).
  * ``ENCODE_STAGES`` — wire byte recoding ('identity', 'sign_magnitude').
  * ``PACK_STAGES``   — flit layout: 'row' (row-major), 'lane' (the PSU's
    lane-major packing, paper Fig. 2), 'col' (whole-stream column-major —
    the layout under which row ordering has leverage, EXPERIMENTS.md
    §Arch-BT).  'col' is a stream layout only; the paired per-packet framing
    uses 'row'/'lane'.

The legacy strategy API (``make_order`` / ``order_packets`` /
``ORDER_STRATEGIES``) is preserved on top of the registries; the old import
path ``repro.core.ordering`` re-exports it.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Mapping, Optional

import jax
import jax.numpy as jnp

from repro.core.coding import gray_encode_bytes
from repro.core.popcount import bucket_map, popcount
from repro.core.sorting import counting_sort_indices

__all__ = [
    "KeyStage",
    "PackStage",
    "KEY_STAGES",
    "ENCODE_STAGES",
    "PACK_STAGES",
    "lookup_stage",
    "make_order",
    "order_packets",
    "ORDER_STRATEGIES",
    "to_sign_magnitude",
    "to_gray",
    "tensor_flit_stream",
    "row_bucket_keys",
    "row_bucket_order",
]


def lookup_stage(kind: str, name: str, registry: Mapping[str, object]):
    """Registry lookup with the harness-wide unknown-name UX: errors list
    every registered stage name (mirrors ``benchmarks/run.py``)."""
    stage = registry.get(name)
    if stage is None:
        raise ValueError(
            f"unknown {kind} stage {name!r}; registered {kind} stages: "
            f"{', '.join(sorted(registry))}"
        )
    return stage


# --------------------------------------------------------------------------
# encode stages
# --------------------------------------------------------------------------


def to_sign_magnitude(q_int8: jax.Array) -> jax.Array:
    """Recode two's-complement int8 as sign-magnitude bytes.

    Beyond-paper optimization (EXPERIMENTS.md §Arch-BT): two's complement
    decorrelates popcount from magnitude (-1 = 0xFF has popcount 8), which
    both halves the ordering signal and inflates baseline BT.  Sign-magnitude
    makes popcount monotone in |value| — near-zero weights become near-zero
    bytes — cutting weight-stream BT by ~50 % *before* any ordering.  In
    hardware this is one XOR per bit at the link interface.
    """
    q = q_int8.astype(jnp.int16)
    sign = (q < 0).astype(jnp.uint8) << 7
    return (sign | jnp.abs(q).astype(jnp.uint8)).astype(jnp.uint8)


def to_gray(values: jax.Array) -> jax.Array:
    """Recode bytes as reflected-binary Gray code (repro.core.coding).

    The stateless half of the ``repro.codec`` family surfaced as an encode
    stage: applied before the KEY stage, so popcount keys are derived from
    the gray image — the element-level composition (DESIGN.md §11; the
    wire-level composition, keys from raw bytes, is the ``LinkSpec.codec``
    stage instead).
    """
    return gray_encode_bytes(values.astype(jnp.uint8))


ENCODE_STAGES: Dict[str, Callable[[jax.Array], jax.Array]] = {
    "identity": lambda v: v,
    "sign_magnitude": to_sign_magnitude,
    "gray": to_gray,
}


# --------------------------------------------------------------------------
# key stages
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class KeyStage:
    """Sort-key derivation: fn(values, *, lanes, width, k) -> (keys, buckets).

    ``data_independent`` marks stages whose permutation is fixed by the
    framing alone (no data inspection): the pipeline broadcasts one
    precomputed row instead of counting-sorting every packet.
    """

    name: str
    fn: Callable[..., tuple[jax.Array, int]]
    data_independent: bool = False


def _key_none(values: jax.Array, **_: object) -> tuple[jax.Array, int]:
    n = values.shape[-1]
    keys = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), values.shape)
    return keys, n


def _key_column_major(
    values: jax.Array, *, lanes: int = 8, **_: object
) -> tuple[jax.Array, int]:
    """Keys = transmit rank of the column-major re-traversal of the packet's
    (flits, lanes) matrix: element at (f, l) is visited in order l*F + f."""
    n = values.shape[-1]
    if n % lanes != 0:
        raise ValueError(f"packet size {n} not divisible by lanes {lanes}")
    flits = n // lanes
    i = jnp.arange(n, dtype=jnp.int32)
    keys = jnp.broadcast_to((i % lanes) * flits + i // lanes, values.shape)
    return keys, n


def _key_acc(
    values: jax.Array, *, width: int = 8, **_: object
) -> tuple[jax.Array, int]:
    return popcount(values, width), width + 1


def _key_app(
    values: jax.Array, *, width: int = 8, k: int = 4, **_: object
) -> tuple[jax.Array, int]:
    return bucket_map(popcount(values, width), width, k), k


def row_bucket_keys(
    rows: jax.Array, levels: int, *, width: int = 8
) -> jax.Array:
    """Bucket key per row of an (R, B) byte matrix.

    Row key = total '1'-bit count of the row's bytes, mapped to ``levels``
    buckets the same way the paper maps element popcounts (uniform partition
    of the [0, 8*B] count range).  ACC element granularity corresponds to
    levels = W+1 = 9, APP to levels = k.
    """
    bits = popcount(rows.astype(jnp.uint8), width).sum(axis=-1)  # (R,)
    max_bits = width * rows.shape[-1]
    return (bits * levels) // (max_bits + 1)


def _key_row_bucket(
    values: jax.Array, *, width: int = 8, k: int = 4, **_: object
) -> tuple[jax.Array, int]:
    return row_bucket_keys(values, k, width=width), k


KEY_STAGES: Dict[str, KeyStage] = {
    "none": KeyStage("none", _key_none, data_independent=True),
    "column_major": KeyStage("column_major", _key_column_major, data_independent=True),
    "acc": KeyStage("acc", _key_acc),
    "app": KeyStage("app", _key_app),
    "row_bucket": KeyStage("row_bucket", _key_row_bucket),
}


def row_bucket_order(
    rows: jax.Array, levels: int, *, width: int = 8, descending: bool = False
) -> jax.Array:
    """Stable comparison-free sort order of rows by popcount bucket."""
    keys = row_bucket_keys(rows, levels, width=width)
    if descending:
        keys = (levels - 1) - keys
    return counting_sort_indices(keys, levels)


# --------------------------------------------------------------------------
# pack stages
# --------------------------------------------------------------------------


def tensor_flit_stream(mat: jax.Array, lanes: int = 16) -> jax.Array:
    """View a byte matrix as a (T, lanes) flit stream (row-major flatten,
    trimmed to whole flits) — for a weight matrix this is exactly the HBM
    row stream the decode path reads."""
    flat = mat.reshape(-1)
    usable = (flat.shape[0] // lanes) * lanes
    return flat[:usable].reshape(-1, lanes)


def _per_packet_row(values: jax.Array, lanes: int) -> jax.Array:
    p, n = values.shape
    if n % lanes != 0:
        raise ValueError(f"payload size {n} not divisible by lanes {lanes}")
    return values.reshape(p, n // lanes, lanes)


def _per_packet_lane(values: jax.Array, lanes: int) -> jax.Array:
    p, n = values.shape
    if n % lanes != 0:
        raise ValueError(f"payload size {n} not divisible by lanes {lanes}")
    return values.reshape(p, lanes, n // lanes).transpose(0, 2, 1)


@dataclasses.dataclass(frozen=True)
class PackStage:
    """Flit layout: ``per_packet`` shapes (P, N) payloads into (P, F, lanes)
    flit halves (None for stream-only layouts); ``stream`` lays a whole byte
    matrix out as (T, lanes) flit rows."""

    name: str
    per_packet: Optional[Callable[[jax.Array, int], jax.Array]]
    stream: Callable[[jax.Array, int], jax.Array]


PACK_STAGES: Dict[str, PackStage] = {
    "row": PackStage("row", _per_packet_row, tensor_flit_stream),
    "lane": PackStage(
        "lane",
        _per_packet_lane,
        lambda m, lanes: _per_packet_lane(m, lanes).reshape(-1, lanes),
    ),
    "col": PackStage("col", None, lambda m, lanes: tensor_flit_stream(m.T, lanes)),
}


# --------------------------------------------------------------------------
# legacy strategy API (paper §IV, Table I) — kept verbatim on the registries
# --------------------------------------------------------------------------


def make_order(
    strategy: str,
    values: jax.Array,
    *,
    lanes: int = 8,
    width: int = 8,
    k: int = 4,
    descending: bool = False,
    **_: object,
) -> jax.Array:
    """Per-packet element order for ``strategy``.

    Args:
      strategy: a packet-granularity ``KEY_STAGES`` name ('none',
        'column_major', 'acc', 'app').
      values: (..., N) uint8 input-side packet values the order is derived
        from (ACC/APP sort keys come from these).
      lanes / width / k / descending: stage parameters.

    Returns:
      int32 (..., N) permutation per packet; gather with it to reorder.
    """
    stage = KEY_STAGES.get(strategy)
    if stage is None or strategy == "row_bucket":
        choices = sorted(set(KEY_STAGES) - {"row_bucket"})
        raise ValueError(
            f"unknown ordering strategy {strategy!r}; choose from {choices}"
        )
    n = values.shape[-1]
    if stage.data_independent:
        # fixed permutation (descending is a sort-stage knob; layout stages
        # ignore it, matching the legacy strategy semantics): derive the
        # order from one key row and broadcast it over the batch
        if strategy == "none":
            order = jnp.arange(n, dtype=jnp.int32)
        else:
            keys, nb = stage.fn(
                jnp.zeros((n,), jnp.int32), lanes=lanes, width=width, k=k
            )
            order = counting_sort_indices(keys, nb)
        return jnp.broadcast_to(order, values.shape).astype(jnp.int32)
    keys, nb = stage.fn(values, lanes=lanes, width=width, k=k)
    if descending:
        keys = (nb - 1) - keys
    return counting_sort_indices(keys, nb).astype(jnp.int32)


def order_packets(
    strategy: str,
    inputs: jax.Array,
    weights: jax.Array | None = None,
    **kwargs: object,
) -> tuple[jax.Array, jax.Array | None]:
    """Reorder packets of (input, weight) pairs with one strategy.

    Args:
      inputs: (P, N) uint8 — P packets of N input bytes.
      weights: optional (P, N) uint8 paired weights (move with the inputs).

    Returns:
      (ordered_inputs, ordered_weights_or_None).
    """
    order = make_order(strategy, inputs, **kwargs)
    out_i = jnp.take_along_axis(inputs, order, axis=-1)
    out_w = (
        jnp.take_along_axis(weights, order, axis=-1) if weights is not None else None
    )
    return out_i, out_w


def _legacy_strategy(name: str) -> Callable[..., jax.Array]:
    def fn(values: jax.Array, **kwargs: object) -> jax.Array:
        return make_order(name, values, **kwargs)

    fn.__name__ = f"order_{name}"
    return fn


ORDER_STRATEGIES: Dict[str, Callable[..., jax.Array]] = {
    name: _legacy_strategy(name) for name in ("none", "column_major", "acc", "app")
}
