# The TX-pipeline subsystem: the paper's transmit dataflow (popcount ->
# bucket -> counting-sort -> reorder -> pack -> measure) as one composable,
# registry-backed pipeline (DESIGN.md §3.2):
#   spec.py     - LinkSpec: framing + stage selection in one dataclass
#   stages.py   - registered key/encode/pack stages + legacy strategy API
#   framing.py  - flit packing and paired-stream assembly (DESIGN.md §1)
#   pipeline.py - TxPipeline: staged path + fused single-launch hot path
#   power.py    - the Fig. 6/7 link power model
# Old import paths (repro.core.link, repro.core.ordering) are shims onto
# this package.  Wire codecs (repro.codec, DESIGN.md §11) plug in through
# the LinkSpec `codec` field.
from .framing import (
    LinkConfig,
    measure,
    pack_to_flits,
    paired_stream,
    unpack_from_flits,
)
from .pipeline import LinkReport, TxPipeline, TxResult
from .power import LinkPowerModel
from .spec import LinkSpec
from .stages import (
    ENCODE_STAGES,
    KEY_STAGES,
    ORDER_STRATEGIES,
    PACK_STAGES,
    KeyStage,
    PackStage,
    lookup_stage,
    make_order,
    order_packets,
    row_bucket_keys,
    row_bucket_order,
    tensor_flit_stream,
    to_gray,
    to_sign_magnitude,
)

__all__ = [
    "LinkSpec",
    "LinkConfig",
    "TxPipeline",
    "TxResult",
    "LinkReport",
    "LinkPowerModel",
    "pack_to_flits",
    "unpack_from_flits",
    "paired_stream",
    "measure",
    "make_order",
    "order_packets",
    "ORDER_STRATEGIES",
    "KEY_STAGES",
    "ENCODE_STAGES",
    "PACK_STAGES",
    "KeyStage",
    "PackStage",
    "lookup_stage",
    "to_sign_magnitude",
    "to_gray",
    "tensor_flit_stream",
    "row_bucket_keys",
    "row_bucket_order",
]
