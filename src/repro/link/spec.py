"""`LinkSpec` — the one dataclass that configures the whole TX pipeline.

A spec describes both the *framing* of the physical link (DESIGN.md §1: a
128-bit link carrying 4-flit packets, each flit split between input and
weight byte lanes) and the *stage selection* of the transmit pipeline built
on it (DESIGN.md §3.2):

    key     — how sort keys are derived ('none' | 'column_major' | 'acc' |
              'app' | 'row_bucket'),
    encode  — element byte recoding ('identity' | 'sign_magnitude' |
              'gray'), applied BEFORE the key stage,
    pack    — flit layout ('row' | 'lane' | 'col'),
    codec   — wire coding of the assembled stream ('none' | a registered
              ``repro.codec`` name, e.g. 'bus_invert'), applied AFTER
              ordering and packing (DESIGN.md §11),

plus the key-stage parameters (element width W, APP bucket count k, sort
direction).  ``LinkSpec`` is a drop-in superset of the old
``repro.core.link.LinkConfig`` (its first four fields, defaults and derived
properties are identical), so legacy framing-only callers keep working
through the ``LinkConfig`` alias.
"""

from __future__ import annotations

import dataclasses

__all__ = ["LinkSpec"]


@dataclasses.dataclass(frozen=True)
class LinkSpec:
    """Framing + stage configuration of one transmit pipeline.

    Framing defaults reproduce the paper's Table-I setup.
    """

    # --- framing (physical link) ---
    width_bits: int = 128  # physical link width
    flits_per_packet: int = 4
    input_lanes: int = 8  # bytes of input data per flit
    weight_lanes: int = 8  # bytes of weight data per flit

    # --- stage selection ---
    key: str = "acc"  # repro.link.stages.KEY_STAGES
    encode: str = "identity"  # repro.link.stages.ENCODE_STAGES
    pack: str = "lane"  # repro.link.stages.PACK_STAGES
    codec: str = "none"  # repro.codec.CODECS (wire coding, DESIGN.md §11)

    # --- key-stage parameters ---
    width: int = 8  # element bit width W of the sort keys
    k: int = 4  # APP / row-bucket count
    descending: bool = False

    @property
    def bytes_per_flit(self) -> int:
        return self.width_bits // 8

    @property
    def elems_per_packet(self) -> int:
        """Input bytes carried per packet."""
        return self.flits_per_packet * self.input_lanes

    @property
    def weight_elems_per_packet(self) -> int:
        """Weight bytes carried per packet (== elems_per_packet only for the
        symmetric paired framing)."""
        return self.flits_per_packet * self.weight_lanes

    @property
    def symmetric(self) -> bool:
        """Input/weight lanes match: (input, weight) pairs move together."""
        return self.input_lanes == self.weight_lanes

    def __post_init__(self) -> None:
        if self.input_lanes + self.weight_lanes != self.bytes_per_flit:
            raise ValueError(
                "input_lanes + weight_lanes must fill the flit: "
                f"{self.input_lanes}+{self.weight_lanes} != {self.bytes_per_flit}"
            )
        # stage names are validated against the registries lazily (the
        # registries live in .stages, which must stay importable first)
        from . import stages

        for field, registry in (
            ("key", stages.KEY_STAGES),
            ("encode", stages.ENCODE_STAGES),
            ("pack", stages.PACK_STAGES),
        ):
            stages.lookup_stage(field, getattr(self, field), registry)
        if self.codec != "none":
            # deferred further: repro.codec registers into repro.link at
            # import, so this import must not run while link initializes
            # (it never does: module-level specs use the 'none' default)
            from repro.codec.schemes import CODECS

            stages.lookup_stage("codec", self.codec, CODECS)
