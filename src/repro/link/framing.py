"""Link framing: packing packet payloads into flit streams (DESIGN.md §1).

The paper's platform transmits packets over a 128-bit link: each packet is 4
flits, each flit carries 8 input bytes and 8 paired weight bytes.  This
module packs (reordered) packet payloads into flit streams; the staged /
fused pipeline on top lives in ``repro.link.pipeline``.

Asymmetric framings (``input_lanes != weight_lanes``) are supported: the
weight side then carries ``flits_per_packet * weight_lanes`` bytes per
packet and is framed natively *without* the input-derived permutation (the
paper's pairing argument — weights move with their inputs — only applies
when both sides carry the same element count; see DESIGN.md §1).
"""

from __future__ import annotations

from typing import Literal

import jax
import jax.numpy as jnp

from repro.core.bt import BTReport, bt_report

from .spec import LinkSpec
from .stages import PACK_STAGES, lookup_stage, make_order

__all__ = [
    "LinkConfig",
    "pack_to_flits",
    "unpack_from_flits",
    "paired_stream",
    "measure",
]

# Legacy name: framing-only callers configured a ``LinkConfig``; the spec is
# a drop-in superset (same leading fields, defaults and derived properties).
LinkConfig = LinkSpec

PackOrder = Literal["row", "lane"]


def pack_to_flits(
    values: jax.Array, lanes: int, pack: PackOrder = "lane"
) -> jax.Array:
    """Pack (P, N) packet payloads into (P, flits, lanes) flit halves.

    ``pack="lane"`` places consecutive payload elements in the *same lane* of
    consecutive flits (element e of a packet -> flit e % F, lane e // F), so a
    popcount-sorted payload yields monotone lane streams — this is the
    packing the transmitting unit uses after the PSU (paper Fig. 2 shows the
    resulting per-flit popcount trend).  ``pack="row"`` is plain row-major.
    """
    stage = lookup_stage("pack", pack, PACK_STAGES)
    if stage.per_packet is None:
        raise ValueError(
            f"pack stage {pack!r} is a stream-only layout; per-packet "
            "framing uses 'row' or 'lane'"
        )
    return stage.per_packet(values, lanes)


def unpack_from_flits(
    flits: jax.Array, pack: PackOrder = "lane"
) -> jax.Array:
    """Inverse of :func:`pack_to_flits`: (P, F, lanes) flit halves back to
    the (P, N) payloads a receiver reassembles (round-tripped in
    ``tests/test_framing.py``, incl. single-flit packets)."""
    lookup_stage("pack", pack, PACK_STAGES)  # same registry, same UX
    p, f, lanes = flits.shape
    if pack == "row":
        return flits.reshape(p, f * lanes)
    if pack == "lane":
        return flits.transpose(0, 2, 1).reshape(p, f * lanes)
    raise ValueError(
        f"pack stage {pack!r} is a stream-only layout; per-packet "
        "framing uses 'row' or 'lane'"
    )


def _validate_paired(
    inputs: jax.Array, weights: jax.Array, cfg: LinkSpec
) -> None:
    if inputs.shape[-1] != cfg.elems_per_packet:
        raise ValueError(
            f"packet payload {inputs.shape[-1]} != "
            f"flits*input_lanes = {cfg.elems_per_packet}"
        )
    if inputs.shape[:-1] != weights.shape[:-1]:
        raise ValueError(
            f"paired batch shapes differ: {inputs.shape} vs {weights.shape}"
        )
    if weights.shape[-1] != cfg.weight_elems_per_packet:
        raise ValueError(
            f"weight payload {weights.shape[-1]} != "
            f"flits*weight_lanes = {cfg.weight_elems_per_packet} "
            f"(input_lanes={cfg.input_lanes}, weight_lanes={cfg.weight_lanes})"
        )


def assemble_stream(
    inputs: jax.Array,
    weights: jax.Array | None,
    cfg: LinkSpec,
    order: jax.Array | None,
    pack: PackOrder = "lane",
) -> jax.Array:
    """Apply ``order``, pack both halves per flit and flatten to (T, bytes).

    The input-derived ``order`` moves the weight bytes along only for the
    symmetric framing (same element count per side); an asymmetric weight
    half is framed in its native order.
    """
    inp = inputs if order is None else jnp.take_along_axis(inputs, order, axis=-1)
    fi = pack_to_flits(inp, cfg.input_lanes, pack)
    if weights is None or cfg.weight_lanes == 0:
        return fi.reshape(-1, cfg.input_lanes).astype(jnp.uint8)
    if order is not None and weights.shape == inputs.shape:
        weights = jnp.take_along_axis(weights, order, axis=-1)
    fw = pack_to_flits(weights, cfg.weight_lanes, pack)
    flits = jnp.concatenate([fi, fw], axis=-1)  # (P, F, bytes_per_flit)
    return flits.reshape(-1, cfg.bytes_per_flit).astype(jnp.uint8)


def paired_stream(
    inputs: jax.Array,
    weights: jax.Array,
    cfg: LinkSpec = LinkSpec(),
    strategy: str = "none",
    pack: PackOrder = "lane",
    **order_kwargs: object,
) -> jax.Array:
    """Assemble the full link stream for P packets of (input, weight) data.

    Applies ``strategy`` per packet (deriving the order from the input side,
    moving the paired weights along when the framing is symmetric), packs
    both halves into flits and concatenates packets into one
    (P*F, bytes_per_flit) uint8 stream.
    """
    _validate_paired(inputs, weights, cfg)
    order = make_order(strategy, inputs, lanes=cfg.input_lanes, **order_kwargs)
    return assemble_stream(inputs, weights, cfg, order, pack)


def measure(
    inputs: jax.Array,
    weights: jax.Array,
    cfg: LinkSpec = LinkSpec(),
    strategy: str = "none",
    pack: PackOrder = "lane",
    **order_kwargs: object,
) -> BTReport:
    """One-call Table-I measurement for a strategy (legacy API).

    New code should use ``repro.link.TxPipeline.measure`` — same numbers,
    one fused kernel launch instead of a sort launch + gather + BT launch.
    """
    stream = paired_stream(inputs, weights, cfg, strategy, pack, **order_kwargs)
    return bt_report(stream, cfg.input_lanes)
