"""Dynamic-power model for link-related power (paper Fig. 6/7; DESIGN.md §6).

    P_link ∝ alpha · C · V^2 · f,  alpha ∝ BT per flit

so *link-related power reduction = transfer_factor × BT reduction*, where the
transfer factor < 1 absorbs the non-data switching floor (clock, control) of
the transmission registers.  Calibrated from the paper: ACC 20.42 % BT ->
18.27 % power gives transfer_factor ≈ 0.895.
"""

from __future__ import annotations

import dataclasses

__all__ = ["LinkPowerModel"]


@dataclasses.dataclass(frozen=True)
class LinkPowerModel:
    """Maps measured BT to link-related energy/power (Fig. 6/7).

    ``transfer_factor`` maps BT reduction to link-related power reduction
    (non-data switching floor of the transmission registers); calibrated to
    the paper's ACC point (20.42 % BT -> 18.27 % power).
    ``energy_per_transition_pj`` sets the absolute scale (representative
    22 nm on-chip wire; absolute numbers are modeled, ratios are the claim).
    """

    transfer_factor: float = 18.27 / 20.42
    energy_per_transition_pj: float = 0.18
    static_flit_energy_pj: float = 2.0  # clock/control floor per flit

    def link_energy_pj(self, total_bt: float, num_flits: int) -> float:
        return (
            self.energy_per_transition_pj * float(total_bt)
            + self.static_flit_energy_pj * float(num_flits)
        )

    def coded_link_energy_pj(
        self,
        data_bt: float,
        aux_bt: float,
        num_flits: int,
        data_wires: int,
        extra_wires: int = 0,
    ) -> float:
        """Energy of a codec-coded stream, net of its added lines.

        Invert-line transitions (``aux_bt``) switch real wires, so they pay
        the same per-transition energy as data; the ``extra_wires`` invert
        lines also widen the clocked register bank, scaling the per-flit
        static floor by the wire-count ratio (DESIGN.md §11).  With
        ``aux_bt = extra_wires = 0`` this is exactly ``link_energy_pj`` —
        BT wins of any codec are reported *net* of this overhead.
        """
        if data_wires <= 0:
            raise ValueError(f"need data_wires >= 1, got {data_wires}")
        floor = 1.0 + extra_wires / float(data_wires)
        return (
            self.energy_per_transition_pj * float(data_bt + aux_bt)
            + self.static_flit_energy_pj * floor * float(num_flits)
        )

    def wire_energy_pj(
        self,
        per_wire_bt,
        num_flits: int,
        *,
        wire_caps=None,
        data_wires: int | None = None,
        extra_wires: int = 0,
    ) -> float:
        """Wire-resolved link energy from a per-wire BT vector (§15).

        ``per_wire_bt`` is the ``data_wires + extra_wires``-long toggle
        vector of one link (the ``ActivityProfile.per_wire`` view);
        ``wire_caps`` is an optional per-wire relative capacitance profile
        — ``energy_per_transition_pj`` is the per-transition cost of a
        cap-1.0 wire, so a 1.3 entry models a 30 % longer/loaded net.
        The static floor is the same widened-register term as
        ``coded_link_energy_pj``.  With uniform caps (the default) this
        reproduces ``link_energy_pj`` / ``coded_link_energy_pj`` EXACTLY
        (same float expression — pinned in tests), so the wire-resolved
        path is a refinement, never a second model.
        """
        bt = [float(b) for b in per_wire_bt]
        if data_wires is None:
            data_wires = len(bt) - extra_wires
        if data_wires <= 0:
            raise ValueError(f"need data_wires >= 1, got {data_wires}")
        if data_wires + extra_wires != len(bt):
            raise ValueError(
                f"{len(bt)} per-wire entries != {data_wires} data + "
                f"{extra_wires} extra wires"
            )
        if wire_caps is None:
            weighted = sum(bt)
        else:
            caps = [float(c) for c in wire_caps]
            if len(caps) != len(bt):
                raise ValueError(
                    f"{len(caps)} wire_caps != {len(bt)} wires"
                )
            weighted = sum(c * b for c, b in zip(caps, bt))
        floor = 1.0 + extra_wires / float(data_wires)
        return (
            self.energy_per_transition_pj * weighted
            + self.static_flit_energy_pj * floor * float(num_flits)
        )

    def power_reduction(self, bt_reduction: float) -> float:
        """Link-related power reduction predicted from a BT reduction."""
        return self.transfer_factor * bt_reduction
