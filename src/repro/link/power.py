"""Dynamic-power model for link-related power (paper Fig. 6/7; DESIGN.md §6).

    P_link ∝ alpha · C · V^2 · f,  alpha ∝ BT per flit

so *link-related power reduction = transfer_factor × BT reduction*, where the
transfer factor < 1 absorbs the non-data switching floor (clock, control) of
the transmission registers.  Calibrated from the paper: ACC 20.42 % BT ->
18.27 % power gives transfer_factor ≈ 0.895.
"""

from __future__ import annotations

import dataclasses

__all__ = ["LinkPowerModel"]


@dataclasses.dataclass(frozen=True)
class LinkPowerModel:
    """Maps measured BT to link-related energy/power (Fig. 6/7).

    ``transfer_factor`` maps BT reduction to link-related power reduction
    (non-data switching floor of the transmission registers); calibrated to
    the paper's ACC point (20.42 % BT -> 18.27 % power).
    ``energy_per_transition_pj`` sets the absolute scale (representative
    22 nm on-chip wire; absolute numbers are modeled, ratios are the claim).
    """

    transfer_factor: float = 18.27 / 20.42
    energy_per_transition_pj: float = 0.18
    static_flit_energy_pj: float = 2.0  # clock/control floor per flit

    def link_energy_pj(self, total_bt: float, num_flits: int) -> float:
        return (
            self.energy_per_transition_pj * float(total_bt)
            + self.static_flit_energy_pj * float(num_flits)
        )

    def coded_link_energy_pj(
        self,
        data_bt: float,
        aux_bt: float,
        num_flits: int,
        data_wires: int,
        extra_wires: int = 0,
    ) -> float:
        """Energy of a codec-coded stream, net of its added lines.

        Invert-line transitions (``aux_bt``) switch real wires, so they pay
        the same per-transition energy as data; the ``extra_wires`` invert
        lines also widen the clocked register bank, scaling the per-flit
        static floor by the wire-count ratio (DESIGN.md §11).  With
        ``aux_bt = extra_wires = 0`` this is exactly ``link_energy_pj`` —
        BT wins of any codec are reported *net* of this overhead.
        """
        if data_wires <= 0:
            raise ValueError(f"need data_wires >= 1, got {data_wires}")
        floor = 1.0 + extra_wires / float(data_wires)
        return (
            self.energy_per_transition_pj * float(data_bt + aux_bt)
            + self.static_flit_energy_pj * floor * float(num_flits)
        )

    def power_reduction(self, bt_reduction: float) -> float:
        """Link-related power reduction predicted from a BT reduction."""
        return self.transfer_factor * bt_reduction
