"""`TxPipeline` — the staged transmit path, fused on its hot path.

One object owns the paper's whole dataflow (popcount -> bucket ->
counting-sort -> reorder -> pack -> measure), configured by a single
``LinkSpec``.  Two execution paths produce bit-identical results:

  * **fused** (default when applicable): one Pallas launch per packet block
    (``repro.kernels.psu_stream``) runs sort + reorder + flit-pack +
    BT-accumulate without the stream ever leaving VMEM.  Applicable for
    'acc'/'app' keys with 'row'/'lane' packing and a symmetric (or absent)
    weight side.
  * **staged** (fallback + reference): the registered stages composed with
    the ``repro.core.sorting`` counting sort and the ``bt_count`` kernel —
    a sort launch, a host gather, and one BT launch per lane half.  Used by
    the data-independent strategies ('none', 'column_major'), the 'col'
    stream layout, asymmetric framings, and row streams.

Row streams (weight matrices traversed row-wise — the TPU traffic
adaptation, DESIGN.md §3.3) go through ``measure_rows``/``transmit_rows``
with the 'row_bucket' key stage.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.bt import BTReport
from repro.kernels import bt_count, psu_stream

from .framing import _validate_paired, assemble_stream
from .power import LinkPowerModel
from .spec import LinkSpec
from .stages import ENCODE_STAGES, PACK_STAGES, make_order, row_bucket_order

__all__ = ["TxPipeline", "TxResult", "LinkReport"]


@dataclasses.dataclass(frozen=True)
class TxResult:
    """What one transmit produces: the permutation, the wire image, the BT."""

    order: jax.Array  # (P, N) int32 (or (R,) for row streams)
    rank: Optional[jax.Array]  # (P, N) int32; None on the staged path
    stream: jax.Array  # (T, lanes) uint8 packed flit rows
    bt_input: jax.Array  # int32: input-side bit transitions
    bt_weight: jax.Array  # int32: weight-side bit transitions
    fused: bool  # produced by the single-launch kernel?


@dataclasses.dataclass(frozen=True)
class LinkReport:
    """BT / energy accounting of one measured stream (Table-I columns +
    the Fig. 6/7 energy model)."""

    name: str
    num_flits: int
    input_bt: int
    weight_bt: int
    fused: bool = False
    energy_pj: float = 0.0

    @property
    def total_bt(self) -> int:
        return self.input_bt + self.weight_bt

    @property
    def input_bt_per_flit(self) -> float:
        return self.input_bt / max(self.num_flits, 1)

    @property
    def weight_bt_per_flit(self) -> float:
        return self.weight_bt / max(self.num_flits, 1)

    @property
    def overall_bt_per_flit(self) -> float:
        return self.total_bt / max(self.num_flits, 1)

    def reduction_vs(self, base: "LinkReport") -> float:
        """Overall BT reduction relative to a baseline report (fraction)."""
        return 1.0 - self.total_bt / max(base.total_bt, 1e-9)

    def to_bt_report(self) -> BTReport:
        """Legacy ``repro.core.bt.BTReport`` view (Table-I columns)."""
        return BTReport(
            jnp.float32(self.input_bt_per_flit),
            jnp.float32(self.weight_bt_per_flit),
            jnp.float32(self.overall_bt_per_flit),
        )


class TxPipeline:
    """Staged TX pipeline over one link, configured by a ``LinkSpec``.

    Args:
      spec: framing + stage selection.
      power: energy model for ``LinkReport.energy_pj`` (default paper model).
      fused: force (True) or forbid (False) the fused kernel; None = use it
        whenever the spec allows.
      interpret: Pallas interpret-mode override (None = auto: interpret off
        TPU).
      block_packets: packets per fused-kernel grid step.
    """

    def __init__(
        self,
        spec: LinkSpec = LinkSpec(),
        *,
        power: LinkPowerModel | None = None,
        fused: bool | None = None,
        interpret: bool | None = None,
        block_packets: int = 64,
    ) -> None:
        self.spec = spec
        self.power = power if power is not None else LinkPowerModel()
        self._fused = fused
        self._interpret = interpret
        self._block_packets = block_packets

    # ---------------------------------------------------------------- stages
    def encode(self, values: jax.Array) -> jax.Array:
        """The wire byte image of ``values`` under the encode stage."""
        return ENCODE_STAGES[self.spec.encode](values)

    def order(self, inputs: jax.Array) -> jax.Array:
        """Per-packet transmit permutation (derived from encoded inputs)."""
        s = self.spec
        return make_order(
            s.key,
            self.encode(inputs),
            lanes=s.input_lanes,
            width=s.width,
            k=s.k,
            descending=s.descending,
        )

    def _fusable(self, weights: jax.Array | None) -> bool:
        s = self.spec
        return (
            s.key in ("acc", "app")
            and s.pack in ("lane", "row")
            and (weights is None or s.symmetric)
        )

    # ------------------------------------------------------------- packet TX
    def run(
        self, inputs: jax.Array, weights: jax.Array | None = None
    ) -> TxResult:
        """Transmit P packets: returns permutation, wire stream and BT.

        ``inputs`` is (P, elems_per_packet); ``weights`` (optional) is
        (P, elems_per_packet) for the symmetric paired framing or
        (P, weight_elems_per_packet) for asymmetric links (framed unordered,
        see DESIGN.md §1).
        """
        s = self.spec
        if weights is not None:
            _validate_paired(inputs, weights, s)
        elif inputs.shape[-1] != s.elems_per_packet:
            raise ValueError(
                f"packet payload {inputs.shape[-1]} != "
                f"flits*input_lanes = {s.elems_per_packet}"
            )
        xi = self.encode(inputs)
        wi = self.encode(weights) if weights is not None else None
        fused = self._fused if self._fused is not None else self._fusable(weights)
        if fused and not self._fusable(weights):
            raise ValueError(
                f"spec (key={s.key!r}, pack={s.pack!r}, symmetric={s.symmetric})"
                " cannot run fused"
            )
        if fused:
            res = psu_stream(
                xi,
                wi,
                width=s.width,
                k=None if s.key == "acc" else s.k,
                descending=s.descending,
                input_lanes=s.input_lanes,
                weight_lanes=s.weight_lanes if wi is not None else None,
                pack=s.pack,
                block_packets=self._block_packets,
                interpret=self._interpret,
            )
            return TxResult(
                res.order, res.rank, res.stream, res.bt_input, res.bt_weight, True
            )
        order = make_order(
            s.key, xi, lanes=s.input_lanes, width=s.width, k=s.k,
            descending=s.descending,
        )
        stream = assemble_stream(xi, wi, s, order, s.pack)
        bt_i = bt_count(stream[:, : s.input_lanes], interpret=self._interpret)
        if wi is not None and s.weight_lanes:
            bt_w = bt_count(stream[:, s.input_lanes :], interpret=self._interpret)
        else:
            bt_w = jnp.int32(0)
        return TxResult(order, None, stream, bt_i, bt_w, False)

    def transmit(
        self, inputs: jax.Array, weights: jax.Array | None = None
    ) -> jax.Array:
        """The (T, lanes) uint8 wire image of the packets."""
        return self.run(inputs, weights).stream

    def measure(
        self,
        inputs: jax.Array,
        weights: jax.Array | None = None,
        name: str = "stream",
    ) -> LinkReport:
        """BT / energy report for transmitting the packets under this spec."""
        res = self.run(inputs, weights)
        num_flits = int(res.stream.shape[0])
        bt_i, bt_w = int(res.bt_input), int(res.bt_weight)
        return LinkReport(
            name,
            num_flits,
            bt_i,
            bt_w,
            fused=res.fused,
            energy_pj=self.power.link_energy_pj(bt_i + bt_w, num_flits),
        )

    # --------------------------------------------------------------- row TX
    def row_order(self, rows: jax.Array) -> jax.Array:
        """Transmit order of whole rows of an (R, B) byte matrix under this
        spec's key stage ('none' or 'row_bucket', DESIGN.md §3.3)."""
        s = self.spec
        if s.key == "none":
            return jnp.arange(rows.shape[0], dtype=jnp.int32)
        if s.key != "row_bucket":
            raise ValueError(
                f"row streams use key 'none' or 'row_bucket', got {s.key!r}"
            )
        return row_bucket_order(rows, s.k, width=s.width, descending=s.descending)

    def transmit_rows(self, rows: jax.Array) -> jax.Array:
        """Wire image of an (R, B) byte-row stream (weight matrix traffic,
        DESIGN.md §3.3): encode, order whole rows by popcount bucket, lay
        out with the pack stage ('row' = HBM-natural, 'col' = interleaved)."""
        enc = self.encode(rows)
        ordered = jnp.take(enc, self.row_order(enc), axis=0)
        return PACK_STAGES[self.spec.pack].stream(
            ordered, self.spec.bytes_per_flit
        ).astype(jnp.uint8)

    def measure_rows(self, rows: jax.Array, name: str = "rows") -> LinkReport:
        """BT / energy report for streaming ``rows`` under this spec."""
        stream = self.transmit_rows(rows)
        bt = int(bt_count(stream, interpret=self._interpret))
        num_flits = int(stream.shape[0])
        return LinkReport(
            name,
            num_flits,
            bt,
            0,
            fused=False,
            energy_pj=self.power.link_energy_pj(bt, num_flits),
        )
