"""`TxPipeline` — the staged transmit path, fused on its hot path.

One object owns the paper's whole dataflow (popcount -> bucket ->
counting-sort -> reorder -> pack -> measure), configured by a single
``LinkSpec``.  Two execution paths produce bit-identical results:

  * **fused** (default when applicable): one Pallas launch per packet block
    (``repro.kernels.psu_stream``) runs sort + reorder + flit-pack +
    BT-accumulate without the stream ever leaving VMEM.  Applicable for
    'acc'/'app' keys with 'row'/'lane' packing and a symmetric (or absent)
    weight side.
  * **staged** (fallback + reference): the registered stages composed with
    the ``repro.core.sorting`` counting sort and the ``bt_count`` kernel —
    a sort launch, a host gather, and one BT launch per lane half.  Used by
    the data-independent strategies ('none', 'column_major'), the 'col'
    stream layout, asymmetric framings, and row streams.

Row streams (weight matrices traversed row-wise — the TPU traffic
adaptation, DESIGN.md §3.3) go through ``measure_rows``/``transmit_rows``
with the 'row_bucket' key stage.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro import _obs_hooks as _obs
from repro.core.bt import BTReport
from repro.kernels import bt_count, psu_stream

from .framing import _validate_paired, assemble_stream
from .power import LinkPowerModel
from .spec import LinkSpec
from .stages import ENCODE_STAGES, PACK_STAGES, make_order, row_bucket_order

__all__ = ["TxPipeline", "TxResult", "LinkReport"]


@dataclasses.dataclass(frozen=True)
class TxResult:
    """What one transmit produces: the permutation, the wire image, the BT."""

    order: jax.Array  # (P, N) int32 (or (R,) for row streams)
    rank: Optional[jax.Array]  # (P, N) int32; None on the staged path
    stream: jax.Array  # (T, lanes) uint8 wire rows (codec-coded if any)
    bt_input: jax.Array  # int32: input-side bit transitions
    bt_weight: jax.Array  # int32: weight-side bit transitions
    fused: bool  # produced by the single-launch kernel?
    invert: Optional[jax.Array] = None  # (T, P) uint8 bus-invert lines
    bt_aux: jax.Array | int = 0  # int32: invert-line transitions


@dataclasses.dataclass(frozen=True)
class LinkReport:
    """BT / energy accounting of one measured stream (Table-I columns +
    the Fig. 6/7 energy model)."""

    name: str
    num_flits: int
    input_bt: int
    weight_bt: int
    fused: bool = False
    energy_pj: float = 0.0
    aux_bt: int = 0  # invert-line transitions (codec overhead)
    extra_wires: int = 0  # invert lines added beside the data lanes

    @property
    def total_bt(self) -> int:
        return self.input_bt + self.weight_bt

    @property
    def gross_bt(self) -> int:
        """Data BT plus the codec's own invert-line transitions — the
        number every codec comparison is scored on (net of overhead)."""
        return self.total_bt + self.aux_bt

    @property
    def input_bt_per_flit(self) -> float:
        return self.input_bt / max(self.num_flits, 1)

    @property
    def weight_bt_per_flit(self) -> float:
        return self.weight_bt / max(self.num_flits, 1)

    @property
    def overall_bt_per_flit(self) -> float:
        return self.total_bt / max(self.num_flits, 1)

    def reduction_vs(self, base: "LinkReport") -> float:
        """Overall BT reduction relative to a baseline report (fraction).

        Scored on ``gross_bt``, so coded streams are credited net of their
        invert-line overhead (identical to the data-only ratio when neither
        report carries a codec)."""
        return 1.0 - self.gross_bt / max(base.gross_bt, 1e-9)

    def to_bt_report(self) -> BTReport:
        """Legacy ``repro.core.bt.BTReport`` view (Table-I columns)."""
        return BTReport(
            jnp.float32(self.input_bt_per_flit),
            jnp.float32(self.weight_bt_per_flit),
            jnp.float32(self.overall_bt_per_flit),
        )


class TxPipeline:
    """Staged TX pipeline over one link, configured by a ``LinkSpec``.

    Args:
      spec: framing + stage selection.
      power: energy model for ``LinkReport.energy_pj`` (default paper model).
      fused: force (True) or forbid (False) the fused kernel; None = use it
        whenever the spec allows.
      interpret: Pallas interpret-mode override (None = auto: interpret off
        TPU).
      backend: kernel backend override ('pallas' | 'compiled' |
        'interpret', DESIGN.md §13); wins over ``interpret``.
      block_packets: packets per fused-kernel grid step.
    """

    def __init__(
        self,
        spec: LinkSpec = LinkSpec(),
        *,
        power: LinkPowerModel | None = None,
        fused: bool | None = None,
        interpret: bool | None = None,
        backend: str | None = None,
        block_packets: int = 64,
    ) -> None:
        self.spec = spec
        self.power = power if power is not None else LinkPowerModel()
        self._fused = fused
        self._interpret = interpret
        self._backend = backend
        self._block_packets = block_packets

    # ---------------------------------------------------------------- stages
    def encode(self, values: jax.Array) -> jax.Array:
        """The wire byte image of ``values`` under the encode stage."""
        return ENCODE_STAGES[self.spec.encode](values)

    def order(self, inputs: jax.Array) -> jax.Array:
        """Per-packet transmit permutation (derived from encoded inputs)."""
        s = self.spec
        return make_order(
            s.key,
            self.encode(inputs),
            lanes=s.input_lanes,
            width=s.width,
            k=s.k,
            descending=s.descending,
        )

    def _fusable(self, weights: jax.Array | None) -> bool:
        s = self.spec
        # a wire codec recodes the assembled stream AFTER packing, so its
        # BT cannot come out of the fused sort+pack+measure kernel; coded
        # specs take the staged path (the single-launch multi-codec hot
        # path is repro.kernels.bt_count_codecs)
        return (
            s.key in ("acc", "app")
            and s.pack in ("lane", "row")
            and s.codec == "none"
            and (weights is None or s.symmetric)
        )

    def _code_wire(
        self, stream: jax.Array
    ) -> tuple[jax.Array, Optional[jax.Array], jax.Array]:
        """Apply the spec's wire codec: (wire, invert lines, aux BT)."""
        # deferred import: repro.codec registers into repro.link on import
        from repro.codec.schemes import codec_by_name, invert_line_transitions

        coded = codec_by_name(self.spec.codec).encode(stream)
        return coded.wire, coded.invert, invert_line_transitions(coded.invert)

    # ------------------------------------------------------------- packet TX
    def run(
        self, inputs: jax.Array, weights: jax.Array | None = None
    ) -> TxResult:
        """Transmit P packets: returns permutation, wire stream and BT.

        ``inputs`` is (P, elems_per_packet); ``weights`` (optional) is
        (P, elems_per_packet) for the symmetric paired framing or
        (P, weight_elems_per_packet) for asymmetric links (framed unordered,
        see DESIGN.md §1).
        """
        s = self.spec
        if weights is not None:
            _validate_paired(inputs, weights, s)
        elif inputs.shape[-1] != s.elems_per_packet:
            raise ValueError(
                f"packet payload {inputs.shape[-1]} != "
                f"flits*input_lanes = {s.elems_per_packet}"
            )
        fused = self._fused if self._fused is not None else self._fusable(weights)
        if fused and not self._fusable(weights):
            raise ValueError(
                f"spec (key={s.key!r}, pack={s.pack!r}, codec={s.codec!r}, "
                f"symmetric={s.symmetric}) cannot run fused"
            )
        with _obs.span(
            "link.tx", path="fused" if fused else "staged", key=s.key,
            codec=s.codec, packets=int(inputs.shape[0]),
        ):
            xi = self.encode(inputs)
            wi = self.encode(weights) if weights is not None else None
            if fused:
                res = psu_stream(
                    xi,
                    wi,
                    width=s.width,
                    k=None if s.key == "acc" else s.k,
                    descending=s.descending,
                    input_lanes=s.input_lanes,
                    weight_lanes=s.weight_lanes if wi is not None else None,
                    pack=s.pack,
                    block_packets=self._block_packets,
                    interpret=self._interpret,
                    backend=self._backend,
                )
                return TxResult(
                    res.order, res.rank, res.stream, res.bt_input,
                    res.bt_weight, True,
                )
            with _obs.span("link.stage", stage="order"):
                order = make_order(
                    s.key, xi, lanes=s.input_lanes, width=s.width, k=s.k,
                    descending=s.descending,
                )
            with _obs.span("link.stage", stage="assemble"):
                stream = assemble_stream(xi, wi, s, order, s.pack)
            invert, bt_aux = None, jnp.int32(0)
            if s.codec != "none":
                with _obs.span("link.stage", stage="codec"):
                    stream, invert, bt_aux = self._code_wire(stream)
            with _obs.span("link.stage", stage="bt"):
                bt_i = bt_count(
                    stream[:, : s.input_lanes], interpret=self._interpret,
                    backend=self._backend,
                )
                if wi is not None and s.weight_lanes:
                    bt_w = bt_count(
                        stream[:, s.input_lanes :], interpret=self._interpret,
                        backend=self._backend,
                    )
                else:
                    bt_w = jnp.int32(0)
            return TxResult(
                order, None, stream, bt_i, bt_w, False, invert, bt_aux
            )

    def transmit(
        self, inputs: jax.Array, weights: jax.Array | None = None
    ) -> jax.Array:
        """The (T, lanes) uint8 wire image of the packets."""
        return self.run(inputs, weights).stream

    def measure(
        self,
        inputs: jax.Array,
        weights: jax.Array | None = None,
        name: str = "stream",
    ) -> LinkReport:
        """BT / energy report for transmitting the packets under this spec.

        Coded specs report their invert-line transitions and added wires,
        and the energy model charges both (``coded_link_energy_pj``) — the
        BT win is net of the codec's own overhead."""
        res = self.run(inputs, weights)
        num_flits, lanes = (int(d) for d in res.stream.shape)
        bt_i, bt_w = int(res.bt_input), int(res.bt_weight)
        aux, wires = int(res.bt_aux), self._extra_wires(lanes)
        energy = self.power.coded_link_energy_pj(
            bt_i + bt_w, aux, num_flits, 8 * lanes, wires
        )
        _obs.event(
            "link.report", name=name, bt_input=bt_i, bt_weight=bt_w,
            aux_bt=aux, num_flits=num_flits, energy_pj=energy,
        )
        return LinkReport(
            name,
            num_flits,
            bt_i,
            bt_w,
            fused=res.fused,
            energy_pj=energy,
            aux_bt=aux,
            extra_wires=wires,
        )

    def _extra_wires(self, lanes: int) -> int:
        """Invert lines the spec's codec adds beside ``lanes`` byte lanes.

        ``lanes`` is the ACTUAL width of the assembled stream — an
        input-only run of a paired spec codes only the input half, so the
        codec framing (and the wire/energy accounting) must follow the
        stream, not ``bytes_per_flit``."""
        if self.spec.codec == "none":
            return 0
        from repro.codec.schemes import codec_by_name

        return codec_by_name(self.spec.codec).extra_wires(lanes)

    # --------------------------------------------------------------- row TX
    def row_order(self, rows: jax.Array) -> jax.Array:
        """Transmit order of whole rows of an (R, B) byte matrix under this
        spec's key stage ('none' or 'row_bucket', DESIGN.md §3.3)."""
        s = self.spec
        if s.key == "none":
            return jnp.arange(rows.shape[0], dtype=jnp.int32)
        if s.key != "row_bucket":
            raise ValueError(
                f"row streams use key 'none' or 'row_bucket', got {s.key!r}"
            )
        return row_bucket_order(rows, s.k, width=s.width, descending=s.descending)

    def _row_wire(self, rows: jax.Array) -> tuple[jax.Array, jax.Array]:
        """(wire stream, aux BT) of an (R, B) byte-row stream."""
        enc = self.encode(rows)
        ordered = jnp.take(enc, self.row_order(enc), axis=0)
        stream = PACK_STAGES[self.spec.pack].stream(
            ordered, self.spec.bytes_per_flit
        ).astype(jnp.uint8)
        if self.spec.codec == "none":
            return stream, jnp.int32(0)
        wire, _, bt_aux = self._code_wire(stream)
        return wire, bt_aux

    def transmit_rows(self, rows: jax.Array) -> jax.Array:
        """Wire image of an (R, B) byte-row stream (weight matrix traffic,
        DESIGN.md §3.3): encode, order whole rows by popcount bucket, lay
        out with the pack stage ('row' = HBM-natural, 'col' = interleaved),
        then apply the wire codec (if any)."""
        return self._row_wire(rows)[0]

    def measure_rows(self, rows: jax.Array, name: str = "rows") -> LinkReport:
        """BT / energy report for streaming ``rows`` under this spec."""
        stream, bt_aux = self._row_wire(rows)
        aux = int(bt_aux)
        bt = int(
            bt_count(stream, interpret=self._interpret, backend=self._backend)
        )
        num_flits, lanes = (int(d) for d in stream.shape)
        wires = self._extra_wires(lanes)
        energy = self.power.coded_link_energy_pj(
            bt, aux, num_flits, 8 * lanes, wires
        )
        _obs.event(
            "link.report", name=name, bt_input=bt, bt_weight=0,
            aux_bt=aux, num_flits=num_flits, energy_pj=energy,
        )
        return LinkReport(
            name,
            num_flits,
            bt,
            0,
            fused=False,
            energy_pj=energy,
            aux_bt=aux,
            extra_wires=wires,
        )
