"""Dry-run case construction: abstract inputs (ShapeDtypeStruct — no
allocation) + shardings + the function to lower, per (arch x shape).

``train`` lowers the full train_step (fwd + bwd + AdamW update, donated
buffers); ``prefill`` lowers prompt processing returning (logits, cache);
``decode`` lowers one serve_step against a seq_len KV/SSM cache.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.configs.shapes import SHAPES, ShapeSpec
from repro.models import decode_step, init_cache, param_shapes, prefill
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig
from repro.optim import init as opt_init
from repro.train import make_train_step

from .mesh import dp_axes
from .sharding import (
    batch_shardings,
    cache_shardings,
    opt_shardings,
    params_shardings,
    replicated,
)

ENC_FRAMES = 1500  # whisper stub frontend length (DESIGN.md §4)

# Gradient-accumulation microbatches per train cell: chosen so the scanned
# (production) lowering's peak bytes/device fits 16 GiB v5e (§Dry-run).
TRAIN_MICROBATCHES = {
    "gemma-7b": 4,
    "codeqwen1.5-7b": 4,
    "internvl2-26b": 8,
    "qwen3-moe-30b-a3b": 4,
}
DEFAULT_TRAIN_MICROBATCHES = 2

# Optimized per-cell profiles from the §Perf hillclimb + capacity-fix passes
# (EXPERIMENTS.md §Perf): (cfg_overrides, mesh_shape | None, microbatches |
# None).  Select with ``repro.launch.dryrun --profile optimized`` or
# ``OPTIMIZED_PROFILES[(arch, shape)]``.
_SCAN_ATTN = {"attn_impl": "chunked", "attn_chunk": 4096}
OPTIMIZED_PROFILES: dict[tuple[str, str], tuple[dict, tuple | None, int | None]] = {
    ("mamba2-370m", "train_4k"): ({"pure_dp": True}, None, None),  # A3 base
    ("codeqwen1.5-7b", "prefill_32k"): (dict(_SCAN_ATTN), (32, 8), None),  # B5
    ("internlm2-1.8b", "train_4k"): (
        {"remat_policy": "save_block_io", "zero1": True}, (128, 2), None),  # C6
    ("granite-moe-3b-a800m", "train_4k"): ({"zero1": True}, (32, 8), 4),
    ("granite-moe-3b-a800m", "prefill_32k"): (dict(_SCAN_ATTN), (32, 8), None),
    ("internvl2-26b", "train_4k"): ({"fsdp": False, "zero1": True}, None, None),
    ("internvl2-26b", "prefill_32k"): (dict(_SCAN_ATTN), None, None),
    ("qwen3-moe-30b-a3b", "train_4k"): ({}, None, 8),
    ("qwen3-moe-30b-a3b", "prefill_32k"): (dict(_SCAN_ATTN), None, None),
    ("whisper-medium", "train_4k"): ({"logits_chunk": 512}, None, 4),
    ("qwen3-4b", "prefill_32k"): (dict(_SCAN_ATTN), None, None),
    ("zamba2-1.2b", "prefill_32k"): (dict(_SCAN_ATTN), None, None),
}


def _sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


@dataclasses.dataclass
class DryrunCase:
    arch: str
    shape: ShapeSpec
    cfg: ModelConfig
    kind: str
    fn: Callable
    args: tuple  # abstract arg pytrees
    donate: tuple[int, ...]

    def shardings(self, mesh) -> tuple[Any, Any]:
        """(in_shardings, out_shardings) matching ``self.args`` / outputs."""
        cfg = self.cfg
        p_shapes = self.args[0]
        mode = "train" if self.kind == "train" else "serve"
        p_sh = params_shardings(cfg, mesh, p_shapes, mode=mode)
        if self.kind == "train":
            o_sh = opt_shardings(cfg, mesh, self.args[1], p_shapes)
            b_sh = batch_shardings(cfg, mesh, self.args[2])
            metrics_sh = {k: replicated(mesh) for k in ("loss", "grad_norm", "lr")}
            return (p_sh, o_sh, b_sh), (p_sh, o_sh, metrics_sh)
        if self.kind == "prefill":
            b_sh = batch_shardings(cfg, mesh, self.args[1])
            return (p_sh, b_sh), None  # cache/logits shardings: GSPMD-chosen
        # decode
        c_sh = cache_shardings(cfg, mesh, self.args[1])
        t_sh = batch_shardings(cfg, mesh, {"tokens": self.args[2]})["tokens"]
        dp = dp_axes(mesh)
        b, v = self.args[2].shape[0], cfg.vocab
        dpn = 1
        for a in dp:
            dpn *= mesh.shape[a]
        lspec = P(dp if b % dpn == 0 else None, None,
                  "model" if v % mesh.shape["model"] == 0 else None)
        return (p_sh, c_sh, t_sh), (NamedSharding(mesh, lspec), c_sh)


def build_case(arch: str, shape_name: str, **cfg_overrides) -> DryrunCase:
    # Dry-run default: UNROLL layer scans.  XLA's HloCostAnalysis visits
    # while-loop bodies once, so scanned lowerings under-report FLOPs/bytes
    # by ~n_layers; unrolled lowerings make cost_analysis exact (the
    # runnable-production config keeps scan_layers=True).
    cfg_overrides.setdefault("scan_layers", False)
    shape = SHAPES[shape_name]
    if shape.kind != "train":
        # serving stores weights in bf16 (production inference precision);
        # fp32 masters exist only in the training job
        cfg_overrides.setdefault("param_dtype", "bfloat16")
    cfg = get_config(arch, **cfg_overrides)
    p_shapes = param_shapes(cfg)
    s, gb = shape.seq_len, shape.global_batch
    fam = cfg.family

    if shape.kind == "train":
        opt_shapes = jax.eval_shape(opt_init, p_shapes)
        batch: dict[str, jax.ShapeDtypeStruct] = {}
        if fam in ("encdec", "audio"):
            batch["frames"] = _sds((gb, ENC_FRAMES, cfg.d_model), cfg.dtype)
            batch["tokens"] = _sds((gb, s), jnp.int32)
            batch["labels"] = _sds((gb, s), jnp.int32)
        elif fam == "vlm":
            nf = cfg.n_frontend_tokens
            batch["patches"] = _sds((gb, nf, cfg.d_model), cfg.dtype)
            batch["tokens"] = _sds((gb, s - nf), jnp.int32)
            batch["labels"] = _sds((gb, s), jnp.int32)
        else:
            batch["tokens"] = _sds((gb, s), jnp.int32)
            batch["labels"] = _sds((gb, s), jnp.int32)
        mb = TRAIN_MICROBATCHES.get(arch, DEFAULT_TRAIN_MICROBATCHES)
        step_fn = make_train_step(cfg, AdamWConfig(total_steps=10_000), microbatches=mb)
        return DryrunCase(
            arch, shape, cfg, "train",
            lambda p, o, b: step_fn(p, o, b),
            (p_shapes, opt_shapes, batch),
            donate=(0, 1),
        )

    if shape.kind == "prefill":
        batch: dict[str, jax.ShapeDtypeStruct] = {}
        if fam in ("encdec", "audio"):
            batch["frames"] = _sds((gb, ENC_FRAMES, cfg.d_model), cfg.dtype)
            batch["tokens"] = _sds((gb, s), jnp.int32)
            fn = lambda p, b: prefill(p, cfg, b["tokens"], s, frames=b["frames"])
        elif fam == "vlm":
            nf = cfg.n_frontend_tokens
            batch["patches"] = _sds((gb, nf, cfg.d_model), cfg.dtype)
            batch["tokens"] = _sds((gb, s - nf), jnp.int32)
            fn = lambda p, b: prefill(
                p, cfg, b["tokens"], s, inputs_embeds=b["patches"]
            )
        else:
            batch["tokens"] = _sds((gb, s), jnp.int32)
            fn = lambda p, b: prefill(p, cfg, b["tokens"], s)
        return DryrunCase(arch, shape, cfg, "prefill", fn, (p_shapes, batch), donate=())

    # decode: one new token against a seq_len cache
    enc_len = ENC_FRAMES if fam in ("encdec", "audio") else 0
    cache_shapes = jax.eval_shape(lambda: init_cache(cfg, gb, s, enc_len=enc_len))
    tokens = _sds((gb, 1), jnp.int32)
    fn = lambda p, c, t: decode_step(p, cfg, c, t)
    return DryrunCase(
        arch, shape, cfg, "decode", fn, (p_shapes, cache_shapes, tokens), donate=(1,)
    )
