"""GPipe-style pipeline parallelism over a "pipe" mesh axis.

Completes the parallelism menu (DP/TP/EP/SP/FSDP/ZeRO-1 + PP).  For the
assigned model sizes TP x DP always fits (DESIGN.md §5), so PP ships as a
first-class *option* rather than a default: stages hold contiguous layer
blocks, microbatches stream through ``lax.ppermute`` inside ``shard_map``,
and jax AD differentiates through the permutes (reverse schedule) for
training.

Schedule: plain GPipe fill-drain — T = n_micro + stages - 1 ticks; at tick t
stage s processes microbatch (t - s).  Bubble fraction = (S-1)/(T), the
standard GPipe trade-off; activations for AD are kept per tick (GPipe
re-materialisation would wrap ``stage_fn`` in jax.checkpoint, composable via
cfg.remat).

Numerical equivalence with the unpipelined stack is tested on a 4-device
mesh in tests/test_pipeline.py.
"""

from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map

Carry = jax.Array


def pipeline_apply(
    stage_fn: Callable[[dict, jax.Array], jax.Array],
    stage_params: dict,  # leaves stacked (n_stages, ...) — one slice/stage
    x_micro: jax.Array,  # (n_micro, mb, ...) microbatched input
    mesh: Mesh,
    axis: str = "pipe",
) -> jax.Array:
    """Run ``stage_fn`` as a pipeline over ``mesh[axis]``.

    Returns the stage-(S-1) outputs re-assembled as (n_micro, mb, ...).
    """
    n_stages = mesh.shape[axis]
    n_micro = x_micro.shape[0]
    ticks = n_micro + n_stages - 1

    def per_device(params_local, x_local):
        # params_local: this stage's slice (leading stage axis of size 1)
        params_me = jax.tree.map(lambda p: p[0], params_local)
        # x_local: full microbatch stream only meaningful on stage 0
        # (shard_map replicates it; non-zero stages ignore their copy)
        sid = lax.axis_index(axis)
        zero = jnp.zeros_like(x_local[0])
        fwd = [(i, i + 1) for i in range(n_stages - 1)]

        carry = zero
        outs = []
        for t in range(ticks):
            inject = x_local[t] if t < n_micro else zero
            h_in = jnp.where(sid == 0, inject, carry)
            h_out = stage_fn(params_me, h_in)
            # keep the last-stage output for microbatch (t - (S-1))
            if t >= n_stages - 1:
                outs.append(h_out)
            carry = lax.ppermute(h_out, axis, fwd)
        # (n_micro, mb, ...) valid on the LAST stage; broadcast via ppermute
        # ring so every device returns the same tensor (replicated out-spec)
        result = jnp.stack(outs)
        last = n_stages - 1
        # bring last stage's result to all: sum of masked psum
        mine = jnp.where(sid == last, result, jnp.zeros_like(result))
        return lax.psum(mine, axis)

    # check_rep=False: the activation-tagging primitive (checkpoint_name)
    # has no replication rule in some jax versions; replication of the
    # output is guaranteed by the masked-psum broadcast above.
    kwargs = dict(mesh=mesh, in_specs=(P(axis), P()), out_specs=P())
    try:
        fn = shard_map(per_device, check_rep=False, **kwargs)
    except TypeError:  # newer jax renamed/removed check_rep
        fn = shard_map(per_device, **kwargs)
    return fn(stage_params, x_micro)


def stack_stages(layer_params: dict, n_stages: int) -> dict:
    """Reshape (L, ...) layer-stacked params into (n_stages, L/n_stages, ...)."""
    def r(x):
        l = x.shape[0]
        if l % n_stages:
            raise ValueError(f"{l} layers not divisible by {n_stages} stages")
        return x.reshape(n_stages, l // n_stages, *x.shape[1:])

    return jax.tree.map(r, layer_params)


def make_pipe_mesh(n_stages: int) -> Mesh:
    import numpy as np

    return Mesh(np.array(jax.devices()[:n_stages]), ("pipe",))
