# Launch layer: production mesh, sharding rules, dry-run specs.
# NOTE: repro.launch.dryrun must be imported/run as the entry point BEFORE
# other jax use (it sets the 512-device XLA flag); import it lazily.
from .mesh import axis_size, dp_axes, make_production_mesh, make_smoke_mesh
from .sharding import (
    batch_shardings,
    cache_shardings,
    opt_shardings,
    param_spec,
    params_shardings,
)
from .specs import DryrunCase, build_case

__all__ = [
    "make_production_mesh",
    "make_smoke_mesh",
    "dp_axes",
    "axis_size",
    "param_spec",
    "params_shardings",
    "opt_shardings",
    "batch_shardings",
    "cache_shardings",
    "build_case",
    "DryrunCase",
]
