"""Sharding rules: DP / TP / EP / SP assignment per parameter and input.

Strategy (DESIGN.md §5):
  * batch           -> ("pod","data")  [DP; falls back to sequence (SP) when
                       the batch doesn't divide, e.g. long_500k's batch=1]
  * attention heads -> "model" (TP); GQA archs whose kv-head count doesn't
                       divide the axis shard the contraction (d_model) side
  * d_ff            -> "model" (Megatron column->row pair: one all-reduce)
  * experts         -> "model" (EP; granite pads 40 -> 48 experts)
  * vocab           -> "model" when divisible, else embedding d-axis
  * SSD blocks      -> contraction sharding on in/out projections; SSM head
                       axis of activations/caches on "model"

Every rule guards divisibility and falls back to replication, so every
(arch x shape x mesh) cell is *legal by construction*; the roofline then
shows what the fallbacks cost.
"""

from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig

from .mesh import dp_axes


def _div(n: int, m: int) -> bool:
    return m > 0 and n % m == 0


def _pad_rank(spec: tuple, rank: int) -> P:
    """Left-pad a trailing-dims spec with None up to the leaf rank (covers
    the layer-stack leading axis)."""
    pad = rank - len(spec)
    return P(*((None,) * pad + spec))


def _norm_path(path: str) -> str:
    """Normalize keystr paths: "['layers']['attn']['wq']" -> ".layers.attn.wq"."""
    return (
        path.replace("['", ".").replace("']", "").replace("[", ".").replace("]", "")
    )


def param_spec(
    path: str, shape: tuple[int, ...], cfg: ModelConfig, mesh, mode: str = "train"
) -> P:
    m = mesh.shape["model"]
    path = _norm_path(path)
    name = path.rsplit(".", 1)[-1]
    rank = len(shape)
    if cfg.pure_dp:
        return P()  # replicate everything; batch shards over all axes
    if cfg.fsdp and mode == "train":
        return _fsdp_spec(path, name, shape, cfg, mesh)

    if name == "embed":
        v, d = shape
        if _div(v, m):
            return P("model", None)
        if _div(d, m):
            return P(None, "model")
        return P()
    if name == "head":
        d, v = shape
        if _div(v, m):
            return P(None, "model")
        if _div(d, m):
            return P("model", None)
        return P()

    if ".attn" in path or ".cross_attn" in path:
        if name == "wq":
            d, h, hd = shape[-3:]
            if _div(h, m):
                return _pad_rank((None, "model", None), rank)
            if _div(d, m):
                return _pad_rank(("model", None, None), rank)
            return P()
        if name in ("wk", "wv"):
            d, hkv, hd = shape[-3:]
            if _div(hkv, m):
                return _pad_rank((None, "model", None), rank)
            # GQA with kv-heads < TP degree: REPLICATE the (small) kv
            # projections — d-contraction sharding costs an all-gather-heavy
            # backward (measured: +155 GB/device collectives on internlm2
            # train_4k; see EXPERIMENTS.md §Perf)
            return P()
        if name == "wo":
            h, hd, d = shape[-3:]
            if _div(h, m):
                return _pad_rank(("model", None, None), rank)
            if _div(d, m):
                return _pad_rank((None, None, "model"), rank)
            return P()
        return P()  # q_norm / k_norm / biases

    if ".moe" in path:
        if name == "router":
            d, e = shape[-2:]
            return _pad_rank((None, "model"), rank) if _div(e, m) else P()
        if name in ("gate", "up", "down"):
            e = shape[-3]
            if _div(e, m):
                return _pad_rank(("model", None, None), rank)
            ff_axis = -1 if name in ("gate", "up") else -2
            if _div(shape[ff_axis], m):
                spec = [None, None, None]
                spec[ff_axis] = "model"
                return _pad_rank(tuple(spec), rank)
            return P()
        if name.startswith("shared_"):
            ff_axis = -1 if name in ("shared_gate", "shared_up") else -2
            spec = [None, None]
            if _div(shape[ff_axis], m):
                spec[ff_axis] = "model"
            return _pad_rank(tuple(spec), rank)
        return P()

    if ".mlp" in path:
        if name in ("gate", "up"):
            d, ff = shape[-2:]
            return _pad_rank((None, "model"), rank) if _div(ff, m) else P()
        if name == "down":
            ff, d = shape[-2:]
            return _pad_rank(("model", None), rank) if _div(ff, m) else P()
        return P()

    if ".ssd" in path:
        if name == "in_proj":  # contraction (d_model) sharding
            d = shape[-2]
            return _pad_rank(("model", None), rank) if _div(d, m) else P()
        if name == "out_proj":  # contraction (d_inner) sharding
            di = shape[-2]
            return _pad_rank(("model", None), rank) if _div(di, m) else P()
        return P()  # conv / dt / a_log / norms: small, replicated

    return P()  # norms and anything unmatched: replicated


def _fsdp_spec(path: str, name: str, shape: tuple[int, ...], cfg, mesh) -> P:
    """ZeRO-3-style 2D sharding: "model" on the TP axis as usual, plus the
    largest remaining axis sharded over "data".  GSPMD all-gathers weights
    at use (per layer inside the scan) and reduce-scatters gradients — the
    standard FSDP dataflow, required where fp32 params + Adam exceed HBM."""
    m = mesh.shape["model"]
    d = mesh.shape["data"]
    rank = len(shape)

    def pick(tp_axis: int | None) -> P:
        spec: list = [None] * rank
        if tp_axis is not None:
            spec[tp_axis] = "model"
        # largest un-taken axis divisible by the data-axis size
        best, best_size = None, 0
        for i, s in enumerate(shape):
            if i == tp_axis:
                continue
            if _div(s, d) and s > best_size:
                best, best_size = i, s
        if best is not None:
            spec[best] = "data"
        return P(*spec)

    if name == "embed":
        return pick(0 if _div(shape[0], m) else (1 if _div(shape[1], m) else None))
    if name == "head":
        return pick(1 if _div(shape[1], m) else None)
    if name in ("wq",):
        h = shape[-2]
        return pick(rank - 2 if _div(h, m) else None)
    if name in ("wk", "wv"):
        hkv = shape[-2]
        return pick(rank - 2 if _div(hkv, m) else None)
    if name == "wo":
        h = shape[-3]
        return pick(rank - 3 if _div(h, m) else None)
    if name in ("gate", "up", "down") and ".moe" in path:
        e = shape[-3]
        return pick(rank - 3 if _div(e, m) else None)
    if name == "router":
        return pick(rank - 1 if _div(shape[-1], m) else None)
    if name in ("gate", "up") and ".mlp" in path:
        return pick(rank - 1 if _div(shape[-1], m) else None)
    if name == "down" and ".mlp" in path:
        return pick(rank - 2 if _div(shape[-2], m) else None)
    if name in ("in_proj", "out_proj"):
        return pick(rank - 2 if _div(shape[-2], m) else None)
    # small leaves (norms, biases): replicate
    return P()


def params_shardings(cfg: ModelConfig, mesh, params_shapes: Any, mode: str = "train") -> Any:
    def leaf(path, x):
        spec = param_spec(jax.tree_util.keystr(path), x.shape, cfg, mesh, mode)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(leaf, params_shapes)


def opt_shardings(cfg: ModelConfig, mesh, opt_shapes: Any, params_shapes: Any) -> Any:
    """Optimizer m/v mirror the parameter shardings; step is replicated.

    With ``cfg.zero1`` the m/v leaves are additionally sharded over the
    "data" axis (largest free divisible dim) — ZeRO-1: the optimizer state
    is never replicated across data-parallel ranks; GSPMD reshards grads in
    and all-gathers updated params out.
    """
    p_sh = params_shardings(cfg, mesh, params_shapes)
    if not cfg.zero1:
        return type(opt_shapes)(
            step=NamedSharding(mesh, P()),
            m=p_sh,
            v=jax.tree.map(lambda s: s, p_sh),
        )
    d = mesh.shape["data"]

    def add_data_axis(sh: NamedSharding, shape_leaf) -> NamedSharding:
        spec = list(sh.spec) + [None] * (len(shape_leaf.shape) - len(sh.spec))
        for i, s in enumerate(shape_leaf.shape):
            if spec[i] is None and _div(s, d):
                spec[i] = "data"
                break
        return NamedSharding(mesh, P(*spec))

    mv_sh = jax.tree.map(add_data_axis, p_sh, params_shapes)
    return type(opt_shapes)(
        step=NamedSharding(mesh, P()),
        m=mv_sh,
        v=jax.tree.map(lambda s: s, mv_sh),
    )


# --------------------------------------------------------------------------
# input / cache shardings
# --------------------------------------------------------------------------


def batch_shardings(cfg: ModelConfig, mesh, batch_shapes: dict) -> dict:
    dp = dp_axes(mesh)
    if cfg.pure_dp:
        dp = tuple(mesh.axis_names)  # batch over every axis incl. "model"
    dpn = int(np.prod([mesh.shape[a] for a in dp]))

    def leaf(x):
        b = x.shape[0]
        spec = [None] * len(x.shape)
        if _div(b, dpn):
            spec[0] = dp
        elif len(x.shape) > 1 and _div(x.shape[1], dpn):
            spec[1] = dp  # SP fallback: shard sequence
        return NamedSharding(mesh, P(*spec))

    return {k: leaf(v) for k, v in batch_shapes.items()}


def cache_shardings(cfg: ModelConfig, mesh, cache_shapes: dict) -> dict:
    """KV/SSM cache shardings for decode cells.

    k/v: (L, B, S, Hkv, hd)  -> B over DP (or S when B=1: SP), Hkv over model
    ssm state: (L, B, H, N, P) -> B over DP, H over model
    conv: (L, B, K, C) -> B over DP, C over model
    """
    dp = dp_axes(mesh)
    if cfg.pure_dp:
        dp = tuple(mesh.axis_names)
    dpn = int(np.prod([mesh.shape[a] for a in dp]))
    m = mesh.shape["model"]

    model_free = not cfg.pure_dp  # pure_dp spends "model" on the batch axis

    def kv(x):
        l, b, s, hkv, hd = x.shape
        spec: list = [None] * 5
        if _div(b, dpn):
            spec[1] = dp
        elif _div(s, dpn):
            spec[2] = dp
        if model_free and _div(hkv, m):
            spec[3] = "model"
        elif model_free and spec[2] is None and _div(s, m):
            # GQA archs with kv-heads < model axis: shard the KV sequence
            # instead (flash-decoding-style split-K) — removes both the
            # replicated-cache memory and the redundant attention compute
            spec[2] = "model"
        return NamedSharding(mesh, P(*spec))

    def ssm_state(x):
        l, b, h, n, p = x.shape
        spec: list = [None] * 5
        if _div(b, dpn):
            spec[1] = dp
        if model_free and _div(h, m):
            spec[2] = "model"
        return NamedSharding(mesh, P(*spec))

    def conv(x):
        l, b, k, c = x.shape
        spec: list = [None] * 4
        if _div(b, dpn):
            spec[1] = dp
        if model_free and _div(c, m):
            spec[3] = "model"
        return NamedSharding(mesh, P(*spec))

    out: dict = {}
    for key, val in cache_shapes.items():
        if key == "pos":
            out[key] = NamedSharding(mesh, P())
        elif key in ("k", "v", "cross_k", "cross_v"):
            out[key] = kv(val)
        elif key in ("ssm", "ssm_trailing"):
            out[key] = {"state": ssm_state(val["state"]), "conv": conv(val["conv"])}
        else:
            out[key] = NamedSharding(mesh, P())
    return out


def replicated(mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
