import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
).strip()

"""Multi-pod dry-run driver (deliverable e).

Lowers + compiles every (architecture x input-shape) cell against the
production mesh — 16x16 single pod and 2x16x16 multi-pod — and records
memory_analysis / cost_analysis / the collective schedule for the roofline
(EXPERIMENTS.md §Dry-run, §Roofline).

The XLA_FLAGS line above MUST precede any jax import (device count locks on
first init); it lives only here, so smoke tests and benches see 1 device.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch internlm2-1.8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --out experiments/dryrun
  PYTHONPATH=src python -m repro.launch.dryrun --all --multipod
"""

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402


def _compile_case(case, mesh):
    in_sh, out_sh = case.shardings(mesh)
    t0 = time.monotonic()
    with mesh:
        jitted = jax.jit(
            case.fn,
            in_shardings=in_sh,
            out_shardings=out_sh,
            donate_argnums=case.donate,
        )
        lowered = jitted.lower(*case.args)
        t_lower = time.monotonic() - t0
        compiled = lowered.compile()
        t_compile = time.monotonic() - t0 - t_lower
    return compiled, round(t_lower, 2), round(t_compile, 2)


def _extrapolated_cost(arch: str, shape_name: str, mesh, mesh_desc: str,
                       cfg_overrides: dict | None = None) -> dict:
    """Exact-cost pass via layer-count extrapolation.

    Full-depth unrolled compiles are exact but slow (48-layer MoE > 10 min
    on this host).  Per-layer cost is *structurally linear* in depth for
    homogeneous stacks (every layer lowers to identical HLO), so compile two
    shallow unrolled variants (a, b layers) and extrapolate scalars to the
    real depth: cost(L) = cost(a) + (cost(b) - cost(a)) / (b - a) * (L - a).
    Validated against a full 24-layer unrolled compile (internlm2 train_4k):
    collective bytes match EXACTLY (structural), flops within ~6 % (small
    per-layer fusion nonlinearity amplified by the lever arm; see
    EXPERIMENTS.md §Dry-run).  Hybrid archs extrapolate in shared-attention
    *groups*; encdec varies encoder+decoder depth jointly.
    """
    from repro.configs import get_config
    from repro.launch.specs import build_case
    from repro.roofline.collect import collect_from_compiled

    cfg_overrides = dict(cfg_overrides or {})
    cfg_full = get_config(arch)
    fam = cfg_full.family
    if fam == "hybrid":
        per = cfg_full.shared_attn_every
        trail = cfg_full.n_layers % per
        la, lb = per + trail, 2 * per + trail
        steps_full = (cfg_full.n_layers - trail) // per
        steps_a, steps_b = 1, 2
    elif fam in ("encdec", "audio"):
        la, lb = 2, 4
        steps_full, steps_a, steps_b = cfg_full.n_layers, la, lb
    else:
        la, lb = 2, 4
        steps_full, steps_a, steps_b = cfg_full.n_layers, la, lb

    recs = []
    for l in (la, lb):
        over = {**cfg_overrides, "n_layers": l, "scan_layers": False}
        if fam in ("encdec", "audio"):
            over["n_enc_layers"] = l
        case = build_case(arch, shape_name, **over)
        compiled, _, t_c = _compile_case(case, mesh)
        recs.append(collect_from_compiled(
            arch=arch, shape=shape_name, kind=case.kind, mesh_desc=mesh_desc,
            num_devices=mesh.size, compiled=compiled, cfg=case.cfg,
        ))

    def lerp(key: str) -> float:
        va, vb = recs[0][key], recs[1][key]
        return va + (vb - va) / (steps_b - steps_a) * (steps_full - steps_a)

    colls: dict[str, dict] = {}
    for kind in set(recs[0]["collectives"]) | set(recs[1]["collectives"]):
        ca = recs[0]["collectives"].get(kind, {"count": 0, "bytes": 0})
        cb = recs[1]["collectives"].get(kind, {"count": 0, "bytes": 0})
        scale = (steps_full - steps_a) / (steps_b - steps_a)
        colls[kind] = {
            "count": round(ca["count"] + (cb["count"] - ca["count"]) * scale),
            "bytes": round(ca["bytes"] + (cb["bytes"] - ca["bytes"]) * scale),
        }
    return {
        "hlo_flops_per_device": lerp("hlo_flops_per_device"),
        "hlo_bytes_per_device": lerp("hlo_bytes_per_device"),
        "wire_bytes_per_device": lerp("wire_bytes_per_device"),
        "collectives": colls,
        "cost_source": f"unrolled-extrapolated(L={la},{lb})",
    }


def run_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    verbose: bool = True,
    with_cost_pass: bool = True,
    cfg_overrides: dict | None = None,
    mesh_shape: tuple[int, int] | None = None,  # logical remesh of the pod
) -> dict:
    """Dual-pass dry-run for one cell.

    Pass 1 (always): the PRODUCTION lowering (scan-over-layers) — proves the
    cell lowers+compiles on the mesh and gives the real memory_analysis.
    Pass 2 (single-pod roofline cells): an UNROLLED lowering whose
    cost_analysis / collective schedule is exact — XLA's HloCostAnalysis
    visits while bodies once, so scanned numbers under-report by ~n_layers
    (measured; see EXPERIMENTS.md §Dry-run).  Its memory_analysis is a
    scheduler artifact (remat ordering is not enforced without the loop) and
    is recorded but NOT used.
    """
    from repro.configs import arch_shapes
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import build_case
    from repro.roofline.collect import collect_from_compiled

    if shape_name not in arch_shapes(arch):
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "mesh": "2x16x16" if multi_pod else "16x16",
                "reason": "long_500k skipped for full-attention archs (DESIGN.md §4)"}

    if mesh_shape is not None:
        # §Perf lever: a pod's 256 chips re-viewed as (data, model) with a
        # different aspect ratio — TP all-reduce payload scales with the
        # per-device batch, so fatter data axes shrink the collective term
        mesh = jax.make_mesh(mesh_shape, ("data", "model"))
        mesh_desc = f"{mesh_shape[0]}x{mesh_shape[1]}"
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
        mesh_desc = "2x16x16" if multi_pod else "16x16"

    # pass 1: production (scanned) lowering
    case = build_case(arch, shape_name, scan_layers=True, **(cfg_overrides or {}))
    compiled_scan, t_lower1, t_compile1 = _compile_case(case, mesh)
    mem = compiled_scan.memory_analysis()
    mem_rec = {a: int(getattr(mem, a)) for a in (
        "argument_size_in_bytes", "output_size_in_bytes",
        "temp_size_in_bytes", "alias_size_in_bytes") if getattr(mem, a, None) is not None}

    rec = {
        "arch": arch, "shape": shape_name, "kind": case.kind,
        "mesh": mesh_desc, "num_devices": mesh.size, "status": "ok",
        "memory": mem_rec,
        "lower_sec": t_lower1, "compile_sec": t_compile1,
        "params": int(case.cfg.param_count()),
        "active_params": int(case.cfg.active_param_count()),
    }
    peak = mem_rec.get("argument_size_in_bytes", 0) - mem_rec.get(
        "alias_size_in_bytes", 0
    ) + mem_rec.get("temp_size_in_bytes", 0) + mem_rec.get("output_size_in_bytes", 0)
    rec["peak_bytes_per_device"] = int(peak)
    # CPU-backend artifact: XLA:CPU materialises f32 copies of bf16 weight
    # stacks / caches (no native bf16); subtract for the TPU-target estimate
    from repro.roofline.collect import cpu_bf16_upcast_bytes

    upcast = cpu_bf16_upcast_bytes(compiled_scan.as_text())
    rec["cpu_bf16_upcast_bytes"] = int(upcast)
    rec["tpu_peak_bytes_per_device"] = int(peak - upcast)

    if with_cost_pass:
        t0 = time.monotonic()
        rec.update(_extrapolated_cost(arch, shape_name, mesh, mesh_desc,
                                      cfg_overrides))
        rec["cost_pass_sec"] = round(time.monotonic() - t0, 2)

    if verbose:
        print(f"--- {arch} x {shape_name} [{mesh_desc}] {rec['kind']}")
        print(f"    memory_analysis (production lowering): {mem_rec}")
        tp = rec["tpu_peak_bytes_per_device"]
        print(f"    peak bytes/device ~ {peak/2**30:.2f} GiB raw; "
              f"{tp/2**30:.2f} GiB TPU-adjusted (cpu bf16-upcast artifact "
              f"{upcast/2**30:.2f} GiB) -> "
              f"{'FITS' if tp < 16*2**30 else 'OVER'} 16 GiB v5e")
        if with_cost_pass:
            print(f"    cost_analysis ({rec['cost_source']}): flops/device="
                  f"{rec['hlo_flops_per_device']:.3e} bytes/device="
                  f"{rec['hlo_bytes_per_device']:.3e}")
            print(f"    collectives: {rec['collectives']}")
            print(f"    wire bytes/device: {rec['wire_bytes_per_device']:.3e}")
        print(f"    compile: scan {t_compile1:.1f}s"
              + (f" cost-pass {rec['cost_pass_sec']:.1f}s" if with_cost_pass else ""))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true", help="run every assigned cell")
    ap.add_argument("--multipod", action="store_true", help="2x16x16 mesh")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None, help="directory for per-cell JSON records")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--profile", choices=["baseline", "optimized"],
                    default="baseline",
                    help="optimized = §Perf hillclimb/capacity-fix configs")
    args = ap.parse_args()

    from repro.configs import all_cells

    if args.all:
        cells = all_cells()
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape)]

    meshes = [args.multipod] if not args.both_meshes else [False, True]
    failures = 0
    for multi in meshes:
        for arch, shape in cells:
            tag = f"{arch}__{shape}__{'2x16x16' if multi else '16x16'}"
            out_path = os.path.join(args.out, tag + ".json") if args.out else None
            if out_path and args.skip_existing and os.path.exists(out_path):
                print(f"skip existing {tag}")
                continue
            try:
                over, mesh_shape, mb = None, None, None
                if args.profile == "optimized":
                    import repro.launch.specs as specs
                    over, mesh_shape, mb = specs.OPTIMIZED_PROFILES.get(
                        (arch, shape), ({}, None, None))
                    if mb:
                        specs.TRAIN_MICROBATCHES[arch] = mb
                    if multi:
                        mesh_shape = None  # remeshes are single-pod profiles
                # roofline cost pass on the single-pod mesh only (the
                # roofline table is single-pod per the assignment; multi-pod
                # proves the "pod" axis shards)
                rec = run_cell(arch, shape, multi, with_cost_pass=not multi,
                               cfg_overrides=over, mesh_shape=mesh_shape)
            except Exception as e:  # a failing cell is a bug: record + count
                traceback.print_exc()
                rec = {"arch": arch, "shape": shape,
                       "mesh": "2x16x16" if multi else "16x16",
                       "status": "failed", "error": f"{type(e).__name__}: {e}"}
                failures += 1
            if out_path:
                os.makedirs(args.out, exist_ok=True)
                with open(out_path, "w") as f:
                    json.dump(rec, f, indent=1)
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
