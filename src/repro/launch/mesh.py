"""Production mesh construction.

Defined as FUNCTIONS (no module-level device access) so importing this
module never initialises jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any import,
smoke tests see the real single device.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 = 256-chip pod ("data", "model"); multi_pod adds a leading
    2-wide "pod" axis (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh(devices: int | None = None) -> jax.sharding.Mesh:
    """Degenerate mesh over whatever devices exist (CPU tests)."""
    n = devices or len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"))


def dp_axes(mesh: jax.sharding.Mesh) -> tuple[str, ...]:
    """Data-parallel axes: ("pod", "data") when the pod axis exists."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def axis_size(mesh: jax.sharding.Mesh, *names: str) -> int:
    out = 1
    for n in names:
        if n in mesh.axis_names:
            out *= mesh.shape[n]
    return out
