"""Model configuration for the architecture zoo.

One ``ModelConfig`` covers every assigned family: dense GQA transformers,
MoE, Mamba2/SSD, hybrid (SSM + shared attention), encoder-decoder (whisper)
and VLM backbones (frontends are stubs per the assignment: ``input_specs``
feeds precomputed frame/patch embeddings).
"""

from __future__ import annotations

import dataclasses
from typing import Literal, Optional

Act = Literal["swiglu", "geglu", "gelu"]
BlockKind = Literal["attn", "ssm"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    capacity_factor: float = 1.5
    group_size: int = 512  # tokens per dispatch group (bounds dispatch memory)
    router_aux_weight: float = 0.01
    # experts are sharded over the "model" axis; pad to a multiple of it
    pad_experts_to: int | None = None

    @property
    def padded_experts(self) -> int:
        return self.pad_experts_to or self.num_experts


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    n_groups: int = 1
    d_conv: int = 4
    chunk: int = 256  # SSD chunk length (matmul-friendly scan)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None  # default d_model // n_heads (gemma: 256)
    act: Act = "swiglu"
    qk_norm: bool = False
    rope_theta: float = 10000.0
    rms_eps: float = 1e-6
    tie_embeddings: bool = False
    # block pattern: None = all-attention; "ssm" = all-SSM (mamba2);
    # "hybrid" = SSM stack with a SHARED attention block every
    # ``shared_attn_every`` layers (zamba2)
    family: Literal["dense", "ssm", "hybrid", "encdec", "moe", "vlm", "audio"] = (
        "dense"
    )
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    shared_attn_every: int = 6  # hybrid only
    # encoder-decoder (whisper): encoder layer count; frontend supplies
    # precomputed frame embeddings (conv stem is a stub per the assignment)
    n_enc_layers: int = 0
    # vlm: leading positions of the sequence are precomputed patch embeddings
    n_frontend_tokens: int = 0
    # numerics / performance knobs (see EXPERIMENTS.md §Perf)
    dtype: str = "bfloat16"  # activation/compute dtype
    param_dtype: str = "float32"
    attn_impl: Literal["dense", "chunked", "chunked_skip"] = "chunked_skip"
    attn_chunk: int = 1024
    remat: bool = True
    # "full": recompute everything in backward (min memory, re-runs the TP
    # collectives).  "save_block_io": save the all-reduced attn/mlp outputs
    # so backward recompute skips the forward collectives (§Perf lever —
    # trades ~2 x (B,S,d) bytes/layer for ~1/3 of the all-reduce wire)
    remat_policy: Literal["full", "save_block_io"] = "full"
    logits_chunk: int = 0  # 0 = unchunked; >0 = sequence-chunked loss
    scan_layers: bool = True
    # FSDP (ZeRO-3-style): additionally shard params/optimizer over the
    # "data" axis for training — required for archs whose fp32 params +
    # Adam state exceed HBM under TP-only sharding (qwen3-moe, internvl2)
    fsdp: bool = False
    # pure data parallelism: replicate ALL params and shard the batch over
    # every mesh axis (incl. "model").  The right regime for small models
    # whose TP collectives dominate (mamba2-370m: §Perf iteration A1)
    pure_dp: bool = False
    # ZeRO-1: shard Adam m/v over the "data" axis (params keep their TP
    # sharding; GSPMD inserts the post-update weight all-gather).  Frees
    # 8 bytes/param of replicated state at low-TP mesh ratios (§Perf C6)
    zero1: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def q_rep(self) -> int:
        if self.n_heads % self.n_kv_heads:
            raise ValueError(
                f"{self.name}: n_heads {self.n_heads} not divisible by "
                f"n_kv_heads {self.n_kv_heads}"
            )
        return self.n_heads // self.n_kv_heads

    def validate(self) -> "ModelConfig":
        if self.family in ("moe",) and self.moe is None:
            raise ValueError(f"{self.name}: family=moe requires moe config")
        if self.family in ("ssm", "hybrid") and self.ssm is None:
            raise ValueError(f"{self.name}: family={self.family} requires ssm config")
        if self.family == "encdec" and self.n_enc_layers <= 0:
            raise ValueError(f"{self.name}: encdec requires n_enc_layers")
        _ = self.q_rep
        return self

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks), for 6·N·D."""
        d, v = self.d_model, self.vocab
        hd = self.resolved_head_dim
        total = v * d * (1 if self.tie_embeddings else 2)
        attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d

        def mlp(ff: int) -> int:
            gates = 2 if self.act in ("swiglu", "geglu") else 1
            return d * ff * gates + ff * d

        if self.family in ("dense", "vlm"):
            total += self.n_layers * (attn + mlp(self.d_ff) + 2 * d)
        elif self.family == "moe":
            m = self.moe
            expert = d * m.d_ff_expert * 3  # gate/up/down
            total += self.n_layers * (
                attn + m.num_experts * expert + d * m.num_experts + 2 * d
            )
        elif self.family == "ssm":
            total += self.n_layers * self._ssm_block_params()
        elif self.family == "hybrid":
            n_shared = 1
            total += self.n_layers * self._ssm_block_params()
            total += n_shared * (attn + mlp(self.d_ff) + 2 * d)
        elif self.family in ("encdec", "audio"):
            total += (self.n_layers + self.n_enc_layers) * (
                attn + mlp(self.d_ff) + 2 * d
            )
            total += self.n_layers * attn  # decoder cross-attention
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of num_experts)."""
        if self.family != "moe":
            return self.param_count()
        m = self.moe
        d = self.d_model
        expert = d * m.d_ff_expert * 3
        total = self.param_count()
        total -= self.n_layers * m.num_experts * expert
        total += self.n_layers * (m.top_k + m.num_shared_experts) * expert
        return total

    def _ssm_block_params(self) -> int:
        s = self.ssm
        d = self.d_model
        d_inner = s.expand * d
        n_heads = d_inner // s.head_dim
        in_proj = d * (2 * d_inner + 2 * s.n_groups * s.d_state + n_heads)
        conv = s.d_conv * (d_inner + 2 * s.n_groups * s.d_state)
        out = d_inner * d
        return in_proj + conv + out + 2 * d_inner + 2 * n_heads + d
