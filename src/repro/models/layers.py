"""Shared neural-net layers: norms, RoPE, attention (train + decode), MLPs.

Pure-JAX (no flax): parameters are plain pytrees of arrays, initialisers are
explicit, and every layer is a function ``(params, inputs, ...) -> outputs``.
Attention supports three implementations (config.attn_impl):

  dense        -- full (S, S) score matrix; smoke tests and short sequences.
  chunked      -- lax.scan over query chunks, online softmax over all KV
                  chunks with causal masking (memory-bound, 2x causal FLOPs).
  chunked_skip -- statically unrolled query-chunk loop that *skips* KV chunks
                  above the causal diagonal (FLOP-optimal; the §Perf default).
"""

from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax import lax

from .config import ModelConfig

Params = Dict[str, Any]


# --------------------------------------------------------------------------
# initialisers / norms / rope
# --------------------------------------------------------------------------


def dense_init(key: jax.Array, shape: tuple[int, ...], in_axis_size: int, dtype):
    scale = 1.0 / math.sqrt(max(1, in_axis_size))
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def rms_norm(x: jax.Array, w: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return ((xf * lax.rsqrt(var + eps)) * (w.astype(jnp.float32))).astype(dt)


def rope_tables(positions: jax.Array, head_dim: int, theta: float) -> tuple:
    """cos/sin tables for given positions: (..., head_dim // 2)."""
    half = head_dim // 2
    freqs = jnp.exp(
        -math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half
    )
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, S, H, D); cos/sin: (B, S, D/2) or (S, D/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# MLP
# --------------------------------------------------------------------------


def init_mlp(key: jax.Array, cfg: ModelConfig, d_ff: int) -> Params:
    pdt = jnp.dtype(cfg.param_dtype)
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    gated = cfg.act in ("swiglu", "geglu")
    p: Params = {"down": dense_init(ks[2], (d_ff, d), d_ff, pdt)}
    if gated:
        p["gate"] = dense_init(ks[0], (d, d_ff), d, pdt)
        p["up"] = dense_init(ks[1], (d, d_ff), d, pdt)
    else:
        p["up"] = dense_init(ks[1], (d, d_ff), d, pdt)
    return p


def mlp(params: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    dt = x.dtype
    if cfg.act == "swiglu":
        h = jax.nn.silu(x @ params["gate"].astype(dt)) * (x @ params["up"].astype(dt))
    elif cfg.act == "geglu":
        h = jax.nn.gelu(x @ params["gate"].astype(dt), approximate=True) * (
            x @ params["up"].astype(dt)
        )
    else:
        h = jax.nn.gelu(x @ params["up"].astype(dt), approximate=True)
    return h @ params["down"].astype(dt)


# --------------------------------------------------------------------------
# attention
# --------------------------------------------------------------------------


def init_attention(key: jax.Array, cfg: ModelConfig) -> Params:
    pdt = jnp.dtype(cfg.param_dtype)
    d, hd = cfg.d_model, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p: Params = {
        "wq": dense_init(ks[0], (d, cfg.n_heads, hd), d, pdt),
        "wk": dense_init(ks[1], (d, cfg.n_kv_heads, hd), d, pdt),
        "wv": dense_init(ks[2], (d, cfg.n_kv_heads, hd), d, pdt),
        "wo": dense_init(ks[3], (cfg.n_heads, hd, d), cfg.n_heads * hd, pdt),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), pdt)
        p["k_norm"] = jnp.ones((hd,), pdt)
    return p


def _qkv(params: Params, x: jax.Array, cfg: ModelConfig, positions: jax.Array):
    """Project + (optional) qk-norm + rope.  x: (B, S, d)."""
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dt))
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.rms_eps)
        k = rms_norm(k, params["k_norm"], cfg.rms_eps)
    cos, sin = rope_tables(positions, cfg.resolved_head_dim, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    return q, k, v


def _sdpa_dense(q, k, v, scale: float, causal: bool) -> jax.Array:
    """q: (B, Sq, H, D), k/v: (B, Sk, Hkv, D) with H = Hkv * rep."""
    b, sq, h, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    rep = h // hkv
    qg = q.reshape(b, sq, hkv, rep, d)
    scores = jnp.einsum("bqhrd,bkhd->bhrqk", qg, k).astype(jnp.float32) * scale
    if causal:
        mask = jnp.tril(jnp.ones((sq, sk), bool), k=sk - sq)
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhrqk,bkhd->bqhrd", probs, v)
    return out.reshape(b, sq, h, d)


def _attn_block(q, k, v, scale, mask_bias):
    """One (q-chunk, kv-chunk) online-softmax block.

    q: (B, Cq, Hkv, rep, D); k/v: (B, Ck, Hkv, D).
    Returns (m, l, acc) partials with m/l: (B, Hkv, rep, Cq), acc like q.
    """
    s = jnp.einsum("bqhrd,bkhd->bhrqk", q, k).astype(jnp.float32) * scale
    if mask_bias is not None:
        s = s + mask_bias
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bhrqk,bkhd->bqhrd", p.astype(q.dtype), v)
    return m, l, acc


def _merge_blocks(m1, l1, a1, m2, l2, a2):
    m = jnp.maximum(m1, m2)
    e1 = jnp.exp(m1 - m)
    e2 = jnp.exp(m2 - m)
    l = l1 * e1 + l2 * e2
    # scale accumulators: acc axes (B, Cq, Hkv, rep, D) vs stats (B,Hkv,rep,Cq)
    s1 = jnp.transpose(e1, (0, 3, 1, 2))[..., None].astype(a1.dtype)
    s2 = jnp.transpose(e2, (0, 3, 1, 2))[..., None].astype(a2.dtype)
    return m, l, a1 * s1 + a2 * s2


def _finalize(m, l, acc):
    denom = jnp.transpose(l, (0, 3, 1, 2))[..., None]
    return (acc.astype(jnp.float32) / jnp.maximum(denom, 1e-30)).astype(acc.dtype)


def _sdpa_chunked(
    q, k, v, scale: float, chunk: int, skip: bool, unroll: bool = False
) -> jax.Array:
    """Causal online-softmax attention over chunks.

    skip=True statically unrolls the query loop and skips KV chunks above the
    causal diagonal (FLOP-optimal); skip=False lax.scans over query chunks
    with full masked KV (compact HLO, 2x causal FLOPs).  ``unroll`` unrolls
    the scans for the dry-run cost pass (XLA cost analysis visits loop
    bodies once — see repro.launch.dryrun).
    """
    b, sq, h, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    rep = h // hkv
    cq = min(chunk, sq)
    ck = min(chunk, sk)
    if sq % cq or sk % ck:
        return _sdpa_dense(q, k, v, scale, causal=True)
    nq, nk = sq // cq, sk // ck
    qg = q.reshape(b, nq, cq, hkv, rep, d)
    kg = k.reshape(b, nk, ck, hkv, d)
    vg = v.reshape(b, nk, ck, hkv, d)
    hist = sk - sq  # KV positions preceding the query window (decode prefill)

    def block_bias(qi: jax.Array, kj: jax.Array):
        qpos = qi * cq + hist + jnp.arange(cq)
        kpos = kj * ck + jnp.arange(ck)
        keep = qpos[:, None] >= kpos[None, :]
        return jnp.where(keep, 0.0, -1e30)[None, None, None]

    if skip:
        outs = []
        for i in range(nq):
            m = jnp.full((b, hkv, rep, cq), -1e30, jnp.float32)
            l = jnp.zeros((b, hkv, rep, cq), jnp.float32)
            acc = jnp.zeros((b, cq, hkv, rep, d), q.dtype)
            hi = min(nk, ((i + 1) * cq + hist + ck - 1) // ck)
            for j in range(hi):
                diag = (j + 1) * ck > i * cq + hist  # block touches the mask
                bias = block_bias(i, j) if diag else None
                mb, lb, ab = _attn_block(qg[:, i], kg[:, j], vg[:, j], scale, bias)
                m, l, acc = _merge_blocks(m, l, acc, mb, lb, ab)
            outs.append(_finalize(m, l, acc))
        out = jnp.stack(outs, axis=1)
    else:

        def q_step(_, i):
            m = jnp.full((b, hkv, rep, cq), -1e30, jnp.float32)
            l = jnp.zeros((b, hkv, rep, cq), jnp.float32)
            acc = jnp.zeros((b, cq, hkv, rep, d), q.dtype)
            qi = qg[:, i]

            def kv_step(carry, j):
                m, l, acc = carry
                mb, lb, ab = _attn_block(qi, kg[:, j], vg[:, j], scale, block_bias(i, j))
                return _merge_blocks(m, l, acc, mb, lb, ab), None

            (m, l, acc), _ = lax.scan(
                kv_step, (m, l, acc), jnp.arange(nk), unroll=unroll
            )
            return None, _finalize(m, l, acc)

        _, out = lax.scan(q_step, None, jnp.arange(nq), unroll=unroll)
        out = jnp.moveaxis(out, 0, 1)  # (B, nq, cq, hkv, rep, d)
    return out.reshape(b, sq, h, d)


def attention(
    params: Params,
    x: jax.Array,
    cfg: ModelConfig,
    positions: jax.Array,
    causal: bool = True,
) -> jax.Array:
    """Full-sequence attention (training / prefill).  x: (B, S, d)."""
    q, k, v = _qkv(params, x, cfg, positions)
    scale = 1.0 / math.sqrt(cfg.resolved_head_dim)
    if not causal or cfg.attn_impl == "dense" or x.shape[1] <= cfg.attn_chunk:
        out = _sdpa_dense(q, k, v, scale, causal)
    else:
        skip = cfg.attn_impl == "chunked_skip"
        # skip statically unrolls (q,kv) blocks: floor the chunk at S/8 to
        # bound HLO size; the scan impl has no such constraint
        chunk = max(cfg.attn_chunk, x.shape[1] // 8) if skip else cfg.attn_chunk
        out = _sdpa_chunked(
            q, k, v, scale, chunk, skip=skip, unroll=not cfg.scan_layers
        )
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))


def cross_attention(
    params: Params,
    x: jax.Array,
    kv_k: jax.Array,
    kv_v: jax.Array,
    cfg: ModelConfig,
) -> jax.Array:
    """Decoder cross-attention against precomputed encoder K/V (no rope)."""
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.rms_eps)
    scale = 1.0 / math.sqrt(cfg.resolved_head_dim)
    out = _sdpa_dense(q, kv_k, kv_v, scale, causal=False)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt))


def encode_kv(params: Params, enc_out: jax.Array, cfg: ModelConfig):
    """Precompute cross-attention K/V from encoder output."""
    dt = enc_out.dtype
    k = jnp.einsum("bsd,dhk->bshk", enc_out, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, params["wv"].astype(dt))
    if cfg.qk_norm:
        k = rms_norm(k, params["k_norm"], cfg.rms_eps)
    return k, v


def attention_decode(
    params: Params,
    x: jax.Array,
    cache_k: jax.Array,
    cache_v: jax.Array,
    pos: jax.Array,
    cfg: ModelConfig,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Single-token decode with KV cache.

    x: (B, 1, d); cache_k/v: (B, S_max, Hkv, D); pos: scalar int32 (tokens
    already in cache).  Returns (y, new_k, new_v).
    """
    dt = x.dtype
    b, _, _ = x.shape
    positions = jnp.full((b, 1), pos, jnp.int32)
    q, k, v = _qkv(params, x, cfg, positions)
    # one-hot masked write instead of dynamic_update_slice: a dynamic-index
    # write on a sequence-SHARDED cache axis otherwise degrades to a full
    # all-gather of the cache (measured +8 GB/device on qwen3-moe decode)
    slot = (jnp.arange(cache_k.shape[1]) == pos)[None, :, None, None]
    cache_k = jnp.where(slot, k.astype(cache_k.dtype), cache_k)
    cache_v = jnp.where(slot, v.astype(cache_v.dtype), cache_v)
    smax = cache_k.shape[1]
    hkv = cfg.n_kv_heads
    rep = cfg.q_rep
    scale = 1.0 / math.sqrt(cfg.resolved_head_dim)
    qg = q.reshape(b, 1, hkv, rep, q.shape[-1])
    scores = (
        jnp.einsum("bqhrd,bkhd->bhrqk", qg, cache_k.astype(dt)).astype(jnp.float32)
        * scale
    )
    valid = (jnp.arange(smax) <= pos)[None, None, None, None, :]
    scores = jnp.where(valid, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(dt)
    out = jnp.einsum("bhrqk,bkhd->bqhrd", probs, cache_v.astype(dt))
    out = out.reshape(b, 1, cfg.n_heads, cfg.resolved_head_dim)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt))
    return y, cache_k, cache_v
