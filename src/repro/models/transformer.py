"""Model assembly: init / forward / prefill / decode for every family.

Parameters are plain pytrees with layer-stacked leaves (leading axis L) so
layer application is a ``lax.scan`` — compile time and HLO size stay flat in
depth (crucial for 48-layer x 512-device dry-runs).  ``jax.checkpoint`` wraps
the scan body when ``cfg.remat`` (activation recomputation).

Families:
  dense / vlm      -- GQA attention + (Ge/Swi)GLU MLP stack
  moe              -- attention + top-k MoE MLP
  ssm              -- Mamba-2 / SSD stack (attention-free)
  hybrid           -- SSD stack with one SHARED attention+MLP block applied
                      after every ``shared_attn_every`` SSM layers (zamba2)
  encdec / audio   -- encoder (bidirectional) + causal decoder with
                      cross-attention (whisper); frame frontend is a stub
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.ad_checkpoint import checkpoint_name

from .config import ModelConfig
from .layers import (
    attention,
    attention_decode,
    cross_attention,
    dense_init,
    encode_kv,
    init_attention,
    init_mlp,
    mlp,
    rms_norm,
    _qkv,
)
from .moe import init_moe, moe_block
from .ssd import init_ssd, init_ssd_cache, ssd_block, ssd_decode, ssm_dims

Params = Dict[str, Any]


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------


def _init_layer(key: jax.Array, cfg: ModelConfig, kind: str) -> Params:
    pdt = jnp.dtype(cfg.param_dtype)
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    if kind == "ssm":
        return {"norm": jnp.ones((d,), pdt), "ssd": init_ssd(ks[0], cfg)}
    p: Params = {
        "attn_norm": jnp.ones((d,), pdt),
        "attn": init_attention(ks[0], cfg),
        "mlp_norm": jnp.ones((d,), pdt),
    }
    if kind == "moe":
        p["moe"] = init_moe(ks[1], cfg)
    else:
        p["mlp"] = init_mlp(ks[1], cfg, cfg.d_ff)
    if kind == "dec":
        p["cross_norm"] = jnp.ones((d,), pdt)
        p["cross_attn"] = init_attention(ks[2], cfg)
    return p


def _init_stack(key: jax.Array, cfg: ModelConfig, kind: str, n: int) -> Params:
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: _init_layer(k, cfg, kind))(keys)


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    pdt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    p: Params = {
        "embed": (jax.random.normal(ks[0], (cfg.vocab, cfg.d_model)) * 0.02).astype(pdt),
        "final_norm": jnp.ones((cfg.d_model,), pdt),
    }
    if not cfg.tie_embeddings:
        p["head"] = dense_init(ks[1], (cfg.d_model, cfg.vocab), cfg.d_model, pdt)
    fam = cfg.family
    if fam in ("dense", "vlm"):
        p["layers"] = _init_stack(ks[2], cfg, "attn", cfg.n_layers)
    elif fam == "moe":
        p["layers"] = _init_stack(ks[2], cfg, "moe", cfg.n_layers)
    elif fam == "ssm":
        p["layers"] = _init_stack(ks[2], cfg, "ssm", cfg.n_layers)
    elif fam == "hybrid":
        groups = cfg.n_layers // cfg.shared_attn_every
        trailing = cfg.n_layers % cfg.shared_attn_every
        p["layers"] = _init_stack(ks[2], cfg, "ssm", groups * cfg.shared_attn_every)
        if trailing:
            p["trailing"] = _init_stack(ks[3], cfg, "ssm", trailing)
        p["shared"] = _init_layer(ks[4], cfg, "attn")
    elif fam in ("encdec", "audio"):
        p["enc_layers"] = _init_stack(ks[2], cfg, "attn", cfg.n_enc_layers)
        p["enc_norm"] = jnp.ones((cfg.d_model,), pdt)
        p["layers"] = _init_stack(ks[3], cfg, "dec", cfg.n_layers)
    else:
        raise ValueError(f"unknown family {fam}")
    return p


def param_shapes(cfg: ModelConfig) -> Params:
    """Abstract init (no allocation) — dry-run input."""
    return jax.eval_shape(lambda: init_params(cfg, jax.random.key(0)))


# --------------------------------------------------------------------------
# layer bodies
# --------------------------------------------------------------------------


def _tag(x: jax.Array, name: str) -> jax.Array:
    """checkpoint_name tag: inert under remat_policy="full"; with
    "save_block_io" these (all-reduced) tensors are saved, so backward
    recompute does not re-run the forward TP collectives."""
    return checkpoint_name(x, name)


def _attn_layer(lp: Params, h: jax.Array, cfg: ModelConfig, positions, causal=True):
    a = attention(lp["attn"], rms_norm(h, lp["attn_norm"], cfg.rms_eps), cfg, positions, causal)
    h = h + _tag(a, "attn_out")
    m = mlp(lp["mlp"], rms_norm(h, lp["mlp_norm"], cfg.rms_eps), cfg)
    return h + _tag(m, "mlp_out")


def _moe_layer(lp: Params, h: jax.Array, cfg: ModelConfig, positions):
    a = attention(lp["attn"], rms_norm(h, lp["attn_norm"], cfg.rms_eps), cfg, positions, True)
    h = h + _tag(a, "attn_out")
    y, aux = moe_block(lp["moe"], rms_norm(h, lp["mlp_norm"], cfg.rms_eps), cfg)
    return h + _tag(y, "mlp_out"), aux


def _ssm_layer(lp: Params, h: jax.Array, cfg: ModelConfig):
    y = ssd_block(lp["ssd"], rms_norm(h, lp["norm"], cfg.rms_eps), cfg)
    return h + _tag(y, "mlp_out")


def _dec_layer(lp: Params, h: jax.Array, ek: jax.Array, ev: jax.Array, cfg, positions):
    h = h + attention(lp["attn"], rms_norm(h, lp["attn_norm"], cfg.rms_eps), cfg, positions, True)
    h = h + cross_attention(lp["cross_attn"], rms_norm(h, lp["cross_norm"], cfg.rms_eps), ek, ev, cfg)
    h = h + mlp(lp["mlp"], rms_norm(h, lp["mlp_norm"], cfg.rms_eps), cfg)
    return h



def _scan(cfg: ModelConfig, body, carry, xs):
    """lax.scan honoring cfg.scan_layers: the dry-run unrolls so XLA's
    cost_analysis (which visits while bodies ONCE) reports true totals."""
    return lax.scan(body, carry, xs, unroll=not cfg.scan_layers)


def _maybe_remat(fn, cfg: ModelConfig):
    if not cfg.remat:
        return fn
    if cfg.remat_policy == "save_block_io":
        policy = jax.checkpoint_policies.save_only_these_names(
            "attn_out", "mlp_out"
        )
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


# --------------------------------------------------------------------------
# forward (training / full-sequence)
# --------------------------------------------------------------------------


def embed_tokens(params: Params, cfg: ModelConfig, tokens: jax.Array) -> jax.Array:
    h = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    return h * math.sqrt(cfg.d_model)


def forward(
    params: Params,
    cfg: ModelConfig,
    tokens: Optional[jax.Array] = None,
    inputs_embeds: Optional[jax.Array] = None,
    positions: Optional[jax.Array] = None,
    causal: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Decoder-only forward.  Returns (hidden (B,S,d), aux_loss)."""
    if inputs_embeds is not None and tokens is not None:
        text = embed_tokens(params, cfg, tokens)
        h = jnp.concatenate([inputs_embeds.astype(text.dtype), text], axis=1)
    elif tokens is not None:
        h = embed_tokens(params, cfg, tokens)
    else:
        h = inputs_embeds.astype(jnp.dtype(cfg.dtype))
    s = h.shape[1]
    if positions is None:
        positions = jnp.arange(s, dtype=jnp.int32)[None, :]
    aux = jnp.float32(0.0)
    fam = cfg.family

    if fam in ("dense", "vlm"):
        def body(carry, lp):
            return _attn_layer(lp, carry, cfg, positions, causal), None
        h, _ = _scan(cfg, _maybe_remat(body, cfg), h, params["layers"])
    elif fam == "moe":
        def body(carry, lp):
            return _moe_layer(lp, carry, cfg, positions)
        h, auxs = _scan(cfg, _maybe_remat(body, cfg), h, params["layers"])
        aux = aux + auxs.sum()
    elif fam == "ssm":
        def body(carry, lp):
            return _ssm_layer(lp, carry, cfg), None
        h, _ = _scan(cfg, _maybe_remat(body, cfg), h, params["layers"])
    elif fam == "hybrid":
        h = _hybrid_forward(params, cfg, h, positions)
    else:
        raise ValueError(f"forward() does not handle family {fam}; use encdec_forward")
    return rms_norm(h, params["final_norm"], cfg.rms_eps), aux


def _hybrid_forward(params: Params, cfg: ModelConfig, h, positions):
    per = cfg.shared_attn_every
    groups = cfg.n_layers // per
    stacked = jax.tree.map(
        lambda x: x.reshape(groups, per, *x.shape[1:]), params["layers"]
    )
    shared = params["shared"]

    def group_body(carry, gp):
        def inner(c, lp):
            return _ssm_layer(lp, c, cfg), None
        c, _ = _scan(cfg, inner, carry, gp)
        c = _attn_layer(shared, c, cfg, positions)  # shared weights
        return c, None

    h, _ = _scan(cfg, _maybe_remat(group_body, cfg), h, stacked)
    if "trailing" in params:
        def body(c, lp):
            return _ssm_layer(lp, c, cfg), None
        h, _ = _scan(cfg, _maybe_remat(body, cfg), h, params["trailing"])
    return h


def encdec_forward(
    params: Params,
    cfg: ModelConfig,
    frames: jax.Array,  # (B, S_enc, d) precomputed frontend embeddings (stub)
    dec_tokens: jax.Array,  # (B, S_dec)
) -> tuple[jax.Array, jax.Array]:
    """Encoder-decoder forward (whisper).  Returns (dec hidden, aux)."""
    dt = jnp.dtype(cfg.dtype)
    enc = frames.astype(dt)
    s_enc = enc.shape[1]
    enc_pos = jnp.arange(s_enc, dtype=jnp.int32)[None, :]

    def enc_body(carry, lp):
        return _attn_layer(lp, carry, cfg, enc_pos, causal=False), None

    enc, _ = _scan(cfg, _maybe_remat(enc_body, cfg), enc, params["enc_layers"])
    enc = rms_norm(enc, params["enc_norm"], cfg.rms_eps)

    h = embed_tokens(params, cfg, dec_tokens)
    dec_pos = jnp.arange(h.shape[1], dtype=jnp.int32)[None, :]

    def dec_body(carry, lp):
        ek, ev = encode_kv(lp["cross_attn"], enc, cfg)
        return _dec_layer(lp, carry, ek, ev, cfg, dec_pos), None

    h, _ = _scan(cfg, _maybe_remat(dec_body, cfg), h, params["layers"])
    return rms_norm(h, params["final_norm"], cfg.rms_eps), jnp.float32(0.0)


# --------------------------------------------------------------------------
# logits / loss
# --------------------------------------------------------------------------


def unembed(params: Params, cfg: ModelConfig, h: jax.Array) -> jax.Array:
    w = params["embed"].T if cfg.tie_embeddings else params["head"]
    return h @ w.astype(h.dtype)


def _ce(logits: jax.Array, labels: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Sum of CE over valid (label >= 0) positions; returns (sum, count)."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    valid = labels >= 0
    ce = jnp.where(valid, lse - gold, 0.0)
    return ce.sum(), valid.sum()


def lm_loss(params: Params, cfg: ModelConfig, h: jax.Array, labels: jax.Array) -> jax.Array:
    """Cross-entropy; optionally sequence-chunked to bound logits memory."""
    chunk = cfg.logits_chunk
    s = h.shape[1]
    if chunk and s % chunk == 0 and s > chunk:
        nc = s // chunk
        hc = h.reshape(h.shape[0], nc, chunk, h.shape[-1])
        lc = labels.reshape(labels.shape[0], nc, chunk)

        def body(carry, xs):
            hh, ll = xs
            cs, cn = _ce(unembed(params, cfg, hh), ll)
            tot, cnt = carry
            return (tot + cs, cnt + cn), None

        (tot, cnt), _ = _scan(cfg, 
            jax.checkpoint(body),
            (jnp.float32(0.0), jnp.int32(0)),
            (jnp.moveaxis(hc, 1, 0), jnp.moveaxis(lc, 1, 0)),
        )
        return tot / jnp.maximum(cnt, 1)
    tot, cnt = _ce(unembed(params, cfg, h), labels)
    return tot / jnp.maximum(cnt, 1)


# --------------------------------------------------------------------------
# prefill / decode (serving)
# --------------------------------------------------------------------------


def _attn_with_kv(lp, h, cfg, positions):
    """Attention layer that also returns (k, v) for cache population."""
    x = rms_norm(h, lp["attn_norm"], cfg.rms_eps)
    q, k, v = _qkv(lp["attn"], x, cfg, positions)
    return k, v


def init_cache(cfg: ModelConfig, batch: int, max_len: int, enc_len: int = 0) -> Params:
    """Abstract-safe cache allocation for every family."""
    dt = jnp.dtype(cfg.dtype)
    hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    fam = cfg.family
    cache: Params = {"pos": jnp.zeros((), jnp.int32)}
    if fam in ("dense", "vlm", "moe"):
        cache["k"] = jnp.zeros((cfg.n_layers, batch, max_len, hkv, hd), dt)
        cache["v"] = jnp.zeros((cfg.n_layers, batch, max_len, hkv, hd), dt)
    elif fam == "ssm":
        stack = init_ssd_cache(cfg, batch, dt)
        cache["ssm"] = jax.tree.map(
            lambda x: jnp.zeros((cfg.n_layers, *x.shape), x.dtype), stack
        )
    elif fam == "hybrid":
        per = cfg.shared_attn_every
        groups = cfg.n_layers // per
        trailing = cfg.n_layers % per
        stack = init_ssd_cache(cfg, batch, dt)
        cache["ssm"] = jax.tree.map(
            lambda x: jnp.zeros((groups * per, *x.shape), x.dtype), stack
        )
        if trailing:
            cache["ssm_trailing"] = jax.tree.map(
                lambda x: jnp.zeros((trailing, *x.shape), x.dtype), stack
            )
        cache["k"] = jnp.zeros((groups, batch, max_len, hkv, hd), dt)
        cache["v"] = jnp.zeros((groups, batch, max_len, hkv, hd), dt)
    elif fam in ("encdec", "audio"):
        cache["k"] = jnp.zeros((cfg.n_layers, batch, max_len, hkv, hd), dt)
        cache["v"] = jnp.zeros((cfg.n_layers, batch, max_len, hkv, hd), dt)
        cache["cross_k"] = jnp.zeros((cfg.n_layers, batch, enc_len, hkv, hd), dt)
        cache["cross_v"] = jnp.zeros((cfg.n_layers, batch, enc_len, hkv, hd), dt)
    return cache


def prefill(
    params: Params,
    cfg: ModelConfig,
    tokens: jax.Array,
    max_len: int,
    frames: Optional[jax.Array] = None,
    inputs_embeds: Optional[jax.Array] = None,
) -> tuple[jax.Array, Params]:
    """Process a prompt, returning (last-position logits, populated cache).

    ``max_len`` is the cache capacity (>= prompt length).  For encdec,
    ``frames`` is the encoder input (stub frontend embeddings) and ``tokens``
    the decoder prompt.
    """
    fam = cfg.family
    eps = cfg.rms_eps
    dt = jnp.dtype(cfg.dtype)
    if inputs_embeds is not None:
        text = embed_tokens(params, cfg, tokens)
        h = jnp.concatenate([inputs_embeds.astype(text.dtype), text], axis=1)
    else:
        h = embed_tokens(params, cfg, tokens)
    b, s = h.shape[0], h.shape[1]
    if s > max_len:
        raise ValueError(f"prompt length {s} exceeds cache capacity {max_len}")
    positions = jnp.arange(s, dtype=jnp.int32)[None, :]
    cache = init_cache(
        cfg, b, max_len, enc_len=frames.shape[1] if frames is not None else 0
    )

    def pad_kv(k):  # (B, S, Hkv, D) -> (B, max_len, Hkv, D)
        return jnp.pad(k, ((0, 0), (0, max_len - s), (0, 0), (0, 0))).astype(dt)

    if fam in ("dense", "vlm", "moe"):
        # run the layer normally; re-project k/v from the normed input for the
        # cache (cheap relative to attention itself, keeps one code path)
        if fam == "moe":
            def body(carry, lp):
                x = rms_norm(carry, lp["attn_norm"], eps)
                _, k, v = _qkv(lp["attn"], x, cfg, positions)
                carry, _aux = _moe_layer(lp, carry, cfg, positions)
                return carry, (pad_kv(k), pad_kv(v))
        else:
            def body(carry, lp):
                x = rms_norm(carry, lp["attn_norm"], eps)
                _, k, v = _qkv(lp["attn"], x, cfg, positions)
                carry = _attn_layer(lp, carry, cfg, positions)
                return carry, (pad_kv(k), pad_kv(v))

        h, (ks, vs) = _scan(cfg, body, h, params["layers"])
        cache.update(k=ks, v=vs, pos=jnp.int32(s))

    elif fam == "ssm":
        def body(carry, lp):
            x = rms_norm(carry, lp["norm"], eps)
            y, c = ssd_block(lp["ssd"], x, cfg, return_cache=True)
            return carry + y, c

        h, cs = _scan(cfg, body, h, params["layers"])
        cache.update(ssm=cs, pos=jnp.int32(s))

    elif fam == "hybrid":
        per = cfg.shared_attn_every
        groups = cfg.n_layers // per
        stacked = jax.tree.map(
            lambda x: x.reshape(groups, per, *x.shape[1:]), params["layers"]
        )
        shared = params["shared"]

        def group_body(carry, gp):
            def inner(c, lp):
                x = rms_norm(c, lp["norm"], eps)
                y, sc = ssd_block(lp["ssd"], x, cfg, return_cache=True)
                return c + y, sc

            c, scs = _scan(cfg, inner, carry, gp)
            x = rms_norm(c, shared["attn_norm"], eps)
            _, k, v = _qkv(shared["attn"], x, cfg, positions)
            c = _attn_layer(shared, c, cfg, positions)
            return c, (scs, pad_kv(k), pad_kv(v))

        h, (scs, ks, vs) = _scan(cfg, group_body, h, stacked)
        cache.update(
            ssm=jax.tree.map(lambda x: x.reshape(-1, *x.shape[2:]), scs),
            k=ks,
            v=vs,
            pos=jnp.int32(s),
        )
        if "trailing" in params:
            def body(c, lp):
                x = rms_norm(c, lp["norm"], eps)
                y, sc = ssd_block(lp["ssd"], x, cfg, return_cache=True)
                return c + y, sc

            h, trail = _scan(cfg, body, h, params["trailing"])
            cache["ssm_trailing"] = trail

    elif fam in ("encdec", "audio"):
        enc = frames.astype(dt)
        enc_pos = jnp.arange(enc.shape[1], dtype=jnp.int32)[None, :]

        def enc_body(carry, lp):
            return _attn_layer(lp, carry, cfg, enc_pos, causal=False), None

        enc, _ = _scan(cfg, enc_body, enc, params["enc_layers"])
        enc = rms_norm(enc, params["enc_norm"], cfg.rms_eps)

        def dec_body(carry, lp):
            ek, ev = encode_kv(lp["cross_attn"], enc, cfg)
            x = rms_norm(carry, lp["attn_norm"], eps)
            _, k, v = _qkv(lp["attn"], x, cfg, positions)
            carry = _dec_layer(lp, carry, ek, ev, cfg, positions)
            return carry, (pad_kv(k), pad_kv(v), ek.astype(dt), ev.astype(dt))

        h, (ks, vs, eks, evs) = _scan(cfg, dec_body, h, params["layers"])
        cache.update(k=ks, v=vs, cross_k=eks, cross_v=evs, pos=jnp.int32(s))
    else:
        raise ValueError(fam)

    h = rms_norm(h, params["final_norm"], cfg.rms_eps)
    return unembed(params, cfg, h[:, -1:, :]), cache


def decode_step(
    params: Params, cfg: ModelConfig, cache: Params, tokens: jax.Array
) -> tuple[jax.Array, Params]:
    """One decode step.  tokens: (B, 1).  Returns (logits (B,1,V), cache)."""
    h = embed_tokens(params, cfg, tokens)
    pos = cache["pos"]
    fam = cfg.family
    eps = cfg.rms_eps

    if fam in ("dense", "vlm", "moe"):
        def body(carry, xs):
            lp, ck, cv = xs
            x = rms_norm(carry, lp["attn_norm"], eps)
            y, nk, nv = attention_decode(lp["attn"], x, ck, cv, pos, cfg)
            carry = carry + y
            x = rms_norm(carry, lp["mlp_norm"], eps)
            if fam == "moe":
                m, _ = moe_block(lp["moe"], x, cfg, dropless=True)
            else:
                m = mlp(lp["mlp"], x, cfg)
            return carry + m, (nk, nv)

        h, (nk, nv) = _scan(cfg, body, h, (params["layers"], cache["k"], cache["v"]))
        new_cache = {**cache, "k": nk, "v": nv, "pos": pos + 1}

    elif fam == "ssm":
        def body(carry, xs):
            lp, c = xs
            x = rms_norm(carry, lp["norm"], eps)
            y, nc = ssd_decode(lp["ssd"], x, c, cfg)
            return carry + y, nc

        h, ncache = _scan(cfg, body, h, (params["layers"], cache["ssm"]))
        new_cache = {**cache, "ssm": ncache, "pos": pos + 1}

    elif fam == "hybrid":
        per = cfg.shared_attn_every
        groups = cfg.n_layers // per
        stacked = jax.tree.map(
            lambda x: x.reshape(groups, per, *x.shape[1:]), params["layers"]
        )
        sstack = jax.tree.map(
            lambda x: x.reshape(groups, per, *x.shape[1:]), cache["ssm"]
        )
        shared = params["shared"]

        def group_body(carry, xs):
            gp, gc, ck, cv = xs

            def inner(c, ys):
                lp, sc = ys
                x = rms_norm(c, lp["norm"], eps)
                y, nsc = ssd_decode(lp["ssd"], x, sc, cfg)
                return c + y, nsc

            c, nsc = _scan(cfg, inner, carry, (gp, gc))
            x = rms_norm(c, shared["attn_norm"], eps)
            y, nk, nv = attention_decode(shared["attn"], x, ck, cv, pos, cfg)
            c = c + y
            c = c + mlp(shared["mlp"], rms_norm(c, shared["mlp_norm"], eps), cfg)
            return c, (nsc, nk, nv)

        h, (nsc, nk, nv) = _scan(cfg, 
            group_body, h, (stacked, sstack, cache["k"], cache["v"])
        )
        new_cache = {
            **cache,
            "ssm": jax.tree.map(lambda x: x.reshape(-1, *x.shape[2:]), nsc),
            "k": nk,
            "v": nv,
            "pos": pos + 1,
        }
        if "ssm_trailing" in cache:
            def body(c, ys):
                lp, sc = ys
                x = rms_norm(c, lp["norm"], eps)
                y, nsc2 = ssd_decode(lp["ssd"], x, sc, cfg)
                return c + y, nsc2

            h, ntrail = _scan(cfg, body, h, (params["trailing"], cache["ssm_trailing"]))
            new_cache["ssm_trailing"] = ntrail

    elif fam in ("encdec", "audio"):
        def body(carry, xs):
            lp, ck, cv, xk, xv = xs
            x = rms_norm(carry, lp["attn_norm"], eps)
            y, nk, nv = attention_decode(lp["attn"], x, ck, cv, pos, cfg)
            carry = carry + y
            x = rms_norm(carry, lp["cross_norm"], eps)
            carry = carry + cross_attention(lp["cross_attn"], x, xk, xv, cfg)
            x = rms_norm(carry, lp["mlp_norm"], eps)
            return carry + mlp(lp["mlp"], x, cfg), (nk, nv)

        h, (nk, nv) = _scan(cfg, 
            body,
            h,
            (params["layers"], cache["k"], cache["v"], cache["cross_k"], cache["cross_v"]),
        )
        new_cache = {**cache, "k": nk, "v": nv, "pos": pos + 1}
    else:
        raise ValueError(fam)

    h = rms_norm(h, params["final_norm"], cfg.rms_eps)
    return unembed(params, cfg, h), new_cache
