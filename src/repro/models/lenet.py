"""A tiny LeNet-5 trained in-repo, so captured conv weights are honest.

The paper's Table I measures the sorting unit on LeNet conv traffic.  The
seed reproduced that with *synthetic* Gaussian weight bytes
(``benchmarks/datagen.py``) — DESIGN.md §10 blames the residual gap vs the
paper on exactly that synthetic distribution.  This module closes the loop:
a real (if small) LeNet is trained here with SGD + weight decay on a
deterministic synthetic classification task, so its int8 weight image has
the genuinely zero-clustered, trained distribution the paper's numbers come
from — not a distribution we assumed.

Everything is plain jax.numpy + lax.conv (no new dependencies); training a
few hundred steps takes seconds on CPU.  Checkpoints go through
``repro.checkpoint.CheckpointManager`` (atomic publish + CRC) so CI can
cache the trained weights between runs: ``train_lenet(ckpt_dir=...)``
restores instead of retraining when a checkpoint exists.

``lenet_forward`` carries the ``lenet.conv`` traffic tap
(``repro._obs_hooks.tap``): called eagerly under ``repro.obs.capture`` it
records the trained conv kernels + input batch; under jit the tap sees
tracers and drops the firing whole, leaving the jaxpr byte-identical.
"""

from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro import _obs_hooks

__all__ = [
    "NUM_CLASSES",
    "init_lenet",
    "lenet_forward",
    "synth_batch",
    "train_lenet",
]

NUM_CLASSES = 10
_DN = ("NHWC", "HWIO", "NHWC")  # conv dimension numbers throughout

Params = Dict[str, Any]


def init_lenet(key: jax.Array) -> Params:
    """LeNet-5 shapes: 32x32x1 -> conv 6@5x5 -> pool -> conv 16@5x5 ->
    pool -> fc 120 -> 84 -> 10 (all float32)."""
    ks = jax.random.split(key, 5)

    def conv(k, shape, fan_in):
        return jax.random.normal(k, shape, jnp.float32) / np.sqrt(fan_in)

    return {
        "conv1": {"w": conv(ks[0], (5, 5, 1, 6), 25), "b": jnp.zeros(6)},
        "conv2": {"w": conv(ks[1], (5, 5, 6, 16), 150), "b": jnp.zeros(16)},
        "fc1": {"w": conv(ks[2], (400, 120), 400), "b": jnp.zeros(120)},
        "fc2": {"w": conv(ks[3], (120, 84), 120), "b": jnp.zeros(84)},
        "fc3": {"w": conv(ks[4], (84, NUM_CLASSES), 84),
                "b": jnp.zeros(NUM_CLASSES)},
    }


def _pool(x: jax.Array) -> jax.Array:
    return lax.reduce_window(
        x, -jnp.inf, lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def lenet_forward(params: Params, images: jax.Array) -> jax.Array:
    """Logits for a (B, 32, 32, 1) float batch."""
    # traffic tap: the conv kernels are the Table-I weight stream and the
    # batch the input stream.  One None test when no capture is active;
    # tracer payloads (jitted callers) are dropped whole by the tap.
    _obs_hooks.tap(
        "lenet.conv",
        conv1=params["conv1"]["w"],
        conv2=params["conv2"]["w"],
        inputs=images,
    )
    x = lax.conv_general_dilated(
        images, params["conv1"]["w"], (1, 1), "VALID", dimension_numbers=_DN
    ) + params["conv1"]["b"]
    x = _pool(jnp.tanh(x))
    x = lax.conv_general_dilated(
        x, params["conv2"]["w"], (1, 1), "VALID", dimension_numbers=_DN
    ) + params["conv2"]["b"]
    x = _pool(jnp.tanh(x))
    x = x.reshape(x.shape[0], -1)  # (B, 400)
    x = jnp.tanh(x @ params["fc1"]["w"] + params["fc1"]["b"])
    x = jnp.tanh(x @ params["fc2"]["w"] + params["fc2"]["b"])
    return x @ params["fc3"]["w"] + params["fc3"]["b"]


@functools.lru_cache(maxsize=8)
def _templates(seed: int) -> np.ndarray:
    """One deterministic smooth 32x32 template per class (box-filtered
    noise, the ``benchmarks/datagen`` recipe) — a separable-by-construction
    10-way task so a few hundred SGD steps visibly learn it."""
    rng = np.random.default_rng(seed)
    raw = rng.normal(size=(NUM_CLASSES, 40, 40)).astype(np.float32)
    k = np.ones((9, 9), np.float32) / 81.0
    out = np.empty((NUM_CLASSES, 32, 32), np.float32)
    for c in range(NUM_CLASSES):
        acc = np.zeros((32, 32), np.float32)
        for i in range(9):
            for j in range(9):
                acc += k[i, j] * raw[c, i : i + 32, j : j + 32]
        out[c] = acc / max(np.abs(acc).max(), 1e-6)
    return out


def synth_batch(
    key: jax.Array, batch: int = 64, seed: int = 0, noise: float = 0.3
) -> tuple[jax.Array, jax.Array]:
    """(images (B,32,32,1), labels (B,)) — class template + fresh noise."""
    tpl = jnp.asarray(_templates(seed))
    k1, k2 = jax.random.split(key)
    labels = jax.random.randint(k1, (batch,), 0, NUM_CLASSES)
    imgs = tpl[labels] + noise * jax.random.normal(
        k2, (batch, 32, 32), jnp.float32
    )
    return imgs[..., None], labels


def _loss(params: Params, images: jax.Array, labels: jax.Array) -> jax.Array:
    logits = lenet_forward(params, images)
    lp = jax.nn.log_softmax(logits.astype(jnp.float32))
    return -jnp.take_along_axis(lp, labels[:, None], axis=-1).mean()


def train_lenet(
    steps: int = 300,
    batch: int = 64,
    lr: float = 0.05,
    momentum: float = 0.9,
    weight_decay: float = 1e-3,
    seed: int = 0,
    ckpt_dir: str | None = None,
) -> tuple[Params, dict]:
    """Train (or restore) the LeNet; returns (params, info).

    With ``ckpt_dir`` set and a checkpoint present the training loop is
    skipped entirely and the stored weights come back
    (``info["restored"] is True``) — how CI caches the trained model.
    SGD + momentum + weight decay: the decay term is what makes the int8
    weight image honestly cluster around zero.
    """
    key = jax.random.key(seed)
    params = init_lenet(key)

    manager = None
    if ckpt_dir is not None:
        from repro.checkpoint import CheckpointManager

        manager = CheckpointManager(ckpt_dir, keep=1)
        if manager.latest_step() is not None:
            tree, extra, step = manager.restore(params)
            return tree, {
                "restored": True,
                "steps": step,
                "final_loss": extra.get("final_loss"),
            }

    @jax.jit
    def sgd_step(params, vel, images, labels):
        loss, grads = jax.value_and_grad(_loss)(params, images, labels)
        vel = jax.tree.map(lambda v, g: momentum * v + g, vel, grads)
        params = jax.tree.map(
            lambda p, v: p - lr * (v + weight_decay * p), params, vel
        )
        return params, vel, loss

    vel = jax.tree.map(jnp.zeros_like, params)
    loss = jnp.float32(0.0)
    for i in range(steps):
        key, sub = jax.random.split(key)
        images, labels = synth_batch(sub, batch=batch, seed=seed)
        params, vel, loss = sgd_step(params, vel, images, labels)
    final_loss = float(loss)

    if manager is not None:
        manager.save(steps, params, extra={"final_loss": final_loss})
    return params, {
        "restored": False, "steps": steps, "final_loss": final_loss,
    }
