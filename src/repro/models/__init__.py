from .config import ModelConfig, MoEConfig, SSMConfig
from .transformer import (
    decode_step,
    encdec_forward,
    forward,
    init_cache,
    init_params,
    lm_loss,
    param_shapes,
    prefill,
    unembed,
)

__all__ = [
    "ModelConfig",
    "MoEConfig",
    "SSMConfig",
    "init_params",
    "param_shapes",
    "forward",
    "encdec_forward",
    "lm_loss",
    "unembed",
    "prefill",
    "decode_step",
    "init_cache",
]
