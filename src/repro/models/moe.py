"""Mixture-of-Experts layer: top-k routing with grouped, capacity-bounded
dispatch (GShard-style) sized so the dispatch tensors stay small.

Memory shape analysis (DESIGN.md §5): with group size ``gs`` the dispatch
one-hot is (G, gs, E, C) with C = gs*k*cf/E, i.e. total = T * gs * k * cf
elements *independent of E* — small groups bound dispatch memory.  The
choice-level one-hot (G, gs*k, E, C) is never materialised: dispatch/combine
are accumulated over the k choices in a short unrolled loop.

Expert sharding: experts live on the "model" mesh axis.  Counts that don't
divide the axis (granite's 40) are padded (``pad_experts_to``) and the router
masks padded experts to -inf, so they receive no tokens and contribute no
FLOPs worth of useful work but keep GSPMD shardings legal.

Token permutation hook (the paper's technique): tokens inside an expert's
capacity buffer are an *unordered set* — ``repro.traffic`` exploits this by
popcount-bucket-ordering dispatch buffers before they cross ICI.
"""

from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro import _obs_hooks

from .config import ModelConfig
from .layers import dense_init

Params = Dict[str, Any]


def init_moe(key: jax.Array, cfg: ModelConfig) -> Params:
    m = cfg.moe
    pdt = jnp.dtype(cfg.param_dtype)
    d, ff = cfg.d_model, m.d_ff_expert
    e = m.padded_experts
    ks = jax.random.split(key, 5)
    p: Params = {
        "router": dense_init(ks[0], (d, e), d, pdt),
        "gate": dense_init(ks[1], (e, d, ff), d, pdt),
        "up": dense_init(ks[2], (e, d, ff), d, pdt),
        "down": dense_init(ks[3], (e, ff, d), ff, pdt),
    }
    if m.num_shared_experts:
        p["shared_gate"] = dense_init(ks[4], (d, ff * m.num_shared_experts), d, pdt)
        p["shared_up"] = dense_init(ks[4], (d, ff * m.num_shared_experts), d, pdt)
        p["shared_down"] = dense_init(ks[4], (ff * m.num_shared_experts, d), ff, pdt)
    return p


def capacity(cfg: ModelConfig, group_size: int) -> int:
    m = cfg.moe
    return max(1, math.ceil(group_size * m.top_k * m.capacity_factor / m.num_experts))


def moe_block(
    params: Params, x: jax.Array, cfg: ModelConfig, dropless: bool = False
) -> tuple[jax.Array, jax.Array]:
    """Top-k MoE.  x: (B, S, d) -> (y, aux_loss).

    ``dropless=True`` sets capacity = group size (no token can ever be
    dropped); used on the decode path, where capacity drops would make
    serving non-deterministic w.r.t. batch composition.
    """
    m = cfg.moe
    dt = x.dtype
    bsz, s, d = x.shape
    t = bsz * s
    gs = min(m.group_size, t)
    if t % gs:
        gs = t  # smoke-test fallback: one group
    g = t // gs
    c = gs if dropless else min(capacity(cfg, gs), gs)
    e = m.padded_experts
    xg = x.reshape(g, gs, d)

    logits = (xg @ params["router"].astype(dt)).astype(jnp.float32)  # (G,gs,E)
    if e > m.num_experts:  # mask padded experts
        pad_mask = jnp.arange(e) >= m.num_experts
        logits = jnp.where(pad_mask[None, None, :], -1e30, logits)
    probs_all = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs_all, m.top_k)  # (G,gs,k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # position of each (token, choice) within its expert's capacity buffer
    flat_e = top_e.reshape(g, gs * m.top_k)
    oh = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)  # (G, gs*k, E)
    pos_all = jnp.cumsum(oh, axis=1) - 1  # (G, gs*k, E)
    pos = jnp.take_along_axis(pos_all, flat_e[..., None], axis=-1)[..., 0]
    pos = pos.reshape(g, gs, m.top_k)
    keep = pos < c

    dispatch = jnp.zeros((g, gs, e, c), dt)
    combine = jnp.zeros((g, gs, e, c), dt)
    for j in range(m.top_k):  # accumulate per choice; never materialise k*E*C
        ohe = jax.nn.one_hot(top_e[:, :, j], e, dtype=dt)
        ohc = jax.nn.one_hot(pos[:, :, j], c, dtype=dt)
        sel = (ohe[..., :, None] * ohc[..., None, :]) * keep[:, :, j, None, None].astype(dt)
        dispatch = dispatch + sel
        combine = combine + sel * top_p[:, :, j, None, None].astype(dt)

    expert_in = jnp.einsum("gsec,gsd->gecd", dispatch, xg)
    # traffic tap: expert_in is exactly the ICI dispatch payload.  Under
    # jit it is a tracer and the tap drops the firing whole; eager capture
    # drivers record real dispatch bytes.
    _obs_hooks.tap("moe.dispatch", expert_in=expert_in)
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", expert_in, params["gate"].astype(dt)))
    h = h * jnp.einsum("gecd,edf->gecf", expert_in, params["up"].astype(dt))
    expert_out = jnp.einsum("gecf,efd->gecd", h, params["down"].astype(dt))
    y = jnp.einsum("gsec,gecd->gsd", combine, expert_out)

    if m.num_shared_experts:
        sh = jax.nn.silu(xg @ params["shared_gate"].astype(dt)) * (
            xg @ params["shared_up"].astype(dt)
        )
        y = y + sh @ params["shared_down"].astype(dt)

    # Switch-style load-balance loss over the real experts
    me = probs_all[..., : m.num_experts].mean(axis=(0, 1))  # mean router prob
    ce = (
        jax.nn.one_hot(top_e[..., 0], e, dtype=jnp.float32)[..., : m.num_experts]
        .mean(axis=(0, 1))
    )  # fraction of tokens whose top-1 is e
    aux = jnp.sum(me * ce) * (m.num_experts**1) * m.router_aux_weight
    return y.reshape(bsz, s, d), aux.astype(jnp.float32)
