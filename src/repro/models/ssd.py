"""Mamba-2 / SSD (state-space duality) block [arXiv:2405.21060].

Implements the chunked SSD algorithm: intra-chunk terms are dense matmuls
(MXU-friendly), inter-chunk state is carried by a short ``lax.scan`` over
chunk boundaries — so sequence memory is O(S * Lc) instead of O(S^2) and the
carried state is (B, H, N, P) only at chunk edges.

Decode is the exact recurrence ``h = exp(dt*A) h + dt * B ⊗ x`` with a
rolling causal-conv cache, giving O(1) state per token — which is why the
``long_500k`` shape runs for the SSM/hybrid archs only (DESIGN.md §4).

Einsum index conventions: b=batch, c=chunk, l/m=position-in-chunk, h=head,
n=state dim, p=head dim.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax import lax

from .config import ModelConfig
from .layers import dense_init, rms_norm

Params = Dict[str, Any]


def ssm_dims(cfg: ModelConfig) -> tuple[int, int, int, int, int]:
    """(d_inner, n_heads, head_dim, n_groups, d_state)."""
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    if d_inner % s.head_dim:
        raise ValueError(f"d_inner {d_inner} not divisible by head_dim {s.head_dim}")
    return d_inner, d_inner // s.head_dim, s.head_dim, s.n_groups, s.d_state


def init_ssd(key: jax.Array, cfg: ModelConfig) -> Params:
    pdt = jnp.dtype(cfg.param_dtype)
    d = cfg.d_model
    s = cfg.ssm
    d_inner, n_heads, _, n_groups, d_state = ssm_dims(cfg)
    d_xbc = d_inner + 2 * n_groups * d_state
    ks = jax.random.split(key, 4)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * d_inner + 2 * n_groups * d_state + n_heads), d, pdt),
        "conv_w": dense_init(ks[1], (s.d_conv, d_xbc), s.d_conv, pdt),
        "conv_b": jnp.zeros((d_xbc,), pdt),
        "a_log": jnp.log(jnp.arange(1, n_heads + 1, dtype=jnp.float32)).astype(pdt),
        "dt_bias": jnp.zeros((n_heads,), pdt),
        "d_skip": jnp.ones((n_heads,), pdt),
        "norm_w": jnp.ones((d_inner,), pdt),
        "out_proj": dense_init(ks[3], (d_inner, d), d_inner, pdt),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over time.  x: (B, S, C); w: (K, C)."""
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(k):  # K is tiny (4): unrolled taps fuse into one kernel
        out = out + pad[:, i : i + x.shape[1], :] * w[i][None, None, :]
    return out + b[None, None, :]


def _split_xbc(xbc: jax.Array, cfg: ModelConfig):
    d_inner, n_heads, hd, n_groups, d_state = ssm_dims(cfg)
    x = xbc[..., :d_inner]
    bmat = xbc[..., d_inner : d_inner + n_groups * d_state]
    cmat = xbc[..., d_inner + n_groups * d_state :]
    bsz, s = x.shape[:2]
    x = x.reshape(bsz, s, n_heads, hd)
    rep = n_heads // n_groups
    bmat = jnp.repeat(bmat.reshape(bsz, s, n_groups, d_state), rep, axis=2)
    cmat = jnp.repeat(cmat.reshape(bsz, s, n_groups, d_state), rep, axis=2)
    return x, bmat, cmat


def ssd_scan(
    x: jax.Array,  # (B, S, H, P)
    dt: jax.Array,  # (B, S, H)  (post-softplus)
    a: jax.Array,  # (H,) negative decay rates
    bmat: jax.Array,  # (B, S, H, N)
    cmat: jax.Array,  # (B, S, H, N)
    chunk: int,
    h0: jax.Array | None = None,  # (B, H, N, P) initial state
    unroll: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD: returns (y (B,S,H,P), final_state (B,H,N,P))."""
    bsz, s, h, p = x.shape
    n = bmat.shape[-1]
    lc = min(chunk, s)
    if s % lc:
        raise ValueError(f"seq {s} not divisible by chunk {lc}")
    nc = s // lc
    xf = x.astype(jnp.float32).reshape(bsz, nc, lc, h, p)
    dtf = dt.astype(jnp.float32).reshape(bsz, nc, lc, h)
    bf = bmat.astype(jnp.float32).reshape(bsz, nc, lc, h, n)
    cf = cmat.astype(jnp.float32).reshape(bsz, nc, lc, h, n)

    da = dtf * a[None, None, None, :]  # log-decay per step
    cum = jnp.cumsum(da, axis=2)  # (B, C, L, H)
    # intra-chunk: M[l,m] = (C_l . B_m) * exp(cum_l - cum_m) * dt_m  (l >= m)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,C,L,M,H)
    tril = jnp.tril(jnp.ones((lc, lc), bool))
    seg = jnp.where(tril[None, None, :, :, None], seg, -jnp.inf)
    mmat = jnp.einsum("bclhn,bcmhn->bclmh", cf, bf) * jnp.exp(seg) * dtf[:, :, None, :, :]
    y_intra = jnp.einsum("bclmh,bcmhp->bclhp", mmat, xf)

    # chunk states: S_c = sum_m exp(cum_last - cum_m) dt_m B_m (x) x_m
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)  # (B,C,L,H)
    s_c = jnp.einsum("bclh,bclhn,bclhp->bchnp", decay_to_end * dtf, bf, xf)
    t_c = jnp.exp(cum[:, :, -1, :])  # (B, C, H) total chunk decay

    def step(hprev, inputs):
        sc, tc = inputs  # (B,H,N,P), (B,H)
        hnew = hprev * tc[..., None, None] + sc
        return hnew, hprev

    hinit = (
        jnp.zeros((bsz, h, n, p), jnp.float32)
        if h0 is None
        else h0.astype(jnp.float32)
    )
    hlast, hprevs = lax.scan(
        step,
        hinit,
        (jnp.moveaxis(s_c, 1, 0), jnp.moveaxis(t_c, 1, 0)),
        unroll=unroll,
    )
    hprevs = jnp.moveaxis(hprevs, 0, 1)  # (B, C, H, N, P) state entering chunk
    y_inter = jnp.einsum("bclhn,bchnp->bclhp", cf * jnp.exp(cum)[..., None], hprevs)
    y = (y_intra + y_inter).reshape(bsz, s, h, p)
    return y.astype(x.dtype), hlast


def ssd_block(
    params: Params, x: jax.Array, cfg: ModelConfig, return_cache: bool = False
):
    """Full-sequence Mamba-2 block (training / prefill).  x: (B, S, d).

    With ``return_cache`` also returns the decode cache (final SSM state +
    causal-conv tail) so prefill can hand off to ``ssd_decode``.
    """
    dt_ = x.dtype
    d_inner, n_heads, hd, n_groups, d_state = ssm_dims(cfg)
    proj = x @ params["in_proj"].astype(dt_)
    z = proj[..., :d_inner]
    xbc_raw = proj[..., d_inner : -n_heads]
    dt_raw = proj[..., -n_heads:]
    xbc = jax.nn.silu(
        _causal_conv(xbc_raw, params["conv_w"].astype(dt_), params["conv_b"].astype(dt_))
    )
    xs, bmat, cmat = _split_xbc(xbc, cfg)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    y, hlast = ssd_scan(
        xs, dt, a, bmat, cmat, cfg.ssm.chunk, unroll=not cfg.scan_layers
    )
    y = y + xs * params["d_skip"].astype(dt_)[None, None, :, None]
    y = y.reshape(*x.shape[:2], d_inner)
    y = rms_norm(y * jax.nn.silu(z), params["norm_w"], cfg.rms_eps)
    out = y @ params["out_proj"].astype(dt_)
    if not return_cache:
        return out
    k = cfg.ssm.d_conv - 1
    # cache layout matches init_ssd_cache: state (B, H, N, P), conv tail raw
    cache = {"state": hlast, "conv": xbc_raw[:, -k:, :].astype(dt_)}
    return out, cache


def init_ssd_cache(cfg: ModelConfig, batch: int, dtype) -> Params:
    d_inner, n_heads, hd, n_groups, d_state = ssm_dims(cfg)
    d_xbc = d_inner + 2 * n_groups * d_state
    return {
        "state": jnp.zeros((batch, n_heads, d_state, hd), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm.d_conv - 1, d_xbc), dtype),
    }


def ssd_decode(
    params: Params, x: jax.Array, cache: Params, cfg: ModelConfig
) -> tuple[jax.Array, Params]:
    """Single-token decode.  x: (B, 1, d); O(1) state update."""
    dt_ = x.dtype
    d_inner, n_heads, hd, n_groups, d_state = ssm_dims(cfg)
    proj = x @ params["in_proj"].astype(dt_)
    z = proj[..., :d_inner]
    xbc = proj[..., d_inner : -n_heads]
    dt_raw = proj[..., -n_heads:]

    # rolling causal-conv cache: window = [conv_cache, xbc_t]
    win = jnp.concatenate([cache["conv"], xbc], axis=1)  # (B, K, d_xbc)
    w = params["conv_w"].astype(dt_)
    conv_out = jnp.einsum("bkc,kc->bc", win, w) + params["conv_b"].astype(dt_)
    xbc_t = jax.nn.silu(conv_out)[:, None, :]
    new_conv = win[:, 1:, :]

    xs, bmat, cmat = _split_xbc(xbc_t, cfg)  # (B,1,H,P), (B,1,H,N)
    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32)
    )[:, 0]  # (B,H)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    decay = jnp.exp(dt * a[None, :])  # (B,H)
    xs32 = xs.astype(jnp.float32)[:, 0]
    b32 = bmat.astype(jnp.float32)[:, 0]
    c32 = cmat.astype(jnp.float32)[:, 0]
    state = cache["state"] * decay[..., None, None] + jnp.einsum(
        "bh,bhn,bhp->bhnp", dt, b32, xs32
    )
    y = jnp.einsum("bhn,bhnp->bhp", c32, state).astype(dt_)
    y = y + xs[:, 0] * params["d_skip"].astype(dt_)[None, :, None]
    y = y.reshape(x.shape[0], 1, d_inner)
    y = rms_norm(y * jax.nn.silu(z), params["norm_w"], cfg.rms_eps)
    return y @ params["out_proj"].astype(dt_), {"state": state, "conv": new_conv}
