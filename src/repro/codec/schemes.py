"""Low-power link codecs: encode/decode pairs over flit streams.

The paper attacks link switching with *ordering*; this module holds the
classic *coding* family it must answer against (DESIGN.md §11): every codec
is a bijective transform of a ``(T, lanes)`` uint8 flit stream into the
wire image the link actually drives, with a decoder that recovers the data
exactly — ``decode(encode(x)) == x`` is the subsystem contract, asserted in
``tests/test_codec.py`` for every registered scheme.

  * ``none``            — identity (the uncoded wire).
  * ``gray`` / ``sign_magnitude`` — stateless per-byte recodes
    (``repro.core.coding``); no extra wires, no state.
  * ``transition``      — XOR transition signaling: wire_t = wire_{t-1} ^
    data_t, so the wire *transitions* carry the data and the stream BT
    equals the total '1'-bit count of the data flits.
  * ``bus_invert``      — Stan & Burleson bus-invert, partitioned: each
    ``partition``-lane group carries one extra invert line; a flit group is
    transmitted complemented whenever that halves its Hamming distance to
    the previous *wire* flit (invert iff HD > half the group width, ties
    uninverted).  The invert lines are real wires whose own transitions are
    the codec's overhead (``repro.codec.overhead``).

Encoders here are whole-stream jnp (the staged/reference path: ``lax.scan``
for the sequential bus-invert decision).  The hot path — every
codec x ordering measured in ONE Pallas launch — is
``repro.kernels.bt_count_codecs``, which re-expresses the scan as a
prefix-XOR with tie resets and is pinned bit-exact against compositions of
the encoders in this module.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.coding import (
    bus_invert_partitions,
    gray_decode_bytes,
    gray_encode_bytes,
    sign_magnitude_decode_bytes,
    sign_magnitude_encode_bytes,
)
from repro.core.popcount import popcount

__all__ = [
    "CodedStream",
    "Codec",
    "CODECS",
    "SCHEMES",
    "codec_by_name",
    "make_bus_invert",
    "register_codec",
    "bus_invert_partitions",
    "invert_line_transitions",
]

# static scheme ids understood by the Pallas codec kernel
SCHEMES = ("none", "gray", "sign_magnitude", "transition", "bus_invert")


class CodedStream(NamedTuple):
    """A codec's wire image: the driven byte lanes plus any invert lines.

    ``wire`` is (T, lanes) uint8; ``invert`` is (T, P) uint8 bus-invert
    line states (one column per partition), or ``None`` for codecs with no
    extra wires.
    """

    wire: jax.Array
    invert: Optional[jax.Array] = None


def invert_line_transitions(invert: Optional[jax.Array]) -> jax.Array:
    """Total transitions of the invert lines themselves (the coding
    overhead the link still pays switching energy for)."""
    if invert is None or invert.shape[0] < 2:
        return jnp.int32(0)
    inv = invert.astype(jnp.int32)
    return jnp.sum(inv[1:] != inv[:-1]).astype(jnp.int32)


@dataclasses.dataclass(frozen=True)
class Codec:
    """One registered link codec: a named encode/decode pair.

    ``scheme`` is the static id the Pallas kernel switches on; ``partition``
    is the bus-invert group width in lanes (None = whole flit).
    ``stateful`` marks codecs whose wire image depends on flit order (they
    must be applied to the *assembled* stream, after ordering and packing —
    the composition semantics of DESIGN.md §11).
    """

    name: str
    scheme: str
    encode: Callable[[jax.Array], CodedStream]
    decode: Callable[[CodedStream], jax.Array]
    partition: int | None = None
    stateful: bool = False

    def extra_wires(self, lanes: int) -> int:
        """Invert lines added next to a ``lanes``-byte flit."""
        if self.scheme != "bus_invert":
            return 0
        return bus_invert_partitions(lanes, self.partition)[0]


# --------------------------------------------------------------------------
# stateless schemes
# --------------------------------------------------------------------------


def _stateless(fn: Callable[[jax.Array], jax.Array]):
    def encode(stream: jax.Array) -> CodedStream:
        return CodedStream(fn(stream.astype(jnp.uint8)), None)

    return encode


def _stateless_decode(fn: Callable[[jax.Array], jax.Array]):
    def decode(coded: CodedStream) -> jax.Array:
        return fn(coded.wire.astype(jnp.uint8))

    return decode


# --------------------------------------------------------------------------
# transition signaling
# --------------------------------------------------------------------------


def transition_encode(stream: jax.Array) -> CodedStream:
    """wire_t = wire_{t-1} ^ data_t (wire_0 = data_0): data rides in the
    wire *transitions*, so the stream's BT is exactly the total popcount of
    the data flits after the first."""
    d = stream.astype(jnp.uint8)
    wire = lax.associative_scan(jnp.bitwise_xor, d, axis=0)
    return CodedStream(wire, None)


def transition_decode(coded: CodedStream) -> jax.Array:
    w = coded.wire.astype(jnp.uint8)
    return jnp.concatenate([w[:1], w[1:] ^ w[:-1]], axis=0)


# --------------------------------------------------------------------------
# bus invert
# --------------------------------------------------------------------------


def bus_invert_encode(
    stream: jax.Array, partition: int | None = None
) -> CodedStream:
    """Sequential bus-invert over a flit stream (the hardware recurrence).

    Flit 0 is transmitted uninverted; each later flit group is complemented
    iff that strictly lowers its Hamming distance to the previous wire flit
    (HD > half the group width; ties uninverted).  This ``lax.scan`` is the
    reference formulation the single-launch kernel's prefix-scan is pinned
    against.
    """
    t, lanes = stream.shape
    npart, pw = bus_invert_partitions(lanes, partition)
    d = stream.astype(jnp.int32).reshape(t, npart, pw)
    lbits = 8 * pw

    def step(prev_wire, dt):
        hd = popcount(dt ^ prev_wire, 8).sum(axis=-1)  # (P,)
        inv = (2 * hd > lbits).astype(jnp.int32)
        wt = dt ^ (inv[:, None] * 0xFF)
        return wt, (wt, inv)

    _, (wires, invs) = lax.scan(step, d[0], d[1:])
    wire = jnp.concatenate([d[:1], wires], axis=0).reshape(t, lanes)
    inv = jnp.concatenate(
        [jnp.zeros((1, npart), jnp.int32), invs], axis=0
    )
    return CodedStream(wire.astype(jnp.uint8), inv.astype(jnp.uint8))


def bus_invert_decode(coded: CodedStream) -> jax.Array:
    t, lanes = coded.wire.shape
    npart = coded.invert.shape[-1]
    _, pw = bus_invert_partitions(lanes, lanes // npart)
    w = coded.wire.astype(jnp.int32).reshape(t, npart, pw)
    inv = coded.invert.astype(jnp.int32)
    return (w ^ (inv[:, :, None] * 0xFF)).reshape(t, lanes).astype(jnp.uint8)


# --------------------------------------------------------------------------
# registry
# --------------------------------------------------------------------------

CODECS: Dict[str, Codec] = {}


def register_codec(codec: Codec) -> Codec:
    if codec.scheme not in SCHEMES:
        raise ValueError(
            f"unknown codec scheme {codec.scheme!r}; choose from {SCHEMES}"
        )
    CODECS[codec.name] = codec
    return codec


def make_bus_invert(
    partition: int | None = None, name: str | None = None
) -> Codec:
    """A bus-invert codec with one invert line per ``partition`` lanes
    (None = a single line over the whole flit)."""
    if name is None:
        name = "bus_invert" if partition is None else f"bus_invert{partition}"
    return Codec(
        name=name,
        scheme="bus_invert",
        encode=lambda s, _p=partition: bus_invert_encode(s, _p),
        decode=bus_invert_decode,
        partition=partition,
        stateful=True,
    )


def codec_by_name(name: str) -> Codec:
    """Registry lookup; unknown names list every registered codec."""
    codec = CODECS.get(name)
    if codec is None:
        raise ValueError(
            f"unknown codec {name!r}; registered codecs: "
            f"{', '.join(sorted(CODECS))}"
        )
    return codec


register_codec(
    Codec("none", "none", _stateless(lambda s: s), _stateless_decode(lambda s: s))
)
register_codec(
    Codec(
        "gray",
        "gray",
        _stateless(gray_encode_bytes),
        _stateless_decode(gray_decode_bytes),
    )
)
register_codec(
    Codec(
        "sign_magnitude",
        "sign_magnitude",
        _stateless(sign_magnitude_encode_bytes),
        _stateless_decode(sign_magnitude_decode_bytes),
    )
)
register_codec(
    Codec(
        "transition",
        "transition",
        transition_encode,
        transition_decode,
        stateful=True,
    )
)
register_codec(make_bus_invert(None))
register_codec(make_bus_invert(4))
