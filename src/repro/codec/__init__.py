# The low-power link-coding subsystem (DESIGN.md §11): the classic coding
# family (bus-invert / gray / sign-magnitude / transition signaling) the
# paper's ordering approach is compared against — and composed with.
#   schemes.py  - encode/decode codec pairs over flit streams + registry
#   stage.py    - registration into the repro.link stage machinery
#   overhead.py - invert-line / extra-wire and encoder-area accounting
#   compare.py  - ordering vs coding vs composed comparison tables, one
#                 single-launch bt_count_codecs measurement per stream
from .compare import ComparisonRow, compare_streams, demo_workloads, format_table
from .overhead import CodecOverhead, codec_overhead, coded_energy_pj
from .schemes import (
    CODECS,
    SCHEMES,
    Codec,
    CodedStream,
    bus_invert_partitions,
    codec_by_name,
    invert_line_transitions,
    make_bus_invert,
    register_codec,
)
from .stage import CODEC_STAGES, encode_stream, kernel_config, wire_codec

__all__ = [
    "Codec",
    "CodedStream",
    "CODECS",
    "CODEC_STAGES",
    "SCHEMES",
    "codec_by_name",
    "make_bus_invert",
    "register_codec",
    "bus_invert_partitions",
    "invert_line_transitions",
    "wire_codec",
    "encode_stream",
    "kernel_config",
    "CodecOverhead",
    "codec_overhead",
    "coded_energy_pj",
    "ComparisonRow",
    "compare_streams",
    "format_table",
    "demo_workloads",
]
