"""Codec overhead accounting: extra wires, encoder area, honest energy.

A codec is never free: bus-invert adds one physical invert line per
partition (whose own transitions burn switching energy and whose flop
widens the clocked register bank), and every scheme adds encoder logic at
the link interface.  This module rolls those costs up so every comparison
in ``repro.codec.compare`` / ``repro.dse`` is *net of overhead*:

  * wire overhead   — ``Codec.extra_wires`` per flit, and the invert-line
    transitions measured alongside data BT (the third column of
    ``repro.kernels.bt_count_codecs``);
  * area overhead   — the ``repro.core.area.codec_area`` gate-count model,
    folded into ``PSUArea.codec`` by ``repro.dse.evaluate``;
  * energy          — ``LinkPowerModel.coded_link_energy_pj`` charges aux
    transitions at the data rate and scales the static floor by the
    widened wire count.
"""

from __future__ import annotations

import dataclasses

from repro.core.area import codec_area
from repro.link.power import LinkPowerModel

from .schemes import Codec, codec_by_name

__all__ = ["CodecOverhead", "codec_overhead", "coded_energy_pj"]


@dataclasses.dataclass(frozen=True)
class CodecOverhead:
    """What one codec costs on an L-byte-lane link."""

    codec: str
    data_wires: int  # 8 * lanes: the wires the link had anyway
    extra_wires: int  # invert lines added beside them
    encoder_area_um2: float

    @property
    def wire_overhead(self) -> float:
        """Fractional widening of the physical link."""
        return self.extra_wires / self.data_wires


def _resolve(codec: Codec | str) -> Codec:
    return codec if isinstance(codec, Codec) else codec_by_name(codec)


def codec_overhead(codec: Codec | str, lanes: int) -> CodecOverhead:
    """Wire + encoder-area overhead of ``codec`` on a ``lanes``-byte flit."""
    c = _resolve(codec)
    return CodecOverhead(
        codec=c.name,
        data_wires=8 * lanes,
        extra_wires=c.extra_wires(lanes),
        encoder_area_um2=codec_area(c.scheme, lanes, c.partition),
    )


def coded_energy_pj(
    power: LinkPowerModel,
    codec: Codec | str,
    data_bt: float,
    aux_bt: float,
    num_flits: int,
    lanes: int,
) -> float:
    """Stream energy under ``power``, charging the codec's added lines."""
    ov = codec_overhead(codec, lanes)
    return power.coded_link_energy_pj(
        data_bt, aux_bt, num_flits, ov.data_wires, ov.extra_wires
    )
