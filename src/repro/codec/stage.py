"""Codec registration into the ``repro.link`` stage machinery.

The TX pipeline's stage registries (DESIGN.md §3.2) gain a fourth axis
here: ``CODEC_STAGES`` is the wire-coding registry a ``LinkSpec.codec``
name resolves against, so any ``TxPipeline``, any ``repro.noc`` per-link
stream and any ``repro.dse.DesignPoint`` can name a codec the same way
they name key/encode/pack stages.  Composition semantics (DESIGN.md §11):

  * the ENCODE stage is *element-level* (applied before the KEY stage, so
    sort keys see the recoded bytes) — the stateless codecs double as
    encode stages ('gray', 'sign_magnitude', registered in
    ``repro.link.stages`` itself so they exist without this import);
  * the CODEC stage is *wire-level* (applied to the assembled flit stream,
    after ordering and packing, keys derived from the un-coded bytes) —
    this is where the stateful codecs (bus-invert, transition signaling)
    must sit, because their wire image depends on flit order.

``kernel_config`` maps a spec's (ordering, codec) selection onto the
static :class:`~repro.kernels.CodecVariant` the single-launch
measurement kernel consumes.
"""

from __future__ import annotations

from typing import Dict

import jax

from repro.kernels import CodecVariant
from repro.link.spec import LinkSpec
from repro.link.stages import lookup_stage

from .schemes import CODECS, Codec, CodedStream, codec_by_name

__all__ = [
    "CODEC_STAGES",
    "wire_codec",
    "encode_stream",
    "kernel_config",
]

# the wire-coding stage registry: the same mapping LinkSpec validates its
# `codec` field against (one home — repro.codec.schemes.CODECS).  The
# stateless codecs' element-level twins ('gray', 'sign_magnitude') are
# registered directly in repro.link.stages.ENCODE_STAGES, which the link
# layer provides without importing this package.
CODEC_STAGES: Dict[str, Codec] = CODECS


def wire_codec(name: str) -> Codec:
    """The registered codec for a ``LinkSpec.codec`` name (stage-UX errors:
    unknown names list the registered codecs)."""
    return lookup_stage("codec", name, CODEC_STAGES)


def encode_stream(stream: jax.Array, name: str) -> CodedStream:
    """Apply the named wire codec to an assembled (T, lanes) stream."""
    return wire_codec(name).encode(stream)


def kernel_config(spec: LinkSpec) -> CodecVariant:
    """The static single-launch kernel config measuring this spec's
    (ordering, codec) pair (``repro.kernels.bt_count_codecs``)."""
    codec = codec_by_name(spec.codec)
    return CodecVariant(
        key=spec.key,
        k=spec.k if spec.key == "app" else None,
        descending=spec.descending,
        codec=codec.scheme,
        partition=codec.partition,
    )
