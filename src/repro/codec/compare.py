"""Ordering vs coding vs ordering∘coding — the comparison tables.

The paper reduces link BT purely by popcount ordering; the classic
alternative is per-link *coding* (bus-invert et al., cf. Li et al.,
arXiv:2002.05293), and the NoC follow-up (arXiv:2509.00500) frames
reordering as composable with it.  ``compare_streams`` makes that a
measured three-way: every (ordering, codec) pair of a grid is scored on
the same packet streams, with ONE ``bt_count_codecs`` launch per stream
(the whole grid lives inside the launch), and every reduction is *net of
overhead* — invert-line transitions count against a codec, and the
baseline is the unordered, uncoded wire.

Workloads: any tuple of (P, elems) byte-packet streams.  The three
standard traffic families of this repo (conv patches, decode weight
streams, all-reduce gradient images) are available via
:func:`demo_workloads`; ``benchmarks/codec_bt.py`` runs the full table
over them and ``benchmarks/lenet_workload.py`` routes the LeNet conv link
through here.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import _obs_hooks as _obs
from repro.kernels import CodecVariant, Variant, bt_count_codecs
from repro.link import LinkPowerModel

from .overhead import codec_overhead
from .schemes import codec_by_name

__all__ = [
    "ComparisonRow",
    "compare_streams",
    "format_table",
    "demo_workloads",
]

_BASELINE = Variant("none", None, False)


@dataclasses.dataclass(frozen=True)
class ComparisonRow:
    """One (ordering, codec) pair scored on one workload's streams."""

    workload: str
    ordering: str  # compact ordering label ('none', 'acc', 'app4', ...)
    codec: str
    data_bt: int
    aux_bt: int  # invert-line transitions (the codec's own switching)
    num_flits: int
    extra_wires: int
    data_wires: int
    bt_reduction: float  # net of overhead, vs the unordered uncoded wire
    power_reduction: float  # Fig. 6/7 transfer of bt_reduction
    energy_pj: float  # coded stream energy incl. widened static floor

    @property
    def gross_bt(self) -> int:
        """Data BT plus invert-line BT — what the reduction is scored on."""
        return self.data_bt + self.aux_bt

    @property
    def label(self) -> str:
        if self.codec == "none":
            return self.ordering
        return f"{self.ordering}+{self.codec}"


def _ordering_label(v: Variant) -> str:
    head = f"app{v.k}" if v.key == "app" else v.key
    return head + ("-desc" if v.descending else "")


def _as_variant(ordering) -> Variant:
    if isinstance(ordering, str):
        return Variant(ordering, None, False)
    return Variant(*ordering)


def compare_streams(
    streams: Sequence[jax.Array],
    lanes: int,
    *,
    orderings: Sequence[Variant | str] = ("none", Variant("acc"), Variant("app", 4)),
    codecs: Sequence[str] = ("none", "bus_invert"),
    width: int = 8,
    power: LinkPowerModel | None = None,
    workload: str = "stream",
    block_packets: int = 64,
    interpret: bool | None = None,
    backend: str | None = None,
    chunk_packets: int | None = None,
) -> tuple[ComparisonRow, ...]:
    """Score every (ordering, codec) pair on the same packet streams.

    Args:
      streams: (P, elems) byte-packet arrays, measured independently and
        summed (the Table-I conv setup streams inputs and weights on
        separate links).
      lanes: byte width of each measured flit.
      orderings: ``Variant`` configs (or bare key strings) for the paper's
        ordering axis.
      codecs: registered ``repro.codec`` names for the coding axis.

    Returns:
      One :class:`ComparisonRow` per pair, in grid order — the unordered
      uncoded baseline (always measured, prepended if absent) has
      ``bt_reduction == 0`` and everything else is relative to it, *net*
      of invert-line overhead.  All pairs are measured by ONE
      ``bt_count_codecs`` launch per stream.  ``backend`` selects the
      kernel execution path (pallas | compiled | interpret, DESIGN.md
      §13); ``chunk_packets`` streams each measurement in fixed-size
      packet chunks.
    """
    power = power if power is not None else LinkPowerModel()
    pairs = [(_as_variant(o), c) for o in orderings for c in codecs]
    if (_BASELINE, "none") not in pairs:
        pairs.insert(0, (_BASELINE, "none"))
    configs = tuple(
        CodecVariant(
            key=o.key,
            k=o.k,
            descending=o.descending,
            codec=codec_by_name(c).scheme,
            partition=codec_by_name(c).partition,
        )
        for o, c in pairs
    )

    totals = np.zeros((len(configs), 3), dtype=np.int64)
    num_flits = 0
    for si, s in enumerate(streams):
        s = jnp.asarray(s)
        if s.ndim != 2 or s.shape[-1] % lanes != 0:
            raise ValueError(
                f"streams must be (P, elems) with elems divisible by "
                f"lanes={lanes}, got {tuple(s.shape)}"
            )
        per_stream = np.asarray(
            bt_count_codecs(
                s,
                None,
                configs=configs,
                width=width,
                input_lanes=lanes,
                block_packets=block_packets,
                interpret=interpret,
                backend=backend,
                chunk_packets=chunk_packets,
            ),
            dtype=np.int64,
        )
        totals += per_stream
        if _obs.active():
            # baseline (unordered, uncoded) data BT of this one stream
            bi = pairs.index((_BASELINE, "none"))
            _obs.event(
                "codec.stream", workload=workload,
                stream=f"{workload}[{si}]",
                bt=int(per_stream[bi][:2].sum()),
                packets=int(s.shape[0]),
            )
        num_flits += int(s.shape[0]) * (int(s.shape[-1]) // lanes)

    base = int(totals[pairs.index((_BASELINE, "none"))][:2].sum())
    rows = []
    for (o, c), (bt_i, bt_w, aux) in zip(pairs, totals.tolist()):
        data_bt = int(bt_i) + int(bt_w)
        ov = codec_overhead(c, lanes)
        red = 1.0 - (data_bt + int(aux)) / max(base, 1)
        rows.append(
            ComparisonRow(
                workload=workload,
                ordering=_ordering_label(o),
                codec=c,
                data_bt=data_bt,
                aux_bt=int(aux),
                num_flits=num_flits,
                extra_wires=ov.extra_wires,
                data_wires=ov.data_wires,
                bt_reduction=red,
                power_reduction=power.power_reduction(red),
                energy_pj=power.coded_link_energy_pj(
                    data_bt, int(aux), num_flits, ov.data_wires, ov.extra_wires
                ),
            )
        )
    return tuple(rows)


def format_table(rows: Sequence[ComparisonRow]) -> str:
    """Aligned text table of comparison rows (the bench / example view)."""
    head = (
        f"{'workload':10s} {'config':22s} {'data BT':>10s} {'aux BT':>8s} "
        f"{'+wires':>6s} {'net red':>8s} {'power red':>9s} {'energy pJ':>11s}"
    )
    lines = [head, "-" * len(head)]
    for r in rows:
        lines.append(
            f"{r.workload:10s} {r.label:22s} {r.data_bt:10d} {r.aux_bt:8d} "
            f"{r.extra_wires:6d} {100 * r.bt_reduction:7.2f}% "
            f"{100 * r.power_reduction:8.2f}% {r.energy_pj:11.0f}"
        )
    return "\n".join(lines)


def demo_workloads(
    elems: int = 64,
    images: int = 4,
    weight_shape: tuple[int, int] = (96, 256),
    grad_size: int = 1 << 14,
    seed: int = 0,
) -> Mapping[str, tuple[jax.Array, ...]]:
    """The repo's three traffic families as (P, elems) packet streams.

      * ``conv``      — spatially-correlated im2col patch packets (the
        §IV-B conv-platform input link; same generator family as
        ``benchmarks/datagen.py``, inlined so ``src`` stays
        benchmark-free);
      * ``decode``    — a weight matrix's int8 HBM image (the decode
        weight-broadcast stream of ``repro.serve`` / ``repro.noc``);
      * ``allreduce`` — an int8 gradient wire image (the compressed
        collective of ``repro.optim``).
    """
    from repro.link import tensor_flit_stream
    from repro.traffic.ordering import int8_view

    rng = np.random.default_rng(seed)
    # conv: smoothed noise -> sparse strokes -> im2col patches, patch-major
    hw, kernel = 32, 5
    imgs = rng.normal(size=(images, hw, hw))
    for _ in range(2):
        imgs = (
            imgs
            + np.roll(imgs, 1, 1)
            + np.roll(imgs, -1, 1)
            + np.roll(imgs, 1, 2)
            + np.roll(imgs, -1, 2)
        ) / 5
    thr = np.quantile(imgs, 0.55, axis=(1, 2), keepdims=True)
    v = np.clip(imgs - thr, 0, None)
    v = (v / (v.max(axis=(1, 2), keepdims=True) + 1e-9) * 255).astype(np.uint8)
    out = hw - kernel + 1
    patches = np.lib.stride_tricks.sliding_window_view(
        v, (kernel, kernel), axis=(1, 2)
    ).reshape(images * out * out, kernel * kernel)
    conv = tensor_flit_stream(jnp.asarray(patches.reshape(-1)), elems)

    wmat = rng.normal(size=weight_shape).astype(np.float32)
    decode = tensor_flit_stream(
        jnp.ravel(int8_view(jnp.asarray(wmat)).astype(jnp.uint8)), elems
    )
    grad = (rng.standard_t(df=4, size=grad_size) * 1e-3).astype(np.float32)
    allreduce = tensor_flit_stream(
        int8_view(jnp.asarray(grad)).astype(jnp.uint8), elems
    )
    return {
        "conv": (conv,),
        "decode": (decode,),
        "allreduce": (allreduce,),
    }
