"""Train-step construction: loss, gradients, optimizer update, microbatching.

``make_train_step`` returns a pure function
``(params, opt_state, batch) -> (params, opt_state, metrics)`` suitable for
``jax.jit`` with donated arguments, on any mesh (shardings supplied by
``repro.launch``) or none (CPU smoke tests).

Gradient accumulation (``microbatches > 1``) lax.scans over batch slices,
trading activation memory for steps — one of the §Perf memory levers.
"""

from __future__ import annotations

from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp
from jax import lax

from repro import _obs_hooks
from repro.models import encdec_forward, forward, lm_loss
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig, OptState, update

Params = Any
Batch = Dict[str, jax.Array]


def make_loss_fn(cfg: ModelConfig) -> Callable[[Params, Batch], jax.Array]:
    fam = cfg.family

    def loss_fn(params: Params, batch: Batch) -> jax.Array:
        if fam in ("encdec", "audio"):
            h, aux = encdec_forward(params, cfg, batch["frames"], batch["tokens"])
        elif fam == "vlm":
            h, aux = forward(
                params, cfg, tokens=batch["tokens"], inputs_embeds=batch["patches"]
            )
        else:
            h, aux = forward(params, cfg, tokens=batch["tokens"])
        return lm_loss(params, cfg, h, batch["labels"]) + aux

    return loss_fn


def make_train_step(
    cfg: ModelConfig, opt_cfg: AdamWConfig, microbatches: int = 1
) -> Callable[[Params, OptState, Batch], tuple[Params, OptState, dict]]:
    loss_fn = make_loss_fn(cfg)
    grad_fn = jax.value_and_grad(loss_fn)

    def train_step(params: Params, opt_state: OptState, batch: Batch):
        if microbatches <= 1:
            loss, grads = grad_fn(params, batch)
        else:
            # STRIDED microbatch split (row i -> microbatch i mod mb): the
            # minor-factor reshape keeps every microbatch shard-local on the
            # data axis, so scan's xs-slicing needs no resharding (contiguous
            # splits crossed shard boundaries: measured 2x flops + permutes)
            def reshape_mb(x):
                r = x.reshape(x.shape[0] // microbatches, microbatches,
                              *x.shape[1:])
                return jnp.moveaxis(r, 1, 0)

            def body(carry, mbatch):
                acc, loss_acc = carry
                l, g = grad_fn(params, mbatch)
                acc = jax.tree.map(jnp.add, acc, g)
                return (acc, loss_acc + l), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (grads, loss), _ = lax.scan(
                body,
                (zeros, jnp.float32(0.0)),
                jax.tree.map(reshape_mb, batch),
                unroll=not cfg.scan_layers,  # dry-run cost pass unrolls
            )
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss / microbatches
        # traffic tap: the gradient tree is exactly the ring all-reduce
        # payload.  Under jit grads are tracers and the tap drops the
        # firing whole (jaxpr-identical); eager callers record real bytes.
        _obs_hooks.tap("train.grads", grads=grads)
        new_params, new_opt, metrics = update(opt_cfg, grads, opt_state, params)
        return new_params, new_opt, {"loss": loss, **metrics}

    return train_step
