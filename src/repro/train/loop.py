"""Fault-tolerant training loop.

Features (DESIGN.md §5):
  * restore-from-latest on start (params, optimizer, data-pipeline step);
  * periodic atomic checkpoints with integrity CRCs;
  * deterministic data sharding (restart/straggler safe);
  * optional simulated preemption (``fail_at_step``) used by the
    fault-tolerance tests to prove restart equivalence;
  * metrics log returned to the caller (and printed).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, SyntheticLMDataset
from repro.models import init_params
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig
from repro.optim import init as opt_init
from repro.train.step import make_train_step


@dataclasses.dataclass
class TrainLoopConfig:
    steps: int = 20
    checkpoint_every: int = 10
    checkpoint_dir: Optional[str] = None
    keep_checkpoints: int = 3
    microbatches: int = 1
    log_every: int = 1
    seed: int = 0
    fail_at_step: Optional[int] = None  # simulated preemption (tests)


class SimulatedPreemption(RuntimeError):
    pass


def train(
    cfg: ModelConfig,
    data_cfg: DataConfig,
    opt_cfg: AdamWConfig,
    loop: TrainLoopConfig,
    batch_transform: Optional[Callable[[dict], dict]] = None,
) -> dict[str, Any]:
    """Run (or resume) a training job.  Returns final state + metrics log."""
    dataset = SyntheticLMDataset(data_cfg)
    step_fn = jax.jit(
        make_train_step(cfg, opt_cfg, loop.microbatches), donate_argnums=(0, 1)
    )

    params = init_params(cfg, jax.random.key(loop.seed))
    opt_state = opt_init(params)
    start_step = 0

    manager = None
    if loop.checkpoint_dir:
        manager = CheckpointManager(loop.checkpoint_dir, keep=loop.keep_checkpoints)
        if manager.latest_step() is not None:
            tree = {"params": params, "opt": opt_state}
            restored, extra, ck_step = manager.restore(tree)
            params, opt_state = restored["params"], restored["opt"]
            params = jax.tree.map(jax.numpy.asarray, params)
            opt_state = jax.tree.map(jax.numpy.asarray, opt_state)
            start_step = int(extra.get("data_step", ck_step))
            print(f"resumed from checkpoint step {ck_step}")

    log: list[dict[str, float]] = []
    for step in range(start_step, loop.steps):
        if loop.fail_at_step is not None and step == loop.fail_at_step:
            raise SimulatedPreemption(f"simulated preemption at step {step}")
        t0 = time.monotonic()
        batch = dataset.global_batch(step)
        if batch_transform is not None:
            batch = batch_transform(batch)
        batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % loop.log_every == 0 or step == loop.steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = step
            m["sec"] = time.monotonic() - t0
            log.append(m)
            print(
                f"step {step:5d} loss {m['loss']:.4f} "
                f"gnorm {m['grad_norm']:.3f} lr {m['lr']:.2e} {m['sec']:.2f}s"
            )
        if manager and ((step + 1) % loop.checkpoint_every == 0 or step == loop.steps - 1):
            manager.save(
                step + 1,
                {"params": params, "opt": opt_state},
                extra={"data_step": step + 1},
            )
    return {"params": params, "opt_state": opt_state, "log": log}
