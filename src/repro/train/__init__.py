from .loop import SimulatedPreemption, TrainLoopConfig, train
from .step import make_loss_fn, make_train_step

__all__ = [
    "make_train_step",
    "make_loss_fn",
    "train",
    "TrainLoopConfig",
    "SimulatedPreemption",
]
