"""Zero-cost observability hook slots (DESIGN.md §14).

This module is the ONLY thing production code imports for telemetry.  It
holds one mutable slot, ``SINK`` — ``None`` by default — that
``repro.obs`` installs a collector into while a ``collect()`` /
``tracing()`` context is active.  With the slot empty every probe is a
single attribute test against ``None`` executed in Python OUTSIDE any
traced computation, so the traced jaxpr of every kernel entry point is
byte-identical whether ``repro.obs`` is imported, active, or absent
(asserted in ``tests/test_obs.py``).

Deliberately dependency-free: importing this module never imports
``repro.obs`` (nor jax), so the hot path carries no observability code
until someone actually turns it on.
"""

from __future__ import annotations

__all__ = ["SINK", "TAP", "active", "capturing", "event", "span", "tap"]

# The installed sink (repro.obs.probes._Sink) or None.  Probes read this
# once per call; repro.obs flips it when the first collector activates.
SINK = None

# The installed traffic tap (repro.obs.capture._Tap) or None.  A separate
# slot from SINK because tap payloads carry ARRAYS (weights, KV slices,
# gradients), not the JSON-safe scalars the probe sink expects.  Same
# zero-cost contract: with the slot empty a tap site is one None test.
TAP = None


class _NullSpan:
    """No-op context manager returned while no sink is installed."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


def active() -> bool:
    """True while at least one collector (registry or tracer) is active."""
    return SINK is not None


def span(kind: str, **data):
    """A context manager timing one probe span (no-op when inactive).

    ``kind`` names the probe point (e.g. ``"kernel.dispatch"``); ``data``
    carries JSON-safe scalars only — probe sites fire during jax tracing
    too, so values must never be traced arrays.
    """
    s = SINK
    return _NULL_SPAN if s is None else s.span(kind, data)


def event(kind: str, **data) -> None:
    """Fire one instant probe event (no-op when inactive)."""
    s = SINK
    if s is not None:
        s.event(kind, data)


def capturing() -> bool:
    """True while at least one traffic-capture session is active."""
    return TAP is not None


def tap(kind: str, **payload) -> None:
    """Offer tensors at a traffic-tap site (no-op when no capture active).

    ``kind`` names the tap point (e.g. ``"serve.kv"``); ``payload`` may
    carry jax arrays or pytrees of them.  Tap sites inside jitted
    functions fire with tracers during tracing — the installed tap drops
    those whole-payload (it performs NO jax operations on them), so the
    traced jaxpr stays byte-identical whether capture is absent,
    installed, or active (tests/test_capture.py pins this).
    """
    t = TAP
    if t is not None:
        t.tap(kind, payload)
