"""Batched device-side fabric expansion (DESIGN.md §17).

The legacy expansion (`simulate._expand_link_streams_reference`) walks the
flows in a Python loop — one encode + one sort-order launch per flow, one
concatenate/assemble/codec chain per distinct link queue — O(flows + links)
traced host round-trips before the single batched BT launch.  This module
replaces that walk with three batched steps over the routing tables a
:class:`~repro.noc.routing.FabricPlan` compiled once:

  1. :class:`FlowBatch` — every flow's packets stacked into ONE
     device-resident (F, P_max, elems) tensor (zero-padded, per-flow packet
     counts kept statically);
  2. :func:`expand_fabric` — encode + per-packet sort order computed for
     ALL flows in one call each, flows gathered into distinct-queue rows by
     one (Q, P_q) index table, the hop-sort packet permutation applied as a
     masked batched counting sort, per-queue flit assembly vmapped over the
     registered ``repro.link`` stages, and the wire codec vmapped across
     queues (bus-invert's scan included) — invert-line state stays on
     device until the activity path consumes it;
  3. one ``bt_count_links`` launch (the §12 multi-axis core) measures every
     distinct queue; per-link numbers are a table lookup, because links
     with the same queued-flow composition carry byte-identical streams.

Bit-exactness vs the legacy loop is the subsystem contract, asserted per
trimmed stream / aux count / invert state in ``tests/test_fabric.py`` on
every existing test fabric.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import _obs_hooks as _obs
from repro.codec.schemes import codec_by_name
from repro.core.sorting import counting_sort_indices
from repro.link import ENCODE_STAGES, LinkSpec, make_order
from repro.link.framing import assemble_stream
from repro.link.stages import row_bucket_keys

from .routing import FabricPlan

__all__ = [
    "FlowBatch",
    "FabricStreams",
    "expand_fabric",
    "validate_flow",
]


def validate_flow(flow, spec: LinkSpec) -> None:
    """Payload/framing consistency of one flow against the link spec."""
    if flow.inputs.ndim != 2 or flow.inputs.shape[-1] != spec.elems_per_packet:
        raise ValueError(
            f"flow {flow.name!r}: payload {tuple(flow.inputs.shape)} != "
            f"(P, {spec.elems_per_packet}) for this spec"
        )
    if flow.inputs.shape[0] == 0:
        raise ValueError(f"flow {flow.name!r}: zero packets")
    if spec.weight_lanes and flow.weights is None:
        raise ValueError(
            f"flow {flow.name!r}: spec has weight lanes but no weight payload"
        )
    if flow.weights is not None:
        if not spec.weight_lanes:
            raise ValueError(
                f"flow {flow.name!r}: weight payload on an input-only spec"
            )
        if flow.weights.shape != (
            flow.inputs.shape[0],
            spec.weight_elems_per_packet,
        ):
            raise ValueError(
                f"flow {flow.name!r}: weight payload "
                f"{tuple(flow.weights.shape)} != "
                f"(P, {spec.weight_elems_per_packet})"
            )


class FlowBatch(NamedTuple):
    """Every flow's packet payloads as one device-resident batch.

    ``inputs`` is (F, P_max, elems) uint8 (flows shorter than P_max are
    zero-padded — padding never reaches a measured wire, the queue tables
    index real packets only); ``weights`` rides along for paired framings.
    ``counts`` keeps each flow's real packet count statically.
    """

    inputs: jax.Array
    weights: Optional[jax.Array]
    counts: tuple[int, ...]

    @property
    def num_flows(self) -> int:
        return len(self.counts)

    @property
    def max_packets(self) -> int:
        return 0 if not self.counts else int(self.inputs.shape[1])

    @classmethod
    def from_flows(cls, flows: Sequence, spec: LinkSpec) -> "FlowBatch":
        """Validate and stack flow payloads (one host staging pass, one
        device transfer per side — not one per flow)."""
        flows = tuple(flows)
        for flow in flows:
            validate_flow(flow, spec)
        counts = tuple(int(f.inputs.shape[0]) for f in flows)
        if not flows:
            e = spec.elems_per_packet
            return cls(jnp.zeros((0, 1, e), jnp.uint8), None, ())
        pmax = max(counts)
        xs = np.zeros((len(flows), pmax, spec.elems_per_packet), np.uint8)
        for i, f in enumerate(flows):
            xs[i, : counts[i]] = np.asarray(f.inputs, np.uint8)
        ws = None
        if spec.weight_lanes:
            ws = np.zeros(
                (len(flows), pmax, spec.weight_elems_per_packet), np.uint8
            )
            for i, f in enumerate(flows):
                ws[i, : counts[i]] = np.asarray(f.weights, np.uint8)
        return cls(
            jnp.asarray(xs), None if ws is None else jnp.asarray(ws), counts
        )


class FabricStreams(NamedTuple):
    """The fabric's distinct-queue wire streams, ready for ONE BT launch.

    ``streams`` is (Q, T_max, bytes_per_flit) uint8 — one row per distinct
    link queue, padded past each queue's real flit count with copies of its
    last flit (the same self-consistent padding the legacy stacker used;
    the kernel masks past ``lengths`` either way).  ``aux_bt`` / ``inverts``
    carry the wire codec's invert-line transition counts and raw line
    states per queue — device arrays until a consumer materializes them
    (``None`` for codecs with no extra wires).  Per-link views are
    ``plan.link_queue`` lookups.
    """

    plan: FabricPlan
    streams: jax.Array
    lengths: tuple[int, ...]
    aux_bt: Optional[jax.Array] = None
    inverts: Optional[jax.Array] = None

    @property
    def num_queues(self) -> int:
        return len(self.lengths)

    def link_lengths(self) -> tuple[int, ...]:
        """Real flit counts in per-active-link order."""
        return tuple(self.lengths[qi] for qi in self.plan.link_queue)


def _queue_gather_table(
    plan: FabricPlan, counts: tuple[int, ...], pmax: int
) -> tuple[np.ndarray, tuple[int, ...]]:
    """(Q, P_qmax) flat packet indices per distinct queue + real counts.

    Queue slot j of queue q maps to flat index flow*P_max + packet of the
    j-th packet in injection order (the legacy concatenation order); pad
    slots point at index 0 and are masked everywhere downstream.
    """
    starts = [f * pmax for f in range(len(counts))]
    qcounts = tuple(
        sum(counts[f] for f in q) for q in plan.queues
    )
    qmax = max(qcounts, default=0)
    table = np.zeros((len(plan.queues), max(qmax, 1)), np.int64)
    for qi, q in enumerate(plan.queues):
        parts = [
            np.arange(starts[f], starts[f] + counts[f], dtype=np.int64)
            for f in q
        ]
        if parts:
            idx = np.concatenate(parts)
            table[qi, : idx.shape[0]] = idx
    return table, qcounts


def _hop_perm_masked(
    rows: jax.Array,
    qcounts: Sequence[int],
    levels: int,
    *,
    width: int,
    descending: bool,
) -> jax.Array:
    """Batched, jagged-queue version of the per-hop packet permutation.

    Real rows get the same popcount-bucket keys (and descending flip) as
    ``simulate``'s legacy ``row_bucket_order`` call; pad rows get one extra
    bucket past everything, so the stable counting sort emits the real
    packets in exactly the legacy order followed by the pads.
    """
    keys = row_bucket_keys(rows, levels, width=width)  # (Q, P)
    if descending:
        keys = (levels - 1) - keys
    p = rows.shape[1]
    mask = jnp.arange(p)[None, :] < jnp.asarray(qcounts, jnp.int32)[:, None]
    keys = jnp.where(mask, keys, levels)
    return counting_sort_indices(keys, levels + 1)


def _validate_expansion(spec: LinkSpec, sort_at: str) -> None:
    if sort_at not in ("source", "hop"):
        raise ValueError(f"sort_at must be 'source' or 'hop', got {sort_at!r}")
    if spec.key == "row_bucket":
        raise ValueError(
            "NoC flows carry packets, which use the packet-granularity key "
            "stages ('none', 'column_major', 'acc', 'app'); 'row_bucket' is "
            "a row-stream stage (TxPipeline.measure_rows)"
        )


def expand_fabric(
    plan: FabricPlan,
    batch: FlowBatch,
    spec: LinkSpec = LinkSpec(),
    *,
    sort_at: str = "source",
) -> FabricStreams:
    """Expand a whole fabric's flows into distinct-queue wire streams.

    Every step is batched over all flows / queues at once; the only Python
    iteration is the O(queues) index-table build.  Bit-exact vs the legacy
    per-flow loop by construction (same stages, same orders, same
    injection-order concatenation — asserted in ``tests/test_fabric.py``).
    """
    _validate_expansion(spec, sort_at)
    if batch.num_flows != plan.num_flows:
        raise ValueError(
            f"batch carries {batch.num_flows} flows but the plan routed "
            f"{plan.num_flows}"
        )
    with _obs.span(
        "noc.expand",
        topology=f"{plan.topo.kind}{plan.topo.rows}x{plan.topo.cols}",
        sort_at=sort_at, flows=plan.num_flows, queues=plan.num_queues,
    ):
        return _expand_fabric(plan, batch, spec, sort_at)


def _expand_fabric(
    plan: FabricPlan, batch: FlowBatch, spec: LinkSpec, sort_at: str
) -> FabricStreams:
    nq = plan.num_queues
    if nq == 0 or batch.num_flows == 0:
        return FabricStreams(
            plan, jnp.zeros((nq, 1, spec.bytes_per_flit), jnp.uint8),
            (0,) * nq,
        )
    encode = ENCODE_STAGES[spec.encode]
    xi = encode(batch.inputs).astype(jnp.uint8)  # (F, Pmax, E)
    wi = (
        encode(batch.weights).astype(jnp.uint8)
        if batch.weights is not None
        else None
    )
    # ONE order derivation for every packet of every flow (per-packet
    # counting sort — identical to the per-flow legacy call)
    order = make_order(
        spec.key,
        xi,
        lanes=spec.input_lanes,
        width=spec.width,
        k=spec.k,
        descending=spec.descending,
    )
    f, pmax, e = (int(d) for d in xi.shape)
    table, qcounts = _queue_gather_table(plan, batch.counts, pmax)
    gather = jnp.asarray(table)  # (Q, Pq)
    qx = jnp.take(xi.reshape(f * pmax, e), gather, axis=0)
    qo = jnp.take(order.reshape(f * pmax, e), gather, axis=0)
    qw = (
        None
        if wi is None
        else jnp.take(wi.reshape(f * pmax, wi.shape[-1]), gather, axis=0)
    )
    if sort_at == "hop":
        rows = qx if qw is None else jnp.concatenate([qx, qw], axis=-1)
        levels = spec.k if spec.key == "app" else spec.width + 1
        perm = _hop_perm_masked(
            rows, qcounts, levels,
            width=spec.width, descending=spec.descending,
        )
        qx = jnp.take_along_axis(qx, perm[..., None], axis=1)
        qo = jnp.take_along_axis(qo, perm[..., None], axis=1)
        if qw is not None:
            qw = jnp.take_along_axis(qw, perm[..., None], axis=1)
    # per-queue flit assembly, vmapped over the queue axis
    if qw is None:
        streams = jax.vmap(
            lambda x, o: assemble_stream(x, None, spec, o, spec.pack)
        )(qx, qo)
    else:
        streams = jax.vmap(
            lambda x, w, o: assemble_stream(x, w, spec, o, spec.pack)
        )(qx, qw, qo)
    lengths = tuple(c * spec.flits_per_packet for c in qcounts)
    aux = inverts = None
    if spec.codec != "none":
        codec = codec_by_name(spec.codec)
        coded = jax.vmap(codec.encode)(streams)
        streams = coded.wire.astype(jnp.uint8)
        if coded.invert is not None:
            inverts = coded.invert
            t = int(streams.shape[1])
            real = (
                jnp.arange(1, t)[None, :, None]
                < jnp.asarray(lengths, jnp.int32)[:, None, None]
            )
            flips = (inverts[:, 1:] != inverts[:, :-1]) & real
            aux = flips.sum(axis=(1, 2)).astype(jnp.int32)
        else:
            aux = jnp.zeros((nq,), jnp.int32)
    # pad rows become copies of each queue's last real flit — the same
    # self-consistent padding the legacy stacker emitted (codec state was
    # already computed on the real region only, which comes first)
    t = int(streams.shape[1])
    last = jnp.maximum(jnp.asarray(lengths, jnp.int32) - 1, 0)
    idx = jnp.minimum(jnp.arange(t, dtype=jnp.int32)[None, :], last[:, None])
    streams = jnp.take_along_axis(streams, idx[..., None], axis=1)
    return FabricStreams(plan, streams, lengths, aux, inverts)
