"""NoC-level simulation: flows in, per-link BT/energy accounting out.

The single-link story (``repro.link``) models one wire; this module models
the fabric.  Traffic is injected as ``TrafficFlow``s (packet payloads with
a source router and one or more destinations), expanded along deterministic
XY/ring routes into per-link flit streams, and measured with ONE batched
Pallas launch (``repro.kernels.bt_count_links``: links x flits x byte-lanes
on the grid) instead of one ``bt_count`` launch per link.

Where the sorting unit sits is the modeled design choice (DESIGN.md §9):

  * ``sort_at='source'`` — one PSU per injection port (the paper's §V
    proposal lifted to a NoC): packets are element-sorted once, the wire
    image is fixed at the source, and every hop of the route re-uses the
    same ordered stream.  Intermediate routers need no sorting hardware;
    the BT advantage rides along the whole path.
  * ``sort_at='hop'``   — a PSU (plus a packet-granularity transmission
    scheduler) at every router egress: each link element-sorts per packet
    *and* reorders the transmission sequence of the packets queued on that
    link by popcount bucket (the scheme of Chen et al., arXiv:2509.00500).
    Per-packet element sorting is idempotent, so the extra leverage is
    exactly at flow-merge points — packets from different flows interleave
    in bucket order instead of arrival order.

Element ordering reuses the registered ``repro.link`` stages (the KEY /
ENCODE / PACK registries and ``assemble_stream``), so a ``LinkSpec`` means
the same thing on a NoC link as on the paper's point-to-point link.  That
includes the wire-codec stage (DESIGN.md §11): a spec naming a
``repro.codec`` codec puts one encoder at every active link's egress — the
measured streams are the coded wire images and each link's invert-line
transitions ride along as ``LinkStats.bt_aux``, so fabric-level
coding-vs-ordering comparisons are net of overhead.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import _obs_hooks as _obs
from repro.kernels import bt_count_links
from repro.link import ENCODE_STAGES, LinkSpec, make_order, row_bucket_order
from repro.link.framing import assemble_stream

from .power import NocPowerModel
from .routing import hop_count, multicast_links
from .topology import Topology

__all__ = [
    "TrafficFlow",
    "LinkStats",
    "LinkStreams",
    "NocReport",
    "expand_link_streams",
    "stack_link_streams",
    "simulate_noc",
]


@dataclasses.dataclass(frozen=True)
class TrafficFlow:
    """One traffic injection: packets from a source router to destination(s).

    ``inputs`` is (P, elems_per_packet) bytes; ``weights`` (optional) is the
    paired weight payload per the ``LinkSpec`` framing.  More than one
    destination means tree multicast along the deterministic routes.
    """

    name: str
    src: int
    dsts: tuple[int, ...]
    inputs: jax.Array
    weights: jax.Array | None = None

    def __post_init__(self) -> None:
        if not self.dsts:
            raise ValueError(f"flow {self.name!r} has no destinations")


@dataclasses.dataclass(frozen=True)
class LinkStats:
    """BT / energy accounting of one directed link's traffic."""

    link: int  # topology link id
    src: int
    dst: int
    num_flits: int
    bt_input: int
    bt_weight: int
    energy_pj: float
    bt_aux: int = 0  # invert-line transitions (wire-codec overhead)

    @property
    def total_bt(self) -> int:
        return self.bt_input + self.bt_weight

    @property
    def gross_bt(self) -> int:
        """Data BT plus the codec's invert-line transitions."""
        return self.total_bt + self.bt_aux

    @property
    def bt_per_flit(self) -> float:
        return self.total_bt / max(self.num_flits, 1)


class LinkStreams(NamedTuple):
    """Per-link wire streams, stacked for the batched BT kernel.

    ``streams`` is (L, T_max, lanes) uint8; links shorter than T_max are
    padded with copies of their last flit (BT-neutral), ``lengths`` keeps
    the real flit counts.  When the spec names a wire codec, ``streams``
    is the *coded* wire image, ``aux_bt`` carries each link's invert-line
    transitions (all zeros otherwise), and ``inverts`` keeps the raw
    (T_link, npart) invert-line states per link (``None`` when the codec
    adds no wires) — the wire-level activity path needs the actual line
    levels, not just their transition total.
    """

    link_ids: tuple[int, ...]
    streams: jax.Array
    lengths: tuple[int, ...]
    aux_bt: tuple[int, ...] = ()
    inverts: tuple = ()


@dataclasses.dataclass(frozen=True)
class NocReport:
    """Fabric-level accounting: per-link stats plus flow path info."""

    name: str
    topology: str
    sort_at: str
    key: str
    links: tuple[LinkStats, ...]
    flow_hops: tuple[tuple[str, int], ...]  # (flow name, max hops to a dst)
    total_links: int  # links in the topology (active or not)
    # wire-level activity (DESIGN.md §15) — populated only when the run was
    # measured with ``activity_windows=``.  One (num_windows, wires) toggle
    # tensor and one (wires,) time-at-1 vector per active link, wires =
    # wire_lanes*8 data bits + the codec's invert lines; consumed
    # duck-typed by ``repro.obs.activity.profiles_from_noc`` (noc never
    # imports repro.obs).
    activity_window: int = 0
    wire_lanes: int = 0
    wire_toggles: tuple = ()
    wire_ones: tuple = ()

    @property
    def active_links(self) -> int:
        return len(self.links)

    @property
    def total_bt(self) -> int:
        return sum(s.total_bt for s in self.links)

    @property
    def total_aux_bt(self) -> int:
        """Fabric-wide invert-line transitions (wire-codec overhead)."""
        return sum(s.bt_aux for s in self.links)

    @property
    def gross_bt(self) -> int:
        return self.total_bt + self.total_aux_bt

    @property
    def total_flit_hops(self) -> int:
        """Flits summed over links — each hop retransmits the payload."""
        return sum(s.num_flits for s in self.links)

    @property
    def energy_pj(self) -> float:
        return sum(s.energy_pj for s in self.links)

    @property
    def max_hops(self) -> int:
        return max((h for _, h in self.flow_hops), default=0)

    def reduction_vs(self, base: "NocReport") -> float:
        """Fabric-level BT reduction relative to a baseline run (fraction,
        scored on ``gross_bt`` so coded fabrics are net of overhead)."""
        return 1.0 - self.gross_bt / max(base.gross_bt, 1e-9)


def _validate_flow(flow: TrafficFlow, spec: LinkSpec) -> None:
    if flow.inputs.ndim != 2 or flow.inputs.shape[-1] != spec.elems_per_packet:
        raise ValueError(
            f"flow {flow.name!r}: payload {tuple(flow.inputs.shape)} != "
            f"(P, {spec.elems_per_packet}) for this spec"
        )
    if flow.inputs.shape[0] == 0:
        raise ValueError(f"flow {flow.name!r}: zero packets")
    if spec.weight_lanes and flow.weights is None:
        raise ValueError(
            f"flow {flow.name!r}: spec has weight lanes but no weight payload"
        )
    if flow.weights is not None:
        if not spec.weight_lanes:
            raise ValueError(
                f"flow {flow.name!r}: weight payload on an input-only spec"
            )
        if flow.weights.shape != (
            flow.inputs.shape[0],
            spec.weight_elems_per_packet,
        ):
            raise ValueError(
                f"flow {flow.name!r}: weight payload "
                f"{tuple(flow.weights.shape)} != "
                f"(P, {spec.weight_elems_per_packet})"
            )


def _packet_perm(
    xi: jax.Array, wi: jax.Array | None, spec: LinkSpec
) -> jax.Array:
    """Per-hop transmission order of the packets queued on one link: stable
    counting sort by the popcount bucket of each packet's full wire image
    (ACC granularity = W+1 levels, APP = k)."""
    rows = xi if wi is None else jnp.concatenate([xi, wi], axis=-1)
    levels = spec.k if spec.key == "app" else spec.width + 1
    return row_bucket_order(
        rows, levels, width=spec.width, descending=spec.descending
    )


def expand_link_streams(
    topo: Topology,
    flows: Sequence[TrafficFlow],
    spec: LinkSpec = LinkSpec(),
    *,
    sort_at: str = "source",
) -> LinkStreams:
    """Expand flows into the per-link wire streams of the whole fabric.

    Element ordering (the spec's KEY stage) is applied per packet at the
    source; ``sort_at='hop'`` additionally re-orders each link's packet
    queue by popcount bucket.  All ordering/packing here is plain jnp (the
    registered ``repro.link`` stages); the Pallas work of a NoC run is the
    single batched BT launch in :func:`simulate_noc`.
    """
    if sort_at not in ("source", "hop"):
        raise ValueError(f"sort_at must be 'source' or 'hop', got {sort_at!r}")
    if spec.key == "row_bucket":
        raise ValueError(
            "NoC flows carry packets, which use the packet-granularity key "
            "stages ('none', 'column_major', 'acc', 'app'); 'row_bucket' is "
            "a row-stream stage (TxPipeline.measure_rows)"
        )
    with _obs.span(
        "noc.expand",
        topology=f"{topo.kind}{topo.rows}x{topo.cols}",
        sort_at=sort_at, flows=len(flows),
    ):
        return _expand_link_streams(topo, flows, spec, sort_at=sort_at)


def _expand_link_streams(
    topo: Topology,
    flows: Sequence[TrafficFlow],
    spec: LinkSpec,
    *,
    sort_at: str,
) -> LinkStreams:
    encode = ENCODE_STAGES[spec.encode]
    # per-flow: encoded payloads + element order, computed ONCE at the source
    per_flow = []
    for flow in flows:
        _validate_flow(flow, spec)
        xi = encode(flow.inputs).astype(jnp.uint8)
        wi = (
            encode(flow.weights).astype(jnp.uint8)
            if flow.weights is not None
            else None
        )
        order = make_order(
            spec.key,
            xi,
            lanes=spec.input_lanes,
            width=spec.width,
            k=spec.k,
            descending=spec.descending,
        )
        links = multicast_links(topo, flow.src, flow.dsts)
        per_flow.append((xi, wi, order, links))

    # per-link: concatenate the queued segments in injection order
    segments: dict[int, list[int]] = {}
    for fi, (_, _, _, links) in enumerate(per_flow):
        for lid in links:
            segments.setdefault(lid, []).append(fi)

    link_ids = sorted(segments)
    # links with the same queued-flow composition carry byte-identical
    # streams (every link of a unicast route, every tree link of a
    # multicast) — assemble each distinct queue once
    assembled: dict[tuple[int, ...], tuple[jax.Array, int, object]] = {}
    streams: list[jax.Array] = []
    aux_bts: list[int] = []
    inverts: list = []
    for lid in link_ids:
        idxs = tuple(segments[lid])
        entry = assembled.get(idxs)
        if entry is None:
            xi = jnp.concatenate([per_flow[i][0] for i in idxs], axis=0)
            wis = [per_flow[i][1] for i in idxs]
            wi = None if wis[0] is None else jnp.concatenate(wis, axis=0)
            order = jnp.concatenate([per_flow[i][2] for i in idxs], axis=0)
            if sort_at == "hop" and len(xi) > 1:
                perm = _packet_perm(xi, wi, spec)
                xi = jnp.take(xi, perm, axis=0)
                wi = None if wi is None else jnp.take(wi, perm, axis=0)
                order = jnp.take(order, perm, axis=0)
            stream = assemble_stream(xi, wi, spec, order, spec.pack)
            aux, inv = 0, None
            if spec.codec != "none":
                # each link's egress encoder codes its own queue; the
                # batched kernel then measures the coded wire directly
                from repro.codec.schemes import (
                    codec_by_name,
                    invert_line_transitions,
                )

                coded = codec_by_name(spec.codec).encode(stream)
                stream = coded.wire
                aux = int(invert_line_transitions(coded.invert))
                inv = (
                    None if coded.invert is None
                    else np.asarray(coded.invert)
                )
            entry = assembled[idxs] = (stream, aux, inv)
        streams.append(entry[0])
        aux_bts.append(entry[1])
        inverts.append(entry[2])
    stacked, lengths = stack_link_streams(streams, spec.bytes_per_flit)
    return LinkStreams(
        tuple(link_ids), stacked, lengths, tuple(aux_bts), tuple(inverts)
    )


def stack_link_streams(
    streams: Sequence[jax.Array], lanes: int
) -> tuple[jax.Array, tuple[int, ...]]:
    """Stack jagged (T_l, lanes) streams to (L, T_max, lanes) uint8.

    Shorter streams are padded with copies of their last flit and the real
    flit counts are returned alongside.  Since the unified kernel masks
    everything past each link's length (DESIGN.md §12), the padding value
    is no longer load-bearing — a repeated flit merely keeps the padded
    tensor self-consistent for callers that inspect it.
    """
    if not streams:
        return jnp.zeros((0, 1, lanes), jnp.uint8), ()
    lengths = tuple(int(s.shape[0]) for s in streams)
    t_max = max(lengths)
    padded = [
        s if s.shape[0] == t_max else jnp.pad(
            s, ((0, t_max - s.shape[0]), (0, 0)), mode="edge"
        )
        for s in streams
    ]
    return jnp.stack(padded).astype(jnp.uint8), lengths


def simulate_noc(
    topo: Topology,
    flows: Sequence[TrafficFlow],
    spec: LinkSpec = LinkSpec(),
    *,
    sort_at: str = "source",
    power: NocPowerModel | None = None,
    interpret: bool | None = None,
    backend: str | None = None,
    chunk_rows: int | None = None,
    activity_windows: int | None = None,
    name: str = "noc",
) -> NocReport:
    """Run the fabric: expand flows to link streams, measure every link.

    All links are measured by one ``bt_count_links`` launch; per-link
    energies roll up through ``NocPowerModel`` (wire switching + router
    flit overhead per hop).  ``backend`` selects the kernel execution path
    (pallas | compiled | interpret, DESIGN.md §13); ``chunk_rows`` streams
    the flit-row axis in fixed-size chunks for fabrics whose stacked link
    tensor would not fit in memory at once.  ``activity_windows`` (a flit
    count) additionally measures per-wire × per-time-window switching
    activity on every link (DESIGN.md §15): the report gains
    ``wire_toggles`` / ``wire_ones`` and each link fires a
    ``link.activity`` probe event.
    """
    power = power if power is not None else NocPowerModel()
    with _obs.span(
        "noc.simulate",
        topology=f"{topo.kind}{topo.rows}x{topo.cols}",
        sort_at=sort_at, key=spec.key, flows=len(flows), name=name,
    ):
        report = _simulate_noc(
            topo, flows, spec, sort_at=sort_at, power=power,
            interpret=interpret, backend=backend, chunk_rows=chunk_rows,
            activity_windows=activity_windows, name=name,
        )
    if _obs.active():
        # per-link egress telemetry (the rows behind repro.obs.report)
        for s in report.links:
            _obs.event(
                "noc.link", link=s.link, src=s.src, dst=s.dst,
                num_flits=s.num_flits, bt_input=s.bt_input,
                bt_weight=s.bt_weight, bt_aux=s.bt_aux,
                energy_pj=s.energy_pj,
            )
        for i, s in enumerate(report.links if report.activity_window else ()):
            pw = report.wire_toggles[i].sum(axis=0)
            hot = int(np.lexsort((np.arange(len(pw)), -pw))[0])
            _obs.event(
                "link.activity", link=s.link, src=s.src, dst=s.dst,
                window_flits=report.activity_window,
                num_windows=-(-s.num_flits // report.activity_window),
                data_lanes=report.wire_lanes,
                toggles_total=int(pw.sum()),
                per_wire=[int(v) for v in pw],
                hot_wire=hot, hot_wire_toggles=int(pw[hot]),
            )
    return report


def _simulate_noc(
    topo: Topology,
    flows: Sequence[TrafficFlow],
    spec: LinkSpec,
    *,
    sort_at: str,
    power: NocPowerModel,
    interpret: bool | None,
    backend: str | None,
    chunk_rows: int | None,
    activity_windows: int | None,
    name: str,
) -> NocReport:
    ls = expand_link_streams(topo, flows, spec, sort_at=sort_at)
    extra_wires = 0
    if spec.codec != "none":
        from repro.codec.schemes import codec_by_name

        extra_wires = codec_by_name(spec.codec).extra_wires(spec.bytes_per_flit)
    stats: list[LinkStats] = []
    wire_toggles: tuple = ()
    wire_ones: tuple = ()
    if ls.link_ids:
        out = bt_count_links(
            ls.streams,
            input_lanes=spec.input_lanes,
            lengths=ls.lengths,
            interpret=interpret,
            backend=backend,
            chunk_rows=chunk_rows,
            activity_windows=activity_windows,
        )
        if activity_windows is not None:
            wire_toggles, wire_ones = _link_wire_activity(
                out, ls, activity_windows, extra_wires
            )
            bt = np.asarray(out.bt)
        else:
            bt = np.asarray(out)
        for (lid, length, aux, (bi, bw)) in zip(
            ls.link_ids, ls.lengths, ls.aux_bt, bt.astype(int).tolist()
        ):
            u, v = topo.links[lid]
            stats.append(
                LinkStats(
                    link=lid,
                    src=u,
                    dst=v,
                    num_flits=length,
                    bt_input=bi,
                    bt_weight=bw,
                    # same coded-wire accounting as the point-to-point
                    # path: invert lines switch and widen this hop too
                    energy_pj=power.coded_hop_energy_pj(
                        bi + bw, aux, length,
                        8 * spec.bytes_per_flit, extra_wires,
                    ),
                    bt_aux=aux,
                )
            )
    flow_hops = tuple(
        (f.name, max(hop_count(topo, f.src, d) for d in f.dsts)) for f in flows
    )
    return NocReport(
        name=name,
        topology=f"{topo.kind}{topo.rows}x{topo.cols}",
        sort_at=sort_at,
        key=spec.key,
        links=tuple(stats),
        flow_hops=flow_hops,
        total_links=topo.num_links,
        activity_window=activity_windows or 0,
        wire_lanes=spec.bytes_per_flit if activity_windows else 0,
        wire_toggles=wire_toggles,
        wire_ones=wire_ones,
    )


def _link_wire_activity(
    out, ls: LinkStreams, window: int, extra_wires: int
) -> tuple[tuple, tuple]:
    """Per-link full-wire activity: the kernel's data-wire tensors widened
    with the codec invert lines' toggles/ones, computed from the raw line
    states ``expand_link_streams`` kept (the invert recurrence is already
    paid there — only window bucketing happens here, in numpy)."""
    tog = np.asarray(out.toggles).astype(np.int64)  # (L, NW, lanes*8)
    one = np.asarray(out.ones).astype(np.int64)  # (L, lanes*8)
    nw = tog.shape[1]
    inverts = ls.inverts if ls.inverts else (None,) * len(ls.link_ids)
    wire_toggles, wire_ones = [], []
    for i, (length, inv) in enumerate(zip(ls.lengths, inverts)):
        aux_t = np.zeros((nw, extra_wires), np.int64)
        aux_o = np.zeros(extra_wires, np.int64)
        if inv is not None and length >= 1:
            iv = np.asarray(inv[:length], np.int64)
            aux_o[: iv.shape[1]] = iv.sum(axis=0)
            if length >= 2:
                flips = (iv[1:] != iv[:-1]).astype(np.int64)
                # boundary into row t lands in window t // window — the
                # same global indexing as the kernel's data wires
                widx = np.arange(1, length) // window
                np.add.at(aux_t[:, : iv.shape[1]], widx, flips)
        wire_toggles.append(np.concatenate([tog[i], aux_t], axis=1))
        wire_ones.append(np.concatenate([one[i], aux_o]))
    return tuple(wire_toggles), tuple(wire_ones)
