"""NoC-level simulation: flows in, per-link BT/energy accounting out.

The single-link story (``repro.link``) models one wire; this module models
the fabric.  Traffic is injected as ``TrafficFlow``s (packet payloads with
a source router and one or more destinations), expanded along deterministic
XY/ring routes into per-link flit streams, and measured with ONE batched
Pallas launch (``repro.kernels.bt_count_links``: links x flits x byte-lanes
on the grid) instead of one ``bt_count`` launch per link.

Where the sorting unit sits is the modeled design choice (DESIGN.md §9):

  * ``sort_at='source'`` — one PSU per injection port (the paper's §V
    proposal lifted to a NoC): packets are element-sorted once, the wire
    image is fixed at the source, and every hop of the route re-uses the
    same ordered stream.  Intermediate routers need no sorting hardware;
    the BT advantage rides along the whole path.
  * ``sort_at='hop'``   — a PSU (plus a packet-granularity transmission
    scheduler) at every router egress: each link element-sorts per packet
    *and* reorders the transmission sequence of the packets queued on that
    link by popcount bucket (the scheme of Chen et al., arXiv:2509.00500).
    Per-packet element sorting is idempotent, so the extra leverage is
    exactly at flow-merge points — packets from different flows interleave
    in bucket order instead of arrival order.

Element ordering reuses the registered ``repro.link`` stages (the KEY /
ENCODE / PACK registries and ``assemble_stream``), so a ``LinkSpec`` means
the same thing on a NoC link as on the paper's point-to-point link.  That
includes the wire-codec stage (DESIGN.md §11): a spec naming a
``repro.codec`` codec puts one encoder at every active link's egress — the
measured streams are the coded wire images and each link's invert-line
transitions ride along as ``LinkStats.bt_aux``, so fabric-level
coding-vs-ordering comparisons are net of overhead.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import _obs_hooks as _obs
from repro.codec.schemes import codec_by_name, invert_line_transitions
from repro.kernels import bt_count_links
from repro.link import ENCODE_STAGES, LinkSpec, make_order, row_bucket_order
from repro.link.framing import assemble_stream

from .fabric import FabricStreams, FlowBatch, expand_fabric, validate_flow
from .latency import FabricLatency, NocLatencyModel, fabric_latency
from .power import NocPowerModel
from .routing import compile_fabric, hop_count, multicast_links
from .topology import Topology

__all__ = [
    "TrafficFlow",
    "LinkStats",
    "LinkStreams",
    "NocReport",
    "expand_link_streams",
    "fabric_to_link_streams",
    "stack_link_streams",
    "simulate_noc",
]


@dataclasses.dataclass(frozen=True)
class TrafficFlow:
    """One traffic injection: packets from a source router to destination(s).

    ``inputs`` is (P, elems_per_packet) bytes; ``weights`` (optional) is the
    paired weight payload per the ``LinkSpec`` framing.  More than one
    destination means tree multicast along the deterministic routes.
    """

    name: str
    src: int
    dsts: tuple[int, ...]
    inputs: jax.Array
    weights: jax.Array | None = None

    def __post_init__(self) -> None:
        if not self.dsts:
            raise ValueError(f"flow {self.name!r} has no destinations")


@dataclasses.dataclass(frozen=True)
class LinkStats:
    """BT / energy accounting of one directed link's traffic."""

    link: int  # topology link id
    src: int
    dst: int
    num_flits: int
    bt_input: int
    bt_weight: int
    energy_pj: float
    bt_aux: int = 0  # invert-line transitions (wire-codec overhead)

    @property
    def total_bt(self) -> int:
        return self.bt_input + self.bt_weight

    @property
    def gross_bt(self) -> int:
        """Data BT plus the codec's invert-line transitions."""
        return self.total_bt + self.bt_aux

    @property
    def bt_per_flit(self) -> float:
        return self.total_bt / max(self.num_flits, 1)


class LinkStreams(NamedTuple):
    """Per-link wire streams, stacked for the batched BT kernel.

    ``streams`` is (L, T_max, lanes) uint8; links shorter than T_max are
    padded with copies of their last flit (BT-neutral), ``lengths`` keeps
    the real flit counts.  When the spec names a wire codec, ``streams``
    is the *coded* wire image, ``aux_bt`` carries each link's invert-line
    transitions (all zeros otherwise), and ``inverts`` keeps the raw
    (T_link, npart) invert-line states per link (``None`` when the codec
    adds no wires) — the wire-level activity path needs the actual line
    levels, not just their transition total.
    """

    link_ids: tuple[int, ...]
    streams: jax.Array
    lengths: tuple[int, ...]
    aux_bt: tuple[int, ...] = ()
    inverts: tuple = ()


@dataclasses.dataclass(frozen=True)
class NocReport:
    """Fabric-level accounting: per-link stats plus flow path info."""

    name: str
    topology: str
    sort_at: str
    key: str
    links: tuple[LinkStats, ...]
    flow_hops: tuple[tuple[str, int], ...]  # (flow name, max hops to a dst)
    total_links: int  # links in the topology (active or not)
    # wire-level activity (DESIGN.md §15) — populated only when the run was
    # measured with ``activity_windows=``.  One (num_windows, wires) toggle
    # tensor and one (wires,) time-at-1 vector per active link, wires =
    # wire_lanes*8 data bits + the codec's invert lines; consumed
    # duck-typed by ``repro.obs.activity.profiles_from_noc`` (noc never
    # imports repro.obs).
    activity_window: int = 0
    wire_lanes: int = 0
    wire_toggles: tuple = ()
    wire_ones: tuple = ()
    # contention-model results (DESIGN.md §17) — populated only when the
    # run was simulated with ``latency=``
    latency: FabricLatency | None = None

    @property
    def active_links(self) -> int:
        return len(self.links)

    @property
    def total_bt(self) -> int:
        return sum(s.total_bt for s in self.links)

    @property
    def total_aux_bt(self) -> int:
        """Fabric-wide invert-line transitions (wire-codec overhead)."""
        return sum(s.bt_aux for s in self.links)

    @property
    def gross_bt(self) -> int:
        return self.total_bt + self.total_aux_bt

    @property
    def total_flit_hops(self) -> int:
        """Flits summed over links — each hop retransmits the payload."""
        return sum(s.num_flits for s in self.links)

    @property
    def energy_pj(self) -> float:
        return sum(s.energy_pj for s in self.links)

    @property
    def max_hops(self) -> int:
        return max((h for _, h in self.flow_hops), default=0)

    def reduction_vs(self, base: "NocReport") -> float:
        """Fabric-level BT reduction relative to a baseline run (fraction,
        scored on ``gross_bt`` so coded fabrics are net of overhead)."""
        return 1.0 - self.gross_bt / max(base.gross_bt, 1e-9)


# flow validation lives with the batched path now; the legacy reference
# loop below shares it
_validate_flow = validate_flow


def _packet_perm(
    xi: jax.Array, wi: jax.Array | None, spec: LinkSpec
) -> jax.Array:
    """Per-hop transmission order of the packets queued on one link: stable
    counting sort by the popcount bucket of each packet's full wire image
    (ACC granularity = W+1 levels, APP = k)."""
    rows = xi if wi is None else jnp.concatenate([xi, wi], axis=-1)
    levels = spec.k if spec.key == "app" else spec.width + 1
    return row_bucket_order(
        rows, levels, width=spec.width, descending=spec.descending
    )


def expand_link_streams(
    topo: Topology,
    flows: Sequence[TrafficFlow],
    spec: LinkSpec = LinkSpec(),
    *,
    sort_at: str = "source",
) -> LinkStreams:
    """Expand flows into the per-link wire streams of the whole fabric.

    Element ordering (the spec's KEY stage) is applied per packet at the
    source; ``sort_at='hop'`` additionally re-orders each link's packet
    queue by popcount bucket.  All ordering/packing is plain jnp (the
    registered ``repro.link`` stages); the Pallas work of a NoC run is the
    single batched BT launch in :func:`simulate_noc`.

    Compatibility wrapper: since the fleet-scale refactor (DESIGN.md §17)
    this delegates to the batched fabric pipeline (``noc.fabric``) and
    re-expands its distinct-queue streams into the legacy per-link view —
    bit-exact vs :func:`_expand_link_streams_reference` (asserted in
    ``tests/test_fabric.py``).  New code should keep the
    :class:`~repro.noc.fabric.FabricStreams` form instead: it measures Q
    distinct queues, not L links.
    """
    plan = compile_fabric(topo, [(f.src, f.dsts) for f in flows])
    batch = FlowBatch.from_flows(flows, spec)
    fs = expand_fabric(plan, batch, spec, sort_at=sort_at)
    return fabric_to_link_streams(fs)


def fabric_to_link_streams(fs: FabricStreams) -> LinkStreams:
    """Per-link view of a fabric expansion: one gather of the distinct-queue
    streams per the plan's link->queue table.  Invert-line states stay
    device arrays, trimmed to each link's real flit count (the legacy
    ``LinkStreams.inverts`` contract); only the scalar aux counts sync to
    host here, once for the whole fabric."""
    plan = fs.plan
    if not plan.link_ids:
        lanes = int(fs.streams.shape[-1])
        return LinkStreams((), jnp.zeros((0, 1, lanes), jnp.uint8), ())
    lq = jnp.asarray(plan.link_queue, jnp.int32)
    stacked = jnp.take(fs.streams, lq, axis=0)
    lengths = fs.link_lengths()
    if fs.aux_bt is None:
        aux = (0,) * len(plan.link_ids)
        inverts: tuple = ()
    else:
        aux_q = np.asarray(fs.aux_bt).astype(int).tolist()
        aux = tuple(aux_q[qi] for qi in plan.link_queue)
        if fs.inverts is None:
            inverts = (None,) * len(plan.link_ids)
        else:
            inverts = tuple(
                fs.inverts[qi, : lengths[i]]
                for i, qi in enumerate(plan.link_queue)
            )
    return LinkStreams(plan.link_ids, stacked, lengths, aux, inverts)


def _expand_link_streams_reference(
    topo: Topology,
    flows: Sequence[TrafficFlow],
    spec: LinkSpec,
    *,
    sort_at: str,
) -> LinkStreams:
    """The original per-flow expansion loop, kept verbatim as the pinned
    bit-exactness reference for the batched fabric pipeline (DESIGN.md
    §17) — O(flows + links) traced host ops, so never use it at fleet
    scale.  ``tests/test_fabric.py`` asserts the batched path reproduces
    its streams / lengths / aux counts / invert states byte for byte on
    every test fabric."""
    encode = ENCODE_STAGES[spec.encode]
    # per-flow: encoded payloads + element order, computed ONCE at the source
    per_flow = []
    for flow in flows:
        _validate_flow(flow, spec)
        xi = encode(flow.inputs).astype(jnp.uint8)
        wi = (
            encode(flow.weights).astype(jnp.uint8)
            if flow.weights is not None
            else None
        )
        order = make_order(
            spec.key,
            xi,
            lanes=spec.input_lanes,
            width=spec.width,
            k=spec.k,
            descending=spec.descending,
        )
        links = multicast_links(topo, flow.src, flow.dsts)
        per_flow.append((xi, wi, order, links))

    # per-link: concatenate the queued segments in injection order
    segments: dict[int, list[int]] = {}
    for fi, (_, _, _, links) in enumerate(per_flow):
        for lid in links:
            segments.setdefault(lid, []).append(fi)

    link_ids = sorted(segments)
    # links with the same queued-flow composition carry byte-identical
    # streams (every link of a unicast route, every tree link of a
    # multicast) — assemble each distinct queue once
    assembled: dict[tuple[int, ...], tuple[jax.Array, int, object]] = {}
    streams: list[jax.Array] = []
    aux_bts: list[int] = []
    inverts: list = []
    for lid in link_ids:
        idxs = tuple(segments[lid])
        entry = assembled.get(idxs)
        if entry is None:
            xi = jnp.concatenate([per_flow[i][0] for i in idxs], axis=0)
            wis = [per_flow[i][1] for i in idxs]
            wi = None if wis[0] is None else jnp.concatenate(wis, axis=0)
            order = jnp.concatenate([per_flow[i][2] for i in idxs], axis=0)
            if sort_at == "hop" and len(xi) > 1:
                perm = _packet_perm(xi, wi, spec)
                xi = jnp.take(xi, perm, axis=0)
                wi = None if wi is None else jnp.take(wi, perm, axis=0)
                order = jnp.take(order, perm, axis=0)
            stream = assemble_stream(xi, wi, spec, order, spec.pack)
            aux, inv = 0, None
            if spec.codec != "none":
                # each link's egress encoder codes its own queue; the
                # batched kernel then measures the coded wire directly.
                # invert-line state stays on device — the activity path
                # trims/materializes it only when asked to
                coded = codec_by_name(spec.codec).encode(stream)
                stream = coded.wire
                aux = int(invert_line_transitions(coded.invert))
                inv = coded.invert
            entry = assembled[idxs] = (stream, aux, inv)
        streams.append(entry[0])
        aux_bts.append(entry[1])
        inverts.append(entry[2])
    stacked, lengths = stack_link_streams(streams, spec.bytes_per_flit)
    return LinkStreams(
        tuple(link_ids), stacked, lengths, tuple(aux_bts), tuple(inverts)
    )


def stack_link_streams(
    streams: Sequence[jax.Array], lanes: int
) -> tuple[jax.Array, tuple[int, ...]]:
    """Stack jagged (T_l, lanes) streams to (L, T_max, lanes) uint8.

    Shorter streams are padded with copies of their last flit and the real
    flit counts are returned alongside.  Since the unified kernel masks
    everything past each link's length (DESIGN.md §12), the padding value
    is no longer load-bearing — a repeated flit merely keeps the padded
    tensor self-consistent for callers that inspect it.
    """
    if not streams:
        return jnp.zeros((0, 1, lanes), jnp.uint8), ()
    lengths = tuple(int(s.shape[0]) for s in streams)
    t_max = max(lengths)
    padded = [
        s if s.shape[0] == t_max else jnp.pad(
            s, ((0, t_max - s.shape[0]), (0, 0)), mode="edge"
        )
        for s in streams
    ]
    return jnp.stack(padded).astype(jnp.uint8), lengths


def simulate_noc(
    topo: Topology,
    flows: Sequence[TrafficFlow],
    spec: LinkSpec = LinkSpec(),
    *,
    sort_at: str = "source",
    power: NocPowerModel | None = None,
    interpret: bool | None = None,
    backend: str | None = None,
    chunk_rows: int | None = None,
    activity_windows: int | None = None,
    latency: NocLatencyModel | None = None,
    name: str = "noc",
) -> NocReport:
    """Run the fabric: expand flows to link streams, measure every link.

    The expansion is the batched fabric pipeline (DESIGN.md §17): routing
    compiled once into a ``FabricPlan``, payloads stacked into a
    ``FlowBatch``, and every distinct link queue assembled/coded in
    vmapped stages — then ONE ``bt_count_links`` launch measures the
    whole fabric (links sharing a queue composition carry byte-identical
    streams, so each distinct queue is measured once).  Per-link energies
    roll up through ``NocPowerModel`` (wire switching + router flit
    overhead per hop).  ``backend`` selects the kernel execution path
    (pallas | compiled | interpret, DESIGN.md §13); ``chunk_rows`` streams
    the flit-row axis in fixed-size chunks for fabrics whose stacked link
    tensor would not fit in memory at once.  ``activity_windows`` (a flit
    count) additionally measures per-wire × per-time-window switching
    activity on every link (DESIGN.md §15): the report gains
    ``wire_toggles`` / ``wire_ones`` and each link fires a
    ``link.activity`` probe event.  ``latency`` (a ``NocLatencyModel``)
    additionally evaluates the hop-contention model over the plan's queue
    tables — the report gains per-link/per-flow ``FabricLatency`` rows and
    contended links fire ``noc.contend`` probe events.
    """
    power = power if power is not None else NocPowerModel()
    with _obs.span(
        "noc.simulate",
        topology=f"{topo.kind}{topo.rows}x{topo.cols}",
        sort_at=sort_at, key=spec.key, flows=len(flows), name=name,
    ):
        report = _simulate_noc(
            topo, flows, spec, sort_at=sort_at, power=power,
            interpret=interpret, backend=backend, chunk_rows=chunk_rows,
            activity_windows=activity_windows, latency=latency, name=name,
        )
    if _obs.active():
        # per-link egress telemetry (the rows behind repro.obs.report)
        for s in report.links:
            _obs.event(
                "noc.link", link=s.link, src=s.src, dst=s.dst,
                num_flits=s.num_flits, bt_input=s.bt_input,
                bt_weight=s.bt_weight, bt_aux=s.bt_aux,
                energy_pj=s.energy_pj,
            )
        for i, s in enumerate(report.links if report.activity_window else ()):
            pw = report.wire_toggles[i].sum(axis=0)
            hot = int(np.lexsort((np.arange(len(pw)), -pw))[0])
            _obs.event(
                "link.activity", link=s.link, src=s.src, dst=s.dst,
                window_flits=report.activity_window,
                num_windows=-(-s.num_flits // report.activity_window),
                data_lanes=report.wire_lanes,
                toggles_total=int(pw.sum()),
                per_wire=[int(v) for v in pw],
                hot_wire=hot, hot_wire_toggles=int(pw[hot]),
            )
    return report


def _simulate_noc(
    topo: Topology,
    flows: Sequence[TrafficFlow],
    spec: LinkSpec,
    *,
    sort_at: str,
    power: NocPowerModel,
    interpret: bool | None,
    backend: str | None,
    chunk_rows: int | None,
    activity_windows: int | None,
    latency: NocLatencyModel | None,
    name: str,
) -> NocReport:
    plan = compile_fabric(topo, [(f.src, f.dsts) for f in flows])
    batch = FlowBatch.from_flows(flows, spec)
    fs = expand_fabric(plan, batch, spec, sort_at=sort_at)
    extra_wires = 0
    if spec.codec != "none":
        extra_wires = codec_by_name(spec.codec).extra_wires(spec.bytes_per_flit)
    stats: list[LinkStats] = []
    wire_toggles: tuple = ()
    wire_ones: tuple = ()
    if plan.link_ids:
        # ONE launch over the Q distinct queues; per-link rows are table
        # lookups (dedup'd links carry byte-identical streams)
        out = bt_count_links(
            fs.streams,
            input_lanes=spec.input_lanes,
            lengths=fs.lengths,
            interpret=interpret,
            backend=backend,
            chunk_rows=chunk_rows,
            activity_windows=activity_windows,
        )
        if activity_windows is not None:
            qtog, qone = _queue_wire_activity(
                out, fs.lengths, fs.inverts, activity_windows, extra_wires
            )
            wire_toggles = tuple(qtog[qi] for qi in plan.link_queue)
            wire_ones = tuple(qone[qi] for qi in plan.link_queue)
            bt = np.asarray(out.bt)
        else:
            bt = np.asarray(out)
        bt_rows = bt.astype(int).tolist()
        aux_q = (
            [0] * plan.num_queues
            if fs.aux_bt is None
            else np.asarray(fs.aux_bt).astype(int).tolist()
        )
        table = topo.link_table
        for lid, qi in zip(plan.link_ids, plan.link_queue):
            length = fs.lengths[qi]
            bi, bw = bt_rows[qi]
            aux = aux_q[qi]
            u, v = int(table[lid, 0]), int(table[lid, 1])
            stats.append(
                LinkStats(
                    link=lid,
                    src=u,
                    dst=v,
                    num_flits=length,
                    bt_input=bi,
                    bt_weight=bw,
                    # same coded-wire accounting as the point-to-point
                    # path: invert lines switch and widen this hop too
                    energy_pj=power.coded_hop_energy_pj(
                        bi + bw, aux, length,
                        8 * spec.bytes_per_flit, extra_wires,
                    ),
                    bt_aux=aux,
                )
            )
    fabric_lat = None
    if latency is not None:
        fabric_lat = fabric_latency(
            plan,
            [c * spec.flits_per_packet for c in batch.counts],
            latency,
        )
    flow_hops = tuple(
        (f.name, max(hop_count(topo, f.src, d) for d in f.dsts)) for f in flows
    )
    return NocReport(
        name=name,
        topology=f"{topo.kind}{topo.rows}x{topo.cols}",
        sort_at=sort_at,
        key=spec.key,
        links=tuple(stats),
        flow_hops=flow_hops,
        total_links=topo.num_links,
        activity_window=activity_windows or 0,
        wire_lanes=spec.bytes_per_flit if activity_windows else 0,
        wire_toggles=wire_toggles,
        wire_ones=wire_ones,
        latency=fabric_lat,
    )


def _queue_wire_activity(
    out, lengths: tuple[int, ...], inverts, window: int, extra_wires: int
) -> tuple[list, list]:
    """Per-queue full-wire activity: the kernel's data-wire tensors widened
    with the codec invert lines' toggles/ones.  The invert recurrence was
    already paid on device in the batched expansion; the (Q, T, npart)
    line-state tensor crosses to host ONCE here and only window bucketing
    happens per queue, in numpy."""
    tog = np.asarray(out.toggles).astype(np.int64)  # (Q, NW, lanes*8)
    one = np.asarray(out.ones).astype(np.int64)  # (Q, lanes*8)
    nw = tog.shape[1]
    inv_all = None if inverts is None else np.asarray(inverts, np.int64)
    wire_toggles, wire_ones = [], []
    for i, length in enumerate(lengths):
        aux_t = np.zeros((nw, extra_wires), np.int64)
        aux_o = np.zeros(extra_wires, np.int64)
        if inv_all is not None and length >= 1:
            iv = inv_all[i, :length]
            aux_o[: iv.shape[1]] = iv.sum(axis=0)
            if length >= 2:
                flips = (iv[1:] != iv[:-1]).astype(np.int64)
                # boundary into row t lands in window t // window — the
                # same global indexing as the kernel's data wires
                widx = np.arange(1, length) // window
                np.add.at(aux_t[:, : iv.shape[1]], widx, flips)
        wire_toggles.append(np.concatenate([tog[i], aux_t], axis=1))
        wire_ones.append(np.concatenate([one[i], aux_o]))
    return wire_toggles, wire_ones
