# The NoC interconnect subsystem: the paper's sorting unit inside a
# multi-router fabric (DESIGN.md §9).  Every hop of a route pays switching
# power, so per-link BT is the fabric metric; all links are measured by ONE
# batched Pallas launch (repro.kernels.bt_count_links).
#   topology.py - mesh / torus / ring builders + directed link tables
#   routing.py  - deterministic XY / shortest-wrap routing, multicast trees
#   simulate.py - flows -> per-link streams -> batched BT / energy report
#   power.py    - per-hop energy: link wire model + router flit overhead
#   adapters.py - real workloads (conv platform, decode weights, gradient
#                 all-reduce, MoE dispatch) as NoC flows
from .adapters import (
    conv_platform_flows,
    decode_weight_flows,
    moe_dispatch_flows,
    packetize,
    ring_allreduce_flows,
)
from .power import NocPowerModel
from .routing import hop_count, multicast_links, route, unicast_links
from .simulate import (
    LinkStats,
    LinkStreams,
    NocReport,
    TrafficFlow,
    expand_link_streams,
    simulate_noc,
    stack_link_streams,
)
from .topology import Topology, mesh, ring, torus

__all__ = [
    "Topology",
    "mesh",
    "torus",
    "ring",
    "route",
    "unicast_links",
    "multicast_links",
    "hop_count",
    "TrafficFlow",
    "LinkStats",
    "LinkStreams",
    "NocReport",
    "expand_link_streams",
    "stack_link_streams",
    "simulate_noc",
    "NocPowerModel",
    "packetize",
    "conv_platform_flows",
    "decode_weight_flows",
    "ring_allreduce_flows",
    "moe_dispatch_flows",
]
