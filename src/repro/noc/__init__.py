# The NoC interconnect subsystem: the paper's sorting unit inside a
# multi-router fabric (DESIGN.md §9, §17).  Every hop of a route pays
# switching power, so per-link BT is the fabric metric; the whole fabric is
# measured by ONE batched Pallas launch (repro.kernels.bt_count_links) over
# its distinct link queues.
#   topology.py - mesh / torus / ring builders + directed link tables
#   routing.py  - deterministic XY / shortest-wrap routing, multicast
#                 trees, and the compiled FabricPlan queue tables
#   fabric.py   - batched device-side expansion: FlowBatch -> per-queue
#                 wire streams (vmapped link stages + codecs)
#   simulate.py - flows -> fabric streams -> batched BT / energy report
#   latency.py  - wormhole serialization + merge-point contention model
#   power.py    - per-hop energy: link wire model + router flit overhead
#   adapters.py - real workloads (conv platform, decode weights, gradient
#                 all-reduce, MoE dispatch, fleet decode) as NoC flows
from .adapters import (
    conv_platform_flows,
    decode_weight_flows,
    fleet_decode_flows,
    moe_dispatch_flows,
    packetize,
    ring_allreduce_flows,
)
from .fabric import FabricStreams, FlowBatch, expand_fabric
from .latency import (
    FabricLatency,
    FlowLatency,
    LinkContention,
    NocLatencyModel,
    fabric_latency,
    route_latency_cycles,
    route_latency_ns,
)
from .power import NocPowerModel
from .routing import (
    FabricPlan,
    compile_fabric,
    hop_count,
    multicast_links,
    route,
    unicast_links,
)
from .simulate import (
    LinkStats,
    LinkStreams,
    NocReport,
    TrafficFlow,
    expand_link_streams,
    fabric_to_link_streams,
    simulate_noc,
    stack_link_streams,
)
from .topology import Topology, mesh, ring, torus

__all__ = [
    "Topology",
    "mesh",
    "torus",
    "ring",
    "route",
    "unicast_links",
    "multicast_links",
    "hop_count",
    "FabricPlan",
    "compile_fabric",
    "FlowBatch",
    "FabricStreams",
    "expand_fabric",
    "TrafficFlow",
    "LinkStats",
    "LinkStreams",
    "NocReport",
    "expand_link_streams",
    "fabric_to_link_streams",
    "stack_link_streams",
    "simulate_noc",
    "NocLatencyModel",
    "LinkContention",
    "FlowLatency",
    "FabricLatency",
    "fabric_latency",
    "route_latency_cycles",
    "route_latency_ns",
    "NocPowerModel",
    "packetize",
    "conv_platform_flows",
    "decode_weight_flows",
    "fleet_decode_flows",
    "ring_allreduce_flows",
    "moe_dispatch_flows",
]
