"""Hop-contention / queueing latency model (DESIGN.md §17).

The BT accounting answers "how much do the wires switch"; this module
answers "how long does the traffic take".  It is a deterministic analytical
wormhole model evaluated host-side over a :class:`~repro.noc.routing.
FabricPlan`'s queue tables — no event simulation, so a 16x16 fleet costs
microseconds and the numbers are exactly reproducible for the DSE plane:

  * serialization — a link transmits ``link_cycles`` per flit, so a flow's
    body pipelines ``link_cycles * (flits - 1)`` behind its head;
  * per-hop traversal — the head pays ``router_cycles + link_cycles`` at
    every hop of its XY route;
  * merge-point contention — flows queued on the same link transmit in
    injection order (the order the plan's queue tables record, which is
    also the order the expansion concatenates wire streams in): a flow
    waits ``link_cycles * (flits queued ahead of it)`` at each contended
    link.

A flow's latency is the max over its destinations of the per-destination
path latency; a link's drain latency is the time to forward its whole
queue.  Contended links (>= 2 merged flows) fire a ``noc.contend`` probe
event so the observability layer can rank merge hot-spots next to the BT
hot links.

:func:`route_latency_ns` is the single-flow special case the DSE uses to
price a design point's topology choice (one workload tenure crossing the
grid) — the AREA_BT_LATENCY Pareto plane ranks on it via
``Evaluation.total_latency_ns``.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Sequence

from repro import _obs_hooks as _obs

from .routing import FabricPlan, unicast_links

__all__ = [
    "NocLatencyModel",
    "LinkContention",
    "FlowLatency",
    "FabricLatency",
    "route_latency_cycles",
    "route_latency_ns",
    "fabric_latency",
]


@dataclasses.dataclass(frozen=True)
class NocLatencyModel:
    """Cycle-level NoC timing constants.

    Defaults follow the same 28nm-class operating point as
    ``NocPowerModel``: a 500 MHz fabric clock, a 3-cycle router pipeline
    (buffer write / route+arbitrate / crossbar) and single-cycle link
    traversal at one flit per cycle.
    """

    clock_ghz: float = 0.5
    router_cycles: int = 3
    link_cycles: int = 1

    def __post_init__(self) -> None:
        if self.clock_ghz <= 0:
            raise ValueError(f"clock_ghz must be > 0, got {self.clock_ghz}")
        if self.router_cycles < 0 or self.link_cycles < 1:
            raise ValueError(
                "need router_cycles >= 0 and link_cycles >= 1, got "
                f"{self.router_cycles}/{self.link_cycles}"
            )

    @property
    def cycle_ns(self) -> float:
        return 1.0 / self.clock_ghz

    def ns(self, cycles: int) -> float:
        return float(cycles) * self.cycle_ns


class LinkContention(NamedTuple):
    """One directed link's occupancy / contention accounting."""

    link: int
    src: int
    dst: int
    flows: int  # flows merged onto this link
    flits: int  # total flits forwarded (serialization occupancy)
    wait_cycles: int  # aggregate injection-order queueing delay
    busy_ns: float  # serialization time: link_cycles * flits
    drain_ns: float  # router traversal + full queue serialization


class FlowLatency(NamedTuple):
    """One flow's delivery latency (max over its destinations)."""

    flow: int
    hops: int  # XY hops to the latency-critical destination
    flits: int
    wait_cycles: int  # contention stalls along the critical path
    cycles: int
    latency_ns: float


class FabricLatency(NamedTuple):
    """The whole fabric's latency picture: per-link and per-flow rows."""

    links: tuple[LinkContention, ...]
    flows: tuple[FlowLatency, ...]
    model: NocLatencyModel

    @property
    def max_latency_ns(self) -> float:
        return max((f.latency_ns for f in self.flows), default=0.0)

    @property
    def mean_latency_ns(self) -> float:
        return (
            sum(f.latency_ns for f in self.flows) / len(self.flows)
            if self.flows
            else 0.0
        )

    @property
    def total_wait_cycles(self) -> int:
        return sum(l.wait_cycles for l in self.links)

    @property
    def contended_links(self) -> int:
        return sum(1 for l in self.links if l.flows >= 2)


def route_latency_cycles(
    hops: int, flits: int, model: NocLatencyModel = NocLatencyModel()
) -> int:
    """Uncontended wormhole traversal of one route: the head flit pays
    router + link at every hop, the body pipelines one link behind."""
    if hops <= 0 or flits <= 0:
        return 0
    head = hops * (model.router_cycles + model.link_cycles)
    return head + model.link_cycles * (flits - 1)


def route_latency_ns(
    hops: int, flits: int, model: NocLatencyModel = NocLatencyModel()
) -> float:
    return model.ns(route_latency_cycles(hops, flits, model))


def fabric_latency(
    plan: FabricPlan,
    flits_per_flow: Sequence[int],
    model: NocLatencyModel = NocLatencyModel(),
) -> FabricLatency:
    """Evaluate the contention model over a compiled fabric plan.

    ``flits_per_flow[f]`` is flow f's flit count (packets x
    flits_per_packet).  Fires one ``noc.contend`` probe event per link
    that merges >= 2 flows.
    """
    flits_per_flow = tuple(int(v) for v in flits_per_flow)
    if len(flits_per_flow) != plan.num_flows:
        raise ValueError(
            f"{len(flits_per_flow)} flit counts for {plan.num_flows} flows"
        )
    topo = plan.topo
    # per active link: queue occupancy + each member flow's head-of-line wait
    wait_at: dict[int, dict[int, int]] = {}
    links: list[LinkContention] = []
    for lid, qi in zip(plan.link_ids, plan.link_queue):
        queue = plan.queues[qi]
        ahead = 0
        waits: dict[int, int] = {}
        for f in queue:
            waits[f] = model.link_cycles * ahead
            ahead += flits_per_flow[f]
        wait_at[lid] = waits
        u, v = topo.links[lid]
        total_wait = sum(waits.values())
        links.append(
            LinkContention(
                link=lid,
                src=u,
                dst=v,
                flows=len(queue),
                flits=ahead,
                wait_cycles=total_wait,
                busy_ns=model.ns(model.link_cycles * ahead),
                drain_ns=model.ns(
                    model.router_cycles + model.link_cycles * ahead
                ),
            )
        )
        if len(queue) >= 2 and _obs.active():
            _obs.event(
                "noc.contend", link=lid, src=u, dst=v, flows=len(queue),
                flits=ahead, wait_cycles=total_wait,
            )
    # per flow: worst destination's path latency under those waits
    flows: list[FlowLatency] = []
    for fi, (src, dsts) in enumerate(plan.endpoints):
        flits = flits_per_flow[fi]
        best = (0, 0, 0)  # (cycles, hops, wait)
        for dst in dsts:
            if dst == src:
                continue
            path = unicast_links(topo, src, dst)
            wait = sum(wait_at[lid].get(fi, 0) for lid in path)
            cycles = (
                route_latency_cycles(len(path), flits, model) + wait
            )
            if cycles > best[0]:
                best = (cycles, len(path), wait)
        cycles, hops, wait = best
        flows.append(
            FlowLatency(
                flow=fi,
                hops=hops,
                flits=flits,
                wait_cycles=wait,
                cycles=cycles,
                latency_ns=model.ns(cycles),
            )
        )
    return FabricLatency(tuple(links), tuple(flows), model)
