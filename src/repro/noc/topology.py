"""NoC topologies: routers, directed links and coordinate maps.

A topology is a grid of routers (``rows`` x ``cols``) plus a table of
*directed* links — each physical channel direction is its own link, because
bit transitions (and therefore switching power) are accounted per driven
wire.  Three families cover the paper's §V NoC setting and the companion
work's evaluation fabrics (arXiv:2509.00500):

  * ``mesh(rows, cols)``  — 2D mesh, no wraparound,
  * ``torus(rows, cols)`` — 2D torus (wraparound both dimensions),
  * ``ring(n)``           — a cycle; represented as a 1 x n torus so the
    routing layer treats all three uniformly (dimension-order steps with a
    shortest-wrap direction choice).

Link ids are stable, dense ints in builder order — the NoC simulator uses
them as rows of the batched BT kernel's (links, flits, lanes) tensor.
"""

from __future__ import annotations

import dataclasses
import functools

__all__ = ["Topology", "mesh", "torus", "ring"]


@dataclasses.dataclass(frozen=True)
class Topology:
    """A router grid plus its directed link table.

    ``wrap`` distinguishes torus/ring (shortest-direction wraparound steps)
    from mesh (monotone steps only).
    """

    kind: str  # 'mesh' | 'torus' | 'ring'
    rows: int
    cols: int
    wrap: bool
    links: tuple[tuple[int, int], ...]  # directed (src, dst) router pairs

    @property
    def num_routers(self) -> int:
        return self.rows * self.cols

    @property
    def num_links(self) -> int:
        return len(self.links)

    def coords(self, router: int) -> tuple[int, int]:
        """(row, col) of a router id."""
        if not 0 <= router < self.num_routers:
            raise ValueError(f"router {router} outside 0..{self.num_routers - 1}")
        return divmod(router, self.cols)

    def router(self, row: int, col: int) -> int:
        """Router id at (row, col); wraps for torus/ring coordinates."""
        if self.wrap:
            row, col = row % self.rows, col % self.cols
        if not (0 <= row < self.rows and 0 <= col < self.cols):
            raise ValueError(f"({row}, {col}) outside {self.rows}x{self.cols} grid")
        return row * self.cols + col

    @functools.cached_property
    def _link_ids(self) -> dict[tuple[int, int], int]:
        return {pair: i for i, pair in enumerate(self.links)}

    def link_id(self, src: int, dst: int) -> int:
        """Dense id of the directed link src -> dst."""
        lid = self._link_ids.get((src, dst))
        if lid is None:
            raise ValueError(f"no link {src} -> {dst} in {self.kind} topology")
        return lid

    def row_routers(self, row: int) -> tuple[int, ...]:
        """All routers in one grid row (the weight-broadcast multicast set)."""
        return tuple(row * self.cols + c for c in range(self.cols))

    def column_routers(self, col: int) -> tuple[int, ...]:
        """All routers in one grid column (a shard's PE placement in the
        fleet decode workload, ``noc.adapters.fleet_decode_flows``)."""
        if not 0 <= col < self.cols:
            raise ValueError(f"column {col} outside 0..{self.cols - 1}")
        return tuple(r * self.cols + col for r in range(self.rows))

    @functools.cached_property
    def link_table(self):
        """The directed link endpoints as one (num_links, 2) int32 numpy
        array — the O(1)-per-lookup form the fleet-scale report builders
        index instead of unpacking ``links`` tuples link by link."""
        import numpy as np

        if not self.links:
            return np.zeros((0, 2), np.int32)
        return np.asarray(self.links, np.int32)


def _grid_links(rows: int, cols: int, wrap: bool) -> tuple[tuple[int, int], ...]:
    """Directed neighbor links in deterministic (router, +col, -col, +row,
    -row) order; wraparound duplicates (2-cycles on 2-long wrapped dims)
    are deduplicated."""
    links: dict[tuple[int, int], None] = {}
    for r in range(rows):
        for c in range(cols):
            u = r * cols + c
            steps = []
            if cols > 1:
                if c + 1 < cols:
                    steps.append((r, c + 1))
                elif wrap:
                    steps.append((r, 0))
                if c - 1 >= 0:
                    steps.append((r, c - 1))
                elif wrap:
                    steps.append((r, cols - 1))
            if rows > 1:
                if r + 1 < rows:
                    steps.append((r + 1, c))
                elif wrap:
                    steps.append((0, c))
                if r - 1 >= 0:
                    steps.append((r - 1, c))
                elif wrap:
                    steps.append((rows - 1, c))
            for rr, cc in steps:
                links.setdefault((u, rr * cols + cc), None)
    return tuple(links)


def mesh(rows: int, cols: int) -> Topology:
    """2D mesh: 2*(rows*(cols-1) + cols*(rows-1)) directed links."""
    if rows < 1 or cols < 1 or rows * cols < 2:
        raise ValueError(f"mesh needs >= 2 routers, got {rows}x{cols}")
    return Topology("mesh", rows, cols, False, _grid_links(rows, cols, False))


def torus(rows: int, cols: int) -> Topology:
    """2D torus: wraparound in both dimensions."""
    if rows < 2 or cols < 2:
        raise ValueError(f"torus needs both dims >= 2, got {rows}x{cols}")
    return Topology("torus", rows, cols, True, _grid_links(rows, cols, True))


def ring(n: int) -> Topology:
    """n-router cycle (a 1 x n torus; both directions are present)."""
    if n < 3:
        raise ValueError(f"ring needs >= 3 routers, got {n}")
    return Topology("ring", 1, n, True, _grid_links(1, n, True))
