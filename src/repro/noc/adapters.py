"""Traffic adapters: real workload tensors -> NoC flows.

Three workload families from the existing layers, mapped onto fabric
traffic patterns (DESIGN.md §9):

  * **Conv platform** (paper §IV-B, ``benchmarks/lenet_workload.py``): the
    allocation unit scatters im2col patch packets from a memory router to
    the PE routers (unicast each), with the convolution kernel bytes riding
    the paired weight lanes — the paper's 16-PE platform laid out on a
    mesh.
  * **Decode weight streams** (``repro.serve`` / ``repro.traffic``): a
    weight matrix's int8 HBM image is one long byte stream multicast from
    the memory-controller router to a row of PEs — the weight-broadcast
    traffic that dominates decode.
  * **Gradient all-reduce** (``repro.optim``): the int8 gradient wire image
    sharded over the routers of a ring schedule, each shard hopping to the
    next router — one step of a ring reduce-scatter, the ICI collective
    pattern of DESIGN.md §5 on the modeled fabric.
  * **Fleet decode** (DESIGN.md §17, the ``fleet_noc`` benchmark):
    multi-tenant decode weight broadcast — users x layers x shards
    multicast flows on a large grid, tenants pinned to rows, shards to
    column groups; the merge-heavy traffic the contention model prices.

Adapters only build ``TrafficFlow``s; ordering/packing/measuring stay in
:mod:`repro.noc.simulate`.
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.link import LinkSpec, tensor_flit_stream
from repro.traffic.ordering import int8_view

from .simulate import TrafficFlow
from .topology import Topology

__all__ = [
    "packetize",
    "conv_platform_flows",
    "decode_weight_flows",
    "fleet_decode_flows",
    "ring_allreduce_flows",
    "moe_dispatch_flows",
]


def _wire_bytes(x: jax.Array) -> jax.Array:
    """A tensor's int8 wire image as flat uint8 bytes.

    int8/uint8 inputs ARE already their wire image (e.g. captured streams
    from ``repro.obs.capture``) and pass through untouched — re-quantizing
    them would rescale the bytes and distort the measured distribution.
    """
    if x.dtype in (jnp.dtype(jnp.uint8), jnp.dtype(jnp.int8)):
        return jnp.ravel(x).astype(jnp.uint8)
    return jnp.ravel(int8_view(x)).astype(jnp.uint8)


def packetize(data: jax.Array, elems: int) -> jax.Array:
    """Flatten a byte tensor and shape it into (P, elems) packets (trimmed
    to whole packets; a NoC flow transmits complete packets only)."""
    pkts = tensor_flit_stream(jnp.ravel(data).astype(jnp.uint8), elems)
    if pkts.shape[0] == 0:
        raise ValueError(
            f"payload of {data.size} bytes is smaller than one "
            f"{elems}-byte packet"
        )
    return pkts


def conv_platform_flows(
    patches: jax.Array,
    kernel_bytes: jax.Array,
    topo: Topology,
    src: int,
    pe_routers: Sequence[int],
    spec: LinkSpec = LinkSpec(),
) -> list[TrafficFlow]:
    """Scatter conv input packets to PE routers, kernels on the weight lanes.

    ``patches`` is the (num_patches, window) im2col byte matrix of one
    image; ``kernel_bytes`` one output channel's flattened kernel.  Packets
    are dealt round-robin over ``pe_routers``; when the spec has weight
    lanes each packet pairs with the cyclically-tiled kernel bytes (the
    repeated-kernel stream of ``benchmarks/lenet_workload.py``).
    """
    topo.coords(src)  # validates the router id
    pkts = packetize(patches, spec.elems_per_packet)
    p = pkts.shape[0]
    if spec.weight_lanes:
        wrep = jnp.tile(
            jnp.ravel(kernel_bytes).astype(jnp.uint8),
            (p * spec.weight_elems_per_packet) // kernel_bytes.size + 1,
        )[: p * spec.weight_elems_per_packet].reshape(p, -1)
    flows = []
    for i, pe in enumerate(pe_routers):
        sel = jnp.arange(i, p, len(pe_routers))
        if sel.shape[0] == 0:
            continue
        flows.append(
            TrafficFlow(
                name=f"conv/pe{pe}",
                src=src,
                dsts=(pe,),
                inputs=jnp.take(pkts, sel, axis=0),
                weights=(
                    jnp.take(wrep, sel, axis=0) if spec.weight_lanes else None
                ),
            )
        )
    return flows


def decode_weight_flows(
    weight: jax.Array,
    topo: Topology,
    src: int,
    dsts: Sequence[int],
    spec: LinkSpec = LinkSpec(),
    max_packets: int | None = None,
) -> list[TrafficFlow]:
    """Multicast a weight matrix's int8 HBM stream to a set of PE routers.

    The matrix is quantized to its int8 wire image (``repro.traffic``), the
    row-major byte stream is packetized, and ONE multicast flow carries it
    down the XY tree — each tree link transmits a single copy, which is the
    bandwidth argument for weight broadcast.  Input-only specs model the
    dedicated weight-distribution channel.
    """
    if spec.weight_lanes:
        raise ValueError(
            "decode weight streams are a one-sided broadcast; use an "
            "input-only spec (weight_lanes=0)"
        )
    topo.coords(src)  # validates the router id
    pkts = packetize(_wire_bytes(weight), spec.elems_per_packet)
    if max_packets is not None:
        pkts = pkts[:max_packets]
    return [
        TrafficFlow(
            name="decode/weights",
            src=src,
            dsts=tuple(dsts),
            inputs=pkts,
        )
    ]


def fleet_decode_flows(
    weights: jax.Array,
    topo: Topology,
    *,
    users: int,
    layers: int,
    shards: int,
    spec: LinkSpec = LinkSpec(input_lanes=16, weight_lanes=0),
    packets_per_flow: int = 2,
) -> list[TrafficFlow]:
    """Multi-tenant decode weight traffic: users x layers x shards flows.

    The fleet-serving pattern behind the ROADMAP's 16x16 north star (and
    the ``fleet_noc`` benchmark): tenant ``u`` is pinned to grid row
    ``u % rows`` — its memory-controller router sits at column 0 and its
    PEs are the remaining routers of the row.  For every decode layer
    ``l``, weight shard ``s`` multicasts from the tenant's memory router
    to the ``s``-th contiguous group of the row's PE columns (the
    tensor-parallel shard placement), so flows of co-located tenants and
    of every layer merge on the row's column-0 egress links — exactly the
    merge-point contention ``noc.latency`` prices.

    Payloads are deterministic strided slices of ``weights``'s int8 wire
    image (tiled if the tensor is smaller than one flow), so every flow
    carries distinct but reproducible bytes.
    """
    if spec.weight_lanes:
        raise ValueError(
            "fleet decode traffic is a one-sided broadcast; use an "
            "input-only spec (weight_lanes=0)"
        )
    if users < 1 or layers < 1 or shards < 1:
        raise ValueError(
            f"need users/layers/shards >= 1, got {users}/{layers}/{shards}"
        )
    if packets_per_flow < 1:
        raise ValueError(f"packets_per_flow must be >= 1, got {packets_per_flow}")
    pe_cols = topo.cols - 1
    if pe_cols < shards:
        raise ValueError(
            f"{shards} shards need {shards} PE columns; a {topo.rows}x"
            f"{topo.cols} grid has {pe_cols} (column 0 is the memory router)"
        )
    data = _wire_bytes(weights)
    need = packets_per_flow * spec.elems_per_packet
    if int(data.size) < need:
        data = jnp.tile(data, -(-need // int(data.size)))
    span = int(data.size) - need  # highest valid slice start
    flows = []
    for u in range(users):
        row = u % topo.rows
        mem = topo.router(row, 0)
        for layer in range(layers):
            for s in range(shards):
                lo = s * pe_cols // shards
                hi = (s + 1) * pe_cols // shards
                dsts = tuple(
                    topo.router(row, 1 + c) for c in range(lo, hi)
                )
                fi = (u * layers + layer) * shards + s
                # coprime stride walks the wire image without re-slicing
                # the same window for co-located tenants
                off = 0 if span == 0 else (fi * 7919) % (span + 1)
                flows.append(
                    TrafficFlow(
                        name=f"u{u}/l{layer}/s{s}",
                        src=mem,
                        dsts=dsts,
                        inputs=data[off : off + need].reshape(
                            packets_per_flow, spec.elems_per_packet
                        ),
                    )
                )
    return flows


def ring_allreduce_flows(
    grad: jax.Array,
    topo: Topology,
    routers: Sequence[int] | None = None,
    spec: LinkSpec = LinkSpec(),
) -> list[TrafficFlow]:
    """One ring reduce-scatter step of a gradient's int8 wire image.

    The flat gradient quantizes to int8 (the ``repro.optim`` compressed
    wire format), shards evenly over ``routers`` (default: every router, in
    id order — on a ring topology that is the physical cycle), and shard i
    flows from router i to its cyclic successor.  Repeating with rotated
    shards would model the remaining R-1 steps; one step already exercises
    every inter-router hop with distinct payloads.
    """
    order = tuple(routers) if routers is not None else tuple(
        range(topo.num_routers)
    )
    if len(order) < 2:
        raise ValueError("ring all-reduce needs >= 2 routers")
    if spec.weight_lanes:
        raise ValueError("gradient traffic is one-sided; use weight_lanes=0")
    pkts = packetize(_wire_bytes(grad), spec.elems_per_packet)
    shard = max(pkts.shape[0] // len(order), 1)
    flows = []
    for i, r in enumerate(order):
        lo = min(i * shard, pkts.shape[0])
        hi = pkts.shape[0] if i == len(order) - 1 else min(lo + shard, pkts.shape[0])
        if hi <= lo:
            continue
        flows.append(
            TrafficFlow(
                name=f"allreduce/shard{i}",
                src=r,
                dsts=(order[(i + 1) % len(order)],),
                inputs=pkts[lo:hi],
            )
        )
    return flows


def moe_dispatch_flows(
    expert_in: jax.Array,
    topo: Topology,
    src: int,
    expert_routers: Sequence[int],
    spec: LinkSpec = LinkSpec(),
) -> list[TrafficFlow]:
    """MoE dispatch: each expert's capacity buffer unicast to its router.

    ``expert_in`` is the (G, E, C, D) dispatched buffer of
    ``repro.models.moe.moe_block`` (or its captured int8 wire image from
    ``repro.obs.capture``); expert e's slice ``expert_in[:, e]`` flows from
    the dispatch router ``src`` to ``expert_routers[e % len]`` — the ICI
    all-to-all leg of DESIGN.md §5 on the modeled fabric.  Tokens inside a
    capacity buffer are an unordered set, which is exactly the permutation
    freedom the paper's sorting unit exploits (``sort_at`` in
    ``simulate_noc``).
    """
    if spec.weight_lanes:
        raise ValueError("dispatch traffic is one-sided; use weight_lanes=0")
    if expert_in.ndim != 4:
        raise ValueError(
            f"expert_in must be (groups, experts, capacity, d_model), "
            f"got shape {tuple(expert_in.shape)}"
        )
    if not expert_routers:
        raise ValueError("moe dispatch needs >= 1 expert router")
    topo.coords(src)  # validates the router id
    flows = []
    for e in range(expert_in.shape[1]):
        data = _wire_bytes(expert_in[:, e])
        if int(data.size) < spec.elems_per_packet:
            continue  # padded expert with an empty (sub-packet) buffer
        flows.append(
            TrafficFlow(
                name=f"moe/expert{e}",
                src=src,
                dsts=(expert_routers[e % len(expert_routers)],),
                inputs=packetize(data, spec.elems_per_packet),
            )
        )
    if not flows:
        raise ValueError(
            f"no expert buffer reaches one {spec.elems_per_packet}-byte "
            "packet; capture more tokens or shrink the packet"
        )
    return flows
