"""NoC power roll-up: the Fig. 6/7 link model extended with per-hop cost.

Every hop of a multi-router path drives one link's wires (BT-proportional,
exactly the single-link ``repro.link.LinkPowerModel``) *and* one router
traversal (buffer write/read, crossbar, arbitration — flit-proportional,
data-independent to first order).  Interconnect energy scaling with the
Hamming distance of consecutive transfers is the observation of Li et al.
(arXiv:2002.05293); the router constant is the standard NoC flit-energy
term.  Sorting therefore attacks the BT-proportional share only: the
router term is the NoC analogue of the single-link model's clock/control
floor, and it dilutes the fabric-level reduction the same way
``transfer_factor`` dilutes the link-level one.
"""

from __future__ import annotations

import dataclasses

from repro.link.power import LinkPowerModel

__all__ = ["NocPowerModel"]


@dataclasses.dataclass(frozen=True)
class NocPowerModel(LinkPowerModel):
    """Per-hop energy: inherited per-link wire model + router flit energy.

    ``router_flit_energy_pj`` is a representative 22 nm 5-port
    wormhole-router traversal (buffering + crossbar + arbitration) per
    128-bit flit; like the base model's absolute constants, ratios are the
    claim, the absolute scale is modeled.
    """

    router_flit_energy_pj: float = 0.98

    def hop_energy_pj(self, total_bt: float, num_flits: int) -> float:
        """Energy of one link traversal: wire switching + router overhead.

        The fabric total is the sum of these over all links — the
        simulator stores one per ``LinkStats`` and ``NocReport.energy_pj``
        sums them, so the roll-up has a single code path.
        """
        return self.link_energy_pj(total_bt, num_flits) + (
            self.router_flit_energy_pj * float(num_flits)
        )

    def coded_hop_energy_pj(
        self,
        data_bt: float,
        aux_bt: float,
        num_flits: int,
        data_wires: int,
        extra_wires: int = 0,
    ) -> float:
        """Coded-link traversal: the widened-wire link model (identical to
        the point-to-point ``coded_link_energy_pj`` accounting, DESIGN.md
        §11) plus the router flit overhead.  Reduces to ``hop_energy_pj``
        when the link is uncoded."""
        return self.coded_link_energy_pj(
            data_bt, aux_bt, num_flits, data_wires, extra_wires
        ) + self.router_flit_energy_pj * float(num_flits)

    def wire_hop_energy_pj(
        self,
        per_wire_bt,
        num_flits: int,
        *,
        wire_caps=None,
        data_wires: int | None = None,
        extra_wires: int = 0,
    ) -> float:
        """Wire-resolved hop traversal: the per-wire link model (§15) plus
        the router flit overhead.  With uniform caps this equals
        ``coded_hop_energy_pj`` of the summed BT exactly — same refinement
        contract as the base model's ``wire_energy_pj``."""
        return self.wire_energy_pj(
            per_wire_bt, num_flits, wire_caps=wire_caps,
            data_wires=data_wires, extra_wires=extra_wires,
        ) + self.router_flit_energy_pj * float(num_flits)
