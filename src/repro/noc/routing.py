"""Deterministic routing and path expansion (DESIGN.md §9).

Dimension-order (XY) routing covers all three topology families: a packet
first corrects its column offset, then its row offset.  On a mesh each
dimension is walked monotonically; on a torus/ring each dimension picks the
shorter wrap direction (ties break toward +), which on a ring degenerates
to classic shortest-direction ring routing.  XY is deadlock-free on the
mesh and — more importantly here — *deterministic*, so a multicast to many
destinations is a tree: paths from one source share prefixes, and the
union of their links visits each physical link at most once (one copy of
the payload per link, the standard tree-multicast accounting).

``unicast_links`` / ``multicast_links`` expand route endpoints into the
ordered link-id lists the simulator schedules flit streams onto.

:func:`compile_fabric` runs the deterministic router ONCE for a whole set
of flow endpoints and freezes the result as a :class:`FabricPlan` — the
(flow x link) incidence and per-link queue tables the batched expansion
path (``repro.noc.fabric``, DESIGN.md §17) and the contention model
(``repro.noc.latency``) both read.  The plan is pure routing: payload
bytes never enter it, so one plan serves every spec / sort mode / payload
of the same traffic pattern.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from .topology import Topology

__all__ = [
    "route",
    "unicast_links",
    "multicast_links",
    "hop_count",
    "FabricPlan",
    "compile_fabric",
]


def _axis_step(pos: int, dst: int, size: int, wrap: bool) -> int:
    """Next coordinate along one dimension (monotone, or shortest wrap)."""
    if pos == dst:
        return pos
    if not wrap:
        return pos + (1 if dst > pos else -1)
    fwd = (dst - pos) % size
    back = (pos - dst) % size
    return (pos + (1 if fwd <= back else -1)) % size


def route(topo: Topology, src: int, dst: int) -> list[int]:
    """Router sequence from src to dst (inclusive) under XY routing."""
    r, c = topo.coords(src)
    dr, dc = topo.coords(dst)
    path = [src]
    while c != dc:
        c = _axis_step(c, dc, topo.cols, topo.wrap)
        path.append(topo.router(r, c))
    while r != dr:
        r = _axis_step(r, dr, topo.rows, topo.wrap)
        path.append(topo.router(r, c))
    return path


def hop_count(topo: Topology, src: int, dst: int) -> int:
    """Number of links the XY route crosses."""
    return len(route(topo, src, dst)) - 1


def unicast_links(topo: Topology, src: int, dst: int) -> list[int]:
    """Ordered link ids of the XY route src -> dst."""
    path = route(topo, src, dst)
    return [topo.link_id(u, v) for u, v in zip(path[:-1], path[1:])]


def multicast_links(topo: Topology, src: int, dsts: tuple[int, ...]) -> list[int]:
    """Link ids of the XY multicast tree from src to every destination.

    The union of the deterministic unicast routes, deduplicated in
    first-visit order: shared path prefixes (and on wrapped topologies the
    occasional shared interior segment) carry ONE copy of the payload — the
    whole point of tree multicast for broadcast-heavy weight traffic.
    """
    seen: dict[int, None] = {}
    for dst in dsts:
        if dst == src:
            continue
        for lid in unicast_links(topo, src, dst):
            seen.setdefault(lid, None)
    return list(seen)


@dataclasses.dataclass(frozen=True)
class FabricPlan:
    """Routing of a whole flow set, compiled once into queue tables.

    The plan captures everything the batched expansion needs that is NOT
    payload bytes:

      * ``link_ids``   — the active links, ascending (the row order of
        every per-link report, identical to the legacy expansion loop);
      * ``link_queue`` — per active link, the index of its *distinct
        queue*: links whose queued-flow composition is identical carry
        byte-identical streams, so they share one assembled/measured row
        (multicast tree links, every interior link of a unicast route);
      * ``queues``     — the distinct queues, each the tuple of flow
        indices feeding that link IN INJECTION ORDER (the order the
        legacy loop concatenated segments in — bit-exactness depends on
        it);
      * ``flow_links`` — per flow, its multicast-tree link ids (the
        flow x link incidence, first-visit order);
      * ``endpoints``  — the (src, dsts) pairs the plan was compiled from,
        kept so the contention model (``noc.latency``) can walk per-
        destination paths without re-deriving the traffic pattern.
    """

    topo: Topology
    num_flows: int
    link_ids: tuple[int, ...]
    link_queue: tuple[int, ...]
    queues: tuple[tuple[int, ...], ...]
    flow_links: tuple[tuple[int, ...], ...]
    endpoints: tuple[tuple[int, tuple[int, ...]], ...]

    @property
    def num_queues(self) -> int:
        return len(self.queues)

    @property
    def active_links(self) -> int:
        return len(self.link_ids)

    def queue_of(self, link_id: int) -> tuple[int, ...]:
        """The flow indices queued on one active link (injection order)."""
        return self.queues[self.link_queue[self.link_ids.index(link_id)]]


def compile_fabric(
    topo: Topology, endpoints: Sequence[tuple[int, tuple[int, ...]]]
) -> FabricPlan:
    """Route every (src, dsts) endpoint pair once and freeze the tables.

    ``endpoints[f]`` describes flow f; the returned plan's queue tables
    reproduce exactly what the legacy per-flow expansion loop built as
    Python dicts — links sorted ascending, each link's queue holding flow
    indices in injection order, distinct compositions deduplicated in
    first-use order along the ascending link scan.
    """
    endpoints = tuple((src, tuple(dsts)) for src, dsts in endpoints)
    flow_links = tuple(
        tuple(multicast_links(topo, src, dsts)) for src, dsts in endpoints
    )
    segments: dict[int, list[int]] = {}
    for fi, links in enumerate(flow_links):
        for lid in links:
            segments.setdefault(lid, []).append(fi)
    link_ids = tuple(sorted(segments))
    queue_index: dict[tuple[int, ...], int] = {}
    link_queue = []
    for lid in link_ids:
        key = tuple(segments[lid])
        qi = queue_index.setdefault(key, len(queue_index))
        link_queue.append(qi)
    return FabricPlan(
        topo=topo,
        num_flows=len(flow_links),
        link_ids=link_ids,
        link_queue=tuple(link_queue),
        queues=tuple(queue_index),
        flow_links=flow_links,
        endpoints=endpoints,
    )
