"""Deterministic routing and path expansion (DESIGN.md §9).

Dimension-order (XY) routing covers all three topology families: a packet
first corrects its column offset, then its row offset.  On a mesh each
dimension is walked monotonically; on a torus/ring each dimension picks the
shorter wrap direction (ties break toward +), which on a ring degenerates
to classic shortest-direction ring routing.  XY is deadlock-free on the
mesh and — more importantly here — *deterministic*, so a multicast to many
destinations is a tree: paths from one source share prefixes, and the
union of their links visits each physical link at most once (one copy of
the payload per link, the standard tree-multicast accounting).

``unicast_links`` / ``multicast_links`` expand route endpoints into the
ordered link-id lists the simulator schedules flit streams onto.
"""

from __future__ import annotations

from .topology import Topology

__all__ = [
    "route",
    "unicast_links",
    "multicast_links",
    "hop_count",
]


def _axis_step(pos: int, dst: int, size: int, wrap: bool) -> int:
    """Next coordinate along one dimension (monotone, or shortest wrap)."""
    if pos == dst:
        return pos
    if not wrap:
        return pos + (1 if dst > pos else -1)
    fwd = (dst - pos) % size
    back = (pos - dst) % size
    return (pos + (1 if fwd <= back else -1)) % size


def route(topo: Topology, src: int, dst: int) -> list[int]:
    """Router sequence from src to dst (inclusive) under XY routing."""
    r, c = topo.coords(src)
    dr, dc = topo.coords(dst)
    path = [src]
    while c != dc:
        c = _axis_step(c, dc, topo.cols, topo.wrap)
        path.append(topo.router(r, c))
    while r != dr:
        r = _axis_step(r, dr, topo.rows, topo.wrap)
        path.append(topo.router(r, c))
    return path


def hop_count(topo: Topology, src: int, dst: int) -> int:
    """Number of links the XY route crosses."""
    return len(route(topo, src, dst)) - 1


def unicast_links(topo: Topology, src: int, dst: int) -> list[int]:
    """Ordered link ids of the XY route src -> dst."""
    path = route(topo, src, dst)
    return [topo.link_id(u, v) for u, v in zip(path[:-1], path[1:])]


def multicast_links(topo: Topology, src: int, dsts: tuple[int, ...]) -> list[int]:
    """Link ids of the XY multicast tree from src to every destination.

    The union of the deterministic unicast routes, deduplicated in
    first-visit order: shared path prefixes (and on wrapped topologies the
    occasional shared interior segment) carry ONE copy of the payload — the
    whole point of tree multicast for broadcast-heavy weight traffic.
    """
    seen: dict[int, None] = {}
    for dst in dsts:
        if dst == src:
            continue
        for lid in unicast_links(topo, src, dst):
            seen.setdefault(lid, None)
    return list(seen)
