"""Pallas TPU kernel: bit-transition counting over a flit stream.

The BT metric (Hamming distance between consecutive flits, summed) is the
paper's evaluation workhorse; at framework scale we run it over multi-GB
modeled traffic (weights, activations, collective payloads), so it gets a
kernel.  The wrapper presents the stream twice (rows [0, T-1) and rows
[1, T)) so each grid step reduces one (R, L) block of XOR popcounts with no
cross-block carry; per-block partials land in a (G,) output reduced by the
caller.  Memory-bound by design: one pass over the stream, 8 ops/byte.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .backend import default_backend
from .psu import _popcount_bits

__all__ = ["bt_count_pallas", "bt_count_compiled"]


def _bt_kernel(a_ref, b_ref, out_ref, *, width: int):
    a = a_ref[...].astype(jnp.int32)
    b = b_ref[...].astype(jnp.int32)
    flips = jnp.bitwise_xor(a, b)
    out_ref[0] = _popcount_bits(flips, width).sum()


def bt_count_pallas(
    stream: jax.Array,
    *,
    width: int = 8,
    block_rows: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Total bit transitions of a (T, L) flit stream (int32 scalar).

    Rows are consecutive flits, columns are byte lanes.  ``T - 1`` boundary
    rows are padded (with zeros on *both* shifted views, so pads contribute
    zero) to a multiple of ``block_rows``.
    """
    if interpret is None:
        interpret = default_backend() != "pallas"
    t, lanes = stream.shape
    if t < 2:
        return jnp.int32(0)
    a = stream[:-1].astype(jnp.int32)
    b = stream[1:].astype(jnp.int32)
    rows = t - 1
    pad = (-rows) % block_rows
    if pad:
        a = jnp.pad(a, ((0, pad), (0, 0)))
        b = jnp.pad(b, ((0, pad), (0, 0)))
    grid = ((rows + pad) // block_rows,)
    kern = functools.partial(_bt_kernel, width=width)
    spec = pl.BlockSpec((block_rows, lanes), lambda i: (i, 0))
    partials = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[spec, spec],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(grid, jnp.int32),
        interpret=interpret,
    )(a, b)
    return partials.sum()


def bt_count_compiled(stream: jax.Array, *, width: int = 8) -> jax.Array:
    """The compiled (pure-jnp) backend: one XOR-popcount reduction.

    Same contract and result as :func:`bt_count_pallas` (exact — integer
    popcount sums have one value).
    """
    t = stream.shape[0]
    if t < 2:
        return jnp.int32(0)
    x = stream.astype(jnp.int32)
    return _popcount_bits(x[1:] ^ x[:-1], width).sum().astype(jnp.int32)
