"""Pallas TPU kernel: batched per-link bit-transition counting.

The NoC simulator (``repro.noc``) accounts BT on every directed link of a
multi-router fabric; looping the single-stream ``bt_count`` kernel over
links costs one launch per link (a 4x4 mesh has 48 directed links, an 8x8
mesh 224).  This kernel puts the link axis on the grid instead: one launch
reduces a (links, flits, byte-lanes) stream tensor to per-link
(input-side, weight-side) BT partials, reusing the ``psu_stream`` popcount
machinery for the XOR popcounts.

Like ``btcount.py``, each grid step reduces a shifted-view block (rows
[0, T-1) vs rows [1, T) of every link) with no cross-block carry.  All row
padding — the ``ops.py`` wrapper's block rounding and the jagged-stream
stacking in ``repro.noc.simulate`` — REPEATS the last flit instead of
appending zeros: the views are sliced from the padded stream, so a zero row
would fabricate a last-flit -> 0 boundary, while a repeated flit XORs with
its copy and flips nothing.  The per-link totals therefore stay exact.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .psu import _popcount_bits

__all__ = ["bt_links_pallas"]


def _bt_links_kernel(a_ref, b_ref, out_ref, *, width: int, input_lanes: int):
    a = a_ref[...].astype(jnp.int32)  # (BL, BR, lanes)
    b = b_ref[...].astype(jnp.int32)
    flips = _popcount_bits(jnp.bitwise_xor(a, b), width)
    lanes = a.shape[-1]
    out_ref[:, 0, 0] = flips[..., :input_lanes].sum(axis=(1, 2))
    if input_lanes < lanes:
        out_ref[:, 0, 1] = flips[..., input_lanes:].sum(axis=(1, 2))
    else:
        out_ref[:, 0, 1] = jnp.zeros_like(out_ref[:, 0, 1])


def bt_links_pallas(
    streams: jax.Array,
    *,
    input_lanes: int,
    width: int = 8,
    block_links: int = 8,
    block_rows: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Per-link (input-side, weight-side) BT of a (L, T, lanes) stream batch.

    Args:
      streams: (L, T, lanes) integer array; row t of link l is flit t on
        that link.  L must be a multiple of ``block_links`` and T - 1 of
        ``block_rows`` (the ``ops.py`` wrapper rounds up: rows by repeating
        each link's last flit, links with all-zero streams — both
        BT-neutral).
      input_lanes: byte lanes [0, input_lanes) are the input side, the rest
        the weight side (DESIGN.md §1).
      width: bits per element (8 for byte lanes).
      block_links / block_rows: grid block shape.
      interpret: run the kernel body in Python (CPU validation mode).

    Returns:
      int32 (L, R_blocks, 2) per-block partials; sum over axis 1 for the
      per-link (input, weight) totals.
    """
    links, t, lanes = streams.shape
    if t < 2:
        return jnp.zeros((links, 1, 2), jnp.int32)
    a = streams[:, :-1].astype(jnp.int32)
    b = streams[:, 1:].astype(jnp.int32)
    rows = t - 1
    if links % block_links != 0:
        raise ValueError(f"L={links} not a multiple of block_links={block_links}")
    if rows % block_rows != 0:
        raise ValueError(f"T-1={rows} not a multiple of block_rows={block_rows}")
    grid = (links // block_links, rows // block_rows)
    kern = functools.partial(
        _bt_links_kernel, width=width, input_lanes=input_lanes
    )
    spec = pl.BlockSpec((block_links, block_rows, lanes), lambda i, j: (i, j, 0))
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[spec, spec],
        out_specs=pl.BlockSpec((block_links, 1, 2), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct(
            (links, rows // block_rows, 2), jnp.int32
        ),
        interpret=interpret,
    )(a, b)
