"""Backend dispatch for the kernel entry points (DESIGN.md §13).

Every BT entry point in ``ops.py`` executes on one of three backends:

  * ``"pallas"``    — the real compiled Pallas kernel.  Only meaningful on
    TPU; on CPU/GPU lowering the TPU kernel fails, which is exactly the
    accident this module exists to prevent.
  * ``"compiled"``  — a jit-compiled pure-``jnp`` implementation that runs
    the SAME block math as the kernel (``axes.py`` factors the body into a
    backend-shared function), vectorized over the link axis and scanned
    over packet blocks.  Bit-exact with the kernel by construction; the
    production path on CPU/GPU.
  * ``"interpret"`` — the Pallas interpreter (kernel body executed step by
    step off-TPU).  Kept ONLY as an explicit validation switch; the entry
    points run it eagerly (un-jitted) so per-op execution and debug prints
    stay observable, which makes it orders of magnitude slower than
    ``"compiled"`` — every wall-clock number it produces is a measurement
    of the interpreter, not the code.

Resolution order for every entry point:

  1. an explicit ``backend=`` keyword,
  2. the legacy ``interpret=`` bool (True -> "interpret", False -> "pallas"),
  3. a :func:`force_default_backend` context (``pallas_launch_count`` pins
     "interpret" while tracing so launch counts stay the cross-backend
     invariant),
  4. the ``REPRO_KERNEL_BACKEND`` environment variable,
  5. platform default: "pallas" on TPU, "compiled" everywhere else.
"""

from __future__ import annotations

import contextlib
import os

import jax

__all__ = [
    "BACKENDS",
    "BACKEND_ENV_VAR",
    "default_backend",
    "resolve_backend",
    "force_default_backend",
]

BACKENDS = ("pallas", "compiled", "interpret")

BACKEND_ENV_VAR = "REPRO_KERNEL_BACKEND"

_FORCED: list[str] = []  # innermost force_default_backend context, if any


def _check(name: str, source: str) -> str:
    if name not in BACKENDS:
        raise ValueError(
            f"unknown kernel backend {name!r} (from {source}); "
            f"choose from {BACKENDS}"
        )
    return name


def default_backend() -> str:
    """The backend an entry point uses when none is requested.

    A :func:`force_default_backend` context wins, then the
    ``REPRO_KERNEL_BACKEND`` environment variable, then the platform
    default ("pallas" on TPU, "compiled" on CPU/GPU).  Read at call time,
    so tests and harnesses can flip the environment per call.
    """
    if _FORCED:
        return _FORCED[-1]
    env = os.environ.get(BACKEND_ENV_VAR, "")
    if env:
        return _check(env, f"${BACKEND_ENV_VAR}")
    return "pallas" if jax.default_backend() == "tpu" else "compiled"


def resolve_backend(backend: str | None, interpret: bool | None) -> str:
    """One resolution rule for every entry point's (backend, interpret) pair.

    ``backend`` wins when given; otherwise the legacy ``interpret`` bool
    maps onto the pallas path (True -> the interpreter, False -> the real
    kernel); otherwise :func:`default_backend`.
    """
    if backend is not None:
        return _check(backend, "backend=")
    if interpret is not None:
        return "interpret" if interpret else "pallas"
    return default_backend()


@contextlib.contextmanager
def force_default_backend(name: str):
    """Pin the *default* backend inside the context (explicit ``backend=``
    / ``interpret=`` arguments still win).

    ``pallas_launch_count`` traces under ``force_default_backend
    ("interpret")`` so the 1-launch claims keep measuring the pallas path
    even where the session default is "compiled".
    """
    _FORCED.append(_check(name, "force_default_backend"))
    try:
        yield
    finally:
        _FORCED.pop()
