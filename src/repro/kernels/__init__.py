# The paper's compute hot-spot IS a sorting circuit, so the kernels here are
# the paper's contribution itself, TPU-native (DESIGN.md §3):
#   psu.py      - popcount-sorting unit (ACC/APP), the Fig. 1 dataflow
#   axes.py     - the ONE multi-axis BT measurement core (DESIGN.md §12):
#                 link axis on the grid, variant (ordering) and codec axes
#                 static inside the launch, one in-kernel masking convention.
#                 The four old entry points — the fused TX pipeline
#                 (psu_stream), the per-link NoC batch (bt_count_links), the
#                 design-grid batch (bt_count_variants) and the codec x
#                 ordering batch (bt_count_codecs) — are thin configurations
#                 of this kernel.
#   btcount.py  - bit-transition counting over one flit stream (the metric)
#   quantize.py - int8 egress quantizer for the compressed all-reduce path
# backend.py holds the three-way backend dispatch (pallas | compiled |
# interpret, DESIGN.md §13), ops.py the public wrappers (padding,
# inter-block fold, chunked streaming, link-axis sharding), ref.py the
# pure-jnp oracles.
from .ops import (
    BACKEND_ENV_VAR,
    BACKENDS,
    AxesActivity,
    CodecVariant,
    LinkActivity,
    PsuStreamResult,
    Variant,
    bt_count,
    bt_count_axes,
    bt_count_axes_sharded,
    bt_count_codecs,
    bt_count_links,
    bt_count_variants,
    default_backend,
    default_interpret,
    force_default_backend,
    pallas_launch_count,
    psu_reorder,
    psu_sort,
    psu_stream,
    quantize_egress,
    resolve_backend,
)

__all__ = [
    "psu_sort",
    "psu_reorder",
    "psu_stream",
    "PsuStreamResult",
    "AxesActivity",
    "LinkActivity",
    "bt_count",
    "bt_count_axes",
    "bt_count_axes_sharded",
    "bt_count_links",
    "bt_count_variants",
    "bt_count_codecs",
    "Variant",
    "CodecVariant",
    "quantize_egress",
    "default_interpret",
    "default_backend",
    "resolve_backend",
    "force_default_backend",
    "BACKENDS",
    "BACKEND_ENV_VAR",
    "pallas_launch_count",
]
