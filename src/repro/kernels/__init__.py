# The paper's compute hot-spot IS a sorting circuit, so the kernels here are
# the paper's contribution itself, TPU-native (DESIGN.md §3):
#   psu.py        - popcount-sorting unit (ACC/APP), the Fig. 1 dataflow
#   psu_stream.py - fused TX pipeline: sort -> reorder -> pack -> BT count
#                   in one launch (the repro.link hot path, DESIGN.md §3.2)
#   btcount.py    - bit-transition counting over flit streams (the metric)
#   bt_links.py   - batched per-link BT over a whole NoC's streams in one
#                   launch (the repro.noc hot path, DESIGN.md §9)
#   bt_variants.py- multi-variant ordered BT: a whole design grid's stream
#                   measurements in one launch (the repro.dse hot path,
#                   DESIGN.md §10)
#   bt_codecs.py  - multi-codec x multi-ordering coded BT: the whole
#                   ordering-vs-coding comparison grid in one launch (the
#                   repro.codec hot path, DESIGN.md §11)
#   quantize.py   - int8 egress quantizer for the compressed all-reduce path
# ops.py holds the jit'd wrappers, ref.py the pure-jnp oracles.
from .ops import (
    CodecVariant,
    PsuStreamResult,
    Variant,
    bt_count,
    bt_count_codecs,
    bt_count_links,
    bt_count_variants,
    default_interpret,
    psu_reorder,
    psu_sort,
    psu_stream,
    quantize_egress,
)

__all__ = [
    "psu_sort",
    "psu_reorder",
    "psu_stream",
    "PsuStreamResult",
    "bt_count",
    "bt_count_links",
    "bt_count_variants",
    "bt_count_codecs",
    "Variant",
    "CodecVariant",
    "quantize_egress",
    "default_interpret",
]
