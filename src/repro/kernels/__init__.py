# The paper's compute hot-spot IS a sorting circuit, so the kernels here are
# the paper's contribution itself, TPU-native (DESIGN.md §3):
#   psu.py      - popcount-sorting unit (ACC/APP), the Fig. 1 dataflow
#   btcount.py  - bit-transition counting over flit streams (the metric)
#   quantize.py - int8 egress quantizer for the compressed all-reduce path
# ops.py holds the jit'd wrappers, ref.py the pure-jnp oracles.
from .ops import bt_count, default_interpret, psu_reorder, psu_sort, quantize_egress

__all__ = [
    "psu_sort",
    "psu_reorder",
    "bt_count",
    "quantize_egress",
    "default_interpret",
]
