"""Jit'd public wrappers around the Pallas kernels.

These handle padding/trimming, static-arg plumbing and the CPU-validation
(interpret) switch.  ``interpret`` defaults to True when no TPU is present so
the whole framework runs (slowly but correctly) on CPU; on TPU the compiled
kernels are used.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .btcount import bt_count_pallas
from .psu import psu_sort_pallas
from .quantize import quantize_egress_pallas

__all__ = ["psu_sort", "psu_reorder", "bt_count", "quantize_egress", "default_interpret"]


def default_interpret() -> bool:
    """Interpret kernels unless running on real TPU hardware."""
    return jax.default_backend() != "tpu"


@partial(
    jax.jit,
    static_argnames=("width", "k", "descending", "block_packets", "interpret"),
)
def psu_sort(
    packets: jax.Array,
    width: int = 8,
    k: int | None = None,
    descending: bool = False,
    block_packets: int = 64,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """(order, rank) of each packet by (approximate) popcount.

    Accepts any (P, N) integer array; P is padded to the kernel block size
    and trimmed on return.
    """
    if interpret is None:
        interpret = default_interpret()
    p, n = packets.shape
    bp = min(block_packets, max(1, p))
    pad = (-p) % bp
    x = jnp.pad(packets.astype(jnp.int32), ((0, pad), (0, 0)))
    order, rank = psu_sort_pallas(
        x,
        width=width,
        k=k,
        descending=descending,
        block_packets=bp,
        interpret=interpret,
    )
    return order[:p], rank[:p]


def psu_reorder(
    packets: jax.Array,
    width: int = 8,
    k: int | None = None,
    descending: bool = False,
    interpret: bool | None = None,
) -> jax.Array:
    """Packets with elements transmitted in PSU order (gather by ``order``)."""
    order, _ = psu_sort(
        packets, width=width, k=k, descending=descending, interpret=interpret
    )
    return jnp.take_along_axis(packets, order, axis=-1)


@partial(jax.jit, static_argnames=("width", "block_rows", "interpret"))
def bt_count(
    stream: jax.Array,
    width: int = 8,
    block_rows: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Total bit transitions of a (T, L) flit stream."""
    if interpret is None:
        interpret = default_interpret()
    return bt_count_pallas(
        stream, width=width, block_rows=block_rows, interpret=interpret
    )


@partial(jax.jit, static_argnames=("block", "interpret"))
def quantize_egress(
    x: jax.Array, block: int = 256, interpret: bool | None = None
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Blockwise int8 quantization of a flat vector (pads internally).

    Returns (q, scales, padded_size) where q/scales cover the padded vector;
    callers keep ``padded_size`` to dequantize and trim.
    """
    if interpret is None:
        interpret = default_interpret()
    m = x.shape[0]
    pad = (-m) % block
    xp = jnp.pad(x.astype(jnp.float32), (0, pad))
    q, s = quantize_egress_pallas(xp, block=block, interpret=interpret)
    return q, s, jnp.int32(m + pad)
