"""Jit'd public wrappers around the Pallas kernels.

These handle padding/trimming, static-arg plumbing and the CPU-validation
(interpret) switch.  ``interpret`` defaults to True when no TPU is present so
the whole framework runs (slowly but correctly) on CPU; on TPU the compiled
kernels are used.

All four BT entry points — ``psu_stream`` (fused TX pipeline),
``bt_count_links`` (per-link NoC batch), ``bt_count_variants`` (design-grid
batch) and ``bt_count_codecs`` (codec x ordering batch) — are thin
configurations of the ONE multi-axis kernel (``axes.py``, DESIGN.md §12):
link axis on the grid, variant x codec axes static inside the launch, one
in-kernel masking convention for padded rows, and one shared inter-block
fold (:func:`_fold_axes`) for the O(G) boundary carry.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.coding import bus_invert_partitions as _partitions

from .axes import (
    CodecVariant,
    Variant,
    bt_axes_pallas,
    validate_variants,
)
from .btcount import bt_count_pallas
from .psu import _popcount_bits, psu_sort_pallas
from .quantize import quantize_egress_pallas

__all__ = [
    "psu_sort",
    "psu_reorder",
    "psu_stream",
    "PsuStreamResult",
    "bt_count",
    "bt_count_axes",
    "bt_count_links",
    "bt_count_variants",
    "bt_count_codecs",
    "Variant",
    "CodecVariant",
    "quantize_egress",
    "default_interpret",
    "pallas_launch_count",
]


def default_interpret() -> bool:
    """Interpret kernels unless running on real TPU hardware."""
    return jax.default_backend() != "tpu"


def pallas_launch_count(fn, *args) -> int:
    """Number of ``pallas_call`` equations in the traced jaxpr of ``fn``
    (recursing through pjit/scan/etc. sub-jaxprs) — the measurement behind
    every 1-launch claim in this repo (benchmarks and tests alike)."""
    try:  # jaxpr types' public home since jax 0.4.33
        from jax.extend import core as jcore
    except ImportError:  # older releases
        from jax import core as jcore

    def walk(jaxpr) -> int:
        n = 0
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == "pallas_call":
                n += 1
            for v in eqn.params.values():
                for sub in _subjaxprs(v):
                    n += walk(sub)
        return n

    def _subjaxprs(v):
        if isinstance(v, jcore.ClosedJaxpr):
            yield v.jaxpr
        elif isinstance(v, jcore.Jaxpr):
            yield v
        elif isinstance(v, (tuple, list)):
            for item in v:
                yield from _subjaxprs(item)

    return walk(jax.make_jaxpr(fn)(*args).jaxpr)


@partial(
    jax.jit,
    static_argnames=("width", "k", "descending", "block_packets", "interpret"),
)
def psu_sort(
    packets: jax.Array,
    width: int = 8,
    k: int | None = None,
    descending: bool = False,
    block_packets: int = 64,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """(order, rank) of each packet by (approximate) popcount.

    Accepts any (P, N) integer array; P is padded to the kernel block size
    and trimmed on return.
    """
    if interpret is None:
        interpret = default_interpret()
    p, n = packets.shape
    bp = min(block_packets, max(1, p))
    pad = (-p) % bp
    x = jnp.pad(packets.astype(jnp.int32), ((0, pad), (0, 0)))
    order, rank = psu_sort_pallas(
        x,
        width=width,
        k=k,
        descending=descending,
        block_packets=bp,
        interpret=interpret,
    )
    return order[:p], rank[:p]


def psu_reorder(
    packets: jax.Array,
    width: int = 8,
    k: int | None = None,
    descending: bool = False,
    interpret: bool | None = None,
) -> jax.Array:
    """Packets with elements transmitted in PSU order (gather by ``order``)."""
    order, _ = psu_sort(
        packets, width=width, k=k, descending=descending, interpret=interpret
    )
    return jnp.take_along_axis(packets, order, axis=-1)


# --------------------------------------------------------------------------
# the shared inter-block fold of the multi-axis kernel (DESIGN.md §12)


def _fold_axes(
    partials: jax.Array,  # (L, G, C, 2, PMAX, 3)
    edges: jax.Array,  # (L, G, C, 2, 2, lanes)
    inv_edges: jax.Array,  # (L, G, C, 2, 2, PMAX)
    configs: tuple[CodecVariant, ...],
    valid_rows: jax.Array,  # (L,) real flit rows per link
    rows: int,  # flit rows per block
    split_lanes: int,
) -> jax.Array:
    """Fold per-(link, block) kernel partials into (L, C, 3) totals.

    Block-internal boundaries are already masked in-kernel; this patches
    the G-1 inter-block boundaries per link in O(G) jnp — stateless codecs
    XOR adjacent edge flits, transition signaling adds each block's
    first-flit popcount, and bus-invert carries each block's entry branch
    from the previous block's last wire flit (``lax.scan``).  Boundaries
    into fully-padded blocks are masked by each link's ``valid_rows``.
    """
    nl, gblocks = partials.shape[:2]
    lanes = edges.shape[-1]
    if gblocks > 1:
        # boundary (g-1 -> g) is real iff block g has any valid row
        bnd_mask = (
            jnp.arange(1, gblocks, dtype=jnp.int32)[None, :] * rows
            < valid_rows[:, None]
        ).astype(jnp.int32)  # (L, G-1)

    def _sides(flips):  # (..., lanes) -> (..., 2) per-side sums
        in_side = flips[..., :split_lanes].sum(-1)
        w_side = (
            flips[..., split_lanes:].sum(-1)
            if split_lanes < lanes
            else jnp.zeros_like(in_side)
        )
        return jnp.stack([in_side, w_side], axis=-1)

    totals = []
    for ci, cfg in enumerate(configs):
        if cfg.codec == "bus_invert":
            npart, pw = _partitions(lanes, cfg.partition)
            lbits = 8 * pw
            in_mask = (
                jnp.arange(lanes, dtype=jnp.int32) < split_lanes
            ).astype(jnp.int32).reshape(npart, pw)
            # block 0 enters uninverted: branch 0
            total = partials[:, 0, ci, 0, :npart]  # (L, npart, 3)
            if gblocks > 1:

                def fold(carry, blk):
                    carry_wire, carry_inv = carry  # (L, npart, pw), (L, npart)
                    part_g, edge_g, inv_g, m = blk
                    # branch-0 first wire IS the block's first data flit
                    d_first = edge_g[:, 0, 0].reshape(nl, npart, pw)
                    hd = _popcount_bits(d_first ^ carry_wire, 8).sum(-1)
                    b = (2 * hd > lbits).astype(jnp.int32)  # (L, npart)
                    first_wire = d_first ^ (b[..., None] * 0xFF)
                    flips = _popcount_bits(carry_wire ^ first_wire, 8)
                    bnd = jnp.stack(
                        [
                            (flips * in_mask).sum(-1),
                            (flips * (1 - in_mask)).sum(-1),
                            (carry_inv != b).astype(jnp.int32),
                        ],
                        axis=-1,
                    )  # (L, npart, 3): the inter-block boundary itself
                    sel = jnp.where(b[..., None] == 1, part_g[:, 1], part_g[:, 0])
                    ew = edge_g[:, :, 1].reshape(nl, 2, npart, pw)
                    new_wire = jnp.where(b[..., None] == 1, ew[:, 1], ew[:, 0])
                    iv = inv_g[:, :, 1]  # (L, 2, npart)
                    new_inv = jnp.where(b == 1, iv[:, 1], iv[:, 0])
                    # links whose valid rows end before this block keep
                    # their carry and contribute nothing
                    m3 = m[:, None, None]
                    new_wire = jnp.where(m3 == 1, new_wire, carry_wire)
                    new_inv = jnp.where(m[:, None] == 1, new_inv, carry_inv)
                    return (new_wire, new_inv), (bnd + sel) * m3

                carry0 = (
                    edges[:, 0, ci, 0, 1].reshape(nl, npart, pw),
                    inv_edges[:, 0, ci, 0, 1, :npart],
                )
                _, contribs = lax.scan(
                    fold,
                    carry0,
                    (
                        jnp.moveaxis(partials[:, 1:, ci, :, :npart], 1, 0),
                        jnp.moveaxis(edges[:, 1:, ci], 1, 0),
                        jnp.moveaxis(inv_edges[:, 1:, ci, :, :, :npart], 1, 0),
                        jnp.moveaxis(bnd_mask, 1, 0),
                    ),
                )
                total = total + contribs.sum(axis=0)
            totals.append(total.sum(axis=1))  # (L, 3)
        else:
            # branch 0 carries every stateless codec; padded slots are zero
            total = partials[:, :, ci, 0].sum(axis=(1, 2))  # (L, 3)
            if gblocks > 1:
                if cfg.codec == "transition":
                    # boundary flips = the next block's first DATA flit bits
                    flips = _popcount_bits(edges[:, 1:, ci, 0, 0, :], 8)
                else:
                    flips = _popcount_bits(
                        jnp.bitwise_xor(
                            edges[:, :-1, ci, 0, 1, :], edges[:, 1:, ci, 0, 0, :]
                        ),
                        8,
                    )
                bnd = (_sides(flips) * bnd_mask[..., None]).sum(axis=1)  # (L, 2)
                total = total + jnp.concatenate(
                    [bnd, jnp.zeros((nl, 1), jnp.int32)], axis=-1
                )
            totals.append(total)
    return jnp.stack(totals, axis=1).astype(jnp.int32)  # (L, C, 3)


def _paired(inputs, weights, weight_lanes, input_lanes):
    """Shared (weights, weight_lanes) defaulting of the packet wrappers."""
    if weights is None:
        weight_lanes = 0 if weight_lanes is None else weight_lanes
        weights = jnp.zeros_like(inputs)
    elif weight_lanes is None:
        weight_lanes = input_lanes
    if weights.shape != inputs.shape:
        raise ValueError(f"paired shapes differ: {inputs.shape} vs {weights.shape}")
    return weights, weight_lanes


class PsuStreamResult(NamedTuple):
    """Everything the fused TX pipeline produces in one kernel launch."""

    order: jax.Array  # (P, N) int32: input index transmitted j-th
    rank: jax.Array  # (P, N) int32: output slot of input element i
    stream: jax.Array  # (P*F, lanes) uint8 packed flit rows
    bt_input: jax.Array  # int32 scalar: input-side bit transitions
    bt_weight: jax.Array  # int32 scalar: weight-side bit transitions


@partial(
    jax.jit,
    static_argnames=(
        "width",
        "k",
        "descending",
        "input_lanes",
        "weight_lanes",
        "pack",
        "block_packets",
        "interpret",
    ),
)
def psu_stream(
    inputs: jax.Array,
    weights: jax.Array | None = None,
    width: int = 8,
    k: int | None = None,
    descending: bool = False,
    input_lanes: int = 8,
    weight_lanes: int | None = None,
    pack: str = "lane",
    block_packets: int = 64,
    interpret: bool | None = None,
) -> PsuStreamResult:
    """Fused popcount-sort -> reorder -> flit-pack -> BT-count, one launch.

    The multi-axis kernel in ``emit_stream`` mode: one link, one uncoded
    'acc'/'app' config, with the permutation-matrix contraction also
    yielding ``order``/``rank`` and the packed wire stream.  Accepts any
    (P, N) integer packets; P is padded to the kernel block size and the
    padded tail is masked in-kernel (the unified convention) — the wrapper
    only folds the G-1 inter-block flit boundaries.
    """
    if interpret is None:
        interpret = default_interpret()
    weights, weight_lanes = _paired(inputs, weights, weight_lanes, input_lanes)
    p, n = inputs.shape
    flits = n // input_lanes
    bp = min(block_packets, max(1, p))
    pad = (-p) % bp
    x = jnp.pad(inputs.astype(jnp.int32), ((0, pad), (0, 0)))
    w = jnp.pad(weights.astype(jnp.int32), ((0, pad), (0, 0)))
    cfg = CodecVariant("acc" if k is None else "app", k, descending)
    valid = jnp.full((1,), p, jnp.int32)
    partials, edges, inv_edges, order, rank, stream = bt_axes_pallas(
        x[None],
        w[None],
        valid,
        configs=(cfg,),
        width=width,
        input_lanes=input_lanes,
        weight_lanes=weight_lanes,
        pack=pack,
        block_packets=bp,
        emit_stream=True,
        interpret=interpret,
    )
    bt = _fold_axes(
        partials, edges, inv_edges, (cfg,), valid * flits, bp * flits,
        input_lanes,
    )[0, 0]
    return PsuStreamResult(
        order[0, :p],
        rank[0, :p],
        stream[0, : p * flits].astype(jnp.uint8),
        bt[0],
        bt[1],
    )


@partial(jax.jit, static_argnames=("width", "block_rows", "interpret"))
def bt_count(
    stream: jax.Array,
    width: int = 8,
    block_rows: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Total bit transitions of a (T, L) flit stream."""
    if interpret is None:
        interpret = default_interpret()
    return bt_count_pallas(
        stream, width=width, block_rows=block_rows, interpret=interpret
    )


@partial(
    jax.jit,
    static_argnames=(
        "configs",
        "width",
        "input_lanes",
        "weight_lanes",
        "split_lanes",
        "pack",
        "block_packets",
        "interpret",
    ),
)
def bt_count_axes(
    inputs: jax.Array,
    weights: jax.Array | None = None,
    valid: jax.Array | Sequence[int] | None = None,
    configs: tuple[CodecVariant, ...] = (CodecVariant(),),
    width: int = 8,
    input_lanes: int = 8,
    weight_lanes: int | None = None,
    split_lanes: int | None = None,
    pack: str = "lane",
    block_packets: int = 64,
    interpret: bool | None = None,
) -> jax.Array:
    """The full multi-axis measurement: per-LINK, per-(ordering, codec)
    config BT of a (L, P, N) packet batch in ONE kernel launch.

    This is the grid the whole stack reduces to (DESIGN.md §12): NoC links,
    DSE variants and wire codecs are orthogonal axes of one launch.  Links
    may be jagged — ``valid`` gives each link's real packet count and
    everything past it contributes zero data BT and zero aux BT (so a
    bus-invert decision is never evaluated on a padded flit).

    Args:
      inputs: (L, P, N) integer packets (P = the longest link, zero-padded).
      weights: optional (L, P, N) paired weight bytes.
      valid: (L,) real packet counts (default: all P real).
      configs: static tuple of :class:`CodecVariant` configurations.
      split_lanes: lane where the input side ends for per-side accounting
        (default ``input_lanes``; the NoC path feeds pre-assembled flit
        rows as N = lanes packets and splits at the spec's input_lanes).

    Returns:
      int32 (L, C, 3): per-link, per-config (input-side BT, weight-side
      BT, invert-line BT) totals.
    """
    if interpret is None:
        interpret = default_interpret()
    if inputs.ndim != 3:
        raise ValueError(f"expected (L, P, N) packets, got {inputs.shape}")
    weights, weight_lanes = _paired(inputs, weights, weight_lanes, input_lanes)
    links, p, n = inputs.shape
    flits = n // input_lanes
    nc = len(configs)
    if links == 0 or p == 0:
        return jnp.zeros((links, nc, 3), jnp.int32)
    if valid is None:
        valid = jnp.full((links,), p, jnp.int32)
    else:
        # clamp to the packets actually present: a valid count past P would
        # silently count the last-real -> zero-pad boundary as real
        valid = jnp.minimum(jnp.asarray(valid, jnp.int32), p)
    bp = min(block_packets, max(1, p))
    pad = (-p) % bp
    x = jnp.pad(inputs.astype(jnp.int32), ((0, 0), (0, pad), (0, 0)))
    w = jnp.pad(weights.astype(jnp.int32), ((0, 0), (0, pad), (0, 0)))
    partials, edges, inv_edges = bt_axes_pallas(
        x,
        w,
        valid,
        configs=tuple(configs),
        width=width,
        input_lanes=input_lanes,
        weight_lanes=weight_lanes,
        split_lanes=split_lanes,
        pack=pack,
        block_packets=bp,
        interpret=interpret,
    )
    return _fold_axes(
        partials,
        edges,
        inv_edges,
        tuple(configs),
        valid * flits,
        bp * flits,
        input_lanes if split_lanes is None else split_lanes,
    )


@partial(
    jax.jit,
    static_argnames=("input_lanes", "width", "block_links", "block_rows", "interpret"),
)
def bt_count_links(
    streams: jax.Array,
    input_lanes: int | None = None,
    lengths: jax.Array | Sequence[int] | None = None,
    width: int = 8,
    block_links: int = 8,
    block_rows: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Per-link BT of a (L, T, lanes) stream batch in ONE kernel launch.

    The batched replacement for looping ``bt_count`` over the links of a
    NoC: each pre-assembled flit row is one N = lanes "packet" of the
    multi-axis kernel with the identity ordering, so the link axis rides
    the kernel grid.  Jagged links pass their real flit counts via
    ``lengths`` and the kernel masks everything past them (the unified
    convention) — any padding value is neutral, including the
    repeated-last-flit rows ``repro.noc.simulate.stack_link_streams``
    emits (which are also zero-BT on their own).

    Args:
      streams: (L, T, lanes) integer flit streams, one per link.
      input_lanes: lanes carrying input bytes (rest = weight side);
        default all lanes.
      lengths: (L,) real flit counts for jagged links (default: all T).
      width: element bit width of the lanes (byte lanes: 8).
      block_links: unused (one grid row per link); kept for call
        compatibility with the pre-unification kernel.
      block_rows: flit rows per grid step.

    Returns:
      int32 (L, 2): per-link (input-side, weight-side) bit transitions.
    """
    del block_links  # the link axis is unblocked on the unified grid
    if interpret is None:
        interpret = default_interpret()
    links, t, lanes = streams.shape
    if input_lanes is None:
        input_lanes = lanes
    if not 0 <= input_lanes <= lanes:
        raise ValueError(
            f"input_lanes={input_lanes} outside the {lanes}-lane flit"
        )
    if links == 0 or t < 2:
        return jnp.zeros((links, 2), jnp.int32)
    valid = (
        jnp.full((links,), t, jnp.int32)
        if lengths is None
        else jnp.minimum(jnp.asarray(lengths, jnp.int32), t)
    )
    bp = min(block_rows, max(1, t))
    pad = (-t) % bp
    x = jnp.pad(streams.astype(jnp.int32), ((0, 0), (0, pad), (0, 0)))
    cfg = (CodecVariant("none"),)
    partials, edges, inv_edges = bt_axes_pallas(
        x,
        jnp.zeros_like(x),
        valid,
        configs=cfg,
        width=width,
        input_lanes=lanes,
        weight_lanes=0,
        split_lanes=input_lanes,
        pack="row",
        block_packets=bp,
        interpret=interpret,
    )
    bt = _fold_axes(partials, edges, inv_edges, cfg, valid, bp, input_lanes)
    return bt[:, 0, :2]


@partial(
    jax.jit,
    static_argnames=(
        "variants",
        "width",
        "input_lanes",
        "weight_lanes",
        "pack",
        "block_packets",
        "interpret",
    ),
)
def bt_count_variants(
    inputs: jax.Array,
    weights: jax.Array | None = None,
    variants: tuple[Variant, ...] = (Variant("acc"),),
    width: int = 8,
    input_lanes: int = 8,
    weight_lanes: int | None = None,
    pack: str = "lane",
    block_packets: int = 64,
    interpret: bool | None = None,
) -> jax.Array:
    """Ordered BT of (P, N) packets under MANY variants in ONE kernel launch.

    The multi-axis kernel restricted to one link and uncoded configs: the
    variant axis lives inside the single launch (one popcount pass per
    block shared by every bucketing), which is what makes a whole
    ``repro.dse`` grid one launch per measured stream.

    Returns:
      int32 (V, 2): per-variant (input-side, weight-side) bit transitions.
    """
    variants = validate_variants(tuple(variants), width)
    weights, weight_lanes = _paired(inputs, weights, weight_lanes, input_lanes)
    configs = tuple(CodecVariant(v.key, v.k, v.descending) for v in variants)
    out = bt_count_axes(
        inputs[None],
        weights[None],
        None,
        configs=configs,
        width=width,
        input_lanes=input_lanes,
        weight_lanes=weight_lanes,
        pack=pack,
        block_packets=block_packets,
        interpret=interpret,
    )
    return out[0, :, :2]


@partial(
    jax.jit,
    static_argnames=(
        "configs",
        "width",
        "input_lanes",
        "weight_lanes",
        "pack",
        "block_packets",
        "interpret",
    ),
)
def bt_count_codecs(
    inputs: jax.Array,
    weights: jax.Array | None = None,
    configs: tuple[CodecVariant, ...] = (CodecVariant(),),
    width: int = 8,
    input_lanes: int = 8,
    weight_lanes: int | None = None,
    pack: str = "lane",
    block_packets: int = 64,
    interpret: bool | None = None,
) -> jax.Array:
    """Coded + ordered BT of (P, N) packets under MANY (ordering, codec)
    configurations in ONE kernel launch.

    The multi-axis kernel restricted to one link: the whole codec x
    ordering grid lives inside the launch (one popcount pass, one reorder
    per distinct ordering, stateful codecs as vectorized per-block prefix
    scans with the wrapper folding the O(G) inter-block carry).

    Returns:
      int32 (C, 3): per-config (input-side BT, weight-side BT, invert-line
      BT) totals.  The invert-line column is the coding overhead the wire
      still pays switching energy for (zero for codecs without extra
      lines).
    """
    weights, weight_lanes = _paired(inputs, weights, weight_lanes, input_lanes)
    out = bt_count_axes(
        inputs[None],
        weights[None],
        None,
        configs=tuple(configs),
        width=width,
        input_lanes=input_lanes,
        weight_lanes=weight_lanes,
        pack=pack,
        block_packets=block_packets,
        interpret=interpret,
    )
    return out[0]


@partial(jax.jit, static_argnames=("block", "interpret"))
def quantize_egress(
    x: jax.Array, block: int = 256, interpret: bool | None = None
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Blockwise int8 quantization of a flat vector (pads internally).

    Returns (q, scales, padded_size) where q/scales cover the padded vector;
    callers keep ``padded_size`` to dequantize and trim.
    """
    if interpret is None:
        interpret = default_interpret()
    m = x.shape[0]
    pad = (-m) % block
    xp = jnp.pad(x.astype(jnp.float32), (0, pad))
    q, s = quantize_egress_pallas(xp, block=block, interpret=interpret)
    return q, s, jnp.int32(m + pad)
