"""Jit'd public wrappers around the Pallas kernels.

These handle padding/trimming, static-arg plumbing and the CPU-validation
(interpret) switch.  ``interpret`` defaults to True when no TPU is present so
the whole framework runs (slowly but correctly) on CPU; on TPU the compiled
kernels are used.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .bt_codecs import (
    CodecVariant,
    _partitions,
    bt_codecs_pallas,
    validate_codec_variants,
)
from .bt_links import bt_links_pallas
from .bt_variants import Variant, bt_variants_pallas, validate_variants
from .btcount import bt_count_pallas
from .psu import _popcount_bits, psu_sort_pallas
from .psu_stream import psu_stream_pallas
from .quantize import quantize_egress_pallas
from .ref import variant_order_ref

__all__ = [
    "psu_sort",
    "psu_reorder",
    "psu_stream",
    "PsuStreamResult",
    "bt_count",
    "bt_count_links",
    "bt_count_variants",
    "bt_count_codecs",
    "Variant",
    "CodecVariant",
    "quantize_egress",
    "default_interpret",
]


def default_interpret() -> bool:
    """Interpret kernels unless running on real TPU hardware."""
    return jax.default_backend() != "tpu"


@partial(
    jax.jit,
    static_argnames=("width", "k", "descending", "block_packets", "interpret"),
)
def psu_sort(
    packets: jax.Array,
    width: int = 8,
    k: int | None = None,
    descending: bool = False,
    block_packets: int = 64,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """(order, rank) of each packet by (approximate) popcount.

    Accepts any (P, N) integer array; P is padded to the kernel block size
    and trimmed on return.
    """
    if interpret is None:
        interpret = default_interpret()
    p, n = packets.shape
    bp = min(block_packets, max(1, p))
    pad = (-p) % bp
    x = jnp.pad(packets.astype(jnp.int32), ((0, pad), (0, 0)))
    order, rank = psu_sort_pallas(
        x,
        width=width,
        k=k,
        descending=descending,
        block_packets=bp,
        interpret=interpret,
    )
    return order[:p], rank[:p]


def psu_reorder(
    packets: jax.Array,
    width: int = 8,
    k: int | None = None,
    descending: bool = False,
    interpret: bool | None = None,
) -> jax.Array:
    """Packets with elements transmitted in PSU order (gather by ``order``)."""
    order, _ = psu_sort(
        packets, width=width, k=k, descending=descending, interpret=interpret
    )
    return jnp.take_along_axis(packets, order, axis=-1)


class PsuStreamResult(NamedTuple):
    """Everything the fused TX pipeline produces in one kernel launch."""

    order: jax.Array  # (P, N) int32: input index transmitted j-th
    rank: jax.Array  # (P, N) int32: output slot of input element i
    stream: jax.Array  # (P*F, lanes) uint8 packed flit rows
    bt_input: jax.Array  # int32 scalar: input-side bit transitions
    bt_weight: jax.Array  # int32 scalar: weight-side bit transitions


@partial(
    jax.jit,
    static_argnames=(
        "width",
        "k",
        "descending",
        "input_lanes",
        "weight_lanes",
        "pack",
        "block_packets",
        "interpret",
    ),
)
def psu_stream(
    inputs: jax.Array,
    weights: jax.Array | None = None,
    width: int = 8,
    k: int | None = None,
    descending: bool = False,
    input_lanes: int = 8,
    weight_lanes: int | None = None,
    pack: str = "lane",
    block_packets: int = 64,
    interpret: bool | None = None,
) -> PsuStreamResult:
    """Fused popcount-sort -> reorder -> flit-pack -> BT-count, one launch.

    Accepts any (P, N) integer packets; P is padded to the kernel block size
    internally.  The per-block BT partials miss (a) the G-1 inter-block flit
    boundaries and (b) over-count one boundary into the zero-padded tail when
    P is not a block multiple; both are patched here with O(G) jnp arithmetic
    on the packed stream — no extra kernel launch.
    """
    if interpret is None:
        interpret = default_interpret()
    if weights is None:
        weight_lanes = 0 if weight_lanes is None else weight_lanes
        weights = jnp.zeros_like(inputs)
    elif weight_lanes is None:
        weight_lanes = input_lanes
    if weights.shape != inputs.shape:
        raise ValueError(f"paired shapes differ: {inputs.shape} vs {weights.shape}")
    p, n = inputs.shape
    flits = n // input_lanes
    bp = min(block_packets, max(1, p))
    pad = (-p) % bp
    x = jnp.pad(inputs.astype(jnp.int32), ((0, pad), (0, 0)))
    w = jnp.pad(weights.astype(jnp.int32), ((0, pad), (0, 0)))
    order, rank, stream, partials = psu_stream_pallas(
        x,
        w,
        width=width,
        k=k,
        descending=descending,
        input_lanes=input_lanes,
        weight_lanes=weight_lanes,
        pack=pack,
        block_packets=bp,
        interpret=interpret,
    )
    bt = partials.sum(axis=0)  # (2,): block-internal boundaries

    def _halves(flips_row):
        return jnp.stack(
            [flips_row[..., :input_lanes].sum(-1), flips_row[..., input_lanes:].sum(-1)],
            axis=-1,
        )

    grid = (p + pad) // bp
    if grid > 1:
        # inter-block boundaries: last flit of block g-1 -> first of block g
        starts = jnp.arange(1, grid) * (bp * flits)
        flips = _popcount_bits(
            jnp.bitwise_xor(stream[starts - 1], stream[starts]), 8
        )
        bt = bt + _halves(flips).sum(axis=0)
    if pad:
        # remove the spurious boundary from the last real flit into the
        # zero-padded tail (zero flits contribute nothing else)
        flips = _popcount_bits(stream[p * flits - 1], 8)
        bt = bt - _halves(flips)
    return PsuStreamResult(
        order[:p],
        rank[:p],
        stream[: p * flits].astype(jnp.uint8),
        bt[0],
        bt[1],
    )


@partial(jax.jit, static_argnames=("width", "block_rows", "interpret"))
def bt_count(
    stream: jax.Array,
    width: int = 8,
    block_rows: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Total bit transitions of a (T, L) flit stream."""
    if interpret is None:
        interpret = default_interpret()
    return bt_count_pallas(
        stream, width=width, block_rows=block_rows, interpret=interpret
    )


@partial(
    jax.jit,
    static_argnames=("input_lanes", "width", "block_links", "block_rows", "interpret"),
)
def bt_count_links(
    streams: jax.Array,
    input_lanes: int | None = None,
    width: int = 8,
    block_links: int = 8,
    block_rows: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Per-link BT of a (L, T, lanes) stream batch in ONE kernel launch.

    The batched replacement for looping ``bt_count`` over the links of a
    NoC: the link axis goes on the Pallas grid (see ``bt_links.py``).
    Accepts any L and T; both are rounded up to the block shape internally
    — rows by repeating each link's last flit (the kernel slices its two
    shifted views from the padded stream, so zero rows there would
    fabricate a last-flit -> 0 boundary; a repeated flit flips nothing),
    links by appending all-zero streams.  Links whose real streams are
    shorter than T must be padded by the caller the same way, with copies
    of their last flit (``repro.noc.simulate.stack_link_streams`` does).

    Args:
      streams: (L, T, lanes) integer flit streams, one per link.
      input_lanes: lanes carrying input bytes (rest = weight side);
        default all lanes.

    Returns:
      int32 (L, 2): per-link (input-side, weight-side) bit transitions.
    """
    if interpret is None:
        interpret = default_interpret()
    links, t, lanes = streams.shape
    if input_lanes is None:
        input_lanes = lanes
    if not 0 <= input_lanes <= lanes:
        raise ValueError(
            f"input_lanes={input_lanes} outside the {lanes}-lane flit"
        )
    if links == 0 or t < 2:
        return jnp.zeros((links, 2), jnp.int32)
    bl = min(block_links, max(1, links))
    br = min(block_rows, max(1, t - 1))
    pad_l = (-links) % bl
    pad_r = (-(t - 1)) % br
    # row padding repeats each link's last flit (kernel shifts internally, so
    # zero rows would fabricate a last-flit -> 0 boundary); link padding is
    # all-zero streams, which flip nothing
    x = jnp.pad(streams.astype(jnp.int32), ((0, 0), (0, pad_r), (0, 0)), mode="edge")
    x = jnp.pad(x, ((0, pad_l), (0, 0), (0, 0)))
    partials = bt_links_pallas(
        x,
        input_lanes=input_lanes,
        width=width,
        block_links=bl,
        block_rows=br,
        interpret=interpret,
    )
    return partials.sum(axis=1)[:links]


@partial(
    jax.jit,
    static_argnames=(
        "variants",
        "width",
        "input_lanes",
        "weight_lanes",
        "pack",
        "block_packets",
        "interpret",
    ),
)
def bt_count_variants(
    inputs: jax.Array,
    weights: jax.Array | None = None,
    variants: tuple[Variant, ...] = (Variant("acc"),),
    width: int = 8,
    input_lanes: int = 8,
    weight_lanes: int | None = None,
    pack: str = "lane",
    block_packets: int = 64,
    interpret: bool | None = None,
) -> jax.Array:
    """Ordered BT of (P, N) packets under MANY variants in ONE kernel launch.

    The batched replacement for looping one ``psu_stream``/``bt_count``
    launch per design configuration: the variant axis lives inside the
    single launch (``bt_variants.py`` unrolls the static variant tuple per
    block, sharing one popcount pass), which is what makes a whole
    ``repro.dse`` grid one launch per measured stream.

    Accepts any (P, N) integer packets; P is padded to the kernel block
    size with zero packets (zeros sort to zeros under every variant).  The
    per-block partials miss (a) the G-1 inter-block flit boundaries —
    patched from the per-block edge flits the kernel emits — and (b)
    over-count one boundary from the last real flit into the zero-padded
    tail, subtracted per variant from the reference reorder of the last
    real packet (O(V*N) jnp arithmetic; no extra launch).

    Args:
      inputs: (P, N) integer packets.
      weights: optional (P, N) paired weight bytes.
      variants: static tuple of ``Variant(key, k, descending)`` configs.
      width: element bit width W of the sort keys.
      input_lanes / weight_lanes: bytes of each side per flit (weight side
        defaults to ``input_lanes`` when weights are given, else 0).
      pack: 'lane' or 'row' flit layout.

    Returns:
      int32 (V, 2): per-variant (input-side, weight-side) bit transitions.
    """
    if interpret is None:
        interpret = default_interpret()
    variants = validate_variants(tuple(variants), width)
    if weights is None:
        weight_lanes = 0 if weight_lanes is None else weight_lanes
        weights = jnp.zeros_like(inputs)
    elif weight_lanes is None:
        weight_lanes = input_lanes
    if weights.shape != inputs.shape:
        raise ValueError(f"paired shapes differ: {inputs.shape} vs {weights.shape}")
    p, n = inputs.shape
    flits = n // input_lanes
    bp = min(block_packets, max(1, p))
    pad = (-p) % bp
    x = jnp.pad(inputs.astype(jnp.int32), ((0, pad), (0, 0)))
    w = jnp.pad(weights.astype(jnp.int32), ((0, pad), (0, 0)))
    partials, edges = bt_variants_pallas(
        x,
        w,
        variants=variants,
        width=width,
        input_lanes=input_lanes,
        weight_lanes=weight_lanes,
        pack=pack,
        block_packets=bp,
        interpret=interpret,
    )
    bt = partials.sum(axis=0)  # (V, 2): block-internal boundaries

    def _halves(flips):  # (..., lanes) -> (..., 2) per-side sums
        return jnp.stack(
            [flips[..., :input_lanes].sum(-1), flips[..., input_lanes:].sum(-1)],
            axis=-1,
        )

    grid = (p + pad) // bp
    if grid > 1:
        # inter-block boundaries: last flit of block g-1 -> first of block g
        flips = _popcount_bits(
            jnp.bitwise_xor(edges[:-1, :, 1, :], edges[1:, :, 0, :]), 8
        )  # (G-1, V, lanes)
        bt = bt + _halves(flips).sum(axis=0)
    if pad:
        # remove the spurious boundary from the last real flit into the
        # zero-padded tail: reorder the ONE last real packet per variant
        # with the pure-jnp reference and take its final flit
        last_flits = []
        for variant in variants:
            order = variant_order_ref(
                x[p - 1 : p], variant, width=width, input_lanes=input_lanes
            )
            xs = jnp.take_along_axis(x[p - 1 : p], order, axis=-1)
            ws = jnp.take_along_axis(w[p - 1 : p], order, axis=-1)
            if pack == "lane":
                fi = xs.reshape(input_lanes, flits).T
                fw = ws.reshape(weight_lanes, flits).T if weight_lanes else None
            else:
                fi = xs.reshape(flits, input_lanes)
                fw = ws.reshape(flits, weight_lanes) if weight_lanes else None
            row = fi[-1] if fw is None else jnp.concatenate([fi[-1], fw[-1]])
            last_flits.append(row)
        flips = _popcount_bits(jnp.stack(last_flits), 8)  # (V, lanes)
        bt = bt - _halves(flips)
    return bt


@partial(
    jax.jit,
    static_argnames=(
        "configs",
        "width",
        "input_lanes",
        "weight_lanes",
        "pack",
        "block_packets",
        "interpret",
    ),
)
def bt_count_codecs(
    inputs: jax.Array,
    weights: jax.Array | None = None,
    configs: tuple[CodecVariant, ...] = (CodecVariant(),),
    width: int = 8,
    input_lanes: int = 8,
    weight_lanes: int | None = None,
    pack: str = "lane",
    block_packets: int = 64,
    interpret: bool | None = None,
) -> jax.Array:
    """Coded + ordered BT of (P, N) packets under MANY (ordering, codec)
    configurations in ONE kernel launch.

    The batched replacement for one ``psu_stream`` launch + a jnp codec +
    ``bt_count`` launch per configuration: the whole codec x ordering grid
    lives inside the single launch (``bt_codecs.py`` shares one popcount
    pass and one reorder per distinct ordering; stateful codecs run as
    vectorized per-block prefix scans).  This is what makes the
    ``repro.codec.compare`` tables and the ``repro.dse`` codec axis one
    launch per measured stream (``benchmarks/codec_bt.py``).

    Accepts any (P, N) integer packets; P is padded to the kernel block
    size with zero packets, which the kernel masks out internally (no
    wrapper-side tail subtraction).  The G-1 inter-block boundaries are
    patched here per codec from the per-block edge states the kernel
    emits: byte-map codecs XOR adjacent edge flits, transition signaling
    adds each block's first-flit popcount, and bus-invert folds an O(G)
    carry — each block's entry branch is chosen from the previous block's
    last wire flit (``lax.scan``, no extra kernel launch).

    Args:
      inputs: (P, N) integer packets.
      weights: optional (P, N) paired weight bytes.
      configs: static tuple of ``CodecVariant`` configurations.
      width: element bit width W of the sort keys.
      input_lanes / weight_lanes: bytes of each side per flit (weight side
        defaults to ``input_lanes`` when weights are given, else 0).
      pack: 'lane' or 'row' flit layout.

    Returns:
      int32 (C, 3): per-config (input-side BT, weight-side BT, invert-line
      BT) totals.  The invert-line column is the coding overhead the wire
      still pays switching energy for (zero for codecs without extra
      lines).
    """
    if interpret is None:
        interpret = default_interpret()
    if weights is None:
        weight_lanes = 0 if weight_lanes is None else weight_lanes
        weights = jnp.zeros_like(inputs)
    elif weight_lanes is None:
        weight_lanes = input_lanes
    if weights.shape != inputs.shape:
        raise ValueError(f"paired shapes differ: {inputs.shape} vs {weights.shape}")
    p, n = inputs.shape
    lanes = input_lanes + weight_lanes
    configs = validate_codec_variants(tuple(configs), width, lanes)
    bp = min(block_packets, max(1, p))
    pad = (-p) % bp
    x = jnp.pad(inputs.astype(jnp.int32), ((0, pad), (0, 0)))
    w = jnp.pad(weights.astype(jnp.int32), ((0, pad), (0, 0)))
    partials, edges, inv_edges = bt_codecs_pallas(
        x,
        w,
        configs=configs,
        width=width,
        input_lanes=input_lanes,
        weight_lanes=weight_lanes,
        pack=pack,
        block_packets=bp,
        real_packets=p,
        interpret=interpret,
    )
    grid = (p + pad) // bp

    def _sides(flips):  # (..., lanes) -> (..., 2) per-side sums
        wside = (
            flips[..., input_lanes:].sum(-1)
            if weight_lanes
            else jnp.zeros_like(flips[..., 0])
        )
        return jnp.stack([flips[..., :input_lanes].sum(-1), wside], axis=-1)

    totals = []
    for ci, cfg in enumerate(configs):
        if cfg.codec == "bus_invert":
            npart, pw = _partitions(lanes, cfg.partition)
            lbits = 8 * pw
            in_mask = (
                jnp.arange(lanes, dtype=jnp.int32) < input_lanes
            ).astype(jnp.int32).reshape(npart, pw)
            total = partials[0, ci, 0, :npart]  # (npart, 3): block 0, branch 0
            if grid > 1:

                def fold(carry, blk):
                    carry_wire, carry_inv = carry
                    part_g, edge_g, inv_g = blk
                    # branch-0 first wire IS the block's first data flit
                    d_first = edge_g[0, 0].reshape(npart, pw)
                    hd = _popcount_bits(d_first ^ carry_wire, 8).sum(-1)
                    b = (2 * hd > lbits).astype(jnp.int32)  # (npart,)
                    first_wire = d_first ^ (b[:, None] * 0xFF)
                    flips = _popcount_bits(carry_wire ^ first_wire, 8)
                    bnd = jnp.stack(
                        [
                            (flips * in_mask).sum(-1),
                            (flips * (1 - in_mask)).sum(-1),
                            (carry_inv != b).astype(jnp.int32),
                        ],
                        axis=-1,
                    )  # (npart, 3): the inter-block boundary itself
                    sel = jnp.where(b[:, None] == 1, part_g[1], part_g[0])
                    ew = edge_g[:, 1].reshape(2, npart, pw)
                    new_wire = jnp.where(b[:, None] == 1, ew[1], ew[0])
                    iv = inv_g[:, 1]
                    new_inv = jnp.where(b == 1, iv[1], iv[0])
                    return (new_wire, new_inv), bnd + sel

                carry0 = (
                    edges[0, ci, 0, 1].reshape(npart, pw),
                    inv_edges[0, ci, 0, 1, :npart],
                )
                _, contribs = jax.lax.scan(
                    fold,
                    carry0,
                    (
                        partials[1:, ci, :, :npart],
                        edges[1:, ci],
                        inv_edges[1:, ci, :, :, :npart],
                    ),
                )
                total = total + contribs.sum(axis=0)
            totals.append(total.sum(axis=0))  # (3,)
        else:
            total = partials[:, ci, 0].sum(axis=(0, 1))  # (3,) over G, slots
            if grid > 1:
                if cfg.codec == "transition":
                    # boundary flips = the next block's first DATA flit bits
                    flips = _popcount_bits(edges[1:, ci, 0, 0, :], 8)
                else:
                    flips = _popcount_bits(
                        jnp.bitwise_xor(
                            edges[:-1, ci, 0, 1, :], edges[1:, ci, 0, 0, :]
                        ),
                        8,
                    )
                bnd = _sides(flips).sum(axis=0)  # (2,)
                total = total + jnp.concatenate([bnd, jnp.zeros((1,), jnp.int32)])
            totals.append(total)
    return jnp.stack(totals).astype(jnp.int32)


@partial(jax.jit, static_argnames=("block", "interpret"))
def quantize_egress(
    x: jax.Array, block: int = 256, interpret: bool | None = None
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Blockwise int8 quantization of a flat vector (pads internally).

    Returns (q, scales, padded_size) where q/scales cover the padded vector;
    callers keep ``padded_size`` to dequantize and trim.
    """
    if interpret is None:
        interpret = default_interpret()
    m = x.shape[0]
    pad = (-m) % block
    xp = jnp.pad(x.astype(jnp.float32), (0, pad))
    q, s = quantize_egress_pallas(xp, block=block, interpret=interpret)
    return q, s, jnp.int32(m + pad)
