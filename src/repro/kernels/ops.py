"""Public wrappers around the kernel entry points, with backend dispatch.

Every BT entry point — ``psu_stream`` (fused TX pipeline),
``bt_count_links`` (per-link NoC batch), ``bt_count_variants`` (design-grid
batch), ``bt_count_codecs`` (codec x ordering batch) and the underlying
``bt_count_axes`` — is a thin configuration of the ONE multi-axis
measurement (``axes.py``, DESIGN.md §12) and executes on one of three
backends (``backend.py``, DESIGN.md §13):

  * ``"pallas"``    — the compiled Pallas TPU kernel (platform default on
    TPU only);
  * ``"compiled"``  — a jit-compiled pure-jnp path running the SAME block
    math (``axes._axes_block``), bit-exact with the kernel and the
    production path on CPU/GPU;
  * ``"interpret"`` — the Pallas interpreter, kept only as an explicit
    validation switch.

Resolution: explicit ``backend=`` > legacy ``interpret=`` bool >
``force_default_backend`` context > ``$REPRO_KERNEL_BACKEND`` > platform.

The wrappers also handle padding/trimming, the shared inter-block fold
(:func:`_fold_axes`), chunked streaming (``chunk_packets=``: a ``lax.scan``
over fixed-size packet chunks threading the fold carry across chunk
boundaries — O(chunk) live memory, bit-exact with the one-shot path) and a
``shard_map``-sharded link axis (:func:`bt_count_axes_sharded`).
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro import _obs_hooks as _obs
from repro.core.coding import bus_invert_partitions as _partitions

from .axes import (
    CodecVariant,
    Variant,
    bt_axes_compiled,
    bt_axes_pallas,
    max_partitions,
    validate_variants,
)
from .backend import (
    BACKENDS,
    BACKEND_ENV_VAR,
    default_backend,
    force_default_backend,
    resolve_backend,
)
from .btcount import bt_count_compiled, bt_count_pallas
from .psu import _popcount_bits, psu_sort_compiled, psu_sort_pallas
from .quantize import quantize_egress_compiled, quantize_egress_pallas

__all__ = [
    "psu_sort",
    "psu_reorder",
    "psu_stream",
    "PsuStreamResult",
    "AxesActivity",
    "LinkActivity",
    "bt_count",
    "bt_count_axes",
    "bt_count_axes_sharded",
    "bt_count_links",
    "bt_count_variants",
    "bt_count_codecs",
    "Variant",
    "CodecVariant",
    "quantize_egress",
    "default_interpret",
    "default_backend",
    "resolve_backend",
    "force_default_backend",
    "BACKENDS",
    "BACKEND_ENV_VAR",
    "pallas_launch_count",
]


def default_interpret() -> bool:
    """Legacy switch: True when the default backend is not the real
    compiled Pallas kernel (i.e. anywhere off-TPU).  Kept for callers that
    predate the three-way backend dispatch."""
    return default_backend() != "pallas"


def _probe(entry: str, resolved: str, **data):
    """One ``kernel.dispatch`` probe span per public entry point call
    (DESIGN.md §14).  Fires in Python OUTSIDE the jitted computation, so
    the traced jaxpr is byte-identical with observability off, on, or
    absent; a no-op ``None`` test when nothing collects.
    ``pallas_launches`` records what this dispatch costs on the pallas
    path (the cross-backend invariant is 1 per entry; the compiled jnp
    backend launches no kernel)."""
    return _obs.span(
        "kernel.dispatch",
        entry=entry,
        backend=resolved,
        pallas_launches=0 if resolved == "compiled" else 1,
        **data,
    )


def _entry(jitted, backend: str):
    """The jit-compiled impl for the perf backends ("pallas"/"compiled");
    the UN-jitted original for "interpret".  The Pallas interpreter is the
    step-by-step validation path (per-op execution, debug prints); jitting
    it would fuse the emulation into one XLA program — fast enough to pass
    for a perf path, and hiding exactly the per-op execution it exists to
    expose.  Inside an outer ``jax.jit`` it is traced like any eager code.
    """
    return jitted.__wrapped__ if backend == "interpret" else jitted


def pallas_launch_count(fn, *args) -> int:
    """Number of ``pallas_call`` equations in the traced jaxpr of ``fn``
    (recursing through pjit/scan/etc. sub-jaxprs) — the measurement behind
    every 1-launch claim in this repo (benchmarks and tests alike).

    Tracing runs under ``force_default_backend("interpret")`` so the
    *pallas* path is what gets counted even where the session default is
    "compiled" (launch counts are the cross-backend grid invariant; the
    compiled backend would trivially trace to zero).  An explicit
    ``backend=``/``interpret=`` inside ``fn`` still wins.
    """
    try:  # jaxpr types' public home since jax 0.4.33
        from jax.extend import core as jcore
    except ImportError:  # older releases
        from jax import core as jcore

    def walk(jaxpr) -> int:
        n = 0
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == "pallas_call":
                n += 1
            for v in eqn.params.values():
                for sub in _subjaxprs(v):
                    n += walk(sub)
        return n

    def _subjaxprs(v):
        if isinstance(v, jcore.ClosedJaxpr):
            yield v.jaxpr
        elif isinstance(v, jcore.Jaxpr):
            yield v
        elif isinstance(v, (tuple, list)):
            for item in v:
                yield from _subjaxprs(item)

    with force_default_backend("interpret"):
        jaxpr = jax.make_jaxpr(fn)(*args).jaxpr
    return walk(jaxpr)


@partial(
    jax.jit,
    static_argnames=("width", "k", "descending", "block_packets", "backend"),
)
def _psu_sort(
    packets: jax.Array,
    *,
    width: int,
    k: int | None,
    descending: bool,
    block_packets: int,
    backend: str,
) -> tuple[jax.Array, jax.Array]:
    p, n = packets.shape
    bp = min(block_packets, max(1, p))
    pad = (-p) % bp
    x = jnp.pad(packets.astype(jnp.int32), ((0, pad), (0, 0)))
    if backend == "compiled":
        order, rank = psu_sort_compiled(
            x, width=width, k=k, descending=descending
        )
    else:
        order, rank = psu_sort_pallas(
            x,
            width=width,
            k=k,
            descending=descending,
            block_packets=bp,
            interpret=backend == "interpret",
        )
    return order[:p], rank[:p]


def psu_sort(
    packets: jax.Array,
    width: int = 8,
    k: int | None = None,
    descending: bool = False,
    block_packets: int = 64,
    interpret: bool | None = None,
    backend: str | None = None,
) -> tuple[jax.Array, jax.Array]:
    """(order, rank) of each packet by (approximate) popcount.

    Accepts any (P, N) integer array; P is padded to the kernel block size
    and trimmed on return.
    """
    resolved = resolve_backend(backend, interpret)
    with _probe("psu_sort", resolved, shape=tuple(map(int, packets.shape)),
                width=width, k=k):
        return _entry(_psu_sort, resolved)(
            packets,
            width=width,
            k=k,
            descending=descending,
            block_packets=block_packets,
            backend=resolved,
        )


def psu_reorder(
    packets: jax.Array,
    width: int = 8,
    k: int | None = None,
    descending: bool = False,
    interpret: bool | None = None,
    backend: str | None = None,
) -> jax.Array:
    """Packets with elements transmitted in PSU order (gather by ``order``)."""
    order, _ = psu_sort(
        packets,
        width=width,
        k=k,
        descending=descending,
        interpret=interpret,
        backend=backend,
    )
    return jnp.take_along_axis(packets, order, axis=-1)


# --------------------------------------------------------------------------
# the shared launch + inter-block fold of the multi-axis measurement
# (DESIGN.md §12/§13)


def _launch_axes(x, w, valid, *, backend, **kw):
    """One (L, P, N) multi-axis launch on the resolved backend."""
    if backend == "compiled":
        return bt_axes_compiled(x, w, valid, **kw)
    return bt_axes_pallas(x, w, valid, interpret=backend == "interpret", **kw)


class AxesActivity(NamedTuple):
    """:func:`bt_count_axes` result with per-wire switching activity.

    Wire indexing (DESIGN.md §15): ``lanes * 8`` data wires first (wire =
    lane * 8 + bit, LSB first), then ``PMAX`` invert-line aux wires (only
    the first ``partitions`` of a bus-invert config ever toggle).
    """

    bt: jax.Array  # (L, C, 3) per-link, per-config BT totals
    toggles: jax.Array  # (L, C, NW, WIRES) toggle counts per time window
    ones: jax.Array  # (L, C, WIRES) flit rows each wire spent at level 1


class LinkActivity(NamedTuple):
    """:func:`bt_count_links` result with per-wire switching activity."""

    bt: jax.Array  # (L, 2) per-link (input, weight) BT totals
    toggles: jax.Array  # (L, NW, WIRES)
    ones: jax.Array  # (L, WIRES)


def _axes_carry(nl: int, configs, lanes: int, activity: bool = False):
    """The zero inter-chunk fold carry: nothing transmitted yet."""
    pmax = max_partitions(configs, lanes)
    carry = {
        "started": jnp.zeros((nl,), jnp.int32),
        "wire": jnp.zeros((len(configs), nl, lanes), jnp.int32),
        "inv": jnp.zeros((len(configs), nl, pmax), jnp.int32),
    }
    if activity:
        # per-wire level parity entering the next chunk ('transition'
        # signaling: the wire level is the running data parity)
        carry["parity"] = jnp.zeros((len(configs), nl, lanes * 8), jnp.int32)
    return carry


def _fold_axes(
    partials: jax.Array,  # (L, G, C, 2, PMAX, 3)
    edges: jax.Array,  # (L, G, C, 2, 2, lanes)
    inv_edges: jax.Array,  # (L, G, C, 2, 2, PMAX)
    configs: tuple[CodecVariant, ...],
    valid_rows: jax.Array,  # (L,) real flit rows per link (this chunk)
    rows: int,  # flit rows per block
    split_lanes: int,
    carry=None,
    return_carry: bool = False,
    activity=None,
    window_rows: int = 0,
    base_row=None,
):
    """Fold per-(link, block) kernel partials into (L, C, 3) totals.

    Block-internal boundaries are already masked in-kernel; this patches
    the inter-block boundaries per link in O(G) jnp — stateless codecs XOR
    adjacent edge flits, transition signaling adds each block's first-flit
    popcount, and bus-invert carries each block's entry branch from the
    previous block's last wire flit (``lax.scan``).  Boundaries into
    fully-padded blocks are masked by each link's ``valid_rows``.

    ``carry`` / ``return_carry`` extend the same fold across *chunk*
    boundaries (the ``chunk_packets`` streaming mode): the carry pytree
    holds, per link, whether anything was transmitted yet ("started"), the
    last wire flit per config ("wire") and the last invert-line states
    ("inv").  With ``carry=None`` the stream starts cold — block 0 enters
    uninverted and its first flit pays no boundary — which reproduces the
    single-shot fold exactly.

    ``activity`` is the optional (act, ones) kernel output pair
    (DESIGN.md §15); the fold then also returns the per-wire window
    toggles (L, C, NW, WIRES) and wire-level 1-counts (L, C, WIRES): the
    inter-block boundary toggles are scattered into the window of each
    block's first row (``base_row`` offsets the chunk), bus-invert branch
    outputs are selected per PARTITION over the wire axis, and transition
    1-counts are resolved against the carried per-wire entry parity (the
    "parity" carry slot).
    """
    nl, gblocks = partials.shape[:2]
    lanes = edges.shape[-1]
    pmax = partials.shape[-2]
    if carry is None:
        carry = _axes_carry(nl, configs, lanes, activity=activity is not None)
    started0 = carry["started"]
    has = (valid_rows > 0).astype(jnp.int32)
    # block g holds >= 1 valid row of this link
    gmask = (
        jnp.arange(gblocks, dtype=jnp.int32)[None, :] * rows
        < valid_rows[:, None]
    ).astype(jnp.int32)  # (L, G)
    # the last block holding valid rows (0 when the chunk is empty)
    glast = jnp.clip((valid_rows + rows - 1) // rows - 1, 0, gblocks - 1)

    def _sides(flips):  # (..., lanes) -> (..., 2) per-side sums
        in_side = flips[..., :split_lanes].sum(-1)
        w_side = (
            flips[..., split_lanes:].sum(-1)
            if split_lanes < lanes
            else jnp.zeros_like(in_side)
        )
        return jnp.stack([in_side, w_side], axis=-1)

    if activity is not None:
        act_in, ones_in = activity  # (L,G,C,2,NW,WIRES), (L,G,C,2,WIRES)
        num_windows = act_in.shape[-2]
        dwires = lanes * 8
        base = (
            jnp.int32(0) if base_row is None
            else jnp.asarray(base_row, jnp.int32)
        )
        # global first row of block g -> the window its entry boundary hits
        g_first = base + jnp.arange(gblocks, dtype=jnp.int32) * rows
        win_onehot_g = (
            (g_first // window_rows)[:, None]
            == jnp.arange(num_windows, dtype=jnp.int32)[None, :]
        ).astype(jnp.int32)  # (G, NW)
        valid_blk = jnp.clip(
            valid_rows[:, None]
            - jnp.arange(gblocks, dtype=jnp.int32)[None, :] * rows,
            0,
            rows,
        )  # (L, G) valid rows inside block g
        bit8 = jnp.arange(8, dtype=jnp.int32)

        def _bits8(arr):  # (..., K) bytes -> (..., K*8) bits, LSB first
            bits = (arr[..., None] >> bit8) & 1
            return bits.reshape(*arr.shape[:-1], arr.shape[-1] * 8)

        def _scatter_g(bnd):  # (L, G, W) -> (L, NW, W) window scatter
            return jnp.einsum("lgw,gn->lnw", bnd, win_onehot_g)

    totals, wire_out, inv_out = [], [], []
    acts_out, ones_out, parity_out = [], [], []
    for ci, cfg in enumerate(configs):
        if cfg.codec == "bus_invert":
            npart, pw = _partitions(lanes, cfg.partition)
            lbits = 8 * pw
            in_mask = (
                jnp.arange(lanes, dtype=jnp.int32) < split_lanes
            ).astype(jnp.int32).reshape(npart, pw)

            def fold(state, blk):
                cw, civ, st = state  # (L,npart,pw), (L,npart), (L,)
                part_g, edge_g, inv_g, m = blk
                # branch-0 first wire IS the block's first data flit
                d_first = edge_g[:, 0, 0].reshape(nl, npart, pw)
                hd = _popcount_bits(d_first ^ cw, 8).sum(-1)
                # entry branch; forced 0 before anything was transmitted
                b = (2 * hd > lbits).astype(jnp.int32) * st[:, None]
                first_wire = d_first ^ (b[..., None] * 0xFF)
                flips = _popcount_bits(cw ^ first_wire, 8)
                bnd = jnp.stack(
                    [
                        (flips * in_mask).sum(-1),
                        (flips * (1 - in_mask)).sum(-1),
                        (civ != b).astype(jnp.int32),
                    ],
                    axis=-1,
                ) * st[:, None, None]  # no boundary into the first flit ever
                sel = jnp.where(b[..., None] == 1, part_g[:, 1], part_g[:, 0])
                ew = edge_g[:, :, 1].reshape(nl, 2, npart, pw)
                new_wire = jnp.where(b[..., None] == 1, ew[:, 1], ew[:, 0])
                iv = inv_g[:, :, 1]  # (L, 2, npart)
                new_inv = jnp.where(b == 1, iv[:, 1], iv[:, 0])
                # links whose valid rows end before this block keep their
                # carry and contribute nothing
                m3 = m[:, None, None]
                new_wire = jnp.where(m3 == 1, new_wire, cw)
                new_inv = jnp.where(m[:, None] == 1, new_inv, civ)
                ys = (bnd + sel) * m3
                if activity is not None:
                    # per-wire boundary toggles + the entry branch per
                    # partition (selects the kernel's per-branch activity)
                    stm = (st * m)[:, None]
                    ys = (
                        ys,
                        b,
                        _bits8((cw ^ first_wire).reshape(nl, lanes)) * stm,
                        (civ != b).astype(jnp.int32) * stm,
                    )
                return (new_wire, new_inv, jnp.maximum(st, m)), ys

            carry0 = (
                carry["wire"][ci].reshape(nl, npart, pw),
                carry["inv"][ci, :, :npart],
                started0,
            )
            (cw, civ, _), scan_ys = lax.scan(
                fold,
                carry0,
                (
                    jnp.moveaxis(partials[:, :, ci, :, :npart], 1, 0),
                    jnp.moveaxis(edges[:, :, ci], 1, 0),
                    jnp.moveaxis(inv_edges[:, :, ci, :, :, :npart], 1, 0),
                    jnp.moveaxis(gmask, 1, 0),
                ),
            )
            contribs = scan_ys[0] if activity is not None else scan_ys
            totals.append(contribs.sum(axis=0).sum(axis=1))  # (L, 3)
            wire_out.append(cw.reshape(nl, lanes))
            inv_out.append(jnp.pad(civ, ((0, 0), (0, pmax - npart))))
            if activity is not None:
                _, bs, bnd_bits, aux_bnd = scan_ys
                # map every wire to its partition's entry branch: data wire
                # lane*8+bit -> partition wire // (8*pw); aux wire i -> i
                part_of_wire = jnp.concatenate([
                    jnp.arange(dwires, dtype=jnp.int32) // (8 * pw),
                    jnp.minimum(
                        jnp.arange(pmax, dtype=jnp.int32), npart - 1
                    ),
                ])
                bsel = jnp.moveaxis(bs, 0, 1)[:, :, part_of_wire]
                acts_out.append(jnp.where(
                    bsel[:, :, None, :] == 1,
                    act_in[:, :, ci, 1],
                    act_in[:, :, ci, 0],
                ).sum(axis=1) + _scatter_g(jnp.concatenate([
                    jnp.moveaxis(bnd_bits, 0, 1),
                    jnp.pad(
                        jnp.moveaxis(aux_bnd, 0, 1),
                        ((0, 0), (0, 0), (0, pmax - npart)),
                    ),
                ], axis=-1)))
                ones_out.append(jnp.where(
                    bsel == 1, ones_in[:, :, ci, 1], ones_in[:, :, ci, 0]
                ).sum(axis=1))
                parity_out.append(carry["parity"][ci])
        else:
            # branch 0 carries every stateless codec; padded slots are zero
            total = partials[:, :, ci, 0].sum(axis=(1, 2))  # (L, 3)
            first = edges[:, :, ci, 0, 0, :]  # (L, G, lanes)
            last = edges[:, :, ci, 0, 1, :]
            if cfg.codec == "transition":
                # boundary flips = each block's first DATA flit bits
                bnd_bytes = first
            else:
                prev = jnp.concatenate(
                    [carry["wire"][ci][:, None], last[:, :-1]], axis=1
                )
                bnd_bytes = prev ^ first
            flips = _popcount_bits(bnd_bytes, 8)
            # boundary into block g counts iff block g is real AND there is
            # a previous flit (g > 0, or the stream already started)
            entry = jnp.concatenate(
                [started0[:, None], jnp.ones((nl, gblocks - 1), jnp.int32)],
                axis=1,
            )
            bnd = (_sides(flips) * (gmask * entry)[..., None]).sum(axis=1)
            totals.append(
                total
                + jnp.concatenate([bnd, jnp.zeros((nl, 1), jnp.int32)], axis=-1)
            )
            lastw = jnp.take_along_axis(last, glast[:, None, None], axis=1)[:, 0]
            wire_out.append(
                jnp.where(has[:, None] == 1, lastw, carry["wire"][ci])
            )
            inv_out.append(carry["inv"][ci])
            if activity is not None:
                bb = _bits8(bnd_bytes) * (gmask * entry)[..., None]
                acts_out.append(
                    act_in[:, :, ci, 0].sum(axis=1)
                    + _scatter_g(jnp.pad(bb, ((0, 0), (0, 0), (0, pmax))))
                )
                if cfg.codec == "transition":
                    # resolve slot-0 1-counts against the carried per-wire
                    # entry parity; slot 1 holds each block's data parity
                    ones_e0 = ones_in[:, :, ci, 0, :dwires]  # (L, G, D)
                    pblk = ones_in[:, :, ci, 1, :dwires]
                    pcarry = carry["parity"][ci]  # (L, D)
                    pent = (
                        pcarry[:, None, :]
                        + jnp.cumsum(pblk, axis=1) - pblk
                    ) & 1
                    ones_g = jnp.where(
                        pent == 1,
                        valid_blk[..., None] - ones_e0,
                        ones_e0,
                    )
                    ones_out.append(jnp.pad(
                        ones_g.sum(axis=1), ((0, 0), (0, pmax))
                    ))
                    parity_out.append((pcarry + pblk.sum(axis=1)) & 1)
                else:
                    ones_out.append(ones_in[:, :, ci, 0].sum(axis=1))
                    parity_out.append(carry["parity"][ci])
    out = jnp.stack(totals, axis=1).astype(jnp.int32)  # (L, C, 3)
    res = (out,)
    if activity is not None:
        res += (
            jnp.stack(acts_out, axis=1).astype(jnp.int32),  # (L,C,NW,WIRES)
            jnp.stack(ones_out, axis=1).astype(jnp.int32),  # (L,C,WIRES)
        )
    if not return_carry:
        return res[0] if activity is None else res
    new_carry = {
        "started": jnp.maximum(started0, has),
        "wire": jnp.stack(wire_out),
        "inv": jnp.stack(inv_out),
    }
    if activity is not None:
        new_carry["parity"] = jnp.stack(parity_out)
        return res + (new_carry,)
    return out, new_carry


def _dispatch_axes(
    inputs,
    weights,
    valid,
    *,
    configs,
    width,
    input_lanes,
    weight_lanes,
    split_lanes,
    pack,
    block_packets,
    backend,
    chunk_packets=None,
    activity_windows=None,
):
    """Pad, launch (on the resolved backend) and fold — optionally chunked.

    The one driver every BT entry point reduces to.  With ``chunk_packets``
    the packet axis becomes a ``lax.scan`` over fixed-size chunks threading
    the :func:`_fold_axes` carry (bus-invert wire/invert-line state,
    stateless-codec edge flits) across chunk boundaries — bit-exact with
    the single-launch path while bounding live intermediates to one chunk.

    With ``activity_windows`` every launch also accumulates the per-wire
    window-toggle tensor (DESIGN.md §15): windows are indexed by GLOBAL
    flit row (each chunk offsets its blocks by ``base_row``), so the
    chunked path lands every toggle in the same window as the one-shot
    path and the trimmed :class:`AxesActivity` result is bit-exact.
    """
    links, p, n = inputs.shape
    flits = n // input_lanes
    sl = input_lanes if split_lanes is None else split_lanes
    bp = min(block_packets, max(1, p))
    kw = dict(
        configs=configs,
        width=width,
        input_lanes=input_lanes,
        weight_lanes=weight_lanes,
        split_lanes=split_lanes,
        pack=pack,
        block_packets=bp,
    )
    wlen = activity_windows
    nw_real = 0 if wlen is None else -(-(p * flits) // wlen)
    x = inputs.astype(jnp.int32)
    w = weights.astype(jnp.int32)
    if chunk_packets is None:
        pad = (-p) % bp
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0)))
        if wlen is None:
            partials, edges, inv_edges = _launch_axes(
                x, w, valid, backend=backend, **kw
            )
            return _fold_axes(
                partials, edges, inv_edges, configs, valid * flits,
                bp * flits, sl,
            )
        nw = -(-((p + pad) * flits) // wlen)
        partials, edges, inv_edges, act, ones = _launch_axes(
            x, w, valid, backend=backend, window_rows=wlen, num_windows=nw,
            **kw,
        )
        bt, act_t, ones_t = _fold_axes(
            partials, edges, inv_edges, configs, valid * flits, bp * flits,
            sl, activity=(act, ones), window_rows=wlen,
        )
        return AxesActivity(bt, act_t[:, :, :nw_real], ones_t)
    # chunked streaming: the chunk is rounded up to a whole block count
    cp = -(-chunk_packets // bp) * bp
    pad = (-p) % cp
    x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    w = jnp.pad(w, ((0, 0), (0, pad), (0, 0)))
    nchunks = (p + pad) // cp
    xb = jnp.moveaxis(x.reshape(links, nchunks, cp, n), 1, 0)
    wb = jnp.moveaxis(w.reshape(links, nchunks, cp, n), 1, 0)
    cvalid = jnp.clip(
        valid[None, :] - jnp.arange(nchunks, dtype=jnp.int32)[:, None] * cp,
        0,
        cp,
    )  # (nchunks, L) valid packets per chunk
    nw = 0 if wlen is None else -(-(nchunks * cp * flits) // wlen)
    bases = jnp.arange(nchunks, dtype=jnp.int32) * (cp * flits)

    def step(state, blk):
        if wlen is None:
            fold_carry, total = state
            xc, wc, vc, _ = blk
            partials, edges, inv_edges = _launch_axes(
                xc, wc, vc, backend=backend, **kw
            )
            bt, fold_carry = _fold_axes(
                partials, edges, inv_edges, configs, vc * flits, bp * flits,
                sl, carry=fold_carry, return_carry=True,
            )
            return (fold_carry, total + bt), None
        fold_carry, total, act_tot, ones_tot = state
        xc, wc, vc, basec = blk
        partials, edges, inv_edges, act, ones = _launch_axes(
            xc, wc, vc, backend=backend, window_rows=wlen, num_windows=nw,
            base_row=basec, **kw,
        )
        bt, act_t, ones_t, fold_carry = _fold_axes(
            partials, edges, inv_edges, configs, vc * flits, bp * flits, sl,
            carry=fold_carry, return_carry=True, activity=(act, ones),
            window_rows=wlen, base_row=basec,
        )
        return (
            fold_carry, total + bt, act_tot + act_t, ones_tot + ones_t
        ), None

    lanes = input_lanes + weight_lanes
    carry0 = _axes_carry(links, configs, lanes, activity=wlen is not None)
    total0 = jnp.zeros((links, len(configs), 3), jnp.int32)
    state0 = (carry0, total0)
    if wlen is not None:
        nwires = lanes * 8 + max_partitions(configs, lanes)
        state0 += (
            jnp.zeros((links, len(configs), nw, nwires), jnp.int32),
            jnp.zeros((links, len(configs), nwires), jnp.int32),
        )
    state, _ = lax.scan(step, state0, (xb, wb, cvalid, bases))
    if wlen is None:
        return state[1]
    return AxesActivity(state[1], state[2][:, :, :nw_real], state[3])


def _paired(inputs, weights, weight_lanes, input_lanes):
    """Shared (weights, weight_lanes) defaulting of the packet wrappers."""
    if weights is None:
        weight_lanes = 0 if weight_lanes is None else weight_lanes
        weights = jnp.zeros_like(inputs)
    elif weight_lanes is None:
        weight_lanes = input_lanes
    if weights.shape != inputs.shape:
        raise ValueError(f"paired shapes differ: {inputs.shape} vs {weights.shape}")
    return weights, weight_lanes


class PsuStreamResult(NamedTuple):
    """Everything the fused TX pipeline produces in one kernel launch."""

    order: jax.Array  # (P, N) int32: input index transmitted j-th
    rank: jax.Array  # (P, N) int32: output slot of input element i
    stream: jax.Array  # (P*F, lanes) uint8 packed flit rows
    bt_input: jax.Array  # int32 scalar: input-side bit transitions
    bt_weight: jax.Array  # int32 scalar: weight-side bit transitions


@partial(
    jax.jit,
    static_argnames=(
        "width",
        "k",
        "descending",
        "input_lanes",
        "weight_lanes",
        "pack",
        "block_packets",
        "backend",
    ),
)
def _psu_stream(
    inputs: jax.Array,
    weights: jax.Array | None,
    *,
    width: int,
    k: int | None,
    descending: bool,
    input_lanes: int,
    weight_lanes: int | None,
    pack: str,
    block_packets: int,
    backend: str,
) -> PsuStreamResult:
    weights, weight_lanes = _paired(inputs, weights, weight_lanes, input_lanes)
    p, n = inputs.shape
    flits = n // input_lanes
    bp = min(block_packets, max(1, p))
    pad = (-p) % bp
    x = jnp.pad(inputs.astype(jnp.int32), ((0, pad), (0, 0)))
    w = jnp.pad(weights.astype(jnp.int32), ((0, pad), (0, 0)))
    cfg = CodecVariant("acc" if k is None else "app", k, descending)
    valid = jnp.full((1,), p, jnp.int32)
    partials, edges, inv_edges, order, rank, stream = _launch_axes(
        x[None],
        w[None],
        valid,
        backend=backend,
        configs=(cfg,),
        width=width,
        input_lanes=input_lanes,
        weight_lanes=weight_lanes,
        pack=pack,
        block_packets=bp,
        emit_stream=True,
    )
    bt = _fold_axes(
        partials, edges, inv_edges, (cfg,), valid * flits, bp * flits,
        input_lanes,
    )[0, 0]
    return PsuStreamResult(
        order[0, :p],
        rank[0, :p],
        stream[0, : p * flits].astype(jnp.uint8),
        bt[0],
        bt[1],
    )


def psu_stream(
    inputs: jax.Array,
    weights: jax.Array | None = None,
    width: int = 8,
    k: int | None = None,
    descending: bool = False,
    input_lanes: int = 8,
    weight_lanes: int | None = None,
    pack: str = "lane",
    block_packets: int = 64,
    interpret: bool | None = None,
    backend: str | None = None,
) -> PsuStreamResult:
    """Fused popcount-sort -> reorder -> flit-pack -> BT-count, one launch.

    The multi-axis measurement in ``emit_stream`` mode: one link, one
    uncoded 'acc'/'app' config, with the permutation-matrix contraction
    also yielding ``order``/``rank`` and the packed wire stream.  Accepts
    any (P, N) integer packets; P is padded to the kernel block size and
    the padded tail is masked inside the launch (the unified convention) —
    the wrapper only folds the G-1 inter-block flit boundaries.
    """
    resolved = resolve_backend(backend, interpret)
    with _probe("psu_stream", resolved, shape=tuple(map(int, inputs.shape)),
                width=width, k=k, pack=pack,
                blocks=-(-int(inputs.shape[0]) // max(1, block_packets))):
        return _entry(_psu_stream, resolved)(
            inputs,
            weights,
            width=width,
            k=k,
            descending=descending,
            input_lanes=input_lanes,
            weight_lanes=weight_lanes,
            pack=pack,
            block_packets=block_packets,
            backend=resolved,
        )


@partial(jax.jit, static_argnames=("width", "block_rows", "backend"))
def _bt_count(
    stream: jax.Array, *, width: int, block_rows: int, backend: str
) -> jax.Array:
    if backend == "compiled":
        return bt_count_compiled(stream, width=width)
    return bt_count_pallas(
        stream, width=width, block_rows=block_rows,
        interpret=backend == "interpret",
    )


def bt_count(
    stream: jax.Array,
    width: int = 8,
    block_rows: int = 512,
    interpret: bool | None = None,
    backend: str | None = None,
) -> jax.Array:
    """Total bit transitions of a (T, L) flit stream."""
    resolved = resolve_backend(backend, interpret)
    with _probe("bt_count", resolved, shape=tuple(map(int, stream.shape)),
                width=width):
        return _entry(_bt_count, resolved)(
            stream,
            width=width,
            block_rows=block_rows,
            backend=resolved,
        )


@partial(
    jax.jit,
    static_argnames=(
        "configs",
        "width",
        "input_lanes",
        "weight_lanes",
        "split_lanes",
        "pack",
        "block_packets",
        "backend",
        "chunk_packets",
        "activity_windows",
    ),
)
def _bt_count_axes(
    inputs: jax.Array,
    weights: jax.Array | None,
    valid,
    *,
    configs: tuple[CodecVariant, ...],
    width: int,
    input_lanes: int,
    weight_lanes: int | None,
    split_lanes: int | None,
    pack: str,
    block_packets: int,
    backend: str,
    chunk_packets: int | None,
    activity_windows: int | None = None,
) -> jax.Array:
    weights, weight_lanes = _paired(inputs, weights, weight_lanes, input_lanes)
    links, p, n = inputs.shape
    nc = len(configs)
    if links == 0 or p == 0:
        bt = jnp.zeros((links, nc, 3), jnp.int32)
        if activity_windows is None:
            return bt
        lanes = input_lanes + weight_lanes
        nwires = lanes * 8 + max_partitions(configs, lanes)
        nw = 0 if p == 0 else -(-(p * (n // input_lanes)) // activity_windows)
        return AxesActivity(
            bt,
            jnp.zeros((links, nc, nw, nwires), jnp.int32),
            jnp.zeros((links, nc, nwires), jnp.int32),
        )
    if valid is None:
        valid = jnp.full((links,), p, jnp.int32)
    else:
        # clamp to the packets actually present: a valid count past P would
        # silently count the last-real -> zero-pad boundary as real
        valid = jnp.minimum(jnp.asarray(valid, jnp.int32), p)
    return _dispatch_axes(
        inputs,
        weights,
        valid,
        configs=configs,
        width=width,
        input_lanes=input_lanes,
        weight_lanes=weight_lanes,
        split_lanes=split_lanes,
        pack=pack,
        block_packets=block_packets,
        backend=backend,
        chunk_packets=chunk_packets,
        activity_windows=activity_windows,
    )


def bt_count_axes(
    inputs: jax.Array,
    weights: jax.Array | None = None,
    valid: jax.Array | Sequence[int] | None = None,
    configs: tuple[CodecVariant, ...] = (CodecVariant(),),
    width: int = 8,
    input_lanes: int = 8,
    weight_lanes: int | None = None,
    split_lanes: int | None = None,
    pack: str = "lane",
    block_packets: int = 64,
    interpret: bool | None = None,
    backend: str | None = None,
    chunk_packets: int | None = None,
    activity_windows: int | None = None,
) -> jax.Array:
    """The full multi-axis measurement: per-LINK, per-(ordering, codec)
    config BT of a (L, P, N) packet batch in ONE kernel launch.

    This is the grid the whole stack reduces to (DESIGN.md §12): NoC links,
    DSE variants and wire codecs are orthogonal axes of one launch.  Links
    may be jagged — ``valid`` gives each link's real packet count and
    everything past it contributes zero data BT and zero aux BT (so a
    bus-invert decision is never evaluated on a padded flit).

    Args:
      inputs: (L, P, N) integer packets (P = the longest link, zero-padded).
      weights: optional (L, P, N) paired weight bytes.
      valid: (L,) real packet counts (default: all P real).
      configs: static tuple of :class:`CodecVariant` configurations.
      split_lanes: lane where the input side ends for per-side accounting
        (default ``input_lanes``; the NoC path feeds pre-assembled flit
        rows as N = lanes packets and splits at the spec's input_lanes).
      backend / interpret: backend selection (DESIGN.md §13); default
        resolves platform/env via :func:`repro.kernels.default_backend`.
      chunk_packets: process the packet axis as a scan over chunks of this
        many packets (rounded up to a block multiple), threading the
        inter-block fold carry across chunk edges — bit-exact, O(chunk)
        live memory.
      activity_windows: also accumulate the per-wire switching-activity
        tensor with this window length in FLIT ROWS (DESIGN.md §15); the
        return type becomes :class:`AxesActivity` with ``toggles`` of
        shape (L, C, ceil(P*F / activity_windows), lanes*8 + PMAX) and
        ``ones`` (time-at-1 per wire, in flit rows) of (L, C, wires).

    Returns:
      int32 (L, C, 3): per-link, per-config (input-side BT, weight-side
      BT, invert-line BT) totals — or :class:`AxesActivity` when
      ``activity_windows`` is set.
    """
    if inputs.ndim != 3:
        raise ValueError(f"expected (L, P, N) packets, got {inputs.shape}")
    if activity_windows is not None and activity_windows < 1:
        raise ValueError(f"activity_windows must be >= 1, got {activity_windows}")
    resolved = resolve_backend(backend, interpret)
    links, p, _ = (int(d) for d in inputs.shape)
    with _probe("bt_count_axes", resolved,
                shape=tuple(map(int, inputs.shape)),
                configs=len(tuple(configs)), width=width,
                blocks=links * -(-p // max(1, min(block_packets, max(1, p)))),
                chunked=chunk_packets is not None,
                activity=activity_windows is not None):
        return _entry(_bt_count_axes, resolved)(
            inputs,
            weights,
            valid,
            configs=tuple(configs),
            width=width,
            input_lanes=input_lanes,
            weight_lanes=weight_lanes,
            split_lanes=split_lanes,
            pack=pack,
            block_packets=block_packets,
            backend=resolved,
            chunk_packets=chunk_packets,
            activity_windows=activity_windows,
        )


def bt_count_axes_sharded(
    inputs: jax.Array,
    weights: jax.Array | None = None,
    valid: jax.Array | Sequence[int] | None = None,
    configs: tuple[CodecVariant, ...] = (CodecVariant(),),
    width: int = 8,
    input_lanes: int = 8,
    weight_lanes: int | None = None,
    split_lanes: int | None = None,
    pack: str = "lane",
    block_packets: int = 64,
    interpret: bool | None = None,
    backend: str | None = None,
    chunk_packets: int | None = None,
    activity_windows: int | None = None,
    devices: Sequence[jax.Device] | None = None,
) -> jax.Array:
    """:func:`bt_count_axes` with the LINK axis sharded across devices.

    ``shard_map`` (via ``repro.compat``) splits the links of a NoC grid
    over a 1-D device mesh; each device measures its shard with the same
    launch + fold as the unsharded path, scatters it into the full-table
    layout and a ``psum`` assembles the replicated (L, C, 3) BT table.
    Links are padded to a device multiple with ``valid = 0`` links, whose
    rows the unified masking convention zeroes — so the padding is exact,
    not approximate.  Per-link results are bit-identical to the unsharded
    entry point (each link's fold never crosses the shard boundary).
    """
    if inputs.ndim != 3:
        raise ValueError(f"expected (L, P, N) packets, got {inputs.shape}")
    from jax.sharding import Mesh, PartitionSpec

    from repro.compat import shard_map

    backend = resolve_backend(backend, interpret)
    devices = list(jax.devices() if devices is None else devices)
    nd = len(devices)
    weights, weight_lanes = _paired(inputs, weights, weight_lanes, input_lanes)
    links, p, n = inputs.shape
    nc = len(configs := tuple(configs))
    lanes = input_lanes + weight_lanes
    if links == 0 or p == 0:
        bt = jnp.zeros((links, nc, 3), jnp.int32)
        if activity_windows is None:
            return bt
        nwires = lanes * 8 + max_partitions(configs, lanes)
        nw = 0 if p == 0 else -(-(p * (n // input_lanes)) // activity_windows)
        return AxesActivity(
            bt,
            jnp.zeros((links, nc, nw, nwires), jnp.int32),
            jnp.zeros((links, nc, nwires), jnp.int32),
        )
    if valid is None:
        valid = jnp.full((links,), p, jnp.int32)
    else:
        valid = jnp.minimum(jnp.asarray(valid, jnp.int32), p)
    lpad = (-links) % nd
    x = jnp.pad(inputs.astype(jnp.int32), ((0, lpad), (0, 0), (0, 0)))
    w = jnp.pad(weights.astype(jnp.int32), ((0, lpad), (0, 0), (0, 0)))
    v = jnp.pad(valid, (0, lpad))
    ltot = links + lpad
    shard = ltot // nd
    mesh = Mesh(np.asarray(devices), ("links",))

    def _assemble(arr):
        # scatter this shard's rows into the full-link layout and psum
        full = jnp.zeros((ltot,) + arr.shape[1:], arr.dtype)
        idx = (lax.axis_index("links") * shard,) + (0,) * (arr.ndim - 1)
        return lax.psum(lax.dynamic_update_slice(full, arr, idx), "links")

    def local(xs, ws, vs):
        out = _dispatch_axes(
            xs,
            ws,
            vs,
            configs=configs,
            width=width,
            input_lanes=input_lanes,
            weight_lanes=weight_lanes,
            split_lanes=split_lanes,
            pack=pack,
            block_packets=block_packets,
            backend=backend,
            chunk_packets=chunk_packets,
            activity_windows=activity_windows,
        )
        if activity_windows is None:
            return _assemble(out)
        return AxesActivity(*(_assemble(o) for o in out))

    spec = PartitionSpec("links")
    with _probe("bt_count_axes_sharded", backend,
                shape=(ltot, int(p), int(n)), configs=nc, width=width,
                devices=nd, activity=activity_windows is not None):
        out = shard_map(
            local,
            mesh=mesh,
            in_specs=(spec, spec, spec),
            out_specs=PartitionSpec(),
        )(x, w, v)
    if activity_windows is None:
        return out[:links]
    return AxesActivity(*(o[:links] for o in out))


@partial(
    jax.jit,
    static_argnames=(
        "input_lanes", "width", "block_rows", "backend", "chunk_rows",
        "activity_windows",
    ),
)
def _bt_count_links(
    streams: jax.Array,
    lengths,
    *,
    input_lanes: int,
    width: int,
    block_rows: int,
    backend: str,
    chunk_rows: int | None,
    activity_windows: int | None = None,
) -> jax.Array:
    links, t, lanes = streams.shape
    valid = (
        jnp.full((links,), t, jnp.int32)
        if lengths is None
        else jnp.minimum(jnp.asarray(lengths, jnp.int32), t)
    )
    out = _dispatch_axes(
        streams,
        jnp.zeros_like(streams),
        valid,
        configs=(CodecVariant("none"),),
        width=width,
        input_lanes=lanes,
        weight_lanes=0,
        split_lanes=input_lanes,
        pack="row",
        block_packets=block_rows,
        backend=backend,
        chunk_packets=chunk_rows,
        activity_windows=activity_windows,
    )
    if activity_windows is None:
        return out[:, 0, :2]
    # one uncoded config: drop the config axis and the (zero) aux wire
    return LinkActivity(
        out.bt[:, 0, :2], out.toggles[:, 0, :, : lanes * 8],
        out.ones[:, 0, : lanes * 8],
    )


def bt_count_links(
    streams: jax.Array,
    input_lanes: int | None = None,
    lengths: jax.Array | Sequence[int] | None = None,
    width: int = 8,
    block_links: int = 8,
    block_rows: int = 512,
    interpret: bool | None = None,
    backend: str | None = None,
    chunk_rows: int | None = None,
    activity_windows: int | None = None,
) -> jax.Array:
    """Per-link BT of a (L, T, lanes) stream batch in ONE kernel launch.

    The batched replacement for looping ``bt_count`` over the links of a
    NoC: each pre-assembled flit row is one N = lanes "packet" of the
    multi-axis measurement with the identity ordering, so the link axis
    rides the kernel grid.  Jagged links pass their real flit counts via
    ``lengths`` and everything past them is masked inside the launch (the
    unified convention) — any padding value is neutral, including the
    repeated-last-flit rows ``repro.noc.simulate.stack_link_streams``
    emits (which are also zero-BT on their own).

    Args:
      streams: (L, T, lanes) integer flit streams, one per link.
      input_lanes: lanes carrying input bytes (rest = weight side);
        default all lanes.
      lengths: (L,) real flit counts for jagged links (default: all T).
      width: element bit width of the lanes (byte lanes: 8).
      block_links: unused (one grid row per link); kept for call
        compatibility with the pre-unification kernel.
      block_rows: flit rows per grid step.
      backend / chunk_rows: backend selection and chunked streaming over
        the flit-row axis (see :func:`bt_count_axes`).
      activity_windows: also accumulate per-wire switching activity with
        this window length in flit rows; the return type becomes
        :class:`LinkActivity` with ``toggles`` (L, NW, lanes*8) and
        ``ones`` (L, lanes*8) over the data wires (wire = lane*8 + bit).

    Returns:
      int32 (L, 2): per-link (input-side, weight-side) bit transitions —
      or :class:`LinkActivity` when ``activity_windows`` is set.
    """
    del block_links  # the link axis is unblocked on the unified grid
    links, t, lanes = streams.shape
    if input_lanes is None:
        input_lanes = lanes
    if not 0 <= input_lanes <= lanes:
        raise ValueError(
            f"input_lanes={input_lanes} outside the {lanes}-lane flit"
        )
    if links == 0 or t == 0 or (t < 2 and activity_windows is None):
        bt = jnp.zeros((links, 2), jnp.int32)
        if activity_windows is None:
            return bt
        nw = -(-int(t) // activity_windows)
        return LinkActivity(
            bt,
            jnp.zeros((links, nw, lanes * 8), jnp.int32),
            jnp.zeros((links, lanes * 8), jnp.int32),
        )
    resolved = resolve_backend(backend, interpret)
    with _probe("bt_count_links", resolved,
                shape=(int(links), int(t), int(lanes)), width=width,
                chunked=chunk_rows is not None,
                activity=activity_windows is not None):
        return _entry(_bt_count_links, resolved)(
            streams,
            lengths,
            input_lanes=input_lanes,
            width=width,
            block_rows=block_rows,
            backend=resolved,
            chunk_rows=chunk_rows,
            activity_windows=activity_windows,
        )


def bt_count_variants(
    inputs: jax.Array,
    weights: jax.Array | None = None,
    variants: tuple[Variant, ...] = (Variant("acc"),),
    width: int = 8,
    input_lanes: int = 8,
    weight_lanes: int | None = None,
    pack: str = "lane",
    block_packets: int = 64,
    interpret: bool | None = None,
    backend: str | None = None,
    chunk_packets: int | None = None,
) -> jax.Array:
    """Ordered BT of (P, N) packets under MANY variants in ONE kernel launch.

    The multi-axis measurement restricted to one link and uncoded configs:
    the variant axis lives inside the single launch (one popcount pass per
    block shared by every bucketing), which is what makes a whole
    ``repro.dse`` grid one launch per measured stream.

    Returns:
      int32 (V, 2): per-variant (input-side, weight-side) bit transitions.
    """
    variants = validate_variants(tuple(variants), width)
    weights, weight_lanes = _paired(inputs, weights, weight_lanes, input_lanes)
    configs = tuple(CodecVariant(v.key, v.k, v.descending) for v in variants)
    out = bt_count_axes(
        inputs[None],
        weights[None],
        None,
        configs=configs,
        width=width,
        input_lanes=input_lanes,
        weight_lanes=weight_lanes,
        pack=pack,
        block_packets=block_packets,
        interpret=interpret,
        backend=backend,
        chunk_packets=chunk_packets,
    )
    return out[0, :, :2]


def bt_count_codecs(
    inputs: jax.Array,
    weights: jax.Array | None = None,
    configs: tuple[CodecVariant, ...] = (CodecVariant(),),
    width: int = 8,
    input_lanes: int = 8,
    weight_lanes: int | None = None,
    pack: str = "lane",
    block_packets: int = 64,
    interpret: bool | None = None,
    backend: str | None = None,
    chunk_packets: int | None = None,
    activity_windows: int | None = None,
) -> jax.Array:
    """Coded + ordered BT of (P, N) packets under MANY (ordering, codec)
    configurations in ONE kernel launch.

    The multi-axis measurement restricted to one link: the whole codec x
    ordering grid lives inside the launch (one popcount pass, one reorder
    per distinct ordering, stateful codecs as vectorized per-block prefix
    scans with the wrapper folding the O(G) inter-block carry).

    Returns:
      int32 (C, 3): per-config (input-side BT, weight-side BT, invert-line
      BT) totals.  The invert-line column is the coding overhead the wire
      still pays switching energy for (zero for codecs without extra
      lines).  With ``activity_windows`` the return type becomes
      :class:`AxesActivity` with the one-link axis dropped: bt (C, 3),
      toggles (C, NW, WIRES), ones (C, WIRES).
    """
    weights, weight_lanes = _paired(inputs, weights, weight_lanes, input_lanes)
    out = bt_count_axes(
        inputs[None],
        weights[None],
        None,
        configs=tuple(configs),
        width=width,
        input_lanes=input_lanes,
        weight_lanes=weight_lanes,
        pack=pack,
        block_packets=block_packets,
        interpret=interpret,
        backend=backend,
        chunk_packets=chunk_packets,
        activity_windows=activity_windows,
    )
    if activity_windows is None:
        return out[0]
    return AxesActivity(out.bt[0], out.toggles[0], out.ones[0])


@partial(jax.jit, static_argnames=("block", "backend"))
def _quantize_egress(
    x: jax.Array, *, block: int, backend: str
) -> tuple[jax.Array, jax.Array, jax.Array]:
    m = x.shape[0]
    pad = (-m) % block
    xp = jnp.pad(x.astype(jnp.float32), (0, pad))
    if backend == "compiled":
        q, s = quantize_egress_compiled(xp, block=block)
    else:
        q, s = quantize_egress_pallas(
            xp, block=block, interpret=backend == "interpret"
        )
    return q, s, jnp.int32(m + pad)


def quantize_egress(
    x: jax.Array,
    block: int = 256,
    interpret: bool | None = None,
    backend: str | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Blockwise int8 quantization of a flat vector (pads internally).

    Returns (q, scales, padded_size) where q/scales cover the padded vector;
    callers keep ``padded_size`` to dequantize and trim.
    """
    resolved = resolve_backend(backend, interpret)
    with _probe("quantize_egress", resolved, elems=int(x.shape[0]),
                block=block):
        return _entry(_quantize_egress, resolved)(
            x, block=block, backend=resolved
        )
